"""Make the `compile` package importable when pytest runs from the repo
root (`python -m pytest python/tests`): the package lives at python/compile
but is imported as `compile.*` by the tests."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
