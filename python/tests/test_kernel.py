"""L1 correctness: Pallas precision kernel vs pure-jnp oracle.

Hypothesis sweeps shapes, sparsity, tile sizes and seeds; numerics are
checked with assert_allclose at f32 tolerances.
"""

import numpy as np
import pytest
from _hyp import given, settings, st

from compile.kernels.precision import (
    _pick_tile,
    mxu_flops,
    precision_pallas,
    vmem_bytes,
)
from compile.kernels.ref import precision_ref


def _data(n, d, k, density, seed):
    rng = np.random.default_rng(seed)
    r = rng.normal(size=(n, d)).astype(np.float32)
    m = (rng.random((n, d)) < density).astype(np.float32)
    v = rng.normal(size=(d, k)).astype(np.float32)
    return r * m, m, v


def _check(n, d, k, density, seed, bn=64, bd=128):
    r, m, v = _data(n, d, k, density, seed)
    lam0, b0 = precision_ref(r, m, v)
    lam1, b1 = precision_pallas(r, m, v, bn=bn, bd=bd)
    scale = max(1.0, float(np.abs(lam0).max()))
    np.testing.assert_allclose(lam1, lam0, rtol=1e-4, atol=1e-4 * scale)
    np.testing.assert_allclose(b1, b0, rtol=1e-4, atol=1e-4 * scale)


@pytest.mark.parametrize(
    "n,d,k",
    [(32, 32, 8), (16, 32, 8), (64, 96, 4), (128, 128, 16), (256, 256, 16)],
)
def test_kernel_matches_ref_fixed(n, d, k):
    _check(n, d, k, density=0.3, seed=0)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 96),
    d=st.integers(4, 96),
    k=st.sampled_from([1, 2, 4, 8, 16]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(n, d, k, density, seed):
    _check(n, d, k, density, seed)


@settings(max_examples=15, deadline=None)
@given(
    bn=st.integers(1, 64),
    bd=st.integers(1, 64),
    seed=st.integers(0, 1000),
)
def test_kernel_tile_size_invariance(bn, bd, seed):
    """Result must not depend on the tiling."""
    _check(48, 80, 8, density=0.25, seed=seed, bn=bn, bd=bd)


def test_empty_mask_gives_zero():
    r, m, v = _data(32, 32, 8, density=0.0, seed=3)
    lam, b = precision_pallas(r, m, v)
    assert float(np.abs(lam).max()) == 0.0
    assert float(np.abs(b).max()) == 0.0


def test_full_mask_equals_vtv():
    """With mask == 1, every lam[n] equals V^T V."""
    r, m, v = _data(8, 40, 4, density=1.1, seed=4)
    lam, _ = precision_pallas(r, m, v)
    vtv = v.T @ v
    for n in range(8):
        np.testing.assert_allclose(lam[n], vtv, rtol=1e-4, atol=1e-4)


def test_pick_tile_divides():
    for n in [1, 7, 12, 100, 256]:
        for t in [1, 8, 64]:
            got = _pick_tile(n, t)
            assert n % got == 0 and 1 <= got <= max(1, min(n, t))


def test_vmem_budget_of_default_tiles():
    """Default tiling must stay under a 4 MiB VMEM budget for all K we ship."""
    for k in (8, 16, 32):
        assert vmem_bytes(64, 128, k) < 4 * 1024 * 1024


def test_mxu_flops_positive_and_scales():
    assert mxu_flops(256, 256, 16) == 2 * 256 * 256 * (16 * 16 + 16)
    assert mxu_flops(512, 512, 32) > mxu_flops(256, 256, 32)
