"""Unrolled batched Cholesky/substitution vs numpy.linalg."""

import numpy as np
from _hyp import given, settings, st

from compile.kernels.linalg import (
    batched_cholesky,
    solve_lower,
    solve_upper_t,
    spd_solve,
)


def _spd_batch(n, k, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, k, k)).astype(np.float32)
    spd = np.einsum("nij,nkj->nik", a, a) + 2 * np.eye(k, dtype=np.float32)
    return spd.astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 16), k=st.sampled_from([1, 2, 3, 4, 8, 16]), seed=st.integers(0, 999))
def test_cholesky_matches_numpy(n, k, seed):
    a = _spd_batch(n, k, seed)
    l = np.array(batched_cholesky(a))
    l0 = np.linalg.cholesky(a.astype(np.float64))
    np.testing.assert_allclose(l, l0, rtol=2e-3, atol=2e-3)
    # strict upper triangle is exactly zero
    for i in range(k):
        for j in range(i + 1, k):
            assert np.all(l[:, i, j] == 0.0)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 12), k=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 999))
def test_spd_solve_matches_numpy(n, k, seed):
    a = _spd_batch(n, k, seed)
    rng = np.random.default_rng(seed + 1)
    b = rng.normal(size=(n, k)).astype(np.float32)
    x = np.array(spd_solve(a, b))
    x0 = np.linalg.solve(a.astype(np.float64), b.astype(np.float64)[..., None])[..., 0]
    np.testing.assert_allclose(x, x0, rtol=5e-3, atol=5e-3)


def test_triangular_solves_roundtrip():
    a = _spd_batch(6, 8, 3)
    l = np.array(batched_cholesky(a))
    rng = np.random.default_rng(4)
    y_true = rng.normal(size=(6, 8)).astype(np.float32)
    b = np.einsum("nij,nj->ni", l, y_true)
    y = np.array(solve_lower(l, b))
    np.testing.assert_allclose(y, y_true, rtol=2e-3, atol=2e-3)
    bt = np.einsum("nji,nj->ni", l, y_true)  # L^T y
    x = np.array(solve_upper_t(l, bt))
    np.testing.assert_allclose(x, y_true, rtol=2e-3, atol=2e-3)


def test_k1_edge_case():
    a = np.full((3, 1, 1), 4.0, np.float32)
    l = np.array(batched_cholesky(a))
    np.testing.assert_allclose(l[:, 0, 0], 2.0)
    x = np.array(spd_solve(a, np.full((3, 1), 8.0, np.float32)))
    np.testing.assert_allclose(x[:, 0], 2.0)
