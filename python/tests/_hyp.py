"""Hypothesis import shim.

The CI image installs hypothesis and the property tests run in full; the
offline development image does not ship the wheel, so this module degrades
gracefully: `@given(...)` marks the test as skipped instead of failing
collection, and strategy expressions evaluate to inert placeholders.
"""

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # offline image: no hypothesis wheel
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Chainable stand-in so module-level strategy expressions like
        `st.integers(1, 8).filter(...)` still evaluate."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Strategy()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
