"""L2 correctness: sample_side / predict graphs vs per-row numpy linalgebra."""

import numpy as np
import pytest
from _hyp import given, settings, st

from compile import model


def _problem(n, d, k, density, seed, tau=1.5):
    rng = np.random.default_rng(seed)
    r = rng.normal(size=(n, d)).astype(np.float32)
    m = (rng.random((n, d)) < density).astype(np.float32)
    r = r * m
    v = (rng.normal(size=(d, k)) * 0.3).astype(np.float32)
    pm = (rng.normal(size=(n, k)) * 0.1).astype(np.float32)
    a = rng.normal(size=(n, k, k)).astype(np.float32)
    pp = np.einsum("nij,nkj->nik", a, a).astype(np.float32) + 2 * np.eye(
        k, dtype=np.float32
    )
    noise = rng.normal(size=(n, k)).astype(np.float32)
    return r, m, v, pm, pp, noise, np.float32(tau)


def _numpy_sample_side(r, m, v, pm, pp, noise, tau):
    n, k = pm.shape
    samples = np.zeros_like(pm)
    means = np.zeros_like(pm)
    for i in range(n):
        prec = pp[i] + tau * np.einsum("d,dk,dl->kl", m[i], v, v)
        rhs = pp[i] @ pm[i] + tau * (m[i] * r[i]) @ v
        mean = np.linalg.solve(prec, rhs)
        chol = np.linalg.cholesky(prec)
        samples[i] = mean + np.linalg.solve(chol.T, noise[i])
        means[i] = mean
    return samples, means


@pytest.mark.parametrize("use_pallas", [True, False])
@pytest.mark.parametrize("n,d,k", [(16, 24, 4), (32, 32, 8), (8, 64, 16)])
def test_sample_side_matches_numpy(n, d, k, use_pallas):
    args = _problem(n, d, k, density=0.4, seed=0)
    s, mu = model.sample_side(*args, use_pallas=use_pallas)
    s0, mu0 = _numpy_sample_side(*args)
    np.testing.assert_allclose(np.array(mu), mu0, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.array(s), s0, rtol=3e-4, atol=3e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 48),
    d=st.integers(2, 48),
    k=st.sampled_from([2, 4, 8]),
    density=st.floats(0.05, 1.0),
    seed=st.integers(0, 10_000),
)
def test_sample_side_hypothesis(n, d, k, density, seed):
    args = _problem(n, d, k, density, seed)
    s, mu = model.sample_side(*args)
    s0, mu0 = _numpy_sample_side(*args)
    np.testing.assert_allclose(np.array(mu), mu0, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.array(s), s0, rtol=1e-3, atol=1e-3)


def test_sample_side_no_observations_returns_prior():
    """With an empty mask and zero noise, sample == prior mean."""
    r, m, v, pm, pp, _, tau = _problem(12, 20, 4, density=0.0, seed=2)
    noise = np.zeros_like(pm)
    s, mu = model.sample_side(r, m, v, pm, pp, noise, tau)
    np.testing.assert_allclose(np.array(s), pm, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(mu), pm, rtol=1e-4, atol=1e-4)


def test_sample_side_is_deterministic_given_noise():
    args = _problem(10, 10, 4, density=0.5, seed=3)
    s1, _ = model.sample_side(*args)
    s2, _ = model.sample_side(*args)
    np.testing.assert_array_equal(np.array(s1), np.array(s2))


def test_predict_sse_matches_numpy():
    rng = np.random.default_rng(5)
    n, d, k = 20, 30, 4
    u = rng.normal(size=(n, k)).astype(np.float32)
    v = rng.normal(size=(d, k)).astype(np.float32)
    r = rng.normal(size=(n, d)).astype(np.float32)
    m = (rng.random((n, d)) < 0.3).astype(np.float32)
    sse, cnt = model.predict_sse(u, v, r, m)
    err = (u @ v.T - r) * m
    np.testing.assert_allclose(float(sse), float((err**2).sum()), rtol=1e-4)
    assert float(cnt) == float(m.sum())


def test_predict_mean_var_shapes_and_consistency():
    rng = np.random.default_rng(6)
    s, n, d, k = 5, 8, 9, 3
    us = rng.normal(size=(s, n, k)).astype(np.float32)
    vs = rng.normal(size=(s, d, k)).astype(np.float32)
    m = np.ones((n, d), np.float32)
    mean, var = model.predict_mean_var(us, vs, m)
    preds = np.einsum("snk,sdk->snd", us, vs)
    np.testing.assert_allclose(np.array(mean), preds.mean(0), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(var), preds.var(0), rtol=1e-3, atol=1e-3)
    assert (np.array(var) >= 0).all()
