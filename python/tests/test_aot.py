"""AOT exporter tests: HLO text well-formedness + manifest round-trip."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


def test_lower_sample_side_produces_hlo_text():
    text = aot.to_hlo_text(aot.lower_sample_side(16, 32, 8))
    assert "HloModule" in text
    assert "ENTRY" in text
    # tuple return convention (return_tuple=True) — rust unwraps with to_tuple
    assert "tuple" in text


def test_lower_predict_produces_hlo_text():
    text = aot.to_hlo_text(aot.lower_predict_sse(16, 32, 8))
    assert "HloModule" in text


def test_ref_and_pallas_flavors_lower():
    t1 = aot.to_hlo_text(aot.lower_sample_side(16, 32, 8, use_pallas=True))
    t2 = aot.to_hlo_text(aot.lower_sample_side(16, 32, 8, use_pallas=False))
    assert "HloModule" in t1 and "HloModule" in t2


def test_registered_shapes_are_sane():
    for n, d, k in aot.SAMPLE_SHAPES:
        assert n > 0 and d > 0 and k > 0
        assert k in (4, 8, 16, 32)
    # every predict shape must have a matching sample shape (same N,D,K)
    for shape in aot.PREDICT_SHAPES:
        assert shape in aot.SAMPLE_SHAPES


def test_no_custom_calls_in_lowered_hlo():
    """Regression: the pinned PJRT runtime (xla_extension 0.5.1) cannot run
    LAPACK/FFI custom-calls; jnp.linalg on CPU would emit them. Everything
    must lower to plain HLO ops (kernels/linalg.py exists for this)."""
    for n, d, k in [(16, 32, 8), (32, 32, 8), (64, 48, 16)]:
        text = aot.to_hlo_text(aot.lower_sample_side(n, d, k))
        assert "custom-call" not in text, f"custom-call leaked into {n}x{d}x{k}"
        text = aot.to_hlo_text(aot.lower_predict_sse(n, d, k))
        assert "custom-call" not in text


def test_rectangular_shapes_registered():
    """The runtime relies on tall-narrow artifacts to bound padding waste."""
    tall = [(n, d) for n, d, _ in aot.SAMPLE_SHAPES if n >= 4 * d]
    assert tall, "no tall-narrow artifact shapes registered"


def test_cli_writes_manifest(tmp_path):
    out = tmp_path / "arts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--only-test-shapes"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    names = {e["name"] for e in manifest["artifacts"]}
    assert "sample_side_32x32x8" in names
    for e in manifest["artifacts"]:
        p = out / e["file"]
        assert p.exists() and p.stat().st_size > 0
        assert {"name", "kind", "n", "d", "k", "file", "flavor"} <= set(e)
