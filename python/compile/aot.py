"""AOT exporter: lower the L2 graphs to HLO *text* + manifest.json.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
`make artifacts` wraps this and is a no-op when inputs are unchanged.

Artifact inventory (shapes are compile-time; the rust runtime pads blocks
with mask=0 into the smallest registered shape that fits):

  sample_side_<N>x<D>x<K>  inputs:  ratings(N,D) mask(N,D) v(D,K)
                                    prior_mean(N,K) prior_prec(N,K,K)
                                    noise(N,K) tau()
                           outputs: (sample(N,K), mean(N,K))
  predict_sse_<N>x<D>x<K>  inputs:  u(N,K) v(D,K) ratings(N,D) mask(N,D)
                           outputs: (sse(), cnt())
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (N, D, K) shapes registered with the rust runtime. Keep this list in sync
# with what the benches/examples need; adding a shape only costs AOT time.
SAMPLE_SHAPES = [
    # test / CI shapes
    (32, 32, 8),
    (16, 32, 8),
    # main block shapes per K (K=8: movielens/amazon profile, K=16: general,
    # K=32: netflix/yahoo profile, paper-K=100 scaled)
    (256, 256, 8),
    (128, 256, 8),
    (64, 256, 8),
    (256, 256, 16),
    (128, 256, 16),
    (64, 256, 16),
    (512, 512, 16),
    (256, 512, 16),
    (128, 512, 16),
    (256, 256, 32),
    (512, 512, 32),
    (256, 512, 32),
    (128, 512, 32),
    # rectangular shapes: tall-narrow blocks (Netflix-like aspect) and
    # short-wide shards — cut the mask-padding waste vs square artifacts
    (256, 64, 8),
    (512, 64, 8),
    (512, 128, 8),
    (256, 64, 16),
    (512, 64, 16),
    (512, 128, 16),
    (1024, 64, 16),
    (512, 64, 32),
    (512, 128, 32),
]

PREDICT_SHAPES = [
    (32, 32, 8),
    (256, 256, 8),
    (256, 256, 16),
    (512, 512, 16),
    (256, 256, 32),
    (512, 512, 32),
]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_sample_side(n, d, k, use_pallas=True):
    fn = functools.partial(model.sample_side, use_pallas=use_pallas)
    return jax.jit(fn).lower(
        f32(n, d),  # ratings
        f32(n, d),  # mask
        f32(d, k),  # v
        f32(n, k),  # prior_mean
        f32(n, k, k),  # prior_prec
        f32(n, k),  # noise
        f32(),  # tau
    )


def lower_predict_sse(n, d, k):
    return jax.jit(model.predict_sse).lower(f32(n, k), f32(d, k), f32(n, d), f32(n, d))


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument(
        "--flavor",
        choices=["pallas", "ref"],
        default="pallas",
        help="L1 implementation lowered into sample_side (ref = pure-jnp oracle)",
    )
    p.add_argument("--only-test-shapes", action="store_true")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    sample_shapes = SAMPLE_SHAPES[:2] if args.only_test_shapes else SAMPLE_SHAPES
    predict_shapes = PREDICT_SHAPES[:1] if args.only_test_shapes else PREDICT_SHAPES

    entries = []
    for n, d, k in sample_shapes:
        name = f"sample_side_{n}x{d}x{k}"
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        text = to_hlo_text(lower_sample_side(n, d, k, use_pallas=args.flavor == "pallas"))
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {"name": name, "kind": "sample_side", "n": n, "d": d, "k": k,
             "file": os.path.basename(path), "flavor": args.flavor}
        )
        print(f"wrote {path} ({len(text)} chars)")

    for n, d, k in predict_shapes:
        name = f"predict_sse_{n}x{d}x{k}"
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        text = to_hlo_text(lower_predict_sse(n, d, k))
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {"name": name, "kind": "predict_sse", "n": n, "d": d, "k": k,
             "file": os.path.basename(path), "flavor": "ref"}
        )
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(entries)} artifacts")


if __name__ == "__main__":
    main()
