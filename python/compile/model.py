"""L2: the BPMF Gibbs half-sweep and evaluation graphs, in JAX.

These are the compute graphs the rust coordinator executes at runtime (AOT
lowered to HLO text by aot.py). Python never runs on the request path.

The central export is `sample_side`: one conditional Gibbs update of the N
factor rows of ONE side of the factorization, given the D opposite-side
factor rows. It is used for BOTH the U-side (fed the block as-is) and the
V-side (fed the transposed block) — this is exactly the alternating
structure of the BPMF sampler of Salakhutdinov & Mnih (2008), and the unit
of work each within-block shard worker executes in the distributed BMF
implementation (Vander Aa et al. 2017).

All randomness is injected by the caller as standard-normal `noise`; the
graph is deterministic. Per-row Gaussian priors (prior_mean, prior_prec)
carry both the Normal-Wishart hyperparameter prior of plain BPMF (all rows
identical) and the Posterior-Propagation propagated marginals of phases
(b)/(c) (row-specific).
"""

import jax
import jax.numpy as jnp

from .kernels.linalg import batched_cholesky, solve_lower, solve_upper_t
from .kernels.precision import precision_pallas
from .kernels.ref import precision_ref


def sample_side(ratings, mask, v, prior_mean, prior_prec, noise, tau, *, use_pallas=True):
    """One conditional Gibbs update for N factor rows given V.

    For each row n, the conditional posterior is Gaussian:

        Prec_n = prior_prec[n] + tau * sum_d mask[n,d] v_d v_d^T
        mu_n   = Prec_n^{-1} (prior_prec[n] prior_mean[n]
                              + tau * sum_d mask[n,d] r_nd v_d)
        u_n    = mu_n + L_n^{-T} noise[n],   Prec_n = L_n L_n^T

    Args:
      ratings:    (N, D) f32 dense block (zeros where unobserved).
      mask:       (N, D) f32 indicator.
      v:          (D, K) f32 opposite-side factors.
      prior_mean: (N, K) f32 per-row prior means.
      prior_prec: (N, K, K) f32 per-row prior precisions (SPD).
      noise:      (N, K) f32 standard normal draws.
      tau:        () f32 residual noise precision.

    Returns:
      sample: (N, K) the Gibbs draw.
      mean:   (N, K) the conditional posterior mean (Rao-Blackwellised
              moment accumulation on the rust side uses this).
    """
    if use_pallas:
        lam, b = precision_pallas(ratings, mask, v)
    else:
        lam, b = precision_ref(ratings, mask, v)
    prec = prior_prec + tau * lam  # (N, K, K)
    rhs = jnp.einsum("nkl,nl->nk", prior_prec, prior_mean) + tau * b  # (N, K)

    # Batched Cholesky + substitutions unrolled over K (kernels/linalg.py):
    # pure-HLO ops — the pinned PJRT runtime cannot execute LAPACK
    # custom-calls that jnp.linalg would emit on CPU.
    chol = batched_cholesky(prec)  # (N, K, K)
    mean = solve_upper_t(chol, solve_lower(chol, rhs))
    # x ~ N(0, Prec^{-1}):  x = L^{-T} eps.
    z = solve_upper_t(chol, noise)
    sample = mean + z
    return sample, mean


def predict_sse(u, v, ratings, mask):
    """Masked sum of squared prediction errors and observation count.

    Returns (sse, cnt) as () f32 each; the rust side streams these over
    blocks to form RMSE = sqrt(sum sse / sum cnt).
    """
    pred = u @ v.T
    err = (pred - ratings) * mask
    return jnp.sum(err * err), jnp.sum(mask)


def predict_mean_var(u_samples, v_samples, mask):
    """Posterior predictive mean and variance from S factor samples.

    Args:
      u_samples: (S, N, K), v_samples: (S, D, K), mask: (N, D).
    Returns:
      (mean, var): (N, D) each, masked.
    """
    preds = jnp.einsum("snk,sdk->snd", u_samples, v_samples)
    mean = preds.mean(axis=0) * mask
    var = preds.var(axis=0) * mask
    return mean, var
