"""Batched K x K linear algebra as pure-HLO ops (no LAPACK custom calls).

jax's `jnp.linalg.cholesky` / `solve_triangular` lower on CPU to
`lapack_*_ffi` custom-calls (API_VERSION_TYPED_FFI), which the pinned
xla_extension 0.5.1 PJRT runtime cannot execute. Since K is a compile-time
constant (<= 32) we unroll Cholesky and the triangular substitutions over K
as vectorized ops batched over N — everything lowers to plain dot/mul/add
HLO that any PJRT backend runs.

Numerically this is the standard Cholesky-Banachiewicz column recurrence in
f32, adequate for the SPD posterior precisions of BPMF (prior precision
ridges every matrix away from singularity).
"""

import jax.numpy as jnp


def batched_cholesky(a):
    """Lower-triangular L with a = L L^T, batched.

    Args:
      a: (N, K, K) SPD matrices.
    Returns:
      (N, K, K) lower-triangular factors (strict upper = 0).
    """
    n, k, _ = a.shape
    l = jnp.zeros_like(a)
    for j in range(k):
        if j > 0:
            # s[:, i] = a[:, j+i, j] - sum_m l[:, j+i, m] * l[:, j, m]
            s = a[:, j:, j] - jnp.einsum("nim,nm->ni", l[:, j:, :j], l[:, j, :j])
        else:
            s = a[:, j:, j]
        d = jnp.sqrt(s[:, 0:1])  # (N, 1)
        if k - j > 1:
            col = jnp.concatenate([d, s[:, 1:] / d], axis=1)  # (N, K-j)
        else:
            col = d
        l = l.at[:, j:, j].set(col)
    return l


def solve_lower(l, b):
    """Solve L y = b (forward substitution), batched.

    Args:
      l: (N, K, K) lower-triangular; b: (N, K).
    Returns:
      y: (N, K).
    """
    n, k, _ = l.shape
    ys = []
    for i in range(k):
        acc = b[:, i]
        if i > 0:
            stack = jnp.stack(ys, axis=1)  # (N, i)
            acc = acc - jnp.einsum("nm,nm->n", l[:, i, :i], stack)
        ys.append(acc / l[:, i, i])
    return jnp.stack(ys, axis=1)


def solve_upper_t(l, b):
    """Solve L^T x = b (back substitution on the transpose), batched.

    Args:
      l: (N, K, K) lower-triangular; b: (N, K).
    Returns:
      x: (N, K).
    """
    n, k, _ = l.shape
    xs = [None] * k
    for i in reversed(range(k)):
        acc = b[:, i]
        if i < k - 1:
            stack = jnp.stack(xs[i + 1 :], axis=1)  # (N, K-1-i)
            # (L^T)[i, m] = L[m, i] for m > i
            acc = acc - jnp.einsum("nm,nm->n", l[:, i + 1 :, i], stack)
        xs[i] = acc / l[:, i, i]
    return jnp.stack(xs, axis=1)


def spd_solve(a, b):
    """Solve a x = b for SPD a via Cholesky, batched: (N,K,K), (N,K) -> (N,K)."""
    l = batched_cholesky(a)
    return solve_upper_t(l, solve_lower(l, b))
