"""Pure-jnp reference oracle for the Gibbs hot-spot kernel.

The hot spot of a BPMF Gibbs half-sweep is, for every factor row n of the
side being updated, the accumulation over observed entries of the opposite
side's factors:

    lam[n] = sum_d mask[n,d] * v[d] v[d]^T          (N,K,K)
    b[n]   = sum_d mask[n,d] * ratings[n,d] * v[d]  (N,K)

This file is the correctness oracle the Pallas kernel (precision.py) is
tested against; it is also what model.py lowers when built with
use_pallas=False (the "ref" artifact flavour used in A/B perf tests).
"""

import jax.numpy as jnp


def precision_ref(ratings, mask, v):
    """Unscaled precision contributions and rhs for one side.

    Args:
      ratings: (N, D) dense block of observed ratings (zeros where unobserved).
      mask:    (N, D) indicator, 1.0 where observed.
      v:       (D, K) opposite-side factors.

    Returns:
      lam: (N, K, K) = einsum('nd,dk,dl->nkl', mask, v, v)
      b:   (N, K)    = (mask * ratings) @ v
    """
    lam = jnp.einsum("nd,dk,dl->nkl", mask, v, v)
    b = (mask * ratings) @ v
    return lam, b
