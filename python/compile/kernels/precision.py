"""L1 Pallas kernel: batched Gibbs precision/rhs accumulation.

Computes, for a tile of N factor rows against all D opposite-side factors,

    lam[n] = sum_d mask[n,d] * v[d] v[d]^T          (N,K,K)
    b[n]   = sum_d mask[n,d] * ratings[n,d] * v[d]  (N,K)

tiled so each (user-tile x item-tile) step streams one VMEM-sized block of
the ratings/mask matrices and one item-tile of V from HBM, and accumulates
the K x K precision blocks in the (revisited) output tile.

TPU adaptation of the paper's CPU/MPI hot loop (DESIGN.md
Hardware-Adaptation): the per-row sparse gather of the original CSR
implementation becomes a dense masked rank-K accumulation, which is
MXU-shaped work: the inner contraction is a (BN*K, BD) x (BD, K) matmul.

Must be lowered with interpret=True: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (tiles must divide evenly)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


def _precision_kernel(r_ref, m_ref, v_ref, lam_ref, b_ref):
    """One grid step: accumulate item-tile j's contribution for user-tile i.

    Shapes inside the kernel:
      r_ref, m_ref: (BN, BD)   ratings / mask tile
      v_ref:        (BD, K)    opposite-side factor tile
      lam_ref:      (BN, K, K) accumulator (revisited across j)
      b_ref:        (BN, K)    accumulator (revisited across j)
    """
    j = pl.program_id(1)

    m = m_ref[...]
    r = r_ref[...]
    v = v_ref[...]

    # masked_v[n, d, :] = mask[n, d] * v[d]  -> (BN, BD, K)
    masked_v = m[:, :, None] * v[None, :, :]
    # lam[n] = masked_v[n]^T-contraction with v over d: (BN, K, K).
    # dot_general: contract dim 1 (d) of masked_v with dim 0 (d) of v,
    # batching over n — expressed as one reshaped MXU matmul per user tile.
    lam_tile = jax.lax.dot_general(
        masked_v,
        v,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BN, K, K)
    b_tile = jnp.dot(m * r, v, preferred_element_type=jnp.float32)  # (BN, K)

    @pl.when(j == 0)
    def _init():
        lam_ref[...] = lam_tile
        b_ref[...] = b_tile

    @pl.when(j > 0)
    def _acc():
        lam_ref[...] += lam_tile
        b_ref[...] += b_tile


@functools.partial(jax.jit, static_argnames=("bn", "bd"))
def precision_pallas(ratings, mask, v, *, bn: int = 64, bd: int = 128):
    """Pallas-tiled version of kernels.ref.precision_ref.

    Args:
      ratings, mask: (N, D) f32.
      v: (D, K) f32.
      bn, bd: requested user/item tile sizes (clamped to divisors).

    Returns:
      (lam, b): (N, K, K), (N, K) — identical (up to float addition order)
      to precision_ref.
    """
    n, d = ratings.shape
    k = v.shape[1]
    bn = _pick_tile(n, bn)
    bd = _pick_tile(d, bd)
    grid = (n // bn, d // bd)

    return pl.pallas_call(
        _precision_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),  # ratings
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),  # mask
            pl.BlockSpec((bd, k), lambda i, j: (j, 0)),  # v
        ],
        out_specs=[
            pl.BlockSpec((bn, k, k), lambda i, j: (i, 0, 0)),  # lam (revisited)
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),  # b   (revisited)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k, k), jnp.float32),
            jax.ShapeDtypeStruct((n, k), jnp.float32),
        ],
        interpret=True,
    )(ratings, mask, v)


def vmem_bytes(bn: int, bd: int, k: int) -> int:
    """Estimated VMEM footprint of one grid step (f32)."""
    tiles = bn * bd * 2  # ratings + mask
    vtile = bd * k
    masked = bn * bd * k  # the masked_v intermediate
    acc = bn * k * k + bn * k
    return 4 * (tiles + vtile + masked + acc)


def mxu_flops(n: int, d: int, k: int) -> int:
    """MAC count of the lam contraction (the MXU-shaped work)."""
    return 2 * n * d * k * k + 2 * n * d * k
