//! Block-size exploration (paper §3.3, Fig. 3): sweep I×J grids on a
//! Netflix-profile dataset and print the RMSE / wall-clock / block-aspect
//! trade-off table — the data behind the paper's bubble plot.
//!
//!     cargo run --release --example blocksize_explore

use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{Engine, TrainConfig};
use bmf_pp::data::generator::SyntheticDataset;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::partition::balance;

fn main() -> anyhow::Result<()> {
    bmf_pp::util::logging::init();
    // Netflix profile: 27x more rows than columns — the shape that makes
    // grid choice interesting
    let ds = SyntheticDataset::by_name("netflix", 0.0018, 21).expect("profile");
    let (train, test) = holdout_split_covered(&ds.ratings, 0.2, 22);
    let tau = auto_tau(&train);
    println!(
        "netflix-profile {}x{} ({} ratings, rows/cols={:.1})",
        train.rows,
        train.cols,
        train.nnz(),
        train.rows as f64 / train.cols as f64
    );
    println!("{:<8} {:>8} {:>10} {:>10} {:>8}", "grid", "aspect", "rmse", "wall(s)", "blocks");

    let grids: &[(usize, usize)] =
        &[(1, 1), (2, 2), (4, 4), (8, 8), (4, 1), (8, 2), (16, 2), (20, 3), (12, 2)];
    let mut best: Option<(f64, (usize, usize))> = None;
    // one warm engine serves the whole grid sweep — no pool re-spawn (and
    // no HLO recompilation under `pjrt`) between the nine runs
    let base = TrainConfig::new(ds.k);
    let engine = Engine::new(&base.backend, base.block_parallelism);
    for &(i, j) in grids {
        if i > train.rows || j > train.cols {
            continue;
        }
        let cfg = TrainConfig::new(ds.k)
            .with_grid(i, j)
            .with_sweeps(8, 16)
            .with_tau(tau)
            .with_seed(5);
        let res = engine.train(&cfg, &train)?;
        let rmse = res.rmse(&test);
        let aspect = balance::block_aspect(train.rows, train.cols, i, j);
        println!(
            "{:<8} {:>8.2} {:>10.4} {:>10.2} {:>8}",
            format!("{i}x{j}"),
            aspect,
            rmse,
            res.timings.total,
            res.stats.blocks
        );
        // paper's trade-off score: prefer fast runs that keep RMSE low
        let score = rmse + 0.02 * res.timings.total / res.stats.blocks.max(1) as f64;
        if best.map(|(s, _)| score < s).unwrap_or(true) {
            best = Some((score, (i, j)));
        }
    }
    if let Some((_, (i, j))) = best {
        let aspect = balance::block_aspect(train.rows, train.cols, i, j);
        println!("\nbest trade-off: {i}x{j} (block aspect {aspect:.2})");
        println!("paper finding: near-square blocks win; Netflix's 27:1 shape → row-heavy grids");
    }
    Ok(())
}
