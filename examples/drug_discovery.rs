//! Drug-discovery scenario (paper §1 motivation): compound × protein-target
//! bioactivity matrix factorization where the Bayesian posterior's
//! *uncertainty quantification* is the point — triaging which unmeasured
//! compound-target pairs to assay next.
//!
//!     cargo run --release --example drug_discovery
//!
//! Demonstrates: posterior predictive mean ± std, empirical coverage of the
//! ±2σ interval on held-out data, the PosteriorModel's top-N ranking
//! (greedy by predicted activity), and an "acquisition" ranking (high
//! predicted activity + high uncertainty, UCB-style).

use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{BackendSpec, Engine, TrainConfig};
use bmf_pp::data::generator::{DatasetProfile, SyntheticDataset};
use bmf_pp::data::split::holdout_split_covered;

fn main() -> anyhow::Result<()> {
    bmf_pp::util::logging::init();

    // a compound x target activity matrix: reuse the generator with a
    // custom profile — pIC50-like values in [4, 10]
    let profile = DatasetProfile {
        name: "chembl-like",
        paper_rows: 50_000,
        paper_cols: 2_000,
        paper_ratings: 600_000,
        min_rating: 4.0,
        max_rating: 10.0,
        paper_k: 16,
        k: 8,
    };
    let ds = SyntheticDataset::generate(profile, 0.01, 101);
    let (train, test) = holdout_split_covered(&ds.ratings, 0.25, 102);
    println!(
        "bioactivity matrix: {} compounds x {} targets, {} measured ({} held out)",
        train.rows,
        train.cols,
        train.nnz(),
        test.nnz()
    );

    let cfg = TrainConfig::new(ds.k)
        .with_grid(4, 2)
        .with_sweeps(10, 32)
        .with_tau(auto_tau(&train))
        .with_seed(103);
    let engine = Engine::new(&cfg.backend, cfg.block_parallelism);
    let model = engine.train(&cfg, &train)?.into_model();
    println!("test RMSE: {:.3} (pIC50 units)", model.rmse(&test));

    // calibration: fraction of held-out activities inside mean ± 2σ
    // (σ from factor posterior + residual noise)
    let residual_var = 1.0 / auto_tau(&train);
    let mut inside = 0usize;
    for e in &test.entries {
        let (r, c) = (e.row as usize, e.col as usize);
        let mu = model.predict(r, c);
        let sigma = (model.predict_variance(r, c) + residual_var).sqrt();
        if (e.val as f64 - mu).abs() <= 2.0 * sigma {
            inside += 1;
        }
    }
    let coverage = inside as f64 / test.nnz() as f64;
    println!("±2σ empirical coverage: {:.1}% (nominal 95%)", coverage * 100.0);

    // acquisition: among unmeasured pairs of the most-assayed compound,
    // rank next assays
    let compound = (0..train.rows)
        .max_by_key(|&r| train.entries.iter().filter(|e| e.row as usize == r).count())
        .unwrap();
    let measured: std::collections::HashSet<usize> = train
        .entries
        .iter()
        .filter(|e| e.row as usize == compound)
        .map(|e| e.col as usize)
        .collect();

    // greedy ranking straight off the model: highest predicted activity
    println!("\ntop-5 unmeasured targets for compound {compound} by predicted pIC50:");
    for (c, mu) in model.top_n_where(compound, 5, |c| !measured.contains(&c)) {
        println!("  target {c:<6} predicted pIC50 {mu:.2}");
    }

    // exploration-aware ranking: UCB = mean + sigma from the posterior
    let mut candidates: Vec<(usize, f64, f64)> = (0..train.cols)
        .filter(|c| !measured.contains(c))
        .map(|c| {
            let mu = model.predict(compound, c);
            let sigma = (model.predict_variance(compound, c) + residual_var).sqrt();
            (c, mu, sigma)
        })
        .collect();
    candidates.sort_by(|a, b| (b.1 + b.2).partial_cmp(&(a.1 + a.2)).unwrap());
    println!("\ntop-5 next assays for compound {compound} (UCB = mean + sigma):");
    for (c, mu, sigma) in candidates.iter().take(5) {
        println!("  target {c:<6} predicted pIC50 {mu:.2} ± {sigma:.2}");
    }

    assert!(coverage > 0.75, "posterior intervals badly miscalibrated: {coverage}");
    println!("drug_discovery OK");
    Ok(())
}
