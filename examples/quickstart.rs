//! Quickstart: factorize a synthetic Movielens-like matrix with D-BMF+PP.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the whole public API in ~50 lines: generate data, split, configure
//! a PP grid, train (through the AOT HLO runtime when `make artifacts` has
//! run, else the native sampler), evaluate RMSE and inspect uncertainty.

use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{PpTrainer, TrainConfig};
use bmf_pp::data::generator::SyntheticDataset;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::metrics::rmse::mean_predictor_rmse;

fn main() -> anyhow::Result<()> {
    bmf_pp::util::logging::init();

    // 1. a small Movielens-profile synthetic dataset (~200x40, dense-ish)
    let ds = SyntheticDataset::by_name("movielens", 0.002, 7).expect("profile");
    let (train, test) = holdout_split_covered(&ds.ratings, 0.2, 8);
    println!(
        "data: {}x{} with {} train / {} test ratings",
        train.rows,
        train.cols,
        train.nnz(),
        test.nnz()
    );

    // 2. configure Posterior Propagation: a 2x2 block grid, 10 burn-in
    //    sweeps then 24 retained samples per block
    let cfg = TrainConfig::new(ds.k)
        .with_grid(2, 2)
        .with_sweeps(10, 24)
        .with_tau(auto_tau(&train))
        .with_seed(1);

    // 3. train — phases (a), (b), (c) + posterior aggregation
    let result = PpTrainer::new(cfg).train(&train)?;

    // 4. evaluate
    let rmse = result.rmse(&test);
    let baseline = mean_predictor_rmse(train.mean(), &test);
    println!("test RMSE  : {rmse:.4}");
    println!("mean-pred  : {baseline:.4}  (sanity baseline)");
    println!(
        "phases     : a={:.2}s b={:.2}s c={:.2}s (total {:.2}s over {} blocks)",
        result.timings.a,
        result.timings.b,
        result.timings.c,
        result.timings.total,
        result.stats.blocks
    );

    // 5. Bayesian bonus: per-prediction uncertainty from the posterior
    let e = &test.entries[0];
    let (r, c) = (e.row as usize, e.col as usize);
    let mean = result.predict(r, c);
    let std = result.predict_variance(r, c).sqrt();
    println!("example prediction ({r},{c}): {mean:.2} ± {std:.2} (true {})", e.val);

    assert!(rmse < baseline, "PP must beat the mean predictor");
    println!("quickstart OK");
    Ok(())
}
