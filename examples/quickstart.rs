//! Quickstart: factorize a synthetic Movielens-like matrix with D-BMF+PP
//! through the Engine/Session API.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the whole public API in ~60 lines: generate data, split, build a
//! warm Engine, submit a run and watch its typed progress events stream,
//! then use the servable PosteriorModel — RMSE, per-cell uncertainty and
//! top-N ranking.

use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{BackendSpec, Engine, TrainConfig, TrainEvent};
use bmf_pp::data::generator::SyntheticDataset;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::metrics::rmse::mean_predictor_rmse;

fn main() -> anyhow::Result<()> {
    bmf_pp::util::logging::init();

    // 1. a small Movielens-profile synthetic dataset (~200x40, dense-ish)
    let ds = SyntheticDataset::by_name("movielens", 0.002, 7).expect("profile");
    let (train, test) = holdout_split_covered(&ds.ratings, 0.2, 8);
    println!(
        "data: {}x{} with {} train / {} test ratings",
        train.rows,
        train.cols,
        train.nnz(),
        test.nnz()
    );

    // 2. one warm engine (the HLO/PJRT backend when `make artifacts` has
    //    run, else the native sampler) + a PP config: 2x2 block grid,
    //    10 burn-in sweeps then 24 retained samples per block
    let engine = Engine::new(&BackendSpec::auto_default(), 4);
    let cfg = TrainConfig::new(ds.k)
        .with_grid(2, 2)
        .with_sweeps(10, 24)
        .with_tau(auto_tau(&train))
        .with_seed(1);

    // 3. submit and watch the run live: phases (a), (b), (c) + aggregation
    //    (the session handle could also pause/resume/cancel the run —
    //    see `bmf-pp jobs` for the multi-session lifecycle demo)
    let session = engine.submit(cfg, &train)?;
    for event in session.events() {
        match event {
            TrainEvent::PhaseStarted { phase } => println!("  phase ({phase}) started"),
            TrainEvent::BlockCompleted { node, secs, sweeps, .. } => {
                println!("  block {node:?} done: {sweeps} sweeps in {secs:.2}s")
            }
            TrainEvent::Finished { secs, blocks } => {
                println!("  finished: {blocks} blocks in {secs:.2}s")
            }
            TrainEvent::SweepSample { .. } => {} // per-sweep RMSE, see movielens_e2e
            _ => {} // chunk exchange / lifecycle events, not used here
        }
    }
    // wait() reports how the run ended; into_result() treats a cancel
    // (impossible here — nobody cancels) as an error
    let result = session.wait()?.into_result()?;

    // 4. evaluate the servable model
    let model = &result.model;
    let rmse = model.rmse(&test);
    let baseline = mean_predictor_rmse(train.mean(), &test);
    println!("test RMSE  : {rmse:.4}");
    println!("mean-pred  : {baseline:.4}  (sanity baseline)");
    println!(
        "phases     : a={:.2}s b={:.2}s c={:.2}s (total {:.2}s over {} blocks)",
        result.timings.a,
        result.timings.b,
        result.timings.c,
        result.timings.total,
        result.stats.blocks
    );

    // 5. Bayesian bonus: per-prediction uncertainty from the posterior
    let e = &test.entries[0];
    let (r, c) = (e.row as usize, e.col as usize);
    let mean = model.predict(r, c);
    let std = model.predict_variance(r, c).sqrt();
    println!("example prediction ({r},{c}): {mean:.2} ± {std:.2} (true {})", e.val);

    // 6. serving primitive: top-N ranking for one row
    println!("top-3 columns for row {r} by posterior mean:");
    for (col, score) in model.top_n(r, 3) {
        println!("  col {col:<6} predicted {score:.2}");
    }

    assert!(rmse < baseline, "PP must beat the mean predictor");
    println!("quickstart OK");
    Ok(())
}
