//! End-to-end validation run (DESIGN.md §6): a Movielens-profile workload
//! through the FULL three-layer stack — rust PP coordinator scheduling
//! blocks, each Gibbs half-sweep executing the AOT-compiled HLO (Pallas
//! kernel + JAX model) on the PJRT runtime — for a few hundred Gibbs
//! sweeps total, logging the RMSE-vs-sweep learning curve.
//!
//!     make artifacts && cargo run --release --example movielens_e2e
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end. Falls back to the
//! native backend when artifacts are missing (CI without python). One
//! Engine carries every run, so the per-thread PJRT engines (compiled
//! executables) stay warm across the whole curve, and the in-training
//! sweep RMSE stream is recorded live off the session's event stream.

use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{BackendSpec, Engine, TrainConfig};
use bmf_pp::data::generator::SyntheticDataset;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::metrics::recorder::Recorder;
use bmf_pp::metrics::rmse::mean_predictor_rmse;
use bmf_pp::metrics::throughput::Throughput;
use bmf_pp::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    bmf_pp::util::logging::init();
    let spec = BackendSpec::auto_default();
    let backend_name = match spec.resolve() {
        BackendSpec::Hlo { .. } => "HLO/PJRT (AOT artifacts)",
        _ => "native (run `make artifacts` for the HLO path)",
    };

    // Movielens profile, scaled to ~830x160 with ~80k ratings
    let ds = SyntheticDataset::by_name("movielens", 0.006, 11).expect("profile");
    let (train, test) = holdout_split_covered(&ds.ratings, 0.2, 12);
    println!(
        "end-to-end: {}x{} matrix, {} train ratings, K={}, backend: {backend_name}",
        train.rows,
        train.cols,
        train.nnz(),
        ds.k
    );

    let tau = auto_tau(&train);
    let mut recorder = Recorder::new();
    let grid = (4, 2);
    let sw = Stopwatch::start();
    let mut total_sweeps = 0usize;

    // Learning curve: train with increasing sample budgets so each point is
    // a full PP pipeline at that compute level (PP is a batch method; the
    // curve shows posterior quality vs Gibbs compute, paper-style).
    // One engine keeps the per-thread PJRT executables warm across points.
    let base_cfg = TrainConfig::new(ds.k);
    let engine = Engine::new(&base_cfg.backend, base_cfg.block_parallelism);
    let mut last = None;
    for &samples in &[4usize, 8, 16, 32, 64] {
        let cfg = TrainConfig::new(ds.k)
            .with_grid(grid.0, grid.1)
            .with_sweeps(8, samples)
            .with_tau(tau)
            .with_seed(3)
            .with_workers(2);
        // stream the run's events straight into the recorder: the
        // per-block sweep-RMSE series accumulate live as blocks execute
        let session = engine.submit(cfg, &train)?;
        for event in session.events() {
            recorder.observe(&event);
        }
        let result = session.wait()?.into_result()?;
        let rmse = result.rmse(&test);
        total_sweeps = result.stats.sweeps;
        println!(
            "samples/block={samples:<4} sweeps(total)={:<6} rmse={rmse:.4} wall={:.2}s",
            result.stats.sweeps, result.timings.total
        );
        recorder.point("rmse_vs_samples", samples as f64, rmse);
        recorder.point("rmse_vs_sweeps", result.stats.sweeps as f64, rmse);
        last = Some(result);
    }
    let result = last.unwrap();
    let rmse = result.rmse(&test);
    let baseline = mean_predictor_rmse(train.mean(), &test);
    let tp = Throughput::measure(
        train.rows,
        train.cols,
        train.nnz(),
        total_sweeps / result.stats.blocks.max(1),
        result.timings.total,
    );

    recorder.scalar("final_rmse", rmse);
    recorder.scalar("mean_predictor_rmse", baseline);
    recorder.scalar("total_secs", sw.secs());
    recorder.scalar("rows_per_sec", tp.rows_per_sec);
    recorder.scalar("ratings_per_sec", tp.ratings_per_sec);
    let out = std::path::Path::new("movielens_e2e_metrics.json");
    recorder.save(out)?;

    println!("final RMSE {rmse:.4} (mean predictor {baseline:.4}); metrics -> {}", out.display());
    println!("throughput: {}", tp.format_table1());
    assert!(rmse < baseline * 0.95, "end-to-end must clearly beat the mean predictor");
    println!("movielens_e2e OK");
    Ok(())
}
