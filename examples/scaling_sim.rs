//! Strong-scaling study (paper §3.4, Figs. 4-5): simulate the PP schedule
//! on the calibrated cluster model for all four dataset profiles, printing
//! wall-clock vs node count per block grid, with Pareto points marked.
//!
//!     cargo run --release --example scaling_sim

use bmf_pp::cluster::calibrate::calibrate;
use bmf_pp::cluster::sim::{node_sweep, pareto_front, simulate_pp, uniform_block_nnz};
use bmf_pp::coordinator::backend::BlockBackend;
use bmf_pp::data::generator::DatasetProfile;
use bmf_pp::partition::Grid;
use bmf_pp::util::timer::fmt_hhmm;

fn main() -> anyhow::Result<()> {
    bmf_pp::util::logging::init();
    let backend = BlockBackend::Native;
    let sweeps = 28;

    for profile in DatasetProfile::all() {
        // paper: K=100 for Netflix/Yahoo, K=10 for Movielens/Amazon;
        // scaled to this repo's artifact Ks
        let k = profile.k * 2; // simulate at 2x repo K for contrast
        let model = calibrate(&backend, profile.k.min(32));
        println!(
            "\n=== {} ({}x{}, {:.1}M ratings, K={k}) ===",
            profile.name,
            profile.paper_rows,
            profile.paper_cols,
            profile.paper_ratings as f64 / 1e6
        );
        let grids: &[(usize, usize)] = match profile.name {
            "netflix" => &[(1, 1), (4, 4), (16, 8), (32, 32)],
            "yahoo" => &[(2, 2), (8, 8), (16, 16), (32, 32)],
            _ => &[(1, 1), (4, 4), (8, 8), (32, 32)],
        };
        for &(gi, gj) in grids {
            let grid = Grid::new(profile.paper_rows, profile.paper_cols, gi, gj);
            let nnz = uniform_block_nnz(&grid, profile.paper_ratings);
            let mut pts = Vec::new();
            print!("  {gi:>2}x{gj:<3}");
            for p in node_sweep(&grid, 16384).into_iter().filter(|p| p.is_power_of_two()) {
                let r = simulate_pp(&model, &grid, &nnz, k, sweeps, sweeps, p);
                pts.push((p, r.total));
            }
            let front = pareto_front(&pts);
            for (p, t) in &pts {
                let mark = if front.contains(&(*p, *t)) { "*" } else { " " };
                print!(" {p}:{}{mark}", fmt_hhmm(*t));
            }
            println!();
        }
        println!("  (* = Pareto-optimal: cannot run faster without more nodes)");
    }
    println!("\nshapes to compare with the paper: 1x1 flattens at the within-block cap;");
    println!("large grids start slower (more total compute) but keep scaling to 10k+ nodes.");
    Ok(())
}
