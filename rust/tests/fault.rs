//! Fault-injection integration tests: a block task that panics (the
//! deterministic stand-in for a worker crash) must fail **its job only**
//! — typed `TrainOutcome::Failed`, in-flight siblings drained, a final
//! abort checkpoint written — while concurrent tenants on the same pool
//! stay bitwise-unaffected, and resume-from-the-newest-generation
//! reproduces the uninterrupted posterior bit for bit.
//!
//! The fast tests below run in the default suite. The exhaustive
//! kill-matrix (every fault point × resume) is `#[ignore]`d and executed
//! by the CI `recovery` job under `--release` with watchdog timeouts:
//!
//!     cargo test --release --test fault -- --ignored --nocapture

use bmf_pp::coordinator::checkpoint;
use bmf_pp::coordinator::{
    BackendSpec, Engine, JobStatus, TrainConfig, TrainOutcome, TrainResult,
};
use bmf_pp::data::generator::SyntheticDataset;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::data::sparse::Coo;
use bmf_pp::testing::fault::FaultPlan;
use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::time::Duration;

fn dataset() -> (Coo, usize) {
    let ds = SyntheticDataset::by_name("movielens", 0.0015, 401).unwrap();
    let (train, _) = holdout_split_covered(&ds.ratings, 0.2, 402);
    (train, ds.k)
}

fn quick_cfg(k: usize) -> TrainConfig {
    TrainConfig::new(k)
        .with_backend(BackendSpec::Native)
        .with_grid(2, 2)
        .with_sweeps(3, 6)
        .with_seed(403)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bmfpp_fault_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn assert_bitwise_eq(a: &TrainResult, b: &TrainResult, ctx: &str) {
    assert_eq!(a.u_post.mean, b.u_post.mean, "u mean diverged: {ctx}");
    assert_eq!(a.u_post.prec, b.u_post.prec, "u prec diverged: {ctx}");
    assert_eq!(a.v_post.mean, b.v_post.mean, "v mean diverged: {ctx}");
    assert_eq!(a.v_post.prec, b.v_post.prec, "v prec diverged: {ctx}");
}

#[test]
fn panic_at_block_yields_typed_failure_with_abort_checkpoint() {
    let (train, k) = dataset();
    let dir = tmp_dir("typed");
    let engine = Engine::new(&BackendSpec::Native, 2);
    let cfg = quick_cfg(k)
        .with_checkpoint_every(1)
        .with_checkpoint_dir(&dir)
        .with_fault_plan(FaultPlan::panic_at_block(2));
    let session = engine.submit(cfg, &train).unwrap();
    let outcome = session.wait().unwrap();
    let info = outcome.failed().expect("injected panic must fail the run").clone();
    assert!(info.error.contains("panicked"), "{}", info.error);
    assert!(info.blocks_completed >= 1, "blocks before the fault point completed");
    let ckpt = info.checkpoint.expect("abort checkpoint written");
    assert!(ckpt.starts_with(&dir), "checkpoint {ckpt:?} not in {dir:?}");
    let loaded = checkpoint::load_partial(&ckpt).unwrap();
    assert_eq!(loaded.blocks.len(), info.blocks_completed);

    // the engine (and its shared pool) keeps serving after the crash
    let r = engine.train(&quick_cfg(k), &train).unwrap();
    assert_eq!(r.stats.blocks, 4);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn failed_session_reports_failed_status() {
    let (train, k) = dataset();
    let engine = Engine::new(&BackendSpec::Native, 2);
    let cfg = quick_cfg(k).with_fault_plan(FaultPlan::panic_at_block(0));
    let session = engine.submit(cfg, &train).unwrap();
    let outcome = session.wait().unwrap();
    let info = outcome.failed().expect("block 0 panics before anything completes");
    assert_eq!(info.blocks_completed, 0);
    assert!(info.checkpoint.is_none(), "no blocks completed → no checkpoint");
    // into_result carries the failure as an error for strict callers
    assert!(outcome.into_result().is_err());
}

#[test]
fn failed_status_visible_through_jobs_snapshot() {
    let (train, k) = dataset();
    let engine = Engine::new(&BackendSpec::Native, 2);
    let session = engine
        .submit(quick_cfg(k).with_fault_plan(FaultPlan::panic_at_block(1)), &train)
        .unwrap();
    // drain the event stream; the terminal status is set before it closes
    let events: Vec<_> = session.events().collect();
    assert_eq!(session.status(), JobStatus::Failed);
    assert!(events.iter().any(|e| matches!(
        e,
        bmf_pp::coordinator::TrainEvent::Failed { .. }
    )));
    let snap = engine.jobs();
    assert_eq!(snap.len(), 1);
    assert_eq!(snap[0].status, JobStatus::Failed);
    session.wait().unwrap();
}

#[test]
fn faulted_job_never_perturbs_a_concurrent_sibling_bitwise() {
    // the regression test for the tentpole bugfix: a panicking block task
    // must not poison the shared pool — the sibling session's posterior
    // is bitwise-identical to the same config run solo
    let (train, k) = dataset();
    let engine = Engine::new(&BackendSpec::Native, 3);
    let sibling_cfg = quick_cfg(k).with_grid(3, 2).with_seed(411);
    let crasher = engine
        .submit(
            quick_cfg(k).with_seed(412).with_fault_plan(FaultPlan::panic_at_block(1)),
            &train,
        )
        .unwrap();
    let sibling = engine.submit(sibling_cfg.clone(), &train).unwrap();

    assert!(crasher.wait().unwrap().failed().is_some());
    let r_sibling = sibling.wait().unwrap().into_result().unwrap();
    let solo = Engine::new(&BackendSpec::Native, 3).train(&sibling_cfg, &train).unwrap();
    assert_bitwise_eq(&r_sibling, &solo, "sibling vs solo after a crash next door");
}

#[test]
fn delay_fault_changes_timing_never_the_math() {
    let (train, k) = dataset();
    let engine = Engine::new(&BackendSpec::Native, 2);
    let plain = engine.train(&quick_cfg(k), &train).unwrap();
    let delayed = engine
        .train(&quick_cfg(k).with_fault_plan(FaultPlan::delay_block(1, 80)), &train)
        .unwrap();
    assert_bitwise_eq(&plain, &delayed, "injected straggler vs plain run");
}

#[test]
fn resume_after_injected_crash_is_bitwise_identical() {
    // the acceptance-criterion shape at one fault point: crash → resume
    // from the newest generation → posterior identical to uninterrupted
    let (train, k) = dataset();
    let dir = tmp_dir("resume_one");
    let engine = Engine::new(&BackendSpec::Native, 2);
    let base = quick_cfg(k).with_grid(3, 3).with_checkpoint_every(1).with_checkpoint_dir(&dir);

    let session = engine
        .submit(base.clone().with_fault_plan(FaultPlan::panic_at_block(4)), &train)
        .unwrap();
    let info = session.wait().unwrap().failed().expect("fault fires").clone();
    assert!(info.blocks_completed >= 1);

    // resume (the crash "does not recur": no fault plan on the retry)
    let resumed = engine.train(&base.clone().with_resume_from(&dir), &train).unwrap();
    assert!(resumed.stats.blocks_restored >= 1);
    let ref_dir = tmp_dir("resume_ref");
    let full = engine.train(&base.clone().with_checkpoint_dir(&ref_dir), &train).unwrap();
    assert_bitwise_eq(&resumed, &full, "resume-after-crash vs uninterrupted");
    std::fs::remove_dir_all(dir).ok();
    std::fs::remove_dir_all(ref_dir).ok();
}

#[test]
fn seeded_random_kill_is_deterministic() {
    let (train, k) = dataset();
    let engine = Engine::new(&BackendSpec::Native, 2);
    // seed 31 at p=0.5 kills exactly the phase-(c) blocks (canonical
    // indices 5..9) of a 3x3 grid: the run makes real progress (a + b
    // blocks survive), then reliably dies — run to run, schedule or not
    let plan = FaultPlan::random_panic(31, 0.5);
    let expected: Vec<usize> = (0..9).filter(|&i| plan.kills_block(i)).collect();
    assert_eq!(expected, vec![5, 6, 7, 8], "kill pattern is part of the contract");
    for attempt in 0..2 {
        let s = engine
            .submit(quick_cfg(k).with_grid(3, 3).with_fault_plan(plan), &train)
            .unwrap();
        match s.wait().unwrap() {
            TrainOutcome::Failed(info) => {
                assert!(info.error.contains("panicked"), "{}", info.error);
                assert!(info.blocks_completed >= 1, "a and b blocks precede the kills");
            }
            other => panic!("attempt {attempt}: expected Failed, got {other:?}"),
        }
    }
}

/// The CI recovery matrix: inject a crash at EVERY block of a 3x3 grid,
/// resume each from the newest valid generation, and require the resumed
/// posterior to be bitwise-identical to the uninterrupted run. Heavy by
/// design; watchdog-guarded like the stress job.
#[test]
#[ignore = "heavy; exercised by the CI recovery job"]
fn kill_matrix_every_fault_point_resumes_bitwise() {
    // the matrix runs on a worker thread; the test thread is the watchdog
    // (mirroring tests/stress.rs) so a wedged pool or a deadlocked drain
    // fails within the budget instead of hanging the CI job
    let (done_tx, done_rx) = channel::<usize>();
    let matrix = std::thread::spawn(move || {
        let (train, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 3);
        let base = quick_cfg(k).with_grid(3, 3).with_sweeps(4, 8).with_seed(431);
        let reference = engine.train(&base, &train).unwrap();
        assert_eq!(reference.stats.blocks, 9);

        for fault_at in 0..9usize {
            let dir = tmp_dir(&format!("matrix_{fault_at}"));
            let cfg = base
                .clone()
                .with_checkpoint_every(1)
                .with_checkpoint_dir(&dir)
                .with_checkpoint_keep(2)
                .with_fault_plan(FaultPlan::panic_at_block(fault_at));
            let session = engine.submit(cfg, &train).unwrap();
            let outcome = session.wait().unwrap();
            let info = outcome.failed().unwrap_or_else(|| {
                panic!("fault at block {fault_at} did not fail the run")
            });

            if fault_at == 0 {
                // nothing completed: no generation to resume from
                assert_eq!(info.blocks_completed, 0);
                assert!(checkpoint::list_generations(&dir).map_or(true, |g| g.is_empty()));
            } else {
                assert!(info.blocks_completed >= 1);
                let resume_cfg = base.clone().with_resume_from(&dir);
                let resumed = engine.train(&resume_cfg, &train).unwrap();
                assert!(resumed.stats.blocks_restored >= 1, "fault point {fault_at}");
                assert_eq!(resumed.stats.blocks + resumed.stats.blocks_restored, 9);
                assert_bitwise_eq(
                    &resumed,
                    &reference,
                    &format!("fault point {fault_at} resume vs uninterrupted"),
                );
            }
            std::fs::remove_dir_all(dir).ok();
            done_tx.send(fault_at).unwrap();
        }
    });

    for expected in 0..9usize {
        let fault_at = done_rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|_| panic!("fault point {expected} did not settle within 120s"));
        println!("fault point {fault_at}: killed, resumed, bitwise-verified");
    }
    matrix.join().expect("matrix thread panicked");
}
