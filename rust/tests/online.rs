//! Incremental-update suite: `Engine::update` must be a *pruned resume* —
//! an empty delta reproduces the prior posterior bit for bit, a delta
//! confined to one block re-samples exactly that block, and the
//! store-backed path (`ingest --append` + `update --store`) lands on the
//! same bits as the resident one.

use bmf_pp::coordinator::{BackendSpec, Engine, TrainConfig, TrainOutcome, TrainResult};
use bmf_pp::data::generator::SyntheticDataset;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::data::sparse::Coo;
use bmf_pp::online::{append_delta, load_prior, RatingDelta};
use bmf_pp::partition::Grid;
use bmf_pp::posterior::PosteriorModel;
use bmf_pp::store::{ingest, ShardStore};
use std::path::PathBuf;
use std::sync::Arc;

/// Unique scratch directory, removed on drop.
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let path = std::env::temp_dir().join(format!(
            "bmfpp_online_{tag}_{}_{}",
            std::process::id(),
            std::thread::current().name().unwrap_or("t").replace("::", "_")
        ));
        std::fs::remove_dir_all(&path).ok();
        std::fs::create_dir_all(&path).unwrap();
        TempDir(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn dataset() -> (Coo, usize) {
    let ds = SyntheticDataset::by_name("movielens", 0.0015, 91).unwrap();
    let (train, _test) = holdout_split_covered(&ds.ratings, 0.2, 7);
    (train, ds.k)
}

/// The shared config every leg of a test must agree on (same k, grid,
/// seed, tau — the update path checks the first three against the prior).
fn config(k: usize) -> TrainConfig {
    TrainConfig::new(k)
        .with_grid(2, 2)
        .with_sweeps(4, 8)
        .with_tau(1.5)
        .with_seed(91)
        .with_backend(BackendSpec::Native)
}

fn run_to_completion(
    session: anyhow::Result<bmf_pp::coordinator::Session>,
) -> TrainResult {
    match session.and_then(|s| s.wait()).unwrap() {
        TrainOutcome::Completed(result) => *result,
        other => panic!("run did not complete: {other:?}"),
    }
}

/// Exact posterior comparison: both marginal sides (means *and*
/// precisions), the factor caches, and the global mean, all by bits.
fn assert_bitwise(a: &PosteriorModel, b: &PosteriorModel, what: &str) {
    assert_eq!(a.k, b.k, "{what}: k");
    assert_eq!(a.global_mean.to_bits(), b.global_mean.to_bits(), "{what}: global_mean");
    for (side, ga, gb) in [("u", &a.u_post, &b.u_post), ("v", &a.v_post, &b.v_post)] {
        assert_eq!(ga.n, gb.n, "{what}: {side}_post.n");
        for (field, xa, xb) in [("mean", &ga.mean, &gb.mean), ("prec", &ga.prec, &gb.prec)] {
            assert_eq!(xa.len(), xb.len(), "{what}: {side}_post.{field} len");
            for i in 0..xa.len() {
                assert_eq!(
                    xa[i].to_bits(),
                    xb[i].to_bits(),
                    "{what}: {side}_post.{field}[{i}]: {} vs {}",
                    xa[i],
                    xb[i]
                );
            }
        }
    }
    for (side, fa, fb) in [("u", &a.u_mean, &b.u_mean), ("v", &a.v_mean, &b.v_mean)] {
        assert_eq!(fa.len(), fb.len(), "{what}: {side}_mean len");
        for i in 0..fa.len() {
            assert_eq!(fa[i].to_bits(), fb[i].to_bits(), "{what}: {side}_mean[{i}]");
        }
    }
}

/// Train the full run with per-sweep checkpointing so the newest
/// generation is complete, and return (full result, engine, ckpt dir).
fn full_run(train: &Coo, k: usize) -> (TrainResult, Engine, TempDir) {
    let ckpt = TempDir::new("prior");
    let cfg = config(k).with_checkpoint_every(1).with_checkpoint_dir(&ckpt.0);
    let engine = Engine::new(&cfg.backend, cfg.block_parallelism);
    let full = run_to_completion(engine.submit(cfg, train));
    (full, engine, ckpt)
}

#[test]
fn empty_delta_update_is_bitwise_noop() {
    let (train, k) = dataset();
    let (full, engine, ckpt) = full_run(&train, k);

    let prior = load_prior(&ckpt.0).unwrap();
    let delta = RatingDelta::new(train.rows, train.cols);
    assert!(delta.is_empty());
    let update = run_to_completion(engine.update(config(k), &prior, &delta, &train));

    assert_eq!(update.stats.blocks, 0, "an empty delta must re-sample nothing");
    assert_eq!(
        update.stats.blocks_skipped_clean, 4,
        "all 2x2 blocks must pass through clean"
    );
    assert_bitwise(&full.model, &update.model, "empty-delta update");
}

#[test]
fn single_block_delta_resamples_only_that_block() {
    let (train, k) = dataset();
    let (full, engine, ckpt) = full_run(&train, k);
    let prior = load_prior(&ckpt.0).unwrap();

    // a delta strictly inside block (1,1): rows/cols of stripe 1 only
    let grid = Grid::new(train.rows, train.cols, 2, 2);
    let (r_start, _) = grid.row_range(1);
    let (c_start, _) = grid.col_range(1);
    let mut delta = RatingDelta::new(train.rows, train.cols);
    delta.push(r_start, c_start, 4.5);
    delta.push(r_start + 1, c_start, 1.0);

    let update = run_to_completion(engine.update(config(k), &prior, &delta, &train));
    assert_eq!(update.stats.blocks, 1, "exactly block (1,1) is dirty");
    assert_eq!(update.stats.blocks_skipped_clean, 3);

    // rows and columns of stripe 0 aggregate only clean blocks, so their
    // marginals — and therefore predictions over stripe-0 × stripe-0 —
    // must be bitwise-identical to the full run
    let (_, r_end0) = grid.row_range(0);
    let (_, c_end0) = grid.col_range(0);
    for r in (0..r_end0).step_by((r_end0 / 5).max(1)) {
        for c in (0..c_end0).step_by((c_end0 / 5).max(1)) {
            assert_eq!(
                full.model.predict(r, c).to_bits(),
                update.model.predict(r, c).to_bits(),
                "untouched ({r},{c}) prediction drifted"
            );
        }
    }
    for i in 0..r_end0 * k {
        assert_eq!(
            full.model.u_post.mean[i].to_bits(),
            update.model.u_post.mean[i].to_bits(),
            "clean row-stripe posterior drifted at {i}"
        );
    }
}

#[test]
fn store_update_matches_resident_update_bitwise() {
    let (train, k) = dataset();
    let (_full, engine, ckpt) = full_run(&train, k);
    let prior = load_prior(&ckpt.0).unwrap();

    let grid = Grid::new(train.rows, train.cols, 2, 2);
    let (r_start, _) = grid.row_range(1);
    let (c_start, _) = grid.col_range(1);
    let mut delta = RatingDelta::new(train.rows, train.cols);
    delta.push(r_start, c_start, 4.5);

    // store path: ingest the base matrix, fold the delta in, update
    let store_dir = TempDir::new("store");
    ingest(&train, 2, 2, &store_dir.0).unwrap();
    let report = append_delta(&delta, &store_dir.0).unwrap();
    assert_eq!(report.revision, 1, "append must bump the manifest revision");
    assert_eq!(report.rewritten, 1, "only the dirty shard is rewritten");
    let store = Arc::new(ShardStore::open(&store_dir.0).unwrap());
    let via_store =
        run_to_completion(engine.update_store(config(k), &prior, &delta, store));

    let via_resident = run_to_completion(engine.update(config(k), &prior, &delta, &train));

    assert_eq!(via_store.stats.blocks, 1);
    assert_eq!(via_resident.stats.blocks, 1);
    assert_bitwise(
        &via_resident.model,
        &via_store.model,
        "store vs resident update",
    );
}
