//! End-to-end serving tests over real sockets: a trained model goes
//! through the checkpoint pipeline into a running [`Server`], and plain
//! `TcpStream` HTTP clients exercise every endpoint.
//!
//! The headline test is the hot-swap acceptance criterion: while client
//! threads hammer `/predict` and `/top`, a new checkpoint generation is
//! published into the watched directory, and the server must flip to it
//! with **zero failed requests** and **zero torn responses** — every
//! answer bitwise-matches the old model or the new one, tagged with the
//! matching generation, never a mix.

use bmf_pp::prelude::*;
use bmf_pp::data::generator::SyntheticDataset;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::data::sparse::Coo;
use bmf_pp::train::checkpoint::{self, generation_path, latest_valid_partial, save_partial};
use bmf_pp::util::json::{self, Json};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn dataset() -> (Coo, usize) {
    let ds = SyntheticDataset::by_name("movielens", 0.0015, 601).unwrap();
    let (train, _) = holdout_split_covered(&ds.ratings, 0.2, 602);
    (train, ds.k)
}

fn quick_cfg(k: usize) -> TrainConfig {
    TrainConfig::new(k)
        .with_backend(BackendSpec::Native)
        .with_grid(2, 2)
        .with_sweeps(3, 6)
        .with_seed(603)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bmfpp_serve_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// One-shot HTTP exchange: connect, send, read to EOF (the server always
/// answers `Connection: close`), return `(status, parsed JSON body)`.
fn http(addr: SocketAddr, request: &str) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(request.as_bytes()).expect("send request");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("unparseable status line in {raw:?}"));
    let body = raw.split("\r\n\r\n").nth(1).unwrap_or("");
    let body = json::parse(body).unwrap_or_else(|e| panic!("bad body {body:?}: {e}"));
    (status, body)
}

fn get(addr: SocketAddr, target: &str) -> (u16, Json) {
    http(addr, &format!("GET {target} HTTP/1.1\r\nhost: test\r\nconnection: close\r\n\r\n"))
}

fn post(addr: SocketAddr, target: &str) -> (u16, Json) {
    http(addr, &format!("POST {target} HTTP/1.1\r\nhost: test\r\nconnection: close\r\n\r\n"))
}

#[test]
fn endpoints_answer_over_real_sockets() {
    let (train, k) = dataset();
    let engine = Engine::new(&BackendSpec::Native, 2);
    let model = engine.train(&quick_cfg(k), &train).unwrap().model;
    let dir = tmp_dir("file");
    let path = dir.join("model.json");
    checkpoint::save(&model, &path).unwrap();

    let server = Server::start(
        ServeConfig::default().with_addr("127.0.0.1:0").with_threads(2),
        ModelSource::File(path),
    )
    .unwrap();
    let addr = server.addr();

    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200);
    assert_eq!(body.get("ok").and_then(Json::as_bool), Some(true));

    // predictions over the wire are bitwise the model's own answers
    let (status, body) = get(addr, "/predict?row=0&col=0&variance");
    assert_eq!(status, 200);
    let value = body.get("value").and_then(Json::as_f64).expect("value");
    assert_eq!(value.to_bits(), model.predict(0, 0).to_bits());
    let var = body.get("variance").and_then(Json::as_f64).expect("variance");
    assert_eq!(var.to_bits(), model.predict_variance(0, 0).to_bits());
    assert_eq!(body.get("generation").and_then(Json::as_str), Some("0"));

    let (status, body) = get(addr, "/top?row=1&n=3");
    assert_eq!(status, 200);
    let items = body.get("items").and_then(Json::as_arr).expect("items");
    let expect = model.top_n(1, 3);
    assert_eq!(items.len(), expect.len());
    for (item, (col, score)) in items.iter().zip(&expect) {
        assert_eq!(item.get("col").and_then(Json::as_usize), Some(*col));
        let got = item.get("score").and_then(Json::as_f64).expect("score");
        assert_eq!(got.to_bits(), score.to_bits());
    }

    // out-of-range ids are typed 404s carrying the PredictError message
    let (status, body) = get(addr, &format!("/predict?row={}&col=0", model.rows()));
    assert_eq!(status, 404);
    let msg = body.get("error").and_then(Json::as_str).expect("error body");
    assert!(msg.contains("out of range"), "unexpected error: {msg}");
    // malformed queries are 400s, unknown paths 404s — never a hangup
    let (status, _) = get(addr, "/predict?row=zero&col=0");
    assert_eq!(status, 400);
    let (status, _) = get(addr, "/predict?col=0");
    assert_eq!(status, 400);
    let (status, _) = get(addr, "/nope");
    assert_eq!(status, 404);

    let (status, body) = post(addr, "/shutdown");
    assert_eq!(status, 200);
    assert_eq!(body.get("stopping").and_then(Json::as_bool), Some(true));
    let stats = server.join();
    assert!(stats.http_requests >= 7, "requests counted: {}", stats.http_requests);
    assert!(stats.http_errors >= 4, "errors counted: {}", stats.http_errors);
    assert_eq!(stats.generation, 0, "model files carry no generation");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn startup_requires_a_servable_generation() {
    let dir = tmp_dir("unservable");
    std::fs::write(generation_path(&dir, 1), "definitely not json").unwrap();
    let err = Server::start(
        ServeConfig::default().with_addr("127.0.0.1:0"),
        ModelSource::CheckpointDir(dir.clone()),
    )
    .expect_err("a corrupt-only directory must not start");
    assert!(
        err.to_string().contains("no servable checkpoint generation"),
        "unexpected error: {err:#}"
    );
    std::fs::remove_dir_all(dir).ok();
}

/// The acceptance criterion: publish generation N+1 while clients hammer
/// the server — zero failed requests, zero torn responses, `/stats`
/// advances, and a corrupt newest generation is skipped, not served.
#[test]
fn hot_swap_under_fire_drops_nothing_and_never_tears() {
    let (train, k) = dataset();
    let engine = Engine::new(&BackendSpec::Native, 2);

    // run A checkpoints into the served directory; with --checkpoint-every 1
    // the newest generation of a successful run holds every block
    let dir = tmp_dir("swap");
    let cfg_a = quick_cfg(k)
        .with_checkpoint_every(1)
        .with_checkpoint_dir(&dir)
        .with_checkpoint_keep(1);
    let model_a = engine.train(&cfg_a, &train).unwrap().model;
    let (ckpt_a, _) = latest_valid_partial(&dir).unwrap().expect("run A checkpointed");
    assert!(ckpt_a.is_complete(), "a finished run's newest generation is complete");
    let gen_a = ckpt_a.generation;

    // run B (different seed → distinguishable posterior) staged in a side
    // directory, renumbered to land strictly after run A's generation
    let dir_b = tmp_dir("swap_staging");
    let cfg_b = quick_cfg(k)
        .with_seed(617)
        .with_checkpoint_every(1)
        .with_checkpoint_dir(&dir_b)
        .with_checkpoint_keep(1);
    let model_b = engine.train(&cfg_b, &train).unwrap().model;
    let (mut ckpt_b, _) = latest_valid_partial(&dir_b).unwrap().expect("run B checkpointed");
    let gen_b = gen_a + 1;
    ckpt_b.generation = gen_b;

    // a corrupt file newer than everything else: must be skipped forever
    std::fs::write(generation_path(&dir, gen_a + 7), "definitely not json").unwrap();

    let pa = model_a.predict(0, 0).to_bits();
    let pb = model_b.predict(0, 0).to_bits();
    assert_ne!(pa, pb, "the two runs must be distinguishable bitwise");
    let ta = model_a.top_n(0, 2);
    let tb = model_b.top_n(0, 2);

    let server = Server::start(
        ServeConfig::default()
            .with_addr("127.0.0.1:0")
            .with_threads(3)
            .with_poll(Duration::from_millis(20)),
        ModelSource::CheckpointDir(dir.clone()),
    )
    .unwrap();
    let addr = server.addr();
    assert_eq!(server.stats().generation, gen_a);

    // client threads hammer both prediction endpoints through the swap;
    // any non-200, or any response mixing models/generations, panics here
    // and fails the join below
    let stop = Arc::new(AtomicBool::new(false));
    let gen_a_str = gen_a.to_string();
    let gen_b_str = gen_b.to_string();
    let mut clients = Vec::new();
    for client_id in 0..3usize {
        let stop = stop.clone();
        let (gen_a_str, gen_b_str) = (gen_a_str.clone(), gen_b_str.clone());
        let (ta, tb) = (ta.clone(), tb.clone());
        clients.push(std::thread::spawn(move || {
            let mut answered = 0u64;
            let mut saw_new = false;
            while !stop.load(Ordering::Relaxed) {
                if (answered as usize + client_id) % 2 == 0 {
                    let (status, body) = get(addr, "/predict?row=0&col=0");
                    assert_eq!(status, 200, "predict failed mid-swap: {body}");
                    let bits =
                        body.get("value").and_then(Json::as_f64).expect("value").to_bits();
                    let generation =
                        body.get("generation").and_then(Json::as_str).expect("generation");
                    let old = bits == pa && generation == gen_a_str;
                    let new = bits == pb && generation == gen_b_str;
                    assert!(old || new, "torn predict: bits={bits} generation={generation}");
                    saw_new |= new;
                } else {
                    let (status, body) = get(addr, "/top?row=0&n=2");
                    assert_eq!(status, 200, "top failed mid-swap: {body}");
                    let generation =
                        body.get("generation").and_then(Json::as_str).expect("generation");
                    let items = body.get("items").and_then(Json::as_arr).expect("items");
                    let scores: Vec<u64> = items
                        .iter()
                        .map(|i| i.get("score").and_then(Json::as_f64).unwrap().to_bits())
                        .collect();
                    let want = |m: &[(usize, f64)]| {
                        m.iter().map(|(_, s)| s.to_bits()).collect::<Vec<u64>>()
                    };
                    let old = scores == want(&ta) && generation == gen_a_str;
                    let new = scores == want(&tb) && generation == gen_b_str;
                    assert!(old || new, "torn ranking: generation={generation}");
                    saw_new |= new;
                }
                answered += 1;
            }
            (answered, saw_new)
        }));
    }

    // let the clients get going, then publish run B's generation the way
    // the trainer does: write to a temp name, atomic rename into place
    std::thread::sleep(Duration::from_millis(50));
    let tmp = dir.join("incoming.tmp");
    save_partial(&ckpt_b, &tmp).unwrap();
    std::fs::rename(&tmp, generation_path(&dir, gen_b)).unwrap();

    let deadline = Instant::now() + Duration::from_secs(10);
    while server.stats().generation != gen_b {
        assert!(Instant::now() < deadline, "hot-swap did not land within 10s");
        std::thread::sleep(Duration::from_millis(10));
    }
    // keep firing a little longer so clients observe the new snapshot
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::Relaxed);
    let mut answered_total = 0u64;
    let mut any_saw_new = false;
    for c in clients {
        let (answered, saw_new) = c.join().expect("a client hit a failed or torn response");
        answered_total += answered;
        any_saw_new |= saw_new;
    }
    assert!(answered_total > 0, "clients never got a request through");
    assert!(any_saw_new, "no client ever observed the swapped-in generation");

    let stats = server.stats();
    assert_eq!(stats.generation, gen_b);
    assert!(stats.swaps >= 1, "swap counter never moved");
    assert!(stats.swaps_skipped >= 1, "corrupt newest generation was not counted");
    assert_eq!(stats.http_errors, 0, "a request failed during the swap window");
    assert_eq!(
        stats.batched_requests, answered_total,
        "every client request flows through the batcher"
    );
    assert!(stats.batches <= stats.batched_requests);

    // the flip is visible over the wire too
    let (status, body) = get(addr, "/stats");
    assert_eq!(status, 200);
    assert_eq!(body.get("generation").and_then(Json::as_str), Some(gen_b_str.as_str()));
    assert_eq!(
        body.get("model").and_then(|m| m.get("k")).and_then(Json::as_usize),
        Some(k)
    );

    let final_stats = server.stop();
    assert_eq!(final_stats.http_errors, 0);
    std::fs::remove_dir_all(dir).ok();
    std::fs::remove_dir_all(dir_b).ok();
}
