//! Cross-module integration tests: the full pipeline (data → partition →
//! PP phases → runtime → aggregation → evaluation), backend equivalence,
//! file-loader round trips and the CLI binary.

use bmf_pp::baselines::sgd_common::SgdConfig;
use bmf_pp::baselines::{fpsgd, nomad};
use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{BackendSpec, Engine, SchedulerMode, TrainConfig, TrainResult};
use bmf_pp::data::generator::SyntheticDataset;
use bmf_pp::data::loader;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::data::sparse::Coo;
use bmf_pp::gibbs::NativeGibbs;
use bmf_pp::metrics::rmse::mean_predictor_rmse;

/// One-shot training run on a private engine sized by the config.
fn train_once(cfg: TrainConfig, train: &Coo) -> TrainResult {
    Engine::new(&cfg.backend, cfg.block_parallelism).train(&cfg, train).unwrap()
}

fn artifacts_present() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

fn dataset(scale: f64) -> (Coo, Coo, usize) {
    let ds = SyntheticDataset::by_name("movielens", scale, 71).unwrap();
    let (train, test) = holdout_split_covered(&ds.ratings, 0.2, 72);
    let k = ds.k;
    (train, test, k)
}

#[test]
fn full_pipeline_hlo_backend() {
    if !artifacts_present() || !cfg!(feature = "pjrt") {
        eprintln!("skipping: needs `make artifacts` and `--features pjrt`");
        return;
    }
    let (train, test, k) = dataset(0.002);
    let cfg = TrainConfig::new(k)
        .with_grid(2, 2)
        .with_sweeps(8, 16)
        .with_tau(auto_tau(&train))
        .with_seed(73);
    let res = train_once(cfg, &train);
    let rmse = res.rmse(&test);
    let base = mean_predictor_rmse(train.mean(), &test);
    assert!(rmse < base * 0.9, "hlo pipeline rmse {rmse} vs mean {base}");
}

#[test]
fn hlo_and_native_backends_agree_statistically() {
    if !artifacts_present() || !cfg!(feature = "pjrt") {
        return;
    }
    let (train, test, k) = dataset(0.002);
    let mk = |backend| {
        TrainConfig::new(k)
            .with_grid(2, 2)
            .with_sweeps(8, 16)
            .with_tau(auto_tau(&train))
            .with_seed(74)
            .with_backend(backend)
    };
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let r_hlo = train_once(mk(BackendSpec::Hlo { artifact_dir: dir }), &train);
    let r_nat = train_once(mk(BackendSpec::Native), &train);
    let (a, b) = (r_hlo.rmse(&test), r_nat.rmse(&test));
    // same seeds and same math; f32-vs-f64 accumulation orders diverge over
    // a chain, so compare quality, not bits
    assert!((a - b).abs() < 0.1 * a.max(b), "hlo {a} vs native {b}");
}

#[test]
fn within_block_workers_match_single_worker_exactly() {
    let (train, test, k) = dataset(0.0015);
    let mk = |workers| {
        TrainConfig::new(k)
            .with_grid(2, 1)
            .with_sweeps(5, 10)
            .with_tau(2.0)
            .with_seed(75)
            .with_workers(workers)
            .with_backend(BackendSpec::Native)
    };
    let r1 = train_once(mk(1), &train);
    let r4 = train_once(mk(4), &train);
    assert_eq!(r1.u_mean, r4.u_mean, "sharding must be bit-exact");
    assert!((r1.rmse(&test) - r4.rmse(&test)).abs() < 1e-12);
}

#[test]
fn pp_matches_plain_bmf_quality() {
    // the paper's ML claim (Table 2 ≈ BMF column): PP RMSE ≈ plain Gibbs
    let (train, test, k) = dataset(0.002);
    let tau = auto_tau(&train);
    let cfg = TrainConfig::new(k)
        .with_grid(3, 2)
        .with_sweeps(10, 20)
        .with_tau(tau)
        .with_seed(76)
        .with_backend(BackendSpec::Native);
    let pp = train_once(cfg, &train).rmse(&test);
    let mut bmf = NativeGibbs::new(&train, k, tau, 76);
    for _ in 0..30 {
        bmf.sweep();
    }
    let bmf_rmse = bmf.rmse(&test);
    assert!(
        (pp - bmf_rmse).abs() < 0.2 * bmf_rmse,
        "pp {pp} vs plain bmf {bmf_rmse}"
    );
}

#[test]
fn all_methods_beat_mean_predictor_on_all_profiles() {
    for name in ["movielens", "netflix"] {
        let scale = 0.0015;
        let ds = SyntheticDataset::by_name(name, scale, 81).unwrap();
        let (train, test) = holdout_split_covered(&ds.ratings, 0.2, 82);
        let base = mean_predictor_rmse(train.mean(), &test);

        let cfg = TrainConfig::new(ds.k)
            .with_grid(2, 2)
            .with_sweeps(8, 16)
            .with_tau(auto_tau(&train))
            .with_seed(83)
            .with_backend(BackendSpec::Native);
        let pp = train_once(cfg, &train).rmse(&test);
        let sgd = SgdConfig::new(ds.k).with_epochs(25).with_seed(83);
        let f = fpsgd::train(&train, &sgd).rmse(&test);
        let n = nomad::train(&train, &sgd).rmse(&test);
        for (label, rmse) in [("pp", pp), ("fpsgd", f), ("nomad", n)] {
            assert!(rmse < base, "{name}/{label}: {rmse} vs mean {base}");
        }
    }
}

#[test]
fn csv_to_training_pipeline() {
    // export a synthetic matrix, reload it, train on it
    let ds = SyntheticDataset::by_name("movielens", 0.0015, 91).unwrap();
    let path = std::env::temp_dir().join(format!("bmfpp_it_{}.csv", std::process::id()));
    loader::save_csv(&ds.ratings, &path).unwrap();
    let loaded = loader::load_csv(&path, false).unwrap();
    assert_eq!(loaded.nnz(), ds.ratings.nnz());
    let (train, test) = holdout_split_covered(&loaded, 0.2, 92);
    let cfg = TrainConfig::new(8)
        .with_sweeps(5, 10)
        .with_tau(auto_tau(&train))
        .with_backend(BackendSpec::Native);
    let res = train_once(cfg, &train);
    assert!(res.rmse(&test).is_finite());
    std::fs::remove_file(path).ok();
}

#[test]
fn cli_binary_smoke() {
    let bin = env!("CARGO_BIN_EXE_bmf-pp");
    let out = std::process::Command::new(bin)
        .args(["datasets", "--scale", "0.001"])
        .output()
        .expect("run bmf-pp");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for name in ["movielens", "netflix", "yahoo", "amazon"] {
        assert!(stdout.contains(name), "missing {name} in:\n{stdout}");
    }

    let out = std::process::Command::new(bin)
        .args([
            "train",
            "--dataset",
            "movielens",
            "--scale",
            "0.0015",
            "--grid",
            "2x2",
            "--burnin",
            "4",
            "--samples",
            "8",
            "--native",
        ])
        .output()
        .expect("run train");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("test RMSE"));

    // unknown flag is rejected
    let out = std::process::Command::new(bin)
        .args(["train", "--no-such-flag", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn cli_train_save_predict_roundtrip_reports_identical_rmse() {
    // acceptance path: `train --save m.json --save-test t.csv` followed by
    // `predict --load m.json --file t.csv` must report the same holdout
    // RMSE the training run printed (CSV and JSON round-trips are exact)
    fn rmse_line(stdout: &str) -> String {
        stdout
            .lines()
            .find(|l| l.starts_with("test RMSE = "))
            .unwrap_or_else(|| panic!("no RMSE line in:\n{stdout}"))
            .split_whitespace()
            .nth(3)
            .unwrap()
            .to_string()
    }
    let bin = env!("CARGO_BIN_EXE_bmf-pp");
    let dir = std::env::temp_dir();
    let model = dir.join(format!("bmfpp_cli_model_{}.json", std::process::id()));
    let holdout = dir.join(format!("bmfpp_cli_holdout_{}.csv", std::process::id()));

    let out = std::process::Command::new(bin)
        .args([
            "train",
            "--dataset",
            "movielens",
            "--scale",
            "0.0015",
            "--grid",
            "2x2",
            "--burnin",
            "3",
            "--samples",
            "6",
            "--native",
            "--quiet",
            "--save",
            model.to_str().unwrap(),
            "--save-test",
            holdout.to_str().unwrap(),
        ])
        .output()
        .expect("run train");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let train_rmse = rmse_line(&String::from_utf8_lossy(&out.stdout));

    let out = std::process::Command::new(bin)
        .args(["predict", "--load", model.to_str().unwrap(), "--file", holdout.to_str().unwrap()])
        .output()
        .expect("run predict");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let predict_rmse = rmse_line(&String::from_utf8_lossy(&out.stdout));

    assert_eq!(train_rmse, predict_rmse, "train-side vs predict-side RMSE");
    std::fs::remove_file(model).ok();
    std::fs::remove_file(holdout).ok();
}

#[test]
fn cli_jobs_runs_concurrent_sessions_to_completion() {
    // the multi-tenant demo: three mixed-priority jobs on one engine,
    // status streamed, all terminal, finish order reported
    let bin = env!("CARGO_BIN_EXE_bmf-pp");
    let out = std::process::Command::new(bin)
        .args([
            "jobs", "--dataset", "movielens", "--scale", "0.001", "--jobs", "3", "--burnin",
            "2", "--samples", "4", "--threads", "2",
        ])
        .output()
        .expect("run jobs");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.matches("submitted job #").count(), 3, "{stdout}");
    assert_eq!(stdout.matches(": completed").count(), 3, "{stdout}");
    assert!(stdout.contains("finish order"), "{stdout}");
}

#[test]
fn cli_periodic_checkpoint_then_dir_resume_matches_rmse() {
    // the recovery drill's core path at tier-1 scale: a run with
    // --checkpoint-every leaves generation files behind; a second run
    // resuming from the DIRECTORY restores them (reported on stdout) and
    // lands on the identical holdout RMSE
    fn rmse_line(stdout: &str) -> String {
        stdout
            .lines()
            .find(|l| l.starts_with("test RMSE = "))
            .unwrap_or_else(|| panic!("no RMSE line in:\n{stdout}"))
            .split_whitespace()
            .nth(3)
            .unwrap()
            .to_string()
    }
    let bin = env!("CARGO_BIN_EXE_bmf-pp");
    let ckpts = std::env::temp_dir().join(format!("bmfpp_cli_ckpts_{}", std::process::id()));
    std::fs::remove_dir_all(&ckpts).ok();
    let common = [
        "--dataset",
        "movielens",
        "--scale",
        "0.0015",
        "--grid",
        "2x2",
        "--burnin",
        "3",
        "--samples",
        "6",
        "--native",
        "--quiet",
    ];

    let out = std::process::Command::new(bin)
        .arg("train")
        .args(common)
        .args(["--checkpoint-every", "1", "--checkpoint-dir", ckpts.to_str().unwrap()])
        .output()
        .expect("run train with periodic checkpoints");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let first_rmse = rmse_line(&String::from_utf8_lossy(&out.stdout));
    let generations = std::fs::read_dir(&ckpts)
        .expect("checkpoint dir created")
        .filter_map(|e| e.ok()?.file_name().into_string().ok())
        .filter(|n| n.starts_with("partial-gen-") && n.ends_with(".json"))
        .count();
    assert_eq!(
        generations, 3,
        "4 blocks at every=1 under keep-last-3 must leave exactly 3 generations"
    );

    let out = std::process::Command::new(bin)
        .arg("train")
        .args(common)
        .args(["--resume", ckpts.to_str().unwrap()])
        .output()
        .expect("run resumed train");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("blocks restored from checkpoint"), "{stdout}");
    assert_eq!(first_rmse, rmse_line(&stdout), "resumed RMSE must match");
    std::fs::remove_dir_all(ckpts).ok();
}

#[test]
fn cli_jobs_backlog_rejects_past_bound() {
    // admission control through the CLI: with --backlog 1 only the first
    // job is admitted; the rest are rejected with the typed message
    let bin = env!("CARGO_BIN_EXE_bmf-pp");
    let out = std::process::Command::new(bin)
        .args([
            "jobs", "--dataset", "movielens", "--scale", "0.001", "--jobs", "3", "--burnin",
            "2", "--samples", "4", "--threads", "2", "--backlog", "1",
        ])
        .output()
        .expect("run jobs with backlog");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.matches("submitted job #").count(), 1, "{stdout}");
    assert_eq!(stdout.matches("REJECTED").count(), 2, "{stdout}");
    assert!(stdout.contains("backlog full"), "{stdout}");
}

#[test]
fn cli_rejects_unknown_flags_listing_known_ones() {
    let bin = env!("CARGO_BIN_EXE_bmf-pp");
    let out = std::process::Command::new(bin)
        .args(["datasets", "--scalee", "0.001"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("scalee"), "{stderr}");
    assert!(stderr.contains("--scale"), "should list known flags: {stderr}");
}

#[test]
fn dag_and_barrier_schedulers_agree_bitwise_end_to_end() {
    // the full pipeline (centering → grid split → DAG → aggregation →
    // concat) must be schedule-invariant down to the last bit
    let (train, test, k) = dataset(0.002);
    let mk = |mode: SchedulerMode| {
        TrainConfig::new(k)
            .with_grid(3, 2)
            .with_sweeps(6, 12)
            .with_tau(auto_tau(&train))
            .with_seed(77)
            .with_backend(BackendSpec::Native)
            .with_scheduler(mode)
    };
    let dag = train_once(mk(SchedulerMode::Dag), &train);
    let bar = train_once(mk(SchedulerMode::Barrier), &train);
    assert_eq!(dag.u_mean, bar.u_mean);
    assert_eq!(dag.v_mean, bar.v_mean);
    assert_eq!(dag.u_post.prec, bar.u_post.prec);
    assert_eq!(dag.v_post.prec, bar.v_post.prec);
    assert!((dag.rmse(&test) - bar.rmse(&test)).abs() < 1e-12);
    // barrier edges forbid any phase-(b)/(c) overlap
    assert_eq!(bar.stats.overlap_secs, 0.0);
}

#[test]
fn phase_sample_reduction_reduces_compute() {
    let (train, _test, k) = dataset(0.002);
    let mk = |frac| {
        let mut c = TrainConfig::new(k)
            .with_grid(2, 2)
            .with_sweeps(6, 16)
            .with_tau(2.0)
            .with_backend(BackendSpec::Native);
        c.phase_sample_frac = frac;
        c
    };
    let full = train_once(mk(1.0), &train);
    let quarter = train_once(mk(0.25), &train);
    assert!(
        quarter.stats.sweeps < full.stats.sweeps,
        "{} vs {}",
        quarter.stats.sweeps,
        full.stats.sweeps
    );
}
