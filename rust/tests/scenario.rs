//! Scenario-harness suite: negative-path spec parsing (typed errors,
//! never panics, non-zero CLI exits), the shipped `scenarios/` directory
//! staying parseable, a tiny end-to-end run through the full
//! parse → execute → compare → report pipeline, and a property test that
//! random valid scenarios hold the cross-leg bitwise invariant.

use bmf_pp::harness::{self, Scenario, SpecError};
use bmf_pp::testing::prop::{check, Gen};
use std::path::{Path, PathBuf};

/// Unique scratch file holding `content`, cleaned up on drop.
struct SpecFile(PathBuf);

impl SpecFile {
    fn new(tag: &str, content: &str) -> SpecFile {
        let path = std::env::temp_dir().join(format!(
            "bmfpp_scn_{tag}_{}_{}.json",
            std::process::id(),
            std::thread::current().name().unwrap_or("t").replace("::", "_")
        ));
        std::fs::write(&path, content).unwrap();
        SpecFile(path)
    }
}

impl Drop for SpecFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

fn tiny_scenario(legs: &str, invariants: &str) -> String {
    format!(
        r#"{{
          "name": "tiny", "description": "test spec",
          "dataset": {{"profile": "movielens", "scale": 0.001, "seed": 4}},
          "config": {{"grid": "2x2", "burnin": 2, "samples": 4, "seed": 4}},
          "legs": [{legs}],
          "invariants": [{invariants}]
        }}"#
    )
}

// ---------------------------------------------------------------------------
// negative paths: typed SpecErrors, never a panic

#[test]
fn malformed_json_yields_typed_error() {
    let err = Scenario::parse("{ \"name\": ", "<t>").unwrap_err();
    assert!(matches!(err, SpecError::Json { .. }), "{err}");
}

#[test]
fn unknown_invariant_yields_typed_error() {
    let text = tiny_scenario(
        r#"{"name": "a"}"#,
        r#"{"check": "rmse_exactly", "leg": "a", "max": 1.0}"#,
    );
    let err = Scenario::parse(&text, "<t>").unwrap_err();
    match err {
        SpecError::BadValue { field, got, .. } => {
            assert_eq!(field, "check");
            assert_eq!(got, "rmse_exactly");
        }
        other => panic!("expected BadValue, got {other}"),
    }
}

#[test]
fn staleness_on_lockstep_yields_typed_error() {
    let text = tiny_scenario(
        r#"{"name": "a", "staleness": 3}"#,
        r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
    );
    let err = Scenario::parse(&text, "<t>").unwrap_err();
    assert!(matches!(err, SpecError::StalenessOnLockstep { staleness: 3, .. }), "{err}");
}

#[test]
fn fault_without_checkpointing_yields_typed_error() {
    let text = tiny_scenario(
        r#"{"name": "a", "fault_block": 1}"#,
        r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
    );
    let err = Scenario::parse(&text, "<t>").unwrap_err();
    assert!(matches!(err, SpecError::FaultWithoutCheckpoint { .. }), "{err}");
}

#[test]
fn unknown_key_yields_typed_error_with_accepted_list() {
    let text = tiny_scenario(
        r#"{"name": "a", "cache_byte": 64}"#,
        r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
    );
    let err = Scenario::parse(&text, "<t>").unwrap_err();
    let msg = err.to_string();
    assert!(matches!(err, SpecError::UnknownKey { .. }), "{msg}");
    assert!(msg.contains("cache_byte") && msg.contains("cache_bytes"), "{msg}");
}

#[test]
fn empty_directory_yields_typed_error() {
    let dir = std::env::temp_dir().join(format!("bmfpp_scn_empty_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let err = harness::load_path(&dir).unwrap_err();
    assert!(matches!(err, SpecError::NoScenarios { .. }), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// CLI exit codes

fn run_scenario_cli(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_bmf-pp"))
        .arg("scenario")
        .args(args)
        .output()
        .expect("spawn bmf-pp")
}

#[test]
fn cli_malformed_specs_exit_nonzero_with_typed_message() {
    let bad_check =
        tiny_scenario(r#"{"name": "a"}"#, r#"{"check": "rmse_min", "leg": "a", "max": 1.0}"#);
    let stale = tiny_scenario(
        r#"{"name": "a", "staleness": 2}"#,
        r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
    );
    let no_ckpt = tiny_scenario(
        r#"{"name": "a", "fault_block": 1}"#,
        r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
    );
    for (tag, content, needle) in [
        ("badjson", "{ not json at all", "not valid JSON"),
        ("badcheck", bad_check.as_str(), "bad value"),
        ("stale", stale.as_str(), "staleness"),
        ("nockpt", no_ckpt.as_str(), "checkpointing"),
    ] {
        let spec = SpecFile::new(tag, content);
        let out = run_scenario_cli(&[spec.0.to_str().unwrap()]);
        assert!(!out.status.success(), "{tag}: malformed spec must exit non-zero");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(needle), "{tag}: stderr missing '{needle}':\n{stderr}");
    }
}

#[test]
fn cli_missing_path_exits_nonzero() {
    let out = run_scenario_cli(&["/definitely/not/there.json"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("cannot read scenario"));
}

#[test]
fn cli_failed_invariant_exits_nonzero_and_prints_rerun_line() {
    // impossible RMSE bound: the run completes but the invariant fails
    let spec = SpecFile::new(
        "failinv",
        &tiny_scenario(r#"{"name": "a"}"#, r#"{"check": "rmse_max", "leg": "a", "max": 0.000001}"#),
    );
    let out = run_scenario_cli(&[spec.0.to_str().unwrap()]);
    assert!(!out.status.success(), "failed invariant must exit non-zero");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("FAIL"), "{stdout}");
    assert!(
        stdout.contains(&format!("re-run: bmf-pp scenario {}", spec.0.display())),
        "missing re-run hint:\n{stdout}"
    );
}

#[test]
fn cli_list_parses_all_shipped_scenarios() {
    let shipped = Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios");
    let out = run_scenario_cli(&[shipped.to_str().unwrap(), "--list"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "--list failed:\n{}", String::from_utf8_lossy(&out.stderr));
    // the shipped suite must keep covering the standing guarantees
    for name in [
        "tau0-pipelined-bitwise",
        "out-of-core",
        "crash-resume",
        "multi-tenant-priority",
        "skewed-grid-rmse",
    ] {
        assert!(stdout.contains(name), "--list missing {name}:\n{stdout}");
    }
    assert!(stdout.lines().count() >= 8, "expected >= 8 shipped scenarios:\n{stdout}");
}

#[test]
fn cli_filter_selects_by_name() {
    let shipped = Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios");
    let out = run_scenario_cli(&[shipped.to_str().unwrap(), "--list", "--filter", "crash"]);
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("crash-resume"), "{stdout}");
    assert!(!stdout.contains("tau0-pipelined-bitwise"), "{stdout}");

    let none = run_scenario_cli(&[shipped.to_str().unwrap(), "--list", "--filter", "zzz-none"]);
    assert!(!none.status.success(), "empty filter match must exit non-zero");
}

// ---------------------------------------------------------------------------
// end-to-end through the library pipeline

#[test]
fn tiny_bitwise_scenario_passes_end_to_end() {
    let text = tiny_scenario(
        r#"{"name": "dag"}, {"name": "barrier", "scheduler": "barrier"}"#,
        r#"{"check": "bitwise_equal", "legs": ["dag", "barrier"]},
           {"check": "expect_outcome", "leg": "dag", "outcome": "completed"}"#,
    );
    let scn = Scenario::parse(&text, "<inline>").unwrap();
    let report = harness::run_and_check(&scn).unwrap();
    assert!(
        report.passed(),
        "tiny scenario failed:\n{}",
        harness::render_human(&report)
    );
    // the machine report round-trips through the JSON writer/parser
    let json = bmf_pp::util::json::to_string_pretty(&harness::to_json(std::slice::from_ref(
        &report,
    )));
    let parsed = bmf_pp::util::json::parse(&json).unwrap();
    assert_eq!(parsed.get("passed").and_then(|v| v.as_f64()), Some(1.0));
}

#[test]
fn fault_leg_resumes_bitwise_end_to_end() {
    let text = r#"{
      "name": "tiny-crash", "description": "crash then resume equals uninterrupted",
      "dataset": {"profile": "movielens", "scale": 0.001, "seed": 6},
      "config": {"grid": "2x2", "burnin": 2, "samples": 4, "seed": 6},
      "legs": [
        {"name": "reference"},
        {"name": "crashed", "fault_block": 3, "checkpoint_every": 1}
      ],
      "invariants": [
        {"check": "resume_bitwise", "resumed": "crashed", "reference": "reference"}
      ]
    }"#;
    let scn = Scenario::parse(text, "<inline>").unwrap();
    let report = harness::run_and_check(&scn).unwrap();
    assert!(report.passed(), "crash scenario failed:\n{}", harness::render_human(&report));
    let crashed = report.run.leg("crashed").unwrap();
    assert!(crashed.blocks_restored > 0, "resume restored nothing");
}

// ---------------------------------------------------------------------------
// property: random valid scenarios hold the cross-leg bitwise invariant

#[derive(Debug)]
struct RandomScenario {
    text: String,
}

fn random_scenario(g: &mut Gen) -> RandomScenario {
    let (gi, gj) = *g.pick(&[(1usize, 1usize), (2, 2), (3, 2)]);
    let seed = g.usize_in(1, 1000);
    let scheduler = *g.pick(&["dag", "barrier"]);
    // the varied leg flips sweep mode (τ=0) and/or goes store-backed —
    // every combination must stay bitwise-equal to the plain leg
    let pipelined = *g.pick(&[true, false]);
    let store = *g.pick(&[true, false]);
    let mut varied = String::from(r#"{"name": "varied""#);
    if pipelined {
        varied.push_str(r#", "sweep": "pipelined", "staleness": 0, "chunk_rows": 16"#);
    }
    if store {
        varied.push_str(r#", "store": true, "cache_bytes": 2048"#);
    }
    varied.push('}');
    let text = format!(
        r#"{{
          "name": "prop-{gi}x{gj}-{seed}",
          "description": "randomized bitwise pair",
          "dataset": {{"profile": "movielens", "scale": 0.001, "seed": {seed}}},
          "config": {{"grid": "{gi}x{gj}", "burnin": 2, "samples": 4, "seed": {seed},
                     "scheduler": "{scheduler}", "tau": 1.5}},
          "legs": [{{"name": "plain"}}, {varied}],
          "invariants": [{{"check": "bitwise_equal", "legs": ["plain", "varied"]}}]
        }}"#
    );
    RandomScenario { text }
}

#[test]
fn random_valid_scenarios_hold_bitwise_invariant() {
    check(4, random_scenario, |scn| {
        let parsed = Scenario::parse(&scn.text, "<prop>")
            .map_err(|e| format!("generated spec rejected: {e}"))?;
        let report = harness::run_and_check(&parsed).map_err(|e| format!("run failed: {e}"))?;
        if report.passed() {
            Ok(())
        } else {
            Err(format!("invariant failed:\n{}", harness::render_human(&report)))
        }
    });
}
