//! Multi-session stress: N concurrent submits with deterministic-random
//! cancels/pauses on one engine, watchdog-guarded so a scheduler deadlock
//! fails the test instead of hanging CI, and a timed engine drop proving
//! the pool shuts down clean afterwards.
//!
//! Heavy by design — run explicitly (CI stress job):
//!
//!     cargo test --release --test stress -- --ignored --nocapture

use bmf_pp::coordinator::{BackendSpec, Engine, Priority, TrainConfig, TrainOutcome};
use bmf_pp::data::generator::SyntheticDataset;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::rng::Rng;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

#[test]
#[ignore = "heavy; exercised by the CI stress job"]
fn multi_session_stress_random_cancels_no_deadlock() {
    let ds = SyntheticDataset::by_name("movielens", 0.0015, 301).unwrap();
    let (train, _) = holdout_split_covered(&ds.ratings, 0.2, 302);
    let engine = Arc::new(Engine::new(&BackendSpec::Native, 4));
    let mut rng = Rng::seed_from_u64(303);

    const JOBS: usize = 12;
    let (done_tx, done_rx) = channel::<(usize, &'static str)>();
    let mut workers = Vec::new();
    for idx in 0..JOBS {
        let priority = match idx % 3 {
            0 => Priority::Low,
            1 => Priority::Normal,
            _ => Priority::High,
        };
        let grid = if rng.bernoulli(0.5) { (3, 3) } else { (2, 2) };
        let cfg = TrainConfig::new(ds.k)
            .with_backend(BackendSpec::Native)
            .with_grid(grid.0, grid.1)
            .with_sweeps(3, 6)
            .with_seed(304 + idx as u64)
            .with_priority(priority)
            .with_max_in_flight(if rng.bernoulli(0.3) { 1 } else { 0 })
            .with_checkpoint_on_cancel(std::env::temp_dir().join(format!(
                "bmfpp_stress_{}_{idx}.json",
                std::process::id()
            )));
        let session = engine.submit(cfg, &train).unwrap();
        // a third of the jobs get cancelled at a random point, a third
        // get briefly paused; the pool must drain them all either way
        let action = rng.uniform();
        let delay_ms = (rng.uniform() * 40.0) as u64;
        let done = done_tx.clone();
        workers.push(std::thread::spawn(move || {
            if action < 0.33 {
                std::thread::sleep(Duration::from_millis(delay_ms));
                session.cancel();
            } else if action < 0.66 {
                std::thread::sleep(Duration::from_millis(delay_ms));
                session.pause();
                std::thread::sleep(Duration::from_millis(10));
                session.resume();
            }
            let outcome = session.wait().unwrap();
            let kind = match outcome {
                TrainOutcome::Completed(_) => "completed",
                TrainOutcome::Cancelled(info) => {
                    // a checkpoint only exists when blocks completed
                    assert_eq!(info.checkpoint.is_some(), info.blocks_completed > 0);
                    if let Some(p) = &info.checkpoint {
                        std::fs::remove_file(p).ok();
                    }
                    "cancelled"
                }
                // no fault plan is armed here: any failure is a real bug
                TrainOutcome::Failed(info) => panic!("stress job failed: {}", info.error),
            };
            let _ = done.send((idx, kind));
        }));
    }
    drop(done_tx);

    // watchdog: every session must settle well within the budget — a
    // recv timeout here IS the deadlock detector
    let mut outcomes = Vec::new();
    for _ in 0..JOBS {
        let (idx, kind) = done_rx
            .recv_timeout(Duration::from_secs(180))
            .expect("a session failed to settle within 180s — scheduler deadlock?");
        outcomes.push((idx, kind));
    }
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(outcomes.len(), JOBS);
    println!(
        "settled: {} completed / {} cancelled",
        outcomes.iter().filter(|(_, k)| *k == "completed").count(),
        outcomes.iter().filter(|(_, k)| *k == "cancelled").count()
    );

    // clean pool shutdown: dropping the engine joins every worker; guard
    // it with the same watchdog pattern
    let (drop_tx, drop_rx) = channel::<()>();
    let engine = Arc::try_unwrap(engine).map_err(|_| ()).expect("all clones joined");
    std::thread::spawn(move || {
        drop(engine);
        let _ = drop_tx.send(());
    });
    drop_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("engine drop (pool join) hung — queue failed to drain");
}
