//! Gibbs-kernel contracts: the optimized `RowSampler` must reproduce the
//! retained naive reference bit for bit in the f64 regime (across random
//! shapes, skewed sparsity, empty rows, and arbitrary chunk boundaries),
//! the f32 regime must track f64 within its documented tolerance, and a
//! non-SPD posterior precision must surface as a typed error through
//! every layer — kernel, `run_block` (both sweep schedules), and the
//! engine's failure path — never as a panic or a deadlock.

use bmf_pp::coordinator::backend::{BlockBackend, BlockData};
use bmf_pp::coordinator::block_task::{run_block, BlockObs, BlockTaskCfg};
use bmf_pp::coordinator::SweepMode;
use bmf_pp::data::sparse::{Coo, Csr};
use bmf_pp::gibbs::native::{
    sample_rows_reference, sample_side_native, GibbsPrecision, RowSampler, SampleError,
};
use bmf_pp::posterior::RowGaussians;
use bmf_pp::rng::{normal::standard_normal_vec, Rng};
use bmf_pp::testing::prop::{check, Gen};

/// A random side: CSR with skewed per-row occupancy (some rows dense,
/// some sparse, some empty), opposite factors, a randomized SPD prior,
/// injected noise, and a τ.
#[derive(Debug)]
struct KernelCase {
    n: usize,
    d: usize,
    k: usize,
    entries: Vec<(usize, usize, f32)>,
    tau: f64,
}

fn gen_case(g: &mut Gen) -> KernelCase {
    let k = g.usize_in(1, 32);
    let n = g.size(1, 48);
    let d = g.size(1, 40);
    let mut entries = Vec::new();
    for r in 0..n {
        // skewed occupancy: square a uniform so most rows are sparse and
        // a few are dense; ~1 in 4 rows stays completely empty
        if g.rng.uniform() < 0.25 {
            continue;
        }
        let frac = g.rng.uniform().powi(2);
        let nnz_row = ((d as f64 * frac).ceil() as usize).min(d);
        for _ in 0..nnz_row {
            let c = g.rng.below(d);
            entries.push((r, c, (g.rng.uniform() * 4.0 + 1.0) as f32));
        }
    }
    let tau = g.f64_in(0.1, 5.0);
    KernelCase { n, d, k, entries, tau }
}

fn case_inputs(case: &KernelCase, seed: u64) -> (Csr, Vec<f32>, RowGaussians, Vec<f32>) {
    let (n, d, k) = (case.n, case.d, case.k);
    let mut coo = Coo::new(n, d);
    for &(r, c, val) in &case.entries {
        coo.push(r, c, val);
    }
    let csr = Csr::from_coo(&coo);
    let mut rng = Rng::seed_from_u64(seed);
    let v = standard_normal_vec(&mut rng, d * k);
    let mut prior = RowGaussians::standard(n, k, 1.0 + rng.uniform() * 3.0);
    for m in prior.mean.iter_mut() {
        *m = (rng.uniform() - 0.5) * 2.0;
    }
    let noise = standard_normal_vec(&mut rng, n * k);
    (csr, v, prior, noise)
}

#[test]
fn optimized_kernel_is_bitwise_equal_to_reference_across_random_cases() {
    check(40, gen_case, |case| {
        let (n, k) = (case.n, case.k);
        let (csr, v, prior, noise) = case_inputs(case, 0xC0FFEE ^ n as u64);

        let mut s_ref = vec![0.0f32; n * k];
        let mut m_ref = vec![0.0f32; n * k];
        sample_rows_reference(&csr, 0..n, &v, k, &prior, case.tau, &noise, &mut s_ref, &mut m_ref)
            .map_err(|e| format!("reference errored: {e}"))?;

        // one reused arena, driven over arbitrary chunk boundaries — the
        // chunk-invariance contract and the bitwise contract in one pass
        let mut sampler = RowSampler::new(k, GibbsPrecision::F64);
        let mut s_opt = vec![0.0f32; n * k];
        let mut m_opt = vec![0.0f32; n * k];
        let chunk = 1 + (n * k) % 7; // deterministic, often straddles rows
        let mut a = 0;
        while a < n {
            let b = (a + chunk).min(n);
            sampler
                .sample_rows_into(
                    &csr,
                    a..b,
                    &v,
                    &prior,
                    case.tau,
                    &noise,
                    &mut s_opt[a * k..b * k],
                    &mut m_opt[a * k..b * k],
                )
                .map_err(|e| format!("optimized errored: {e}"))?;
            a = b;
        }

        for i in 0..n * k {
            if s_opt[i].to_bits() != s_ref[i].to_bits() {
                return Err(format!(
                    "sample[{i}] diverged: {} vs {}",
                    s_opt[i], s_ref[i]
                ));
            }
            if m_opt[i].to_bits() != m_ref[i].to_bits() {
                return Err(format!("mean[{i}] diverged: {} vs {}", m_opt[i], m_ref[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn f32_regime_tracks_f64_within_documented_tolerance() {
    check(15, gen_case, |case| {
        let (n, k) = (case.n, case.k);
        let (csr, v, prior, noise) = case_inputs(case, 0xF32 ^ n as u64);

        let (s64, m64) = RowSampler::new(k, GibbsPrecision::F64)
            .sample_side(&csr, &v, &prior, case.tau, &noise)
            .map_err(|e| format!("f64 errored: {e}"))?;
        let (s32, m32) = RowSampler::new(k, GibbsPrecision::F32)
            .sample_side(&csr, &v, &prior, case.tau, &noise)
            .map_err(|e| format!("f32 errored: {e}"))?;

        // documented tolerance: ~1e-3 relative typical (docs/PERFORMANCE.md);
        // the hard bound here is 5e-3 to absorb ill-conditioned random cases
        for i in 0..n * k {
            let scale = s64[i].abs().max(1.0);
            if (s32[i] - s64[i]).abs() > 5e-3 * scale {
                return Err(format!("sample[{i}]: f32 {} vs f64 {}", s32[i], s64[i]));
            }
            let mscale = m64[i].abs().max(1.0);
            if (m32[i] - m64[i]).abs() > 5e-3 * mscale {
                return Err(format!("mean[{i}]: f32 {} vs f64 {}", m32[i], m64[i]));
            }
        }
        Ok(())
    });
}

/// A 4×3 block whose row 2 is unobserved with an all-zero prior precision
/// row — the posterior precision for that row is exactly zero, so the
/// factorization must reject it at pivot 0.
fn degenerate_setup(k: usize) -> (BlockData, RowGaussians) {
    let mut coo = Coo::new(4, 3);
    coo.push(0, 0, 3.0);
    coo.push(1, 1, 2.0);
    coo.push(3, 2, 4.0);
    let mut prior = RowGaussians::standard(4, k, 2.0);
    for x in prior.prec[2 * k * k..3 * k * k].iter_mut() {
        *x = 0.0;
    }
    (BlockData::new(coo), prior)
}

#[test]
fn degenerate_prior_yields_typed_error_in_both_kernels() {
    let k = 3;
    let (data, prior) = degenerate_setup(k);
    let mut rng = Rng::seed_from_u64(5);
    let v = standard_normal_vec(&mut rng, 3 * k);
    let noise = standard_normal_vec(&mut rng, 4 * k);

    let err = sample_side_native(&data.csr, &v, k, &prior, 1.0, &noise).unwrap_err();
    assert_eq!(err.row, 2, "error names the degenerate row");
    assert_eq!(err.source.index, 0, "zero precision fails at the first pivot");

    let mut s = vec![0.0f32; 4 * k];
    let mut m = vec![0.0f32; 4 * k];
    let ref_err = sample_rows_reference(&data.csr, 0..4, &v, k, &prior, 1.0, &noise, &mut s, &mut m)
        .unwrap_err();
    assert_eq!(ref_err.row, err.row, "both kernels reject the same row");
}

#[test]
fn run_block_surfaces_degenerate_prior_as_error_not_panic() {
    let k = 3;
    let (data, prior) = degenerate_setup(k);
    let cfg = BlockTaskCfg {
        k,
        tau: 1.0,
        burnin: 2,
        samples: 4,
        workers: 1,
        ridge: 1e-3,
        seed: 9,
        sweep: SweepMode::Lockstep,
        chunk_rows: 2,
        staleness: 0,
        precision: GibbsPrecision::F64,
    };
    let err = run_block(&BlockBackend::Native, &data, &cfg, Some(&prior), None, BlockObs::default())
        .unwrap_err();
    let sample_err = err.downcast_ref::<SampleError>().expect("typed SampleError");
    assert_eq!(sample_err.row, 2);
}

#[test]
fn pipelined_run_with_degenerate_prior_errors_without_deadlocking() {
    // the failing U worker must zero-fill-publish its remaining chunks so
    // peer workers' staleness gates open; the sweep then fails cleanly
    let k = 3;
    let (data, prior) = degenerate_setup(k);
    for workers in [1usize, 2, 3] {
        let cfg = BlockTaskCfg {
            k,
            tau: 1.0,
            burnin: 2,
            samples: 4,
            workers,
            ridge: 1e-3,
            seed: 11,
            sweep: SweepMode::Pipelined,
            chunk_rows: 1,
            staleness: 0,
            precision: GibbsPrecision::F64,
        };
        let err = run_block(
            &BlockBackend::Native,
            &data,
            &cfg,
            Some(&prior),
            None,
            BlockObs::default(),
        )
        .unwrap_err();
        let sample_err = err.downcast_ref::<SampleError>().expect("typed SampleError");
        assert_eq!(sample_err.row, 2, "workers={workers}");
    }
}

#[test]
fn f32_regime_trains_a_block_end_to_end() {
    // the opt-in fast path runs the full block task and produces finite,
    // usable posteriors (statistical sanity only — it is excluded from
    // the bitwise contracts by design)
    let mut coo = Coo::new(20, 16);
    let mut rng = Rng::seed_from_u64(21);
    for _ in 0..140 {
        coo.push(rng.below(20), rng.below(16), (rng.uniform() * 4.0 + 1.0) as f32);
    }
    let data = BlockData::new(coo);
    let cfg = BlockTaskCfg {
        k: 4,
        tau: 2.0,
        burnin: 4,
        samples: 8,
        workers: 2,
        ridge: 1e-3,
        seed: 22,
        sweep: SweepMode::Lockstep,
        chunk_rows: 8,
        staleness: 0,
        precision: GibbsPrecision::F32,
    };
    let (post, stats) =
        run_block(&BlockBackend::Native, &data, &cfg, None, None, BlockObs::default()).unwrap();
    assert_eq!(stats.sweeps, 12);
    assert!(post.u.mean.iter().all(|x| x.is_finite()));
    assert!(post.v.mean.iter().all(|x| x.is_finite()));
}
