//! Out-of-core store integration tests: the acceptance contract is that
//! training from an ingested shard store is the **same computation** as
//! training resident — bitwise-identical posteriors across grids, sweep
//! modes, and cache budgets (including a degenerate budget that forces
//! the cache to evict on every block), typed `StoreError`s for corrupt
//! or version-skewed stores surfaced before any training starts, and
//! cancel → resume working unchanged on the store-backed path.
//!
//! The CI `out-of-core` job runs this suite under `--release` next to
//! `scripts/out_of_core_drill.sh` (the ulimit-capped CLI drill).

use bmf_pp::coordinator::{
    BackendSpec, Engine, SweepMode, TrainConfig, TrainOutcome, TrainResult,
};
use bmf_pp::data::generator::SyntheticDataset;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::data::sparse::Coo;
use bmf_pp::store::{ingest, ShardStore, StoreError};
use std::path::PathBuf;
use std::sync::Arc;

fn dataset() -> (Coo, usize) {
    let ds = SyntheticDataset::by_name("movielens", 0.0015, 501).unwrap();
    let (train, _) = holdout_split_covered(&ds.ratings, 0.2, 502);
    (train, ds.k)
}

fn quick_cfg(k: usize) -> TrainConfig {
    TrainConfig::new(k)
        .with_backend(BackendSpec::Native)
        .with_grid(2, 2)
        .with_sweeps(3, 6)
        .with_tau(1.2)
        .with_seed(503)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bmfpp_store_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn ingest_to(train: &Coo, gi: usize, gj: usize, tag: &str) -> (Arc<ShardStore>, PathBuf) {
    let dir = tmp_dir(tag);
    ingest(train, gi, gj, &dir).unwrap();
    (Arc::new(ShardStore::open(&dir).unwrap()), dir)
}

fn assert_bitwise_eq(a: &TrainResult, b: &TrainResult, ctx: &str) {
    assert_eq!(a.u_post.mean, b.u_post.mean, "u mean diverged: {ctx}");
    assert_eq!(a.u_post.prec, b.u_post.prec, "u prec diverged: {ctx}");
    assert_eq!(a.v_post.mean, b.v_post.mean, "v mean diverged: {ctx}");
    assert_eq!(a.v_post.prec, b.v_post.prec, "v prec diverged: {ctx}");
}

#[test]
fn store_backed_training_is_bitwise_identical_to_resident() {
    // the full equivalence matrix: grid shape x sweep mode x cache budget.
    // budget 0 = unbounded; budget 1 byte cannot hold even one shard, so
    // every block load evicts its predecessors — the posterior must not
    // notice either way.
    let (train, k) = dataset();
    let engine = Engine::new(&BackendSpec::Native, 2);
    for &(gi, gj) in &[(1usize, 1usize), (2, 2), (3, 2)] {
        let (store, dir) = ingest_to(&train, gi, gj, &format!("matrix_{gi}x{gj}"));
        for mode in [SweepMode::Lockstep, SweepMode::Pipelined] {
            let mut cfg = quick_cfg(k).with_grid(gi, gj).with_sweep_mode(mode);
            if mode == SweepMode::Pipelined {
                cfg = cfg.with_chunk_rows(64).with_staleness(0);
            }
            let resident = engine.train(&cfg, &train).unwrap();
            for budget in [0u64, 1] {
                let r = engine
                    .train_store(&cfg.clone().with_cache_bytes(budget), store.clone())
                    .unwrap();
                let ctx = format!("grid {gi}x{gj}, {mode:?}, cache_bytes={budget}");
                assert_bitwise_eq(&resident, &r, &ctx);
                if budget == 1 && gi * gj > 1 {
                    assert!(
                        r.stats.shard_evictions > 0,
                        "degenerate budget must force evictions ({ctx})"
                    );
                    assert!(r.stats.shard_misses > 0, "every load is a miss ({ctx})");
                }
            }
        }
        std::fs::remove_dir_all(dir).ok();
    }
}

#[test]
fn shard_counters_reach_run_stats_and_job_snapshot() {
    let (train, k) = dataset();
    let (store, dir) = ingest_to(&train, 2, 2, "counters");
    let engine = Engine::new(&BackendSpec::Native, 2);
    let session =
        engine.submit_store(quick_cfg(k).with_cache_bytes(1), store).unwrap();
    let result = session.wait().unwrap().into_result().unwrap();
    // every phase touches each of the 4 blocks at least once from disk
    assert!(result.stats.shard_misses >= 4, "misses: {}", result.stats.shard_misses);
    assert!(result.stats.shard_bytes_peak > 0);
    let snap = engine.jobs();
    assert_eq!(snap.len(), 1);
    assert_eq!(snap[0].shard_misses, result.stats.shard_misses);
    assert_eq!(snap[0].shard_hits, result.stats.shard_hits);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn grid_mismatch_is_a_typed_submit_time_error() {
    let (train, k) = dataset();
    let (store, dir) = ingest_to(&train, 2, 2, "grid_mismatch");
    let engine = Engine::new(&BackendSpec::Native, 2);
    let err = engine
        .submit_store(quick_cfg(k).with_grid(3, 3), store.clone())
        .expect_err("3x3 config over a 2x2 store must be rejected");
    match err.downcast_ref::<StoreError>() {
        Some(StoreError::GridMismatch { cfg, store }) => {
            assert_eq!(*cfg, (3, 3));
            assert_eq!(*store, (2, 2));
        }
        other => panic!("expected GridMismatch, got {other:?}"),
    }
    // the blocking train path rejects identically
    let err = engine
        .train_store(&quick_cfg(k).with_grid(3, 3), store)
        .expect_err("train_store must reject too");
    assert!(matches!(
        err.downcast_ref::<StoreError>(),
        Some(StoreError::GridMismatch { .. })
    ));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn corrupt_and_stale_stores_fail_typed_at_open() {
    let (train, _) = dataset();

    // truncated shard → SizeMismatch naming the file
    let dir = tmp_dir("truncated");
    ingest(&train, 2, 2, &dir).unwrap();
    let shard = dir.join("shard-0000-0000.bin");
    let bytes = std::fs::read(&shard).unwrap();
    std::fs::write(&shard, &bytes[..bytes.len() - 1]).unwrap();
    match ShardStore::open(&dir) {
        Err(StoreError::SizeMismatch { path, .. }) => {
            assert!(path.ends_with("shard-0000-0000.bin"), "{path:?}")
        }
        other => panic!("expected SizeMismatch, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();

    // flipped byte → ChecksumMismatch
    let dir = tmp_dir("corrupt");
    ingest(&train, 2, 2, &dir).unwrap();
    let shard = dir.join("shard-0001-0001.bin");
    let mut bytes = std::fs::read(&shard).unwrap();
    bytes[0] ^= 0xFF;
    std::fs::write(&shard, &bytes).unwrap();
    assert!(matches!(
        ShardStore::open(&dir),
        Err(StoreError::ChecksumMismatch { .. })
    ));
    std::fs::remove_dir_all(&dir).ok();

    // missing shard → MissingShard
    let dir = tmp_dir("missing");
    ingest(&train, 2, 2, &dir).unwrap();
    std::fs::remove_file(dir.join("shard-0001-0000.bin")).unwrap();
    assert!(matches!(ShardStore::open(&dir), Err(StoreError::MissingShard { .. })));
    std::fs::remove_dir_all(&dir).ok();

    // future manifest version → Version naming the supported range
    let dir = tmp_dir("stale");
    ingest(&train, 2, 2, &dir).unwrap();
    let manifest = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest).unwrap();
    assert!(text.contains("\"version\": 1"), "manifest format changed? {text}");
    std::fs::write(&manifest, text.replace("\"version\": 1", "\"version\": 999")).unwrap();
    match ShardStore::open(&dir) {
        Err(StoreError::Version { found, oldest, newest }) => {
            assert_eq!(found, 999);
            assert!(oldest <= newest);
        }
        other => panic!("expected Version, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cancel_then_resume_store_backed_is_bitwise_identical() {
    // cancel a store-backed run after its first block, resume from the
    // abort checkpoint (still store-backed), and require the posterior to
    // match both an uninterrupted store run and the resident run
    let (train, k) = dataset();
    let (store, dir) = ingest_to(&train, 3, 3, "cancel_resume");
    let ckpt = tmp_dir("cancel_ckpt").join("abort.json");
    std::fs::create_dir_all(ckpt.parent().unwrap()).unwrap();
    let engine = Engine::new(&BackendSpec::Native, 2);
    let base = quick_cfg(k).with_grid(3, 3);

    let session = engine
        .submit_store(base.clone().with_checkpoint_on_cancel(&ckpt), store.clone())
        .unwrap();
    while session.progress().0 < 1 && !session.status().is_terminal() {
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    session.cancel();
    let info = match session.wait().unwrap() {
        TrainOutcome::Cancelled(info) => info,
        // the run can beat the cancel on a fast machine — then there is
        // nothing to resume and the bitwise matrix test already covers it
        TrainOutcome::Completed(_) => return,
        TrainOutcome::Failed(info) => panic!("unexpected failure: {}", info.error),
    };
    let ckpt_path = info.checkpoint.expect("abort checkpoint written");

    let resumed = engine
        .train_store(&base.clone().with_resume_from(&ckpt_path), store.clone())
        .unwrap();
    assert!(resumed.stats.blocks_restored >= 1);
    let uninterrupted = engine.train_store(&base, store).unwrap();
    let resident = engine.train(&base, &train).unwrap();
    assert_bitwise_eq(&resumed, &uninterrupted, "resumed vs uninterrupted (store)");
    assert_bitwise_eq(&resumed, &resident, "resumed store run vs resident");
    std::fs::remove_dir_all(dir).ok();
    std::fs::remove_file(&ckpt_path).ok();
}
