//! # D-BMF+PP
//!
//! Distributed Bayesian Matrix Factorization with Posterior Propagation —
//! a reproduction of Vander Aa et al. (2020), "A High-Performance
//! Implementation of Bayesian Matrix Factorization with Limited
//! Communication".
//!
//! ## The API in three types
//!
//! - [`coordinator::Engine`] — a persistent training engine owning the
//!   warm worker pool (and, under the `pjrt` feature, each worker's PJRT
//!   client and compiled-artifact cache). Build it once, run many jobs —
//!   *concurrently*: all submitted jobs share one priority-ordered ready
//!   queue ([`coordinator::Priority`], `TrainConfig::max_in_flight`), and
//!   interleaving never changes any job's posterior.
//! - [`coordinator::Session`] — a handle to one in-flight run, returned by
//!   the non-blocking [`coordinator::Engine::submit`]; it streams typed
//!   [`coordinator::TrainEvent`]s (phase starts, block completions,
//!   per-sweep RMSE samples) while training executes, exposes lifecycle
//!   control (`cancel` / `pause` / `resume` / `status`), and
//!   [`coordinator::Session::wait`] yields the
//!   [`coordinator::TrainOutcome`]. A cancelled run persists its
//!   completed block posteriors as a partial (v3) checkpoint;
//!   `TrainConfig::resume_from` continues from it bitwise-identically.
//!   Runs are crash-tolerant too: `TrainConfig::{checkpoint_every,
//!   checkpoint_dir}` write periodic generation files (resume from the
//!   directory restores the newest valid one), a panicking block fails
//!   only its own session ([`coordinator::TrainOutcome::Failed`]), and
//!   the engine's [`coordinator::AdmissionPolicy`] bounds the backlog.
//! - [`posterior::PosteriorModel`] — the servable artifact every run
//!   produces: posterior means/precisions + global mean, with `predict`,
//!   `predict_variance`, `rmse` and `top_n`. Checkpoints persist exactly
//!   this type, and the baselines convert into it, so serving code never
//!   cares which method trained the model.
//!
//! PP and the comparator methods all implement
//! [`coordinator::Factorizer`], so sweeping methods is a loop over
//! `fit(&engine, &data)` calls on one warm engine.
//!
//! ## Quickstart
//!
//! ```
//! use bmf_pp::coordinator::{BackendSpec, Engine, TrainConfig, TrainEvent};
//! use bmf_pp::data::generator::SyntheticDataset;
//! use bmf_pp::data::split::holdout_split_covered;
//!
//! let ds = SyntheticDataset::by_name("movielens", 0.001, 7).expect("profile");
//! let (train, test) = holdout_split_covered(&ds.ratings, 0.2, 8);
//!
//! // one warm engine, reusable across any number of runs
//! let engine = Engine::new(&BackendSpec::Native, 2);
//! let cfg = TrainConfig::new(ds.k).with_grid(2, 2).with_sweeps(3, 6).with_seed(1);
//!
//! // submit() is non-blocking: it validates the config and returns a
//! // Session streaming progress events (any number may run at once)
//! let session = engine.submit(cfg, &train).unwrap();
//! let mut blocks_done = 0;
//! for event in session.events() {
//!     if let TrainEvent::BlockCompleted { .. } = event {
//!         blocks_done += 1;
//!     }
//! }
//! // wait() reports how the run ended; nobody cancelled, so unwrap the
//! // completed result
//! let result = session.wait().unwrap().into_result().unwrap();
//! assert_eq!(blocks_done, 4); // 2x2 grid
//!
//! // the servable artifact: predictions, uncertainty, rankings
//! let model = result.model;
//! assert!(model.rmse(&test).is_finite());
//! assert!(model.predict_variance(0, 0) > 0.0);
//! let top = model.top_n(0, 3);
//! assert_eq!(top.len(), 3);
//! ```
//!
//! ## The three-layer stack
//!
//! The rust crate is the Layer-3 coordinator:
//! - **L3 (this crate)**: Posterior-Propagation phase scheduling across an
//!   I×J block grid, distributed Gibbs workers inside each block, posterior
//!   propagation/aggregation, datasets, baselines (NOMAD/FPSGD/ALS/CGD/
//!   SGLD), a cluster simulator for strong-scaling studies, CLI and
//!   metrics.
//! - **L2 (python/compile/model.py, build-time)**: the BPMF Gibbs half-sweep
//!   as a JAX graph, AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels/, build-time)**: the Gibbs hot-spot as a
//!   Pallas kernel lowered into the same HLO.
//!
//! At runtime the coordinator executes the AOT artifacts through the PJRT
//! CPU client (`runtime`); python is never on the hot path.
//!
//! A narrative tour of the stack — the paper-section → module map, the
//! block DAG, and the pipelined sweep — lives in `docs/ARCHITECTURE.md`
//! at the repository root.

#![warn(missing_docs)]

pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod gibbs;
pub mod linalg;
pub mod metrics;
pub mod partition;
pub mod posterior;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod testing;
pub mod util;
