//! # D-BMF+PP
//!
//! Distributed Bayesian Matrix Factorization with Posterior Propagation —
//! a reproduction of Vander Aa et al. (2020), "A High-Performance
//! Implementation of Bayesian Matrix Factorization with Limited
//! Communication".
//!
//! The rust crate is the Layer-3 coordinator of a three-layer stack:
//! - **L3 (this crate)**: Posterior-Propagation phase scheduling across an
//!   I×J block grid, distributed Gibbs workers inside each block, posterior
//!   propagation/aggregation, datasets, baselines (NOMAD/FPSGD), a cluster
//!   simulator for strong-scaling studies, CLI and metrics.
//! - **L2 (python/compile/model.py, build-time)**: the BPMF Gibbs half-sweep
//!   as a JAX graph, AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels/, build-time)**: the Gibbs hot-spot as a
//!   Pallas kernel lowered into the same HLO.
//!
//! At runtime the coordinator executes the AOT artifacts through the PJRT
//! CPU client (`runtime`); python is never on the hot path.

pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod gibbs;
pub mod linalg;
pub mod metrics;
pub mod partition;
pub mod posterior;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod testing;
pub mod util;
