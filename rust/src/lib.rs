//! # D-BMF+PP
//!
//! Distributed Bayesian Matrix Factorization with Posterior Propagation —
//! a reproduction of Vander Aa et al. (2020), "A High-Performance
//! Implementation of Bayesian Matrix Factorization with Limited
//! Communication".
//!
//! ## Two facades
//!
//! The public surface splits along the model's lifecycle:
//!
//! - [`train`] — *producing* a model. [`train::Engine`] owns a warm
//!   worker pool and runs concurrent prioritized jobs; each
//!   [`train::Session`] streams typed [`train::TrainEvent`]s, supports
//!   cancel/pause/resume, survives crashes through periodic v3
//!   checkpoint generations, and yields a [`train::TrainOutcome`]
//!   carrying the servable model.
//! - [`serve`] — *consuming* a model under traffic. A
//!   [`serve::Server`] answers predict/top-n over HTTP, coalescing
//!   concurrent requests into batched passes
//!   ([`serve::batcher`]), reading through lock-free
//!   [`serve::ModelSnapshot`] flips ([`serve::SnapshotCell`]), and
//!   hot-swapping to the newest servable checkpoint generation the
//!   moment retraining publishes one.
//!
//! The hinge between them is [`serve::PosteriorModel`] (re-exported by
//! both facades): posterior means/precisions + global mean, with
//! `predict` / `predict_variance` / `top_n` and fallible `try_*`
//! variants returning a typed [`serve::PredictError`] for untrusted
//! ids. Checkpoints persist exactly this type; a *complete* v3
//! generation rebuilds it bitwise
//! ([`train::checkpoint::model_from_partial`]), which is what makes the
//! train → serve handoff exact.
//!
//! [`prelude`] curates the common names from both facades. The deep
//! module paths (`bmf_pp::coordinator`, `bmf_pp::posterior`, …) remain
//! public for existing code, hidden from the docs to keep the surface
//! navigable.
//!
//! ## Quickstart: train, check, hand off
//!
//! ```
//! use bmf_pp::prelude::*;
//! use bmf_pp::data::generator::SyntheticDataset;
//! use bmf_pp::data::split::holdout_split_covered;
//!
//! let ds = SyntheticDataset::by_name("movielens", 0.001, 7).expect("profile");
//! let (train, test) = holdout_split_covered(&ds.ratings, 0.2, 8);
//!
//! // one warm engine, reusable across any number of runs
//! let engine = Engine::new(&BackendSpec::Native, 2);
//! let cfg = TrainConfig::new(ds.k).with_grid(2, 2).with_sweeps(3, 6).with_seed(1);
//!
//! // submit() is non-blocking: it validates the config and returns a
//! // Session streaming progress events (any number may run at once)
//! let session = engine.submit(cfg, &train).unwrap();
//! let mut blocks_done = 0;
//! for event in session.events() {
//!     if let TrainEvent::BlockCompleted { .. } = event {
//!         blocks_done += 1;
//!     }
//! }
//! let result = session.wait().unwrap().into_result().unwrap();
//! assert_eq!(blocks_done, 4); // 2x2 grid
//!
//! // the servable artifact: predictions, uncertainty, rankings — with
//! // typed errors on out-of-range ids (the serving side maps them to 4xx)
//! let model = result.model;
//! assert!(model.rmse(&test).is_finite());
//! assert!(model.predict_variance(0, 0) > 0.0);
//! assert!(model.try_predict(usize::MAX, 0).is_err());
//! assert_eq!(model.top_n(0, 3).len(), 3);
//!
//! // freeze it into the serving side's unit of exchange; a live HTTP
//! // server over snapshots is the `bmf_pp::serve` quickstart
//! let snapshot = ModelSnapshot { model, generation: 0, source: None };
//! assert!(snapshot.model.try_top_n(0, 1).is_ok());
//! ```
//!
//! ## The three-layer stack
//!
//! The rust crate is the Layer-3 coordinator:
//! - **L3 (this crate)**: Posterior-Propagation phase scheduling across an
//!   I×J block grid, distributed Gibbs workers inside each block, posterior
//!   propagation/aggregation, datasets, baselines (NOMAD/FPSGD/ALS/CGD/
//!   SGLD), a cluster simulator for strong-scaling studies, the serving
//!   subsystem, CLI and metrics.
//! - **L2 (python/compile/model.py, build-time)**: the BPMF Gibbs half-sweep
//!   as a JAX graph, AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels/, build-time)**: the Gibbs hot-spot as a
//!   Pallas kernel lowered into the same HLO.
//!
//! At runtime the coordinator executes the AOT artifacts through the PJRT
//! CPU client (`runtime`); python is never on the hot path.
//!
//! A narrative tour of the stack — the paper-section → module map, the
//! block DAG, the pipelined sweep, and the serving dataflow — lives in
//! `docs/ARCHITECTURE.md` at the repository root.

#![warn(missing_docs)]

pub mod prelude;
pub mod serve;
pub mod train;

#[doc(hidden)]
pub mod baselines;
#[doc(hidden)]
pub mod cluster;
#[doc(hidden)]
pub mod coordinator;
#[doc(hidden)]
pub mod data;
#[doc(hidden)]
pub mod gibbs;
pub mod harness;
#[doc(hidden)]
pub mod linalg;
#[doc(hidden)]
pub mod metrics;
pub mod online;
#[doc(hidden)]
pub mod partition;
#[doc(hidden)]
pub mod posterior;
#[doc(hidden)]
pub mod rng;
#[cfg(feature = "pjrt")]
#[doc(hidden)]
pub mod runtime;
#[doc(hidden)]
pub mod store;
#[doc(hidden)]
pub mod testing;
#[doc(hidden)]
pub mod util;
