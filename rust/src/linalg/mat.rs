//! Row-major dense matrix with the handful of ops the sampler needs.

use std::ops::{Add, Index, IndexMut, Mul, Sub};

/// Row-major dense f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major elements (rows × cols).
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zero rows × cols matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// n × n identity.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from row slices (all must share a length).
    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Wrap a row-major buffer of exactly rows × cols elements.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Identity scaled by `s`.
    pub fn scaled_eye(n: usize, s: f64) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = s;
        }
        m
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// The transposed matrix (copied).
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self * other.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// self * v (matrix-vector).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Outer product x yᵀ.
    pub fn outer(x: &[f64], y: &[f64]) -> Mat {
        let mut m = Mat::zeros(x.len(), y.len());
        for (i, &xi) in x.iter().enumerate() {
            for (j, &yj) in y.iter().enumerate() {
                m[(i, j)] = xi * yj;
            }
        }
        m
    }

    /// In-place `self += s * other`.
    pub fn add_scaled(&mut self, other: &Mat, s: f64) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// In-place `self *= s`.
    pub fn scale(&mut self, s: f64) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Symmetrize: (A + Aᵀ)/2 (fights float drift in SPD accumulations).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Largest element-wise absolute difference.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, rhs: &Mat) -> Mat {
        let mut out = self.clone();
        out.add_scaled(rhs, 1.0);
        out
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, rhs: &Mat) -> Mat {
        let mut out = self.clone();
        out.add_scaled(rhs, -1.0);
        out
    }
}

impl Mul for &Mat {
    type Output = Mat;
    fn mul(self, rhs: &Mat) -> Mat {
        self.matmul(rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn identity_is_neutral() {
        let a = Mat::from_rows(&[&[1.0, -2.0, 0.5], &[0.0, 3.0, 4.0]]);
        assert_eq!(a.matmul(&Mat::eye(3)), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let v = vec![5.0, 6.0];
        assert_eq!(a.matvec(&v), vec![17.0, 39.0]);
    }

    #[test]
    fn outer_and_add_scaled() {
        let m = Mat::outer(&[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(m, Mat::from_rows(&[&[3.0, 4.0], &[6.0, 8.0]]));
        let mut acc = Mat::zeros(2, 2);
        acc.add_scaled(&m, 2.0);
        assert_eq!(acc[(1, 1)], 16.0);
    }

    #[test]
    fn symmetrize_fixes_drift() {
        let mut a = Mat::from_rows(&[&[1.0, 2.0], &[2.1, 1.0]]);
        a.symmetrize();
        assert_eq!(a[(0, 1)], a[(1, 0)]);
        assert!((a[(0, 1)] - 2.05).abs() < 1e-12);
    }
}
