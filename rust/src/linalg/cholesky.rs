//! Cholesky factorization and SPD solves for K×K posterior precisions.

use super::mat::Mat;

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
#[derive(Debug, Clone)]
pub struct Cholesky {
    /// The lower-triangular factor L.
    pub l: Mat,
}

/// The factorization hit a non-positive pivot: the input was not SPD.
#[derive(Debug, thiserror::Error)]
#[error("matrix is not positive definite (pivot {pivot} at {index})")]
pub struct NotPositiveDefinite {
    /// The offending pivot value.
    pub pivot: f64,
    /// Diagonal index where factorization failed.
    pub index: usize,
}

impl Cholesky {
    /// Factor an SPD matrix.
    pub fn new(a: &Mat) -> Result<Cholesky, NotPositiveDefinite> {
        assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return Err(NotPositiveDefinite { pivot: s, index: i });
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.l.rows
    }

    /// Solve L y = b (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.dim();
        let mut y = b.to_vec();
        for i in 0..n {
            for k in 0..i {
                y[i] -= self.l[(i, k)] * y[k];
            }
            y[i] /= self.l[(i, i)];
        }
        y
    }

    /// Solve Lᵀ x = b (back substitution).
    pub fn solve_upper(&self, b: &[f64]) -> Vec<f64> {
        let n = self.dim();
        let mut x = b.to_vec();
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                x[i] -= self.l[(k, i)] * x[k];
            }
            x[i] /= self.l[(i, i)];
        }
        x
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// A⁻¹ (column-by-column solve; K is small).
    pub fn inverse(&self) -> Mat {
        let n = self.dim();
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e);
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
            e[j] = 0.0;
        }
        inv.symmetrize();
        inv
    }

    /// log det A = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.dim()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Sample x ~ N(mean, A⁻¹) given A = L Lᵀ: x = mean + L⁻ᵀ ε.
    pub fn sample_with_precision(&self, mean: &[f64], eps: &[f64]) -> Vec<f64> {
        let z = self.solve_upper(eps);
        mean.iter().zip(z).map(|(m, zi)| m + zi).collect()
    }

    /// Sample x ~ N(mean, A) when this factors the COVARIANCE: x = mean + L ε.
    pub fn sample_with_covariance(&self, mean: &[f64], eps: &[f64]) -> Vec<f64> {
        let n = self.dim();
        let mut x = mean.to_vec();
        for i in 0..n {
            for k in 0..=i {
                x[i] += self.l[(i, k)] * eps[k];
            }
        }
        x
    }
}

/// In-place Cholesky factorization over a *packed* lower triangle — the
/// Gibbs kernel's workhorse (see `gibbs::native::RowSampler`).
///
/// The k(k+1)/2 elements are stored column-major ("L"-packed, LAPACK
/// convention): column `j` of L occupies the contiguous run
/// `off(j) .. off(j) + (k - j)` with `off(j) = j·k − j(j−1)/2`, so
/// element `L[i][j]` (i ≥ j) sits at `off(j) + (i − j)`. Because the
/// input matrix is symmetric, the same bytes read row-major are the
/// packed *upper* triangle — which is exactly the layout the kernel's
/// rank-1 accumulation produces, so no transposition ever happens.
///
/// The buffer doubles as input and output: fill it with the matrix (via
/// [`PackedCholesky::set_matrix`] or directly through
/// [`PackedCholesky::packed_mut`]), then [`PackedCholesky::factor_in_place`]
/// overwrites it with L. Every element is computed by the identical
/// expression, in the identical accumulation order, as [`Cholesky::new`] —
/// the factors are **bitwise equal**, which is what lets the optimized
/// kernel keep the repo's bitwise-equivalence contracts.
///
/// ```
/// use bmf_pp::linalg::{Mat, PackedCholesky};
///
/// let a = Mat::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
/// let mut ch = PackedCholesky::new(2);
/// ch.factor_into(&a).unwrap();
///
/// // solve A x = b in place
/// let mut x = vec![10.0, 8.0];
/// ch.solve_in_place(&mut x);
/// assert!((a.matvec(&x)[0] - 10.0).abs() < 1e-12);
/// assert!((a.matvec(&x)[1] - 8.0).abs() < 1e-12);
/// ```
#[derive(Debug, Clone)]
pub struct PackedCholesky {
    k: usize,
    data: Vec<f64>,
}

impl PackedCholesky {
    /// Zeroed workspace for k×k matrices (k(k+1)/2 packed elements).
    pub fn new(k: usize) -> PackedCholesky {
        PackedCholesky { k, data: vec![0.0; k * (k + 1) / 2] }
    }

    /// Dimension k of the factored matrix.
    pub fn dim(&self) -> usize {
        self.k
    }

    /// Packed start offset of column `j` (row `j` of the upper triangle):
    /// `Σ_{t<j} (k − t) = j(2k − j + 1)/2`.
    #[inline]
    pub fn off(&self, j: usize) -> usize {
        j * (2 * self.k - j + 1) / 2
    }

    /// The packed buffer (the matrix before factoring, L after).
    pub fn packed(&self) -> &[f64] {
        &self.data
    }

    /// Mutable packed buffer — the kernel accumulates rank-1 updates
    /// directly here before factoring.
    pub fn packed_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Copy the lower triangle of a dense symmetric `a` into the packed
    /// buffer (ready for [`PackedCholesky::factor_in_place`]).
    pub fn set_matrix(&mut self, a: &Mat) {
        assert_eq!(a.rows, self.k, "matrix dimension");
        assert_eq!(a.cols, self.k, "matrix dimension");
        let k = self.k;
        let mut o = 0;
        for j in 0..k {
            for i in j..k {
                self.data[o] = a[(i, j)];
                o += 1;
            }
        }
    }

    /// Factor the packed matrix in place: the buffer is overwritten with
    /// L (A = L Lᵀ). Bitwise-equal to [`Cholesky::new`] on the same
    /// matrix; returns the same typed [`NotPositiveDefinite`] on failure.
    ///
    /// ```
    /// use bmf_pp::linalg::{Cholesky, Mat, PackedCholesky};
    ///
    /// let a = Mat::from_rows(&[&[9.0, 3.0], &[3.0, 5.0]]);
    /// let dense = Cholesky::new(&a).unwrap();
    /// let mut packed = PackedCholesky::new(2);
    /// packed.set_matrix(&a);
    /// packed.factor_in_place().unwrap();
    /// // same factor, bit for bit
    /// assert_eq!(packed.unpack().data, dense.l.data);
    /// ```
    pub fn factor_in_place(&mut self) -> Result<(), NotPositiveDefinite> {
        let k = self.k;
        let d = &mut self.data;
        // left-looking, column by column: when column j is reached,
        // columns t < j already hold L, and every element (i, j) is
        //   s = a[i][j] − Σ_{t<j} l[i][t]·l[j][t]   (t ascending)
        // — the exact expression and accumulation order of
        // `Cholesky::new`, hence bitwise-equal factors.
        let mut off_j = 0; // off(j), maintained incrementally
        for j in 0..k {
            let mut off_t = 0; // off(t)
            for t in 0..j {
                let ljt = d[off_t + (j - t)];
                for i in j..k {
                    d[off_j + (i - j)] -= d[off_t + (i - t)] * ljt;
                }
                off_t += k - t;
            }
            let s = d[off_j];
            if s <= 0.0 || !s.is_finite() {
                return Err(NotPositiveDefinite { pivot: s, index: j });
            }
            let ljj = s.sqrt();
            d[off_j] = ljj;
            for i in (j + 1)..k {
                d[off_j + (i - j)] /= ljj;
            }
            off_j += k - j;
        }
        Ok(())
    }

    /// [`PackedCholesky::set_matrix`] + [`PackedCholesky::factor_in_place`]
    /// in one call — factor a dense SPD matrix without allocating.
    pub fn factor_into(&mut self, a: &Mat) -> Result<(), NotPositiveDefinite> {
        self.set_matrix(a);
        self.factor_in_place()
    }

    /// Rank-1 update of an existing factor: after the call the buffer
    /// holds the Cholesky factor of `L Lᵀ + x xᵀ`, computed with Givens
    /// rotations in O(k²) instead of re-factoring in O(k³) — the tool for
    /// incrementally growing a precision matrix one observation at a time.
    ///
    /// ```
    /// use bmf_pp::linalg::{Mat, PackedCholesky};
    ///
    /// let a = Mat::from_rows(&[&[4.0, 1.0], &[1.0, 3.0]]);
    /// let mut ch = PackedCholesky::new(2);
    /// ch.factor_into(&a).unwrap();
    /// ch.rank1_update(&[0.5, -1.0]);
    /// // now ch factors A + x xᵀ
    /// let l = ch.unpack();
    /// let axxt = Mat::from_rows(&[&[4.25, 0.5], &[0.5, 4.0]]);
    /// assert!(l.matmul(&l.transpose()).max_abs_diff(&axxt) < 1e-12);
    /// ```
    pub fn rank1_update(&mut self, x: &[f64]) {
        let k = self.k;
        assert_eq!(x.len(), k, "update vector length");
        let mut w = x.to_vec();
        let d = &mut self.data;
        let mut off_j = 0;
        for j in 0..k {
            let ljj = d[off_j];
            let r = (ljj * ljj + w[j] * w[j]).sqrt();
            let c = r / ljj;
            let s = w[j] / ljj;
            d[off_j] = r;
            for i in (j + 1)..k {
                let lij = (d[off_j + (i - j)] + s * w[i]) / c;
                d[off_j + (i - j)] = lij;
                w[i] = c * w[i] - s * lij;
            }
            off_j += k - j;
        }
    }

    /// Solve L y = b in place (forward substitution). Same operation
    /// order as [`Cholesky::solve_lower`], so bitwise-equal results.
    pub fn solve_lower_in_place(&self, b: &mut [f64]) {
        let k = self.k;
        assert_eq!(b.len(), k, "rhs length");
        for i in 0..k {
            let mut off_t = 0;
            for t in 0..i {
                b[i] -= self.data[off_t + (i - t)] * b[t];
                off_t += k - t;
            }
            b[i] /= self.data[off_t];
        }
    }

    /// Solve Lᵀ x = b in place (back substitution). Reads column `i` of
    /// L as one contiguous packed run — the cache-friendly direction of
    /// this layout. Bitwise-equal to [`Cholesky::solve_upper`].
    pub fn solve_upper_in_place(&self, b: &mut [f64]) {
        let k = self.k;
        assert_eq!(b.len(), k, "rhs length");
        for i in (0..k).rev() {
            let off_i = self.off(i);
            let col = &self.data[off_i..off_i + (k - i)];
            for t in (i + 1)..k {
                b[i] -= col[t - i] * b[t];
            }
            b[i] /= col[0];
        }
    }

    /// Solve A x = b in place (forward then back substitution).
    pub fn solve_in_place(&self, b: &mut [f64]) {
        self.solve_lower_in_place(b);
        self.solve_upper_in_place(b);
    }

    /// log det A = 2 Σ log L_ii over the packed diagonal.
    pub fn log_det(&self) -> f64 {
        let mut s = 0.0;
        let mut off_j = 0;
        for j in 0..self.k {
            s += self.data[off_j].ln();
            off_j += self.k - j;
        }
        s * 2.0
    }

    /// Unpack the factor into a dense lower-triangular [`Mat`] (tests,
    /// doc examples; the hot path never calls this).
    pub fn unpack(&self) -> Mat {
        let k = self.k;
        let mut l = Mat::zeros(k, k);
        let mut o = 0;
        for j in 0..k {
            for i in j..k {
                l[(i, j)] = self.data[o];
                o += 1;
            }
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::seed_from_u64(seed);
        let mut a = Mat::zeros(n, n);
        for v in a.data.iter_mut() {
            *v = rng.uniform() * 2.0 - 1.0;
        }
        let mut spd = a.matmul(&a.transpose());
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        spd
    }

    #[test]
    fn factor_roundtrip() {
        for n in [1, 2, 5, 16, 32] {
            let a = random_spd(n, n as u64);
            let ch = Cholesky::new(&a).unwrap();
            let reconstructed = ch.l.matmul(&ch.l.transpose());
            assert!(a.max_abs_diff(&reconstructed) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn solve_matches_matvec() {
        let a = random_spd(8, 3);
        let ch = Cholesky::new(&a).unwrap();
        let x_true: Vec<f64> = (0..8).map(|i| (i as f64) - 3.5).collect();
        let b = a.matvec(&x_true);
        let x = ch.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let a = random_spd(6, 4);
        let inv = Cholesky::new(&a).unwrap().inverse();
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&Mat::eye(6)) < 1e-9);
    }

    #[test]
    fn log_det_known() {
        let a = Mat::from_rows(&[&[4.0, 0.0], &[0.0, 9.0]]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn packed_factor_matches_dense_bitwise() {
        for n in [1usize, 2, 3, 5, 8, 16, 32] {
            let a = random_spd(n, 100 + n as u64);
            let dense = Cholesky::new(&a).unwrap();
            let mut packed = PackedCholesky::new(n);
            packed.factor_into(&a).unwrap();
            let l = packed.unpack();
            for i in 0..n {
                for j in 0..=i {
                    assert_eq!(
                        l[(i, j)].to_bits(),
                        dense.l[(i, j)].to_bits(),
                        "n={n} L[{i}][{j}]"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_solves_match_dense_bitwise() {
        for n in [1usize, 4, 16] {
            let a = random_spd(n, 200 + n as u64);
            let dense = Cholesky::new(&a).unwrap();
            let mut packed = PackedCholesky::new(n);
            packed.factor_into(&a).unwrap();
            let b: Vec<f64> = (0..n).map(|i| (i as f64) - 1.5).collect();
            let mut x = b.clone();
            packed.solve_in_place(&mut x);
            let x_dense = dense.solve(&b);
            for i in 0..n {
                assert_eq!(x[i].to_bits(), x_dense[i].to_bits(), "n={n} x[{i}]");
            }
            let mut y = b.clone();
            packed.solve_upper_in_place(&mut y);
            let y_dense = dense.solve_upper(&b);
            for i in 0..n {
                assert_eq!(y[i].to_bits(), y_dense[i].to_bits(), "n={n} upper[{i}]");
            }
        }
    }

    #[test]
    fn packed_rejects_indefinite_with_same_pivot() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        let dense_err = Cholesky::new(&a).unwrap_err();
        let mut packed = PackedCholesky::new(2);
        let packed_err = packed.factor_into(&a).unwrap_err();
        assert_eq!(packed_err.index, dense_err.index);
        assert_eq!(packed_err.pivot.to_bits(), dense_err.pivot.to_bits());
    }

    #[test]
    fn packed_rank1_update_matches_refactor() {
        let n = 6;
        let a = random_spd(n, 300);
        let x: Vec<f64> = (0..n).map(|i| 0.3 * (i as f64) - 0.7).collect();
        let mut ch = PackedCholesky::new(n);
        ch.factor_into(&a).unwrap();
        ch.rank1_update(&x);
        let l = ch.unpack();
        let mut axxt = a.clone();
        axxt.add_scaled(&Mat::outer(&x, &x), 1.0);
        assert!(l.matmul(&l.transpose()).max_abs_diff(&axxt) < 1e-10);
    }

    #[test]
    fn packed_log_det_matches_dense() {
        let a = random_spd(5, 400);
        let dense = Cholesky::new(&a).unwrap();
        let mut packed = PackedCholesky::new(5);
        packed.factor_into(&a).unwrap();
        assert_eq!(packed.log_det().to_bits(), dense.log_det().to_bits());
    }

    #[test]
    fn precision_sampling_has_right_covariance() {
        // A = precision; sample many draws with eps ~ N(0, I) and check
        // empirical covariance ≈ A^{-1}.
        let a = random_spd(3, 7);
        let ch = Cholesky::new(&a).unwrap();
        let target = ch.inverse();
        let mut rng = Rng::seed_from_u64(99);
        let mut norm = crate::rng::StdNormal::new();
        let n = 60_000;
        let mean = vec![0.0; 3];
        let mut cov = Mat::zeros(3, 3);
        for _ in 0..n {
            let eps: Vec<f64> = (0..3).map(|_| norm.sample(&mut rng)).collect();
            let x = ch.sample_with_precision(&mean, &eps);
            cov.add_scaled(&Mat::outer(&x, &x), 1.0 / n as f64);
        }
        assert!(cov.max_abs_diff(&target) < 0.05, "{:?} vs {:?}", cov, target);
    }
}
