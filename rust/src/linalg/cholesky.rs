//! Cholesky factorization and SPD solves for K×K posterior precisions.

use super::mat::Mat;

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
#[derive(Debug, Clone)]
pub struct Cholesky {
    /// The lower-triangular factor L.
    pub l: Mat,
}

/// The factorization hit a non-positive pivot: the input was not SPD.
#[derive(Debug, thiserror::Error)]
#[error("matrix is not positive definite (pivot {pivot} at {index})")]
pub struct NotPositiveDefinite {
    /// The offending pivot value.
    pub pivot: f64,
    /// Diagonal index where factorization failed.
    pub index: usize,
}

impl Cholesky {
    /// Factor an SPD matrix.
    pub fn new(a: &Mat) -> Result<Cholesky, NotPositiveDefinite> {
        assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return Err(NotPositiveDefinite { pivot: s, index: i });
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.l.rows
    }

    /// Solve L y = b (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.dim();
        let mut y = b.to_vec();
        for i in 0..n {
            for k in 0..i {
                y[i] -= self.l[(i, k)] * y[k];
            }
            y[i] /= self.l[(i, i)];
        }
        y
    }

    /// Solve Lᵀ x = b (back substitution).
    pub fn solve_upper(&self, b: &[f64]) -> Vec<f64> {
        let n = self.dim();
        let mut x = b.to_vec();
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                x[i] -= self.l[(k, i)] * x[k];
            }
            x[i] /= self.l[(i, i)];
        }
        x
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// A⁻¹ (column-by-column solve; K is small).
    pub fn inverse(&self) -> Mat {
        let n = self.dim();
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e);
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
            e[j] = 0.0;
        }
        inv.symmetrize();
        inv
    }

    /// log det A = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.dim()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Sample x ~ N(mean, A⁻¹) given A = L Lᵀ: x = mean + L⁻ᵀ ε.
    pub fn sample_with_precision(&self, mean: &[f64], eps: &[f64]) -> Vec<f64> {
        let z = self.solve_upper(eps);
        mean.iter().zip(z).map(|(m, zi)| m + zi).collect()
    }

    /// Sample x ~ N(mean, A) when this factors the COVARIANCE: x = mean + L ε.
    pub fn sample_with_covariance(&self, mean: &[f64], eps: &[f64]) -> Vec<f64> {
        let n = self.dim();
        let mut x = mean.to_vec();
        for i in 0..n {
            for k in 0..=i {
                x[i] += self.l[(i, k)] * eps[k];
            }
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::seed_from_u64(seed);
        let mut a = Mat::zeros(n, n);
        for v in a.data.iter_mut() {
            *v = rng.uniform() * 2.0 - 1.0;
        }
        let mut spd = a.matmul(&a.transpose());
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        spd
    }

    #[test]
    fn factor_roundtrip() {
        for n in [1, 2, 5, 16, 32] {
            let a = random_spd(n, n as u64);
            let ch = Cholesky::new(&a).unwrap();
            let reconstructed = ch.l.matmul(&ch.l.transpose());
            assert!(a.max_abs_diff(&reconstructed) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn solve_matches_matvec() {
        let a = random_spd(8, 3);
        let ch = Cholesky::new(&a).unwrap();
        let x_true: Vec<f64> = (0..8).map(|i| (i as f64) - 3.5).collect();
        let b = a.matvec(&x_true);
        let x = ch.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let a = random_spd(6, 4);
        let inv = Cholesky::new(&a).unwrap().inverse();
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&Mat::eye(6)) < 1e-9);
    }

    #[test]
    fn log_det_known() {
        let a = Mat::from_rows(&[&[4.0, 0.0], &[0.0, 9.0]]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn precision_sampling_has_right_covariance() {
        // A = precision; sample many draws with eps ~ N(0, I) and check
        // empirical covariance ≈ A^{-1}.
        let a = random_spd(3, 7);
        let ch = Cholesky::new(&a).unwrap();
        let target = ch.inverse();
        let mut rng = Rng::seed_from_u64(99);
        let mut norm = crate::rng::StdNormal::new();
        let n = 60_000;
        let mean = vec![0.0; 3];
        let mut cov = Mat::zeros(3, 3);
        for _ in 0..n {
            let eps: Vec<f64> = (0..3).map(|_| norm.sample(&mut rng)).collect();
            let x = ch.sample_with_precision(&mean, &eps);
            cov.add_scaled(&Mat::outer(&x, &x), 1.0 / n as f64);
        }
        assert!(cov.max_abs_diff(&target) < 0.05, "{:?} vs {:?}", cov, target);
    }
}
