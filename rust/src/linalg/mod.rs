//! Small dense linear algebra substrate (K×K scale, row-major f64).
//!
//! The coordinator needs exact K×K work — Cholesky factorizations, SPD
//! solves, posterior precision algebra — both for the Normal-Wishart
//! hyperparameter sampler and as the oracle the AOT HLO path is
//! cross-checked against. K ≤ 128 in all uses; no BLAS needed.

pub mod cholesky;
pub mod mat;

pub use cholesky::{Cholesky, NotPositiveDefinite, PackedCholesky};
pub use mat::Mat;
