//! Curated one-line import for the common cases on both sides of the
//! train/serve split:
//!
//! ```
//! use bmf_pp::prelude::*;
//! let _cfg = TrainConfig::new(8);
//! let _scfg = ServeConfig::default();
//! ```
//!
//! Training: [`Engine`], [`Session`], [`TrainConfig`], [`TrainEvent`],
//! [`TrainOutcome`], [`BackendSpec`]. Serving: [`PosteriorModel`],
//! [`PredictError`], [`ModelSnapshot`], [`ModelSource`], [`ServeConfig`],
//! [`Server`]. Anything rarer comes from [`crate::train`] /
//! [`crate::serve`] explicitly.

pub use crate::coordinator::{
    BackendSpec, Engine, Session, TrainConfig, TrainEvent, TrainOutcome,
};
pub use crate::posterior::{PosteriorModel, PredictError};
pub use crate::serve::{ModelSnapshot, ModelSource, ServeConfig, Server};
