//! Wishart sampling via the Bartlett decomposition — the Normal-Wishart
//! hyperprior updates of BPMF (Salakhutdinov & Mnih 2008, eqs. 14-16) need
//! draws Λ ~ W(W₀, ν₀).

use super::gamma::chi_square;
use super::normal::StdNormal;
use super::pcg::Rng;
use crate::linalg::{Cholesky, Mat};

/// Draw Λ ~ Wishart(scale, dof) where `scale` is the K×K scale matrix and
/// `dof >= K`. Bartlett: Λ = L A Aᵀ Lᵀ with scale = L Lᵀ, A lower-triangular
/// with A_ii = sqrt(χ²(dof-i)) and N(0,1) below the diagonal.
pub fn sample_wishart(rng: &mut Rng, scale: &Mat, dof: f64) -> Mat {
    let k = scale.rows;
    assert!(dof >= k as f64, "wishart dof {dof} < dim {k}");
    let l = Cholesky::new(scale).expect("wishart scale must be SPD").l;
    let mut a = Mat::zeros(k, k);
    let mut norm = StdNormal::new();
    for i in 0..k {
        a[(i, i)] = chi_square(rng, dof - i as f64).sqrt();
        for j in 0..i {
            a[(i, j)] = norm.sample(rng);
        }
    }
    let la = l.matmul(&a);
    let mut out = la.matmul(&la.transpose());
    out.symmetrize();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_is_dof_times_scale() {
        let k = 3;
        let scale = {
            let mut s = Mat::eye(k);
            s[(0, 1)] = 0.3;
            s[(1, 0)] = 0.3;
            s[(0, 0)] = 2.0;
            s
        };
        let dof = 7.0;
        let mut rng = Rng::seed_from_u64(21);
        let n = 20_000;
        let mut mean = Mat::zeros(k, k);
        for _ in 0..n {
            let w = sample_wishart(&mut rng, &scale, dof);
            mean.add_scaled(&w, 1.0 / n as f64);
        }
        let mut want = scale.clone();
        want.scale(dof);
        assert!(mean.max_abs_diff(&want) < 0.15, "{mean:?} vs {want:?}");
    }

    #[test]
    fn draws_are_spd() {
        let mut rng = Rng::seed_from_u64(22);
        let scale = Mat::eye(5);
        for _ in 0..50 {
            let w = sample_wishart(&mut rng, &scale, 6.0);
            assert!(Cholesky::new(&w).is_ok());
        }
    }

    #[test]
    #[should_panic]
    fn rejects_low_dof() {
        let mut rng = Rng::seed_from_u64(23);
        let _ = sample_wishart(&mut rng, &Mat::eye(4), 2.0);
    }
}
