//! xoshiro256++ PRNG seeded via splitmix64.
//!
//! Fast, high-quality, tiny-state generator (Blackman & Vigna 2019). All
//! stochastic components of the system (noise injection for the HLO Gibbs
//! graphs, synthetic data, SGD shuffles, property tests) draw from this.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn seed_from_u64(seed: u64) -> Rng {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // avoid the all-zero state (probability ~0, but be safe)
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Derive an independent stream for worker `i` (seed-domain separation).
    pub fn fork(&self, i: u64) -> Rng {
        // hash the state with the fork index through splitmix
        let mut sm = self.s[0] ^ self.s[1].rotate_left(17) ^ i.wrapping_mul(0xA24BAED4963EE407);
        Rng::seed_from_u64(splitmix64(&mut sm))
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n) (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fork_streams_are_independent() {
        let root = Rng::seed_from_u64(7);
        let mut w0 = root.fork(0);
        let mut w1 = root.fork(1);
        let a: Vec<u64> = (0..8).map(|_| w0.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| w1.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::seed_from_u64(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
