//! Random-number substrate (the offline environment has no `rand`):
//! a counter-free xoshiro256++ generator, Gaussian / Gamma / Wishart
//! samplers — everything the BPMF Gibbs sampler and the synthetic dataset
//! generator need. All randomness in the system flows through here; the AOT
//! compute graphs are deterministic and consume injected noise.

pub mod gamma;
pub mod normal;
pub mod pcg;
pub mod wishart;

pub use gamma::Gamma;
pub use normal::StdNormal;
pub use pcg::Rng;
