//! Gamma sampling — Marsaglia & Tsang (2000) squeeze method, with the
//! Johnk-style boost for shape < 1. Needed for Wishart (chi-square) draws
//! in the Normal-Wishart hyperparameter sampler.

use super::normal::StdNormal;
use super::pcg::Rng;

/// Gamma(shape k, scale θ) sampler.
#[derive(Debug, Clone)]
pub struct Gamma {
    shape: f64,
    scale: f64,
}

impl Gamma {
    /// Sampler for Gamma(shape, scale); both parameters must be positive.
    pub fn new(shape: f64, scale: f64) -> Gamma {
        assert!(shape > 0.0 && scale > 0.0, "gamma params must be positive");
        Gamma { shape, scale }
    }

    /// Draw one variate.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        self.scale * sample_standard(rng, self.shape)
    }
}

/// Gamma(shape, 1).
pub fn sample_standard(rng: &mut Rng, shape: f64) -> f64 {
    if shape < 1.0 {
        // boost: X_a = X_{a+1} * U^{1/a}
        let x = sample_standard(rng, shape + 1.0);
        let u: f64 = rng.uniform().max(f64::MIN_POSITIVE);
        return x * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    let mut norm = StdNormal::new();
    loop {
        let x = norm.sample(rng);
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v3 = v * v * v;
        let u = rng.uniform();
        if u < 1.0 - 0.0331 * x.powi(4) {
            return d * v3;
        }
        if u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
            return d * v3;
        }
    }
}

/// Chi-square with `dof` degrees of freedom = Gamma(dof/2, 2).
pub fn chi_square(rng: &mut Rng, dof: f64) -> f64 {
    2.0 * sample_standard(rng, dof / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_moments(shape: f64, scale: f64, n: usize, tol: f64) {
        let mut rng = Rng::seed_from_u64((shape * 1000.0) as u64 + 1);
        let g = Gamma::new(shape, scale);
        let mut s1 = 0.0;
        let mut s2 = 0.0;
        for _ in 0..n {
            let x = g.sample(&mut rng);
            assert!(x > 0.0);
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        let want_mean = shape * scale;
        let want_var = shape * scale * scale;
        assert!((mean - want_mean).abs() / want_mean < tol, "mean {mean} vs {want_mean}");
        assert!((var - want_var).abs() / want_var < 4.0 * tol, "var {var} vs {want_var}");
    }

    #[test]
    fn moments_large_shape() {
        check_moments(5.0, 2.0, 100_000, 0.02);
        check_moments(50.0, 0.5, 100_000, 0.02);
    }

    #[test]
    fn moments_small_shape() {
        check_moments(0.5, 1.0, 200_000, 0.03);
    }

    #[test]
    fn chi_square_mean_is_dof() {
        let mut rng = Rng::seed_from_u64(9);
        let n = 50_000;
        let dof = 7.0;
        let mean: f64 = (0..n).map(|_| chi_square(&mut rng, dof)).sum::<f64>() / n as f64;
        assert!((mean - dof).abs() < 0.1, "mean={mean}");
    }

    #[test]
    #[should_panic]
    fn rejects_bad_params() {
        let _ = Gamma::new(-1.0, 1.0);
    }
}
