//! Gaussian sampling (Marsaglia polar method) and bulk noise generation.

use super::pcg::Rng;

/// Standard normal sampler with one-value cache (polar method emits pairs).
#[derive(Debug, Clone, Default)]
pub struct StdNormal {
    cached: Option<f64>,
}

impl StdNormal {
    /// Sampler with an empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Draw one N(0,1) variate.
    pub fn sample(&mut self, rng: &mut Rng) -> f64 {
        if let Some(v) = self.cached.take() {
            return v;
        }
        loop {
            let u = 2.0 * rng.uniform() - 1.0;
            let v = 2.0 * rng.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.cached = Some(v * f);
                return u * f;
            }
        }
    }
}

/// Fill `out` with i.i.d. N(0,1) f32 draws (bulk noise for the HLO graphs).
pub fn fill_standard_normal(rng: &mut Rng, out: &mut [f32]) {
    let mut n = StdNormal::new();
    for x in out.iter_mut() {
        *x = n.sample(rng) as f32;
    }
}

/// Draw a vector of N(0,1) f32.
pub fn standard_normal_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    fill_standard_normal(rng, &mut v);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let mut rng = Rng::seed_from_u64(11);
        let mut n = StdNormal::new();
        let count = 200_000;
        let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..count {
            let x = n.sample(&mut rng);
            s1 += x;
            s2 += x * x;
            s3 += x * x * x;
            s4 += x * x * x * x;
        }
        let m = s1 / count as f64;
        let var = s2 / count as f64 - m * m;
        let skew = s3 / count as f64;
        let kurt = s4 / count as f64;
        assert!(m.abs() < 0.01, "mean={m}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!(skew.abs() < 0.05, "skew={skew}");
        assert!((kurt - 3.0).abs() < 0.1, "kurt={kurt}");
    }

    #[test]
    fn bulk_fill_matches_distribution() {
        let mut rng = Rng::seed_from_u64(12);
        let v = standard_normal_vec(&mut rng, 50_000);
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.02);
        // tail sanity: |x|>4 should be very rare but finite values only
        assert!(v.iter().all(|x| x.is_finite()));
    }
}
