//! Posterior-Propagation block partitioning: the I×J grid over R and the
//! block-shape analysis of paper §3.3 (blocks should be roughly square).

pub mod balance;
pub mod grid;

pub use grid::{BlockId, Grid};
