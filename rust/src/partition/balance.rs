//! Block-shape analysis (paper §3.3): blocks should be approximately
//! square; the best grid for a matrix with aspect ratio rows/cols ≈ A uses
//! I/J ≈ A. Also the bubble-size metric of Fig. 3 (aspect ratio of blocks).

use super::grid::Grid;

/// Aspect ratio of the blocks of a grid: max(h/w, w/h) ≥ 1; 1 = square.
/// This is the paper's Fig.-3 bubble size ("smaller bubbles indicate the
/// blocks are more square").
pub fn block_aspect(rows: usize, cols: usize, i: usize, j: usize) -> f64 {
    let h = rows as f64 / i as f64;
    let w = cols as f64 / j as f64;
    (h / w).max(w / h)
}

/// Information-per-compute score of a block shape (paper §3.3: both the
/// amount of information and compute are "proportionate to the ratio of the
/// area versus the circumference"). Higher is better; square maximizes it.
pub fn area_over_circumference(rows: usize, cols: usize, i: usize, j: usize) -> f64 {
    let h = rows as f64 / i as f64;
    let w = cols as f64 / j as f64;
    (h * w) / (2.0 * (h + w))
}

/// Choose the I×J grid with `target_blocks` total blocks whose blocks are
/// most square (the paper's recommendation). Returns (I, J).
pub fn squarest_grid(rows: usize, cols: usize, target_blocks: usize) -> (usize, usize) {
    let mut best = (1, target_blocks.max(1));
    let mut best_aspect = f64::INFINITY;
    for i in 1..=target_blocks {
        if target_blocks % i != 0 {
            continue;
        }
        let j = target_blocks / i;
        if i > rows || j > cols {
            continue;
        }
        let a = block_aspect(rows, cols, i, j);
        if a < best_aspect {
            best_aspect = a;
            best = (i, j);
        }
    }
    best
}

/// Enumerate candidate grids (both square-count and rectangular) up to
/// `max_side` blocks per side — the Fig-3 exploration set.
pub fn candidate_grids(max_side: usize) -> Vec<(usize, usize)> {
    let mut v = Vec::new();
    let mut side = 1;
    while side <= max_side {
        v.push((side, side));
        side *= 2;
    }
    // rectangular candidates biased toward more row blocks (Netflix-like)
    for &(i, j) in &[(2usize, 1usize), (4, 2), (8, 4), (16, 8), (20, 3), (32, 8), (8, 2)] {
        if i <= max_side && j <= max_side {
            v.push((i, j));
        }
    }
    v.sort_unstable();
    v.dedup();
    v
}

/// Recommend an I×J grid for a node budget by simulating the PP schedule
/// on the calibrated cluster model over candidate grids and picking the
/// fastest whose blocks stay information-dense enough (block aspect within
/// `max_aspect` of square — the paper's §3.3 quality guard).
pub fn recommend_grid(
    model: &crate::cluster::model::ClusterModel,
    rows: usize,
    cols: usize,
    nnz: usize,
    k: usize,
    sweeps: usize,
    nodes: usize,
    max_aspect: f64,
) -> (usize, usize) {
    let mut best = ((1usize, 1usize), f64::INFINITY);
    for (i, j) in candidate_grids(64) {
        if i > rows || j > cols {
            continue;
        }
        // the aspect guard protects per-block information content; a 1×1
        // "grid" holds the full matrix and is always admissible
        if (i, j) != (1, 1) && block_aspect(rows, cols, i, j) > max_aspect {
            continue;
        }
        let grid = Grid::new(rows, cols, i, j);
        let block_nnz = crate::cluster::sim::uniform_block_nnz(&grid, nnz);
        let r =
            crate::cluster::sim::simulate_pp(model, &grid, &block_nnz, k, sweeps, sweeps, nodes);
        if r.total < best.1 {
            best = ((i, j), r.total);
        }
    }
    best.0
}

/// Per-block observation counts — used to check information balance.
pub fn block_nnz_histogram(grid: &Grid, blocks: &[Vec<crate::data::sparse::Coo>]) -> Vec<usize> {
    let mut out = Vec::with_capacity(grid.n_blocks());
    for row in blocks {
        for b in row {
            out.push(b.nnz());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_matrix_prefers_square_grid() {
        assert_eq!(squarest_grid(1000, 1000, 16), (4, 4));
    }

    #[test]
    fn netflix_like_prefers_row_heavy_grid() {
        // Netflix: 27x more rows than cols → with 64 blocks the squarest
        // split puts many more blocks on rows
        let (i, j) = squarest_grid(480_200, 17_800, 64);
        assert!(i > j, "expected row-heavy grid, got {i}x{j}");
        assert!(block_aspect(480_200, 17_800, i, j) < block_aspect(480_200, 17_800, 8, 8));
    }

    #[test]
    fn aspect_is_symmetric_and_min_at_square() {
        assert_eq!(block_aspect(100, 100, 2, 2), 1.0);
        let a = block_aspect(100, 100, 4, 1);
        let b = block_aspect(100, 100, 1, 4);
        assert!((a - b).abs() < 1e-12);
        assert!(a > 1.0);
    }

    #[test]
    fn area_over_circumference_peaks_at_square() {
        let sq = area_over_circumference(1200, 1200, 4, 4);
        let rect = area_over_circumference(1200, 1200, 16, 1);
        assert!(sq > rect);
    }

    #[test]
    fn recommender_scales_grid_with_node_budget() {
        let model = crate::cluster::model::ClusterModel::default();
        let (rows, cols, nnz, k) = (480_200, 17_800, 100_000_000, 16);
        let small = recommend_grid(&model, rows, cols, nnz, k, 28, 1, 8.0);
        let big = recommend_grid(&model, rows, cols, nnz, k, 28, 4096, 8.0);
        assert!(
            big.0 * big.1 >= small.0 * small.1,
            "more nodes should not shrink the grid: {small:?} -> {big:?}"
        );
        // 1 node: no reason to pay the multi-block compute overhead
        assert_eq!(small, (1, 1));
    }

    #[test]
    fn recommender_respects_aspect_guard() {
        let model = crate::cluster::model::ClusterModel::default();
        let g = recommend_grid(&model, 480_200, 17_800, 100_000_000, 16, 28, 1024, 4.0);
        // any multi-block recommendation must satisfy the guard; the 1×1
        // fallback (full-information single block) is always admissible
        assert!(
            g == (1, 1) || block_aspect(480_200, 17_800, g.0, g.1) <= 4.0,
            "grid {g:?} too skewed"
        );
    }

    #[test]
    fn candidates_contain_paper_points() {
        let c = candidate_grids(32);
        assert!(c.contains(&(1, 1)));
        assert!(c.contains(&(32, 32)));
        assert!(c.contains(&(20, 3))); // the paper's Netflix winner
        assert!(c.contains(&(16, 8)));
    }
}
