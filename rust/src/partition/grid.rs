//! The I×J grid partition of the rating matrix.
//!
//! Rows are split into I contiguous ranges of (near-)equal size, columns
//! into J ranges; block (i, j) covers rows(i) × cols(j). Phase assignment
//! follows the Posterior Propagation scheme (paper Fig. 1):
//!   (0,0) → phase a; first row/col → phase b; the rest → phase c.

use crate::data::sparse::Coo;

/// Identifies one block of the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId {
    /// Row-block index.
    pub i: usize,
    /// Column-block index.
    pub j: usize,
}

/// The PP phase a block belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Block (0,0): fresh priors both sides.
    A,
    /// First row / first column blocks.
    B,
    /// Interior blocks.
    C,
}

/// An I×J partition of an N×D matrix.
#[derive(Debug, Clone)]
pub struct Grid {
    /// Matrix rows covered.
    pub rows: usize,
    /// Matrix columns covered.
    pub cols: usize,
    /// Number of row-blocks (I).
    pub i_blocks: usize,
    /// Number of column-blocks (J).
    pub j_blocks: usize,
    /// Row range boundaries, length i_blocks + 1.
    pub row_bounds: Vec<usize>,
    /// Column range boundaries, length j_blocks + 1.
    pub col_bounds: Vec<usize>,
}

fn bounds(total: usize, parts: usize) -> Vec<usize> {
    // distribute remainder one-per-leading-part: sizes differ by ≤ 1
    let base = total / parts;
    let extra = total % parts;
    let mut b = Vec::with_capacity(parts + 1);
    let mut acc = 0;
    b.push(0);
    for p in 0..parts {
        acc += base + usize::from(p < extra);
        b.push(acc);
    }
    b
}

impl Grid {
    /// Near-equal I×J partition of a rows × cols matrix.
    pub fn new(rows: usize, cols: usize, i_blocks: usize, j_blocks: usize) -> Grid {
        assert!(i_blocks >= 1 && j_blocks >= 1, "grid must be at least 1x1");
        assert!(i_blocks <= rows && j_blocks <= cols, "more blocks than rows/cols");
        Grid {
            rows,
            cols,
            i_blocks,
            j_blocks,
            row_bounds: bounds(rows, i_blocks),
            col_bounds: bounds(cols, j_blocks),
        }
    }

    /// Total block count I·J.
    pub fn n_blocks(&self) -> usize {
        self.i_blocks * self.j_blocks
    }

    /// Row range [start, end) of row-block `i`.
    pub fn row_range(&self, i: usize) -> (usize, usize) {
        (self.row_bounds[i], self.row_bounds[i + 1])
    }

    /// Column range [start, end) of column-block `j`.
    pub fn col_range(&self, j: usize) -> (usize, usize) {
        (self.col_bounds[j], self.col_bounds[j + 1])
    }

    /// (rows, cols) of one block.
    pub fn block_shape(&self, id: BlockId) -> (usize, usize) {
        let (r0, r1) = self.row_range(id.i);
        let (c0, c1) = self.col_range(id.j);
        (r1 - r0, c1 - c0)
    }

    /// PP phase of a block (paper Fig. 1).
    pub fn phase(&self, id: BlockId) -> Phase {
        match (id.i, id.j) {
            (0, 0) => Phase::A,
            (0, _) | (_, 0) => Phase::B,
            _ => Phase::C,
        }
    }

    /// All blocks in row-major (i, j) order.
    pub fn blocks(&self) -> impl Iterator<Item = BlockId> + '_ {
        (0..self.i_blocks)
            .flat_map(move |i| (0..self.j_blocks).map(move |j| BlockId { i, j }))
    }

    /// The blocks belonging to one PP phase.
    pub fn blocks_in_phase(&self, phase: Phase) -> Vec<BlockId> {
        self.blocks().filter(|b| self.phase(*b) == phase).collect()
    }

    /// Cut the data matrix into per-block COOs, indexed [i][j].
    pub fn split(&self, data: &Coo) -> Vec<Vec<Coo>> {
        assert_eq!((data.rows, data.cols), (self.rows, self.cols), "grid/data shape mismatch");
        // single pass: route each entry to its block
        let mut out: Vec<Vec<Coo>> = (0..self.i_blocks)
            .map(|i| {
                (0..self.j_blocks)
                    .map(|j| {
                        let (r, c) = self.block_shape(BlockId { i, j });
                        Coo::new(r, c)
                    })
                    .collect()
            })
            .collect();
        for e in &data.entries {
            let i = self.find_block(&self.row_bounds, e.row as usize);
            let j = self.find_block(&self.col_bounds, e.col as usize);
            let (r0, _) = self.row_range(i);
            let (c0, _) = self.col_range(j);
            out[i][j].push(e.row as usize - r0, e.col as usize - c0, e.val);
        }
        out
    }

    /// Block containing global cell `(row, col)` — the same routing
    /// arithmetic [`Grid::split`] uses, exposed so a rating delta can be
    /// projected onto the canonical block indices without splitting the
    /// whole matrix. `row`/`col` must lie inside the grid's dimensions.
    pub fn block_of(&self, row: usize, col: usize) -> BlockId {
        debug_assert!(row < self.rows && col < self.cols, "cell outside the grid");
        BlockId {
            i: self.find_block(&self.row_bounds, row),
            j: self.find_block(&self.col_bounds, col),
        }
    }

    fn find_block(&self, bounds: &[usize], idx: usize) -> usize {
        // bounds is sorted; find the partition containing idx
        match bounds.binary_search(&idx) {
            Ok(k) => k.min(bounds.len() - 2),
            Err(k) => k - 1,
        }
    }

    /// Max parallelism per phase (paper §3.4): phase b can use I+J-2 block
    /// slots, phase c (I-1)(J-1).
    pub fn phase_parallelism(&self) -> (usize, usize, usize) {
        (
            1,
            self.i_blocks + self.j_blocks - 2,
            (self.i_blocks - 1).saturating_mul(self.j_blocks - 1),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::SyntheticDataset;
    use crate::testing::prop;

    #[test]
    fn bounds_cover_exactly() {
        let g = Grid::new(10, 7, 3, 2);
        assert_eq!(g.row_bounds, vec![0, 4, 7, 10]);
        assert_eq!(g.col_bounds, vec![0, 4, 7]);
    }

    #[test]
    fn phases_follow_fig1() {
        let g = Grid::new(30, 40, 3, 4);
        assert_eq!(g.phase(BlockId { i: 0, j: 0 }), Phase::A);
        assert_eq!(g.phase(BlockId { i: 0, j: 2 }), Phase::B);
        assert_eq!(g.phase(BlockId { i: 2, j: 0 }), Phase::B);
        assert_eq!(g.phase(BlockId { i: 1, j: 1 }), Phase::C);
        assert_eq!(g.blocks_in_phase(Phase::A).len(), 1);
        assert_eq!(g.blocks_in_phase(Phase::B).len(), 3 + 4 - 2);
        assert_eq!(g.blocks_in_phase(Phase::C).len(), 2 * 3);
    }

    #[test]
    fn split_routes_every_entry_once() {
        let d = SyntheticDataset::by_name("movielens", 0.001, 13).unwrap();
        let g = Grid::new(d.ratings.rows, d.ratings.cols, 4, 3);
        let blocks = g.split(&d.ratings);
        let total: usize = blocks.iter().flatten().map(|b| b.nnz()).sum();
        assert_eq!(total, d.ratings.nnz());
    }

    #[test]
    fn prop_grid_partition_invariants() {
        prop::check(
            25,
            |g| {
                let rows = g.size(2, 200);
                let cols = g.size(2, 200);
                let i = g.usize_in(1, rows.min(8));
                let j = g.usize_in(1, cols.min(8));
                (rows, cols, i, j)
            },
            |&(rows, cols, i, j)| {
                let g = Grid::new(rows, cols, i, j);
                // bounds monotone, cover [0, rows]
                if g.row_bounds[0] != 0 || *g.row_bounds.last().unwrap() != rows {
                    return Err("row bounds don't cover".into());
                }
                for w in g.row_bounds.windows(2) {
                    if w[1] <= w[0] {
                        return Err("empty row block".into());
                    }
                }
                // block sizes differ by at most 1 (load balance)
                let sizes: Vec<usize> =
                    (0..i).map(|b| g.row_range(b).1 - g.row_range(b).0).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                if mx - mn > 1 {
                    return Err(format!("unbalanced rows: {sizes:?}"));
                }
                // every cell belongs to exactly one block
                let (pa, pb, pc) = g.phase_parallelism();
                if pa + pb + pc != g.n_blocks() {
                    return Err("phase partition of blocks broken".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_split_reassembles() {
        prop::check(
            15,
            |g| {
                let rows = g.size(3, 80);
                let cols = g.size(3, 80);
                let mut coo = Coo::new(rows, cols);
                for _ in 0..g.size(1, 300) {
                    coo.push(g.usize_in(0, rows - 1), g.usize_in(0, cols - 1), 1.0);
                }
                let i = g.usize_in(1, rows.min(6));
                let j = g.usize_in(1, cols.min(6));
                (coo, i, j)
            },
            |(coo, i, j)| {
                let g = Grid::new(coo.rows, coo.cols, *i, *j);
                let blocks = g.split(coo);
                let mut reassembled: Vec<(u32, u32)> = Vec::new();
                for bi in 0..*i {
                    for bj in 0..*j {
                        let (r0, _) = g.row_range(bi);
                        let (c0, _) = g.col_range(bj);
                        for e in &blocks[bi][bj].entries {
                            reassembled
                                .push((e.row + r0 as u32, e.col + c0 as u32));
                        }
                    }
                }
                let mut orig: Vec<(u32, u32)> =
                    coo.entries.iter().map(|e| (e.row, e.col)).collect();
                reassembled.sort_unstable();
                orig.sort_unstable();
                if reassembled != orig {
                    return Err("reassembled entries differ".into());
                }
                Ok(())
            },
        );
    }
}
