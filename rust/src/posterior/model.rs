//! The servable artifact of a factorization run.
//!
//! [`PosteriorModel`] is what training *produces* and serving *consumes*:
//! the aggregated per-row Gaussian posteriors over both factor sides plus
//! the global rating mean — nothing about how the run was scheduled or how
//! long it took (that lives in `coordinator::trainer::TrainResult`).
//! Checkpoints persist exactly this type, the `bmf-pp predict` subcommand
//! loads exactly this type, and the baseline comparators convert their
//! point estimates into it so every method is evaluated through one
//! prediction path.

use super::RowGaussians;
use crate::data::sparse::Coo;
use crate::linalg::Cholesky;
use crate::metrics::rmse::{rmse_factors, rmse_with};

/// A prediction request referenced an entity the model does not contain.
///
/// Ids arrive from untrusted callers (the `serve` HTTP surface, CLI
/// arguments), so the fallible `try_*` accessors return this instead of
/// panicking; the server maps it to a 4xx response.
#[derive(Debug, Clone, Copy, PartialEq, Eq, thiserror::Error)]
pub enum PredictError {
    /// The row id is ≥ the number of row entities in the model.
    #[error("row {row} out of range (model has {rows} rows)")]
    RowOutOfRange {
        /// The offending row id.
        row: usize,
        /// Number of row entities in the model.
        rows: usize,
    },
    /// The column id is ≥ the number of column entities in the model.
    #[error("col {col} out of range (model has {cols} cols)")]
    ColOutOfRange {
        /// The offending column id.
        col: usize,
        /// Number of column entities in the model.
        cols: usize,
    },
}

/// A trained factorization model: posterior marginals over the factor rows
/// (means + precisions), f32 mean mirrors for fast prediction, and the
/// global rating mean (training is mean-centred; predictions add it back).
#[derive(Debug, Clone)]
pub struct PosteriorModel {
    /// Latent dimension.
    pub k: usize,
    /// Global rating mean.
    pub global_mean: f64,
    /// Row-side posterior marginals (n_rows × k Gaussians).
    pub u_post: RowGaussians,
    /// Column-side posterior marginals (n_cols × k Gaussians).
    pub v_post: RowGaussians,
    /// Posterior means as f32 factors (rows×k) for fast prediction.
    pub u_mean: Vec<f32>,
    /// Posterior means as f32 factors (cols×k) for fast prediction.
    pub v_mean: Vec<f32>,
}

impl PosteriorModel {
    /// Build from the two aggregated posterior sides.
    pub fn new(u_post: RowGaussians, v_post: RowGaussians, global_mean: f64) -> PosteriorModel {
        assert_eq!(u_post.k, v_post.k, "factor sides must share the latent dimension");
        let u_mean: Vec<f32> = u_post.mean.iter().map(|&x| x as f32).collect();
        let v_mean: Vec<f32> = v_post.mean.iter().map(|&x| x as f32).collect();
        PosteriorModel { k: u_post.k, global_mean, u_post, v_post, u_mean, v_mean }
    }

    /// Wrap a point estimate (e.g. an SGD/ALS baseline) as a degenerate
    /// posterior: means from the factors, precision `precision`·I per row.
    /// A large `precision` makes `predict_variance` report near-zero
    /// factor uncertainty, which is the honest statement for a MAP fit.
    pub fn from_factors(
        k: usize,
        u: &[f32],
        v: &[f32],
        global_mean: f64,
        precision: f64,
    ) -> PosteriorModel {
        assert!(k > 0, "k must be positive");
        assert_eq!(u.len() % k, 0, "u length must be a multiple of k");
        assert_eq!(v.len() % k, 0, "v length must be a multiple of k");
        let (n, d) = (u.len() / k, v.len() / k);
        let mut u_post = RowGaussians::standard(n, k, precision);
        u_post.mean = u.iter().map(|&x| x as f64).collect();
        let mut v_post = RowGaussians::standard(d, k, precision);
        v_post.mean = v.iter().map(|&x| x as f64).collect();
        PosteriorModel::new(u_post, v_post, global_mean)
    }

    /// Number of row entities (users / compounds / …).
    pub fn rows(&self) -> usize {
        self.u_post.n
    }

    /// Number of column entities (items / targets / …).
    pub fn cols(&self) -> usize {
        self.v_post.n
    }

    /// Return an error when either id falls outside the model.
    fn check_ids(&self, row: usize, col: usize) -> Result<(), PredictError> {
        if row >= self.rows() {
            return Err(PredictError::RowOutOfRange { row, rows: self.rows() });
        }
        if col >= self.cols() {
            return Err(PredictError::ColOutOfRange { col, cols: self.cols() });
        }
        Ok(())
    }

    /// Posterior-mean prediction for one cell.
    ///
    /// Panics when an id is out of range; use
    /// [`PosteriorModel::try_predict`] for untrusted input.
    pub fn predict(&self, row: usize, col: usize) -> f64 {
        self.try_predict(row, col).expect("predict: id out of range")
    }

    /// Fallible [`PosteriorModel::predict`]: out-of-range ids become a
    /// typed [`PredictError`] instead of a panic.
    pub fn try_predict(&self, row: usize, col: usize) -> Result<f64, PredictError> {
        self.check_ids(row, col)?;
        Ok(self.global_mean
            + (0..self.k)
                .map(|j| (self.u_mean[row * self.k + j] * self.v_mean[col * self.k + j]) as f64)
                .sum::<f64>())
    }

    /// RMSE of posterior-mean predictions on a held-out set.
    pub fn rmse(&self, test: &Coo) -> f64 {
        if self.global_mean == 0.0 {
            rmse_factors(&self.u_mean, &self.v_mean, self.k, test)
        } else {
            rmse_with(test, |r, c| self.predict(r, c))
        }
    }

    /// Predictive variance of one cell from the factor posteriors
    /// (delta-method approximation: uᵀΣ_v u + vᵀΣ_u v + tr(Σ_u Σ_v)).
    ///
    /// Panics when an id is out of range; use
    /// [`PosteriorModel::try_predict_variance`] for untrusted input.
    pub fn predict_variance(&self, row: usize, col: usize) -> f64 {
        self.try_predict_variance(row, col).expect("predict_variance: id out of range")
    }

    /// Fallible [`PosteriorModel::predict_variance`]: out-of-range ids
    /// become a typed [`PredictError`] instead of a panic. A numerically
    /// unusable posterior precision still yields `Ok(NAN)` — that is a
    /// model property, not a caller error.
    pub fn try_predict_variance(&self, row: usize, col: usize) -> Result<f64, PredictError> {
        self.check_ids(row, col)?;
        let k = self.k;
        let su = self.u_post.row_prec(row);
        let sv = self.v_post.row_prec(col);
        let cu = Cholesky::new(&su).map(|c| c.inverse());
        let cv = Cholesky::new(&sv).map(|c| c.inverse());
        let (cu, cv) = match (cu, cv) {
            (Ok(a), Ok(b)) => (a, b),
            _ => return Ok(f64::NAN),
        };
        let u: Vec<f64> = (0..k).map(|j| self.u_mean[row * k + j] as f64).collect();
        let v: Vec<f64> = (0..k).map(|j| self.v_mean[col * k + j] as f64).collect();
        let vsv = cv.matvec(&u);
        let usu = cu.matvec(&v);
        let term1: f64 = u.iter().zip(&vsv).map(|(a, b)| a * b).sum();
        let term2: f64 = v.iter().zip(&usu).map(|(a, b)| a * b).sum();
        let term3: f64 = (0..k).map(|a| (0..k).map(|b| cu[(a, b)] * cv[(b, a)]).sum::<f64>()).sum();
        Ok(term1 + term2 + term3)
    }

    /// The `n` columns with the highest posterior-mean prediction for
    /// `row`, best first — the serving-side ranking primitive.
    ///
    /// Panics when `row` is out of range; use
    /// [`PosteriorModel::try_top_n`] for untrusted input.
    pub fn top_n(&self, row: usize, n: usize) -> Vec<(usize, f64)> {
        self.try_top_n(row, n).expect("top_n: row out of range")
    }

    /// Fallible [`PosteriorModel::top_n`]: an out-of-range row becomes a
    /// typed [`PredictError`] instead of a panic.
    pub fn try_top_n(&self, row: usize, n: usize) -> Result<Vec<(usize, f64)>, PredictError> {
        self.try_top_n_where(row, n, |_| true)
    }

    /// [`PosteriorModel::top_n`] restricted to columns where `keep` holds
    /// (e.g. skip already-rated items).
    ///
    /// Panics when `row` is out of range; use
    /// [`PosteriorModel::try_top_n_where`] for untrusted input.
    pub fn top_n_where(
        &self,
        row: usize,
        n: usize,
        keep: impl Fn(usize) -> bool,
    ) -> Vec<(usize, f64)> {
        self.try_top_n_where(row, n, keep).expect("top_n_where: row out of range")
    }

    /// Fallible [`PosteriorModel::top_n_where`]: an out-of-range row
    /// becomes a typed [`PredictError`] instead of a panic.
    pub fn try_top_n_where(
        &self,
        row: usize,
        n: usize,
        keep: impl Fn(usize) -> bool,
    ) -> Result<Vec<(usize, f64)>, PredictError> {
        if row >= self.rows() {
            return Err(PredictError::RowOutOfRange { row, rows: self.rows() });
        }
        let mut scored: Vec<(usize, f64)> = (0..self.cols())
            .filter(|&c| keep(c))
            .map(|c| (c, self.predict(row, c)))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        scored.truncate(n);
        Ok(scored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point_model() -> PosteriorModel {
        // 2 rows × 3 cols, k = 2
        let u = vec![1.0f32, 0.0, 0.0, 1.0];
        let v = vec![1.0f32, 2.0, 3.0, -1.0, 0.5, 0.5];
        PosteriorModel::from_factors(2, &u, &v, 1.5, 1e6)
    }

    #[test]
    fn from_factors_predicts_dot_plus_mean() {
        let m = point_model();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        // row 0 picks the first factor coordinate
        assert!((m.predict(0, 0) - (1.5 + 1.0)).abs() < 1e-9);
        assert!((m.predict(0, 1) - (1.5 + 3.0)).abs() < 1e-9);
        // row 1 picks the second coordinate
        assert!((m.predict(1, 1) - (1.5 - 1.0)).abs() < 1e-9);
    }

    #[test]
    fn point_model_has_near_zero_variance() {
        let m = point_model();
        let var = m.predict_variance(0, 0);
        assert!(var.is_finite() && var >= 0.0 && var < 1e-4, "var={var}");
    }

    #[test]
    fn top_n_orders_by_prediction() {
        let m = point_model();
        // row 0 scores columns by v[c][0]: col1 (3.0) > col2 (0.5) > col0 (1.0)?
        // v rows: col0=(1,2) col1=(3,-1) col2=(0.5,0.5) → row0 dot = 1, 3, 0.5
        let top = m.top_n(0, 2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 0);
        assert!(top[0].1 >= top[1].1);
    }

    #[test]
    fn top_n_where_filters() {
        let m = point_model();
        let top = m.top_n_where(0, 3, |c| c != 1);
        assert_eq!(top.len(), 2);
        assert!(top.iter().all(|&(c, _)| c != 1));
        assert_eq!(top[0].0, 0); // next best after excluded col 1
    }

    #[test]
    fn try_predict_rejects_out_of_range_ids() {
        let m = point_model();
        assert_eq!(
            m.try_predict(2, 0),
            Err(PredictError::RowOutOfRange { row: 2, rows: 2 })
        );
        assert_eq!(
            m.try_predict(0, 3),
            Err(PredictError::ColOutOfRange { col: 3, cols: 3 })
        );
        assert_eq!(
            m.try_predict_variance(7, 0),
            Err(PredictError::RowOutOfRange { row: 7, rows: 2 })
        );
        assert_eq!(
            m.try_top_n(9, 1),
            Err(PredictError::RowOutOfRange { row: 9, rows: 2 })
        );
        assert!(m.try_top_n_where(9, 1, |_| true).is_err());
    }

    #[test]
    fn try_variants_agree_with_infallible_ones() {
        let m = point_model();
        assert_eq!(m.try_predict(0, 1).unwrap(), m.predict(0, 1));
        assert_eq!(m.try_predict_variance(1, 2).unwrap(), m.predict_variance(1, 2));
        assert_eq!(m.try_top_n(0, 2).unwrap(), m.top_n(0, 2));
    }

    #[test]
    fn predict_error_messages_name_the_bounds() {
        let err = PredictError::RowOutOfRange { row: 5, rows: 2 };
        assert!(err.to_string().contains("row 5"));
        assert!(err.to_string().contains("2 rows"));
        let err = PredictError::ColOutOfRange { col: 4, cols: 3 };
        assert!(err.to_string().contains("col 4"));
    }

    #[test]
    fn rmse_of_exact_fit_is_zero() {
        let m = point_model();
        let mut test = Coo::new(2, 3);
        for r in 0..2 {
            for c in 0..3 {
                test.push(r, c, m.predict(r, c) as f32);
            }
        }
        assert!(m.rmse(&test) < 1e-6);
    }
}
