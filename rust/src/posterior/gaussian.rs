//! Row-wise Gaussian posterior marginals.
//!
//! Posterior Propagation approximates the posterior over each factor row
//! u_n by a multivariate Gaussian N(mean[n], prec[n]^{-1}) (Qin et al.
//! 2019). Phases (b) and (c) consume these as priors; aggregation divides
//! away multiply-counted propagated marginals — Gaussian density division
//! subtracts precisions and natural parameters.

use crate::linalg::{Cholesky, Mat};

/// N independent K-dimensional Gaussians: per-row mean and precision.
#[derive(Debug, Clone)]
pub struct RowGaussians {
    /// Number of rows (independent Gaussians).
    pub n: usize,
    /// Dimension of each Gaussian.
    pub k: usize,
    /// Means, row-major (n × k).
    pub mean: Vec<f64>,
    /// Precisions, row-major (n × k × k), each SPD.
    pub prec: Vec<f64>,
}

impl RowGaussians {
    /// All rows share `mean`/`prec` (the plain-BPMF hyperprior case).
    pub fn broadcast(n: usize, mean: &[f64], prec: &Mat) -> RowGaussians {
        let k = mean.len();
        assert_eq!((prec.rows, prec.cols), (k, k));
        let mut g = RowGaussians {
            n,
            k,
            mean: Vec::with_capacity(n * k),
            prec: Vec::with_capacity(n * k * k),
        };
        for _ in 0..n {
            g.mean.extend_from_slice(mean);
            g.prec.extend_from_slice(&prec.data);
        }
        g
    }

    /// Standard-normal prior N(0, I/alpha) i.e. precision alpha*I.
    pub fn standard(n: usize, k: usize, alpha: f64) -> RowGaussians {
        RowGaussians::broadcast(n, &vec![0.0; k], &Mat::scaled_eye(k, alpha))
    }

    /// Mean of row `i`.
    pub fn row_mean(&self, i: usize) -> &[f64] {
        &self.mean[i * self.k..(i + 1) * self.k]
    }

    /// Precision matrix of row `i` (copied into a `Mat`).
    pub fn row_prec(&self, i: usize) -> Mat {
        let kk = self.k * self.k;
        Mat::from_vec(self.k, self.k, self.prec[i * kk..(i + 1) * kk].to_vec())
    }

    fn set_row(&mut self, i: usize, mean: &[f64], prec: &Mat) {
        let k = self.k;
        self.mean[i * k..(i + 1) * k].copy_from_slice(mean);
        self.prec[i * k * k..(i + 1) * k * k].copy_from_slice(&prec.data);
    }

    /// Product of densities per row (posterior combine):
    /// prec = pa + pb, mean = prec^{-1} (pa μa + pb μb).
    pub fn combine(&self, other: &RowGaussians) -> RowGaussians {
        assert_eq!((self.n, self.k), (other.n, other.k));
        let mut out = self.clone();
        for i in 0..self.n {
            let pa = self.row_prec(i);
            let pb = other.row_prec(i);
            let prec = &pa + &pb;
            let mut h = pa.matvec(self.row_mean(i));
            let hb = pb.matvec(other.row_mean(i));
            for (a, b) in h.iter_mut().zip(hb) {
                *a += b;
            }
            let mean = Cholesky::new(&prec)
                .expect("combined precision must be SPD")
                .solve(&h);
            out.set_row(i, &mean, &prec);
        }
        out
    }

    /// Density division per row (divide away a multiply-counted prior):
    /// prec = pa - pb (ridged to stay SPD), mean = prec^{-1} (pa μa - pb μb).
    ///
    /// `ridge` guards against the difference losing positive-definiteness
    /// to Monte-Carlo noise — the standard fix in embarrassingly-parallel
    /// MCMC aggregation.
    pub fn divide(&self, other: &RowGaussians, ridge: f64) -> RowGaussians {
        assert_eq!((self.n, self.k), (other.n, other.k));
        let mut out = self.clone();
        for i in 0..self.n {
            let pa = self.row_prec(i);
            let pb = other.row_prec(i);
            let mut prec = &pa - &pb;
            prec.symmetrize();
            // ridge escalation until SPD
            let mut lam = ridge;
            let chol = loop {
                match Cholesky::new(&prec) {
                    Ok(c) => break c,
                    Err(_) => {
                        for d in 0..self.k {
                            prec[(d, d)] += lam;
                        }
                        lam *= 10.0;
                        if lam > 1e8 {
                            panic!("divide: precision unrecoverable");
                        }
                    }
                }
            };
            let mut h = pa.matvec(self.row_mean(i));
            let hb = pb.matvec(other.row_mean(i));
            for (a, b) in h.iter_mut().zip(hb) {
                *a -= b;
            }
            let mean = chol.solve(&h);
            out.set_row(i, &mean, &prec);
        }
        out
    }

    /// Stack two row sets (concatenate along n).
    pub fn concat(&self, other: &RowGaussians) -> RowGaussians {
        assert_eq!(self.k, other.k);
        let mut out = self.clone();
        out.n += other.n;
        out.mean.extend_from_slice(&other.mean);
        out.prec.extend_from_slice(&other.prec);
        out
    }

    /// Slice rows [a, b).
    pub fn slice(&self, a: usize, b: usize) -> RowGaussians {
        let k = self.k;
        RowGaussians {
            n: b - a,
            k,
            mean: self.mean[a * k..b * k].to_vec(),
            prec: self.prec[a * k * k..b * k * k].to_vec(),
        }
    }

    /// Flatten to f32 buffers in the layout the AOT artifacts consume
    /// (mean: n×k, prec: n×k×k), zero-padded to `pad_n` rows with identity
    /// precisions (padding rows must stay SPD for the batched Cholesky).
    pub fn to_f32_padded(&self, pad_n: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(self.n <= pad_n);
        let k = self.k;
        let mut mean = vec![0.0f32; pad_n * k];
        let mut prec = vec![0.0f32; pad_n * k * k];
        for (dst, src) in mean.iter_mut().zip(&self.mean) {
            *dst = *src as f32;
        }
        for (dst, src) in prec.iter_mut().zip(&self.prec) {
            *dst = *src as f32;
        }
        for i in self.n..pad_n {
            for d in 0..k {
                prec[i * k * k + d * k + d] = 1.0;
            }
        }
        (mean, prec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::prop;

    fn random_gaussians(n: usize, k: usize, seed: u64) -> RowGaussians {
        let mut rng = Rng::seed_from_u64(seed);
        let mut g = RowGaussians::standard(n, k, 1.0);
        for i in 0..n {
            let mut a = Mat::zeros(k, k);
            for v in a.data.iter_mut() {
                *v = rng.uniform() - 0.5;
            }
            let mut spd = a.matmul(&a.transpose());
            for d in 0..k {
                spd[(d, d)] += 1.0 + k as f64 * 0.25;
            }
            let mean: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0 - 1.0).collect();
            g.set_row(i, &mean, &spd);
        }
        g
    }

    #[test]
    fn broadcast_rows_are_identical() {
        let g = RowGaussians::standard(4, 3, 2.0);
        assert_eq!(g.row_mean(0), g.row_mean(3));
        assert_eq!(g.row_prec(1), Mat::scaled_eye(3, 2.0));
    }

    #[test]
    fn combine_of_identical_doubles_precision() {
        let g = random_gaussians(3, 4, 1);
        let c = g.combine(&g);
        for i in 0..3 {
            let mut want = g.row_prec(i);
            want.scale(2.0);
            assert!(c.row_prec(i).max_abs_diff(&want) < 1e-9);
            // mean unchanged
            for (a, b) in c.row_mean(i).iter().zip(g.row_mean(i)) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn divide_inverts_combine() {
        let a = random_gaussians(5, 3, 2);
        let b = random_gaussians(5, 3, 3);
        let c = a.combine(&b);
        let back = c.divide(&b, 1e-9);
        for i in 0..5 {
            assert!(back.row_prec(i).max_abs_diff(&a.row_prec(i)) < 1e-6);
            for (x, y) in back.row_mean(i).iter().zip(a.row_mean(i)) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn prop_combine_commutes() {
        prop::check(
            15,
            |g| {
                let n = g.size(1, 8);
                let k = *g.pick(&[1usize, 2, 4]);
                (n, k, g.usize_in(0, 1000) as u64)
            },
            |&(n, k, seed)| {
                let a = random_gaussians(n, k, seed);
                let b = random_gaussians(n, k, seed + 77);
                let ab = a.combine(&b);
                let ba = b.combine(&a);
                for i in 0..n {
                    if ab.row_prec(i).max_abs_diff(&ba.row_prec(i)) > 1e-9 {
                        return Err("precisions differ".into());
                    }
                    for (x, y) in ab.row_mean(i).iter().zip(ba.row_mean(i)) {
                        if (x - y).abs() > 1e-8 {
                            return Err("means differ".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concat_and_slice_roundtrip() {
        let a = random_gaussians(3, 2, 5);
        let b = random_gaussians(2, 2, 6);
        let c = a.concat(&b);
        assert_eq!(c.n, 5);
        let back = c.slice(3, 5);
        assert_eq!(back.mean, b.mean);
        assert_eq!(back.prec, b.prec);
    }

    #[test]
    fn f32_padding_is_identity_spd() {
        let g = random_gaussians(2, 3, 7);
        let (mean, prec) = g.to_f32_padded(4);
        assert_eq!(mean.len(), 4 * 3);
        assert_eq!(prec.len(), 4 * 9);
        // padded row has identity precision
        assert_eq!(prec[3 * 9 + 0], 1.0);
        assert_eq!(prec[3 * 9 + 4], 1.0);
        assert_eq!(prec[3 * 9 + 8], 1.0);
        assert_eq!(prec[3 * 9 + 1], 0.0);
    }
}
