//! Running moment accumulation: MCMC samples → Gaussian marginals.
//!
//! Each Posterior-Propagation phase runs Gibbs on a block and then
//! summarizes the retained samples of each factor row as a Gaussian
//! N(sample mean, sample covariance). This accumulator streams samples
//! (no sample storage) keeping sum and sum-of-outer-products per row.

use super::gaussian::RowGaussians;
use crate::linalg::{Cholesky, Mat};

/// Streaming first/second moments for N rows of dimension K.
#[derive(Debug, Clone)]
pub struct RunningMoments {
    /// Rows tracked.
    pub n: usize,
    /// Dimension per row.
    pub k: usize,
    /// Samples accumulated so far.
    pub count: usize,
    sum: Vec<f64>,     // n × k
    sum_sq: Vec<f64>,  // n × k × k (outer products)
}

impl RunningMoments {
    /// Zeroed accumulator for `n` rows of dimension `k`.
    pub fn new(n: usize, k: usize) -> RunningMoments {
        RunningMoments { n, k, count: 0, sum: vec![0.0; n * k], sum_sq: vec![0.0; n * k * k] }
    }

    /// Accumulate one sample of all rows (row-major n × k, f32 as produced
    /// by the runtime).
    pub fn push_f32(&mut self, sample: &[f32]) {
        assert_eq!(sample.len(), self.n * self.k);
        let k = self.k;
        for i in 0..self.n {
            let row = &sample[i * k..(i + 1) * k];
            let s = &mut self.sum[i * k..(i + 1) * k];
            for (a, &b) in s.iter_mut().zip(row) {
                *a += b as f64;
            }
            let sq = &mut self.sum_sq[i * k * k..(i + 1) * k * k];
            for a in 0..k {
                let ra = row[a] as f64;
                for b in 0..k {
                    sq[a * k + b] += ra * row[b] as f64;
                }
            }
        }
        self.count += 1;
    }

    /// Accumulate an f64 sample.
    pub fn push(&mut self, sample: &[f64]) {
        let f32s: Vec<f32> = sample.iter().map(|&x| x as f32).collect();
        self.push_f32(&f32s);
    }

    /// Row means (n × k).
    pub fn mean(&self) -> Vec<f64> {
        assert!(self.count > 0);
        self.sum.iter().map(|s| s / self.count as f64).collect()
    }

    /// Finalize into per-row Gaussians: mean = sample mean, precision =
    /// (sample covariance + ridge)^{-1}.
    ///
    /// The effective ridge is **scale-aware**: `ridge_abs + ridge_rel *
    /// tr(cov)/k` per row. With S retained samples the sample covariance
    /// has rank ≤ S-1; when S ≤ K a purely absolute ridge lets the
    /// precision explode along null directions (1/ridge), which then
    /// dominates posterior aggregation with pure Monte-Carlo noise. Tying
    /// the ridge to the row's own covariance scale caps the null-direction
    /// precision at ~(1/ridge_rel)× the average — statistically this is
    /// shrinkage of the propagated covariance toward a scaled identity.
    pub fn finalize_with(&self, ridge_abs: f64, ridge_rel: f64) -> RowGaussians {
        assert!(self.count >= 2, "need at least 2 samples to form a covariance");
        let k = self.k;
        let cnt = self.count as f64;
        let mut out = RowGaussians {
            n: self.n,
            k,
            mean: self.mean(),
            prec: vec![0.0; self.n * k * k],
        };
        for i in 0..self.n {
            let mu = &out.mean[i * k..(i + 1) * k];
            let mut cov = Mat::zeros(k, k);
            let sq = &self.sum_sq[i * k * k..(i + 1) * k * k];
            for a in 0..k {
                for b in 0..k {
                    cov[(a, b)] = sq[a * k + b] / cnt - mu[a] * mu[b];
                }
            }
            cov.symmetrize();
            let trace: f64 = (0..k).map(|d| cov[(d, d)]).sum();
            let eff = ridge_abs + ridge_rel * (trace / k as f64).max(0.0);
            for d in 0..k {
                cov[(d, d)] += eff;
            }
            let prec = Cholesky::new(&cov)
                .expect("ridged covariance must be SPD")
                .inverse();
            out.prec[i * k * k..(i + 1) * k * k].copy_from_slice(&prec.data);
        }
        out
    }

    /// `finalize_with(ridge, 0.1)` — the default shrinkage level.
    pub fn finalize(&self, ridge: f64) -> RowGaussians {
        self.finalize_with(ridge, 0.1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{normal::StdNormal, Rng};

    #[test]
    fn mean_and_cov_of_known_gaussian() {
        // stream draws from N(mu, diag(sig^2)) and check recovered moments
        let (n, k) = (2usize, 3usize);
        let mu = [1.0, -2.0, 0.5];
        let sig = [0.5, 1.0, 2.0];
        let mut rng = Rng::seed_from_u64(31);
        let mut norm = StdNormal::new();
        let mut acc = RunningMoments::new(n, k);
        let draws = 40_000;
        let mut buf = vec![0.0f64; n * k];
        for _ in 0..draws {
            for i in 0..n {
                for j in 0..k {
                    buf[i * k + j] = mu[j] + sig[j] * norm.sample(&mut rng);
                }
            }
            acc.push(&buf);
        }
        let g = acc.finalize_with(1e-6, 0.0); // no shrinkage: test exact recovery
        for i in 0..n {
            for j in 0..k {
                assert!((g.row_mean(i)[j] - mu[j]).abs() < 0.05);
            }
            // precision should approximate diag(1/sig^2)
            let prec = g.row_prec(i);
            for j in 0..k {
                let want = 1.0 / (sig[j] * sig[j]);
                assert!(
                    (prec[(j, j)] - want).abs() / want < 0.1,
                    "prec[{j}]={} want {want}",
                    prec[(j, j)]
                );
            }
        }
    }

    #[test]
    fn matches_naive_two_pass() {
        let (n, k) = (3usize, 2usize);
        let mut rng = Rng::seed_from_u64(8);
        let samples: Vec<Vec<f64>> = (0..50)
            .map(|_| (0..n * k).map(|_| rng.uniform() * 4.0 - 2.0).collect())
            .collect();
        let mut acc = RunningMoments::new(n, k);
        for s in &samples {
            acc.push(s);
        }
        let mean = acc.mean();
        for i in 0..n {
            for j in 0..k {
                let naive: f64 =
                    samples.iter().map(|s| s[i * k + j]).sum::<f64>() / samples.len() as f64;
                assert!((mean[i * k + j] - naive).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[should_panic]
    fn finalize_requires_two_samples() {
        let mut acc = RunningMoments::new(1, 2);
        acc.push(&[1.0, 2.0]);
        let _ = acc.finalize(1e-6);
    }

    #[test]
    fn constant_samples_yield_high_precision() {
        let mut acc = RunningMoments::new(1, 2);
        for _ in 0..10 {
            acc.push(&[3.0, -1.0]);
        }
        let g = acc.finalize(1e-4);
        // zero covariance + ridge → precision = 1/ridge on the diagonal
        let prec = g.row_prec(0);
        assert!(prec[(0, 0)] > 1e3);
        assert!((g.row_mean(0)[0] - 3.0).abs() < 1e-9);
    }
}
