//! Posterior representations for Posterior Propagation: row-wise Gaussian
//! marginals over factor rows, the combine/divide algebra used when
//! propagating and aggregating them, and running moment estimators that
//! turn MCMC samples into those Gaussians.

pub mod gaussian;
pub mod moments;

pub use gaussian::RowGaussians;
pub use moments::RunningMoments;
