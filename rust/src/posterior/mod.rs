//! Posterior representations for Posterior Propagation: row-wise Gaussian
//! marginals over factor rows, the combine/divide algebra used when
//! propagating and aggregating them, running moment estimators that turn
//! MCMC samples into those Gaussians, and the servable [`PosteriorModel`]
//! a training run ultimately produces.

pub mod gaussian;
pub mod model;
pub mod moments;

pub use gaussian::RowGaussians;
pub use model::{PosteriorModel, PredictError};
pub use moments::RunningMoments;
