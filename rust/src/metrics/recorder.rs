//! Structured metric recording: named series of (step, value) points,
//! dumped as JSON for EXPERIMENTS.md and plotting.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Collects named numeric series and scalar results.
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    series: BTreeMap<String, Vec<(f64, f64)>>,
    scalars: BTreeMap<String, f64>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn point(&mut self, series: &str, x: f64, y: f64) {
        self.series.entry(series.to_string()).or_default().push((x, y));
    }

    pub fn scalar(&mut self, name: &str, value: f64) {
        self.scalars.insert(name.to_string(), value);
    }

    pub fn get_scalar(&self, name: &str) -> Option<f64> {
        self.scalars.get(name).copied()
    }

    pub fn get_series(&self, name: &str) -> Option<&[(f64, f64)]> {
        self.series.get(name).map(|v| v.as_slice())
    }

    pub fn to_json(&self) -> Json {
        let series = Json::Obj(
            self.series
                .iter()
                .map(|(k, pts)| {
                    (
                        k.clone(),
                        Json::Arr(
                            pts.iter()
                                .map(|(x, y)| Json::Arr(vec![Json::Num(*x), Json::Num(*y)]))
                                .collect(),
                        ),
                    )
                })
                .collect(),
        );
        let scalars = Json::Obj(
            self.scalars.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
        );
        Json::obj(vec![("series", series), ("scalars", scalars)])
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, crate::util::json::to_string_pretty(&self.to_json()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_serializes() {
        let mut r = Recorder::new();
        r.point("rmse", 1.0, 0.95);
        r.point("rmse", 2.0, 0.90);
        r.scalar("final_rmse", 0.90);
        let j = r.to_json();
        let text = crate::util::json::to_string(&j);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(
            back.get("scalars").unwrap().get("final_rmse").unwrap().as_f64(),
            Some(0.90)
        );
        assert_eq!(back.get("series").unwrap().get("rmse").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn save_writes_file() {
        let mut r = Recorder::new();
        r.scalar("x", 1.5);
        let p = std::env::temp_dir().join(format!("bmfpp_rec_{}.json", std::process::id()));
        r.save(&p).unwrap();
        assert!(p.exists());
        std::fs::remove_file(p).ok();
    }
}
