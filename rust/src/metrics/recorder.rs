//! Structured metric recording: named series of (step, value) points,
//! dumped as JSON for EXPERIMENTS.md and plotting.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Collects named numeric series and scalar results.
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    series: BTreeMap<String, Vec<(f64, f64)>>,
    scalars: BTreeMap<String, f64>,
    /// Pipelined chunk publications observed — a plain counter because
    /// `ChunkExchanged` fires from sampling worker threads at chunk rate,
    /// too hot for a per-event map lookup.
    chunks_exchanged: u64,
}

impl Recorder {
    /// An empty recorder.
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Append `(x, y)` to the named series.
    pub fn point(&mut self, series: &str, x: f64, y: f64) {
        self.series.entry(series.to_string()).or_default().push((x, y));
    }

    /// Set a named scalar result (overwrites).
    pub fn scalar(&mut self, name: &str, value: f64) {
        self.scalars.insert(name.to_string(), value);
    }

    /// Read back a scalar, if recorded.
    pub fn get_scalar(&self, name: &str) -> Option<f64> {
        self.scalars.get(name).copied()
    }

    /// Read back a series, if any points were recorded.
    pub fn get_series(&self, name: &str) -> Option<&[(f64, f64)]> {
        self.series.get(name).map(|v| v.as_slice())
    }

    /// Fold one live [`TrainEvent`](crate::coordinator::TrainEvent) into
    /// the recorded series — point a session's event stream at a recorder
    /// and the learning curve / block timeline accumulate as the run
    /// executes instead of being reconstructed post-hoc.
    pub fn observe(&mut self, event: &crate::coordinator::TrainEvent) {
        use crate::coordinator::TrainEvent;
        match event {
            TrainEvent::SweepSample { node, sweep, rmse } => {
                self.point(&format!("sweep_rmse_{}x{}", node.0, node.1), *sweep as f64, *rmse);
            }
            TrainEvent::BlockCompleted { secs, .. } => {
                let idx = self.get_series("block_secs").map_or(0, |s| s.len());
                self.point("block_secs", idx as f64, *secs);
            }
            TrainEvent::Finished { secs, blocks } => {
                self.scalar("train_secs", *secs);
                self.scalar("blocks", *blocks as f64);
            }
            TrainEvent::ChunkExchanged { .. } => self.chunks_exchanged += 1,
            TrainEvent::Cancelled { blocks_completed } => {
                self.scalar("cancelled_after_blocks", *blocks_completed as f64);
            }
            TrainEvent::Failed { blocks_completed, .. } => {
                self.scalar("failed_after_blocks", *blocks_completed as f64);
            }
            TrainEvent::CheckpointSaved { blocks, .. } => {
                self.scalar("checkpoint_blocks", *blocks as f64);
            }
            TrainEvent::ShardLoaded {
                hits, misses, prefetch_hits, evictions, resident_bytes, ..
            } => {
                // the event carries cumulative totals, so the last one
                // observed leaves the final counters in the scalars
                self.scalar("shard_hits", *hits as f64);
                self.scalar("shard_misses", *misses as f64);
                self.scalar("shard_prefetch_hits", *prefetch_hits as f64);
                self.scalar("shard_evictions", *evictions as f64);
                let idx = self.get_series("shard_resident_bytes").map_or(0, |s| s.len());
                self.point("shard_resident_bytes", idx as f64, *resident_bytes as f64);
            }
            TrainEvent::BlockSkippedClean { .. } => {
                let n = self.get_scalar("blocks_skipped_clean").unwrap_or(0.0);
                self.scalar("blocks_skipped_clean", n + 1.0);
            }
            TrainEvent::PhaseStarted { .. } | TrainEvent::BlockRestored { .. } => {}
        }
    }

    /// Serialize all series and scalars as a JSON object.
    pub fn to_json(&self) -> Json {
        let series = Json::Obj(
            self.series
                .iter()
                .map(|(k, pts)| {
                    (
                        k.clone(),
                        Json::Arr(
                            pts.iter()
                                .map(|(x, y)| Json::Arr(vec![Json::Num(*x), Json::Num(*y)]))
                                .collect(),
                        ),
                    )
                })
                .collect(),
        );
        let mut scalars: BTreeMap<String, Json> =
            self.scalars.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
        if self.chunks_exchanged > 0 {
            scalars
                .insert("chunks_exchanged".to_string(), Json::Num(self.chunks_exchanged as f64));
        }
        Json::obj(vec![("series", series), ("scalars", Json::Obj(scalars))])
    }

    /// Write the JSON dump to `path` (pretty-printed).
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, crate::util::json::to_string_pretty(&self.to_json()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_serializes() {
        let mut r = Recorder::new();
        r.point("rmse", 1.0, 0.95);
        r.point("rmse", 2.0, 0.90);
        r.scalar("final_rmse", 0.90);
        let j = r.to_json();
        let text = crate::util::json::to_string(&j);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(
            back.get("scalars").unwrap().get("final_rmse").unwrap().as_f64(),
            Some(0.90)
        );
        assert_eq!(back.get("series").unwrap().get("rmse").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn observes_train_events() {
        use crate::coordinator::{FactorSide, PpPhase, TrainEvent};
        let mut r = Recorder::new();
        r.observe(&TrainEvent::PhaseStarted { phase: PpPhase::A });
        for chunk in 0..3 {
            r.observe(&TrainEvent::ChunkExchanged {
                node: (0, 0),
                side: FactorSide::U,
                sweep: 1,
                chunk,
                seq: chunk as u64 + 1,
            });
        }
        r.observe(&TrainEvent::SweepSample { node: (0, 0), sweep: 3, rmse: 0.9 });
        r.observe(&TrainEvent::SweepSample { node: (0, 0), sweep: 4, rmse: 0.8 });
        r.observe(&TrainEvent::BlockCompleted {
            node: (0, 0),
            phase: PpPhase::A,
            secs: 1.5,
            sweeps: 5,
        });
        r.observe(&TrainEvent::BlockSkippedClean { node: (0, 1) });
        r.observe(&TrainEvent::BlockSkippedClean { node: (1, 1) });
        r.observe(&TrainEvent::Finished { secs: 2.0, blocks: 1 });
        assert_eq!(r.get_scalar("blocks_skipped_clean"), Some(2.0));
        assert_eq!(r.get_series("sweep_rmse_0x0").unwrap().len(), 2);
        assert_eq!(r.get_series("block_secs").unwrap(), &[(0.0, 1.5)]);
        assert_eq!(r.get_scalar("train_secs"), Some(2.0));
        assert_eq!(r.get_scalar("blocks"), Some(1.0));
        // chunk publications land in the JSON dump as one scalar count
        let j = r.to_json();
        assert_eq!(
            j.get("scalars").unwrap().get("chunks_exchanged").unwrap().as_f64(),
            Some(3.0)
        );
    }

    #[test]
    fn observes_shard_loads_as_cumulative_scalars() {
        use crate::coordinator::TrainEvent;
        let mut r = Recorder::new();
        for i in 0..2u64 {
            r.observe(&TrainEvent::ShardLoaded {
                node: (0, i as usize),
                bytes: 24,
                prefetch: i == 1,
                hits: i,
                misses: i + 1,
                prefetch_hits: i,
                evictions: i,
                resident_bytes: 24 * (i + 1),
            });
        }
        assert_eq!(r.get_scalar("shard_hits"), Some(1.0));
        assert_eq!(r.get_scalar("shard_misses"), Some(2.0));
        assert_eq!(r.get_scalar("shard_prefetch_hits"), Some(1.0));
        assert_eq!(r.get_scalar("shard_evictions"), Some(1.0));
        assert_eq!(r.get_series("shard_resident_bytes").unwrap().len(), 2);
    }

    #[test]
    fn save_writes_file() {
        let mut r = Recorder::new();
        r.scalar("x", 1.5);
        let p = std::env::temp_dir().join(format!("bmfpp_rec_{}.json", std::process::id()));
        r.save(&p).unwrap();
        assert!(p.exists());
        std::fs::remove_file(p).ok();
    }
}
