//! Compute-performance metrics — the paper's Table-1 bottom rows
//! (rows/sec and ratings/sec of the sampler).

/// Throughput of a Gibbs run.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    /// Factor rows updated per second (U rows + V rows per sweep).
    pub rows_per_sec: f64,
    /// Observed ratings processed per second.
    pub ratings_per_sec: f64,
}

impl Throughput {
    /// From totals: `sweeps` full Gibbs sweeps over a matrix with
    /// `rows`+`cols` factor rows and `nnz` observations, in `secs` seconds.
    /// Each full sweep touches every rating twice (U side and V side).
    pub fn measure(rows: usize, cols: usize, nnz: usize, sweeps: usize, secs: f64) -> Throughput {
        let total_rows = (rows + cols) as f64 * sweeps as f64;
        let total_ratings = 2.0 * nnz as f64 * sweeps as f64;
        Throughput {
            rows_per_sec: total_rows / secs,
            ratings_per_sec: total_ratings / secs,
        }
    }

    /// Paper formatting: rows/sec in thousands, ratings/sec in millions.
    pub fn format_table1(&self) -> String {
        format!(
            "rows/sec(x1000)={:.1} ratings/sec(x1e6)={:.2}",
            self.rows_per_sec / 1e3,
            self.ratings_per_sec / 1e6
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_known_values() {
        let t = Throughput::measure(100, 50, 1000, 10, 2.0);
        assert!((t.rows_per_sec - 150.0 * 10.0 / 2.0).abs() < 1e-9);
        assert!((t.ratings_per_sec - 2.0 * 1000.0 * 10.0 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn formatting_units() {
        let t = Throughput { rows_per_sec: 416_000.0, ratings_per_sec: 70_000_000.0 };
        let s = t.format_table1();
        assert!(s.contains("416.0"));
        assert!(s.contains("70.00"));
    }
}
