//! Uncertainty-quantification metrics — the Bayesian payoff the paper's
//! introduction motivates (drug discovery needs calibrated predictive
//! uncertainty, Labelle et al. 2019 [9]).

use crate::data::sparse::Coo;

/// Empirical coverage of central credible intervals: the fraction of
/// held-out observations falling inside mean ± z·σ, for a set of z values.
#[derive(Debug, Clone)]
pub struct CoverageReport {
    /// (z, nominal coverage, empirical coverage).
    pub rows: Vec<(f64, f64, f64)>,
    /// Held-out observations evaluated.
    pub n: usize,
}

/// Standard normal CDF (Abramowitz-Stegun 7.1.26 via erf approximation).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    // max abs error ~1.5e-7
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Compute coverage at the given z values for a predictor that returns
/// (mean, std) per cell.
pub fn coverage(
    test: &Coo,
    zs: &[f64],
    mut predict: impl FnMut(usize, usize) -> (f64, f64),
) -> CoverageReport {
    let mut hits = vec![0usize; zs.len()];
    for e in &test.entries {
        let (mu, sigma) = predict(e.row as usize, e.col as usize);
        let dev = (e.val as f64 - mu).abs();
        for (h, &z) in hits.iter_mut().zip(zs) {
            if dev <= z * sigma {
                *h += 1;
            }
        }
    }
    let n = test.nnz().max(1);
    CoverageReport {
        rows: zs
            .iter()
            .zip(&hits)
            .map(|(&z, &h)| (z, 2.0 * normal_cdf(z) - 1.0, h as f64 / n as f64))
            .collect(),
        n,
    }
}

/// Mean negative log predictive density under per-cell Gaussians — the
/// proper-scoring complement to RMSE (lower is better).
pub fn mean_nlpd(
    test: &Coo,
    mut predict: impl FnMut(usize, usize) -> (f64, f64),
) -> f64 {
    let ln_2pi = (2.0 * std::f64::consts::PI).ln();
    let mut total = 0.0;
    for e in &test.entries {
        let (mu, sigma) = predict(e.row as usize, e.col as usize);
        let var = (sigma * sigma).max(1e-12);
        let z2 = (e.val as f64 - mu).powi(2) / var;
        total += 0.5 * (ln_2pi + var.ln() + z2);
    }
    total / test.nnz().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{normal::StdNormal, Rng};

    #[test]
    fn normal_cdf_known_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-1.96) - 0.025).abs() < 1e-3);
    }

    fn gaussian_test_set(sigma: f64, n: usize) -> Coo {
        let mut rng = Rng::seed_from_u64(7);
        let mut norm = StdNormal::new();
        let mut coo = Coo::new(n, 1);
        for r in 0..n {
            coo.push(r, 0, (3.0 + sigma * norm.sample(&mut rng)) as f32);
        }
        coo
    }

    #[test]
    fn well_calibrated_predictor_covers_nominally() {
        let test = gaussian_test_set(0.5, 20_000);
        let rep = coverage(&test, &[1.0, 2.0], |_, _| (3.0, 0.5));
        for (z, nominal, empirical) in rep.rows {
            assert!(
                (nominal - empirical).abs() < 0.02,
                "z={z}: nominal {nominal} vs {empirical}"
            );
        }
    }

    #[test]
    fn overconfident_predictor_undercovers() {
        let test = gaussian_test_set(1.0, 10_000);
        let rep = coverage(&test, &[2.0], |_, _| (3.0, 0.25)); // 4x overconfident
        assert!(rep.rows[0].2 < 0.7, "should undercover: {:?}", rep.rows);
    }

    #[test]
    fn nlpd_prefers_true_sigma() {
        let test = gaussian_test_set(0.5, 10_000);
        let good = mean_nlpd(&test, |_, _| (3.0, 0.5));
        let over = mean_nlpd(&test, |_, _| (3.0, 0.05));
        let under = mean_nlpd(&test, |_, _| (3.0, 5.0));
        assert!(good < over && good < under, "good {good} over {over} under {under}");
    }
}
