//! Evaluation metrics and performance counters.

pub mod calibration;
pub mod recorder;
pub mod rmse;
pub mod throughput;
