//! RMSE evaluation.

use crate::data::sparse::Coo;

/// Streaming SSE accumulator → RMSE.
#[derive(Debug, Clone, Copy, Default)]
pub struct SseAccumulator {
    /// Sum of squared errors so far.
    pub sse: f64,
    /// Observations accumulated.
    pub count: f64,
}

impl SseAccumulator {
    /// Fold in a partial SSE over `count` observations.
    pub fn add(&mut self, sse: f64, count: f64) {
        self.sse += sse;
        self.count += count;
    }

    /// Fold in another accumulator.
    pub fn merge(&mut self, other: &SseAccumulator) {
        self.sse += other.sse;
        self.count += other.count;
    }

    /// RMSE of everything accumulated (NaN when empty).
    pub fn rmse(&self) -> f64 {
        if self.count == 0.0 {
            f64::NAN
        } else {
            (self.sse / self.count).sqrt()
        }
    }
}

/// RMSE of factor predictions u vᵀ against observed entries of `test`.
/// Factors are row-major f32 (rows×k, cols×k).
pub fn rmse_factors(u: &[f32], v: &[f32], k: usize, test: &Coo) -> f64 {
    let mut acc = SseAccumulator::default();
    for e in &test.entries {
        let (r, c) = (e.row as usize, e.col as usize);
        let pred: f32 = (0..k).map(|j| u[r * k + j] * v[c * k + j]).sum();
        let err = (pred - e.val) as f64;
        acc.add(err * err, 1.0);
    }
    acc.rmse()
}

/// RMSE of an arbitrary predictor closure.
pub fn rmse_with(test: &Coo, mut predict: impl FnMut(usize, usize) -> f64) -> f64 {
    let mut acc = SseAccumulator::default();
    for e in &test.entries {
        let err = predict(e.row as usize, e.col as usize) - e.val as f64;
        acc.add(err * err, 1.0);
    }
    acc.rmse()
}

/// RMSE of always predicting the train-set mean (the weakest sane baseline).
pub fn mean_predictor_rmse(train_mean: f64, test: &Coo) -> f64 {
    rmse_with(test, |_, _| train_mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_merges() {
        let mut a = SseAccumulator::default();
        a.add(4.0, 1.0);
        let mut b = SseAccumulator::default();
        b.add(0.0, 1.0);
        a.merge(&b);
        assert!((a.rmse() - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_test_is_nan() {
        assert!(SseAccumulator::default().rmse().is_nan());
    }

    #[test]
    fn perfect_factors_have_zero_rmse() {
        let k = 2;
        let u = vec![1.0f32, 0.0, 0.0, 1.0]; // 2 rows
        let v = vec![0.5f32, 0.25, 1.0, -1.0]; // 2 cols
        let mut t = Coo::new(2, 2);
        t.push(0, 0, 0.5);
        t.push(1, 1, -1.0);
        assert!(rmse_factors(&u, &v, k, &t) < 1e-7);
    }

    #[test]
    fn known_rmse() {
        let mut t = Coo::new(1, 2);
        t.push(0, 0, 1.0);
        t.push(0, 1, 3.0);
        // predict 2.0 everywhere: errors 1 and 1 → rmse 1
        assert!((rmse_with(&t, |_, _| 2.0) - 1.0).abs() < 1e-12);
    }
}
