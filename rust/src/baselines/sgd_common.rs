//! Shared pieces of the SGD-based baselines (FPSGD, NOMAD).

use crate::rng::{normal::standard_normal_vec, Rng};

/// Hyperparameters for SGD matrix factorization.
#[derive(Debug, Clone, Copy)]
pub struct SgdConfig {
    /// Latent dimension.
    pub k: usize,
    /// Initial learning rate.
    pub lr: f32,
    /// L2 regularization weight.
    pub reg: f32,
    /// Passes over the data.
    pub epochs: usize,
    /// Per-epoch learning-rate decay factor.
    pub decay: f32,
    /// Worker threads.
    pub threads: usize,
    /// RNG seed.
    pub seed: u64,
}

impl SgdConfig {
    /// Defaults for latent dimension `k`.
    pub fn new(k: usize) -> SgdConfig {
        SgdConfig { k, lr: 0.05, reg: 0.05, epochs: 20, decay: 0.9, threads: 4, seed: 42 }
    }

    /// Set the number of passes over the data.
    pub fn with_epochs(mut self, epochs: usize) -> Self {
        self.epochs = epochs;
        self
    }

    /// Set the worker thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Set the RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Learning rate after `epoch` decay steps.
    pub fn lr_at_epoch(&self, epoch: usize) -> f32 {
        self.lr * self.decay.powi(epoch as i32)
    }
}

/// One SGD update on a single rating residual (ratings centred on `mean`).
/// Returns the squared error before the update.
#[inline]
pub fn sgd_update(
    u: &mut [f32],
    v: &mut [f32],
    rating: f32,
    mean: f32,
    lr: f32,
    reg: f32,
) -> f32 {
    debug_assert_eq!(u.len(), v.len());
    let mut dot = 0.0f32;
    for (a, b) in u.iter().zip(v.iter()) {
        dot += a * b;
    }
    let err = rating - mean - dot;
    for (a, b) in u.iter_mut().zip(v.iter_mut()) {
        let (ua, vb) = (*a, *b);
        *a += lr * (err * vb - reg * ua);
        *b += lr * (err * ua - reg * vb);
    }
    err * err
}

/// Random factor initialization at scale 1/sqrt(k).
pub fn init_factors(rng: &mut Rng, rows: usize, k: usize) -> Vec<f32> {
    let scale = (1.0 / k as f64).sqrt() as f32;
    standard_normal_vec(rng, rows * k).iter().map(|x| x * scale).collect()
}

/// Mean and standard deviation of the observed ratings — SGD baselines
/// standardize internally so one learning rate works across rating scales
/// (1-5 vs 0-100; without this the Yahoo scale diverges).
pub fn standardization(data: &crate::data::sparse::Coo) -> (f32, f32) {
    let mean = data.mean();
    if data.nnz() == 0 {
        return (0.0, 1.0);
    }
    let var: f64 = data
        .entries
        .iter()
        .map(|e| (e.val as f64 - mean).powi(2))
        .sum::<f64>()
        / data.nnz() as f64;
    (mean as f32, (var.sqrt().max(1e-6)) as f32)
}

/// Result of an SGD baseline run.
#[derive(Debug, Clone)]
pub struct SgdModel {
    /// Latent dimension.
    pub k: usize,
    /// Global rating mean (added back at prediction).
    pub mean: f32,
    /// Rating scale the factors were trained in (predictions multiply back).
    pub scale: f32,
    /// Row factors (rows × k).
    pub u: Vec<f32>,
    /// Column factors (cols × k).
    pub v: Vec<f32>,
    /// Wall-clock seconds of the fit.
    pub secs: f64,
    /// Epochs actually run.
    pub epochs_run: usize,
}

impl SgdModel {
    /// Point prediction for one cell.
    pub fn predict(&self, row: usize, col: usize) -> f64 {
        let mut dot = 0.0f64;
        for j in 0..self.k {
            dot += (self.u[row * self.k + j] * self.v[col * self.k + j]) as f64;
        }
        self.mean as f64 + self.scale as f64 * dot
    }

    /// RMSE of point predictions on a held-out set.
    pub fn rmse(&self, test: &crate::data::sparse::Coo) -> f64 {
        crate::metrics::rmse::rmse_with(test, |r, c| self.predict(r, c))
    }

    /// Convert to the servable [`PosteriorModel`]: the training scale is
    /// folded into the U factors and the point estimate becomes a
    /// degenerate posterior (tight identity precision), so baselines flow
    /// through the same checkpoint/predict/evaluate path as PP.
    pub fn to_posterior(&self) -> crate::posterior::PosteriorModel {
        let u_scaled: Vec<f32> = self.u.iter().map(|x| x * self.scale).collect();
        crate::posterior::PosteriorModel::from_factors(
            self.k,
            &u_scaled,
            &self.v,
            self.mean as f64,
            1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_reduces_error_on_repeat() {
        let mut u = vec![0.1f32, -0.1];
        let mut v = vec![0.2f32, 0.3];
        let target = 4.0;
        let mut last = f32::INFINITY;
        for _ in 0..200 {
            let se = sgd_update(&mut u, &mut v, target, 0.0, 0.05, 0.0);
            assert!(se <= last * 1.001, "error should shrink: {se} > {last}");
            last = se;
        }
        assert!(last < 1e-3);
    }

    #[test]
    fn regularization_shrinks_factors() {
        let mut u = vec![5.0f32];
        let mut v = vec![5.0f32];
        // rating equals current prediction → err 0, only reg acts
        let r = 25.0;
        sgd_update(&mut u, &mut v, r, 0.0, 0.1, 0.5);
        assert!(u[0] < 5.0 && v[0] < 5.0);
    }

    #[test]
    fn lr_decays() {
        let c = SgdConfig::new(8);
        assert!(c.lr_at_epoch(5) < c.lr_at_epoch(0));
    }

    #[test]
    fn init_scale() {
        let mut rng = Rng::seed_from_u64(1);
        let f = init_factors(&mut rng, 1000, 16);
        let var: f64 =
            f.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / f.len() as f64;
        assert!((var - 1.0 / 16.0).abs() < 0.01, "var={var}");
    }
}
