//! CCD++-style Coordinate Gradient Descent baseline (paper §3.2: "CGD-
//! based algorithms update along one dimension at a time"; Yu et al. 2012
//! [18]). Rank-one refinements: for each latent dimension t, alternately
//! re-fit the t-th column of U and V against the residual with the other
//! K−1 dimensions fixed — closed-form scalar updates per row.

use super::sgd_common::{init_factors, standardization, SgdModel};
use crate::data::sparse::{Coo, Csr};
use crate::rng::Rng;

/// CCD++ hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct CgdConfig {
    /// Latent dimension.
    pub k: usize,
    /// Ridge weight λ.
    pub lambda: f64,
    /// Outer passes over all K dimensions.
    pub outer_iters: usize,
    /// Inner refinements of each rank-one subproblem.
    pub inner_iters: usize,
    /// RNG seed.
    pub seed: u64,
}

impl CgdConfig {
    /// Defaults for latent dimension `k`.
    pub fn new(k: usize) -> CgdConfig {
        CgdConfig { k, lambda: 0.05, outer_iters: 6, inner_iters: 2, seed: 42 }
    }
}

/// One scalar coordinate refit: for each row i of this side,
/// u_i = Σ_d res_id v_d / (λ·nnz_i + Σ_d v_d²) over observed d.
fn refit_column(csr: &Csr, res: &[f32], vt: &[f32], lambda: f64, out: &mut [f32]) {
    for i in 0..csr.rows {
        let (cols, vals_idx) = csr.row(i);
        if cols.is_empty() {
            out[i] = 0.0;
            continue;
        }
        let mut num = 0.0f64;
        let mut den = lambda * cols.len() as f64 + 1e-12;
        let (lo, _) = (csr.indptr[i], csr.indptr[i + 1]);
        for (slot, c) in cols.iter().enumerate() {
            let v = vt[*c as usize] as f64;
            num += res[lo + slot] as f64 * v;
            den += v * v;
            let _ = vals_idx;
        }
        out[i] = (num / den) as f32;
    }
}

/// Train CCD++.
pub fn train(data: &Coo, cfg: &CgdConfig) -> SgdModel {
    let t0 = std::time::Instant::now();
    let k = cfg.k;
    let (mean, scale) = standardization(data);
    let mut std_data = data.clone();
    for e in std_data.entries.iter_mut() {
        e.val = (e.val - mean) / scale;
    }
    let rows = Csr::from_coo(&std_data);
    let cols = rows.transpose();
    // residual arrays aligned with each CSR's value layout
    let mut res_rows = rows.values.clone();
    let mut res_cols = cols.values.clone();

    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut u = init_factors(&mut rng, data.rows, k);
    let mut v = init_factors(&mut rng, data.cols, k);

    // start residual = r − u·v
    subtract_predictions(&rows, &u, &v, k, &mut res_rows);
    subtract_predictions(&cols, &v, &u, k, &mut res_cols);

    let mut ut = vec![0.0f32; data.rows];
    let mut vt = vec![0.0f32; data.cols];
    for _ in 0..cfg.outer_iters {
        for t in 0..k {
            // add back dimension t's contribution into the residuals
            for (slice, csr_side, a, b) in [
                (&mut res_rows, &rows, &u, &v),
                (&mut res_cols, &cols, &v, &u),
            ] {
                add_rank_one(csr_side, a, b, k, t, slice, 1.0);
            }
            for (i, x) in ut.iter_mut().enumerate() {
                *x = u[i * k + t];
            }
            for (i, x) in vt.iter_mut().enumerate() {
                *x = v[i * k + t];
            }
            for _ in 0..cfg.inner_iters {
                refit_column(&rows, &res_rows, &vt, cfg.lambda, &mut ut);
                refit_column(&cols, &res_cols, &ut, cfg.lambda, &mut vt);
            }
            for (i, x) in ut.iter().enumerate() {
                u[i * k + t] = *x;
            }
            for (i, x) in vt.iter().enumerate() {
                v[i * k + t] = *x;
            }
            // subtract the refreshed dimension back out
            for (slice, csr_side, a, b) in [
                (&mut res_rows, &rows, &u, &v),
                (&mut res_cols, &cols, &v, &u),
            ] {
                add_rank_one(csr_side, a, b, k, t, slice, -1.0);
            }
        }
    }
    SgdModel {
        k,
        mean,
        scale,
        u,
        v,
        secs: t0.elapsed().as_secs_f64(),
        epochs_run: cfg.outer_iters,
    }
}

fn subtract_predictions(csr: &Csr, a: &[f32], b: &[f32], k: usize, res: &mut [f32]) {
    for i in 0..csr.rows {
        let (cols, _) = csr.row(i);
        let lo = csr.indptr[i];
        for (slot, c) in cols.iter().enumerate() {
            let mut dot = 0.0f32;
            for j in 0..k {
                dot += a[i * k + j] * b[*c as usize * k + j];
            }
            res[lo + slot] -= dot;
        }
    }
}

fn add_rank_one(csr: &Csr, a: &[f32], b: &[f32], k: usize, t: usize, res: &mut [f32], sign: f32) {
    for i in 0..csr.rows {
        let (cols, _) = csr.row(i);
        let lo = csr.indptr[i];
        let at = a[i * k + t];
        for (slot, c) in cols.iter().enumerate() {
            res[lo + slot] += sign * at * b[*c as usize * k + t];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::metrics::rmse::mean_predictor_rmse;

    #[test]
    fn learns_better_than_mean() {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 55).unwrap();
        let (train_set, test) = holdout_split_covered(&d.ratings, 0.2, 56);
        let model = train(&train_set, &CgdConfig::new(8));
        let rmse = model.rmse(&test);
        let base = mean_predictor_rmse(train_set.mean(), &test);
        assert!(rmse < 0.9 * base, "cgd rmse {rmse} vs mean {base}");
    }

    #[test]
    fn more_outer_iters_fit_train_better() {
        let d = SyntheticDataset::by_name("movielens", 0.001, 57).unwrap();
        let coo = &d.ratings;
        let mut c1 = CgdConfig::new(4);
        c1.outer_iters = 1;
        let mut c6 = CgdConfig::new(4);
        c6.outer_iters = 6;
        assert!(train(coo, &c6).rmse(coo) <= train(coo, &c1).rmse(coo) + 1e-9);
    }

    #[test]
    fn handles_empty_rows_and_cols() {
        let mut coo = Coo::new(6, 6);
        coo.push(0, 0, 2.0);
        coo.push(5, 5, 4.0);
        let model = train(&coo, &CgdConfig::new(3));
        assert!(model.u.iter().chain(model.v.iter()).all(|x| x.is_finite()));
    }
}
