//! Distributed SGLD baseline — the *other* scalable-Bayesian-MF line of
//! work the paper positions against (Ahn et al. 2015 [1]): stochastic
//! gradient Langevin dynamics on minibatches of ratings. Unlike PP it
//! needs a step-size schedule and mixes slowly, but it is a true posterior
//! sampler, so it gives the Bayesian-quality reference point for Table 2
//! style comparisons at much lower cost per update than full Gibbs.

use super::sgd_common::{init_factors, standardization, SgdModel};
use crate::data::sparse::Coo;
use crate::rng::{normal::StdNormal, Rng};

/// SGLD hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct SgldConfig {
    /// Latent dimension.
    pub k: usize,
    /// Initial step size ε₀.
    pub eps0: f64,
    /// Polynomial decay: ε_t = ε₀ (1 + t/t0)^(−κ).
    pub kappa: f64,
    /// Decay offset t0.
    pub t0: f64,
    /// Gaussian prior precision on factors.
    pub prior_prec: f64,
    /// Residual noise precision τ (likelihood weight).
    pub tau: f64,
    /// Passes over the data.
    pub epochs: usize,
    /// Fraction of the chain (from the end) averaged as the posterior mean.
    pub average_frac: f64,
    /// RNG seed.
    pub seed: u64,
}

impl SgldConfig {
    /// Defaults for latent dimension `k`.
    pub fn new(k: usize) -> SgldConfig {
        SgldConfig {
            k,
            eps0: 1e-2,
            kappa: 0.51,
            t0: 1000.0,
            prior_prec: 1.0,
            tau: 4.0,
            epochs: 40,
            average_frac: 0.5,
            seed: 42,
        }
    }

    /// Set the number of passes over the data.
    pub fn with_epochs(mut self, epochs: usize) -> Self {
        self.epochs = epochs;
        self
    }
}

/// Train SGLD; the returned factors are the averaged tail of the chain
/// (posterior-mean estimate).
pub fn train(data: &Coo, cfg: &SgldConfig) -> SgdModel {
    let t0w = std::time::Instant::now();
    let k = cfg.k;
    let (mean, scale) = standardization(data);
    let n_obs = data.nnz().max(1) as f64;
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut norm = StdNormal::new();
    let mut u = init_factors(&mut rng, data.rows, k);
    let mut v = init_factors(&mut rng, data.cols, k);
    let mut u_avg = vec![0.0f64; u.len()];
    let mut v_avg = vec![0.0f64; v.len()];
    let mut avg_count = 0usize;

    let mut order: Vec<usize> = (0..data.nnz()).collect();
    let avg_start = ((cfg.epochs as f64) * (1.0 - cfg.average_frac)) as usize;
    let mut t = 0usize;
    for epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for &idx in &order {
            let e = data.entries[idx];
            let (r, c) = (e.row as usize, e.col as usize);
            let val = (e.val - mean) / scale;
            let eps = cfg.eps0 * (1.0 + t as f64 / cfg.t0).powf(-cfg.kappa);
            let noise_scale = (2.0 * eps).sqrt();
            let ur = r * k;
            let vc = c * k;
            let mut dot = 0.0f32;
            for j in 0..k {
                dot += u[ur + j] * v[vc + j];
            }
            let err = cfg.tau * (val - dot) as f64;
            // stochastic gradient of the log-posterior, minibatch size 1
            // scaled to the full dataset (Welling & Teh 2011)
            for j in 0..k {
                let gu = n_obs * err * v[vc + j] as f64 - cfg.prior_prec * u[ur + j] as f64;
                let gv = n_obs * err * u[ur + j] as f64 - cfg.prior_prec * v[vc + j] as f64;
                // per-coordinate step: eps/n_obs keeps the dataset-scaled
                // gradient O(1) per observation visit
                let step = eps / n_obs;
                u[ur + j] += (step * gu + noise_scale / n_obs.sqrt() * norm.sample(&mut rng))
                    as f32;
                v[vc + j] +=
                    (step * gv + noise_scale / n_obs.sqrt() * norm.sample(&mut rng)) as f32;
            }
            t += 1;
        }
        if epoch >= avg_start {
            for (a, &x) in u_avg.iter_mut().zip(&u) {
                *a += x as f64;
            }
            for (a, &x) in v_avg.iter_mut().zip(&v) {
                *a += x as f64;
            }
            avg_count += 1;
        }
    }
    let (u_out, v_out) = if avg_count > 0 {
        (
            u_avg.iter().map(|&x| (x / avg_count as f64) as f32).collect(),
            v_avg.iter().map(|&x| (x / avg_count as f64) as f32).collect(),
        )
    } else {
        (u, v)
    };
    SgdModel {
        k,
        mean,
        scale,
        u: u_out,
        v: v_out,
        secs: t0w.elapsed().as_secs_f64(),
        epochs_run: cfg.epochs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::metrics::rmse::mean_predictor_rmse;

    #[test]
    fn learns_better_than_mean() {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 61).unwrap();
        let (train_set, test) = holdout_split_covered(&d.ratings, 0.2, 62);
        let model = train(&train_set, &SgldConfig::new(8));
        let rmse = model.rmse(&test);
        let base = mean_predictor_rmse(train_set.mean(), &test);
        assert!(rmse < base, "sgld rmse {rmse} vs mean {base}");
    }

    #[test]
    fn chain_stays_finite() {
        let d = SyntheticDataset::by_name("yahoo", 0.0002, 63).unwrap();
        let model = train(&d.ratings, &SgldConfig::new(4).with_epochs(5));
        assert!(model.u.iter().chain(model.v.iter()).all(|x| x.is_finite()));
    }

    #[test]
    fn averaging_tail_helps_or_matches() {
        let d = SyntheticDataset::by_name("movielens", 0.001, 64).unwrap();
        let (train_set, test) = holdout_split_covered(&d.ratings, 0.2, 65);
        let mut no_avg = SgldConfig::new(4).with_epochs(20);
        no_avg.average_frac = 0.05;
        let mut avg = SgldConfig::new(4).with_epochs(20);
        avg.average_frac = 0.5;
        let r_no = train(&train_set, &no_avg).rmse(&test);
        let r_avg = train(&train_set, &avg).rmse(&test);
        assert!(r_avg < r_no * 1.15, "averaging should not hurt much: {r_avg} vs {r_no}");
    }
}
