//! FPSGD-style blocked multicore SGD (paper §3.2 comparator; Zhuang et
//! al. / LIBMF lineage, cited through [15]).
//!
//! The rating matrix is partitioned into a (2T)×(2T) block grid for T
//! threads. A scheduler hands each idle thread a *free* block — one whose
//! row-range and column-range no running block touches — so threads update
//! disjoint slices of U and V without locks on the factors themselves.
//! Within an epoch every block is processed exactly once.
//!
//! Factor storage uses an `UnsafeCell` wrapper; soundness rests on the
//! scheduler invariant (disjoint row/col ranges of concurrently running
//! blocks), exactly like the original FPSGD implementation.

use super::sgd_common::{init_factors, sgd_update, standardization, SgdConfig, SgdModel};
use crate::data::sparse::{Coo, Entry};
use crate::rng::Rng;
use std::cell::UnsafeCell;
use std::sync::{Condvar, Mutex};

struct FactorStore(UnsafeCell<Vec<f32>>);
// SAFETY: disjoint row-ranges are guaranteed by the block scheduler; two
// threads never touch the same factor rows concurrently.
unsafe impl Sync for FactorStore {}

impl FactorStore {
    fn new(v: Vec<f32>) -> Self {
        FactorStore(UnsafeCell::new(v))
    }
    /// SAFETY: caller must hold a scheduler grant covering these rows.
    #[allow(clippy::mut_from_ref)]
    unsafe fn rows_mut(&self, row: usize, k: usize) -> &mut [f32] {
        let vec = &mut *self.0.get();
        &mut vec[row * k..(row + 1) * k]
    }
    fn into_inner(self) -> Vec<f32> {
        self.0.into_inner()
    }
}

#[derive(Clone)]
struct SchedState {
    row_busy: Vec<bool>,
    col_busy: Vec<bool>,
    /// Per-block: processed in the current epoch?
    done: Vec<bool>,
    remaining: usize,
}

struct Scheduler {
    state: Mutex<SchedState>,
    cv: Condvar,
    grid: usize,
}

impl Scheduler {
    fn new(grid: usize) -> Scheduler {
        Scheduler {
            state: Mutex::new(SchedState {
                row_busy: vec![false; grid],
                col_busy: vec![false; grid],
                done: vec![false; grid * grid],
                remaining: grid * grid,
            }),
            cv: Condvar::new(),
            grid,
        }
    }

    /// Claim a free, not-yet-done block; None when the epoch is finished.
    fn acquire(&self, rng: &mut Rng) -> Option<(usize, usize)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.remaining == 0 {
                return None;
            }
            // randomized scan for a free block (randomization avoids the
            // deterministic update order plain SGD would impose)
            let g = self.grid;
            let offset = rng.below(g * g);
            for t in 0..g * g {
                let idx = (offset + t) % (g * g);
                let (bi, bj) = (idx / g, idx % g);
                if !st.done[idx] && !st.row_busy[bi] && !st.col_busy[bj] {
                    st.done[idx] = true;
                    st.row_busy[bi] = true;
                    st.col_busy[bj] = true;
                    st.remaining -= 1;
                    return Some((bi, bj));
                }
            }
            // nothing free right now — wait for a release
            st = self.cv.wait(st).unwrap();
        }
    }

    fn release(&self, bi: usize, bj: usize) {
        let mut st = self.state.lock().unwrap();
        st.row_busy[bi] = false;
        st.col_busy[bj] = false;
        drop(st);
        self.cv.notify_all();
    }

    fn reset_epoch(&self) {
        let mut st = self.state.lock().unwrap();
        let g = self.grid;
        st.done.iter_mut().for_each(|d| *d = false);
        st.remaining = g * g;
    }
}

/// Train FPSGD on a rating matrix.
pub fn train(data: &Coo, cfg: &SgdConfig) -> SgdModel {
    let t0 = std::time::Instant::now();
    let k = cfg.k;
    let (mean, scale) = standardization(data);
    let threads = cfg.threads.max(1);
    let grid = (2 * threads).min(data.rows).min(data.cols).max(1);

    // bucket standardized entries into the block grid
    let row_of = |r: usize| (r * grid / data.rows).min(grid - 1);
    let col_of = |c: usize| (c * grid / data.cols).min(grid - 1);
    let mut blocks: Vec<Vec<Entry>> = vec![Vec::new(); grid * grid];
    for e in &data.entries {
        let mut e = *e;
        e.val = (e.val - mean) / scale;
        blocks[row_of(e.row as usize) * grid + col_of(e.col as usize)].push(e);
    }
    // shuffle within blocks once (SGD order randomization)
    let mut rng = Rng::seed_from_u64(cfg.seed);
    for b in blocks.iter_mut() {
        rng.shuffle(b);
    }

    let u = FactorStore::new(init_factors(&mut rng, data.rows, k));
    let v = FactorStore::new(init_factors(&mut rng, data.cols, k));
    let sched = Scheduler::new(grid);

    for epoch in 0..cfg.epochs {
        let lr = cfg.lr_at_epoch(epoch);
        sched.reset_epoch();
        crossbeam_utils::thread::scope(|scope| {
            for t in 0..threads {
                let blocks = &blocks;
                let sched = &sched;
                let u = &u;
                let v = &v;
                let mut trng = Rng::seed_from_u64(cfg.seed ^ (epoch as u64) << 16 ^ t as u64);
                scope.spawn(move |_| {
                    while let Some((bi, bj)) = sched.acquire(&mut trng) {
                        for e in &blocks[bi * grid + bj] {
                            // SAFETY: scheduler grants exclusive row/col ranges
                            let (ur, vr) = unsafe {
                                (
                                    u.rows_mut(e.row as usize, k),
                                    v.rows_mut(e.col as usize, k),
                                )
                            };
                            sgd_update(ur, vr, e.val, 0.0, lr, cfg.reg);
                        }
                        sched.release(bi, bj);
                    }
                });
            }
        })
        .expect("fpsgd worker panicked");
    }

    SgdModel {
        k,
        mean,
        scale,
        u: u.into_inner(),
        v: v.into_inner(),
        secs: t0.elapsed().as_secs_f64(),
        epochs_run: cfg.epochs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::metrics::rmse::mean_predictor_rmse;

    fn dataset() -> (Coo, Coo) {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 31).unwrap();
        holdout_split_covered(&d.ratings, 0.2, 32)
    }

    #[test]
    fn learns_better_than_mean() {
        let (train_set, test) = dataset();
        let model = train(&train_set, &SgdConfig::new(8).with_epochs(15).with_seed(33));
        let rmse = model.rmse(&test);
        let base = mean_predictor_rmse(train_set.mean(), &test);
        assert!(rmse < 0.9 * base, "fpsgd rmse {rmse} vs mean {base}");
    }

    #[test]
    fn thread_counts_converge_similarly() {
        let (train_set, test) = dataset();
        let r1 = train(&train_set, &SgdConfig::new(8).with_epochs(10).with_threads(1))
            .rmse(&test);
        let r4 = train(&train_set, &SgdConfig::new(8).with_epochs(10).with_threads(4))
            .rmse(&test);
        assert!((r1 - r4).abs() < 0.12 * r1.max(r4), "1-thread {r1} vs 4-thread {r4}");
    }

    #[test]
    fn handles_tiny_matrices() {
        let mut coo = Coo::new(3, 2);
        coo.push(0, 0, 5.0);
        coo.push(2, 1, 1.0);
        let model = train(&coo, &SgdConfig::new(2).with_epochs(5).with_threads(8));
        assert!(model.rmse(&coo).is_finite());
    }

    #[test]
    fn more_epochs_do_not_hurt() {
        let (train_set, test) = dataset();
        let r5 = train(&train_set, &SgdConfig::new(8).with_epochs(5)).rmse(&test);
        let r25 = train(&train_set, &SgdConfig::new(8).with_epochs(25)).rmse(&test);
        assert!(r25 < r5 * 1.05, "5ep={r5} 25ep={r25}");
    }
}
