//! ALS (Alternating Least Squares) baseline — the third classic MF family
//! the paper's related-work section covers (Koren et al. 2009; Tan et al.
//! 2016). Each half-sweep solves the ridge-regularized normal equations
//! per row exactly; it is the MAP analogue of the Gibbs sampler (same
//! per-row linear systems, no sampling), which makes it a useful
//! convergence reference for the Bayesian path.

use super::sgd_common::{init_factors, standardization, SgdModel};
use crate::data::sparse::{Coo, Csr};
use crate::linalg::{Cholesky, Mat};
use crate::rng::Rng;

/// ALS hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct AlsConfig {
    /// Latent dimension.
    pub k: usize,
    /// Ridge weight λ (per-observation scaling, Zhou et al. 2008 style).
    pub lambda: f64,
    /// Alternating sweeps (each updates both sides).
    pub sweeps: usize,
    /// RNG seed.
    pub seed: u64,
}

impl AlsConfig {
    /// Defaults for latent dimension `k`.
    pub fn new(k: usize) -> AlsConfig {
        AlsConfig { k, lambda: 0.05, sweeps: 12, seed: 42 }
    }

    /// Set the alternating sweep count.
    pub fn with_sweeps(mut self, sweeps: usize) -> Self {
        self.sweeps = sweeps;
        self
    }
}

/// Solve one side's normal equations: for each row i,
/// (Σ v_d v_dᵀ + λ·nnz_i·I) u_i = Σ r_id v_d.
fn solve_side(csr: &Csr, v: &[f32], k: usize, lambda: f64, out: &mut [f32]) {
    let mut a = Mat::zeros(k, k);
    let mut rhs = vec![0.0f64; k];
    for i in 0..csr.rows {
        let (cols, vals) = csr.row(i);
        if cols.is_empty() {
            out[i * k..(i + 1) * k].iter_mut().for_each(|x| *x = 0.0);
            continue;
        }
        a.data.iter_mut().for_each(|x| *x = 0.0);
        rhs.iter_mut().for_each(|x| *x = 0.0);
        for (c, r) in cols.iter().zip(vals) {
            let vd = &v[*c as usize * k..(*c as usize + 1) * k];
            for p in 0..k {
                let vp = vd[p] as f64;
                for q in p..k {
                    a[(p, q)] += vp * vd[q] as f64;
                }
                rhs[p] += (*r as f64) * vp;
            }
        }
        for p in 1..k {
            for q in 0..p {
                a[(p, q)] = a[(q, p)];
            }
        }
        let ridge = lambda * cols.len() as f64 + 1e-9;
        for d in 0..k {
            a[(d, d)] += ridge;
        }
        let x = Cholesky::new(&a).expect("ALS normal equations SPD").solve(&rhs);
        for d in 0..k {
            out[i * k + d] = x[d] as f32;
        }
    }
}

/// Train ALS.
pub fn train(data: &Coo, cfg: &AlsConfig) -> SgdModel {
    let t0 = std::time::Instant::now();
    let k = cfg.k;
    let (mean, scale) = standardization(data);
    let mut std_data = data.clone();
    for e in std_data.entries.iter_mut() {
        e.val = (e.val - mean) / scale;
    }
    let rows = Csr::from_coo(&std_data);
    let cols = rows.transpose();
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut u = init_factors(&mut rng, data.rows, k);
    let mut v = init_factors(&mut rng, data.cols, k);
    for _ in 0..cfg.sweeps {
        solve_side(&rows, &v, k, cfg.lambda, &mut u);
        solve_side(&cols, &u, k, cfg.lambda, &mut v);
    }
    SgdModel {
        k,
        mean,
        scale,
        u,
        v,
        secs: t0.elapsed().as_secs_f64(),
        epochs_run: cfg.sweeps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::metrics::rmse::mean_predictor_rmse;

    #[test]
    fn learns_better_than_mean() {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 51).unwrap();
        let (train_set, test) = holdout_split_covered(&d.ratings, 0.2, 52);
        let model = train(&train_set, &AlsConfig::new(8));
        let rmse = model.rmse(&test);
        let base = mean_predictor_rmse(train_set.mean(), &test);
        assert!(rmse < 0.9 * base, "als rmse {rmse} vs mean {base}");
    }

    #[test]
    fn exact_solve_on_noiseless_rank1() {
        // rank-1 noiseless matrix: ALS recovers it to ~exactly
        let (n, d) = (20, 15);
        let mut coo = Coo::new(n, d);
        for r in 0..n {
            for c in 0..d {
                if (r + c) % 2 == 0 {
                    coo.push(r, c, ((r + 1) as f32) * 0.2 * ((c + 1) as f32) * 0.1);
                }
            }
        }
        let model = train(&coo, &AlsConfig { k: 2, lambda: 1e-6, sweeps: 30, seed: 1 });
        assert!(model.rmse(&coo) < 0.02, "rank-1 fit rmse {}", model.rmse(&coo));
    }

    #[test]
    fn empty_rows_stay_finite() {
        let mut coo = Coo::new(5, 4);
        coo.push(0, 0, 3.0); // rows 1..4 empty
        let model = train(&coo, &AlsConfig::new(3));
        assert!(model.u.iter().all(|x| x.is_finite()));
        assert!(model.v.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn objective_decreases_across_sweeps() {
        let d = SyntheticDataset::by_name("movielens", 0.001, 53).unwrap();
        let coo = &d.ratings;
        let r1 = train(coo, &AlsConfig::new(4).with_sweeps(1)).rmse(coo);
        let r8 = train(coo, &AlsConfig::new(4).with_sweeps(8)).rmse(coo);
        assert!(r8 <= r1 + 1e-9, "train rmse went up: {r1} -> {r8}");
    }
}
