//! NOMAD-style asynchronous, decentralized SGD (paper §3.2 comparator;
//! Yun et al. 2014 [19]).
//!
//! Rows of U are partitioned statically across threads. The columns of V
//! circulate: each item's factor vector travels inside a *token* through
//! the threads' queues; whoever holds the token updates that item against
//! the ratings its own row partition has for the item, then forwards the
//! token. No factor state is shared — ownership transfer replaces locking
//! (rust's move semantics make the NOMAD invariant structural).

use super::sgd_common::{init_factors, sgd_update, SgdConfig, SgdModel};
use crate::coordinator::worker::shard_bounds;
use crate::data::sparse::{Coo, Csr};
use crate::rng::Rng;
use std::sync::mpsc::{channel, Receiver, Sender};

/// A circulating item: its column id, factor vector and remaining hops.
struct Token {
    col: usize,
    vcol: Vec<f32>,
    hops: usize,
}

enum Msg {
    Item(Token),
    Shutdown,
}

/// Train NOMAD on a rating matrix.
pub fn train(data: &Coo, cfg: &SgdConfig) -> SgdModel {
    let t0 = std::time::Instant::now();
    let k = cfg.k;
    let (mean, scale) = super::sgd_common::standardization(data);
    let threads = cfg.threads.max(1).min(data.rows.max(1));
    let bounds = shard_bounds(data.rows, threads);
    let mut rng = Rng::seed_from_u64(cfg.seed);

    // standardize (see sgd_common::standardization), then per-thread CSC
    // view of each row partition: [t] -> csr over columns
    let mut std_data = data.clone();
    for e in std_data.entries.iter_mut() {
        e.val = (e.val - mean) / scale;
    }
    let csr = Csr::from_coo(&std_data);
    let col_views: Vec<Csr> = bounds
        .iter()
        .map(|&(a, b)| csr.slice_rows(a, b).transpose())
        .collect();

    let u_full = init_factors(&mut rng, data.rows, k);
    let v_init = init_factors(&mut rng, data.cols, k);

    // channels: one queue per thread
    let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(threads);
    let mut receivers: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(threads);
    for _ in 0..threads {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(Some(rx));
    }

    // seed tokens round-robin; each token makes epochs*threads hops so every
    // thread sees every item `epochs` times
    let total_hops = cfg.epochs * threads;
    for (col, chunk) in v_init.chunks(k).enumerate() {
        let target = col % threads;
        senders[target]
            .send(Msg::Item(Token { col, vcol: chunk.to_vec(), hops: total_hops }))
            .unwrap();
    }

    // result collection: final v columns + per-thread u shards
    let (done_tx, done_rx) = channel::<Token>();
    let mut u_shards: Vec<Vec<f32>> = bounds
        .iter()
        .map(|&(a, b)| u_full[a * k..b * k].to_vec())
        .collect();

    crossbeam_utils::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (t, (rx, mut u_shard)) in receivers
            .iter_mut()
            .map(|r| r.take().unwrap())
            .zip(u_shards.drain(..))
            .enumerate()
        {
            let senders = senders.clone();
            let done_tx = done_tx.clone();
            let col_view = &col_views[t];
            let epochs = cfg.epochs;
            let (lr0, decay, reg) = (cfg.lr, cfg.decay, cfg.reg);
            handles.push(scope.spawn(move |_| {
                let next = (t + 1) % senders.len();
                let mut finished = 0usize;
                let n_cols = col_view.rows;
                let _ = n_cols;
                for msg in rx.iter() {
                    match msg {
                        Msg::Shutdown => break,
                        Msg::Item(mut tok) => {
                            // lr follows the token's epoch (completed rounds)
                            let epoch = epochs - tok.hops.div_ceil(senders.len()).max(1);
                            let lr = lr0 * decay.powi(epoch as i32);
                            let (rows, vals) = col_view.row(tok.col);
                            let kk = tok.vcol.len();
                            for (r, val) in rows.iter().zip(vals) {
                                let ur = &mut u_shard[*r as usize * kk..(*r as usize + 1) * kk];
                                sgd_update(ur, &mut tok.vcol, *val, 0.0, lr, reg);
                            }
                            tok.hops -= 1;
                            if tok.hops == 0 {
                                done_tx.send(tok).unwrap();
                                finished += 1;
                                let _ = finished;
                            } else {
                                senders[next].send(Msg::Item(tok)).unwrap();
                            }
                        }
                    }
                }
                u_shard
            }));
        }
        drop(done_tx);

        // leader: wait for all tokens to retire, then shut workers down
        let mut v_final = v_init.clone();
        let mut retired = 0usize;
        let n_cols = data.cols;
        while retired < n_cols {
            match done_rx.recv() {
                Ok(tok) => {
                    v_final[tok.col * k..(tok.col + 1) * k].copy_from_slice(&tok.vcol);
                    retired += 1;
                }
                Err(_) => break,
            }
        }
        for s in &senders {
            let _ = s.send(Msg::Shutdown);
        }
        let mut u_out = vec![0.0f32; data.rows * k];
        for (h, &(a, b)) in handles.into_iter().zip(&bounds) {
            let shard = h.join().expect("nomad worker panicked");
            u_out[a * k..b * k].copy_from_slice(&shard);
        }
        (u_out, v_final)
    })
    .map(|(u, v)| SgdModel {
        k,
        mean,
        scale,
        u,
        v,
        secs: t0.elapsed().as_secs_f64(),
        epochs_run: cfg.epochs,
    })
    .expect("nomad scope panicked")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::metrics::rmse::mean_predictor_rmse;

    fn dataset() -> (Coo, Coo) {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 41).unwrap();
        holdout_split_covered(&d.ratings, 0.2, 42)
    }

    #[test]
    fn learns_better_than_mean() {
        let (train_set, test) = dataset();
        let model = train(&train_set, &SgdConfig::new(8).with_epochs(15).with_seed(43));
        let rmse = model.rmse(&test);
        let base = mean_predictor_rmse(train_set.mean(), &test);
        assert!(rmse < 0.9 * base, "nomad rmse {rmse} vs mean {base}");
    }

    #[test]
    fn single_thread_matches_multithread_quality() {
        let (train_set, test) = dataset();
        let r1 =
            train(&train_set, &SgdConfig::new(8).with_epochs(10).with_threads(1)).rmse(&test);
        let r4 =
            train(&train_set, &SgdConfig::new(8).with_epochs(10).with_threads(4)).rmse(&test);
        assert!((r1 - r4).abs() < 0.12 * r1.max(r4), "1t {r1} vs 4t {r4}");
    }

    #[test]
    fn every_column_retires() {
        // a matrix with empty columns still terminates (tokens circulate
        // without updates and retire)
        let mut coo = Coo::new(10, 6);
        coo.push(0, 0, 3.0);
        coo.push(9, 5, 4.0);
        let model = train(&coo, &SgdConfig::new(4).with_epochs(3).with_threads(3));
        assert_eq!(model.v.len(), 6 * 4);
        assert!(model.u.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn more_threads_than_rows_is_safe() {
        let mut coo = Coo::new(2, 3);
        coo.push(0, 0, 1.0);
        coo.push(1, 2, 2.0);
        let model = train(&coo, &SgdConfig::new(2).with_epochs(2).with_threads(16));
        assert!(model.rmse(&coo).is_finite());
    }
}
