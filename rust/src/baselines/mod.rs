//! Comparator matrix-factorization methods (paper §3.2 and related work):
//! FPSGD-style blocked multicore SGD, NOMAD-style asynchronous SGD, ALS,
//! CCD++-style coordinate descent, and distributed-SGLD (the other
//! scalable-Bayesian line of work, Ahn et al. 2015) — all in rust on the
//! same data structures.

pub mod als;
pub mod cgd;
pub mod fpsgd;
pub mod nomad;
pub mod sgd_common;
pub mod sgld;
