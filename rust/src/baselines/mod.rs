//! Comparator matrix-factorization methods (paper §3.2 and related work):
//! FPSGD-style blocked multicore SGD, NOMAD-style asynchronous SGD, ALS,
//! CCD++-style coordinate descent, and distributed-SGLD (the other
//! scalable-Bayesian line of work, Ahn et al. 2015) — all in rust on the
//! same data structures.
//!
//! Every method is also exposed as a [`Factorizer`], so PP and the
//! baselines share one `fit(&Engine, &Coo) -> FitOutcome` entry point and
//! comparing methods (or cross-validating one) is a loop over fits on a
//! single warm engine. The SGD-family baselines manage their own
//! intra-method threading; the engine parameter keeps the interface
//! uniform and hands PP its warm pool.

pub mod als;
pub mod cgd;
pub mod fpsgd;
pub mod nomad;
pub mod sgd_common;
pub mod sgld;

use crate::coordinator::engine::{Engine, Factorizer, FitOutcome};
use crate::data::sparse::Coo;
use crate::gibbs::NativeGibbs;
use crate::posterior::PosteriorModel;
use als::AlsConfig;
use cgd::CgdConfig;
use sgd_common::{SgdConfig, SgdModel};
use sgld::SgldConfig;

fn outcome(method: &str, model: PosteriorModel, secs: f64) -> FitOutcome {
    FitOutcome { method: method.to_string(), model, secs, pp_stats: None }
}

fn sgd_outcome(method: &str, t0: std::time::Instant, model: SgdModel) -> FitOutcome {
    outcome(method, model.to_posterior(), t0.elapsed().as_secs_f64())
}

/// NOMAD-style asynchronous SGD as a [`Factorizer`].
pub struct Nomad(pub SgdConfig);

impl Factorizer for Nomad {
    fn name(&self) -> &str {
        "nomad"
    }

    fn fit(&self, _engine: &Engine, data: &Coo) -> anyhow::Result<FitOutcome> {
        let t0 = std::time::Instant::now();
        Ok(sgd_outcome("nomad", t0, nomad::train(data, &self.0)))
    }
}

/// FPSGD-style blocked multicore SGD as a [`Factorizer`].
pub struct Fpsgd(pub SgdConfig);

impl Factorizer for Fpsgd {
    fn name(&self) -> &str {
        "fpsgd"
    }

    fn fit(&self, _engine: &Engine, data: &Coo) -> anyhow::Result<FitOutcome> {
        let t0 = std::time::Instant::now();
        Ok(sgd_outcome("fpsgd", t0, fpsgd::train(data, &self.0)))
    }
}

/// SGLD (stochastic gradient Langevin dynamics) as a [`Factorizer`].
pub struct Sgld(pub SgldConfig);

impl Factorizer for Sgld {
    fn name(&self) -> &str {
        "sgld"
    }

    fn fit(&self, _engine: &Engine, data: &Coo) -> anyhow::Result<FitOutcome> {
        let t0 = std::time::Instant::now();
        Ok(sgd_outcome("sgld", t0, sgld::train(data, &self.0)))
    }
}

/// ALS (alternating least squares) as a [`Factorizer`].
pub struct Als(pub AlsConfig);

impl Factorizer for Als {
    fn name(&self) -> &str {
        "als"
    }

    fn fit(&self, _engine: &Engine, data: &Coo) -> anyhow::Result<FitOutcome> {
        let t0 = std::time::Instant::now();
        Ok(sgd_outcome("als", t0, als::train(data, &self.0)))
    }
}

/// CCD++-style coordinate descent as a [`Factorizer`].
pub struct Cgd(pub CgdConfig);

impl Factorizer for Cgd {
    fn name(&self) -> &str {
        "cgd"
    }

    fn fit(&self, _engine: &Engine, data: &Coo) -> anyhow::Result<FitOutcome> {
        let t0 = std::time::Instant::now();
        Ok(sgd_outcome("cgd", t0, cgd::train(data, &self.0)))
    }
}

/// Plain (unblocked) BPMF Gibbs — the paper's "BMF" column — as a
/// [`Factorizer`]. The chain's final factor state is the point estimate.
pub struct PlainBmf {
    /// Latent dimension.
    pub k: usize,
    /// Residual noise precision.
    pub tau: f64,
    /// Gibbs sweeps to run.
    pub sweeps: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Factorizer for PlainBmf {
    fn name(&self) -> &str {
        "bmf"
    }

    fn fit(&self, _engine: &Engine, data: &Coo) -> anyhow::Result<FitOutcome> {
        let t0 = std::time::Instant::now();
        let mut g = NativeGibbs::new(data, self.k, self.tau, self.seed);
        for _ in 0..self.sweeps {
            g.sweep();
        }
        let model = PosteriorModel::from_factors(self.k, &g.u, &g.v, g.global_mean, 1e6);
        Ok(outcome("bmf", model, t0.elapsed().as_secs_f64()))
    }
}

/// Common knobs the CLI maps onto per-method configs.
pub struct BaselineOpts {
    /// Latent dimension.
    pub k: usize,
    /// SGD-family passes over the data.
    pub epochs: usize,
    /// Intra-method worker threads.
    pub threads: usize,
    /// MCMC sweeps (bmf / sgld / als / cgd iterations).
    pub sweeps: usize,
    /// RNG seed.
    pub seed: u64,
    /// Residual noise precision for the Bayesian methods.
    pub tau: f64,
}

/// The method names [`factorizer`] accepts, for up-front CLI validation.
pub const METHODS: [&str; 6] = ["bmf", "nomad", "fpsgd", "sgld", "als", "cgd"];

/// Look up a baseline [`Factorizer`] by CLI name.
pub fn factorizer(method: &str, o: &BaselineOpts) -> Option<Box<dyn Factorizer>> {
    match method {
        "bmf" => Some(Box::new(PlainBmf { k: o.k, tau: o.tau, sweeps: o.sweeps, seed: o.seed })),
        "nomad" => Some(Box::new(Nomad(
            SgdConfig::new(o.k).with_epochs(o.epochs).with_threads(o.threads).with_seed(o.seed),
        ))),
        "fpsgd" => Some(Box::new(Fpsgd(
            SgdConfig::new(o.k).with_epochs(o.epochs).with_threads(o.threads).with_seed(o.seed),
        ))),
        "sgld" => Some(Box::new(Sgld(SgldConfig {
            seed: o.seed,
            ..SgldConfig::new(o.k).with_epochs(o.epochs)
        }))),
        "als" => Some(Box::new(Als(AlsConfig {
            seed: o.seed,
            ..AlsConfig::new(o.k).with_sweeps(o.sweeps)
        }))),
        "cgd" => Some(Box::new(Cgd(CgdConfig { seed: o.seed, ..CgdConfig::new(o.k) }))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendSpec, PpFactorizer, TrainConfig};
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::metrics::rmse::mean_predictor_rmse;

    #[test]
    fn every_factorizer_beats_the_mean_predictor_on_one_engine() {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 51).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 52);
        let base = mean_predictor_rmse(train.mean(), &test);
        let engine = Engine::new(&BackendSpec::Native, 4);
        let opts =
            BaselineOpts { k: d.k, epochs: 40, threads: 2, sweeps: 16, seed: 53, tau: 2.0 };
        let mut fits: Vec<Box<dyn Factorizer>> = vec![Box::new(PpFactorizer(
            TrainConfig::new(d.k)
                .with_grid(2, 2)
                .with_sweeps(6, 12)
                .with_backend(BackendSpec::Native)
                .with_seed(53),
        ))];
        for m in ["bmf", "nomad", "fpsgd", "sgld", "als", "cgd"] {
            fits.push(factorizer(m, &opts).unwrap());
        }
        for f in &fits {
            let out = f.fit(&engine, &train).unwrap();
            let rmse = out.model.rmse(&test);
            assert!(rmse < base, "{}: rmse {rmse} vs mean predictor {base}", f.name());
            assert_eq!(out.method, f.name());
        }
    }

    #[test]
    fn unknown_method_is_none() {
        let o = BaselineOpts { k: 4, epochs: 1, threads: 1, sweeps: 1, seed: 1, tau: 1.0 };
        assert!(factorizer("laplace", &o).is_none());
        // the advertised method list and the lookup table agree
        for m in METHODS {
            assert!(factorizer(m, &o).is_some(), "{m}");
        }
    }

    #[test]
    fn sgd_model_posterior_matches_its_predictions() {
        let d = SyntheticDataset::by_name("movielens", 0.001, 54).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 55);
        let m = fpsgd::train(&train, &SgdConfig::new(d.k).with_epochs(10).with_seed(56));
        let p = m.to_posterior();
        // the scale fold-in reproduces SgdModel::predict to f32 rounding
        for (r, c) in [(0usize, 0usize), (3, 5), (10, 1)] {
            assert!((m.predict(r, c) - p.predict(r, c)).abs() < 1e-3);
        }
        assert!((m.rmse(&test) - p.rmse(&test)).abs() < 1e-3);
    }
}
