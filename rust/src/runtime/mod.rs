//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Thread model: `PjRtClient` in the `xla` crate is `Rc`-based and NOT
//! `Send`, so an [`Engine`] is **thread-confined** — each coordinator
//! worker thread constructs its own Engine (compilation is per-thread,
//! one-time). XLA's CPU backend parallelizes internally, so even a single
//! Engine uses multiple cores for large blocks.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactSpec, Manifest};
pub use executor::Engine;
