//! Artifact manifest: the registry of AOT-compiled HLO programs.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing each
//! lowered program (kind, block shape, latent dim, flavor). Shapes are
//! compile-time constants of the HLO; the runtime picks, for each real
//! block, the smallest registered shape that fits and zero-pads (masked
//! padding is exact, not approximate).

use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};

/// One AOT artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    /// Unique artifact name (the HLO file stem).
    pub name: String,
    /// "sample_side" or "predict_sse".
    pub kind: String,
    /// Padded row capacity.
    pub n: usize,
    /// Padded column capacity.
    pub d: usize,
    /// Latent dimension the artifact was lowered for.
    pub k: usize,
    /// HLO text file name inside the artifact directory.
    pub file: String,
    /// "pallas" or "ref" — which L1 implementation was lowered in.
    pub flavor: String,
}

/// Why the artifact registry could not be loaded or queried.
#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    /// The manifest file could not be read.
    #[error("io error reading {path}: {err}")]
    Io {
        /// Path that failed to read.
        path: String,
        /// Underlying IO error.
        err: std::io::Error,
    },
    /// The manifest JSON was malformed.
    #[error("manifest parse error: {0}")]
    Parse(String),
    /// No registered artifact shape covers the requested block.
    #[error("no registered {kind} artifact fits n={n} d={d} k={k}")]
    NoFit {
        /// Artifact kind requested.
        kind: String,
        /// Required row capacity.
        n: usize,
        /// Required column capacity.
        d: usize,
        /// Required latent dimension.
        k: usize,
    },
}

/// The parsed artifact registry.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the artifacts live in.
    pub dir: PathBuf,
    /// Registered artifact entries.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|err| ManifestError::Io { path: path.display().to_string(), err })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON text rooted at `dir`.
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, ManifestError> {
        let root = json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| ManifestError::Parse("missing 'artifacts' array".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let get_str = |k: &str| {
                a.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| ManifestError::Parse(format!("missing field '{k}'")))
            };
            let get_num = |k: &str| {
                a.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| ManifestError::Parse(format!("missing field '{k}'")))
            };
            artifacts.push(ArtifactSpec {
                name: get_str("name")?,
                kind: get_str("kind")?,
                n: get_num("n")?,
                d: get_num("d")?,
                k: get_num("k")?,
                file: get_str("file")?,
                flavor: get_str("flavor")?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Path of an artifact's HLO text file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// The smallest registered artifact of `kind` with matching k that fits
    /// an (n, d) block — "smallest" by padded area (wasted compute).
    pub fn best_fit(
        &self,
        kind: &str,
        n: usize,
        d: usize,
        k: usize,
    ) -> Result<&ArtifactSpec, ManifestError> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.k == k && a.n >= n && a.d >= d)
            .min_by_key(|a| a.n * a.d)
            .ok_or_else(|| ManifestError::NoFit { kind: kind.into(), n, d, k })
    }

    /// All latent dims available for a kind.
    pub fn available_ks(&self, kind: &str) -> Vec<usize> {
        let mut ks: Vec<usize> =
            self.artifacts.iter().filter(|a| a.kind == kind).map(|a| a.k).collect();
        ks.sort_unstable();
        ks.dedup();
        ks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "sample_side_32x32x8", "kind": "sample_side", "n": 32, "d": 32, "k": 8,
         "file": "sample_side_32x32x8.hlo.txt", "flavor": "pallas"},
        {"name": "sample_side_256x256x8", "kind": "sample_side", "n": 256, "d": 256, "k": 8,
         "file": "sample_side_256x256x8.hlo.txt", "flavor": "pallas"},
        {"name": "predict_sse_32x32x8", "kind": "predict_sse", "n": 32, "d": 32, "k": 8,
         "file": "predict_sse_32x32x8.hlo.txt", "flavor": "ref"}
      ]
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[0].k, 8);
        assert_eq!(m.available_ks("sample_side"), vec![8]);
    }

    #[test]
    fn best_fit_prefers_smallest() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let a = m.best_fit("sample_side", 20, 30, 8).unwrap();
        assert_eq!(a.n, 32);
        let b = m.best_fit("sample_side", 33, 20, 8).unwrap();
        assert_eq!(b.n, 256);
    }

    #[test]
    fn best_fit_errors_when_nothing_fits() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert!(m.best_fit("sample_side", 1000, 1000, 8).is_err());
        assert!(m.best_fit("sample_side", 10, 10, 99).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), r#"{"artifacts":[{"name":1}]}"#).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // integration-ish: only runs when `make artifacts` has been run
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert!(m.path_of(a).exists(), "missing {}", a.file);
            }
        }
    }
}
