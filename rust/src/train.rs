//! Training: engines, sessions, configs, and checkpoints — the
//! `bmf_pp::train` facade.
//!
//! Everything needed to *produce* a model lives here:
//!
//! - [`Engine`] owns the warm worker pool; [`Engine::submit`] runs any
//!   number of prioritized jobs concurrently and returns a [`Session`]
//!   streaming typed [`TrainEvent`]s.
//! - [`TrainConfig`] is the builder-style run description (grid, sweeps,
//!   backend, checkpointing, admission priority).
//! - [`TrainOutcome`] / [`TrainResult`] report how a run ended and carry
//!   the servable [`PosteriorModel`].
//! - [`checkpoint`] persists models (v1/v2 files) and partial run state
//!   (v3 generation files) — the handoff point to the serving side,
//!   which watches a generation directory and hot-swaps
//!   (see [`crate::serve`]).
//! - The out-of-core storage layer ([`ingest`], [`ShardStore`],
//!   [`StoreError`]) feeds [`Engine::submit_store`] /
//!   [`Engine::train_store`]: blocks stream from per-block shard files
//!   through a `TrainConfig::cache_bytes`-budgeted cache, producing a
//!   posterior bitwise-identical to the resident run.
//! - Incremental updates ([`RatingDelta`], [`append_delta`],
//!   [`Engine::update`] / [`Engine::update_store`]) re-sample only the
//!   blocks a batch of new ratings touches, passing clean posteriors
//!   through unchanged — the serve → collect → retrain → hot-swap loop
//!   (full story in [`crate::online`]).
//!
//! This module re-exports the coordinator layer; the deep
//! `bmf_pp::coordinator::*` paths keep working for existing code.

pub use crate::coordinator::checkpoint;
pub use crate::online::{append_delta, AppendReport, RatingDelta, UpdateError, UpdateWarning};
pub use crate::coordinator::{
    AdmissionPolicy, BackendSpec, CancelInfo, ConfigError, Engine, FactorSide, Factorizer,
    FailInfo, FitOutcome, JobId, JobSnapshot, JobStatus, PpFactorizer, PpPhase, Priority,
    SchedulerMode, Session, SubmitError, SweepMode, TrainConfig, TrainEvent, TrainOutcome,
    TrainResult,
};
pub use crate::posterior::PosteriorModel;
pub use crate::store::{ingest, IngestReport, ShardStore, StoreError};
