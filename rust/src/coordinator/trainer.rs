//! The top-level D-BMF+PP training pipeline.
//!
//! Phases (a) → (b) → (c) → aggregation are expressed as one dependency
//! DAG over block tasks: phase-(b) block (i,0) depends only on (0,0);
//! phase-(c) block (i,j) depends only on the row posterior from (i,0) and
//! the column posterior from (0,j); each aggregated posterior part depends
//! only on the blocks that feed it. Under [`SchedulerMode::Dag`] every
//! node is dispatched the moment its parents complete, so no phase waits
//! for the slowest straggler of the previous one. [`SchedulerMode::Barrier`]
//! adds edges from every phase-(b) block to every phase-(c) block (and
//! from all blocks to aggregation), reproducing the classic phase-barrier
//! schedule through the same machinery — both modes run the identical
//! per-block math with identical seeds and produce bitwise-identical
//! posteriors.
//!
//! The pipeline itself is [`run_pp`], invoked through
//! [`crate::coordinator::Engine`]; as it executes it streams typed
//! [`TrainEvent`]s to an optional sink and honours the session's run
//! control: a set cancel flag stops dispatching block tasks, drains
//! the ones in flight, optionally persists every completed block posterior
//! as a partial (v3) checkpoint (`TrainConfig::checkpoint_on_cancel`), and
//! yields [`TrainOutcome::Cancelled`]. A later run with
//! `TrainConfig::resume_from` restores those blocks instead of re-sampling
//! them; because per-block seeds derive from the config seed and
//! aggregation consumes inputs in canonical order, the resumed posterior
//! is bitwise-identical to an uninterrupted run over the same
//! completed-block set.
//!
//! **Crash tolerance.** `TrainConfig::{checkpoint_every, checkpoint_dir}`
//! arm *periodic* checkpointing: after every N newly completed blocks the
//! run persists all completed block posteriors as an atomically-renamed,
//! monotonically numbered generation file
//! ([`checkpoint::generation_path`]), pruned to the newest
//! `checkpoint_keep` generations — so a hard crash (`SIGKILL`, node loss)
//! costs at most the blocks finished since the last generation, and
//! `resume_from` pointed at the *directory* restores the newest valid
//! generation. A block task that errors or panics fails **its job only**:
//! dispatch stops, in-flight siblings drain, a final abort checkpoint is
//! written, and the run yields [`TrainOutcome::Failed`] with a typed
//! [`FailInfo`] — the shared pool and every other tenant keep running.

use super::aggregate::aggregate_part;
use super::backend::{BlockBackend, BlockData};
use super::block_task::{
    run_block, BlockObs, BlockPosteriors, BlockRunStats, BlockTaskCfg, PpTaskOutput,
};
use super::checkpoint::{self, PartialBlock, PartialCheckpoint};
use super::config::{SchedulerMode, TrainConfig};
use super::engine::{EventSink, FactorSide, PpPhase, TrainEvent};
use super::scheduler::{DagRunOpts, DagScheduler, JobId, NodeId, WorkerPool};
use crate::data::sparse::Coo;
use crate::partition::Grid;
use crate::posterior::{PosteriorModel, RowGaussians};
use crate::store::{Prefetcher, ShardCache, ShardCounters, ShardLoad, ShardStore, StoreError};
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Wall-clock seconds per PP phase, attributed from per-block completion
/// times: a phase's time is the gap between its last block finishing and
/// the previous phase's last block finishing (zero-clamped).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTimings {
    /// Seconds until the phase-(a) block finished.
    pub a: f64,
    /// Seconds between the last phase-(a) and last phase-(b) completion.
    pub b: f64,
    /// Seconds between the last phase-(b) and last phase-(c) completion.
    pub c: f64,
    /// Seconds between the last block and the last aggregation part.
    pub aggregate: f64,
    /// Wall-clock seconds of the whole run.
    pub total: f64,
}

/// Aggregate compute counters over all blocks.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunStats {
    /// Blocks sampled (excludes blocks restored from a resume checkpoint).
    pub blocks: usize,
    /// Blocks restored from a `resume_from` partial checkpoint instead of
    /// being re-sampled. 0 for non-resumed runs.
    pub blocks_restored: usize,
    /// Blocks an incremental update passed through unchanged because no
    /// delta entry touched them (see `Engine::update`): their
    /// checkpointed posteriors fed aggregation as-is. 0 outside update
    /// runs; `blocks` then counts exactly the dirty blocks re-sampled.
    pub blocks_skipped_clean: usize,
    /// Total Gibbs sweeps across all blocks.
    pub sweeps: usize,
    /// Factor rows sampled across all blocks and sweeps.
    pub rows_processed: u64,
    /// Rating observations visited across all blocks and sweeps.
    pub ratings_processed: u64,
    /// Sum of per-block compute seconds (≥ wall-clock when parallel).
    pub compute_secs: f64,
    /// Worker-slot seconds spent waiting during the schedule (pool slots ×
    /// schedule span − busy seconds): the straggler cost a barrier
    /// schedule pays and the DAG schedule shrinks.
    pub idle_secs: f64,
    /// Phase-(c) compute seconds that ran before the last phase-(b) block
    /// finished — positive only under the dependency-driven scheduler.
    pub overlap_secs: f64,
    /// Within-block compute/communication overlap summed over all blocks:
    /// V-half-sweep compute seconds that ran while the U half-sweep was
    /// still sampling/publishing. Positive only under
    /// [`SweepMode::Pipelined`](super::config::SweepMode::Pipelined) —
    /// lockstep sweeps serialize exchange after compute by definition.
    pub comm_overlap_secs: f64,
    /// Seconds between the admitted run starting to schedule (config
    /// validated, data prepared, DAG about to dispatch) and its first
    /// task executing on a pool worker — the fairness signal for
    /// multi-tenant scheduling: compare it across
    /// [`Priority`](super::Priority) levels to see who actually waited
    /// behind whom. Setup cost (resume-checkpoint loading, data centring)
    /// is deliberately excluded — this measures waiting, not preparing.
    pub queue_wait_secs: f64,
    /// Shard-cache hits: block fetches served from memory in a
    /// store-backed run (see [`crate::store::ShardCache`] for exact
    /// semantics). 0 for resident runs.
    pub shard_hits: u64,
    /// Shard-cache misses: block fetches that read their shard from disk
    /// on the task's own time. 0 for resident runs.
    pub shard_misses: u64,
    /// Hits whose shard was resident because the DAG-fed prefetcher
    /// warmed it (counted once per prefetched load). 0 for resident runs.
    pub shard_prefetch_hits: u64,
    /// Shards evicted to respect `TrainConfig::cache_bytes`. 0 for
    /// resident or unbounded runs.
    pub shard_evictions: u64,
    /// High-water mark of resident shard bytes (accounted at on-disk
    /// size) — the proof the working set stayed bounded. 0 for resident
    /// runs.
    pub shard_bytes_peak: u64,
}

impl RunStats {
    fn absorb(&mut self, s: &BlockRunStats) {
        self.blocks += 1;
        self.sweeps += s.sweeps;
        self.rows_processed += s.rows_processed;
        self.ratings_processed += s.ratings_processed;
        self.compute_secs += s.secs;
        self.comm_overlap_secs += s.comm_overlap_secs;
    }
}

/// Outcome of one training run: the servable [`PosteriorModel`] plus the
/// run's diagnostics (phase timings, scheduling stats, grid used).
///
/// Derefs to the model, so prediction/evaluation calls (`predict`, `rmse`,
/// `predict_variance`, `top_n`, field access like `u_post`) go straight
/// through; persist or serve `result.model` alone.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// The servable artifact — the only part a checkpoint stores.
    pub model: PosteriorModel,
    /// Block grid the run used.
    pub grid: (usize, usize),
    /// Wall-clock seconds attributed to each PP phase.
    pub timings: PhaseTimings,
    /// Aggregate compute and scheduling counters.
    pub stats: RunStats,
}

impl std::ops::Deref for TrainResult {
    type Target = PosteriorModel;

    fn deref(&self) -> &PosteriorModel {
        &self.model
    }
}

impl TrainResult {
    /// Extract the servable model, discarding run diagnostics.
    pub fn into_model(self) -> PosteriorModel {
        self.model
    }
}

/// What happened to a cancelled run.
#[derive(Debug, Clone)]
pub struct CancelInfo {
    /// Blocks whose posteriors were completed (sampled or restored) when
    /// the cancellation took effect.
    pub blocks_completed: usize,
    /// Where the partial (v3) checkpoint of those posteriors was written —
    /// the newest generation in `TrainConfig::checkpoint_dir` when
    /// periodic checkpointing is armed, else the
    /// `TrainConfig::checkpoint_on_cancel` file. `None` when neither is
    /// armed or no block had completed.
    pub checkpoint: Option<PathBuf>,
}

/// What happened to a failed run: a block task errored or panicked, the
/// job stopped dispatching, drained its in-flight siblings, and (when any
/// checkpoint destination was armed) persisted everything that completed.
#[derive(Debug, Clone)]
pub struct FailInfo {
    /// The first task failure, rendered (panics read "dag node N failed:
    /// dag task panicked").
    pub error: String,
    /// Blocks whose posteriors were completed (sampled or restored) when
    /// the failure took the run down — including in-flight siblings that
    /// drained *after* the failing task died.
    pub blocks_completed: usize,
    /// Where the final abort checkpoint of those posteriors was written:
    /// the newest generation in `TrainConfig::checkpoint_dir`, or the
    /// `TrainConfig::checkpoint_on_cancel` file, whichever is armed
    /// (directory wins when both are). `None` when neither is armed or no
    /// block had completed.
    pub checkpoint: Option<PathBuf>,
}

/// How a submitted run ended: trained to completion, cancelled, or failed
/// (with a resumable partial checkpoint when one was requested and any
/// block had finished).
#[derive(Debug)]
pub enum TrainOutcome {
    /// The run trained to completion.
    Completed(Box<TrainResult>),
    /// The run was cancelled before completing.
    Cancelled(CancelInfo),
    /// A block task errored or panicked; the job failed without touching
    /// its neighbours on the shared pool.
    Failed(FailInfo),
}

impl TrainOutcome {
    /// The completed result, or an error describing the cancellation or
    /// failure — for callers that treat anything short of completion as
    /// failure.
    pub fn into_result(self) -> anyhow::Result<TrainResult> {
        let ckpt_hint = |p: &Option<PathBuf>| match p {
            Some(p) => format!(" (partial checkpoint: {})", p.display()),
            None => String::new(),
        };
        match self {
            TrainOutcome::Completed(r) => Ok(*r),
            TrainOutcome::Cancelled(info) => Err(anyhow::anyhow!(
                "training cancelled after {} completed blocks{}",
                info.blocks_completed,
                ckpt_hint(&info.checkpoint)
            )),
            TrainOutcome::Failed(info) => Err(anyhow::anyhow!(
                "training failed after {} completed blocks: {}{}",
                info.blocks_completed,
                info.error,
                ckpt_hint(&info.checkpoint)
            )),
        }
    }

    /// The completed result, if the run trained to completion.
    pub fn completed(&self) -> Option<&TrainResult> {
        match self {
            TrainOutcome::Completed(r) => Some(r.as_ref()),
            _ => None,
        }
    }

    /// The cancellation record, if the run was cancelled.
    pub fn cancelled(&self) -> Option<&CancelInfo> {
        match self {
            TrainOutcome::Cancelled(info) => Some(info),
            _ => None,
        }
    }

    /// The failure record, if a block task took the run down.
    pub fn failed(&self) -> Option<&FailInfo> {
        match self {
            TrainOutcome::Failed(info) => Some(info),
            _ => None,
        }
    }
}

/// Shared live state between a running job and its [`Session`]
/// (`super::Session`) handle: the cooperative cancel flag plus block
/// progress counters the trainer updates as the schedule executes.
#[derive(Debug)]
pub(crate) struct RunControl {
    /// Cooperative cancellation flag (shared with the DAG dispatcher).
    pub cancel: Arc<AtomicBool>,
    /// Blocks completed so far (sampled + restored).
    pub blocks_done: AtomicUsize,
    /// Total blocks in the run's grid.
    pub blocks_total: AtomicUsize,
    /// `RunStats::queue_wait_secs` as `f64` bits once the schedule has
    /// measured it; `u64::MAX` (a NaN pattern no measurement produces)
    /// while unset. Lets `Engine::jobs()` surface the admission fairness
    /// signal live instead of only in the final result.
    queue_wait_bits: AtomicU64,
    /// Live shard-cache counters for store-backed runs (all zero for
    /// resident runs). Shared with the run's `ShardCache` so
    /// `Engine::jobs()` can surface hit/miss/prefetch numbers while the
    /// job is still training.
    pub shards: Arc<ShardCounters>,
}

impl RunControl {
    const QUEUE_WAIT_UNSET: u64 = u64::MAX;

    pub(crate) fn new() -> RunControl {
        RunControl {
            cancel: Arc::new(AtomicBool::new(false)),
            blocks_done: AtomicUsize::new(0),
            blocks_total: AtomicUsize::new(0),
            queue_wait_bits: AtomicU64::new(Self::QUEUE_WAIT_UNSET),
            shards: Arc::new(ShardCounters::default()),
        }
    }

    /// Publish the run's measured queue wait (seconds).
    pub(crate) fn set_queue_wait(&self, secs: f64) {
        self.queue_wait_bits.store(secs.to_bits(), Ordering::Relaxed);
    }

    /// The measured queue wait, once the schedule has produced one.
    pub(crate) fn queue_wait(&self) -> Option<f64> {
        match self.queue_wait_bits.load(Ordering::Relaxed) {
            Self::QUEUE_WAIT_UNSET => None,
            bits => Some(f64::from_bits(bits)),
        }
    }
}

/// Per-run context the engine threads through the pipeline: the pool job
/// the run's tasks are tagged with, the shared control block, and the
/// resume state (if any).
pub(crate) struct JobCtx {
    pub job: JobId,
    pub control: Arc<RunControl>,
    pub resume: Option<PartialCheckpoint>,
    /// True for incremental updates (`Engine::update`): blocks carried in
    /// through `resume` are *clean* — untouched by the delta — so their
    /// pass-through is reported as [`TrainEvent::BlockSkippedClean`] and
    /// counted in `RunStats::blocks_skipped_clean` instead of the
    /// crash-resume restore accounting.
    pub clean_skip: bool,
}

/// The periodic-checkpoint writer one run shares across its block tasks:
/// every completed block posterior is recorded here (restored blocks are
/// seeded at construction), and each `every` newly completed blocks the
/// full completed set is persisted as the next generation file — written
/// atomically and pruned to the newest `keep` generations. Write errors
/// are logged and never fail the run: a checkpoint hiccup must not take
/// down the training it exists to protect.
///
/// Generation writes happen on the worker thread that completed the
/// triggering block, while holding the sink mutex — deliberately: the
/// lock is what keeps generation numbering and contents strictly
/// monotonic without a writer thread. The cost scales with
/// `1/checkpoint_every`; tiny intervals (every=1) trade worker time for
/// recovery granularity and are priced accordingly.
struct CheckpointSink {
    every: usize,
    dir: PathBuf,
    keep: usize,
    k: usize,
    seed: u64,
    grid: (usize, usize),
    global_mean: f64,
    store_revision: u64,
    state: std::sync::Mutex<SinkState>,
}

struct SinkState {
    /// Every completed block posterior so far, in completion order
    /// (resume-inherited blocks first) — what each generation persists.
    blocks: Vec<PartialBlock>,
    /// Newly completed blocks since the last generation write.
    since_last: usize,
    /// Number the next generation file is written under.
    next_generation: u64,
    /// Newest generation successfully written by *this* run.
    last_written: Option<PathBuf>,
}

impl CheckpointSink {
    /// Build the sink when `cfg` arms periodic checkpointing (`Ok(None)`
    /// otherwise). Creates the directory, continues generation numbering
    /// past both the files already present and the generation the run is
    /// resuming from, and seeds the completed set with the resumed blocks
    /// so on-disk progress never shrinks across crash/resume cycles.
    fn from_config(
        cfg: &TrainConfig,
        global_mean: f64,
        store_revision: u64,
        resume: Option<&PartialCheckpoint>,
    ) -> anyhow::Result<Option<Arc<CheckpointSink>>> {
        if cfg.checkpoint_every == 0 {
            return Ok(None);
        }
        // validate() enforces the pairing; double-checked for direct callers
        let Some(dir) = &cfg.checkpoint_dir else { return Ok(None) };
        std::fs::create_dir_all(dir).map_err(|e| {
            anyhow::anyhow!("cannot create checkpoint dir {}: {e}", dir.display())
        })?;
        let existing = checkpoint::list_generations(dir).map_err(|e| {
            anyhow::anyhow!("cannot list checkpoint dir {}: {e}", dir.display())
        })?;
        let mut next_generation = existing.last().map_or(0, |(g, _)| *g) + 1;
        let mut blocks = Vec::new();
        if let Some(r) = resume {
            next_generation = next_generation.max(r.generation + 1);
            blocks = r.blocks.clone();
        }
        Ok(Some(Arc::new(CheckpointSink {
            every: cfg.checkpoint_every,
            dir: dir.clone(),
            keep: cfg.checkpoint_keep,
            k: cfg.k,
            seed: cfg.seed,
            grid: cfg.grid,
            global_mean,
            store_revision,
            state: std::sync::Mutex::new(SinkState {
                blocks,
                since_last: 0,
                next_generation,
                last_written: None,
            }),
        })))
    }

    /// Record one newly completed block; writes a generation when the
    /// interval is reached. Called from worker threads.
    fn record(&self, i: usize, j: usize, post: &BlockPosteriors, em: &Emitter) {
        let mut st = self.state.lock().unwrap();
        st.blocks.push(PartialBlock { i, j, post: post.clone() });
        st.since_last += 1;
        if st.since_last >= self.every {
            self.write_generation(&mut st, em);
        }
    }

    fn write_generation(&self, st: &mut SinkState, em: &Emitter) {
        let path = checkpoint::generation_path(&self.dir, st.next_generation);
        let ckpt = PartialCheckpoint {
            k: self.k,
            seed: self.seed,
            grid: self.grid,
            global_mean: self.global_mean,
            generation: st.next_generation,
            store_revision: self.store_revision,
            blocks: st.blocks.clone(),
        };
        match checkpoint::save_partial(&ckpt, &path) {
            Ok(()) => {
                em.checkpoint_saved(&path, ckpt.blocks.len());
                st.next_generation += 1;
                st.since_last = 0;
                st.last_written = Some(path);
                if let Err(e) = checkpoint::prune_generations(&self.dir, self.keep) {
                    log::warn!("checkpoint retention in {} failed: {e}", self.dir.display());
                }
            }
            Err(e) => {
                log::warn!("periodic checkpoint write to {} failed: {e}", path.display())
            }
        }
    }

    /// Final flush on cancel or failure: persist any blocks newer than the
    /// last generation, then return the newest generation this run wrote
    /// (if any) — the path an abort outcome points its resume hint at. A
    /// run that holds blocks but never wrote (e.g. resumed, then aborted
    /// before any new block completed) writes one now, so an abort with
    /// completed blocks always has a generation to point at.
    fn flush_final(&self, em: &Emitter) -> Option<PathBuf> {
        let mut st = self.state.lock().unwrap();
        if !st.blocks.is_empty() && (st.since_last > 0 || st.last_written.is_none()) {
            self.write_generation(&mut st, em);
        }
        st.last_written.clone()
    }
}

/// Persist `blocks` to every armed abort destination — the periodic
/// checkpoint directory (as a final generation) and/or the one-shot
/// `checkpoint_on_cancel` file — and return the path a resume should be
/// pointed at (the directory generation wins when both are armed). The
/// shared tail of both the cancel and the failure exits.
fn persist_abort(
    cfg: &TrainConfig,
    global_mean: f64,
    store_revision: u64,
    blocks: &[PartialBlock],
    em: &Emitter,
    sink: Option<&CheckpointSink>,
) -> anyhow::Result<Option<PathBuf>> {
    // the sink first: its writes never error out of this function, so a
    // broken checkpoint_on_cancel path can't cost the directory its final
    // generation
    let gen_saved = sink.and_then(|s| s.flush_final(em));
    if !blocks.is_empty() {
        if let Some(path) = &cfg.checkpoint_on_cancel {
            let ckpt = PartialCheckpoint {
                k: cfg.k,
                seed: cfg.seed,
                grid: cfg.grid,
                global_mean,
                generation: 0,
                store_revision,
                blocks: blocks.to_vec(),
            };
            match checkpoint::save_partial(&ckpt, path) {
                Ok(()) => {
                    em.checkpoint_saved(path, blocks.len());
                    if gen_saved.is_none() {
                        return Ok(Some(path.clone()));
                    }
                }
                // with a generation on disk the abort state IS persisted;
                // only a run with no other checkpoint treats this as fatal
                Err(e) if gen_saved.is_some() => {
                    log::warn!(
                        "abort checkpoint write to {} failed (resume from the \
                         checkpoint dir instead): {e}",
                        path.display()
                    );
                }
                Err(e) => {
                    return Err(anyhow::anyhow!(
                        "abort checkpoint write to {} failed: {e}",
                        path.display()
                    ))
                }
            }
        }
    }
    Ok(gen_saved)
}

/// Emit the cancel events and build the cancellation outcome — the one
/// tail every cancel path (before or after the DAG started) goes through.
fn finish_cancelled(
    cfg: &TrainConfig,
    global_mean: f64,
    store_revision: u64,
    blocks: Vec<PartialBlock>,
    em: &Emitter,
    sink: Option<&CheckpointSink>,
) -> anyhow::Result<TrainOutcome> {
    let blocks_completed = blocks.len();
    let saved = persist_abort(cfg, global_mean, store_revision, &blocks, em, sink)?;
    em.cancelled(blocks_completed);
    Ok(TrainOutcome::Cancelled(CancelInfo { blocks_completed, checkpoint: saved }))
}

/// A block task errored or panicked: persist everything that completed,
/// emit the failure event, and build the typed failure outcome. Unlike the
/// cancel path an abort-write error cannot replace the primary error — it
/// is logged and the failure is still reported.
fn finish_failed(
    cfg: &TrainConfig,
    global_mean: f64,
    store_revision: u64,
    blocks: Vec<PartialBlock>,
    em: &Emitter,
    sink: Option<&CheckpointSink>,
    error: &anyhow::Error,
) -> anyhow::Result<TrainOutcome> {
    let blocks_completed = blocks.len();
    let saved = match persist_abort(cfg, global_mean, store_revision, &blocks, em, sink) {
        Ok(p) => p,
        Err(e) => {
            log::warn!("abort checkpoint after failure could not be written: {e:#}");
            None
        }
    };
    let error = format!("{error:#}");
    em.failed(&error, blocks_completed);
    Ok(TrainOutcome::Failed(FailInfo { error, blocks_completed, checkpoint: saved }))
}

/// Load + validate `cfg.resume_from` against the config it will resume
/// under. A mismatched latent dim, grid, or seed would silently change the
/// math, so each is rejected with the pair of values named. The path may
/// be a single v3 file or a periodic-checkpoint *directory* — for a
/// directory the newest generation that validates is restored (a
/// truncated newest file is skipped, never loaded).
pub(crate) fn load_resume(cfg: &TrainConfig) -> anyhow::Result<Option<PartialCheckpoint>> {
    let Some(path) = &cfg.resume_from else { return Ok(None) };
    let ckpt = if path.is_dir() {
        let found = checkpoint::latest_valid_partial(path)
            .map_err(|e| anyhow::anyhow!("cannot resume from {}: {e}", path.display()))?;
        let Some((ckpt, file)) = found else {
            anyhow::bail!(
                "cannot resume from {}: directory holds no checkpoint generation \
                 ({}*.json)",
                path.display(),
                checkpoint::GENERATION_PREFIX
            );
        };
        log::info!(
            "resuming from generation {} ({} blocks): {}",
            ckpt.generation,
            ckpt.blocks.len(),
            file.display()
        );
        ckpt
    } else {
        checkpoint::load_partial(path)
            .map_err(|e| anyhow::anyhow!("cannot resume from {}: {e}", path.display()))?
    };
    anyhow::ensure!(
        ckpt.k == cfg.k,
        "resume checkpoint has k={} but the config trains k={}",
        ckpt.k,
        cfg.k
    );
    anyhow::ensure!(
        ckpt.grid == cfg.grid,
        "resume checkpoint has grid {}x{} but the config trains {}x{}",
        ckpt.grid.0,
        ckpt.grid.1,
        cfg.grid.0,
        cfg.grid.1
    );
    anyhow::ensure!(
        ckpt.seed == cfg.seed,
        "resume checkpoint was written under seed {} but the config uses {} \
         (per-block seeds derive from it, so the math would diverge)",
        ckpt.seed,
        cfg.seed
    );
    Ok(Some(ckpt))
}

/// Emits [`TrainEvent`]s from inside DAG task closures. Phase starts are
/// deduplicated with atomics because the first task of a phase is decided
/// by the scheduler at run time, not by construction order.
#[derive(Clone)]
struct Emitter {
    sink: Option<EventSink>,
    sweep_rmse: bool,
    /// Incremental-update run: pass-through blocks are clean skips, not
    /// crash-resume restores (see `JobCtx::clean_skip`).
    clean_skip: bool,
    phase_started: Arc<[AtomicBool; 4]>,
    control: Arc<RunControl>,
}

impl Emitter {
    fn new(
        sink: Option<EventSink>,
        sweep_rmse: bool,
        clean_skip: bool,
        control: Arc<RunControl>,
    ) -> Emitter {
        Emitter {
            sink,
            sweep_rmse,
            clean_skip,
            phase_started: Arc::new([
                AtomicBool::new(false),
                AtomicBool::new(false),
                AtomicBool::new(false),
                AtomicBool::new(false),
            ]),
            control,
        }
    }

    fn phase(&self, phase: PpPhase) {
        let Some(sink) = &self.sink else { return };
        if !self.phase_started[phase as usize].swap(true, Ordering::Relaxed) {
            sink(TrainEvent::PhaseStarted { phase });
        }
    }

    fn block_done(&self, node: (usize, usize), phase: PpPhase, stats: &BlockRunStats) {
        self.control.blocks_done.fetch_add(1, Ordering::Relaxed);
        if let Some(sink) = &self.sink {
            sink(TrainEvent::BlockCompleted {
                node,
                phase,
                secs: stats.secs,
                sweeps: stats.sweeps,
            });
        }
    }

    fn block_restored(&self, node: (usize, usize)) {
        self.control.blocks_done.fetch_add(1, Ordering::Relaxed);
        if let Some(sink) = &self.sink {
            if self.clean_skip {
                sink(TrainEvent::BlockSkippedClean { node });
            } else {
                sink(TrainEvent::BlockRestored { node });
            }
        }
    }

    fn cancelled(&self, blocks_completed: usize) {
        if let Some(sink) = &self.sink {
            sink(TrainEvent::Cancelled { blocks_completed });
        }
    }

    fn failed(&self, error: &str, blocks_completed: usize) {
        if let Some(sink) = &self.sink {
            sink(TrainEvent::Failed { error: error.to_string(), blocks_completed });
        }
    }

    fn checkpoint_saved(&self, path: &std::path::Path, blocks: usize) {
        if let Some(sink) = &self.sink {
            sink(TrainEvent::CheckpointSaved { path: path.to_path_buf(), blocks });
        }
    }

    /// A shard entered the cache (store-backed runs only). Fired by the
    /// cache's load hook from whichever thread performed the read.
    fn shard_loaded(&self, load: &ShardLoad) {
        if let Some(sink) = &self.sink {
            let c = load.counters;
            sink(TrainEvent::ShardLoaded {
                node: (load.i, load.j),
                bytes: load.bytes,
                prefetch: load.prefetch,
                hits: c.hits,
                misses: c.misses,
                prefetch_hits: c.prefetch_hits,
                evictions: c.evictions,
                resident_bytes: c.resident_bytes,
            });
        }
    }

    /// Per-sweep observer for one block, or None when nobody listens or
    /// the config disabled sweep streaming (the block then skips the
    /// per-sweep RMSE computation entirely).
    fn sweep_observer(&self, node: (usize, usize)) -> Option<Box<dyn Fn(usize, f64)>> {
        if !self.sweep_rmse {
            return None;
        }
        let sink = self.sink.clone()?;
        Some(Box::new(move |sweep, rmse| {
            sink(TrainEvent::SweepSample { node, sweep, rmse })
        }))
    }

    /// Per-chunk publication observer for one block (pipelined sweeps),
    /// or None when nobody listens. Called from worker threads, hence the
    /// `Sync` bound.
    fn chunk_observer(
        &self,
        node: (usize, usize),
    ) -> Option<Box<dyn Fn(FactorSide, usize, usize, u64) + Sync>> {
        let sink = self.sink.clone()?;
        Some(Box::new(move |side, sweep, chunk, seq| {
            sink(TrainEvent::ChunkExchanged { node, side, sweep, chunk, seq })
        }))
    }

    fn finished(&self, secs: f64, blocks: usize) {
        if let Some(sink) = &self.sink {
            sink(TrainEvent::Finished { secs, blocks });
        }
    }
}

fn pick_u(bp: &BlockPosteriors) -> &RowGaussians {
    &bp.u
}

fn pick_v(bp: &BlockPosteriors) -> &RowGaussians {
    &bp.v
}

/// Add one aggregation node: `prior` (a block node) refined by the block
/// nodes in `posts`, consumed in the given canonical order; `join` is the
/// barrier-mode phase join, appended after the posts so the task's parent
/// slice never includes it. Encodes the parent-slice bound (`posts.len()`)
/// exactly once for all four U/V part shapes.
fn add_part(
    dag: &mut DagScheduler<PpTaskOutput>,
    prior: NodeId,
    posts: &[NodeId],
    join: Option<NodeId>,
    ridge: f64,
    pick: fn(&BlockPosteriors) -> &RowGaussians,
    em: &Emitter,
) -> NodeId {
    let mut edges = Vec::with_capacity(posts.len() + 2);
    edges.push(prior);
    edges.extend_from_slice(posts);
    if let Some(j) = join {
        edges.push(j);
    }
    let n_posts = posts.len();
    let em = em.clone();
    dag.add(&edges, move |_b: &BlockBackend, p: &[Arc<PpTaskOutput>]| {
        em.phase(PpPhase::Aggregate);
        let posts: Vec<&RowGaussians> =
            p[1..1 + n_posts].iter().map(|q| pick(q.block())).collect();
        Ok(PpTaskOutput::Part(aggregate_part(pick(p[0].block()), &posts, ridge)))
    })
}

fn block_seed(cfg: &TrainConfig, i: usize, j: usize) -> u64 {
    cfg.seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((i as u64) << 32 | j as u64)
}

fn task_cfg(cfg: &TrainConfig, samples: usize, seed: u64) -> BlockTaskCfg {
    BlockTaskCfg {
        k: cfg.k,
        tau: cfg.tau,
        burnin: cfg.burnin,
        samples,
        workers: cfg.workers,
        ridge: cfg.ridge,
        seed,
        sweep: cfg.sweep,
        chunk_rows: cfg.chunk_rows,
        staleness: cfg.staleness,
        precision: cfg.kernel_precision,
    }
}

/// Mean-centre a training matrix into a private copy: the factors model
/// the residual, the global mean is restored at prediction — standard for
/// all methods compared in the paper.
pub(crate) fn center(train: &Coo) -> (Coo, f64) {
    let global_mean = train.mean();
    let mut centered = train.clone();
    for e in centered.entries.iter_mut() {
        e.val -= global_mean as f32;
    }
    (centered, global_mean)
}

/// Where a run's ratings come from: the whole (already mean-centred)
/// matrix resident in memory, or an opened on-disk shard store whose
/// blocks are materialized on demand (centring applied per entry at read
/// time — see `store::shard` for the bitwise-equivalence argument).
pub(crate) enum DataSource {
    /// The classic path: one private, centred `Coo` owned by the run.
    Resident(Coo),
    /// Out-of-core: blocks fetched through a byte-budgeted `ShardCache`.
    Store(Arc<ShardStore>),
}

impl DataSource {
    fn rows(&self) -> usize {
        match self {
            DataSource::Resident(c) => c.rows,
            DataSource::Store(s) => s.rows(),
        }
    }

    fn cols(&self) -> usize {
        match self {
            DataSource::Resident(c) => c.cols,
            DataSource::Store(s) => s.cols(),
        }
    }
}

/// Per-run block provider the DAG builder draws from. Resident blocks
/// are split (and their CSR layouts built) up front exactly as before;
/// store blocks stay on disk until their task runs.
enum BlockSource {
    Resident(Vec<Vec<Coo>>),
    Store(Arc<ShardCache>),
}

impl BlockSource {
    fn take(&mut self, i: usize, j: usize) -> BlockSlot {
        match self {
            BlockSource::Resident(blocks) => BlockSlot::Owned(Arc::new(BlockData::new(
                std::mem::replace(&mut blocks[i][j], Coo::new(0, 0)),
            ))),
            BlockSource::Store(cache) => BlockSlot::Lazy { cache: cache.clone(), i, j },
        }
    }
}

/// What a block task closure captures: the block itself (resident) or a
/// cache ticket redeemed when the task actually starts — after the
/// restored-block early return and the fault-injection hook, so resumed
/// blocks never touch disk and injected crashes model dying *before* the
/// read.
enum BlockSlot {
    Owned(Arc<BlockData>),
    Lazy { cache: Arc<ShardCache>, i: usize, j: usize },
}

impl BlockSlot {
    fn fetch(&self) -> anyhow::Result<Arc<BlockData>> {
        match self {
            BlockSlot::Owned(data) => Ok(data.clone()),
            BlockSlot::Lazy { cache, i, j } => Ok(cache.get(*i, *j)?),
        }
    }
}

/// Run the full PP pipeline for `cfg` on a caller-owned worker pool,
/// streaming progress to `sink` (if any). Blocking, not cancellable: the
/// run executes under a transient pool job at the config's priority.
pub(crate) fn run_pp(
    cfg: &TrainConfig,
    pool: &WorkerPool,
    train: &Coo,
    sink: Option<EventSink>,
) -> anyhow::Result<TrainResult> {
    cfg.validate(train.rows, train.cols)?;
    let resume = load_resume(cfg)?;
    let job = pool.register_job(cfg.priority, cfg.max_in_flight);
    let ctx = JobCtx { job, control: Arc::new(RunControl::new()), resume, clean_skip: false };
    let (centered, global_mean) = center(train);
    let out = run_pp_centered(cfg, pool, DataSource::Resident(centered), global_mean, sink, ctx);
    pool.finish_job(job);
    out.and_then(TrainOutcome::into_result)
}

/// [`run_pp`] against an opened shard store. Blocking, not cancellable —
/// the store-backed twin of the resident convenience path. The centring
/// mean comes from the store's manifest (persisted at ingest), so the
/// posterior is bitwise-identical to a resident run of the same data.
pub(crate) fn run_pp_store(
    cfg: &TrainConfig,
    pool: &WorkerPool,
    store: Arc<ShardStore>,
    sink: Option<EventSink>,
) -> anyhow::Result<TrainResult> {
    cfg.validate(store.rows(), store.cols())?;
    let resume = load_resume(cfg)?;
    let job = pool.register_job(cfg.priority, cfg.max_in_flight);
    let ctx = JobCtx { job, control: Arc::new(RunControl::new()), resume, clean_skip: false };
    let global_mean = store.global_mean();
    let out = run_pp_centered(cfg, pool, DataSource::Store(store), global_mean, sink, ctx);
    pool.finish_job(job);
    out.and_then(TrainOutcome::into_result)
}

/// [`run_pp`] over an already mean-centred matrix the caller gives away —
/// the path `Engine::submit` uses so a session holds exactly one private
/// copy of the data (centring happens during that one clone) instead of
/// clone-for-the-thread plus clone-for-centring. The caller owns the
/// ctx's pool-job registration (and its `finish_job`).
pub(crate) fn run_pp_centered(
    cfg: &TrainConfig,
    pool: &WorkerPool,
    data: DataSource,
    global_mean: f64,
    sink: Option<EventSink>,
    ctx: JobCtx,
) -> anyhow::Result<TrainOutcome> {
    let (rows, cols) = (data.rows(), data.cols());
    cfg.validate(rows, cols)?;
    if let DataSource::Store(store) = &data {
        // shards were cut on the ingest grid; a different training grid
        // would need different block membership, so it is a typed error
        let store_grid = store.grid_dims();
        if store_grid != cfg.grid {
            return Err(StoreError::GridMismatch { cfg: cfg.grid, store: store_grid }.into());
        }
    }
    let em = Emitter::new(sink, cfg.stream_sweep_rmse, ctx.clean_skip, ctx.control.clone());
    let clean_skip = ctx.clean_skip;
    // the store revision the periodic/abort checkpoints will record:
    // live manifest value for store runs; for resident runs, whatever the
    // resume checkpoint carried (an update keeps its prior's revision)
    let store_revision = match &data {
        DataSource::Store(store) => store.revision(),
        DataSource::Resident(_) => ctx.resume.as_ref().map_or(0, |r| r.store_revision),
    };

    let (gi, gj) = cfg.grid;
    ctx.control.blocks_total.store(gi * gj, Ordering::Relaxed);
    if let Some(ckpt) = &ctx.resume {
        // the engine validated k/grid/seed; the centring mean is the
        // data fingerprint and is only known here
        anyhow::ensure!(
            ckpt.global_mean.to_bits() == global_mean.to_bits(),
            "resume checkpoint was written for different data \
             (global mean {} vs {global_mean})",
            ckpt.global_mean
        );
    }
    // the periodic writer, when armed — seeded with the resumed blocks so
    // generations never shrink across crash/resume cycles
    let ckpt_sink = CheckpointSink::from_config(cfg, global_mean, store_revision, ctx.resume.as_ref())?;
    // blocks restored from a resume checkpoint, keyed by grid coordinate
    let mut restored: HashMap<(usize, usize), BlockPosteriors> = HashMap::new();
    // the restored posteriors get moved into DAG closures below; when any
    // abort checkpoint destination is armed, keep the originals (in
    // checkpoint order) so an abort can re-persist blocks whose restore
    // node never dispatched — checkpointed progress must never shrink
    // across cancel/resume cycles. With no destination the backup can
    // never be read, so skip the copy.
    let mut resume_backup: Vec<PartialBlock> = Vec::new();
    if let Some(ckpt) = ctx.resume {
        if cfg.checkpoint_on_cancel.is_some() || ckpt_sink.is_some() {
            resume_backup = ckpt.blocks.clone();
        }
        restored = ckpt.blocks.into_iter().map(|b| ((b.i, b.j), b.post)).collect();
    }
    // a cancel that lands before the schedule starts runs nothing — but a
    // resumed run must still carry its inherited blocks forward into the
    // abort checkpoint rather than dropping them
    if ctx.control.cancel.load(Ordering::Relaxed) {
        return finish_cancelled(
            cfg,
            global_mean,
            store_revision,
            resume_backup,
            &em,
            ckpt_sink.as_deref(),
        );
    }
    let mut restored_ids: HashSet<NodeId> = HashSet::new();
    // grid coordinate of every block node, for checkpoint-on-abort
    let mut block_nodes: Vec<((usize, usize), NodeId)> = Vec::new();

    let (mut source, cache) = match data {
        DataSource::Resident(train) => {
            let grid = Grid::new(rows, cols, gi, gj);
            (BlockSource::Resident(grid.split(&train)), None)
        }
        DataSource::Store(store) => {
            let em_load = em.clone();
            let cache = Arc::new(ShardCache::new(
                store,
                cfg.cache_bytes,
                ctx.control.shards.clone(),
                Some(Box::new(move |load: &ShardLoad| em_load.shard_loaded(load))),
            ));
            (BlockSource::Store(cache.clone()), Some(cache))
        }
    };
    let t_total = std::time::Instant::now();
    let barrier = cfg.scheduler == SchedulerMode::Barrier;
    let ridge = cfg.ridge;
    let phase_samples = cfg.phase_samples();

    let mut dag: DagScheduler<PpTaskOutput> = DagScheduler::new();

    // fault injection (testing hook): consulted by canonical block index
    // right before each sampled block; `None` in production
    let fault = cfg.fault;

    // ---- Phase (a): block (0,0), fresh priors both sides ----
    let a_slot = source.take(0, 0);
    let cfg_a = task_cfg(cfg, cfg.samples, block_seed(cfg, 0, 0));
    let em_a = em.clone();
    let pre_a = restored.remove(&(0, 0));
    let a_restored = pre_a.is_some();
    let sink_a = ckpt_sink.clone();
    let a_id = dag.add(&[], move |b: &BlockBackend, _p: &[Arc<PpTaskOutput>]| {
        if let Some(post) = pre_a {
            em_a.block_restored((0, 0));
            return Ok(PpTaskOutput::Block(post, BlockRunStats::default()));
        }
        if let Some(f) = &fault {
            f.before_block(0, (0, 0));
        }
        let a_data = a_slot.fetch()?;
        em_a.phase(PpPhase::A);
        let sweep_obs = em_a.sweep_observer((0, 0));
        let chunk_obs = em_a.chunk_observer((0, 0));
        let obs = BlockObs { sweep: sweep_obs.as_deref(), chunk: chunk_obs.as_deref() };
        let (post, stats) = run_block(b, &a_data, &cfg_a, None, None, obs)?;
        em_a.block_done((0, 0), PpPhase::A, &stats);
        if let Some(s) = &sink_a {
            s.record(0, 0, &post, &em_a);
        }
        Ok(PpTaskOutput::Block(post, stats))
    });
    if a_restored {
        restored_ids.insert(a_id);
    }
    block_nodes.push(((0, 0), a_id));

    // ---- Phase (b): first-row and first-column blocks; each depends
    // only on (a), whose posterior it consumes as a prior ----
    let mut b_row_ids: Vec<NodeId> = vec![a_id; gi];
    let mut b_col_ids: Vec<NodeId> = vec![a_id; gj];
    let mut b_ids: Vec<NodeId> = Vec::new();
    for i in 1..gi {
        let slot = source.take(i, 0);
        let bcfg = task_cfg(cfg, phase_samples, block_seed(cfg, i, 0));
        let em_b = em.clone();
        let pre = restored.remove(&(i, 0));
        let is_restored = pre.is_some();
        let sink_b = ckpt_sink.clone();
        let idx = block_nodes.len();
        let id = dag.add(&[a_id], move |b: &BlockBackend, p: &[Arc<PpTaskOutput>]| {
            if let Some(post) = pre {
                em_b.block_restored((i, 0));
                return Ok(PpTaskOutput::Block(post, BlockRunStats::default()));
            }
            if let Some(f) = &fault {
                f.before_block(idx, (i, 0));
            }
            let data = slot.fetch()?;
            em_b.phase(PpPhase::B);
            let sweep_obs = em_b.sweep_observer((i, 0));
            let chunk_obs = em_b.chunk_observer((i, 0));
            let obs = BlockObs { sweep: sweep_obs.as_deref(), chunk: chunk_obs.as_deref() };
            let (post, stats) = run_block(b, &data, &bcfg, None, Some(&p[0].block().v), obs)?;
            em_b.block_done((i, 0), PpPhase::B, &stats);
            if let Some(s) = &sink_b {
                s.record(i, 0, &post, &em_b);
            }
            Ok(PpTaskOutput::Block(post, stats))
        });
        if is_restored {
            restored_ids.insert(id);
        }
        block_nodes.push(((i, 0), id));
        b_row_ids[i] = id;
        b_ids.push(id);
    }
    for j in 1..gj {
        let slot = source.take(0, j);
        let bcfg = task_cfg(cfg, phase_samples, block_seed(cfg, 0, j));
        let em_b = em.clone();
        let pre = restored.remove(&(0, j));
        let is_restored = pre.is_some();
        let sink_b = ckpt_sink.clone();
        let idx = block_nodes.len();
        let id = dag.add(&[a_id], move |b: &BlockBackend, p: &[Arc<PpTaskOutput>]| {
            if let Some(post) = pre {
                em_b.block_restored((0, j));
                return Ok(PpTaskOutput::Block(post, BlockRunStats::default()));
            }
            if let Some(f) = &fault {
                f.before_block(idx, (0, j));
            }
            let data = slot.fetch()?;
            em_b.phase(PpPhase::B);
            let sweep_obs = em_b.sweep_observer((0, j));
            let chunk_obs = em_b.chunk_observer((0, j));
            let obs = BlockObs { sweep: sweep_obs.as_deref(), chunk: chunk_obs.as_deref() };
            let (post, stats) = run_block(b, &data, &bcfg, Some(&p[0].block().u), None, obs)?;
            em_b.block_done((0, j), PpPhase::B, &stats);
            if let Some(s) = &sink_b {
                s.record(0, j, &post, &em_b);
            }
            Ok(PpTaskOutput::Block(post, stats))
        });
        if is_restored {
            restored_ids.insert(id);
        }
        block_nodes.push(((0, j), id));
        b_col_ids[j] = id;
        b_ids.push(id);
    }

    // barrier mode: one synthetic join node per phase keeps the edge
    // count linear in the block count — every phase-(c) block waits on
    // this single node instead of on each of the I+J-2 (b) blocks
    let b_join = (barrier && !b_ids.is_empty()).then(|| {
        dag.add(&b_ids, |_b: &BlockBackend, _p: &[Arc<PpTaskOutput>]| {
            Ok(PpTaskOutput::Barrier)
        })
    });

    // ---- Phase (c): interior block (i,j) depends on its two real
    // parents (i,0) and (0,j); barrier mode adds the phase-(b) join,
    // restoring the old full phase barrier ----
    let mut c_ids: Vec<NodeId> = Vec::new();
    let mut c_id_at = vec![vec![a_id; gj]; gi];
    for i in 1..gi {
        for j in 1..gj {
            let slot = source.take(i, j);
            let bcfg = task_cfg(cfg, phase_samples, block_seed(cfg, i, j));
            let mut edges = vec![b_row_ids[i], b_col_ids[j]];
            if let Some(join) = b_join {
                edges.push(join);
            }
            let em_c = em.clone();
            let pre = restored.remove(&(i, j));
            let is_restored = pre.is_some();
            let sink_c = ckpt_sink.clone();
            let idx = block_nodes.len();
            let id = dag.add(&edges, move |b: &BlockBackend, p: &[Arc<PpTaskOutput>]| {
                if let Some(post) = pre {
                    em_c.block_restored((i, j));
                    return Ok(PpTaskOutput::Block(post, BlockRunStats::default()));
                }
                if let Some(f) = &fault {
                    f.before_block(idx, (i, j));
                }
                let data = slot.fetch()?;
                em_c.phase(PpPhase::C);
                let sweep_obs = em_c.sweep_observer((i, j));
                let chunk_obs = em_c.chunk_observer((i, j));
                let obs =
                    BlockObs { sweep: sweep_obs.as_deref(), chunk: chunk_obs.as_deref() };
                let (post, stats) = run_block(
                    b,
                    &data,
                    &bcfg,
                    Some(&p[0].block().u),
                    Some(&p[1].block().v),
                    obs,
                )?;
                em_c.block_done((i, j), PpPhase::C, &stats);
                if let Some(s) = &sink_c {
                    s.record(i, j, &post, &em_c);
                }
                Ok(PpTaskOutput::Block(post, stats))
            });
            if is_restored {
                restored_ids.insert(id);
            }
            block_nodes.push(((i, j), id));
            c_ids.push(id);
            c_id_at[i][j] = id;
        }
    }

    // barrier mode: aggregation waits for the slower of the two phase
    // joins (phase (c) when interior blocks exist, else phase (b))
    let c_join = (barrier && !c_ids.is_empty()).then(|| {
        dag.add(&c_ids, |_b: &BlockBackend, _p: &[Arc<PpTaskOutput>]| {
            Ok(PpTaskOutput::Barrier)
        })
    });
    let agg_join = c_join.or(b_join);

    // ---- Aggregation as DAG nodes: each row/column part starts the
    // moment its own inputs exist instead of after every block.
    // Inputs are consumed in canonical (i, j) order, so the floating-
    // point reduction is identical whatever the completion order. ----
    let mut u_part_ids: Vec<NodeId> = Vec::with_capacity(gi);
    let mut v_part_ids: Vec<NodeId> = Vec::with_capacity(gj);
    // U^(0): phase-a posterior refined by the phase-b column blocks
    let posts: Vec<NodeId> = (1..gj).map(|j| b_col_ids[j]).collect();
    u_part_ids.push(add_part(&mut dag, a_id, &posts, agg_join, ridge, pick_u, &em));
    // U^(i): phase-b row posterior refined by row i's (c) blocks
    for i in 1..gi {
        let posts: Vec<NodeId> = (1..gj).map(|j| c_id_at[i][j]).collect();
        u_part_ids.push(add_part(&mut dag, b_row_ids[i], &posts, agg_join, ridge, pick_u, &em));
    }
    // V^(0): phase-a posterior refined by the phase-b row blocks
    let posts: Vec<NodeId> = (1..gi).map(|i| b_row_ids[i]).collect();
    v_part_ids.push(add_part(&mut dag, a_id, &posts, agg_join, ridge, pick_v, &em));
    // V^(j): phase-b column posterior refined by column j's (c) blocks
    for j in 1..gj {
        let posts: Vec<NodeId> = (1..gi).map(|i| c_id_at[i][j]).collect();
        v_part_ids.push(add_part(&mut dag, b_col_ids[j], &posts, agg_join, ridge, pick_v, &em));
    }

    // store mode: a background prefetcher warms each block's shard the
    // moment the scheduler declares the block runnable — restored blocks
    // are excluded (their tasks never read data)
    let prefetcher = cache.as_ref().map(|c| Prefetcher::spawn(c.clone()));
    let on_ready = prefetcher.as_ref().map(|p| {
        let handle = p.handle();
        let coord_of: HashMap<NodeId, (usize, usize)> = block_nodes
            .iter()
            .filter(|&&(_, id)| !restored_ids.contains(&id))
            .map(|&(coord, id)| (id, coord))
            .collect();
        Box::new(move |id: NodeId| {
            if let Some(&(i, j)) = coord_of.get(&id) {
                handle.request(i, j);
            }
        }) as Box<dyn Fn(NodeId) + Send + Sync>
    });

    let outcome = dag.run_with(
        pool,
        &DagRunOpts { job: Some(ctx.job), cancel: Some(ctx.control.cancel.clone()), on_ready },
    )?;
    // closes the prefetch queue and joins the thread, so every counter
    // below reflects a finished cache
    drop(prefetcher);

    if outcome.cancelled || outcome.failed.is_some() {
        // ---- checkpoint-on-abort: persist every block whose posterior
        // is known — sampled/restored this run (including in-flight
        // siblings that drained after a cancel or a crash), or carried in
        // from the resume checkpoint with its restore node still
        // undispatched ----
        let backup_by_coord: HashMap<(usize, usize), &BlockPosteriors> =
            resume_backup.iter().map(|b| ((b.i, b.j), &b.post)).collect();
        let mut blocks = Vec::new();
        for &((i, j), id) in &block_nodes {
            if let Some(res) = &outcome.nodes[id] {
                if let PpTaskOutput::Block(post, _) = res.output.as_ref() {
                    blocks.push(PartialBlock { i, j, post: post.clone() });
                }
            } else if let Some(post) = backup_by_coord.get(&(i, j)) {
                blocks.push(PartialBlock { i, j, post: (*post).clone() });
            }
        }
        // a failure racing a cancel drain resolves as the cancel — the
        // user asked for it and the checkpoint is identical either way
        return if outcome.cancelled {
            finish_cancelled(cfg, global_mean, store_revision, blocks, &em, ckpt_sink.as_deref())
        } else {
            let err = outcome.failed.expect("checked above");
            finish_failed(
                cfg,
                global_mean,
                store_revision,
                blocks,
                &em,
                ckpt_sink.as_deref(),
                &err,
            )
        };
    }
    // a non-cancelled run_with completes every node
    let nodes: Vec<_> = outcome
        .nodes
        .into_iter()
        .map(|r| r.expect("all nodes completed"))
        .collect();

    // ---- stats + phase attribution from per-node completion times ----
    let mut stats = RunStats::default();
    for (id, res) in nodes.iter().enumerate() {
        if let Some(s) = res.output.block_stats() {
            if restored_ids.contains(&id) {
                if clean_skip {
                    stats.blocks_skipped_clean += 1;
                } else {
                    stats.blocks_restored += 1;
                }
            } else {
                stats.absorb(s);
            }
        }
    }
    let a_finish = nodes[a_id].finished;
    let b_finish = b_ids.iter().map(|&id| nodes[id].finished).fold(a_finish, f64::max);
    let c_finish = c_ids.iter().map(|&id| nodes[id].finished).fold(b_finish, f64::max);
    let agg_finish = u_part_ids
        .iter()
        .chain(&v_part_ids)
        .map(|&id| nodes[id].finished)
        .fold(c_finish, f64::max);
    let mut timings = PhaseTimings {
        a: a_finish,
        b: b_finish - a_finish,
        c: c_finish - b_finish,
        aggregate: agg_finish - c_finish,
        total: 0.0,
    };

    // idle: worker-slot seconds not spent computing over the schedule
    // span — the straggler cost the barrier-free schedule removes
    let busy: f64 = nodes.iter().map(|r| r.busy()).sum();
    stats.idle_secs = (pool.threads as f64 * agg_finish - busy).max(0.0);
    // queue wait: earliest task start relative to the schedule clock (the
    // DAG driver's t0) — measured entirely inside the dispatch machinery,
    // so setup work (resume loading, centring, sink creation, DAG build)
    // can never leak into the fairness signal
    stats.queue_wait_secs = nodes
        .iter()
        .map(|r| r.started)
        .fold(f64::INFINITY, f64::min)
        .max(0.0);
    ctx.control.set_queue_wait(stats.queue_wait_secs);
    // overlap: phase-(c) compute that ran while phase-(b) stragglers
    // were still in flight (zero under the barrier scheduler)
    stats.overlap_secs = c_ids
        .iter()
        .map(|&id| (b_finish - nodes[id].started).clamp(0.0, nodes[id].busy()))
        .sum();
    // shard-cache counters (all zero for resident runs)
    let shard = ctx.control.shards.snapshot();
    stats.shard_hits = shard.hits;
    stats.shard_misses = shard.misses;
    stats.shard_prefetch_hits = shard.prefetch_hits;
    stats.shard_evictions = shard.evictions;
    stats.shard_bytes_peak = shard.peak_bytes;

    let mut u_post = nodes[u_part_ids[0]].output.part().clone();
    for &id in &u_part_ids[1..] {
        u_post = u_post.concat(nodes[id].output.part());
    }
    let mut v_post = nodes[v_part_ids[0]].output.part().clone();
    for &id in &v_part_ids[1..] {
        v_post = v_post.concat(nodes[id].output.part());
    }
    timings.total = t_total.elapsed().as_secs_f64();

    assert_eq!(u_post.n, rows, "U posterior row count");
    assert_eq!(v_post.n, cols, "V posterior row count");

    em.finished(timings.total, stats.blocks);

    Ok(TrainOutcome::Completed(Box::new(TrainResult {
        model: PosteriorModel::new(u_post, v_post, global_mean),
        grid: cfg.grid,
        timings,
        stats,
    })))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::BackendSpec;
    use crate::coordinator::Engine;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::metrics::rmse::mean_predictor_rmse;

    fn quick_cfg(k: usize) -> TrainConfig {
        TrainConfig::new(k)
            .with_backend(BackendSpec::Native)
            .with_sweeps(6, 20)
            .with_seed(1)
    }

    /// One-shot run on a private engine sized by the config.
    fn train_once(cfg: TrainConfig, train: &Coo) -> TrainResult {
        Engine::new(&cfg.backend, cfg.block_parallelism).train(&cfg, train).unwrap()
    }

    fn dataset() -> (Coo, Coo, usize) {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 21).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 22);
        (train, test, d.k)
    }

    #[test]
    fn pp_1x1_learns() {
        let (train, test, k) = dataset();
        let res = train_once(quick_cfg(k), &train);
        let rmse = res.rmse(&test);
        let base = mean_predictor_rmse(train.mean(), &test);
        assert!(rmse < base, "1x1 rmse {rmse} vs mean {base}");
        assert_eq!(res.stats.blocks, 1);
    }

    #[test]
    fn pp_grid_learns_and_phases_run() {
        let (train, test, k) = dataset();
        let res = train_once(quick_cfg(k).with_grid(3, 2), &train);
        let rmse = res.rmse(&test);
        let base = mean_predictor_rmse(train.mean(), &test);
        assert!(rmse < base, "3x2 rmse {rmse} vs mean {base}");
        assert_eq!(res.stats.blocks, 6);
        assert!(res.timings.b > 0.0 && res.timings.c > 0.0);
    }

    #[test]
    fn pp_rmse_close_to_plain_bmf() {
        // the paper's core ML claim: PP ≈ plain BMF in RMSE
        let (train, test, k) = dataset();
        let r1 = train_once(quick_cfg(k), &train);
        let r2 = train_once(quick_cfg(k).with_grid(2, 2), &train);
        let (a, b) = (r1.rmse(&test), r2.rmse(&test));
        assert!((a - b).abs() < 0.15 * a.max(b), "1x1={a} vs 2x2={b}");
    }

    #[test]
    fn row_heavy_grid_works() {
        let (train, test, k) = dataset();
        let res = train_once(quick_cfg(k).with_grid(4, 1), &train);
        assert!(res.rmse(&test).is_finite());
        assert_eq!(res.stats.blocks, 4);
        assert_eq!(res.u_post.n, train.rows);
    }

    #[test]
    fn predict_variance_positive() {
        let (train, _, k) = dataset();
        let res = train_once(quick_cfg(k), &train);
        let var = res.predict_variance(0, 0);
        assert!(var > 0.0 && var.is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let (train, _, k) = dataset();
        let r1 = train_once(quick_cfg(k).with_grid(2, 2), &train);
        let r2 = train_once(quick_cfg(k).with_grid(2, 2), &train);
        assert_eq!(r1.u_mean, r2.u_mean);
    }

    #[test]
    fn dag_matches_barrier_bitwise_across_worker_counts() {
        // out-of-order completion must not change a single bit of the
        // posterior: per-block seeds and canonical aggregation order make
        // the schedule irrelevant to the math
        let (train, _, k) = dataset();
        let mk = |mode: SchedulerMode, slots: usize| {
            let mut c = quick_cfg(k).with_grid(3, 4).with_scheduler(mode);
            c.block_parallelism = slots;
            train_once(c, &train)
        };
        let base = mk(SchedulerMode::Barrier, 4);
        for slots in [1usize, 2, 8] {
            let dag = mk(SchedulerMode::Dag, slots);
            assert_eq!(dag.u_post.mean, base.u_post.mean, "u mean, slots={slots}");
            assert_eq!(dag.u_post.prec, base.u_post.prec, "u prec, slots={slots}");
            assert_eq!(dag.v_post.mean, base.v_post.mean, "v mean, slots={slots}");
            assert_eq!(dag.v_post.prec, base.v_post.prec, "v prec, slots={slots}");
        }
    }

    #[test]
    fn pipelined_tau0_bitwise_equals_lockstep_end_to_end() {
        // τ = 0 pipelined sweeps must be invisible to the math across the
        // whole PP pipeline, grid and all
        use crate::coordinator::config::SweepMode;
        let (train, _, k) = dataset();
        let lock = train_once(quick_cfg(k).with_grid(2, 2).with_workers(2), &train);
        let pipe = train_once(
            quick_cfg(k)
                .with_grid(2, 2)
                .with_workers(2)
                .with_sweep_mode(SweepMode::Pipelined)
                .with_chunk_rows(16)
                .with_staleness(0),
            &train,
        );
        assert_eq!(pipe.u_post.mean, lock.u_post.mean);
        assert_eq!(pipe.u_post.prec, lock.u_post.prec);
        assert_eq!(pipe.v_post.mean, lock.v_post.mean);
        assert_eq!(pipe.v_post.prec, lock.v_post.prec);
        assert_eq!(lock.stats.comm_overlap_secs, 0.0, "lockstep never overlaps");
    }

    #[test]
    fn pipelined_stale_mode_learns_close_to_lockstep() {
        // τ > 0 trades bitwise equality for overlap; the fit must stay
        // statistically equivalent (RMSE within tolerance)
        use crate::coordinator::config::SweepMode;
        let (train, test, k) = dataset();
        let lock =
            train_once(quick_cfg(k).with_grid(2, 2), &train);
        let pipe = train_once(
            quick_cfg(k)
                .with_grid(2, 2)
                .with_workers(3)
                .with_sweep_mode(SweepMode::Pipelined)
                .with_chunk_rows(8)
                .with_staleness(2),
            &train,
        );
        let (a, b) = (lock.rmse(&test), pipe.rmse(&test));
        assert!((a - b).abs() < 0.15 * a.max(b), "lockstep={a} vs pipelined={b}");
        assert!(pipe.stats.comm_overlap_secs >= 0.0);
    }

    #[test]
    fn periodic_checkpoints_write_pruned_generations_and_resume_bitwise() {
        let (train, _, k) = dataset();
        let dir = std::env::temp_dir()
            .join(format!("bmfpp_trainer_gens_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = quick_cfg(k)
            .with_grid(3, 2)
            .with_checkpoint_every(2)
            .with_checkpoint_dir(&dir)
            .with_checkpoint_keep(2);
        let full = train_once(cfg.clone(), &train);
        assert_eq!(full.stats.blocks, 6);

        // 6 blocks at every=2 → generations 1, 2, 3; keep-last-2 retention
        // leaves exactly {2, 3}, and generation 3 covers all 6 blocks
        let gens: Vec<u64> = checkpoint::list_generations(&dir)
            .unwrap()
            .into_iter()
            .map(|(g, _)| g)
            .collect();
        assert_eq!(gens, vec![2, 3], "monotonic numbering + keep-last-K");
        let (newest, _) = checkpoint::latest_valid_partial(&dir).unwrap().unwrap();
        assert_eq!(newest.generation, 3);
        assert_eq!(newest.blocks.len(), 6);

        // resume pointed at the *directory* restores the newest generation
        // and reproduces the uninterrupted posterior bit for bit
        let resumed = train_once(cfg.clone().with_resume_from(&dir), &train);
        assert_eq!(resumed.stats.blocks_restored, 6);
        assert_eq!(resumed.u_post.mean, full.u_post.mean);
        assert_eq!(resumed.u_post.prec, full.u_post.prec);
        assert_eq!(resumed.v_post.mean, full.v_post.mean);
        assert_eq!(resumed.v_post.prec, full.v_post.prec);

        // drop the newest generation to model a crash that lost it: the
        // resume falls back to generation 2 (4 blocks), re-samples the
        // rest, still matches bitwise, and continues numbering monotonically
        std::fs::remove_file(checkpoint::generation_path(&dir, 3)).unwrap();
        let resumed = train_once(cfg.with_resume_from(&dir), &train);
        assert_eq!(resumed.stats.blocks_restored, 4);
        assert_eq!(resumed.stats.blocks, 2);
        assert_eq!(resumed.u_post.mean, full.u_post.mean);
        assert_eq!(resumed.v_post.mean, full.v_post.mean);
        let (newest, _) = checkpoint::latest_valid_partial(&dir).unwrap().unwrap();
        assert_eq!(newest.generation, 3, "numbering continues past the restored gen");
        assert_eq!(newest.blocks.len(), 6, "progress never shrinks");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn queue_wait_is_recorded() {
        let (train, _, k) = dataset();
        let res = train_once(quick_cfg(k).with_grid(2, 2), &train);
        assert!(res.stats.queue_wait_secs.is_finite());
        assert!(res.stats.queue_wait_secs >= 0.0);
        assert!(res.stats.queue_wait_secs < 60.0, "queue wait implausibly large");
    }

    #[test]
    fn barrier_mode_reports_zero_overlap() {
        let (train, _, k) = dataset();
        let mk = |mode: SchedulerMode| {
            train_once(quick_cfg(k).with_grid(3, 3).with_scheduler(mode), &train)
        };
        let bar = mk(SchedulerMode::Barrier);
        let dag = mk(SchedulerMode::Dag);
        // with barrier edges no phase-(c) block can start before the last
        // phase-(b) block finishes; the DAG schedule may overlap freely
        assert_eq!(bar.stats.overlap_secs, 0.0);
        assert!(dag.stats.overlap_secs >= 0.0);
        assert!(bar.stats.idle_secs >= 0.0 && dag.stats.idle_secs >= 0.0);
        assert_eq!(dag.u_mean, bar.u_mean, "scheduling must not change the posterior");
    }
}
