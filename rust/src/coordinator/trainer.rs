//! The top-level D-BMF+PP training pipeline.
//!
//! Phases (a) → (b) → (c) → aggregation are expressed as one dependency
//! DAG over block tasks: phase-(b) block (i,0) depends only on (0,0);
//! phase-(c) block (i,j) depends only on the row posterior from (i,0) and
//! the column posterior from (0,j); each aggregated posterior part depends
//! only on the blocks that feed it. Under [`SchedulerMode::Dag`] every
//! node is dispatched the moment its parents complete, so no phase waits
//! for the slowest straggler of the previous one. [`SchedulerMode::Barrier`]
//! adds edges from every phase-(b) block to every phase-(c) block (and
//! from all blocks to aggregation), reproducing the classic phase-barrier
//! schedule through the same machinery — both modes run the identical
//! per-block math with identical seeds and produce bitwise-identical
//! posteriors.
//!
//! The pipeline itself is [`run_pp`], invoked through
//! [`crate::coordinator::Engine`]; as it executes it streams typed
//! [`TrainEvent`]s to an optional sink. [`PpTrainer`] remains as a thin
//! compatibility facade over a one-shot engine.

use super::aggregate::aggregate_part;
use super::backend::{BlockBackend, BlockData};
use super::block_task::{
    run_block, BlockObs, BlockPosteriors, BlockRunStats, BlockTaskCfg, PpTaskOutput,
};
use super::config::{SchedulerMode, TrainConfig};
use super::engine::{Engine, EventSink, FactorSide, PpPhase, TrainEvent};
use super::scheduler::{DagScheduler, NodeId, WorkerPool};
use crate::data::sparse::Coo;
use crate::partition::Grid;
use crate::posterior::{PosteriorModel, RowGaussians};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Wall-clock seconds per PP phase, attributed from per-block completion
/// times: a phase's time is the gap between its last block finishing and
/// the previous phase's last block finishing (zero-clamped).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTimings {
    /// Seconds until the phase-(a) block finished.
    pub a: f64,
    /// Seconds between the last phase-(a) and last phase-(b) completion.
    pub b: f64,
    /// Seconds between the last phase-(b) and last phase-(c) completion.
    pub c: f64,
    /// Seconds between the last block and the last aggregation part.
    pub aggregate: f64,
    /// Wall-clock seconds of the whole run.
    pub total: f64,
}

/// Aggregate compute counters over all blocks.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunStats {
    /// Blocks sampled.
    pub blocks: usize,
    /// Total Gibbs sweeps across all blocks.
    pub sweeps: usize,
    /// Factor rows sampled across all blocks and sweeps.
    pub rows_processed: u64,
    /// Rating observations visited across all blocks and sweeps.
    pub ratings_processed: u64,
    /// Sum of per-block compute seconds (≥ wall-clock when parallel).
    pub compute_secs: f64,
    /// Worker-slot seconds spent waiting during the schedule (pool slots ×
    /// schedule span − busy seconds): the straggler cost a barrier
    /// schedule pays and the DAG schedule shrinks.
    pub idle_secs: f64,
    /// Phase-(c) compute seconds that ran before the last phase-(b) block
    /// finished — positive only under the dependency-driven scheduler.
    pub overlap_secs: f64,
    /// Within-block compute/communication overlap summed over all blocks:
    /// V-half-sweep compute seconds that ran while the U half-sweep was
    /// still sampling/publishing. Positive only under
    /// [`SweepMode::Pipelined`](super::config::SweepMode::Pipelined) —
    /// lockstep sweeps serialize exchange after compute by definition.
    pub comm_overlap_secs: f64,
}

impl RunStats {
    fn absorb(&mut self, s: &BlockRunStats) {
        self.blocks += 1;
        self.sweeps += s.sweeps;
        self.rows_processed += s.rows_processed;
        self.ratings_processed += s.ratings_processed;
        self.compute_secs += s.secs;
        self.comm_overlap_secs += s.comm_overlap_secs;
    }
}

/// Outcome of one training run: the servable [`PosteriorModel`] plus the
/// run's diagnostics (phase timings, scheduling stats, grid used).
///
/// Derefs to the model, so prediction/evaluation calls (`predict`, `rmse`,
/// `predict_variance`, `top_n`, field access like `u_post`) go straight
/// through; persist or serve `result.model` alone.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// The servable artifact — the only part a checkpoint stores.
    pub model: PosteriorModel,
    /// Block grid the run used.
    pub grid: (usize, usize),
    /// Wall-clock seconds attributed to each PP phase.
    pub timings: PhaseTimings,
    /// Aggregate compute and scheduling counters.
    pub stats: RunStats,
}

impl std::ops::Deref for TrainResult {
    type Target = PosteriorModel;

    fn deref(&self) -> &PosteriorModel {
        &self.model
    }
}

impl TrainResult {
    /// Extract the servable model, discarding run diagnostics.
    pub fn into_model(self) -> PosteriorModel {
        self.model
    }
}

/// Emits [`TrainEvent`]s from inside DAG task closures. Phase starts are
/// deduplicated with atomics because the first task of a phase is decided
/// by the scheduler at run time, not by construction order.
#[derive(Clone)]
struct Emitter {
    sink: Option<EventSink>,
    sweep_rmse: bool,
    phase_started: Arc<[AtomicBool; 4]>,
}

impl Emitter {
    fn new(sink: Option<EventSink>, sweep_rmse: bool) -> Emitter {
        Emitter {
            sink,
            sweep_rmse,
            phase_started: Arc::new([
                AtomicBool::new(false),
                AtomicBool::new(false),
                AtomicBool::new(false),
                AtomicBool::new(false),
            ]),
        }
    }

    fn phase(&self, phase: PpPhase) {
        let Some(sink) = &self.sink else { return };
        if !self.phase_started[phase as usize].swap(true, Ordering::Relaxed) {
            sink(TrainEvent::PhaseStarted { phase });
        }
    }

    fn block_done(&self, node: (usize, usize), phase: PpPhase, stats: &BlockRunStats) {
        if let Some(sink) = &self.sink {
            sink(TrainEvent::BlockCompleted {
                node,
                phase,
                secs: stats.secs,
                sweeps: stats.sweeps,
            });
        }
    }

    /// Per-sweep observer for one block, or None when nobody listens or
    /// the config disabled sweep streaming (the block then skips the
    /// per-sweep RMSE computation entirely).
    fn sweep_observer(&self, node: (usize, usize)) -> Option<Box<dyn Fn(usize, f64)>> {
        if !self.sweep_rmse {
            return None;
        }
        let sink = self.sink.clone()?;
        Some(Box::new(move |sweep, rmse| {
            sink(TrainEvent::SweepSample { node, sweep, rmse })
        }))
    }

    /// Per-chunk publication observer for one block (pipelined sweeps),
    /// or None when nobody listens. Called from worker threads, hence the
    /// `Sync` bound.
    fn chunk_observer(
        &self,
        node: (usize, usize),
    ) -> Option<Box<dyn Fn(FactorSide, usize, usize, u64) + Sync>> {
        let sink = self.sink.clone()?;
        Some(Box::new(move |side, sweep, chunk, seq| {
            sink(TrainEvent::ChunkExchanged { node, side, sweep, chunk, seq })
        }))
    }

    fn finished(&self, secs: f64, blocks: usize) {
        if let Some(sink) = &self.sink {
            sink(TrainEvent::Finished { secs, blocks });
        }
    }
}

fn pick_u(bp: &BlockPosteriors) -> &RowGaussians {
    &bp.u
}

fn pick_v(bp: &BlockPosteriors) -> &RowGaussians {
    &bp.v
}

/// Add one aggregation node: `prior` (a block node) refined by the block
/// nodes in `posts`, consumed in the given canonical order; `join` is the
/// barrier-mode phase join, appended after the posts so the task's parent
/// slice never includes it. Encodes the parent-slice bound (`posts.len()`)
/// exactly once for all four U/V part shapes.
fn add_part(
    dag: &mut DagScheduler<PpTaskOutput>,
    prior: NodeId,
    posts: &[NodeId],
    join: Option<NodeId>,
    ridge: f64,
    pick: fn(&BlockPosteriors) -> &RowGaussians,
    em: &Emitter,
) -> NodeId {
    let mut edges = Vec::with_capacity(posts.len() + 2);
    edges.push(prior);
    edges.extend_from_slice(posts);
    if let Some(j) = join {
        edges.push(j);
    }
    let n_posts = posts.len();
    let em = em.clone();
    dag.add(&edges, move |_b: &BlockBackend, p: &[Arc<PpTaskOutput>]| {
        em.phase(PpPhase::Aggregate);
        let posts: Vec<&RowGaussians> =
            p[1..1 + n_posts].iter().map(|q| pick(q.block())).collect();
        Ok(PpTaskOutput::Part(aggregate_part(pick(p[0].block()), &posts, ridge)))
    })
}

fn block_seed(cfg: &TrainConfig, i: usize, j: usize) -> u64 {
    cfg.seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((i as u64) << 32 | j as u64)
}

fn task_cfg(cfg: &TrainConfig, samples: usize, seed: u64) -> BlockTaskCfg {
    BlockTaskCfg {
        k: cfg.k,
        tau: cfg.tau,
        burnin: cfg.burnin,
        samples,
        workers: cfg.workers,
        ridge: cfg.ridge,
        seed,
        sweep: cfg.sweep,
        chunk_rows: cfg.chunk_rows,
        staleness: cfg.staleness,
    }
}

/// Mean-centre a training matrix into a private copy: the factors model
/// the residual, the global mean is restored at prediction — standard for
/// all methods compared in the paper.
pub(crate) fn center(train: &Coo) -> (Coo, f64) {
    let global_mean = train.mean();
    let mut centered = train.clone();
    for e in centered.entries.iter_mut() {
        e.val -= global_mean as f32;
    }
    (centered, global_mean)
}

/// Run the full PP pipeline for `cfg` on a caller-owned worker pool,
/// streaming progress to `sink` (if any).
pub(crate) fn run_pp(
    cfg: &TrainConfig,
    pool: &WorkerPool,
    train: &Coo,
    sink: Option<EventSink>,
) -> anyhow::Result<TrainResult> {
    cfg.validate(train.rows, train.cols)?;
    let (centered, global_mean) = center(train);
    run_pp_centered(cfg, pool, centered, global_mean, sink)
}

/// [`run_pp`] over an already mean-centred matrix the caller gives away —
/// the path `Engine::submit` uses so a session holds exactly one private
/// copy of the data (centring happens during that one clone) instead of
/// clone-for-the-thread plus clone-for-centring.
pub(crate) fn run_pp_centered(
    cfg: &TrainConfig,
    pool: &WorkerPool,
    train: Coo,
    global_mean: f64,
    sink: Option<EventSink>,
) -> anyhow::Result<TrainResult> {
    cfg.validate(train.rows, train.cols)?;
    let em = Emitter::new(sink, cfg.stream_sweep_rmse);
    let train = &train;

    let (gi, gj) = cfg.grid;
    let grid = Grid::new(train.rows, train.cols, gi, gj);
    let mut blocks = grid.split(train);
    let t_total = std::time::Instant::now();
    let barrier = cfg.scheduler == SchedulerMode::Barrier;
    let ridge = cfg.ridge;
    let phase_samples = cfg.phase_samples();

    let mut dag: DagScheduler<PpTaskOutput> = DagScheduler::new();
    let mut take = |i: usize, j: usize| {
        BlockData::new(std::mem::replace(&mut blocks[i][j], Coo::new(0, 0)))
    };

    // ---- Phase (a): block (0,0), fresh priors both sides ----
    let a_data = take(0, 0);
    let cfg_a = task_cfg(cfg, cfg.samples, block_seed(cfg, 0, 0));
    let em_a = em.clone();
    let a_id = dag.add(&[], move |b: &BlockBackend, _p: &[Arc<PpTaskOutput>]| {
        em_a.phase(PpPhase::A);
        let sweep_obs = em_a.sweep_observer((0, 0));
        let chunk_obs = em_a.chunk_observer((0, 0));
        let obs = BlockObs { sweep: sweep_obs.as_deref(), chunk: chunk_obs.as_deref() };
        let (post, stats) = run_block(b, &a_data, &cfg_a, None, None, obs)?;
        em_a.block_done((0, 0), PpPhase::A, &stats);
        Ok(PpTaskOutput::Block(post, stats))
    });

    // ---- Phase (b): first-row and first-column blocks; each depends
    // only on (a), whose posterior it consumes as a prior ----
    let mut b_row_ids: Vec<NodeId> = vec![a_id; gi];
    let mut b_col_ids: Vec<NodeId> = vec![a_id; gj];
    let mut b_ids: Vec<NodeId> = Vec::new();
    for i in 1..gi {
        let data = take(i, 0);
        let bcfg = task_cfg(cfg, phase_samples, block_seed(cfg, i, 0));
        let em_b = em.clone();
        let id = dag.add(&[a_id], move |b: &BlockBackend, p: &[Arc<PpTaskOutput>]| {
            em_b.phase(PpPhase::B);
            let sweep_obs = em_b.sweep_observer((i, 0));
            let chunk_obs = em_b.chunk_observer((i, 0));
            let obs = BlockObs { sweep: sweep_obs.as_deref(), chunk: chunk_obs.as_deref() };
            let (post, stats) = run_block(b, &data, &bcfg, None, Some(&p[0].block().v), obs)?;
            em_b.block_done((i, 0), PpPhase::B, &stats);
            Ok(PpTaskOutput::Block(post, stats))
        });
        b_row_ids[i] = id;
        b_ids.push(id);
    }
    for j in 1..gj {
        let data = take(0, j);
        let bcfg = task_cfg(cfg, phase_samples, block_seed(cfg, 0, j));
        let em_b = em.clone();
        let id = dag.add(&[a_id], move |b: &BlockBackend, p: &[Arc<PpTaskOutput>]| {
            em_b.phase(PpPhase::B);
            let sweep_obs = em_b.sweep_observer((0, j));
            let chunk_obs = em_b.chunk_observer((0, j));
            let obs = BlockObs { sweep: sweep_obs.as_deref(), chunk: chunk_obs.as_deref() };
            let (post, stats) = run_block(b, &data, &bcfg, Some(&p[0].block().u), None, obs)?;
            em_b.block_done((0, j), PpPhase::B, &stats);
            Ok(PpTaskOutput::Block(post, stats))
        });
        b_col_ids[j] = id;
        b_ids.push(id);
    }

    // barrier mode: one synthetic join node per phase keeps the edge
    // count linear in the block count — every phase-(c) block waits on
    // this single node instead of on each of the I+J-2 (b) blocks
    let b_join = (barrier && !b_ids.is_empty()).then(|| {
        dag.add(&b_ids, |_b: &BlockBackend, _p: &[Arc<PpTaskOutput>]| {
            Ok(PpTaskOutput::Barrier)
        })
    });

    // ---- Phase (c): interior block (i,j) depends on its two real
    // parents (i,0) and (0,j); barrier mode adds the phase-(b) join,
    // restoring the old full phase barrier ----
    let mut c_ids: Vec<NodeId> = Vec::new();
    let mut c_id_at = vec![vec![a_id; gj]; gi];
    for i in 1..gi {
        for j in 1..gj {
            let data = take(i, j);
            let bcfg = task_cfg(cfg, phase_samples, block_seed(cfg, i, j));
            let mut edges = vec![b_row_ids[i], b_col_ids[j]];
            if let Some(join) = b_join {
                edges.push(join);
            }
            let em_c = em.clone();
            let id = dag.add(&edges, move |b: &BlockBackend, p: &[Arc<PpTaskOutput>]| {
                em_c.phase(PpPhase::C);
                let sweep_obs = em_c.sweep_observer((i, j));
                let chunk_obs = em_c.chunk_observer((i, j));
                let obs =
                    BlockObs { sweep: sweep_obs.as_deref(), chunk: chunk_obs.as_deref() };
                let (post, stats) = run_block(
                    b,
                    &data,
                    &bcfg,
                    Some(&p[0].block().u),
                    Some(&p[1].block().v),
                    obs,
                )?;
                em_c.block_done((i, j), PpPhase::C, &stats);
                Ok(PpTaskOutput::Block(post, stats))
            });
            c_ids.push(id);
            c_id_at[i][j] = id;
        }
    }

    // barrier mode: aggregation waits for the slower of the two phase
    // joins (phase (c) when interior blocks exist, else phase (b))
    let c_join = (barrier && !c_ids.is_empty()).then(|| {
        dag.add(&c_ids, |_b: &BlockBackend, _p: &[Arc<PpTaskOutput>]| {
            Ok(PpTaskOutput::Barrier)
        })
    });
    let agg_join = c_join.or(b_join);

    // ---- Aggregation as DAG nodes: each row/column part starts the
    // moment its own inputs exist instead of after every block.
    // Inputs are consumed in canonical (i, j) order, so the floating-
    // point reduction is identical whatever the completion order. ----
    let mut u_part_ids: Vec<NodeId> = Vec::with_capacity(gi);
    let mut v_part_ids: Vec<NodeId> = Vec::with_capacity(gj);
    // U^(0): phase-a posterior refined by the phase-b column blocks
    let posts: Vec<NodeId> = (1..gj).map(|j| b_col_ids[j]).collect();
    u_part_ids.push(add_part(&mut dag, a_id, &posts, agg_join, ridge, pick_u, &em));
    // U^(i): phase-b row posterior refined by row i's (c) blocks
    for i in 1..gi {
        let posts: Vec<NodeId> = (1..gj).map(|j| c_id_at[i][j]).collect();
        u_part_ids.push(add_part(&mut dag, b_row_ids[i], &posts, agg_join, ridge, pick_u, &em));
    }
    // V^(0): phase-a posterior refined by the phase-b row blocks
    let posts: Vec<NodeId> = (1..gi).map(|i| b_row_ids[i]).collect();
    v_part_ids.push(add_part(&mut dag, a_id, &posts, agg_join, ridge, pick_v, &em));
    // V^(j): phase-b column posterior refined by column j's (c) blocks
    for j in 1..gj {
        let posts: Vec<NodeId> = (1..gi).map(|i| c_id_at[i][j]).collect();
        v_part_ids.push(add_part(&mut dag, b_col_ids[j], &posts, agg_join, ridge, pick_v, &em));
    }

    let nodes = dag.run(pool)?;

    // ---- stats + phase attribution from per-node completion times ----
    let mut stats = RunStats::default();
    for res in &nodes {
        if let Some(s) = res.output.block_stats() {
            stats.absorb(s);
        }
    }
    let a_finish = nodes[a_id].finished;
    let b_finish = b_ids.iter().map(|&id| nodes[id].finished).fold(a_finish, f64::max);
    let c_finish = c_ids.iter().map(|&id| nodes[id].finished).fold(b_finish, f64::max);
    let agg_finish = u_part_ids
        .iter()
        .chain(&v_part_ids)
        .map(|&id| nodes[id].finished)
        .fold(c_finish, f64::max);
    let mut timings = PhaseTimings {
        a: a_finish,
        b: b_finish - a_finish,
        c: c_finish - b_finish,
        aggregate: agg_finish - c_finish,
        total: 0.0,
    };

    // idle: worker-slot seconds not spent computing over the schedule
    // span — the straggler cost the barrier-free schedule removes
    let busy: f64 = nodes.iter().map(|r| r.busy()).sum();
    stats.idle_secs = (pool.threads as f64 * agg_finish - busy).max(0.0);
    // overlap: phase-(c) compute that ran while phase-(b) stragglers
    // were still in flight (zero under the barrier scheduler)
    stats.overlap_secs = c_ids
        .iter()
        .map(|&id| (b_finish - nodes[id].started).clamp(0.0, nodes[id].busy()))
        .sum();

    let mut u_post = nodes[u_part_ids[0]].output.part().clone();
    for &id in &u_part_ids[1..] {
        u_post = u_post.concat(nodes[id].output.part());
    }
    let mut v_post = nodes[v_part_ids[0]].output.part().clone();
    for &id in &v_part_ids[1..] {
        v_post = v_post.concat(nodes[id].output.part());
    }
    timings.total = t_total.elapsed().as_secs_f64();

    assert_eq!(u_post.n, train.rows, "U posterior row count");
    assert_eq!(v_post.n, train.cols, "V posterior row count");

    em.finished(timings.total, stats.blocks);

    Ok(TrainResult {
        model: PosteriorModel::new(u_post, v_post, global_mean),
        grid: cfg.grid,
        timings,
        stats,
    })
}

/// Legacy one-shot trainer facade.
///
/// **Deprecated** in favour of [`Engine`]: each `train` call builds (and
/// tears down) a private single-run engine, so nothing is kept warm across
/// runs and no progress events are observable. Kept for one release so
/// existing callers and the DAG/Barrier equivalence tests compile
/// unchanged; both paths execute the identical [`run_pp`] pipeline.
pub struct PpTrainer {
    /// The training configuration every `train` call runs with.
    pub cfg: TrainConfig,
}

impl PpTrainer {
    /// Wrap a configuration in the legacy one-shot facade.
    pub fn new(cfg: TrainConfig) -> PpTrainer {
        PpTrainer { cfg }
    }

    /// Run the full PP pipeline on a training matrix through a fresh
    /// one-shot [`Engine`] sized by `cfg.block_parallelism`.
    pub fn train(&self, train: &Coo) -> anyhow::Result<TrainResult> {
        Engine::new(&self.cfg.backend, self.cfg.block_parallelism).train(&self.cfg, train)
    }

    /// `train` against a caller-owned worker pool — reuses the per-thread
    /// PJRT engines (compiled executables) across multiple training runs.
    /// Prefer an [`Engine`], which owns such a pool.
    pub fn train_with_pool(&self, pool: &WorkerPool, train: &Coo) -> anyhow::Result<TrainResult> {
        run_pp(&self.cfg, pool, train, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::BackendSpec;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::metrics::rmse::mean_predictor_rmse;

    fn quick_cfg(k: usize) -> TrainConfig {
        TrainConfig::new(k)
            .with_backend(BackendSpec::Native)
            .with_sweeps(6, 20)
            .with_seed(1)
    }

    fn dataset() -> (Coo, Coo, usize) {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 21).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 22);
        (train, test, d.k)
    }

    #[test]
    fn pp_1x1_learns() {
        let (train, test, k) = dataset();
        let res = PpTrainer::new(quick_cfg(k)).train(&train).unwrap();
        let rmse = res.rmse(&test);
        let base = mean_predictor_rmse(train.mean(), &test);
        assert!(rmse < base, "1x1 rmse {rmse} vs mean {base}");
        assert_eq!(res.stats.blocks, 1);
    }

    #[test]
    fn pp_grid_learns_and_phases_run() {
        let (train, test, k) = dataset();
        let res = PpTrainer::new(quick_cfg(k).with_grid(3, 2)).train(&train).unwrap();
        let rmse = res.rmse(&test);
        let base = mean_predictor_rmse(train.mean(), &test);
        assert!(rmse < base, "3x2 rmse {rmse} vs mean {base}");
        assert_eq!(res.stats.blocks, 6);
        assert!(res.timings.b > 0.0 && res.timings.c > 0.0);
    }

    #[test]
    fn pp_rmse_close_to_plain_bmf() {
        // the paper's core ML claim: PP ≈ plain BMF in RMSE
        let (train, test, k) = dataset();
        let r1 = PpTrainer::new(quick_cfg(k)).train(&train).unwrap();
        let r2 = PpTrainer::new(quick_cfg(k).with_grid(2, 2)).train(&train).unwrap();
        let (a, b) = (r1.rmse(&test), r2.rmse(&test));
        assert!((a - b).abs() < 0.15 * a.max(b), "1x1={a} vs 2x2={b}");
    }

    #[test]
    fn row_heavy_grid_works() {
        let (train, test, k) = dataset();
        let res = PpTrainer::new(quick_cfg(k).with_grid(4, 1)).train(&train).unwrap();
        assert!(res.rmse(&test).is_finite());
        assert_eq!(res.stats.blocks, 4);
        assert_eq!(res.u_post.n, train.rows);
    }

    #[test]
    fn predict_variance_positive() {
        let (train, _, k) = dataset();
        let res = PpTrainer::new(quick_cfg(k)).train(&train).unwrap();
        let var = res.predict_variance(0, 0);
        assert!(var > 0.0 && var.is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let (train, _, k) = dataset();
        let r1 = PpTrainer::new(quick_cfg(k).with_grid(2, 2)).train(&train).unwrap();
        let r2 = PpTrainer::new(quick_cfg(k).with_grid(2, 2)).train(&train).unwrap();
        assert_eq!(r1.u_mean, r2.u_mean);
    }

    #[test]
    fn dag_matches_barrier_bitwise_across_worker_counts() {
        // out-of-order completion must not change a single bit of the
        // posterior: per-block seeds and canonical aggregation order make
        // the schedule irrelevant to the math
        let (train, _, k) = dataset();
        let mk = |mode: SchedulerMode, slots: usize| {
            let mut c = quick_cfg(k).with_grid(3, 4).with_scheduler(mode);
            c.block_parallelism = slots;
            PpTrainer::new(c).train(&train).unwrap()
        };
        let base = mk(SchedulerMode::Barrier, 4);
        for slots in [1usize, 2, 8] {
            let dag = mk(SchedulerMode::Dag, slots);
            assert_eq!(dag.u_post.mean, base.u_post.mean, "u mean, slots={slots}");
            assert_eq!(dag.u_post.prec, base.u_post.prec, "u prec, slots={slots}");
            assert_eq!(dag.v_post.mean, base.v_post.mean, "v mean, slots={slots}");
            assert_eq!(dag.v_post.prec, base.v_post.prec, "v prec, slots={slots}");
        }
    }

    #[test]
    fn pipelined_tau0_bitwise_equals_lockstep_end_to_end() {
        // τ = 0 pipelined sweeps must be invisible to the math across the
        // whole PP pipeline, grid and all
        use crate::coordinator::config::SweepMode;
        let (train, _, k) = dataset();
        let lock = PpTrainer::new(quick_cfg(k).with_grid(2, 2).with_workers(2))
            .train(&train)
            .unwrap();
        let pipe = PpTrainer::new(
            quick_cfg(k)
                .with_grid(2, 2)
                .with_workers(2)
                .with_sweep_mode(SweepMode::Pipelined)
                .with_chunk_rows(16)
                .with_staleness(0),
        )
        .train(&train)
        .unwrap();
        assert_eq!(pipe.u_post.mean, lock.u_post.mean);
        assert_eq!(pipe.u_post.prec, lock.u_post.prec);
        assert_eq!(pipe.v_post.mean, lock.v_post.mean);
        assert_eq!(pipe.v_post.prec, lock.v_post.prec);
        assert_eq!(lock.stats.comm_overlap_secs, 0.0, "lockstep never overlaps");
    }

    #[test]
    fn pipelined_stale_mode_learns_close_to_lockstep() {
        // τ > 0 trades bitwise equality for overlap; the fit must stay
        // statistically equivalent (RMSE within tolerance)
        use crate::coordinator::config::SweepMode;
        let (train, test, k) = dataset();
        let lock =
            PpTrainer::new(quick_cfg(k).with_grid(2, 2)).train(&train).unwrap();
        let pipe = PpTrainer::new(
            quick_cfg(k)
                .with_grid(2, 2)
                .with_workers(3)
                .with_sweep_mode(SweepMode::Pipelined)
                .with_chunk_rows(8)
                .with_staleness(2),
        )
        .train(&train)
        .unwrap();
        let (a, b) = (lock.rmse(&test), pipe.rmse(&test));
        assert!((a - b).abs() < 0.15 * a.max(b), "lockstep={a} vs pipelined={b}");
        assert!(pipe.stats.comm_overlap_secs >= 0.0);
    }

    #[test]
    fn barrier_mode_reports_zero_overlap() {
        let (train, _, k) = dataset();
        let mk = |mode: SchedulerMode| {
            PpTrainer::new(quick_cfg(k).with_grid(3, 3).with_scheduler(mode))
                .train(&train)
                .unwrap()
        };
        let bar = mk(SchedulerMode::Barrier);
        let dag = mk(SchedulerMode::Dag);
        // with barrier edges no phase-(c) block can start before the last
        // phase-(b) block finishes; the DAG schedule may overlap freely
        assert_eq!(bar.stats.overlap_secs, 0.0);
        assert!(dag.stats.overlap_secs >= 0.0);
        assert!(bar.stats.idle_secs >= 0.0 && dag.stats.idle_secs >= 0.0);
        assert_eq!(dag.u_mean, bar.u_mean, "scheduling must not change the posterior");
    }
}
