//! The top-level D-BMF+PP trainer: phases (a) → (b) → (c) → aggregation.

use super::aggregate::aggregate_rows;
use super::backend::{BlockBackend, BlockData};
use super::block_task::{run_block, BlockPosteriors, BlockRunStats, BlockTaskCfg};
use super::config::TrainConfig;
use super::scheduler::WorkerPool;
use crate::data::sparse::Coo;
use crate::metrics::rmse::rmse_factors;
use crate::partition::Grid;
use crate::posterior::RowGaussians;

/// Wall-clock seconds per PP phase.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTimings {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub aggregate: f64,
    pub total: f64,
}

/// Aggregate compute counters over all blocks.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunStats {
    pub blocks: usize,
    pub sweeps: usize,
    pub rows_processed: u64,
    pub ratings_processed: u64,
    /// Sum of per-block compute seconds (≥ wall-clock when parallel).
    pub compute_secs: f64,
}

impl RunStats {
    fn absorb(&mut self, s: &BlockRunStats) {
        self.blocks += 1;
        self.sweeps += s.sweeps;
        self.rows_processed += s.rows_processed;
        self.ratings_processed += s.ratings_processed;
        self.compute_secs += s.secs;
    }
}

/// The trained model: aggregated posterior marginals over all factor rows.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub k: usize,
    pub grid: (usize, usize),
    pub u_post: RowGaussians,
    pub v_post: RowGaussians,
    /// Posterior means as f32 factors (rows×k, cols×k) for fast prediction.
    pub u_mean: Vec<f32>,
    pub v_mean: Vec<f32>,
    /// Global rating mean (training is mean-centred; predictions add it back).
    pub global_mean: f64,
    pub timings: PhaseTimings,
    pub stats: RunStats,
}

impl TrainResult {
    /// Posterior-mean prediction for one cell.
    pub fn predict(&self, row: usize, col: usize) -> f64 {
        self.global_mean
            + (0..self.k)
                .map(|j| (self.u_mean[row * self.k + j] * self.v_mean[col * self.k + j]) as f64)
                .sum::<f64>()
    }

    /// RMSE of posterior-mean predictions on a held-out set.
    pub fn rmse(&self, test: &Coo) -> f64 {
        if self.global_mean == 0.0 {
            rmse_factors(&self.u_mean, &self.v_mean, self.k, test)
        } else {
            crate::metrics::rmse::rmse_with(test, |r, c| self.predict(r, c))
        }
    }

    /// Predictive variance of one cell from the factor posteriors
    /// (delta-method approximation: uᵀΣ_v u + vᵀΣ_u v + tr(Σ_u Σ_v)).
    pub fn predict_variance(&self, row: usize, col: usize) -> f64 {
        let k = self.k;
        let su = self.u_post.row_prec(row);
        let sv = self.v_post.row_prec(col);
        let cu = crate::linalg::Cholesky::new(&su).map(|c| c.inverse());
        let cv = crate::linalg::Cholesky::new(&sv).map(|c| c.inverse());
        let (cu, cv) = match (cu, cv) {
            (Ok(a), Ok(b)) => (a, b),
            _ => return f64::NAN,
        };
        let u: Vec<f64> = (0..k).map(|j| self.u_mean[row * k + j] as f64).collect();
        let v: Vec<f64> = (0..k).map(|j| self.v_mean[col * k + j] as f64).collect();
        let vsv = cv.matvec(&u);
        let usu = cu.matvec(&v);
        let term1: f64 = u.iter().zip(&vsv).map(|(a, b)| a * b).sum();
        let term2: f64 = v.iter().zip(&usu).map(|(a, b)| a * b).sum();
        let term3: f64 = (0..k).map(|a| (0..k).map(|b| cu[(a, b)] * cv[(b, a)]).sum::<f64>()).sum();
        term1 + term2 + term3
    }
}

/// Posterior-Propagation trainer.
pub struct PpTrainer {
    pub cfg: TrainConfig,
}

impl PpTrainer {
    pub fn new(cfg: TrainConfig) -> PpTrainer {
        PpTrainer { cfg }
    }

    fn block_seed(&self, i: usize, j: usize) -> u64 {
        self.cfg
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((i as u64) << 32 | j as u64)
    }

    fn task_cfg(&self, samples: usize, seed: u64) -> BlockTaskCfg {
        BlockTaskCfg {
            k: self.cfg.k,
            tau: self.cfg.tau,
            burnin: self.cfg.burnin,
            samples,
            workers: self.cfg.workers,
            ridge: self.cfg.ridge,
            seed,
        }
    }

    /// Run the full PP pipeline on a training matrix.
    ///
    /// Ratings are mean-centred before inference (the factors model the
    /// residual, the global mean is restored at prediction) — standard for
    /// all methods compared in the paper.
    pub fn train(&self, train: &Coo) -> anyhow::Result<TrainResult> {
        let pool = WorkerPool::new(&self.cfg.backend, self.cfg.block_parallelism);
        self.train_with_pool(&pool, train)
    }

    /// `train` against a caller-owned worker pool — reuses the per-thread
    /// PJRT engines (compiled executables) across multiple training runs;
    /// use this for repeated/warm-measured runs (benches, learning curves).
    pub fn train_with_pool(&self, pool: &WorkerPool, train: &Coo) -> anyhow::Result<TrainResult> {
        let global_mean = train.mean();
        let mut centered = train.clone();
        for e in centered.entries.iter_mut() {
            e.val -= global_mean as f32;
        }
        let train = &centered;

        let (gi, gj) = self.cfg.grid;
        let grid = Grid::new(train.rows, train.cols, gi, gj);
        let mut blocks = grid.split(train);
        let k = self.cfg.k;
        let t_total = std::time::Instant::now();
        let mut timings = PhaseTimings::default();
        let mut stats = RunStats::default();

        // ---- Phase (a): block (0,0), fresh priors both sides ----
        let t0 = std::time::Instant::now();
        let a_data = BlockData::new(std::mem::replace(&mut blocks[0][0], Coo::new(0, 0)));
        let cfg_a = self.task_cfg(self.cfg.samples, self.block_seed(0, 0));
        let (q_a, s_a) = pool
            .run_phase(vec![move |b: &BlockBackend| run_block(b, &a_data, &cfg_a, None, None)])?
            .pop()
            .unwrap();
        stats.absorb(&s_a);
        timings.a = t0.elapsed().as_secs_f64();

        // ---- Phase (b): first row + first column in parallel ----
        let t0 = std::time::Instant::now();
        let phase_samples = self.cfg.phase_samples();
        enum BTag {
            Row(usize),
            Col(usize),
        }
        let mut b_tags = Vec::new();
        let mut b_tasks: Vec<Box<dyn FnOnce(&BlockBackend) -> anyhow::Result<(BlockPosteriors, BlockRunStats)> + Send>> =
            Vec::new();
        for i in 1..gi {
            let data = BlockData::new(std::mem::replace(&mut blocks[i][0], Coo::new(0, 0)));
            let cfg = self.task_cfg(phase_samples, self.block_seed(i, 0));
            let v_prior = q_a.v.clone();
            b_tags.push(BTag::Row(i));
            b_tasks.push(Box::new(move |b| run_block(b, &data, &cfg, None, Some(&v_prior))));
        }
        for j in 1..gj {
            let data = BlockData::new(std::mem::replace(&mut blocks[0][j], Coo::new(0, 0)));
            let cfg = self.task_cfg(phase_samples, self.block_seed(0, j));
            let u_prior = q_a.u.clone();
            b_tags.push(BTag::Col(j));
            b_tasks.push(Box::new(move |b| run_block(b, &data, &cfg, Some(&u_prior), None)));
        }
        let b_results = pool.run_phase(b_tasks)?;
        let mut q_b_row: Vec<Option<BlockPosteriors>> = (0..gi).map(|_| None).collect();
        let mut q_b_col: Vec<Option<BlockPosteriors>> = (0..gj).map(|_| None).collect();
        for (tag, (post, s)) in b_tags.iter().zip(b_results) {
            stats.absorb(&s);
            match tag {
                BTag::Row(i) => q_b_row[*i] = Some(post),
                BTag::Col(j) => q_b_col[*j] = Some(post),
            }
        }
        timings.b = t0.elapsed().as_secs_f64();

        // ---- Phase (c): interior blocks in parallel ----
        let t0 = std::time::Instant::now();
        let mut c_tags = Vec::new();
        let mut c_tasks: Vec<Box<dyn FnOnce(&BlockBackend) -> anyhow::Result<(BlockPosteriors, BlockRunStats)> + Send>> =
            Vec::new();
        for i in 1..gi {
            for j in 1..gj {
                let data =
                    BlockData::new(std::mem::replace(&mut blocks[i][j], Coo::new(0, 0)));
                let cfg = self.task_cfg(phase_samples, self.block_seed(i, j));
                let u_prior = q_b_row[i].as_ref().unwrap().u.clone();
                let v_prior = q_b_col[j].as_ref().unwrap().v.clone();
                c_tags.push((i, j));
                c_tasks.push(Box::new(move |b| {
                    run_block(b, &data, &cfg, Some(&u_prior), Some(&v_prior))
                }));
            }
        }
        let c_results = pool.run_phase(c_tasks)?;
        let mut q_c: std::collections::HashMap<(usize, usize), BlockPosteriors> =
            std::collections::HashMap::new();
        for (&(i, j), (post, s)) in c_tags.iter().zip(c_results) {
            stats.absorb(&s);
            q_c.insert((i, j), post);
        }
        timings.c = t0.elapsed().as_secs_f64();

        // ---- Aggregation ----
        let t0 = std::time::Instant::now();
        let ridge = self.cfg.ridge;
        // U^(0): phase-a posterior refined by the phase-b column blocks
        let mut u_parts: Vec<RowGaussians> = Vec::with_capacity(gi);
        {
            let posts: Vec<&RowGaussians> =
                (1..gj).map(|j| &q_b_col[j].as_ref().unwrap().u).collect();
            u_parts.push(if posts.is_empty() {
                q_a.u.clone()
            } else {
                aggregate_rows(&posts, Some(&q_a.u), ridge)
            });
        }
        // U^(i), i ≥ 1: phase-b row posterior refined by phase-c blocks
        for i in 1..gi {
            let prior = &q_b_row[i].as_ref().unwrap().u;
            let posts: Vec<&RowGaussians> = (1..gj).map(|j| &q_c[&(i, j)].u).collect();
            u_parts.push(if posts.is_empty() {
                prior.clone()
            } else {
                aggregate_rows(&posts, Some(prior), ridge)
            });
        }
        // V^(0) and V^(j)
        let mut v_parts: Vec<RowGaussians> = Vec::with_capacity(gj);
        {
            let posts: Vec<&RowGaussians> =
                (1..gi).map(|i| &q_b_row[i].as_ref().unwrap().v).collect();
            v_parts.push(if posts.is_empty() {
                q_a.v.clone()
            } else {
                aggregate_rows(&posts, Some(&q_a.v), ridge)
            });
        }
        for j in 1..gj {
            let prior = &q_b_col[j].as_ref().unwrap().v;
            let posts: Vec<&RowGaussians> = (1..gi).map(|i| &q_c[&(i, j)].v).collect();
            v_parts.push(if posts.is_empty() {
                prior.clone()
            } else {
                aggregate_rows(&posts, Some(prior), ridge)
            });
        }

        let mut u_post = u_parts[0].clone();
        for p in &u_parts[1..] {
            u_post = u_post.concat(p);
        }
        let mut v_post = v_parts[0].clone();
        for p in &v_parts[1..] {
            v_post = v_post.concat(p);
        }
        timings.aggregate = t0.elapsed().as_secs_f64();
        timings.total = t_total.elapsed().as_secs_f64();

        assert_eq!(u_post.n, train.rows, "U posterior row count");
        assert_eq!(v_post.n, train.cols, "V posterior row count");

        let u_mean: Vec<f32> = u_post.mean.iter().map(|&x| x as f32).collect();
        let v_mean: Vec<f32> = v_post.mean.iter().map(|&x| x as f32).collect();

        Ok(TrainResult {
            k,
            grid: self.cfg.grid,
            u_post,
            v_post,
            u_mean,
            v_mean,
            global_mean,
            timings,
            stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::BackendSpec;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::metrics::rmse::mean_predictor_rmse;

    fn quick_cfg(k: usize) -> TrainConfig {
        TrainConfig::new(k)
            .with_backend(BackendSpec::Native)
            .with_sweeps(6, 20)
            .with_seed(1)
    }

    fn dataset() -> (Coo, Coo, usize) {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 21).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 22);
        (train, test, d.k)
    }

    #[test]
    fn pp_1x1_learns() {
        let (train, test, k) = dataset();
        let res = PpTrainer::new(quick_cfg(k)).train(&train).unwrap();
        let rmse = res.rmse(&test);
        let base = mean_predictor_rmse(train.mean(), &test);
        assert!(rmse < base, "1x1 rmse {rmse} vs mean {base}");
        assert_eq!(res.stats.blocks, 1);
    }

    #[test]
    fn pp_grid_learns_and_phases_run() {
        let (train, test, k) = dataset();
        let res =
            PpTrainer::new(quick_cfg(k).with_grid(3, 2)).train(&train).unwrap();
        let rmse = res.rmse(&test);
        let base = mean_predictor_rmse(train.mean(), &test);
        assert!(rmse < base, "3x2 rmse {rmse} vs mean {base}");
        assert_eq!(res.stats.blocks, 6);
        assert!(res.timings.b > 0.0 && res.timings.c > 0.0);
    }

    #[test]
    fn pp_rmse_close_to_plain_bmf() {
        // the paper's core ML claim: PP ≈ plain BMF in RMSE
        let (train, test, k) = dataset();
        let r1 = PpTrainer::new(quick_cfg(k)).train(&train).unwrap();
        let r2 = PpTrainer::new(quick_cfg(k).with_grid(2, 2)).train(&train).unwrap();
        let (a, b) = (r1.rmse(&test), r2.rmse(&test));
        assert!((a - b).abs() < 0.15 * a.max(b), "1x1={a} vs 2x2={b}");
    }

    #[test]
    fn row_heavy_grid_works() {
        let (train, test, k) = dataset();
        let res = PpTrainer::new(quick_cfg(k).with_grid(4, 1)).train(&train).unwrap();
        assert!(res.rmse(&test).is_finite());
        assert_eq!(res.stats.blocks, 4);
        assert_eq!(res.u_post.n, train.rows);
    }

    #[test]
    fn predict_variance_positive() {
        let (train, _, k) = dataset();
        let res = PpTrainer::new(quick_cfg(k)).train(&train).unwrap();
        let var = res.predict_variance(0, 0);
        assert!(var > 0.0 && var.is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let (train, _, k) = dataset();
        let r1 = PpTrainer::new(quick_cfg(k).with_grid(2, 2)).train(&train).unwrap();
        let r2 = PpTrainer::new(quick_cfg(k).with_grid(2, 2)).train(&train).unwrap();
        assert_eq!(r1.u_mean, r2.u_mean);
    }
}
