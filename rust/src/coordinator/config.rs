//! Training configuration for D-BMF+PP.

use super::scheduler::Priority;
use crate::gibbs::native::GibbsPrecision;
use crate::testing::fault::FaultPlan;
use std::path::PathBuf;

/// Which compute backend executes the Gibbs half-sweeps.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    /// Pure-rust sampler (oracle; also the plain-BMF baseline path).
    Native,
    /// AOT HLO artifacts through the PJRT runtime (the production path).
    Hlo {
        /// Directory holding `manifest.json` and the HLO artifacts.
        artifact_dir: PathBuf,
    },
    /// HLO if the artifact directory exists, else native — for tests and
    /// examples that should run pre-`make artifacts`.
    Auto {
        /// Directory probed for `manifest.json`.
        artifact_dir: PathBuf,
    },
}

impl BackendSpec {
    /// Default: `Auto` over the repo's `artifacts/` directory.
    pub fn auto_default() -> BackendSpec {
        BackendSpec::Auto {
            artifact_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        }
    }

    /// Resolve Auto into Native/Hlo by checking the manifest. Builds
    /// without the `pjrt` feature always resolve Auto to Native — the HLO
    /// runtime is not compiled in.
    pub fn resolve(&self) -> BackendSpec {
        match self {
            BackendSpec::Auto { artifact_dir } => {
                if cfg!(feature = "pjrt") && artifact_dir.join("manifest.json").exists() {
                    BackendSpec::Hlo { artifact_dir: artifact_dir.clone() }
                } else {
                    BackendSpec::Native
                }
            }
            other => other.clone(),
        }
    }
}

/// A training configuration the engine refuses to run: every variant names
/// the field and the constraint so bad CLI input fails at submit time with
/// an actionable message instead of panicking inside a worker thread.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum ConfigError {
    /// `k == 0`: a factor model needs at least one latent dimension.
    #[error("latent dimension k must be > 0")]
    ZeroK,
    /// One of the grid dimensions is zero.
    #[error("grid {0}x{1} has a zero dimension")]
    ZeroGrid(usize, usize),
    /// The grid has more row-blocks than matrix rows (or columns).
    #[error("grid {gi}x{gj} does not fit a {rows}x{cols} matrix")]
    GridExceedsMatrix {
        /// Requested row-blocks.
        gi: usize,
        /// Requested column-blocks.
        gj: usize,
        /// Training-matrix rows.
        rows: usize,
        /// Training-matrix columns.
        cols: usize,
    },
    /// τ must be a positive finite precision.
    #[error("noise precision tau must be positive and finite (got {0})")]
    BadTau(f64),
    /// The worker pool needs at least one block slot.
    #[error("block_parallelism must be > 0")]
    ZeroBlockParallelism,
    /// Pipelined sweeps publish factor rows in chunks; a chunk must hold
    /// at least one row.
    #[error("chunk_rows must be > 0")]
    ZeroChunkRows,
    /// Periodic checkpointing needs somewhere to write its generations.
    #[error("checkpoint_every is set but checkpoint_dir is not — periodic \
             checkpoints need a directory to write generations into")]
    CheckpointEveryWithoutDir,
    /// A staleness bound was requested for lockstep sweeps, where it can
    /// never apply — lockstep gathers every shard before the opposite
    /// side starts, so no stale chunk is ever read. Raised by the CLI
    /// (library callers may legitimately set `staleness` on a config
    /// whose sweep mode is chosen later).
    #[error(
        "staleness {0} requires --sweep pipelined \
         (lockstep sweeps never read stale chunks)"
    )]
    StalenessWithLockstep(usize),
}

/// How the U/V half-sweeps inside one block execute across the
/// within-block shard workers — the paper's second pillar (asynchronous
/// communication *within* a block, GASPI-style).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepMode {
    /// Classic synchronous half-sweeps: every worker samples its whole
    /// shard, the leader gathers all shards (the MPI-allgather analogue),
    /// and only then does the opposite side start. The default, and the
    /// reference the pipelined mode is validated against.
    Lockstep,
    /// GASPI-style pipelined half-sweeps: each half-sweep is split into
    /// per-shard column chunks, and a worker publishes every finished
    /// chunk to a double-buffered [`crate::coordinator::mailbox::FactorMailbox`]
    /// while it keeps sampling — so the factor exchange overlaps
    /// computation instead of following it. The opposite side starts as
    /// soon as all but [`TrainConfig::staleness`] chunks are published,
    /// reading the previous sweep's values for the (bounded) remainder.
    /// With `staleness == 0` the output is bitwise identical to
    /// [`SweepMode::Lockstep`]; with `staleness > 0` it is validated
    /// statistically (RMSE within tolerance).
    Pipelined,
}

/// How block tasks are ordered across the PP phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// Full barrier between phases (a), (b), (c) and aggregation: every
    /// task of a phase waits for the slowest block of the previous phase.
    Barrier,
    /// Dependency-driven: a block is dispatched the moment the posteriors
    /// it consumes are aggregated — phase-(c) blocks overlap phase-(b)
    /// stragglers (the paper's asynchronous-communication direction).
    Dag,
}

/// Heuristic residual-noise precision from the data: assumes the factor
/// model explains ~75% of the centred rating variance, so the residual
/// variance is ~25% and τ ≈ 4 / Var(r). Keeps τ sensible across rating
/// scales (1-5 vs 0-100) without a hyperparameter search.
pub fn auto_tau(train: &crate::data::sparse::Coo) -> f64 {
    let mean = train.mean();
    if train.nnz() == 0 {
        return 2.0;
    }
    let var: f64 = train
        .entries
        .iter()
        .map(|e| (e.val as f64 - mean).powi(2))
        .sum::<f64>()
        / train.nnz() as f64;
    (4.0 / var.max(1e-9)).clamp(1e-4, 1e4)
}

/// Full configuration of a PP training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Latent dimension (must match an AOT artifact K when using HLO).
    pub k: usize,
    /// Residual noise precision τ.
    pub tau: f64,
    /// Block grid: I row-blocks × J column-blocks.
    pub grid: (usize, usize),
    /// Burn-in Gibbs sweeps per block before samples are retained.
    pub burnin: usize,
    /// Retained samples per block (posterior moments are formed from these).
    pub samples: usize,
    /// Within-block shard workers (the distributed-BMF level).
    pub workers: usize,
    /// Parallel block slots for phases (b) and (c). Sizes the pool of a
    /// one-shot run (the CLI builds its engine from this field); a
    /// caller-owned `Engine` keeps its own thread count and this field
    /// does not resize it. Parallelism never changes the posterior
    /// (bitwise-invariant scheduling).
    pub block_parallelism: usize,
    /// Ridge added when inverting sample covariances / dividing posteriors.
    pub ridge: f64,
    /// Base RNG seed.
    pub seed: u64,
    /// Which compute backend executes the Gibbs half-sweeps.
    pub backend: BackendSpec,
    /// Barrier vs dependency-driven block scheduling. Both produce
    /// bitwise-identical posteriors for the same seeds/config; Dag removes
    /// the straggler wait between phases.
    pub scheduler: SchedulerMode,
    /// Optional sweep-reduction for later phases (paper §4 future work):
    /// phases b and c run `max(min_phase_sweeps, samples * frac)` retained
    /// samples where `frac = phase_sample_frac`. 1.0 = paper default
    /// (same samples for every block).
    pub phase_sample_frac: f64,
    /// Floor on retained samples per phase-(b)/(c) block under sweep
    /// reduction (keeps posterior moments estimable at small fractions).
    pub min_phase_samples: usize,
    /// Emit a `TrainEvent::SweepSample` (block training RMSE of the
    /// current factor sample) after every retained sweep when an event
    /// sink is attached. Costs an extra O(nnz·k) pass per retained sweep,
    /// so consumers that only want phase/block progress can turn it off;
    /// with no sink attached nothing is computed either way.
    pub stream_sweep_rmse: bool,
    /// Lockstep vs pipelined within-block half-sweeps.
    /// [`SweepMode::Lockstep`] (the default) is the synchronous reference;
    /// [`SweepMode::Pipelined`] overlaps the factor exchange with
    /// computation and, at `staleness == 0`, reproduces lockstep bitwise.
    pub sweep: SweepMode,
    /// Rows per published chunk in pipelined sweeps: each worker's shard
    /// is cut into chunks of this many rows, and every finished chunk is
    /// published to the other shards immediately. Smaller chunks publish
    /// earlier (finer overlap) at a higher per-chunk bookkeeping cost.
    /// Ignored under [`SweepMode::Lockstep`].
    pub chunk_rows: usize,
    /// Staleness bound τ for pipelined sweeps: a half-sweep may begin
    /// reading the opposite side while at most τ chunks of it are still
    /// unpublished, substituting the previous sweep's values for exactly
    /// those chunks. τ = 0 forbids stale reads (bitwise-lockstep);
    /// larger τ buys more compute/communication overlap at a bounded,
    /// mailbox-audited staleness. Ignored under [`SweepMode::Lockstep`].
    pub staleness: usize,
    /// Dispatch priority of this job's block tasks in the engine's shared
    /// ready-queue when several sessions run concurrently. Priority never
    /// changes the math — only which queued task takes the next free
    /// worker slot.
    pub priority: Priority,
    /// Max block tasks of this job occupying pool workers at once
    /// (0 = the pool width, i.e. no extra throttle). Setting this below
    /// the pool width on wide low-priority jobs keeps worker slots
    /// turning over for higher-priority neighbours.
    pub max_in_flight: usize,
    /// Resume from a partial (v3) checkpoint written by a cancelled run:
    /// blocks recorded in the file are restored instead of re-sampled, and
    /// the final posterior is bitwise-identical to an uninterrupted run
    /// over the same completed-block set (same data/config/seed).
    pub resume_from: Option<PathBuf>,
    /// Where a cancelled or failed run writes its partial (v3) checkpoint
    /// of all completed block posteriors. `None` (the default) skips
    /// checkpoint-on-abort; an abort with zero completed blocks never
    /// writes a file either way.
    pub checkpoint_on_cancel: Option<PathBuf>,
    /// Periodic checkpointing: persist a partial (v3) checkpoint of every
    /// completed block posterior after each `checkpoint_every` newly
    /// completed blocks (0, the default, disables it). Writes go into
    /// [`TrainConfig::checkpoint_dir`] as atomically-renamed,
    /// monotonically numbered generation files, so a crash — even
    /// `SIGKILL` — loses at most the blocks completed since the last
    /// generation; `resume_from` pointed at the directory restores the
    /// newest valid generation bitwise-identically.
    pub checkpoint_every: usize,
    /// Directory the periodic generations are written into (created on
    /// first write). Required when `checkpoint_every > 0`. One run at a
    /// time: generation numbering is computed per run at start, so
    /// concurrent sessions sharing a directory would interleave (and
    /// overwrite) each other's generations — give each job its own
    /// directory.
    pub checkpoint_dir: Option<PathBuf>,
    /// Keep-last-K retention for periodic generations: after each write,
    /// all but the newest `checkpoint_keep` generation files are deleted
    /// (0 keeps every generation). Default 3.
    pub checkpoint_keep: usize,
    /// Deterministic fault injection for crash-tolerance tests: consulted
    /// before each sampled block, on the worker thread about to run it
    /// (see [`crate::testing::fault::FaultPlan`]). `None` — always, in
    /// production — costs nothing.
    pub fault: Option<FaultPlan>,
    /// Submit the job paused: its tasks queue but are not dispatched until
    /// [`Session::resume`](super::Session::resume) (or cancel, which
    /// drains them). Useful for staging work behind other jobs
    /// deterministically. Only meaningful for
    /// [`Engine::submit`](super::Engine::submit) — the blocking paths
    /// (`Engine::train` / `train_observed`) have no handle that could
    /// ever resume the job, so they run immediately and ignore this flag.
    pub start_paused: bool,
    /// Shard-cache byte budget for store-backed runs
    /// ([`Engine::submit_store`](super::Engine::submit_store)): the run
    /// keeps at most this many bytes of block shards resident, evicting
    /// least-recently-used shards past it (0, the default, is unbounded).
    /// A budget below one shard still works — every block is evicted
    /// after use. Ignored for resident (`Coo`) runs; never changes the
    /// posterior, only residency and disk traffic.
    pub cache_bytes: u64,
    /// Floating-point regime of the native Gibbs kernel.
    /// [`GibbsPrecision::F64`] (the default) accumulates and factors in
    /// f64 and participates in every bitwise-equivalence contract
    /// (chunk-invariance, τ=0 pipelined≡lockstep, store≡resident).
    /// [`GibbsPrecision::F32`] keeps f64 accumulation but stores the
    /// posterior precision and runs the factorization/solves in f32
    /// (f64 inner products) — a smaller per-row working set at ~1e-3
    /// relative deviation; it is excluded from the bitwise contracts.
    /// The HLO backend has its own fixed arithmetic and ignores this.
    pub kernel_precision: GibbsPrecision,
}

impl TrainConfig {
    /// Defaults for latent dimension `k`: 1×1 grid, lockstep sweeps,
    /// dependency-driven scheduling, auto-resolved backend.
    pub fn new(k: usize) -> TrainConfig {
        TrainConfig {
            k,
            tau: 2.0,
            grid: (1, 1),
            burnin: 8,
            samples: 20,
            workers: 1,
            block_parallelism: std::thread::available_parallelism()
                .map(|p| p.get().min(8))
                .unwrap_or(4),
            ridge: 1e-3,
            seed: 42,
            backend: BackendSpec::auto_default(),
            scheduler: SchedulerMode::Dag,
            phase_sample_frac: 1.0,
            min_phase_samples: 4,
            stream_sweep_rmse: true,
            sweep: SweepMode::Lockstep,
            chunk_rows: 256,
            staleness: 0,
            priority: Priority::Normal,
            max_in_flight: 0,
            resume_from: None,
            checkpoint_on_cancel: None,
            checkpoint_every: 0,
            checkpoint_dir: None,
            checkpoint_keep: 3,
            fault: None,
            start_paused: false,
            cache_bytes: 0,
            kernel_precision: GibbsPrecision::F64,
        }
    }

    /// Set the block grid (I row-blocks × J column-blocks).
    pub fn with_grid(mut self, i: usize, j: usize) -> Self {
        self.grid = (i, j);
        self
    }

    /// Set burn-in and retained sweeps per block.
    pub fn with_sweeps(mut self, burnin: usize, samples: usize) -> Self {
        self.burnin = burnin;
        self.samples = samples;
        self
    }

    /// Set the compute backend.
    pub fn with_backend(mut self, backend: BackendSpec) -> Self {
        self.backend = backend;
        self
    }

    /// Set the base RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the within-block shard worker count.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Set the residual noise precision τ.
    pub fn with_tau(mut self, tau: f64) -> Self {
        self.tau = tau;
        self
    }

    /// Set barrier vs dependency-driven block scheduling.
    pub fn with_scheduler(mut self, scheduler: SchedulerMode) -> Self {
        self.scheduler = scheduler;
        self
    }

    /// Set lockstep vs pipelined within-block half-sweeps.
    pub fn with_sweep_mode(mut self, sweep: SweepMode) -> Self {
        self.sweep = sweep;
        self
    }

    /// Set the rows-per-chunk granularity of pipelined publication.
    pub fn with_chunk_rows(mut self, chunk_rows: usize) -> Self {
        self.chunk_rows = chunk_rows;
        self
    }

    /// Set the staleness bound τ (in chunks) for pipelined reads.
    pub fn with_staleness(mut self, staleness: usize) -> Self {
        self.staleness = staleness;
        self
    }

    /// Set the job's dispatch priority in the shared ready-queue.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Cap how many of this job's block tasks occupy workers at once
    /// (0 = pool width).
    pub fn with_max_in_flight(mut self, max_in_flight: usize) -> Self {
        self.max_in_flight = max_in_flight;
        self
    }

    /// Resume from a partial (v3) checkpoint written on cancel.
    pub fn with_resume_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume_from = Some(path.into());
        self
    }

    /// Write a partial (v3) checkpoint of completed blocks on cancel (or
    /// on failure).
    pub fn with_checkpoint_on_cancel(mut self, path: impl Into<PathBuf>) -> Self {
        self.checkpoint_on_cancel = Some(path.into());
        self
    }

    /// Persist a partial (v3) generation after every `every` newly
    /// completed blocks (0 disables periodic checkpointing). Pair with
    /// [`TrainConfig::with_checkpoint_dir`].
    pub fn with_checkpoint_every(mut self, every: usize) -> Self {
        self.checkpoint_every = every;
        self
    }

    /// Directory the periodic checkpoint generations are written into.
    pub fn with_checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    /// Keep only the newest `keep` periodic generations (0 keeps all).
    pub fn with_checkpoint_keep(mut self, keep: usize) -> Self {
        self.checkpoint_keep = keep;
        self
    }

    /// Attach a deterministic fault-injection plan (testing hook).
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }

    /// Submit the job paused (dispatch gated until resumed).
    pub fn with_start_paused(mut self, paused: bool) -> Self {
        self.start_paused = paused;
        self
    }

    /// Bound resident shard bytes for store-backed runs (0 = unbounded).
    pub fn with_cache_bytes(mut self, cache_bytes: u64) -> Self {
        self.cache_bytes = cache_bytes;
        self
    }

    /// Select the native Gibbs kernel's floating-point regime (see
    /// [`TrainConfig::kernel_precision`]).
    pub fn with_kernel_precision(mut self, precision: GibbsPrecision) -> Self {
        self.kernel_precision = precision;
        self
    }

    /// Check the configuration against the training matrix's dimensions.
    /// Called by the engine on every submit; the typed [`ConfigError`]
    /// reaches the caller before any worker thread sees the job.
    pub fn validate(&self, rows: usize, cols: usize) -> Result<(), ConfigError> {
        if self.k == 0 {
            return Err(ConfigError::ZeroK);
        }
        let (gi, gj) = self.grid;
        if gi == 0 || gj == 0 {
            return Err(ConfigError::ZeroGrid(gi, gj));
        }
        if gi > rows || gj > cols {
            return Err(ConfigError::GridExceedsMatrix { gi, gj, rows, cols });
        }
        if !(self.tau > 0.0 && self.tau.is_finite()) {
            return Err(ConfigError::BadTau(self.tau));
        }
        if self.block_parallelism == 0 {
            return Err(ConfigError::ZeroBlockParallelism);
        }
        if self.chunk_rows == 0 {
            return Err(ConfigError::ZeroChunkRows);
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_none() {
            return Err(ConfigError::CheckpointEveryWithoutDir);
        }
        Ok(())
    }

    /// Retained samples for a phase-(b)/(c) block under sweep reduction.
    pub fn phase_samples(&self) -> usize {
        ((self.samples as f64 * self.phase_sample_frac) as usize)
            .max(self.min_phase_samples)
            .min(self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let c = TrainConfig::new(8).with_grid(4, 2).with_sweeps(5, 10).with_seed(7);
        assert_eq!(c.k, 8);
        assert_eq!(c.grid, (4, 2));
        assert_eq!(c.burnin, 5);
        assert_eq!(c.samples, 10);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn phase_sample_reduction() {
        let mut c = TrainConfig::new(8).with_sweeps(4, 20);
        assert_eq!(c.phase_samples(), 20);
        c.phase_sample_frac = 0.25;
        assert_eq!(c.phase_samples(), 5);
        c.phase_sample_frac = 0.0;
        assert_eq!(c.phase_samples(), 4); // floor at min_phase_samples
    }

    #[test]
    fn validate_accepts_defaults() {
        assert_eq!(TrainConfig::new(8).validate(100, 50), Ok(()));
        assert_eq!(TrainConfig::new(8).with_grid(4, 2).validate(100, 50), Ok(()));
    }

    #[test]
    fn validate_rejects_bad_fields() {
        assert_eq!(TrainConfig::new(0).validate(100, 50), Err(ConfigError::ZeroK));
        assert_eq!(
            TrainConfig::new(8).with_grid(0, 2).validate(100, 50),
            Err(ConfigError::ZeroGrid(0, 2))
        );
        assert_eq!(
            TrainConfig::new(8).with_grid(4, 51).validate(100, 50),
            Err(ConfigError::GridExceedsMatrix { gi: 4, gj: 51, rows: 100, cols: 50 })
        );
        assert_eq!(
            TrainConfig::new(8).with_tau(0.0).validate(100, 50),
            Err(ConfigError::BadTau(0.0))
        );
        assert!(matches!(
            TrainConfig::new(8).with_tau(f64::NAN).validate(100, 50),
            Err(ConfigError::BadTau(_))
        ));
        let mut c = TrainConfig::new(8);
        c.block_parallelism = 0;
        assert_eq!(c.validate(100, 50), Err(ConfigError::ZeroBlockParallelism));
    }

    #[test]
    fn sweep_mode_defaults_and_builders() {
        let c = TrainConfig::new(8);
        assert_eq!(c.sweep, SweepMode::Lockstep);
        assert_eq!(c.staleness, 0);
        assert!(c.chunk_rows > 0);
        let c = c.with_sweep_mode(SweepMode::Pipelined).with_chunk_rows(32).with_staleness(2);
        assert_eq!(c.sweep, SweepMode::Pipelined);
        assert_eq!(c.chunk_rows, 32);
        assert_eq!(c.staleness, 2);
        assert_eq!(c.validate(100, 50), Ok(()));
        assert_eq!(
            TrainConfig::new(8).with_chunk_rows(0).validate(100, 50),
            Err(ConfigError::ZeroChunkRows)
        );
    }

    #[test]
    fn lifecycle_fields_default_and_chain() {
        let c = TrainConfig::new(8);
        assert_eq!(c.priority, Priority::Normal);
        assert_eq!(c.max_in_flight, 0);
        assert_eq!(c.cache_bytes, 0);
        assert_eq!(c.clone().with_cache_bytes(1 << 20).cache_bytes, 1 << 20);
        assert!(c.resume_from.is_none());
        assert!(c.checkpoint_on_cancel.is_none());
        assert!(!c.start_paused);
        let c = c
            .with_priority(Priority::High)
            .with_max_in_flight(2)
            .with_resume_from("/tmp/partial.json")
            .with_checkpoint_on_cancel("/tmp/abort.json")
            .with_start_paused(true);
        assert_eq!(c.priority, Priority::High);
        assert_eq!(c.max_in_flight, 2);
        assert_eq!(c.resume_from.as_deref(), Some(std::path::Path::new("/tmp/partial.json")));
        assert_eq!(
            c.checkpoint_on_cancel.as_deref(),
            Some(std::path::Path::new("/tmp/abort.json"))
        );
        assert!(c.start_paused);
        assert_eq!(c.validate(100, 50), Ok(()));
        // priorities order Low < Normal < High (queue pop relies on it)
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        assert_eq!("high".parse::<Priority>(), Ok(Priority::High));
        assert!("urgent".parse::<Priority>().is_err());
    }

    #[test]
    fn periodic_checkpoint_fields_default_chain_and_validate() {
        let c = TrainConfig::new(8);
        assert_eq!(c.checkpoint_every, 0);
        assert!(c.checkpoint_dir.is_none());
        assert_eq!(c.checkpoint_keep, 3);
        assert!(c.fault.is_none());
        // every > 0 without a directory is a typed config error
        assert_eq!(
            TrainConfig::new(8).with_checkpoint_every(2).validate(100, 50),
            Err(ConfigError::CheckpointEveryWithoutDir)
        );
        let c = TrainConfig::new(8)
            .with_checkpoint_every(2)
            .with_checkpoint_dir("/tmp/ckpts")
            .with_checkpoint_keep(5)
            .with_fault_plan(FaultPlan::panic_at_block(1));
        assert_eq!(c.checkpoint_every, 2);
        assert_eq!(c.checkpoint_dir.as_deref(), Some(std::path::Path::new("/tmp/ckpts")));
        assert_eq!(c.checkpoint_keep, 5);
        assert!(c.fault.unwrap().kills_block(1));
        assert_eq!(c.validate(100, 50), Ok(()));
        // a directory alone (no interval) is fine — on-cancel writers use it
        assert_eq!(
            TrainConfig::new(8).with_checkpoint_dir("/tmp/ckpts").validate(100, 50),
            Ok(())
        );
    }

    #[test]
    fn auto_backend_resolves() {
        let spec = BackendSpec::Auto { artifact_dir: PathBuf::from("/definitely/not/here") };
        assert!(matches!(spec.resolve(), BackendSpec::Native));
    }
}
