//! Phase-parallel task scheduling on a persistent worker pool.
//!
//! Within a PP phase all block tasks are independent; across phases the
//! expensive per-thread state (the PJRT engine: client + compiled
//! executables) must be REUSED, so the pool outlives individual phases.
//! Each worker thread instantiates its own `BlockBackend` once (the engine
//! is thread-confined) and then serves jobs from a shared channel.

use super::backend::BlockBackend;
use super::config::BackendSpec;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

type Job = Box<dyn FnOnce(&BlockBackend) + Send>;

/// A pool of worker threads, each owning one backend instance.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub threads: usize,
}

impl WorkerPool {
    /// Spawn `threads` workers, each constructing its own backend from
    /// `spec`. Backend construction errors surface on the first job.
    pub fn new(spec: &BackendSpec, threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let rx = rx.clone();
            let spec = spec.clone();
            handles.push(std::thread::spawn(move || {
                let backend = BlockBackend::create(&spec);
                loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => match &backend {
                            Ok(b) => job(b),
                            Err(e) => {
                                // construct a fresh native backend so the job
                                // can still report the error path cleanly
                                log::error!("backend construction failed: {e:#}");
                                job(&BlockBackend::Native);
                            }
                        },
                        Err(_) => break, // pool dropped
                    }
                }
            }));
        }
        WorkerPool { tx: Some(tx), handles, threads }
    }

    /// Run a batch of tasks to completion; results in task order.
    pub fn run_phase<T, F>(&self, tasks: Vec<F>) -> anyhow::Result<Vec<T>>
    where
        T: Send + 'static,
        F: FnOnce(&BlockBackend) -> anyhow::Result<T> + Send + 'static,
    {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let (rtx, rrx): (Sender<(usize, anyhow::Result<T>)>, Receiver<_>) = channel();
        for (idx, task) in tasks.into_iter().enumerate() {
            let rtx = rtx.clone();
            let job: Job = Box::new(move |backend| {
                let out = task(backend);
                let _ = rtx.send((idx, out));
            });
            self.tx.as_ref().expect("pool alive").send(job).expect("workers alive");
        }
        drop(rtx);
        let mut slots: Vec<Option<anyhow::Result<T>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (idx, res) = rrx.recv().map_err(|_| anyhow::anyhow!("worker pool hung up"))?;
            slots[idx] = Some(res);
        }
        let mut out = Vec::with_capacity(n);
        for (i, s) in slots.into_iter().enumerate() {
            match s {
                Some(Ok(v)) => out.push(v),
                Some(Err(e)) => return Err(e.context(format!("phase task {i} failed"))),
                None => anyhow::bail!("phase task {i} was never executed"),
            }
        }
        Ok(out)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One-shot convenience used by tests and simple callers: builds a
/// transient pool, runs the batch, tears it down.
pub fn run_phase<T, F>(spec: &BackendSpec, slots: usize, tasks: Vec<F>) -> anyhow::Result<Vec<T>>
where
    T: Send + 'static,
    F: FnOnce(&BlockBackend) -> anyhow::Result<T> + Send + 'static,
{
    WorkerPool::new(spec, slots.min(tasks.len().max(1))).run_phase(tasks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_tasks_in_order() {
        let tasks: Vec<_> = (0..20)
            .map(|i| move |_b: &BlockBackend| -> anyhow::Result<usize> { Ok(i * i) })
            .collect();
        let out = run_phase(&BackendSpec::Native, 4, tasks).unwrap();
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_multiple_phases() {
        let pool = WorkerPool::new(&BackendSpec::Native, 3);
        for round in 0..4 {
            let tasks: Vec<_> = (0..7)
                .map(|i| move |_b: &BlockBackend| -> anyhow::Result<usize> { Ok(i + round) })
                .collect();
            let out = pool.run_phase(tasks).unwrap();
            assert_eq!(out, (0..7).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn propagates_task_errors() {
        let tasks: Vec<_> = (0..4)
            .map(|i| {
                move |_b: &BlockBackend| -> anyhow::Result<usize> {
                    if i == 2 {
                        anyhow::bail!("boom");
                    }
                    Ok(i)
                }
            })
            .collect();
        let err = run_phase(&BackendSpec::Native, 2, tasks).unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
    }

    #[test]
    fn empty_task_list() {
        let tasks: Vec<fn(&BlockBackend) -> anyhow::Result<()>> = vec![];
        assert!(run_phase(&BackendSpec::Native, 4, tasks).unwrap().is_empty());
    }

    #[test]
    fn actually_parallel() {
        let t0 = std::time::Instant::now();
        let tasks: Vec<_> = (0..4)
            .map(|_| {
                move |_b: &BlockBackend| -> anyhow::Result<()> {
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    Ok(())
                }
            })
            .collect();
        run_phase(&BackendSpec::Native, 4, tasks).unwrap();
        let dt = t0.elapsed().as_millis();
        assert!(dt < 160, "took {dt}ms — not parallel");
    }
}
