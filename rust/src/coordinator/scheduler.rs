//! Block-task scheduling on a persistent worker pool.
//!
//! Two scheduling regimes share the same pool:
//!
//! - [`WorkerPool::run_phase`] — the barrier scheduler: a batch of
//!   independent tasks runs to completion before the caller continues, so
//!   every batch waits for its slowest straggler.
//! - [`DagScheduler`] — dependency-driven (barrier-free) scheduling: each
//!   node is dispatched the moment its parents' outputs exist, so tasks of
//!   a later PP phase start while stragglers of the previous phase are
//!   still running.
//!
//! Across phases the expensive per-thread state (the PJRT engine: client +
//! compiled executables) must be REUSED, so the pool outlives individual
//! phases — and, via [`crate::coordinator::Engine`], individual *runs*:
//! the training engine holds one pool for its whole lifetime and schedules
//! every submitted job onto it. Each worker thread instantiates its own
//! `BlockBackend` once (the PJRT engine is thread-confined) and then
//! serves jobs from a shared channel. If backend construction fails, every
//! job submitted to that worker reports the construction error to its
//! caller — jobs are never silently run on a substitute backend.

use super::backend::BlockBackend;
use super::config::BackendSpec;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A job receives the worker's backend, or the error that prevented the
/// backend from being constructed.
type Job = Box<dyn FnOnce(anyhow::Result<&BlockBackend>) + Send>;

/// A pool of worker threads, each owning one backend instance.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Number of worker threads (parallel task slots).
    pub threads: usize,
}

impl WorkerPool {
    /// Spawn `threads` workers, each constructing its own backend from
    /// `spec`. Backend construction errors surface on the first job.
    pub fn new(spec: &BackendSpec, threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let rx = rx.clone();
            let spec = spec.clone();
            handles.push(std::thread::spawn(move || {
                let backend = BlockBackend::create(&spec);
                loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            // catch unwinds so one panicking task cannot kill
                            // the worker and strand the jobs queued behind it
                            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || match &backend {
                                    Ok(b) => job(Ok(b)),
                                    // propagate the construction failure to the
                                    // submitter instead of substituting a fresh
                                    // native backend behind its back
                                    Err(e) => job(Err(anyhow::anyhow!(
                                        "backend construction failed: {e:#}"
                                    ))),
                                },
                            ));
                            if run.is_err() {
                                log::error!("scheduled task panicked; worker continues");
                            }
                        }
                        Err(_) => break, // pool dropped
                    }
                }
            }));
        }
        WorkerPool { tx: Some(tx), handles, threads }
    }

    fn submit(&self, job: Job) {
        self.tx.as_ref().expect("pool alive").send(job).expect("workers alive");
    }

    /// Run a batch of tasks to completion; results in task order.
    pub fn run_phase<T, F>(&self, tasks: Vec<F>) -> anyhow::Result<Vec<T>>
    where
        T: Send + 'static,
        F: FnOnce(&BlockBackend) -> anyhow::Result<T> + Send + 'static,
    {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let (rtx, rrx): (Sender<(usize, anyhow::Result<T>)>, Receiver<_>) = channel();
        for (idx, task) in tasks.into_iter().enumerate() {
            let rtx = rtx.clone();
            let job: Job = Box::new(move |backend| {
                let out = backend.and_then(task);
                let _ = rtx.send((idx, out));
            });
            self.submit(job);
        }
        drop(rtx);
        let mut slots: Vec<Option<anyhow::Result<T>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (idx, res) = rrx.recv().map_err(|_| anyhow::anyhow!("worker pool hung up"))?;
            slots[idx] = Some(res);
        }
        let mut out = Vec::with_capacity(n);
        for (i, s) in slots.into_iter().enumerate() {
            match s {
                Some(Ok(v)) => out.push(v),
                Some(Err(e)) => return Err(e.context(format!("phase task {i} failed"))),
                None => anyhow::bail!("phase task {i} was never executed"),
            }
        }
        Ok(out)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One-shot convenience used by tests and simple callers: builds a
/// transient pool, runs the batch, tears it down.
pub fn run_phase<T, F>(spec: &BackendSpec, slots: usize, tasks: Vec<F>) -> anyhow::Result<Vec<T>>
where
    T: Send + 'static,
    F: FnOnce(&BlockBackend) -> anyhow::Result<T> + Send + 'static,
{
    WorkerPool::new(spec, slots.min(tasks.len().max(1))).run_phase(tasks)
}

/// Identifier of a node added to a [`DagScheduler`]: its insertion index.
pub type NodeId = usize;

type DagTask<T> = Box<dyn FnOnce(&BlockBackend, &[Arc<T>]) -> anyhow::Result<T> + Send>;

/// (node, output, compute start, compute end) reported by a worker.
type Done<T> = (NodeId, anyhow::Result<T>, Instant, Instant);

struct DagNodeSpec<T> {
    deps: Vec<NodeId>,
    task: DagTask<T>,
}

/// A completed node: its output plus start/finish seconds relative to the
/// moment the schedule began (for phase attribution and idle accounting).
pub struct DagNodeResult<T> {
    /// The node's task output.
    pub output: Arc<T>,
    /// Seconds after schedule start when the task began computing.
    pub started: f64,
    /// Seconds after schedule start when the task finished.
    pub finished: f64,
}

impl<T> DagNodeResult<T> {
    /// Seconds this node occupied a worker slot.
    pub fn busy(&self) -> f64 {
        self.finished - self.started
    }
}

/// Dependency-driven (barrier-free) scheduler over a [`WorkerPool`].
///
/// Nodes are added in topological order — a node may only depend on nodes
/// added before it, which makes cycles unrepresentable. [`DagScheduler::run`]
/// dispatches every node with no pending dependencies, then dispatches each
/// remaining node the moment its last parent completes.
pub struct DagScheduler<T> {
    nodes: Vec<DagNodeSpec<T>>,
}

impl<T: Send + Sync + 'static> DagScheduler<T> {
    /// An empty DAG.
    pub fn new() -> DagScheduler<T> {
        DagScheduler { nodes: Vec::new() }
    }

    /// Number of nodes added so far.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when no nodes have been added.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a node depending on `deps` (all must already be in the DAG).
    /// The task receives its parents' outputs in `deps` order.
    pub fn add<F>(&mut self, deps: &[NodeId], task: F) -> NodeId
    where
        F: FnOnce(&BlockBackend, &[Arc<T>]) -> anyhow::Result<T> + Send + 'static,
    {
        for &d in deps {
            assert!(d < self.nodes.len(), "dependency {d} on a node not yet added");
        }
        self.nodes.push(DagNodeSpec { deps: deps.to_vec(), task: Box::new(task) });
        self.nodes.len() - 1
    }

    /// Execute the DAG on `pool`; returns per-node outputs and timings.
    ///
    /// On a task failure no further nodes are dispatched; in-flight nodes
    /// drain and the first error is returned with the node attributed.
    pub fn run(self, pool: &WorkerPool) -> anyhow::Result<Vec<DagNodeResult<T>>> {
        let n = self.nodes.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let mut deps: Vec<Vec<NodeId>> = Vec::with_capacity(n);
        let mut tasks: Vec<Option<DagTask<T>>> = Vec::with_capacity(n);
        for spec in self.nodes {
            deps.push(spec.deps);
            tasks.push(Some(spec.task));
        }
        let mut dependents: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        let mut unmet: Vec<usize> = vec![0; n];
        for (id, dl) in deps.iter().enumerate() {
            let mut uniq = dl.clone();
            uniq.sort_unstable();
            uniq.dedup();
            unmet[id] = uniq.len();
            for d in uniq {
                dependents[d].push(id);
            }
        }

        let t0 = Instant::now();
        let (rtx, rrx): (Sender<Done<T>>, Receiver<Done<T>>) = channel();
        let mut outputs: Vec<Option<Arc<T>>> = (0..n).map(|_| None).collect();
        let mut results: Vec<Option<DagNodeResult<T>>> = (0..n).map(|_| None).collect();
        let mut in_flight = 0usize;
        let mut completed = 0usize;
        let mut first_err: Option<anyhow::Error> = None;

        for id in 0..n {
            if unmet[id] == 0 {
                dispatch(pool, &rtx, id, tasks[id].take().expect("task present"), Vec::new());
                in_flight += 1;
            }
        }
        while completed < n {
            if in_flight == 0 {
                // a failed parent kept the rest of the DAG from running
                return Err(first_err.unwrap_or_else(|| {
                    anyhow::anyhow!("dag stalled with {completed}/{n} nodes completed")
                }));
            }
            let (id, out, started, finished) =
                rrx.recv().map_err(|_| anyhow::anyhow!("worker pool hung up"))?;
            in_flight -= 1;
            completed += 1;
            match out {
                Ok(value) => {
                    let value = Arc::new(value);
                    outputs[id] = Some(value.clone());
                    results[id] = Some(DagNodeResult {
                        output: value,
                        started: started.saturating_duration_since(t0).as_secs_f64(),
                        finished: finished.saturating_duration_since(t0).as_secs_f64(),
                    });
                    for &child in &dependents[id] {
                        unmet[child] -= 1;
                        if unmet[child] == 0 && first_err.is_none() {
                            let parents: Vec<Arc<T>> = deps[child]
                                .iter()
                                .map(|&p| outputs[p].clone().expect("parent completed"))
                                .collect();
                            let task = tasks[child].take().expect("task present");
                            dispatch(pool, &rtx, child, task, parents);
                            in_flight += 1;
                        }
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("dag node {id} failed")));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(results.into_iter().map(|r| r.expect("all nodes completed")).collect()),
        }
    }
}

impl<T: Send + Sync + 'static> Default for DagScheduler<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Reports a node as failed if its task unwinds: `DagScheduler::run` holds
/// its own `Sender` for later dispatches, so unlike `run_phase` it cannot
/// rely on channel disconnection to notice a dead worker — without this
/// guard a panicking task would leave the scheduler waiting forever.
struct PanicGuard<T> {
    rtx: Option<Sender<Done<T>>>,
    id: NodeId,
    started: Instant,
}

impl<T> Drop for PanicGuard<T> {
    fn drop(&mut self) {
        if let Some(rtx) = self.rtx.take() {
            let _ = rtx.send((
                self.id,
                Err(anyhow::anyhow!("dag task panicked")),
                self.started,
                Instant::now(),
            ));
        }
    }
}

fn dispatch<T: Send + Sync + 'static>(
    pool: &WorkerPool,
    rtx: &Sender<Done<T>>,
    id: NodeId,
    task: DagTask<T>,
    parents: Vec<Arc<T>>,
) {
    let rtx = rtx.clone();
    let job: Job = Box::new(move |backend| {
        let started = Instant::now();
        let mut guard = PanicGuard { rtx: Some(rtx), id, started };
        let out = backend.and_then(|b| task(b, &parents));
        let rtx = guard.rtx.take().expect("guard armed");
        let _ = rtx.send((id, out, started, Instant::now()));
    });
    pool.submit(job);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_tasks_in_order() {
        let tasks: Vec<_> = (0..20)
            .map(|i| move |_b: &BlockBackend| -> anyhow::Result<usize> { Ok(i * i) })
            .collect();
        let out = run_phase(&BackendSpec::Native, 4, tasks).unwrap();
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_multiple_phases() {
        let pool = WorkerPool::new(&BackendSpec::Native, 3);
        for round in 0..4 {
            let tasks: Vec<_> = (0..7)
                .map(|i| move |_b: &BlockBackend| -> anyhow::Result<usize> { Ok(i + round) })
                .collect();
            let out = pool.run_phase(tasks).unwrap();
            assert_eq!(out, (0..7).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn propagates_task_errors() {
        let tasks: Vec<_> = (0..4)
            .map(|i| {
                move |_b: &BlockBackend| -> anyhow::Result<usize> {
                    if i == 2 {
                        anyhow::bail!("boom");
                    }
                    Ok(i)
                }
            })
            .collect();
        let err = run_phase(&BackendSpec::Native, 2, tasks).unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
    }

    #[test]
    fn propagates_backend_construction_errors() {
        // an HLO spec over a missing artifact dir (or a build without the
        // `pjrt` feature) must fail the task, not silently run natively
        let spec = BackendSpec::Hlo {
            artifact_dir: std::path::PathBuf::from("/definitely/not/here"),
        };
        let tasks: Vec<_> = (0..3)
            .map(|i| move |_b: &BlockBackend| -> anyhow::Result<usize> { Ok(i) })
            .collect();
        let err = run_phase(&spec, 2, tasks).unwrap_err();
        assert!(
            format!("{err:#}").contains("backend construction failed"),
            "got: {err:#}"
        );
    }

    #[test]
    fn empty_task_list() {
        let tasks: Vec<fn(&BlockBackend) -> anyhow::Result<()>> = vec![];
        assert!(run_phase(&BackendSpec::Native, 4, tasks).unwrap().is_empty());
    }

    #[test]
    fn actually_parallel() {
        let t0 = std::time::Instant::now();
        let tasks: Vec<_> = (0..4)
            .map(|_| {
                move |_b: &BlockBackend| -> anyhow::Result<()> {
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    Ok(())
                }
            })
            .collect();
        run_phase(&BackendSpec::Native, 4, tasks).unwrap();
        let dt = t0.elapsed().as_millis();
        assert!(dt < 160, "took {dt}ms — not parallel");
    }

    #[test]
    fn dag_propagates_parent_outputs() {
        let pool = WorkerPool::new(&BackendSpec::Native, 4);
        let mut dag: DagScheduler<usize> = DagScheduler::new();
        let a = dag.add(&[], |_b: &BlockBackend, _p: &[Arc<usize>]| Ok(1));
        let b = dag.add(&[a], |_b: &BlockBackend, p: &[Arc<usize>]| Ok(*p[0] * 10));
        let c = dag.add(&[a], |_b: &BlockBackend, p: &[Arc<usize>]| Ok(*p[0] * 100));
        let d = dag.add(&[b, c], |_b: &BlockBackend, p: &[Arc<usize>]| Ok(*p[0] + *p[1]));
        assert_eq!(dag.len(), 4);
        let out = dag.run(&pool).unwrap();
        assert_eq!(*out[b].output, 10);
        assert_eq!(*out[c].output, 100);
        assert_eq!(*out[d].output, 110);
        // children never start before their parents finish
        assert!(out[b].started >= out[a].finished - 1e-9);
        assert!(out[d].started >= out[c].finished - 1e-9);
    }

    #[test]
    fn dag_empty_is_ok() {
        let pool = WorkerPool::new(&BackendSpec::Native, 2);
        let dag: DagScheduler<()> = DagScheduler::new();
        assert!(dag.is_empty());
        assert!(dag.run(&pool).unwrap().is_empty());
    }

    #[test]
    fn dag_starts_children_before_sibling_stragglers_finish() {
        // PP-shaped DAG: a; then b1 (straggler) and b2 (fast) both depend
        // on a; c depends only on b2. Barrier-free scheduling must start —
        // and even finish — c while b1 is still running.
        let pool = WorkerPool::new(&BackendSpec::Native, 3);
        let sleep = |ms: u64| std::thread::sleep(std::time::Duration::from_millis(ms));
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        let a = dag.add(&[], move |_b: &BlockBackend, _p: &[Arc<u32>]| Ok(0));
        let b1 = dag.add(&[a], move |_b: &BlockBackend, _p: &[Arc<u32>]| {
            sleep(400);
            Ok(1)
        });
        let b2 = dag.add(&[a], move |_b: &BlockBackend, _p: &[Arc<u32>]| {
            sleep(25);
            Ok(2)
        });
        let c = dag.add(&[b2], move |_b: &BlockBackend, p: &[Arc<u32>]| {
            sleep(25);
            Ok(*p[0] + 1)
        });
        let out = dag.run(&pool).unwrap();
        assert_eq!(*out[c].output, 3);
        assert!(
            out[c].started < out[b1].finished,
            "c started at {:.3}s, after the straggler finished at {:.3}s",
            out[c].started,
            out[b1].finished
        );
        assert!(out[c].finished < out[b1].finished, "c should finish inside the straggler");
    }

    #[test]
    fn dag_errors_abort_descendants() {
        let pool = WorkerPool::new(&BackendSpec::Native, 2);
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        let a = dag.add(&[], |_b: &BlockBackend, _p: &[Arc<u32>]| Ok(7));
        let b = dag.add(&[a], |_b: &BlockBackend, _p: &[Arc<u32>]| anyhow::bail!("boom"));
        let _c = dag.add(&[b], |_b: &BlockBackend, p: &[Arc<u32>]| Ok(*p[0]));
        let err = dag.run(&pool).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("boom"), "{msg}");
        assert!(msg.contains("dag node 1"), "{msg}");
    }

    #[test]
    #[should_panic(expected = "not yet added")]
    fn dag_rejects_forward_dependencies() {
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        dag.add(&[3], |_b: &BlockBackend, _p: &[Arc<u32>]| Ok(0));
    }

    #[test]
    fn dag_task_panic_reports_error_instead_of_hanging() {
        let pool = WorkerPool::new(&BackendSpec::Native, 2);
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        let a = dag.add(&[], |_b: &BlockBackend, _p: &[Arc<u32>]| Ok(1));
        let _b = dag.add(&[a], |_b: &BlockBackend, _p: &[Arc<u32>]| -> anyhow::Result<u32> {
            panic!("kaboom")
        });
        let err = dag.run(&pool).unwrap_err();
        assert!(format!("{err:#}").contains("panicked"), "{err:#}");
    }
}
