//! Block-task scheduling on a persistent worker pool.
//!
//! Two scheduling regimes share the same pool:
//!
//! - [`WorkerPool::run_phase`] — the barrier scheduler: a batch of
//!   independent tasks runs to completion before the caller continues, so
//!   every batch waits for its slowest straggler.
//! - [`DagScheduler`] — dependency-driven (barrier-free) scheduling: each
//!   node is dispatched the moment its parents' outputs exist, so tasks of
//!   a later PP phase start while stragglers of the previous phase are
//!   still running.
//!
//! **Multi-tenancy.** The pool serves many concurrent *jobs* (training
//! sessions) at once: every task is tagged with the [`JobId`] it belongs
//! to, and all tasks wait in **one shared ready-queue** ordered by the
//! job's [`Priority`] (then FIFO by submission). Dependency tracking stays
//! per-job — each job's `DagScheduler` runs on its own driver thread —
//! but dispatch is global, so a High-priority job submitted into a busy
//! pool takes the next free worker slot ahead of every queued Normal/Low
//! task. Per-job in-flight caps (see [`WorkerPool::register_job`]) bound
//! how many workers one wide job may occupy, so it cannot starve its
//! neighbours, and paused jobs simply become ineligible for dispatch
//! without losing queue position.
//!
//! Across phases the expensive per-thread state (the PJRT engine: client +
//! compiled executables) must be REUSED, so the pool outlives individual
//! phases — and, via [`crate::coordinator::Engine`], individual *runs*:
//! the training engine holds one pool for its whole lifetime and schedules
//! every submitted job onto it. Each worker thread instantiates its own
//! `BlockBackend` once (the PJRT engine is thread-confined) and then
//! serves tasks from the shared queue. If backend construction fails,
//! every task popped by that worker reports the construction error to its
//! caller — jobs are never silently run on a substitute backend.

use super::backend::BlockBackend;
use super::config::BackendSpec;
use std::cmp::Reverse;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Identifier of one job (training session) registered with a pool. Stable
/// for the engine's lifetime; never reused by the same pool.
pub type JobId = u64;

/// Dispatch priority of a job's tasks in the shared ready-queue. Within a
/// priority, tasks dispatch FIFO by submission order across all jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Dispatched only when no Normal/High task is eligible.
    Low,
    /// The default.
    #[default]
    Normal,
    /// Takes the next free worker slot ahead of all Normal/Low tasks.
    High,
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        })
    }
}

impl std::str::FromStr for Priority {
    type Err = String;

    fn from_str(s: &str) -> Result<Priority, String> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            other => Err(format!("unknown priority '{other}' (low | normal | high)")),
        }
    }
}

/// A task receives the worker's backend, or the error that prevented the
/// backend from being constructed.
type Job = Box<dyn FnOnce(anyhow::Result<&BlockBackend>) + Send>;

/// One queued task: its job tag, the job's priority at submission time,
/// and a global sequence number for FIFO order within a priority.
struct QueueTask {
    priority: Priority,
    seq: u64,
    job: JobId,
    run: Job,
}

/// Per-job dispatch bookkeeping.
struct JobState {
    priority: Priority,
    /// Max tasks of this job on workers at once (0 = pool width).
    cap: usize,
    in_flight: usize,
    paused: bool,
}

struct QueueInner {
    tasks: Vec<QueueTask>,
    jobs: HashMap<JobId, JobState>,
    next_seq: u64,
    closed: bool,
    threads: usize,
}

impl QueueInner {
    /// May this task be handed to a worker right now?
    fn eligible(&self, t: &QueueTask) -> bool {
        match self.jobs.get(&t.job) {
            // job already finished (or never registered): no gating
            None => true,
            Some(js) => {
                // a paused job keeps its queue position but is skipped;
                // once the pool is closing everything must drain
                if js.paused && !self.closed {
                    return false;
                }
                let cap = if js.cap == 0 { self.threads } else { js.cap };
                js.in_flight < cap
            }
        }
    }
}

/// The shared prioritized ready-queue all pool workers drain.
struct ReadyQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

impl ReadyQueue {
    /// Block for the best eligible task; `None` once the queue is closed
    /// and fully drained (the worker should exit).
    fn pop(&self) -> Option<QueueTask> {
        let mut g = self.inner.lock().unwrap();
        loop {
            let mut best: Option<usize> = None;
            for (idx, t) in g.tasks.iter().enumerate() {
                if !g.eligible(t) {
                    continue;
                }
                best = match best {
                    None => Some(idx),
                    Some(b) => {
                        let bt = &g.tasks[b];
                        if (t.priority, Reverse(t.seq)) > (bt.priority, Reverse(bt.seq)) {
                            Some(idx)
                        } else {
                            Some(b)
                        }
                    }
                };
            }
            if let Some(idx) = best {
                let t = g.tasks.swap_remove(idx);
                if let Some(js) = g.jobs.get_mut(&t.job) {
                    js.in_flight += 1;
                }
                return Some(t);
            }
            if g.closed && g.tasks.is_empty() {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn push(&self, job: JobId, run: Job) {
        let mut g = self.inner.lock().unwrap();
        let priority = g.jobs.get(&job).map_or(Priority::Normal, |j| j.priority);
        let seq = g.next_seq;
        g.next_seq += 1;
        g.tasks.push(QueueTask { priority, seq, job, run });
        drop(g);
        // a push can unblock any worker (and pause/cap state may differ
        // per task), so wake them all
        self.cv.notify_all();
    }

    fn task_done(&self, job: JobId) {
        let mut g = self.inner.lock().unwrap();
        if let Some(js) = g.jobs.get_mut(&job) {
            js.in_flight = js.in_flight.saturating_sub(1);
        }
        drop(g);
        self.cv.notify_all();
    }

    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// A pool of worker threads, each owning one backend instance, all
/// draining one shared prioritized ready-queue.
pub struct WorkerPool {
    queue: Arc<ReadyQueue>,
    handles: Vec<std::thread::JoinHandle<()>>,
    next_job: AtomicU64,
    /// Number of worker threads (parallel task slots).
    pub threads: usize,
}

impl WorkerPool {
    /// Spawn `threads` workers, each constructing its own backend from
    /// `spec`. Backend construction errors surface on the first task.
    pub fn new(spec: &BackendSpec, threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let queue = Arc::new(ReadyQueue {
            inner: Mutex::new(QueueInner {
                tasks: Vec::new(),
                jobs: HashMap::new(),
                next_seq: 0,
                closed: false,
                threads,
            }),
            cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let queue = queue.clone();
            let spec = spec.clone();
            handles.push(std::thread::spawn(move || {
                let backend = BlockBackend::create(&spec);
                while let Some(task) = queue.pop() {
                    let job = task.job;
                    let run = task.run;
                    // catch unwinds so one panicking task cannot kill the
                    // worker and strand the tasks queued behind it
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || match &backend {
                            Ok(b) => run(Ok(b)),
                            // propagate the construction failure to the
                            // submitter instead of substituting a fresh
                            // native backend behind its back
                            Err(e) => run(Err(anyhow::anyhow!(
                                "backend construction failed: {e:#}"
                            ))),
                        },
                    ));
                    if res.is_err() {
                        log::error!("scheduled task panicked; worker continues");
                    }
                    queue.task_done(job);
                }
            }));
        }
        WorkerPool { queue, handles, next_job: AtomicU64::new(1), threads }
    }

    /// Register a job with the shared ready-queue: all tasks submitted
    /// under the returned [`JobId`] dispatch at `priority`, and at most
    /// `max_in_flight` of them occupy workers at once (`0` = the pool
    /// width, i.e. no extra throttle). Call [`WorkerPool::finish_job`]
    /// when the job ends to drop the bookkeeping.
    pub fn register_job(&self, priority: Priority, max_in_flight: usize) -> JobId {
        let id = self.next_job.fetch_add(1, Ordering::Relaxed);
        let mut g = self.queue.inner.lock().unwrap();
        g.jobs.insert(
            id,
            JobState { priority, cap: max_in_flight, in_flight: 0, paused: false },
        );
        id
    }

    /// Pause / unpause a job: paused jobs keep their queued tasks (and
    /// queue positions) but are skipped by dispatch until resumed.
    /// In-flight tasks always drain. Unknown ids are a no-op.
    pub fn set_job_paused(&self, job: JobId, paused: bool) {
        let mut g = self.queue.inner.lock().unwrap();
        if let Some(js) = g.jobs.get_mut(&job) {
            js.paused = paused;
        }
        drop(g);
        self.queue.cv.notify_all();
    }

    /// Drop a job's dispatch bookkeeping. Any task still queued under the
    /// id afterwards dispatches ungated (no pause/cap) but keeps the
    /// priority it was tagged with at submission.
    pub fn finish_job(&self, job: JobId) {
        let mut g = self.queue.inner.lock().unwrap();
        g.jobs.remove(&job);
        drop(g);
        self.queue.cv.notify_all();
    }

    fn submit_for(&self, job: JobId, run: Job) {
        self.queue.push(job, run);
    }

    /// Run a batch of tasks to completion; results in task order. The
    /// batch runs as one transient Normal-priority job.
    pub fn run_phase<T, F>(&self, tasks: Vec<F>) -> anyhow::Result<Vec<T>>
    where
        T: Send + 'static,
        F: FnOnce(&BlockBackend) -> anyhow::Result<T> + Send + 'static,
    {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let job = self.register_job(Priority::Normal, 0);
        let (rtx, rrx): (Sender<(usize, anyhow::Result<T>)>, Receiver<_>) = channel();
        for (idx, task) in tasks.into_iter().enumerate() {
            let rtx = rtx.clone();
            let run: Job = Box::new(move |backend| {
                let out = backend.and_then(task);
                let _ = rtx.send((idx, out));
            });
            self.submit_for(job, run);
        }
        drop(rtx);
        let mut slots: Vec<Option<anyhow::Result<T>>> = (0..n).map(|_| None).collect();
        let mut recv_err = false;
        for _ in 0..n {
            match rrx.recv() {
                Ok((idx, res)) => slots[idx] = Some(res),
                Err(_) => {
                    recv_err = true;
                    break;
                }
            }
        }
        self.finish_job(job);
        if recv_err {
            anyhow::bail!("worker pool hung up");
        }
        let mut out = Vec::with_capacity(n);
        for (i, s) in slots.into_iter().enumerate() {
            match s {
                Some(Ok(v)) => out.push(v),
                Some(Err(e)) => return Err(e.context(format!("phase task {i} failed"))),
                None => anyhow::bail!("phase task {i} was never executed"),
            }
        }
        Ok(out)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // closing lets queued tasks drain (paused jobs included), then the
        // workers exit; joining proves a clean shutdown
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One-shot convenience used by tests and simple callers: builds a
/// transient pool, runs the batch, tears it down.
pub fn run_phase<T, F>(spec: &BackendSpec, slots: usize, tasks: Vec<F>) -> anyhow::Result<Vec<T>>
where
    T: Send + 'static,
    F: FnOnce(&BlockBackend) -> anyhow::Result<T> + Send + 'static,
{
    WorkerPool::new(spec, slots.min(tasks.len().max(1))).run_phase(tasks)
}

/// Identifier of a node added to a [`DagScheduler`]: its insertion index.
pub type NodeId = usize;

type DagTask<T> = Box<dyn FnOnce(&BlockBackend, &[Arc<T>]) -> anyhow::Result<T> + Send>;

/// What a worker reports back for one dispatched node.
enum TaskDone<T> {
    /// The task ran to completion.
    Ran(T),
    /// The task errored — or *panicked*: the unwind is caught at the task
    /// boundary (see [`PanicGuard`]) and converted into this variant, so
    /// a crashing block fails **its job only** instead of poisoning the
    /// shared pool or wedging the other tenants' runs.
    Failed(anyhow::Error),
    /// The task was popped after its job's cancel flag was set and never
    /// executed.
    Skipped,
}

/// (node, outcome, compute start, compute end) reported by a worker.
type Done<T> = (NodeId, TaskDone<T>, Instant, Instant);

struct DagNodeSpec<T> {
    deps: Vec<NodeId>,
    task: DagTask<T>,
}

/// A completed node: its output plus start/finish seconds relative to the
/// moment the schedule began (for phase attribution and idle accounting).
pub struct DagNodeResult<T> {
    /// The node's task output.
    pub output: Arc<T>,
    /// Seconds after schedule start when the task began computing.
    pub started: f64,
    /// Seconds after schedule start when the task finished.
    pub finished: f64,
}

impl<T> DagNodeResult<T> {
    /// Seconds this node occupied a worker slot.
    pub fn busy(&self) -> f64 {
        self.finished - self.started
    }
}

/// How a DAG execution attaches to the pool's multi-tenant queue.
#[derive(Default)]
pub struct DagRunOpts {
    /// Job tag for every dispatched task; `None` registers a transient
    /// Normal-priority job for the duration of the run.
    pub job: Option<JobId>,
    /// Cooperative cancellation flag. Once set: no further nodes are
    /// dispatched, queued tasks fast-skip when popped, in-flight tasks
    /// drain, and the run returns with
    /// [`DagOutcome::cancelled`]` == true` and the nodes completed so far.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Called with each node id the moment it becomes runnable (all
    /// dependencies met), immediately before the node is handed to the
    /// pool's ready queue. This is the scheduler's look-ahead signal: the
    /// out-of-core trainer uses it to start warming a block's shard while
    /// the task waits for a worker slot. Invoked on the scheduling
    /// thread — keep it cheap (enqueue, don't do I/O).
    pub on_ready: Option<Box<dyn Fn(NodeId) + Send + Sync>>,
}

/// Result of [`DagScheduler::run_with`]: per-node outputs (a node that
/// never ran — cancelled before dispatch, skipped, or failed — is `None`).
pub struct DagOutcome<T> {
    /// One slot per node, in insertion order.
    pub nodes: Vec<Option<DagNodeResult<T>>>,
    /// True when the run stopped early because the cancel flag was set.
    pub cancelled: bool,
    /// First task failure (error or caught panic), if any. A failure
    /// stops further dispatch and drains in-flight siblings — whose
    /// completed outputs still appear in `nodes`, so the caller can
    /// checkpoint everything that finished before (and while) the run
    /// went down. When `cancelled` is also set the cancel takes
    /// precedence as the outcome; the failure is still reported here.
    pub failed: Option<anyhow::Error>,
}

/// Dependency-driven (barrier-free) scheduler over a [`WorkerPool`].
///
/// Nodes are added in topological order — a node may only depend on nodes
/// added before it, which makes cycles unrepresentable. [`DagScheduler::run`]
/// dispatches every node with no pending dependencies, then dispatches each
/// remaining node the moment its last parent completes. Dependency
/// tracking lives entirely in this scheduler (per job); the pool only sees
/// ready tasks, so many DAGs from different jobs interleave on one pool
/// under the shared priority queue.
pub struct DagScheduler<T> {
    nodes: Vec<DagNodeSpec<T>>,
}

impl<T: Send + Sync + 'static> DagScheduler<T> {
    /// An empty DAG.
    pub fn new() -> DagScheduler<T> {
        DagScheduler { nodes: Vec::new() }
    }

    /// Number of nodes added so far.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when no nodes have been added.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a node depending on `deps` (all must already be in the DAG).
    /// The task receives its parents' outputs in `deps` order.
    pub fn add<F>(&mut self, deps: &[NodeId], task: F) -> NodeId
    where
        F: FnOnce(&BlockBackend, &[Arc<T>]) -> anyhow::Result<T> + Send + 'static,
    {
        for &d in deps {
            assert!(d < self.nodes.len(), "dependency {d} on a node not yet added");
        }
        self.nodes.push(DagNodeSpec { deps: deps.to_vec(), task: Box::new(task) });
        self.nodes.len() - 1
    }

    /// Execute the DAG on `pool`; returns per-node outputs and timings.
    ///
    /// On a task failure no further nodes are dispatched; in-flight nodes
    /// drain and the first error is returned with the node attributed.
    pub fn run(self, pool: &WorkerPool) -> anyhow::Result<Vec<DagNodeResult<T>>> {
        let out = self.run_with(pool, &DagRunOpts::default())?;
        if let Some(e) = out.failed {
            return Err(e);
        }
        // without a cancel flag the run can only end complete or failed
        debug_assert!(!out.cancelled);
        Ok(out
            .nodes
            .into_iter()
            .map(|r| r.expect("all nodes completed"))
            .collect())
    }

    /// [`DagScheduler::run`] under an explicit job tag and optional
    /// cancellation flag (the multi-tenant entry point).
    pub fn run_with(
        self,
        pool: &WorkerPool,
        opts: &DagRunOpts,
    ) -> anyhow::Result<DagOutcome<T>> {
        let transient = opts.job.is_none();
        let job = opts
            .job
            .unwrap_or_else(|| pool.register_job(Priority::Normal, 0));
        let out = self.run_inner(pool, job, opts.cancel.clone(), opts.on_ready.as_deref());
        if transient {
            pool.finish_job(job);
        }
        out
    }

    fn run_inner(
        self,
        pool: &WorkerPool,
        job: JobId,
        cancel: Option<Arc<AtomicBool>>,
        on_ready: Option<&(dyn Fn(NodeId) + Send + Sync)>,
    ) -> anyhow::Result<DagOutcome<T>> {
        let n = self.nodes.len();
        let cancelled = || {
            cancel
                .as_ref()
                .map_or(false, |c| c.load(Ordering::Relaxed))
        };
        if n == 0 {
            return Ok(DagOutcome { nodes: Vec::new(), cancelled: cancelled(), failed: None });
        }
        let mut deps: Vec<Vec<NodeId>> = Vec::with_capacity(n);
        let mut tasks: Vec<Option<DagTask<T>>> = Vec::with_capacity(n);
        for spec in self.nodes {
            deps.push(spec.deps);
            tasks.push(Some(spec.task));
        }
        let mut dependents: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        let mut unmet: Vec<usize> = vec![0; n];
        for (id, dl) in deps.iter().enumerate() {
            let mut uniq = dl.clone();
            uniq.sort_unstable();
            uniq.dedup();
            unmet[id] = uniq.len();
            for d in uniq {
                dependents[d].push(id);
            }
        }

        let t0 = Instant::now();
        let (rtx, rrx): (Sender<Done<T>>, Receiver<Done<T>>) = channel();
        let mut outputs: Vec<Option<Arc<T>>> = (0..n).map(|_| None).collect();
        let mut results: Vec<Option<DagNodeResult<T>>> = (0..n).map(|_| None).collect();
        let mut in_flight = 0usize;
        let mut completed = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        // sticky: once true, no further nodes are dispatched this run
        let mut aborted = cancelled();

        if !aborted {
            for id in 0..n {
                if unmet[id] == 0 {
                    if let Some(cb) = on_ready {
                        cb(id);
                    }
                    let task = tasks[id].take().expect("task present");
                    dispatch(pool, &rtx, id, task, Vec::new(), job, cancel.clone());
                    in_flight += 1;
                }
            }
        }
        while completed < n {
            if !aborted && cancelled() {
                aborted = true;
            }
            if in_flight == 0 {
                if aborted || first_err.is_some() {
                    // cancelled or failed: stop here — the nodes that did
                    // complete (before and during the drain) are in
                    // `results` for checkpoint-on-abort
                    break;
                }
                anyhow::bail!("dag stalled with {completed}/{n} nodes completed");
            }
            let (id, out, started, finished) =
                rrx.recv().map_err(|_| anyhow::anyhow!("worker pool hung up"))?;
            in_flight -= 1;
            completed += 1;
            match out {
                TaskDone::Ran(value) => {
                    let value = Arc::new(value);
                    outputs[id] = Some(value.clone());
                    results[id] = Some(DagNodeResult {
                        output: value,
                        started: started.saturating_duration_since(t0).as_secs_f64(),
                        finished: finished.saturating_duration_since(t0).as_secs_f64(),
                    });
                    if !aborted && cancelled() {
                        aborted = true;
                    }
                    for &child in &dependents[id] {
                        unmet[child] -= 1;
                        if unmet[child] == 0 && first_err.is_none() && !aborted {
                            if let Some(cb) = on_ready {
                                cb(child);
                            }
                            let parents: Vec<Arc<T>> = deps[child]
                                .iter()
                                .map(|&p| outputs[p].clone().expect("parent completed"))
                                .collect();
                            let task = tasks[child].take().expect("task present");
                            dispatch(pool, &rtx, child, task, parents, job, cancel.clone());
                            in_flight += 1;
                        }
                    }
                }
                // an error or a caught panic: fail this job only — no new
                // dispatch, in-flight siblings drain into `results`
                TaskDone::Failed(e) => {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("dag node {id} failed")));
                    }
                }
                // only sent when the cancel flag was observed set
                TaskDone::Skipped => aborted = true,
            }
        }
        if let (Some(e), true) = (&first_err, aborted) {
            // a task error racing a cancel drain: the cancel is the
            // outcome, but the failure stays visible to the caller
            log::warn!("dag task failed during cancel drain: {e:#}");
        }
        Ok(DagOutcome { nodes: results, cancelled: aborted, failed: first_err })
    }
}

impl<T: Send + Sync + 'static> Default for DagScheduler<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Reports a node as [`TaskDone::Failed`] if its task unwinds: the
/// catch-at-the-task-boundary half of per-job failure isolation.
/// `DagScheduler` holds its own `Sender` for later dispatches, so unlike
/// `run_phase` it cannot rely on channel disconnection to notice a dead
/// worker — without this guard a panicking task would leave the scheduler
/// waiting forever (and the panic would surface only as a pool log line,
/// invisible to the job that owned the task).
struct PanicGuard<T> {
    rtx: Option<Sender<Done<T>>>,
    id: NodeId,
    started: Instant,
}

impl<T> Drop for PanicGuard<T> {
    fn drop(&mut self) {
        if let Some(rtx) = self.rtx.take() {
            let _ = rtx.send((
                self.id,
                TaskDone::Failed(anyhow::anyhow!("dag task panicked")),
                self.started,
                Instant::now(),
            ));
        }
    }
}

/// Best-effort extraction of a panic payload's message (the two shapes
/// `panic!` actually produces).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

fn dispatch<T: Send + Sync + 'static>(
    pool: &WorkerPool,
    rtx: &Sender<Done<T>>,
    id: NodeId,
    task: DagTask<T>,
    parents: Vec<Arc<T>>,
    job: JobId,
    cancel: Option<Arc<AtomicBool>>,
) {
    let rtx = rtx.clone();
    let run: Job = Box::new(move |backend| {
        let started = Instant::now();
        // a task popped after cancellation reports back without running,
        // so the driver's in-flight accounting drains exactly
        if cancel.as_ref().map_or(false, |c| c.load(Ordering::Relaxed)) {
            let _ = rtx.send((id, TaskDone::Skipped, started, Instant::now()));
            return;
        }
        let mut guard = PanicGuard { rtx: Some(rtx), id, started };
        // catch the unwind HERE, at the task boundary, so the panic
        // message travels to the owning job's FailInfo instead of dying
        // as a pool log line (the guard still covers anything that slips
        // through this catch)
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            backend.and_then(|b| task(b, &parents))
        }));
        let rtx = guard.rtx.take().expect("guard armed");
        let done = match out {
            Ok(Ok(value)) => TaskDone::Ran(value),
            Ok(Err(e)) => TaskDone::Failed(e),
            Err(payload) => TaskDone::Failed(anyhow::anyhow!(
                "dag task panicked: {}",
                panic_message(payload.as_ref())
            )),
        };
        let _ = rtx.send((id, done, started, Instant::now()));
    });
    pool.submit_for(job, run);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_tasks_in_order() {
        let tasks: Vec<_> = (0..20)
            .map(|i| move |_b: &BlockBackend| -> anyhow::Result<usize> { Ok(i * i) })
            .collect();
        let out = run_phase(&BackendSpec::Native, 4, tasks).unwrap();
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_multiple_phases() {
        let pool = WorkerPool::new(&BackendSpec::Native, 3);
        for round in 0..4 {
            let tasks: Vec<_> = (0..7)
                .map(|i| move |_b: &BlockBackend| -> anyhow::Result<usize> { Ok(i + round) })
                .collect();
            let out = pool.run_phase(tasks).unwrap();
            assert_eq!(out, (0..7).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn propagates_task_errors() {
        let tasks: Vec<_> = (0..4)
            .map(|i| {
                move |_b: &BlockBackend| -> anyhow::Result<usize> {
                    if i == 2 {
                        anyhow::bail!("boom");
                    }
                    Ok(i)
                }
            })
            .collect();
        let err = run_phase(&BackendSpec::Native, 2, tasks).unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
    }

    #[test]
    fn propagates_backend_construction_errors() {
        // an HLO spec over a missing artifact dir (or a build without the
        // `pjrt` feature) must fail the task, not silently run natively
        let spec = BackendSpec::Hlo {
            artifact_dir: std::path::PathBuf::from("/definitely/not/here"),
        };
        let tasks: Vec<_> = (0..3)
            .map(|i| move |_b: &BlockBackend| -> anyhow::Result<usize> { Ok(i) })
            .collect();
        let err = run_phase(&spec, 2, tasks).unwrap_err();
        assert!(
            format!("{err:#}").contains("backend construction failed"),
            "got: {err:#}"
        );
    }

    #[test]
    fn empty_task_list() {
        let tasks: Vec<fn(&BlockBackend) -> anyhow::Result<()>> = vec![];
        assert!(run_phase(&BackendSpec::Native, 4, tasks).unwrap().is_empty());
    }

    #[test]
    fn actually_parallel() {
        let t0 = std::time::Instant::now();
        let tasks: Vec<_> = (0..4)
            .map(|_| {
                move |_b: &BlockBackend| -> anyhow::Result<()> {
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    Ok(())
                }
            })
            .collect();
        run_phase(&BackendSpec::Native, 4, tasks).unwrap();
        let dt = t0.elapsed().as_millis();
        assert!(dt < 160, "took {dt}ms — not parallel");
    }

    /// Block the pool's single worker until released, so tasks queued
    /// behind the blocker dispatch strictly by queue order. Returns only
    /// once the worker is verifiably inside the blocker task.
    fn blocker(pool: &WorkerPool) -> Sender<()> {
        let (tx, rx) = channel::<()>();
        let (started_tx, started_rx) = channel::<()>();
        let job = pool.register_job(Priority::Normal, 0);
        let run: Job = Box::new(move |_b| {
            let _ = started_tx.send(());
            let _ = rx.recv();
        });
        pool.submit_for(job, run);
        // the blocker test jobs are transient; bookkeeping can go as soon
        // as the task is queued (unregistered tasks dispatch ungated)
        pool.finish_job(job);
        started_rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("blocker task did not start");
        tx
    }

    /// Submit one recording task under `job`; returns nothing — order is
    /// observed through the shared log.
    fn record_task(pool: &WorkerPool, job: JobId, log: &Arc<Mutex<Vec<&'static str>>>, tag: &'static str, done: &Sender<()>) {
        let log = log.clone();
        let done = done.clone();
        let run: Job = Box::new(move |_b| {
            log.lock().unwrap().push(tag);
            let _ = done.send(());
        });
        pool.submit_for(job, run);
    }

    #[test]
    fn ready_queue_orders_by_priority_then_fifo() {
        let pool = WorkerPool::new(&BackendSpec::Native, 1);
        let release = blocker(&pool);
        let lo = pool.register_job(Priority::Low, 0);
        let hi = pool.register_job(Priority::High, 0);
        let nm = pool.register_job(Priority::Normal, 0);
        let log = Arc::new(Mutex::new(Vec::new()));
        let (done_tx, done_rx) = channel::<()>();
        record_task(&pool, lo, &log, "low-1", &done_tx);
        record_task(&pool, nm, &log, "normal-1", &done_tx);
        record_task(&pool, hi, &log, "high-1", &done_tx);
        record_task(&pool, hi, &log, "high-2", &done_tx);
        record_task(&pool, lo, &log, "low-2", &done_tx);
        release.send(()).unwrap();
        for _ in 0..5 {
            done_rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        }
        assert_eq!(
            *log.lock().unwrap(),
            vec!["high-1", "high-2", "normal-1", "low-1", "low-2"]
        );
        pool.finish_job(lo);
        pool.finish_job(hi);
        pool.finish_job(nm);
    }

    #[test]
    fn paused_jobs_are_skipped_until_resumed() {
        let pool = WorkerPool::new(&BackendSpec::Native, 1);
        let release = blocker(&pool);
        let paused = pool.register_job(Priority::High, 0);
        let other = pool.register_job(Priority::Low, 0);
        pool.set_job_paused(paused, true);
        let log = Arc::new(Mutex::new(Vec::new()));
        let (done_tx, done_rx) = channel::<()>();
        record_task(&pool, paused, &log, "paused", &done_tx);
        record_task(&pool, other, &log, "other", &done_tx);
        release.send(()).unwrap();
        // only the unpaused job's task runs, despite lower priority
        done_rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        assert_eq!(*log.lock().unwrap(), vec!["other"]);
        pool.set_job_paused(paused, false);
        done_rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        assert_eq!(*log.lock().unwrap(), vec!["other", "paused"]);
        pool.finish_job(paused);
        pool.finish_job(other);
    }

    #[test]
    fn in_flight_cap_bounds_a_jobs_concurrency() {
        use std::sync::atomic::AtomicUsize;
        let pool = WorkerPool::new(&BackendSpec::Native, 4);
        let capped = pool.register_job(Priority::Normal, 1);
        let current = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let (done_tx, done_rx) = channel::<()>();
        for _ in 0..6 {
            let current = current.clone();
            let peak = peak.clone();
            let done = done_tx.clone();
            let run: Job = Box::new(move |_b| {
                let now = current.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(20));
                current.fetch_sub(1, Ordering::SeqCst);
                let _ = done.send(());
            });
            pool.submit_for(capped, run);
        }
        for _ in 0..6 {
            done_rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        }
        assert_eq!(peak.load(Ordering::SeqCst), 1, "cap=1 job ran concurrently");
        pool.finish_job(capped);
    }

    #[test]
    fn dag_propagates_parent_outputs() {
        let pool = WorkerPool::new(&BackendSpec::Native, 4);
        let mut dag: DagScheduler<usize> = DagScheduler::new();
        let a = dag.add(&[], |_b: &BlockBackend, _p: &[Arc<usize>]| Ok(1));
        let b = dag.add(&[a], |_b: &BlockBackend, p: &[Arc<usize>]| Ok(*p[0] * 10));
        let c = dag.add(&[a], |_b: &BlockBackend, p: &[Arc<usize>]| Ok(*p[0] * 100));
        let d = dag.add(&[b, c], |_b: &BlockBackend, p: &[Arc<usize>]| Ok(*p[0] + *p[1]));
        assert_eq!(dag.len(), 4);
        let out = dag.run(&pool).unwrap();
        assert_eq!(*out[b].output, 10);
        assert_eq!(*out[c].output, 100);
        assert_eq!(*out[d].output, 110);
        // children never start before their parents finish
        assert!(out[b].started >= out[a].finished - 1e-9);
        assert!(out[d].started >= out[c].finished - 1e-9);
    }

    #[test]
    fn dag_empty_is_ok() {
        let pool = WorkerPool::new(&BackendSpec::Native, 2);
        let dag: DagScheduler<()> = DagScheduler::new();
        assert!(dag.is_empty());
        assert!(dag.run(&pool).unwrap().is_empty());
    }

    #[test]
    fn dag_starts_children_before_sibling_stragglers_finish() {
        // PP-shaped DAG: a; then b1 (straggler) and b2 (fast) both depend
        // on a; c depends only on b2. Barrier-free scheduling must start —
        // and even finish — c while b1 is still running.
        let pool = WorkerPool::new(&BackendSpec::Native, 3);
        let sleep = |ms: u64| std::thread::sleep(std::time::Duration::from_millis(ms));
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        let a = dag.add(&[], move |_b: &BlockBackend, _p: &[Arc<u32>]| Ok(0));
        let b1 = dag.add(&[a], move |_b: &BlockBackend, _p: &[Arc<u32>]| {
            sleep(400);
            Ok(1)
        });
        let b2 = dag.add(&[a], move |_b: &BlockBackend, _p: &[Arc<u32>]| {
            sleep(25);
            Ok(2)
        });
        let c = dag.add(&[b2], move |_b: &BlockBackend, p: &[Arc<u32>]| {
            sleep(25);
            Ok(*p[0] + 1)
        });
        let out = dag.run(&pool).unwrap();
        assert_eq!(*out[c].output, 3);
        assert!(
            out[c].started < out[b1].finished,
            "c started at {:.3}s, after the straggler finished at {:.3}s",
            out[c].started,
            out[b1].finished
        );
        assert!(out[c].finished < out[b1].finished, "c should finish inside the straggler");
    }

    #[test]
    fn dag_errors_abort_descendants() {
        let pool = WorkerPool::new(&BackendSpec::Native, 2);
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        let a = dag.add(&[], |_b: &BlockBackend, _p: &[Arc<u32>]| Ok(7));
        let b = dag.add(&[a], |_b: &BlockBackend, _p: &[Arc<u32>]| anyhow::bail!("boom"));
        let _c = dag.add(&[b], |_b: &BlockBackend, p: &[Arc<u32>]| Ok(*p[0]));
        let err = dag.run(&pool).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("boom"), "{msg}");
        assert!(msg.contains("dag node 1"), "{msg}");
    }

    #[test]
    #[should_panic(expected = "not yet added")]
    fn dag_rejects_forward_dependencies() {
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        dag.add(&[3], |_b: &BlockBackend, _p: &[Arc<u32>]| Ok(0));
    }

    #[test]
    fn dag_task_panic_reports_error_instead_of_hanging() {
        let pool = WorkerPool::new(&BackendSpec::Native, 2);
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        let a = dag.add(&[], |_b: &BlockBackend, _p: &[Arc<u32>]| Ok(1));
        let _b = dag.add(&[a], |_b: &BlockBackend, _p: &[Arc<u32>]| -> anyhow::Result<u32> {
            panic!("kaboom")
        });
        let err = dag.run(&pool).unwrap_err();
        assert!(format!("{err:#}").contains("panicked"), "{err:#}");
    }

    #[test]
    fn dag_failure_keeps_completed_siblings_and_drains_in_flight() {
        // b panics while the straggler sibling c is still running: the
        // outcome must carry the failure AND both a's and c's outputs —
        // that is what checkpoint-on-abort persists after a crash
        let pool = WorkerPool::new(&BackendSpec::Native, 3);
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        let a = dag.add(&[], |_b: &BlockBackend, _p: &[Arc<u32>]| Ok(1));
        let b = dag.add(&[a], |_b: &BlockBackend, _p: &[Arc<u32>]| -> anyhow::Result<u32> {
            panic!("injected crash")
        });
        let c = dag.add(&[a], |_b: &BlockBackend, p: &[Arc<u32>]| {
            std::thread::sleep(std::time::Duration::from_millis(60));
            Ok(*p[0] + 10)
        });
        let d = dag.add(&[b], |_b: &BlockBackend, p: &[Arc<u32>]| Ok(*p[0]));
        let out = dag.run_with(&pool, &DagRunOpts::default()).unwrap();
        assert!(!out.cancelled);
        let err = out.failed.expect("panic must surface as a failure");
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked") && msg.contains("dag node 1"), "{msg}");
        assert_eq!(out.nodes[a].as_ref().map(|r| *r.output), Some(1));
        assert_eq!(
            out.nodes[c].as_ref().map(|r| *r.output),
            Some(11),
            "in-flight sibling must drain to completion, not be discarded"
        );
        assert!(out.nodes[d].is_none(), "descendant of the failed node never runs");

        // the pool is not poisoned: it keeps serving fresh work
        let tasks: Vec<_> = (0..6)
            .map(|i| move |_b: &BlockBackend| -> anyhow::Result<usize> { Ok(i) })
            .collect();
        assert_eq!(pool.run_phase(tasks).unwrap(), (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn dag_cancel_stops_dispatch_and_reports_partial_results() {
        let pool = WorkerPool::new(&BackendSpec::Native, 2);
        let cancel = Arc::new(AtomicBool::new(false));
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        let flip = cancel.clone();
        let a = dag.add(&[], move |_b: &BlockBackend, _p: &[Arc<u32>]| {
            // cancel lands while the root is still running
            flip.store(true, Ordering::Relaxed);
            Ok(1)
        });
        let b = dag.add(&[a], |_b: &BlockBackend, p: &[Arc<u32>]| Ok(*p[0] + 1));
        let _c = dag.add(&[b], |_b: &BlockBackend, p: &[Arc<u32>]| Ok(*p[0] + 1));
        let out = dag
            .run_with(
                &pool,
                &DagRunOpts { job: None, cancel: Some(cancel.clone()), on_ready: None },
            )
            .unwrap();
        assert!(out.cancelled);
        assert_eq!(out.nodes[a].as_ref().map(|r| *r.output), Some(1));
        assert!(out.nodes[b].is_none(), "child dispatched after cancel");
        assert!(out.nodes[2].is_none());
    }

    #[test]
    fn dag_cancel_before_start_runs_nothing() {
        let pool = WorkerPool::new(&BackendSpec::Native, 2);
        let cancel = Arc::new(AtomicBool::new(true));
        let mut dag: DagScheduler<u32> = DagScheduler::new();
        let ran = Arc::new(AtomicBool::new(false));
        let saw = ran.clone();
        dag.add(&[], move |_b: &BlockBackend, _p: &[Arc<u32>]| {
            saw.store(true, Ordering::Relaxed);
            Ok(1)
        });
        let out = dag
            .run_with(&pool, &DagRunOpts { job: None, cancel: Some(cancel), on_ready: None })
            .unwrap();
        assert!(out.cancelled);
        assert!(out.nodes[0].is_none());
        assert!(!ran.load(Ordering::Relaxed), "task ran despite pre-set cancel");
    }
}
