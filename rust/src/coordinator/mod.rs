//! The Layer-3 coordinator: Posterior Propagation over an I×J block grid
//! with distributed Gibbs inside each block — the paper's contribution.
//!
//! Pipeline (paper §2.4, Fig. 1):
//! 1. `partition::Grid` cuts R into blocks.
//! 2. Phase (a): full joint Gibbs on block (0,0).
//! 3. Phase (b): first-row and first-column blocks in parallel, consuming
//!    phase-(a) posterior marginals as priors.
//! 4. Phase (c): all remaining blocks in parallel, consuming phase-(b)
//!    marginals.
//! 5. `aggregate` combines subset posteriors, dividing away multiply-
//!    counted propagated priors.
//!
//! Phases are scheduled as a dependency DAG (`scheduler::DagScheduler`):
//! by default a block runs the moment the posteriors it consumes exist,
//! so no phase barrier stalls on stragglers; `SchedulerMode::Barrier`
//! restores the classic phase-synchronous schedule for comparison. Both
//! produce bitwise-identical posteriors.
//!
//! Within each block, the Gibbs half-sweeps execute over row shards
//! (`worker`) — the distributed-BMF-inside-a-block layer of the paper —
//! through either the AOT HLO runtime or the native oracle backend. The
//! half-sweeps themselves run in one of two regimes
//! ([`SweepMode`]): classic lockstep (sample, then exchange), or
//! GASPI-style pipelined (`mailbox`), where finished factor chunks are
//! published to the other shards while sampling continues, overlapping
//! the exchange with computation under a bounded staleness τ.
//!
//! The public entry point is the [`Engine`]: it owns the persistent worker
//! pool and runs many jobs against it warm — *concurrently*:
//! [`Engine::submit`] is non-blocking and returns a [`Session`] (stable
//! [`JobId`], streamed [`TrainEvent`]s, `cancel`/`pause`/`resume`/`status`
//! lifecycle control), all sessions feed one shared [`Priority`]-ordered
//! ready-queue on the pool, and every completed run yields a servable
//! [`PosteriorModel`] (what `checkpoint` persists). A cancelled session
//! writes a partial (v3) checkpoint of its completed block posteriors;
//! `TrainConfig::resume_from` continues from it bitwise-identically.
//!
//! The engine is production-interruptible: periodic checkpoint
//! generations (`TrainConfig::{checkpoint_every, checkpoint_dir}`)
//! survive hard crashes, a panicking block fails only its own session
//! ([`TrainOutcome::Failed`]), and an [`AdmissionPolicy`] bounds the
//! backlog ([`SubmitError::BacklogFull`]) with per-job queue-wait
//! fairness reported in `RunStats::queue_wait_secs`.

pub mod aggregate;
pub mod backend;
pub mod block_task;
pub mod checkpoint;
pub mod config;
pub mod engine;
pub mod mailbox;
pub mod scheduler;
pub mod trainer;
pub mod worker;

pub use config::{BackendSpec, ConfigError, SchedulerMode, SweepMode, TrainConfig};
pub use engine::{
    AdmissionPolicy, Engine, Factorizer, FactorSide, FitOutcome, JobSnapshot, JobStatus,
    PpFactorizer, PpPhase, Session, SubmitError, TrainEvent,
};
pub use mailbox::{FactorMailbox, MailboxCounters};
pub use scheduler::{JobId, Priority};
pub use trainer::{CancelInfo, FailInfo, TrainOutcome, TrainResult};

pub use crate::posterior::PosteriorModel;
