//! MCMC on one block: the unit of work the PP phases schedule.
//!
//! Runs `burnin + samples` Gibbs sweeps over the block, alternating the
//! row side and the column side. A side either has a **propagated prior**
//! (fixed per-row Gaussians from an earlier PP phase) or a **fresh prior**
//! (Normal-Wishart hyperparameters resampled each sweep, as in plain
//! BPMF). Retained samples stream into `RunningMoments`; the result is the
//! per-row Gaussian posterior marginals that PP propagates onward.

use super::backend::{BlockBackend, BlockData};
use super::config::SweepMode;
use super::engine::FactorSide;
use super::mailbox::FactorMailbox;
use super::worker::{pipelined_sweep, sample_side_sharded, ChunkObs};
use crate::gibbs::hyper::{sample_hyper, NormalWishartPrior};
use crate::gibbs::native::GibbsPrecision;
use crate::posterior::{RowGaussians, RunningMoments};
use crate::rng::{normal::standard_normal_vec, Rng};

/// Posterior marginals of one block's factor sub-matrices.
#[derive(Debug, Clone)]
pub struct BlockPosteriors {
    /// Row-side posterior marginals.
    pub u: RowGaussians,
    /// Column-side posterior marginals.
    pub v: RowGaussians,
}

/// Run statistics (feed the Table-1 throughput rows and the cluster
/// simulator's calibration).
#[derive(Debug, Clone, Copy, Default)]
pub struct BlockRunStats {
    /// Total Gibbs sweeps run (burn-in + retained).
    pub sweeps: usize,
    /// Wall-clock seconds of the block's MCMC.
    pub secs: f64,
    /// Factor rows sampled across all sweeps (both sides).
    pub rows_processed: u64,
    /// Rating observations visited across all sweeps (both sides).
    pub ratings_processed: u64,
    /// V-side receive + compute seconds that ran while the U side was
    /// still sampling/publishing — the compute/communication overlap of
    /// [`SweepMode::Pipelined`]; always 0 under [`SweepMode::Lockstep`].
    pub comm_overlap_secs: f64,
    /// Chunks served from the previous sweep across all stale-bounded
    /// mailbox reads (pipelined sweeps only).
    pub stale_chunk_reads: u64,
    /// Largest number of unpublished chunks any single mailbox read
    /// proceeded with — never above the configured staleness bound τ.
    pub max_staleness: u64,
}

/// Output of one node in the PP task DAG: either a sampled block's
/// posterior marginals, or one aggregated part (a row-group or
/// column-group) of the final factor posterior. Keeping both in one type
/// lets the scheduler pipeline sampling and aggregation without barriers.
#[derive(Debug, Clone)]
pub enum PpTaskOutput {
    /// A sampled block's posterior marginals plus its run statistics.
    Block(BlockPosteriors, BlockRunStats),
    /// One aggregated part of the final factor posterior.
    Part(RowGaussians),
    /// Output of a synthetic phase-join node (barrier mode only): carries
    /// no data, exists so N downstream blocks can wait on one node instead
    /// of each holding edges to every block of the previous phase.
    Barrier,
}

impl PpTaskOutput {
    /// The block posteriors; panics on a non-block node (the trainer
    /// wires block outputs only into nodes expecting blocks).
    pub fn block(&self) -> &BlockPosteriors {
        match self {
            PpTaskOutput::Block(p, _) => p,
            _ => panic!("expected a block node output"),
        }
    }

    /// The block's run statistics, if this node sampled a block.
    pub fn block_stats(&self) -> Option<&BlockRunStats> {
        match self {
            PpTaskOutput::Block(_, s) => Some(s),
            _ => None,
        }
    }

    /// The aggregated posterior part; panics on a non-part node.
    pub fn part(&self) -> &RowGaussians {
        match self {
            PpTaskOutput::Part(g) => g,
            _ => panic!("expected an aggregation node output"),
        }
    }
}

/// Configuration subset a block task needs.
#[derive(Debug, Clone, Copy)]
pub struct BlockTaskCfg {
    /// Latent dimension.
    pub k: usize,
    /// Residual noise precision τ.
    pub tau: f64,
    /// Burn-in sweeps before samples are retained.
    pub burnin: usize,
    /// Retained sweeps (posterior moments are formed from these).
    pub samples: usize,
    /// Within-block shard workers.
    pub workers: usize,
    /// Ridge added when finalizing sample moments.
    pub ridge: f64,
    /// Block RNG seed.
    pub seed: u64,
    /// Lockstep vs pipelined half-sweeps.
    pub sweep: SweepMode,
    /// Rows per published chunk (pipelined sweeps).
    pub chunk_rows: usize,
    /// Staleness bound τ in chunks (pipelined sweeps).
    pub staleness: usize,
    /// Floating-point regime of the native Gibbs kernel. The default
    /// [`GibbsPrecision::F64`] participates in every bitwise-equivalence
    /// contract; [`GibbsPrecision::F32`] trades those contracts for a
    /// smaller working set (see `docs/PERFORMANCE.md`).
    pub precision: GibbsPrecision,
}

/// Observers a block task streams progress through. Both are optional and
/// neither ever touches the block's RNG, so the posterior is bitwise
/// identical with or without them.
#[derive(Clone, Copy, Default)]
pub struct BlockObs<'a> {
    /// Receives `(sweep index, block training RMSE of the current factor
    /// sample)` after every retained sweep — streamed as
    /// `TrainEvent::SweepSample`.
    pub sweep: Option<&'a dyn Fn(usize, f64)>,
    /// Receives `(side, sweep, chunk, writer seq)` for every chunk a
    /// pipelined half-sweep publishes — streamed as
    /// `TrainEvent::ChunkExchanged`. Called from worker threads.
    pub chunk: Option<&'a (dyn Fn(FactorSide, usize, usize, u64) + Sync)>,
}

/// N(0, 0.1) factor initialization both sweep schedules share — the τ=0
/// bitwise-equivalence contract requires lockstep and pipelined runs to
/// consume the block RNG identically, so the sequence lives here once.
fn init_factors(rng: &mut Rng, n: usize, d: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
    let mut u: Vec<f32> = standard_normal_vec(rng, n * k);
    let mut v: Vec<f32> = standard_normal_vec(rng, d * k);
    for x in u.iter_mut().chain(v.iter_mut()) {
        *x *= 0.1;
    }
    (u, v)
}

/// Hyper-sample a fresh broadcast prior from the current factor state —
/// the per-sweep RNG draw both sweep schedules share (see
/// [`init_factors`] on why this must not be duplicated).
fn fresh_prior(
    rng: &mut Rng,
    hyper_prior: &NormalWishartPrior,
    factors: &[f32],
    n: usize,
    k: usize,
) -> RowGaussians {
    let f64s: Vec<f64> = factors.iter().map(|&x| x as f64).collect();
    let h = sample_hyper(rng, hyper_prior, &f64s, n, k);
    RowGaussians::broadcast(n, &h.mu, &h.lambda)
}

/// Run the block's MCMC. `u_prior`/`v_prior`: propagated priors, or None
/// for a fresh (hyper-sampled) prior; `obs` carries the optional progress
/// observers. Dispatches on [`BlockTaskCfg::sweep`]: lockstep half-sweeps
/// run on any backend, pipelined half-sweeps are native-only (the PJRT
/// engine is thread-confined) and fall back to lockstep on HLO.
pub fn run_block(
    backend: &BlockBackend,
    data: &BlockData,
    cfg: &BlockTaskCfg,
    u_prior: Option<&RowGaussians>,
    v_prior: Option<&RowGaussians>,
    obs: BlockObs<'_>,
) -> anyhow::Result<(BlockPosteriors, BlockRunStats)> {
    match cfg.sweep {
        SweepMode::Pipelined if !backend.is_hlo() => {
            run_block_pipelined(data, cfg, u_prior, v_prior, obs)
        }
        SweepMode::Pipelined => {
            log::warn!(
                "pipelined sweeps are native-only; block falls back to lockstep on HLO"
            );
            run_block_lockstep(backend, data, cfg, u_prior, v_prior, obs)
        }
        SweepMode::Lockstep => run_block_lockstep(backend, data, cfg, u_prior, v_prior, obs),
    }
}

/// The classic synchronous schedule: full U half-sweep (sharded, gathered),
/// then full V half-sweep — the reference the pipelined mode is validated
/// against.
fn run_block_lockstep(
    backend: &BlockBackend,
    data: &BlockData,
    cfg: &BlockTaskCfg,
    u_prior: Option<&RowGaussians>,
    v_prior: Option<&RowGaussians>,
    obs: BlockObs<'_>,
) -> anyhow::Result<(BlockPosteriors, BlockRunStats)> {
    let k = cfg.k;
    let (n, d) = (data.rows(), data.cols());
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let t0 = std::time::Instant::now();
    let (mut u, mut v) = init_factors(&mut rng, n, d, k);

    let hyper_prior = NormalWishartPrior::default_for_dim(k);
    let mut u_moments = RunningMoments::new(n, k);
    let mut v_moments = RunningMoments::new(d, k);
    let total_sweeps = cfg.burnin + cfg.samples.max(2);

    // scratch for hyper-sampled priors (avoids a clone of the propagated
    // prior every sweep — it is borrowed directly)
    let mut fresh_u: Option<RowGaussians> = None;
    let mut fresh_v: Option<RowGaussians> = None;
    let mut noise_u = vec![0.0f32; n * k];
    let mut noise_v = vec![0.0f32; d * k];

    for sweep in 0..total_sweeps {
        // --- U side ---
        let prior_u: &RowGaussians = match u_prior {
            Some(p) => p,
            None => &*fresh_u.insert(fresh_prior(&mut rng, &hyper_prior, &u, n, k)),
        };
        crate::rng::normal::fill_standard_normal(&mut rng, &mut noise_u);
        let (u_new, _) = sample_side_sharded(
            backend, data, false, &v, prior_u, cfg.tau, &noise_u, cfg.workers,
            cfg.precision,
        )?;
        u = u_new;

        // --- V side ---
        let prior_v: &RowGaussians = match v_prior {
            Some(p) => p,
            None => &*fresh_v.insert(fresh_prior(&mut rng, &hyper_prior, &v, d, k)),
        };
        crate::rng::normal::fill_standard_normal(&mut rng, &mut noise_v);
        let (v_new, _) = sample_side_sharded(
            backend, data, true, &u, prior_v, cfg.tau, &noise_v, cfg.workers,
            cfg.precision,
        )?;
        v = v_new;

        if sweep >= cfg.burnin {
            u_moments.push_f32(&u);
            v_moments.push_f32(&v);
            if let Some(f) = obs.sweep {
                f(sweep, sample_rmse(&data.coo, &u, &v, k));
            }
        }
    }
    drop((fresh_u, fresh_v));

    let stats = BlockRunStats {
        sweeps: total_sweeps,
        secs: t0.elapsed().as_secs_f64(),
        rows_processed: ((n + d) * total_sweeps) as u64,
        ratings_processed: (2 * data.coo.nnz() * total_sweeps) as u64,
        comm_overlap_secs: 0.0,
        stale_chunk_reads: 0,
        max_staleness: 0,
    };
    let posteriors = BlockPosteriors {
        u: u_moments.finalize(cfg.ridge),
        v: v_moments.finalize(cfg.ridge),
    };
    Ok((posteriors, stats))
}

/// The GASPI-style pipelined schedule: each half-sweep publishes per-shard
/// chunks to a double-buffered [`FactorMailbox`] while sampling continues,
/// and the opposite half-sweep starts under a bounded staleness τ
/// ([`BlockTaskCfg::staleness`]). τ = 0 reproduces the lockstep posterior
/// bitwise; the RNG draw order (hyper U, noise U, hyper V, noise V per
/// sweep) is identical to the lockstep schedule by construction.
fn run_block_pipelined(
    data: &BlockData,
    cfg: &BlockTaskCfg,
    u_prior: Option<&RowGaussians>,
    v_prior: Option<&RowGaussians>,
    obs: BlockObs<'_>,
) -> anyhow::Result<(BlockPosteriors, BlockRunStats)> {
    anyhow::ensure!(cfg.chunk_rows > 0, "chunk_rows must be > 0");
    let k = cfg.k;
    let (n, d) = (data.rows(), data.cols());
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let t0 = std::time::Instant::now();
    let (mut u, mut v) = init_factors(&mut rng, n, d, k);

    let mut u_mail = FactorMailbox::new(n, k, cfg.chunk_rows, &u);
    let mut v_mail = FactorMailbox::new(d, k, cfg.chunk_rows, &v);

    let hyper_prior = NormalWishartPrior::default_for_dim(k);
    let mut u_moments = RunningMoments::new(n, k);
    let mut v_moments = RunningMoments::new(d, k);
    let total_sweeps = cfg.burnin + cfg.samples.max(2);
    let mut fresh_u: Option<RowGaussians> = None;
    let mut fresh_v: Option<RowGaussians> = None;
    let mut noise_u = vec![0.0f32; n * k];
    let mut noise_v = vec![0.0f32; d * k];
    let mut overlap_secs = 0.0f64;

    for sweep in 0..total_sweeps {
        // RNG draw order matches lockstep exactly: hyper(U) — if fresh —
        // then noise(U), hyper(V), noise(V); sampling consumes no RNG
        let prior_u: &RowGaussians = match u_prior {
            Some(p) => p,
            None => &*fresh_u.insert(fresh_prior(&mut rng, &hyper_prior, &u, n, k)),
        };
        crate::rng::normal::fill_standard_normal(&mut rng, &mut noise_u);
        let prior_v: &RowGaussians = match v_prior {
            Some(p) => p,
            None => &*fresh_v.insert(fresh_prior(&mut rng, &hyper_prior, &v, d, k)),
        };
        crate::rng::normal::fill_standard_normal(&mut rng, &mut noise_v);

        // wrap the per-chunk observer with this sweep's index
        let sweep_cb;
        let chunk_obs: ChunkObs<'_> = match obs.chunk {
            Some(f) => {
                sweep_cb =
                    move |side: FactorSide, chunk: usize, seq: u64| f(side, sweep, chunk, seq);
                Some(&sweep_cb)
            }
            None => None,
        };

        overlap_secs += pipelined_sweep(
            data,
            k,
            cfg.tau,
            cfg.workers,
            prior_u,
            prior_v,
            &noise_u,
            &noise_v,
            &mut u_mail,
            &mut v_mail,
            cfg.staleness,
            chunk_obs,
            cfg.precision,
        )?;

        // refresh the main-thread factor snapshots (epoch is complete, so
        // these reads are immediate and never stale)
        u_mail.assemble_latest(&mut u, 0);
        v_mail.assemble_latest(&mut v, 0);
        if sweep >= cfg.burnin {
            u_moments.push_f32(&u);
            v_moments.push_f32(&v);
            if let Some(f) = obs.sweep {
                f(sweep, sample_rmse(&data.coo, &u, &v, k));
            }
        }
    }
    drop((fresh_u, fresh_v));

    let (uc, vc) = (u_mail.counters(), v_mail.counters());
    let stats = BlockRunStats {
        sweeps: total_sweeps,
        secs: t0.elapsed().as_secs_f64(),
        rows_processed: ((n + d) * total_sweeps) as u64,
        ratings_processed: (2 * data.coo.nnz() * total_sweeps) as u64,
        comm_overlap_secs: overlap_secs,
        stale_chunk_reads: uc.stale_chunk_reads + vc.stale_chunk_reads,
        max_staleness: uc.max_staleness.max(vc.max_staleness),
    };
    let posteriors = BlockPosteriors {
        u: u_moments.finalize(cfg.ridge),
        v: v_moments.finalize(cfg.ridge),
    };
    Ok((posteriors, stats))
}

/// RMSE of the current factor sample on the block's own (centred) ratings.
fn sample_rmse(coo: &crate::data::sparse::Coo, u: &[f32], v: &[f32], k: usize) -> f64 {
    if coo.nnz() == 0 {
        return 0.0;
    }
    let mut sse = 0.0f64;
    for e in &coo.entries {
        let (r, c) = (e.row as usize, e.col as usize);
        let dot: f64 = (0..k).map(|j| (u[r * k + j] * v[c * k + j]) as f64).sum();
        sse += (e.val as f64 - dot).powi(2);
    }
    (sse / coo.nnz() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Coo;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn block_from_factors(
        n: usize,
        d: usize,
        k: usize,
        seed: u64,
        density: f64,
    ) -> (BlockData, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let scale = (1.0 / k as f64).sqrt() as f32;
        let u: Vec<f32> =
            standard_normal_vec(&mut rng, n * k).iter().map(|x| x * scale).collect();
        let v: Vec<f32> =
            standard_normal_vec(&mut rng, d * k).iter().map(|x| x * scale).collect();
        let mut coo = Coo::new(n, d);
        for r in 0..n {
            for c in 0..d {
                if rng.bernoulli(density) {
                    let dot: f32 = (0..k).map(|j| u[r * k + j] * v[c * k + j]).sum();
                    coo.push(r, c, dot + 0.05 * standard_normal_vec(&mut rng, 1)[0]);
                }
            }
        }
        (BlockData::new(coo), u, v)
    }

    fn cfg(k: usize, seed: u64) -> BlockTaskCfg {
        BlockTaskCfg {
            k,
            tau: 10.0,
            burnin: 6,
            samples: 10,
            workers: 1,
            ridge: 1e-3,
            seed,
            sweep: SweepMode::Lockstep,
            chunk_rows: 8,
            staleness: 0,
            precision: GibbsPrecision::F64,
        }
    }

    #[test]
    fn block_posterior_predicts_block() {
        let (data, _, _) = block_from_factors(30, 25, 4, 60, 0.5);
        let backend = BlockBackend::Native;
        let (post, stats) =
            run_block(&backend, &data, &cfg(4, 61), None, None, BlockObs::default()).unwrap();
        assert_eq!(post.u.n, 30);
        assert_eq!(post.v.n, 25);
        assert_eq!(stats.sweeps, 16);
        // posterior means should reconstruct the block's ratings decently
        let mut sse = 0.0;
        let mut var = 0.0;
        let mean_rating = data.coo.mean();
        for e in &data.coo.entries {
            let (r, c) = (e.row as usize, e.col as usize);
            let pred: f64 = (0..4)
                .map(|j| post.u.row_mean(r)[j] * post.v.row_mean(c)[j])
                .sum();
            sse += (pred - e.val as f64).powi(2);
            var += (e.val as f64 - mean_rating).powi(2);
        }
        assert!(sse < 0.5 * var, "fit explains < 50% of variance: {sse} vs {var}");
    }

    #[test]
    fn propagated_prior_is_respected() {
        // empty block → posterior ≈ prior (no data to move it)
        let data = BlockData::new(Coo::new(8, 6));
        let k = 3;
        let mut prior_u = RowGaussians::standard(8, k, 50.0); // tight prior
        for i in 0..8 {
            prior_u.mean[i * k] = 2.0;
        }
        let backend = BlockBackend::Native;
        let c = BlockTaskCfg { burnin: 4, samples: 30, ridge: 1e-4, seed: 3, tau: 1.0, ..cfg(k, 3) };
        let (post, _) =
            run_block(&backend, &data, &c, Some(&prior_u), None, BlockObs::default()).unwrap();
        for i in 0..8 {
            assert!(
                (post.u.row_mean(i)[0] - 2.0).abs() < 0.25,
                "row {i} mean {} drifted from tight prior",
                post.u.row_mean(i)[0]
            );
        }
    }

    #[test]
    fn worker_count_does_not_change_posterior_means_much() {
        let (data, _, _) = block_from_factors(24, 20, 4, 62, 0.4);
        let backend = BlockBackend::Native;
        let (p1, _) =
            run_block(&backend, &data, &cfg(4, 63), None, None, BlockObs::default()).unwrap();
        let mut c2 = cfg(4, 63);
        c2.workers = 3;
        let (p3, _) = run_block(&backend, &data, &c2, None, None, BlockObs::default()).unwrap();
        // identical seeds + sharding-invariant math → identical chains
        for i in 0..24 {
            for j in 0..4 {
                assert!((p1.u.row_mean(i)[j] - p3.u.row_mean(i)[j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn posterior_precisions_are_spd() {
        let (data, _, _) = block_from_factors(12, 10, 3, 64, 0.6);
        let backend = BlockBackend::Native;
        let (post, _) =
            run_block(&backend, &data, &cfg(3, 65), None, None, BlockObs::default()).unwrap();
        for i in 0..post.u.n {
            let p: Mat = post.u.row_prec(i);
            assert!(crate::linalg::Cholesky::new(&p).is_ok(), "row {i} precision not SPD");
        }
    }

    #[test]
    fn pipelined_tau0_two_shards_matches_lockstep_bitwise() {
        // the τ = 0 contract: a deterministic two-shard pipelined run is
        // indistinguishable from lockstep to the last bit, because every
        // read waits for the complete opposite side
        let (data, _, _) = block_from_factors(48, 40, 4, 70, 0.4);
        let backend = BlockBackend::Native;
        let mut lock_cfg = cfg(4, 71);
        lock_cfg.workers = 2;
        let (lock, lock_stats) =
            run_block(&backend, &data, &lock_cfg, None, None, BlockObs::default()).unwrap();
        let mut pipe_cfg = lock_cfg;
        pipe_cfg.sweep = SweepMode::Pipelined;
        pipe_cfg.chunk_rows = 8;
        pipe_cfg.staleness = 0;
        let (pipe, pipe_stats) =
            run_block(&backend, &data, &pipe_cfg, None, None, BlockObs::default()).unwrap();
        assert_eq!(pipe.u.mean, lock.u.mean, "U means");
        assert_eq!(pipe.u.prec, lock.u.prec, "U precisions");
        assert_eq!(pipe.v.mean, lock.v.mean, "V means");
        assert_eq!(pipe.v.prec, lock.v.prec, "V precisions");
        // τ = 0 forbids stale reads; lockstep reports no overlap by definition
        assert_eq!(pipe_stats.stale_chunk_reads, 0);
        assert_eq!(pipe_stats.max_staleness, 0);
        assert_eq!(lock_stats.comm_overlap_secs, 0.0);
        assert!(pipe_stats.comm_overlap_secs >= 0.0);
    }

    #[test]
    fn pipelined_staleness_never_exceeds_bound() {
        // τ > 0 relaxes the read gate, but the mailbox counters must show
        // every read stayed within τ chunks of the writers' sequence
        let (data, _, _) = block_from_factors(60, 44, 4, 72, 0.4);
        let backend = BlockBackend::Native;
        for tau in [1usize, 3] {
            let mut c = cfg(4, 73);
            c.sweep = SweepMode::Pipelined;
            c.workers = 3;
            c.chunk_rows = 4;
            c.staleness = tau;
            let (post, stats) =
                run_block(&backend, &data, &c, None, None, BlockObs::default()).unwrap();
            assert!(
                stats.max_staleness <= tau as u64,
                "τ={tau}: observed staleness {}",
                stats.max_staleness
            );
            assert!(post.u.mean.iter().all(|x| x.is_finite()));
            assert!(post.v.mean.iter().all(|x| x.is_finite()));
            // the posterior must still explain the block about as well as
            // the lockstep fit (statistical validation, not bitwise)
            let (lock, _) =
                run_block(&backend, &data, &cfg(4, 73), None, None, BlockObs::default())
                    .unwrap();
            let sse = |p: &BlockPosteriors| {
                data.coo
                    .entries
                    .iter()
                    .map(|e| {
                        let (r, c2) = (e.row as usize, e.col as usize);
                        let pred: f64 = (0..4)
                            .map(|j| p.u.row_mean(r)[j] * p.v.row_mean(c2)[j])
                            .sum();
                        (pred - e.val as f64).powi(2)
                    })
                    .sum::<f64>()
            };
            let (s_pipe, s_lock) = (sse(&post), sse(&lock));
            assert!(
                s_pipe < 2.0 * s_lock.max(1e-6),
                "τ={tau}: pipelined SSE {s_pipe} vs lockstep {s_lock}"
            );
        }
    }

    #[test]
    fn pipelined_chunk_observer_sees_all_publications() {
        let (data, _, _) = block_from_factors(24, 20, 3, 74, 0.5);
        let backend = BlockBackend::Native;
        let mut c = cfg(3, 75);
        c.sweep = SweepMode::Pipelined;
        c.workers = 2;
        c.chunk_rows = 6;
        c.staleness = 1;
        let seen = std::sync::Mutex::new(Vec::<(FactorSide, usize, usize, u64)>::new());
        let chunk_obs = |side: FactorSide, sweep: usize, chunk: usize, seq: u64| {
            seen.lock().unwrap().push((side, sweep, chunk, seq));
        };
        let obs = BlockObs { sweep: None, chunk: Some(&chunk_obs) };
        let (_, stats) = run_block(&backend, &data, &c, None, None, obs).unwrap();
        let seen = seen.into_inner().unwrap();
        // U side: ceil(24/6) = 4 chunks, V side: ceil(20/6) = 4 chunks,
        // published once per sweep each
        assert_eq!(seen.len(), stats.sweeps * (4 + 4));
        assert!(seen.iter().all(|&(_, sweep, _, _)| sweep < stats.sweeps));
    }

    #[test]
    fn sweep_observer_sees_every_retained_sweep_without_changing_the_chain() {
        let (data, _, _) = block_from_factors(20, 16, 4, 66, 0.5);
        let backend = BlockBackend::Native;
        let seen = std::cell::RefCell::new(Vec::<(usize, f64)>::new());
        let obs = |sweep: usize, rmse: f64| seen.borrow_mut().push((sweep, rmse));
        let c = cfg(4, 67);
        let with_obs = BlockObs { sweep: Some(&obs), chunk: None };
        let (observed, _) = run_block(&backend, &data, &c, None, None, with_obs).unwrap();
        let (silent, _) = run_block(&backend, &data, &c, None, None, BlockObs::default()).unwrap();
        let seen = seen.into_inner();
        assert_eq!(seen.len(), c.samples, "one sample per retained sweep");
        assert!(seen.iter().all(|&(s, r)| s >= c.burnin && r.is_finite() && r >= 0.0));
        // observing must not perturb the RNG stream
        assert_eq!(observed.u.mean, silent.u.mean);
        assert_eq!(observed.v.prec, silent.v.prec);
    }
}
