//! MCMC on one block: the unit of work the PP phases schedule.
//!
//! Runs `burnin + samples` Gibbs sweeps over the block, alternating the
//! row side and the column side. A side either has a **propagated prior**
//! (fixed per-row Gaussians from an earlier PP phase) or a **fresh prior**
//! (Normal-Wishart hyperparameters resampled each sweep, as in plain
//! BPMF). Retained samples stream into `RunningMoments`; the result is the
//! per-row Gaussian posterior marginals that PP propagates onward.

use super::backend::{BlockBackend, BlockData};
use super::worker::sample_side_sharded;
use crate::gibbs::hyper::{sample_hyper, NormalWishartPrior};
use crate::posterior::{RowGaussians, RunningMoments};
use crate::rng::{normal::standard_normal_vec, Rng};

/// Posterior marginals of one block's factor sub-matrices.
#[derive(Debug, Clone)]
pub struct BlockPosteriors {
    pub u: RowGaussians,
    pub v: RowGaussians,
}

/// Run statistics (feed the Table-1 throughput rows and the cluster
/// simulator's calibration).
#[derive(Debug, Clone, Copy, Default)]
pub struct BlockRunStats {
    pub sweeps: usize,
    pub secs: f64,
    pub rows_processed: u64,
    pub ratings_processed: u64,
}

/// Output of one node in the PP task DAG: either a sampled block's
/// posterior marginals, or one aggregated part (a row-group or
/// column-group) of the final factor posterior. Keeping both in one type
/// lets the scheduler pipeline sampling and aggregation without barriers.
#[derive(Debug, Clone)]
pub enum PpTaskOutput {
    Block(BlockPosteriors, BlockRunStats),
    Part(RowGaussians),
    /// Output of a synthetic phase-join node (barrier mode only): carries
    /// no data, exists so N downstream blocks can wait on one node instead
    /// of each holding edges to every block of the previous phase.
    Barrier,
}

impl PpTaskOutput {
    /// The block posteriors; panics on a non-block node (the trainer
    /// wires block outputs only into nodes expecting blocks).
    pub fn block(&self) -> &BlockPosteriors {
        match self {
            PpTaskOutput::Block(p, _) => p,
            _ => panic!("expected a block node output"),
        }
    }

    /// The block's run statistics, if this node sampled a block.
    pub fn block_stats(&self) -> Option<&BlockRunStats> {
        match self {
            PpTaskOutput::Block(_, s) => Some(s),
            _ => None,
        }
    }

    /// The aggregated posterior part; panics on a non-part node.
    pub fn part(&self) -> &RowGaussians {
        match self {
            PpTaskOutput::Part(g) => g,
            _ => panic!("expected an aggregation node output"),
        }
    }
}

/// Configuration subset a block task needs.
#[derive(Debug, Clone, Copy)]
pub struct BlockTaskCfg {
    pub k: usize,
    pub tau: f64,
    pub burnin: usize,
    pub samples: usize,
    pub workers: usize,
    pub ridge: f64,
    pub seed: u64,
}

/// Run the block's MCMC. `u_prior`/`v_prior`: propagated priors, or None
/// for a fresh (hyper-sampled) prior. `sweep_obs`, when present, receives
/// `(sweep index, block training RMSE of the current factor sample)` after
/// every retained sweep — the live mixing signal streamed as
/// `TrainEvent::SweepSample`. Observation never touches the RNG, so the
/// posterior is bitwise identical with or without an observer.
pub fn run_block(
    backend: &BlockBackend,
    data: &BlockData,
    cfg: &BlockTaskCfg,
    u_prior: Option<&RowGaussians>,
    v_prior: Option<&RowGaussians>,
    sweep_obs: Option<&dyn Fn(usize, f64)>,
) -> anyhow::Result<(BlockPosteriors, BlockRunStats)> {
    let k = cfg.k;
    let (n, d) = (data.rows(), data.cols());
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let t0 = std::time::Instant::now();

    // init factors
    let mut u: Vec<f32> = standard_normal_vec(&mut rng, n * k);
    let mut v: Vec<f32> = standard_normal_vec(&mut rng, d * k);
    for x in u.iter_mut().chain(v.iter_mut()) {
        *x *= 0.1;
    }

    let hyper_prior = NormalWishartPrior::default_for_dim(k);
    let mut u_moments = RunningMoments::new(n, k);
    let mut v_moments = RunningMoments::new(d, k);
    let total_sweeps = cfg.burnin + cfg.samples.max(2);

    // scratch for hyper-sampled priors (avoids a clone of the propagated
    // prior every sweep — it is borrowed directly)
    let mut fresh_u: Option<RowGaussians> = None;
    let mut fresh_v: Option<RowGaussians> = None;
    let mut noise_u = vec![0.0f32; n * k];
    let mut noise_v = vec![0.0f32; d * k];

    for sweep in 0..total_sweeps {
        // --- U side ---
        let prior_u: &RowGaussians = match u_prior {
            Some(p) => p,
            None => {
                let uf: Vec<f64> = u.iter().map(|&x| x as f64).collect();
                let h = sample_hyper(&mut rng, &hyper_prior, &uf, n, k);
                fresh_u = Some(RowGaussians::broadcast(n, &h.mu, &h.lambda));
                fresh_u.as_ref().unwrap()
            }
        };
        crate::rng::normal::fill_standard_normal(&mut rng, &mut noise_u);
        let (u_new, _) = sample_side_sharded(
            backend, data, false, &v, prior_u, cfg.tau, &noise_u, cfg.workers,
        )?;
        u = u_new;

        // --- V side ---
        let prior_v: &RowGaussians = match v_prior {
            Some(p) => p,
            None => {
                let vf: Vec<f64> = v.iter().map(|&x| x as f64).collect();
                let h = sample_hyper(&mut rng, &hyper_prior, &vf, d, k);
                fresh_v = Some(RowGaussians::broadcast(d, &h.mu, &h.lambda));
                fresh_v.as_ref().unwrap()
            }
        };
        crate::rng::normal::fill_standard_normal(&mut rng, &mut noise_v);
        let (v_new, _) = sample_side_sharded(
            backend, data, true, &u, prior_v, cfg.tau, &noise_v, cfg.workers,
        )?;
        v = v_new;

        if sweep >= cfg.burnin {
            u_moments.push_f32(&u);
            v_moments.push_f32(&v);
            if let Some(obs) = sweep_obs {
                obs(sweep, sample_rmse(&data.coo, &u, &v, k));
            }
        }
    }
    drop((fresh_u, fresh_v));

    let stats = BlockRunStats {
        sweeps: total_sweeps,
        secs: t0.elapsed().as_secs_f64(),
        rows_processed: ((n + d) * total_sweeps) as u64,
        ratings_processed: (2 * data.coo.nnz() * total_sweeps) as u64,
    };
    let posteriors = BlockPosteriors {
        u: u_moments.finalize(cfg.ridge),
        v: v_moments.finalize(cfg.ridge),
    };
    Ok((posteriors, stats))
}

/// RMSE of the current factor sample on the block's own (centred) ratings.
fn sample_rmse(coo: &crate::data::sparse::Coo, u: &[f32], v: &[f32], k: usize) -> f64 {
    if coo.nnz() == 0 {
        return 0.0;
    }
    let mut sse = 0.0f64;
    for e in &coo.entries {
        let (r, c) = (e.row as usize, e.col as usize);
        let dot: f64 = (0..k).map(|j| (u[r * k + j] * v[c * k + j]) as f64).sum();
        sse += (e.val as f64 - dot).powi(2);
    }
    (sse / coo.nnz() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Coo;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn block_from_factors(
        n: usize,
        d: usize,
        k: usize,
        seed: u64,
        density: f64,
    ) -> (BlockData, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let scale = (1.0 / k as f64).sqrt() as f32;
        let u: Vec<f32> =
            standard_normal_vec(&mut rng, n * k).iter().map(|x| x * scale).collect();
        let v: Vec<f32> =
            standard_normal_vec(&mut rng, d * k).iter().map(|x| x * scale).collect();
        let mut coo = Coo::new(n, d);
        for r in 0..n {
            for c in 0..d {
                if rng.bernoulli(density) {
                    let dot: f32 = (0..k).map(|j| u[r * k + j] * v[c * k + j]).sum();
                    coo.push(r, c, dot + 0.05 * standard_normal_vec(&mut rng, 1)[0]);
                }
            }
        }
        (BlockData::new(coo), u, v)
    }

    fn cfg(k: usize, seed: u64) -> BlockTaskCfg {
        BlockTaskCfg { k, tau: 10.0, burnin: 6, samples: 10, workers: 1, ridge: 1e-3, seed }
    }

    #[test]
    fn block_posterior_predicts_block() {
        let (data, _, _) = block_from_factors(30, 25, 4, 60, 0.5);
        let backend = BlockBackend::Native;
        let (post, stats) = run_block(&backend, &data, &cfg(4, 61), None, None, None).unwrap();
        assert_eq!(post.u.n, 30);
        assert_eq!(post.v.n, 25);
        assert_eq!(stats.sweeps, 16);
        // posterior means should reconstruct the block's ratings decently
        let mut sse = 0.0;
        let mut var = 0.0;
        let mean_rating = data.coo.mean();
        for e in &data.coo.entries {
            let (r, c) = (e.row as usize, e.col as usize);
            let pred: f64 = (0..4)
                .map(|j| post.u.row_mean(r)[j] * post.v.row_mean(c)[j])
                .sum();
            sse += (pred - e.val as f64).powi(2);
            var += (e.val as f64 - mean_rating).powi(2);
        }
        assert!(sse < 0.5 * var, "fit explains < 50% of variance: {sse} vs {var}");
    }

    #[test]
    fn propagated_prior_is_respected() {
        // empty block → posterior ≈ prior (no data to move it)
        let data = BlockData::new(Coo::new(8, 6));
        let k = 3;
        let mut prior_u = RowGaussians::standard(8, k, 50.0); // tight prior
        for i in 0..8 {
            prior_u.mean[i * k] = 2.0;
        }
        let backend = BlockBackend::Native;
        let c = BlockTaskCfg {
            k,
            tau: 1.0,
            burnin: 4,
            samples: 30,
            workers: 1,
            ridge: 1e-4,
            seed: 3,
        };
        let (post, _) = run_block(&backend, &data, &c, Some(&prior_u), None, None).unwrap();
        for i in 0..8 {
            assert!(
                (post.u.row_mean(i)[0] - 2.0).abs() < 0.25,
                "row {i} mean {} drifted from tight prior",
                post.u.row_mean(i)[0]
            );
        }
    }

    #[test]
    fn worker_count_does_not_change_posterior_means_much() {
        let (data, _, _) = block_from_factors(24, 20, 4, 62, 0.4);
        let backend = BlockBackend::Native;
        let (p1, _) = run_block(&backend, &data, &cfg(4, 63), None, None, None).unwrap();
        let mut c2 = cfg(4, 63);
        c2.workers = 3;
        let (p3, _) = run_block(&backend, &data, &c2, None, None, None).unwrap();
        // identical seeds + sharding-invariant math → identical chains
        for i in 0..24 {
            for j in 0..4 {
                assert!((p1.u.row_mean(i)[j] - p3.u.row_mean(i)[j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn posterior_precisions_are_spd() {
        let (data, _, _) = block_from_factors(12, 10, 3, 64, 0.6);
        let backend = BlockBackend::Native;
        let (post, _) = run_block(&backend, &data, &cfg(3, 65), None, None, None).unwrap();
        for i in 0..post.u.n {
            let p: Mat = post.u.row_prec(i);
            assert!(crate::linalg::Cholesky::new(&p).is_ok(), "row {i} precision not SPD");
        }
    }

    #[test]
    fn sweep_observer_sees_every_retained_sweep_without_changing_the_chain() {
        let (data, _, _) = block_from_factors(20, 16, 4, 66, 0.5);
        let backend = BlockBackend::Native;
        let seen = std::cell::RefCell::new(Vec::<(usize, f64)>::new());
        let obs = |sweep: usize, rmse: f64| seen.borrow_mut().push((sweep, rmse));
        let c = cfg(4, 67);
        let (observed, _) = run_block(&backend, &data, &c, None, None, Some(&obs)).unwrap();
        let (silent, _) = run_block(&backend, &data, &c, None, None, None).unwrap();
        let seen = seen.into_inner();
        assert_eq!(seen.len(), c.samples, "one sample per retained sweep");
        assert!(seen.iter().all(|&(s, r)| s >= c.burnin && r.is_finite() && r >= 0.0));
        // observing must not perturb the RNG stream
        assert_eq!(observed.u.mean, silent.u.mean);
        assert_eq!(observed.v.prec, silent.v.prec);
    }
}
