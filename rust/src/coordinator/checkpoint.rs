//! Model checkpointing: persist a trained `TrainResult` (posterior means +
//! precisions) to a JSON file and restore it — restartable pipelines and
//! offline serving of the factorization.

use super::trainer::{PhaseTimings, RunStats, TrainResult};
use crate::posterior::RowGaussians;
use crate::util::json::{self, Json};
use std::path::Path;

fn vec_to_json(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn json_to_vec(j: &Json) -> Option<Vec<f64>> {
    Some(j.as_arr()?.iter().filter_map(Json::as_f64).collect())
}

fn gaussians_to_json(g: &RowGaussians) -> Json {
    Json::obj(vec![
        ("n", g.n.into()),
        ("k", g.k.into()),
        ("mean", vec_to_json(&g.mean)),
        ("prec", vec_to_json(&g.prec)),
    ])
}

fn gaussians_from_json(j: &Json) -> Option<RowGaussians> {
    let n = j.get("n")?.as_usize()?;
    let k = j.get("k")?.as_usize()?;
    let mean = json_to_vec(j.get("mean")?)?;
    let prec = json_to_vec(j.get("prec")?)?;
    if mean.len() != n * k || prec.len() != n * k * k {
        return None;
    }
    Some(RowGaussians { n, k, mean, prec })
}

/// Save a trained model.
pub fn save(result: &TrainResult, path: &Path) -> std::io::Result<()> {
    let root = Json::obj(vec![
        ("version", 1usize.into()),
        ("k", result.k.into()),
        ("grid_i", result.grid.0.into()),
        ("grid_j", result.grid.1.into()),
        ("global_mean", result.global_mean.into()),
        ("u_post", gaussians_to_json(&result.u_post)),
        ("v_post", gaussians_to_json(&result.v_post)),
    ]);
    std::fs::write(path, json::to_string(&root))
}

#[derive(Debug, thiserror::Error)]
pub enum CheckpointError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("malformed checkpoint: {0}")]
    Malformed(String),
}

/// Load a trained model (timings/stats are zeroed — they describe a run,
/// not a model).
pub fn load(path: &Path) -> Result<TrainResult, CheckpointError> {
    let text = std::fs::read_to_string(path)?;
    let root =
        json::parse(&text).map_err(|e| CheckpointError::Malformed(e.to_string()))?;
    let bad = |m: &str| CheckpointError::Malformed(m.to_string());
    let k = root.get("k").and_then(Json::as_usize).ok_or_else(|| bad("k"))?;
    let gi = root.get("grid_i").and_then(Json::as_usize).ok_or_else(|| bad("grid_i"))?;
    let gj = root.get("grid_j").and_then(Json::as_usize).ok_or_else(|| bad("grid_j"))?;
    let global_mean =
        root.get("global_mean").and_then(Json::as_f64).ok_or_else(|| bad("global_mean"))?;
    let u_post = root
        .get("u_post")
        .and_then(gaussians_from_json)
        .ok_or_else(|| bad("u_post"))?;
    let v_post = root
        .get("v_post")
        .and_then(gaussians_from_json)
        .ok_or_else(|| bad("v_post"))?;
    if u_post.k != k || v_post.k != k {
        return Err(bad("latent dim mismatch"));
    }
    let u_mean: Vec<f32> = u_post.mean.iter().map(|&x| x as f32).collect();
    let v_mean: Vec<f32> = v_post.mean.iter().map(|&x| x as f32).collect();
    Ok(TrainResult {
        k,
        grid: (gi, gj),
        u_post,
        v_post,
        u_mean,
        v_mean,
        global_mean,
        timings: PhaseTimings::default(),
        stats: RunStats::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendSpec, PpTrainer, TrainConfig};
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;

    #[test]
    fn roundtrip_preserves_predictions() {
        let d = SyntheticDataset::by_name("movielens", 0.001, 44).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 45);
        let cfg = TrainConfig::new(d.k)
            .with_sweeps(4, 8)
            .with_backend(BackendSpec::Native)
            .with_seed(46);
        let result = PpTrainer::new(cfg).train(&train).unwrap();
        let path = std::env::temp_dir().join(format!("bmfpp_ckpt_{}.json", std::process::id()));
        save(&result, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.k, result.k);
        assert!((loaded.rmse(&test) - result.rmse(&test)).abs() < 1e-6);
        // uncertainty survives too
        let v1 = result.predict_variance(0, 0);
        let v2 = loaded.predict_variance(0, 0);
        assert!((v1 - v2).abs() < 1e-9 * v1.abs().max(1.0));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_malformed_files() {
        let path = std::env::temp_dir().join(format!("bmfpp_bad_{}.json", std::process::id()));
        std::fs::write(&path, "{\"version\": 1}").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "not json").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_file_is_io_error() {
        assert!(matches!(
            load(Path::new("/definitely/missing.json")),
            Err(CheckpointError::Io(_))
        ));
    }
}
