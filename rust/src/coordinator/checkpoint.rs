//! Model checkpointing: persist a trained [`PosteriorModel`] (posterior
//! means + precisions + global mean) to a JSON file and restore it —
//! restartable pipelines and offline serving of the factorization.
//!
//! The file stores exactly the servable artifact: run diagnostics
//! (timings, scheduling stats) describe a run, not a model, and never
//! enter the checkpoint.
//!
//! **Version gate:** the model writer emits format v2 (v1's unused grid
//! fields dropped). The model loader accepts v1 and v2; anything outside
//! that range — a pre-versioning v0 file, or a file written by a future
//! format — is rejected with a [`CheckpointError::Malformed`] naming the
//! version found and the supported range, instead of decoding it with
//! wrong assumptions.
//!
//! **Partial checkpoints (v3):** a cancelled, failed, or periodically
//! checkpointing training run persists the posteriors of every *completed
//! block* as a format-v3 file ([`save_partial`] / [`load_partial`],
//! [`PARTIAL_VERSION`]) so the job can later resume via
//! `TrainConfig::resume_from` without re-sampling those blocks. v3 files
//! are not models: feeding one to [`load`] fails with an error naming the
//! found and supported versions plus a pointer at the resume path, and
//! feeding a v1/v2 model to [`load_partial`] fails symmetrically.
//!
//! **Generations:** periodic checkpointing writes a *sequence* of v3
//! files into one directory — `partial-gen-00000001.json`,
//! `partial-gen-00000002.json`, … — each carrying a monotonically
//! increasing [`PartialCheckpoint::generation`] counter. Every write is
//! atomic (write to a temp file in the same directory, then rename), so a
//! crash — even `SIGKILL` mid-write — can never leave a half-written file
//! under a generation name; at worst a stale `*.tmp` is left behind,
//! which discovery ignores. [`latest_valid_partial`] walks the
//! generations newest-first and returns the first one that loads, so a
//! corrupted newest file degrades to the previous generation instead of
//! failing the resume. [`prune_generations`] implements keep-last-K
//! retention.

use super::aggregate::aggregate_part;
use super::block_task::BlockPosteriors;
use crate::posterior::{PosteriorModel, RowGaussians};
use crate::util::json::{self, Json};
use std::path::Path;

fn vec_to_json(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

/// Every element must be numeric: a malformed array is a malformed
/// checkpoint, not a shorter vector (a silent `filter_map` here could drop
/// elements and still pass a length check downstream).
fn json_to_vec(j: &Json) -> Option<Vec<f64>> {
    j.as_arr()?.iter().map(Json::as_f64).collect()
}

fn gaussians_to_json(g: &RowGaussians) -> Json {
    Json::obj(vec![
        ("n", g.n.into()),
        ("k", g.k.into()),
        ("mean", vec_to_json(&g.mean)),
        ("prec", vec_to_json(&g.prec)),
    ])
}

fn gaussians_from_json(j: &Json) -> Option<RowGaussians> {
    let n = j.get("n")?.as_usize()?;
    let k = j.get("k")?.as_usize()?;
    let mean = json_to_vec(j.get("mean")?)?;
    let prec = json_to_vec(j.get("prec")?)?;
    if mean.len() != n * k || prec.len() != n * k * k {
        return None;
    }
    Some(RowGaussians { n, k, mean, prec })
}

/// Save a trained model.
pub fn save(model: &PosteriorModel, path: &Path) -> std::io::Result<()> {
    let root = Json::obj(vec![
        ("version", 2usize.into()),
        ("k", model.k.into()),
        ("global_mean", model.global_mean.into()),
        ("u_post", gaussians_to_json(&model.u_post)),
        ("v_post", gaussians_to_json(&model.v_post)),
    ]);
    std::fs::write(path, json::to_string(&root))
}

/// Why a checkpoint failed to load.
#[derive(Debug, thiserror::Error)]
pub enum CheckpointError {
    /// The file could not be read.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    /// The file parsed but is not a valid checkpoint (bad JSON, missing
    /// fields, shape mismatch, or an unsupported format version).
    #[error("malformed checkpoint: {0}")]
    Malformed(String),
}

/// Oldest and newest checkpoint format versions [`load`] accepts.
pub const SUPPORTED_VERSIONS: (usize, usize) = (1, 2);

/// Load a trained model (accepts format v1 and v2; v1's grid fields are
/// run metadata and are ignored). Versions outside
/// [`SUPPORTED_VERSIONS`] fail with an error naming the found and
/// expected versions.
pub fn load(path: &Path) -> Result<PosteriorModel, CheckpointError> {
    let text = std::fs::read_to_string(path)?;
    let root =
        json::parse(&text).map_err(|e| CheckpointError::Malformed(e.to_string()))?;
    let bad = |m: &str| CheckpointError::Malformed(m.to_string());
    let version = root.get("version").and_then(Json::as_usize).ok_or_else(|| bad("version"))?;
    let (oldest, newest) = SUPPORTED_VERSIONS;
    if version < oldest || version > newest {
        // a real v3 file is a partial *training* checkpoint, not a model —
        // say so instead of only rejecting the number
        let hint = if version == PARTIAL_VERSION && root.get("blocks").is_some() {
            " (version 3 files are partial training checkpoints — \
             resume them with `train --resume`)"
        } else {
            ""
        };
        return Err(bad(&format!(
            "unsupported checkpoint format: found version {version}, \
             this build reads versions {oldest} through {newest}{hint}"
        )));
    }
    let k = root.get("k").and_then(Json::as_usize).ok_or_else(|| bad("k"))?;
    let global_mean =
        root.get("global_mean").and_then(Json::as_f64).ok_or_else(|| bad("global_mean"))?;
    let u_post = root
        .get("u_post")
        .and_then(gaussians_from_json)
        .ok_or_else(|| bad("u_post"))?;
    let v_post = root
        .get("v_post")
        .and_then(gaussians_from_json)
        .ok_or_else(|| bad("v_post"))?;
    if u_post.k != k || v_post.k != k {
        return Err(bad("latent dim mismatch"));
    }
    Ok(PosteriorModel::new(u_post, v_post, global_mean))
}

/// Format version of partial (resume) checkpoints written on cancel.
pub const PARTIAL_VERSION: usize = 3;

/// One completed block recorded in a partial checkpoint.
#[derive(Debug, Clone)]
pub struct PartialBlock {
    /// Row-block index in the PP grid.
    pub i: usize,
    /// Column-block index in the PP grid.
    pub j: usize,
    /// The block's sampled posterior marginals.
    pub post: BlockPosteriors,
}

/// An interrupted run's resumable state: the identity of the run (latent
/// dim, grid, seed, centring mean — resume refuses a mismatch) plus the
/// posterior marginals of every block that completed before the abort or
/// periodic snapshot.
#[derive(Debug, Clone)]
pub struct PartialCheckpoint {
    /// Latent dimension the run used.
    pub k: usize,
    /// Base RNG seed the run used (per-block seeds derive from it, so a
    /// resume with a different seed would silently change the math).
    pub seed: u64,
    /// Block grid (I row-blocks × J column-blocks) of the run.
    pub grid: (usize, usize),
    /// Global mean the training matrix was centred by — doubles as a
    /// fingerprint that the resume is fed the same data.
    pub global_mean: f64,
    /// Monotonic snapshot counter for periodic checkpointing: each write
    /// into a checkpoint directory bumps it, and a resumed run continues
    /// numbering past the generation it restored from. 0 for one-shot
    /// (cancel-path) files that never entered a generation sequence.
    pub generation: u64,
    /// Revision of the shard store the run trained against
    /// ([`Manifest::revision`](crate::store::Manifest)) — 0 for resident
    /// runs and for stores that were never appended to. An incremental
    /// update compares this against the live store's revision to detect
    /// (and warn, non-fatally) when the store has been appended to since
    /// this checkpoint was written.
    pub store_revision: u64,
    /// Completed blocks, in the order they are restored.
    pub blocks: Vec<PartialBlock>,
}

impl PartialCheckpoint {
    /// True when every block of the grid is present — the checkpoint
    /// captures a run whose sampling finished, so a full model can be
    /// rebuilt from it via [`model_from_partial`]. Generations written
    /// mid-run (or by an abort) are incomplete and return `false`.
    pub fn is_complete(&self) -> bool {
        let (gi, gj) = self.grid;
        if gi == 0 || gj == 0 {
            return false;
        }
        let mut seen = vec![false; gi * gj];
        for b in &self.blocks {
            if b.i < gi && b.j < gj {
                seen[b.i * gj + b.j] = true;
            }
        }
        seen.iter().all(|&s| s)
    }
}

/// Rebuild a servable [`PosteriorModel`] from a *complete* partial
/// checkpoint by replaying the trainer's canonical aggregation: each
/// U part takes its row's phase-(a)/(b) posterior as the prior refined by
/// that row's later blocks, each V part symmetrically per column, parts
/// concatenated in grid order. Given the same `ridge` the trainer used
/// (`TrainConfig::ridge`, default `1e-3`), the result is bitwise
/// identical to the model the completed run itself would have returned —
/// which is what lets a serving process hand off from a checkpoint
/// directory without ever touching the Engine.
///
/// Fails with [`CheckpointError::Malformed`] when any grid block is
/// missing (check [`PartialCheckpoint::is_complete`] first to skip
/// mid-run generations without treating them as errors).
pub fn model_from_partial(
    ckpt: &PartialCheckpoint,
    ridge: f64,
) -> Result<PosteriorModel, CheckpointError> {
    let (gi, gj) = ckpt.grid;
    if gi == 0 || gj == 0 {
        return Err(CheckpointError::Malformed(format!(
            "cannot build a model from a degenerate {gi}x{gj} grid"
        )));
    }
    // index by coordinate: sink files hold blocks in completion order
    let mut grid: Vec<Option<&BlockPosteriors>> = vec![None; gi * gj];
    for b in &ckpt.blocks {
        if b.i < gi && b.j < gj {
            grid[b.i * gj + b.j] = Some(&b.post);
        }
    }
    if let Some(pos) = grid.iter().position(|b| b.is_none()) {
        let (i, j) = (pos / gj, pos % gj);
        return Err(CheckpointError::Malformed(format!(
            "cannot build a model from an incomplete partial checkpoint \
             (generation {}): block ({i},{j}) of the {gi}x{gj} grid is missing",
            ckpt.generation
        )));
    }
    let at = |i: usize, j: usize| grid[i * gj + j].expect("completeness checked above");

    // U^(0): block (0,0)'s row posterior refined by the phase-(b) column
    // blocks; U^(i): block (i,0) refined by row i's interior blocks
    let posts: Vec<&RowGaussians> = (1..gj).map(|j| &at(0, j).u).collect();
    let mut u_post = aggregate_part(&at(0, 0).u, &posts, ridge);
    for i in 1..gi {
        let posts: Vec<&RowGaussians> = (1..gj).map(|j| &at(i, j).u).collect();
        u_post = u_post.concat(&aggregate_part(&at(i, 0).u, &posts, ridge));
    }
    // V^(0): block (0,0)'s column posterior refined by the phase-(b) row
    // blocks; V^(j): block (0,j) refined by column j's interior blocks
    let posts: Vec<&RowGaussians> = (1..gi).map(|i| &at(i, 0).v).collect();
    let mut v_post = aggregate_part(&at(0, 0).v, &posts, ridge);
    for j in 1..gj {
        let posts: Vec<&RowGaussians> = (1..gi).map(|i| &at(i, j).v).collect();
        v_post = v_post.concat(&aggregate_part(&at(0, j).v, &posts, ridge));
    }
    Ok(PosteriorModel::new(u_post, v_post, ckpt.global_mean))
}

/// Save an interrupted run's partial state as a format-v3 file.
///
/// The write is atomic: the JSON is written to a `*.tmp` sibling in the
/// same directory and renamed into place, so a reader (or a resume after
/// a crash mid-write) can never observe a half-written file under `path`.
pub fn save_partial(ckpt: &PartialCheckpoint, path: &Path) -> std::io::Result<()> {
    let blocks = Json::Arr(
        ckpt.blocks
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("i", b.i.into()),
                    ("j", b.j.into()),
                    ("u", gaussians_to_json(&b.post.u)),
                    ("v", gaussians_to_json(&b.post.v)),
                ])
            })
            .collect(),
    );
    let root = Json::obj(vec![
        ("version", PARTIAL_VERSION.into()),
        ("k", ckpt.k.into()),
        // JSON numbers are f64; a u64 seed round-trips through a string
        ("seed", Json::Str(ckpt.seed.to_string())),
        ("grid_i", ckpt.grid.0.into()),
        ("grid_j", ckpt.grid.1.into()),
        ("global_mean", ckpt.global_mean.into()),
        ("generation", Json::Str(ckpt.generation.to_string())),
        ("store_revision", Json::Str(ckpt.store_revision.to_string())),
        ("blocks", blocks),
    ]);
    // same-directory temp file so the rename is atomic (one filesystem);
    // pid + per-process counter keeps concurrent writers (two sessions,
    // or two processes) off each other's temp files
    use std::sync::atomic::{AtomicU64, Ordering};
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        WRITE_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, json::to_string(&root))?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            std::fs::remove_file(&tmp).ok();
            Err(e)
        }
    }
}

/// Load a partial (resume) checkpoint. Only format v3 is accepted; any
/// other version — including valid v1/v2 *model* checkpoints — fails with
/// an error naming the version found and the supported one.
pub fn load_partial(path: &Path) -> Result<PartialCheckpoint, CheckpointError> {
    let text = std::fs::read_to_string(path)?;
    let root =
        json::parse(&text).map_err(|e| CheckpointError::Malformed(e.to_string()))?;
    let bad = |m: &str| CheckpointError::Malformed(m.to_string());
    let version = root.get("version").and_then(Json::as_usize).ok_or_else(|| bad("version"))?;
    if version != PARTIAL_VERSION {
        return Err(bad(&format!(
            "unsupported partial checkpoint: found version {version}, partial \
             (resume) checkpoints are version {PARTIAL_VERSION} through \
             {PARTIAL_VERSION} — model checkpoints load via `predict --load`"
        )));
    }
    let k = root.get("k").and_then(Json::as_usize).ok_or_else(|| bad("k"))?;
    let seed = root
        .get("seed")
        .and_then(Json::as_str)
        .and_then(|s| s.parse::<u64>().ok())
        .ok_or_else(|| bad("seed"))?;
    let gi = root.get("grid_i").and_then(Json::as_usize).ok_or_else(|| bad("grid_i"))?;
    let gj = root.get("grid_j").and_then(Json::as_usize).ok_or_else(|| bad("grid_j"))?;
    let global_mean =
        root.get("global_mean").and_then(Json::as_f64).ok_or_else(|| bad("global_mean"))?;
    // absent in pre-generation v3 files (cancel-path writers before
    // periodic checkpointing existed): default 0, never an error
    let generation = match root.get("generation") {
        None => 0,
        Some(g) => g
            .as_str()
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| bad("generation"))?,
    };
    // absent in files written before stores carried revisions: those
    // runs saw revision 0 by definition
    let store_revision = match root.get("store_revision") {
        None => 0,
        Some(r) => r
            .as_str()
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| bad("store_revision"))?,
    };
    let mut blocks = Vec::new();
    for b in root.get("blocks").and_then(Json::as_arr).ok_or_else(|| bad("blocks"))? {
        let i = b.get("i").and_then(Json::as_usize).ok_or_else(|| bad("block i"))?;
        let j = b.get("j").and_then(Json::as_usize).ok_or_else(|| bad("block j"))?;
        if i >= gi || j >= gj {
            return Err(bad(&format!("block ({i},{j}) outside the {gi}x{gj} grid")));
        }
        let u = b.get("u").and_then(gaussians_from_json).ok_or_else(|| bad("block u"))?;
        let v = b.get("v").and_then(gaussians_from_json).ok_or_else(|| bad("block v"))?;
        if u.k != k || v.k != k {
            return Err(bad("latent dim mismatch in block posterior"));
        }
        blocks.push(PartialBlock { i, j, post: BlockPosteriors { u, v } });
    }
    Ok(PartialCheckpoint { k, seed, grid: (gi, gj), global_mean, generation, store_revision, blocks })
}

/// File-name prefix of generation files inside a checkpoint directory.
pub const GENERATION_PREFIX: &str = "partial-gen-";

/// Canonical path of generation `generation` inside checkpoint directory
/// `dir`: `dir/partial-gen-{generation:08}.json`.
pub fn generation_path(dir: &Path, generation: u64) -> std::path::PathBuf {
    dir.join(format!("{GENERATION_PREFIX}{generation:08}.json"))
}

/// Parse a generation number out of a file name following the
/// [`generation_path`] convention; `None` for anything else (models,
/// `*.tmp` leftovers from an interrupted atomic write, unrelated files).
fn parse_generation(name: &str) -> Option<u64> {
    let digits = name.strip_prefix(GENERATION_PREFIX)?.strip_suffix(".json")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Every generation file present in `dir`, sorted ascending by generation
/// number. Only file names matching the [`generation_path`] convention are
/// considered; nothing is opened or validated here.
pub fn list_generations(dir: &Path) -> std::io::Result<Vec<(u64, std::path::PathBuf)>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(generation) = parse_generation(name) {
            out.push((generation, entry.path()));
        }
    }
    out.sort_unstable_by_key(|(g, _)| *g);
    Ok(out)
}

/// Load the newest generation in `dir` that validates, walking the
/// sequence newest-first: a truncated or corrupted newest file (e.g. from
/// a disk-full write racing a kill) is skipped — never loaded — and the
/// previous generation is used instead. `Ok(None)` when the directory
/// holds no generation file at all; an error only when files exist but
/// none of them loads.
pub fn latest_valid_partial(
    dir: &Path,
) -> Result<Option<(PartialCheckpoint, std::path::PathBuf)>, CheckpointError> {
    let generations = list_generations(dir)?;
    if generations.is_empty() {
        return Ok(None);
    }
    let mut last_err = None;
    for (_, path) in generations.iter().rev() {
        match load_partial(path) {
            Ok(ckpt) => return Ok(Some((ckpt, path.clone()))),
            Err(e) => {
                log::warn!("skipping invalid checkpoint generation {}: {e}", path.display());
                last_err = Some(e);
            }
        }
    }
    Err(CheckpointError::Malformed(format!(
        "{} generation file(s) in {} and none is a loadable v3 partial checkpoint \
         (last error: {})",
        generations.len(),
        dir.display(),
        last_err.expect("non-empty list produced at least one error")
    )))
}

/// Keep-last-K retention: delete all but the newest `keep` generation
/// files in `dir` (`keep == 0` keeps everything). Returns how many files
/// were removed; per-file deletion errors are logged, not fatal — a
/// retention hiccup must never fail the training run that triggered it.
pub fn prune_generations(dir: &Path, keep: usize) -> std::io::Result<usize> {
    if keep == 0 {
        return Ok(0);
    }
    let generations = list_generations(dir)?;
    let mut removed = 0;
    if generations.len() > keep {
        for (_, path) in &generations[..generations.len() - keep] {
            match std::fs::remove_file(path) {
                Ok(()) => removed += 1,
                Err(e) => log::warn!("retention could not remove {}: {e}", path.display()),
            }
        }
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendSpec, Engine, TrainConfig};
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("bmfpp_{tag}_{}.json", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_predictions() {
        let d = SyntheticDataset::by_name("movielens", 0.001, 44).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 45);
        let cfg = TrainConfig::new(d.k)
            .with_sweeps(4, 8)
            .with_backend(BackendSpec::Native)
            .with_seed(46);
        let result =
            Engine::new(&BackendSpec::Native, cfg.block_parallelism).train(&cfg, &train).unwrap();
        let path = tmp("ckpt");
        save(&result, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.k, result.k);
        assert!((loaded.rmse(&test) - result.rmse(&test)).abs() < 1e-6);
        // uncertainty survives too
        let v1 = result.predict_variance(0, 0);
        let v2 = loaded.predict_variance(0, 0);
        assert!((v1 - v2).abs() < 1e-9 * v1.abs().max(1.0));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn roundtrip_is_bitwise_across_k_and_grid() {
        // save → load must reproduce predict / predict_variance to the
        // last bit for every (k, grid) shape, since the JSON writer emits
        // shortest-round-trip f64
        let d = SyntheticDataset::by_name("movielens", 0.001, 47).unwrap();
        let (train, _) = holdout_split_covered(&d.ratings, 0.2, 48);
        let engine = Engine::new(&BackendSpec::Native, 4);
        for (k, grid) in [(4usize, (1usize, 1usize)), (8, (2, 2)), (6, (3, 2))] {
            let cfg = TrainConfig::new(k)
                .with_grid(grid.0, grid.1)
                .with_sweeps(3, 6)
                .with_backend(BackendSpec::Native)
                .with_seed(49);
            let result = engine.train(&cfg, &train).unwrap();
            let path = tmp(&format!("bitwise_{k}_{}x{}", grid.0, grid.1));
            save(&result, &path).unwrap();
            let loaded = load(&path).unwrap();
            assert_eq!(loaded.u_mean, result.u_mean, "k={k} grid={grid:?}");
            assert_eq!(loaded.v_mean, result.v_mean, "k={k} grid={grid:?}");
            for (r, c) in [(0usize, 0usize), (1, 2), (train.rows - 1, train.cols - 1)] {
                assert_eq!(
                    loaded.predict(r, c).to_bits(),
                    result.predict(r, c).to_bits(),
                    "predict({r},{c}) k={k} grid={grid:?}"
                );
                assert_eq!(
                    loaded.predict_variance(r, c).to_bits(),
                    result.predict_variance(r, c).to_bits(),
                    "predict_variance({r},{c}) k={k} grid={grid:?}"
                );
            }
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn rejects_malformed_files() {
        let path = tmp("bad");
        std::fs::write(&path, "{\"version\": 1}").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "not json").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_future_format_versions() {
        // a v3 writer may have changed field semantics — refuse rather
        // than decode with v2 assumptions
        let path = tmp("v3");
        std::fs::write(
            &path,
            r#"{"version":3,"k":1,"global_mean":0.0,
                "u_post":{"n":1,"k":1,"mean":[0.5],"prec":[4.0]},
                "v_post":{"n":1,"k":1,"mean":[2.0],"prec":[4.0]}}"#,
        )
        .unwrap();
        let err = load(&path).unwrap_err();
        assert!(matches!(err, CheckpointError::Malformed(_)));
        // the message must name the found version and the supported range
        let msg = err.to_string();
        assert!(msg.contains("version 3"), "{msg}");
        assert!(msg.contains("1 through 2"), "{msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_version_zero_files() {
        // pre-versioning v0 checkpoints are older than the supported range
        let path = tmp("v0");
        std::fs::write(
            &path,
            r#"{"version":0,"k":1,"global_mean":0.0,
                "u_post":{"n":1,"k":1,"mean":[0.5],"prec":[4.0]},
                "v_post":{"n":1,"k":1,"mean":[2.0],"prec":[4.0]}}"#,
        )
        .unwrap();
        let err = load(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("version 0"), "{msg}");
        assert!(msg.contains("1 through 2"), "{msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_non_numeric_array_elements() {
        // n=1, k=1 with a 2-element mean array whose numeric prefix has
        // length 1: the old filter_map decode silently accepted this file;
        // a malformed element must be a Malformed error instead
        let path = tmp("nonnum");
        std::fs::write(
            &path,
            r#"{"version":2,"k":1,"global_mean":0.5,
                "u_post":{"n":1,"k":1,"mean":[1.5,"oops"],"prec":[2.0]},
                "v_post":{"n":1,"k":1,"mean":[0.25],"prec":[2.0]}}"#,
        )
        .unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::Malformed(_))));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn loads_version_1_files_without_grid_semantics() {
        // a v1-style file (extra grid fields) still loads into a model
        let path = tmp("v1");
        std::fs::write(
            &path,
            r#"{"version":1,"k":1,"grid_i":2,"grid_j":3,"global_mean":1.0,
                "u_post":{"n":2,"k":1,"mean":[0.5,-0.5],"prec":[4.0,4.0]},
                "v_post":{"n":1,"k":1,"mean":[2.0],"prec":[4.0]}}"#,
        )
        .unwrap();
        let m = load(&path).unwrap();
        assert_eq!((m.rows(), m.cols(), m.k), (2, 1, 1));
        assert!((m.predict(0, 0) - 2.0).abs() < 1e-12);
        std::fs::remove_file(path).ok();
    }

    fn tiny_partial() -> PartialCheckpoint {
        let g = |vals: &[f64]| RowGaussians {
            n: vals.len(),
            k: 1,
            mean: vals.to_vec(),
            prec: vals.iter().map(|_| 4.0).collect(),
        };
        PartialCheckpoint {
            k: 1,
            seed: u64::MAX - 7, // exercises the string round-trip, breaks an f64 one
            grid: (2, 2),
            global_mean: 3.25,
            generation: u64::MAX - 11, // string round-trip, like the seed
            store_revision: u64::MAX - 13, // string round-trip, like the seed
            blocks: vec![PartialBlock {
                i: 1,
                j: 0,
                post: BlockPosteriors { u: g(&[0.5, -0.5]), v: g(&[2.0]) },
            }],
        }
    }

    #[test]
    fn partial_checkpoint_roundtrips() {
        let path = tmp("partial");
        let ckpt = tiny_partial();
        save_partial(&ckpt, &path).unwrap();
        let back = load_partial(&path).unwrap();
        assert_eq!(back.k, ckpt.k);
        assert_eq!(back.seed, ckpt.seed, "u64 seed must survive JSON exactly");
        assert_eq!(back.generation, ckpt.generation, "generation must survive JSON exactly");
        assert_eq!(
            back.store_revision, ckpt.store_revision,
            "store revision must survive JSON exactly"
        );
        assert_eq!(back.grid, ckpt.grid);
        assert_eq!(back.global_mean.to_bits(), ckpt.global_mean.to_bits());
        assert_eq!(back.blocks.len(), 1);
        assert_eq!((back.blocks[0].i, back.blocks[0].j), (1, 0));
        assert_eq!(back.blocks[0].post.u.mean, ckpt.blocks[0].post.u.mean);
        assert_eq!(back.blocks[0].post.v.prec, ckpt.blocks[0].post.v.prec);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn model_loader_points_v3_partials_at_resume() {
        // a genuine v3 partial fed to the model loader must name found vs
        // supported versions AND say what the file actually is
        let path = tmp("partial_as_model");
        save_partial(&tiny_partial(), &path).unwrap();
        let err = load(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("version 3"), "{msg}");
        assert!(msg.contains("1 through 2"), "{msg}");
        assert!(msg.contains("--resume"), "{msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn partial_loader_rejects_model_files_naming_versions() {
        // symmetric gate: a v2 model fed to the partial loader names the
        // found version and the supported (v3) one
        let path = tmp("model_as_partial");
        std::fs::write(
            &path,
            r#"{"version":2,"k":1,"global_mean":0.0,
                "u_post":{"n":1,"k":1,"mean":[0.5],"prec":[4.0]},
                "v_post":{"n":1,"k":1,"mean":[2.0],"prec":[4.0]}}"#,
        )
        .unwrap();
        let err = load_partial(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("found version 2"), "{msg}");
        assert!(msg.contains("version 3 through 3"), "{msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn partial_loader_rejects_out_of_grid_blocks() {
        let path = tmp("partial_oob");
        let mut ckpt = tiny_partial();
        ckpt.blocks[0].i = 5; // outside the 2x2 grid
        save_partial(&ckpt, &path).unwrap();
        assert!(matches!(load_partial(&path), Err(CheckpointError::Malformed(_))));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_file_is_io_error() {
        assert!(matches!(
            load(Path::new("/definitely/missing.json")),
            Err(CheckpointError::Io(_))
        ));
    }

    #[test]
    fn legacy_v3_without_generation_loads_as_generation_zero() {
        // files written by the pre-periodic cancel path have no
        // generation field — they must keep loading, as generation 0
        let path = tmp("nogen");
        std::fs::write(
            &path,
            r#"{"version":3,"k":1,"seed":"9","grid_i":1,"grid_j":1,
                "global_mean":0.5,"blocks":[]}"#,
        )
        .unwrap();
        let back = load_partial(&path).unwrap();
        assert_eq!(back.generation, 0);
        assert_eq!(back.store_revision, 0, "pre-revision files load as revision 0");
        assert_eq!(back.seed, 9);
        std::fs::remove_file(path).ok();
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("bmfpp_gen_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn save_partial_is_atomic_and_leaves_no_tmp_file() {
        let dir = tmp_dir("atomic");
        let path = generation_path(&dir, 1);
        save_partial(&tiny_partial(), &path).unwrap();
        assert!(path.exists());
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn generation_listing_is_sorted_and_ignores_foreign_files() {
        let dir = tmp_dir("list");
        let mut ckpt = tiny_partial();
        for generation in [3u64, 1, 2] {
            ckpt.generation = generation;
            save_partial(&ckpt, &generation_path(&dir, generation)).unwrap();
        }
        // foreign files and interrupted-write leftovers must be invisible
        std::fs::write(dir.join("model.json"), "{}").unwrap();
        std::fs::write(dir.join("partial-gen-00000009.json.123.tmp"), "garbage").unwrap();
        std::fs::write(dir.join("partial-gen-x.json"), "garbage").unwrap();
        let gens: Vec<u64> = list_generations(&dir).unwrap().into_iter().map(|(g, _)| g).collect();
        assert_eq!(gens, vec![1, 2, 3]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn latest_valid_skips_truncated_newest_generation() {
        let dir = tmp_dir("truncated");
        let mut ckpt = tiny_partial();
        ckpt.generation = 1;
        save_partial(&ckpt, &generation_path(&dir, 1)).unwrap();
        ckpt.generation = 2;
        ckpt.blocks.push(ckpt.blocks[0].clone());
        save_partial(&ckpt, &generation_path(&dir, 2)).unwrap();
        // simulate a crash mid-write bypassing the atomic rename: a
        // half-written newest generation
        let full = std::fs::read_to_string(generation_path(&dir, 2)).unwrap();
        std::fs::write(generation_path(&dir, 3), &full[..full.len() / 2]).unwrap();

        // the truncated file itself is rejected with a Malformed error
        assert!(matches!(
            load_partial(&generation_path(&dir, 3)),
            Err(CheckpointError::Malformed(_))
        ));
        // and discovery falls back to the newest generation that loads
        let (back, path) = latest_valid_partial(&dir).unwrap().expect("valid generation");
        assert_eq!(back.generation, 2);
        assert_eq!(back.blocks.len(), 2);
        assert_eq!(path, generation_path(&dir, 2));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn latest_valid_empty_dir_is_none_and_all_corrupt_is_error() {
        let dir = tmp_dir("none");
        assert!(latest_valid_partial(&dir).unwrap().is_none());
        std::fs::write(generation_path(&dir, 1), "not json").unwrap();
        let err = latest_valid_partial(&dir).unwrap_err();
        assert!(matches!(err, CheckpointError::Malformed(_)), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn is_complete_requires_every_grid_block() {
        let mut ckpt = tiny_partial(); // 2x2 grid, one block
        assert!(!ckpt.is_complete());
        let proto = ckpt.blocks[0].clone();
        for (i, j) in [(0usize, 0usize), (0, 1), (1, 1)] {
            let mut b = proto.clone();
            (b.i, b.j) = (i, j);
            ckpt.blocks.push(b);
        }
        assert!(ckpt.is_complete());
        // a degenerate grid is never complete
        ckpt.grid = (0, 2);
        assert!(!ckpt.is_complete());
    }

    #[test]
    fn model_from_partial_rejects_incomplete_checkpoints() {
        let ckpt = tiny_partial(); // block (1,0) only
        assert!(!ckpt.is_complete());
        let err = model_from_partial(&ckpt, 1e-3).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("incomplete"), "{msg}");
        assert!(msg.contains("(0,0)"), "{msg}");
    }

    #[test]
    fn model_from_partial_matches_live_run_bitwise() {
        // train with checkpoint_every=1 so the newest generation holds
        // every block, then rebuild a model from it: the reconstruction
        // replays the canonical aggregation order, so predictions must
        // match the live run's model to the last bit
        let dir = tmp_dir("rebuild");
        let d = SyntheticDataset::by_name("movielens", 0.001, 50).unwrap();
        let (train, _) = holdout_split_covered(&d.ratings, 0.2, 51);
        let cfg = TrainConfig::new(6)
            .with_grid(2, 2)
            .with_sweeps(3, 6)
            .with_backend(BackendSpec::Native)
            .with_seed(52)
            .with_checkpoint_every(1)
            .with_checkpoint_dir(&dir)
            .with_checkpoint_keep(1);
        let ridge = cfg.ridge;
        let result =
            Engine::new(&BackendSpec::Native, cfg.block_parallelism).train(&cfg, &train).unwrap();
        let (ckpt, _) = latest_valid_partial(&dir).unwrap().expect("final generation");
        assert!(ckpt.is_complete(), "checkpoint_every=1 must leave a full final generation");
        let rebuilt = model_from_partial(&ckpt, ridge).unwrap();
        assert_eq!(rebuilt.u_mean, result.u_mean);
        assert_eq!(rebuilt.v_mean, result.v_mean);
        assert_eq!(rebuilt.global_mean.to_bits(), result.global_mean.to_bits());
        for (r, c) in [(0usize, 0usize), (1, 2), (train.rows - 1, train.cols - 1)] {
            assert_eq!(rebuilt.predict(r, c).to_bits(), result.predict(r, c).to_bits());
            assert_eq!(
                rebuilt.predict_variance(r, c).to_bits(),
                result.predict_variance(r, c).to_bits()
            );
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn prune_keeps_last_k_generations() {
        let dir = tmp_dir("prune");
        let mut ckpt = tiny_partial();
        for generation in 1..=5u64 {
            ckpt.generation = generation;
            save_partial(&ckpt, &generation_path(&dir, generation)).unwrap();
        }
        assert_eq!(prune_generations(&dir, 2).unwrap(), 3);
        let gens: Vec<u64> = list_generations(&dir).unwrap().into_iter().map(|(g, _)| g).collect();
        assert_eq!(gens, vec![4, 5], "the newest K generations survive");
        // keep = 0 disables retention, pruning below the population is a no-op
        assert_eq!(prune_generations(&dir, 0).unwrap(), 0);
        assert_eq!(prune_generations(&dir, 5).unwrap(), 0);
        std::fs::remove_dir_all(dir).ok();
    }
}
