//! The training engine: a warm, shareable compute context for many
//! concurrent runs.
//!
//! [`Engine`] owns the persistent [`WorkerPool`] (and with it, under the
//! `pjrt` feature, each worker thread's PJRT client and compiled-artifact
//! cache) so that many training jobs — repeated benches, learning curves,
//! cross-validation folds, back-to-back CLI runs — execute on the same hot
//! threads instead of re-spawning and re-compiling per call.
//!
//! **Multi-tenancy.** [`Engine::submit`] is non-blocking: it validates the
//! config, registers a pool job, and returns immediately with a
//! [`Session`] carrying a stable [`JobId`]. Any number of sessions run
//! concurrently; their ready block/aggregation tasks meet in the pool's
//! one shared ready-queue, ordered by [`Priority`] then FIFO, with
//! per-job in-flight caps (`TrainConfig::max_in_flight`) so a wide
//! low-priority job cannot starve its neighbours. Scheduling never
//! changes the math: a session's posterior is bitwise-identical whether
//! it ran alone or interleaved with others.
//!
//! **Lifecycle.** A session is controlled through its handle:
//! [`Session::pause`] / [`Session::resume`] gate dispatch without losing
//! queue position, [`Session::cancel`] stops dispatching, drains in-flight
//! blocks, and (when `TrainConfig::checkpoint_on_cancel` is set) persists
//! every completed block posterior as a partial v3 checkpoint from which
//! `TrainConfig::resume_from` continues bitwise-identically.
//! [`Session::status`] / [`Session::progress`] observe the run live, and
//! [`Engine::jobs`] snapshots every session with a live handle.
//!
//! **Crash tolerance.** `TrainConfig::{checkpoint_every, checkpoint_dir}`
//! make a session persist periodic checkpoint generations while it runs,
//! so even a hard crash (process kill, node loss) is resumable from the
//! newest valid generation. A block task that errors or panics fails its
//! own session with [`TrainOutcome::Failed`] — in-flight siblings drain,
//! a final abort checkpoint is written, and every other session on the
//! shared pool is bitwise-unaffected.
//!
//! **Admission control.** The engine's [`AdmissionPolicy`] bounds how
//! many live jobs it accepts: past the bound, [`Engine::submit`] returns
//! a typed [`SubmitError::BacklogFull`] (`Reject`) or applies
//! backpressure by holding the caller (`Block`). `RunStats::
//! queue_wait_secs` reports how long each admitted job then waited for
//! its first worker slot — the fairness signal across [`Priority`]
//! levels.
//!
//! Three ways to run a job:
//!
//! - [`Engine::train`] — blocking, no events: submit + wait in one call.
//! - [`Engine::train_observed`] — blocking, with a callback receiving
//!   typed [`TrainEvent`]s as the schedule executes.
//! - [`Engine::submit`] — returns a [`Session`] handle immediately; the
//!   run proceeds on a background thread and streams [`TrainEvent`]s
//!   through a channel ([`Session::events`]), with [`Session::wait`]
//!   yielding the final [`TrainOutcome`].
//!
//! Each has a store-backed twin ([`Engine::train_store`],
//! [`Engine::train_store_observed`], [`Engine::submit_store`]) that
//! streams blocks from an ingested on-disk shard store
//! (`bmf_pp::store`) through a byte-budgeted cache instead of holding
//! the ratings in memory — same math, bitwise-identical posterior.
//!
//! The [`Factorizer`] trait unifies PP and the baseline comparators behind
//! `fit(&Engine, &Coo)`, so sweeping methods (or cross-validating one) is a
//! loop over fits on one warm engine.

use super::checkpoint::PartialCheckpoint;
use super::config::{BackendSpec, TrainConfig};
use super::scheduler::{JobId, Priority, WorkerPool};
use super::trainer::{
    center, load_resume, run_pp, run_pp_centered, run_pp_store, DataSource, JobCtx, PhaseTimings,
    RunControl, RunStats, TrainOutcome, TrainResult,
};
use crate::data::sparse::Coo;
use crate::online::delta::RatingDelta;
use crate::online::update::{
    check_prior, prior_dims, prune_prior, revision_skew, UpdateError,
};
use crate::partition::grid::Grid;
use crate::posterior::PosteriorModel;
use crate::store::{ShardStore, StoreError};
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex, Weak};

/// One of the four stages of the PP pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PpPhase {
    /// Block (0,0), fresh priors both sides.
    A,
    /// First-row / first-column blocks consuming the phase-(a) posterior.
    B,
    /// Interior blocks consuming two phase-(b) posteriors.
    C,
    /// Posterior aggregation parts.
    Aggregate,
}

impl fmt::Display for PpPhase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PpPhase::A => "a",
            PpPhase::B => "b",
            PpPhase::C => "c",
            PpPhase::Aggregate => "aggregate",
        })
    }
}

/// Which factor side of a block a pipelined chunk belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FactorSide {
    /// The row side (users / compounds / …).
    U,
    /// The column side (items / targets / …).
    V,
}

impl fmt::Display for FactorSide {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            FactorSide::U => "U",
            FactorSide::V => "V",
        })
    }
}

/// Typed progress events streamed while a training run executes. Emitted
/// from worker threads the moment the underlying work happens, so a
/// consumer (CLI, recorder, bench) observes the run live, not post-hoc.
#[derive(Debug, Clone)]
pub enum TrainEvent {
    /// First task of `phase` started executing.
    PhaseStarted {
        /// The PP phase that just started.
        phase: PpPhase,
    },
    /// Block `node` = (i, j) of the grid finished its MCMC.
    BlockCompleted {
        /// Grid coordinates of the block.
        node: (usize, usize),
        /// The PP phase the block belongs to.
        phase: PpPhase,
        /// Wall-clock seconds the block's MCMC took.
        secs: f64,
        /// Total Gibbs sweeps the block ran (burn-in + retained).
        sweeps: usize,
    },
    /// Block `node` was restored from a `resume_from` partial checkpoint
    /// instead of being re-sampled.
    BlockRestored {
        /// Grid coordinates of the block.
        node: (usize, usize),
    },
    /// Block `node` was passed through unchanged by an incremental update
    /// ([`Engine::update`]): no delta entry touched it, so its prior
    /// posterior fed aggregation as-is. Observability for "exactly what
    /// re-ran": an update emits this for every clean block and
    /// [`TrainEvent::BlockCompleted`] for every dirty one.
    BlockSkippedClean {
        /// Grid coordinates of the block.
        node: (usize, usize),
    },
    /// One retained Gibbs sweep on block `node`: training-data RMSE of the
    /// current factor sample (mean-centred scale) — the live mixing signal.
    SweepSample {
        /// Grid coordinates of the block.
        node: (usize, usize),
        /// Sweep index within the block (burn-in sweeps included).
        sweep: usize,
        /// Block training RMSE of the current factor sample.
        rmse: f64,
    },
    /// One chunk of a pipelined half-sweep was published to the block's
    /// [`FactorMailbox`](super::mailbox::FactorMailbox) — the within-block
    /// exchange overlapping computation. Emitted only under
    /// [`SweepMode::Pipelined`](super::config::SweepMode::Pipelined).
    ChunkExchanged {
        /// Grid coordinates of the block.
        node: (usize, usize),
        /// Factor side the chunk belongs to.
        side: FactorSide,
        /// Sweep index within the block.
        sweep: usize,
        /// Chunk index within the side.
        chunk: usize,
        /// Writer sequence number: publications of this side's half-sweep
        /// so far, this one included (1-based).
        seq: u64,
    },
    /// The run persisted its completed block posteriors as a partial (v3)
    /// checkpoint — a periodic generation
    /// (`TrainConfig::checkpoint_every`) or an abort checkpoint written on
    /// cancel/failure.
    CheckpointSaved {
        /// Where the checkpoint was written.
        path: PathBuf,
        /// Completed blocks recorded in it.
        blocks: usize,
    },
    /// A shard entered the cache of a store-backed run: a block task
    /// missed (`prefetch: false`) or the background prefetcher warmed it
    /// ahead of the task (`prefetch: true`). The counters are the cache's
    /// cumulative totals at emission, so the latest event is a live view
    /// of cache effectiveness. Never emitted by resident runs.
    ShardLoaded {
        /// Grid coordinates of the block whose shard was read.
        node: (usize, usize),
        /// On-disk size of the shard just loaded.
        bytes: u64,
        /// True when the background prefetcher performed the read.
        prefetch: bool,
        /// Cumulative cache hits (task fetches served without a disk read
        /// on the task's own time).
        hits: u64,
        /// Cumulative task-initiated disk reads.
        misses: u64,
        /// Cumulative first-touches of prefetcher-warmed shards.
        prefetch_hits: u64,
        /// Cumulative evictions under the `cache_bytes` budget.
        evictions: u64,
        /// Shard bytes resident after this load (and any evictions it
        /// forced).
        resident_bytes: u64,
    },
    /// The run was cancelled; no further block events follow.
    Cancelled {
        /// Blocks whose posteriors were completed before the cancel took
        /// effect.
        blocks_completed: usize,
    },
    /// A block task errored or panicked and the run failed (its job only —
    /// other sessions on the pool are untouched); no further block events
    /// follow.
    Failed {
        /// The first task failure, rendered.
        error: String,
        /// Blocks whose posteriors were completed before (and while) the
        /// run went down.
        blocks_completed: usize,
    },
    /// The whole schedule (all blocks + aggregation) completed.
    Finished {
        /// Wall-clock seconds of the full run.
        secs: f64,
        /// Number of blocks sampled.
        blocks: usize,
    },
}

/// Where events go: any thread-safe callback. `Engine::submit` wires this
/// to a channel; `Engine::train_observed` passes the caller's closure.
pub type EventSink = Arc<dyn Fn(TrainEvent) + Send + Sync>;

/// What [`Engine::submit`] does when the engine already has a full
/// backlog of live (queued or running) jobs. The default accepts
/// everything — PR-4 behaviour. Bounding the backlog turns the engine
/// from "unbounded queueing" into a service with load shedding: a burst
/// of submits past the bound is rejected (or held) instead of silently
/// piling onto the shared queue and starving everyone's latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Admit every submit (no bound). The default.
    #[default]
    Unbounded,
    /// Reject a submit once `max_backlog` jobs are live, with a typed
    /// [`SubmitError::BacklogFull`] the caller can downcast and retry on.
    Reject {
        /// Live (non-terminal) jobs admitted at once.
        max_backlog: usize,
    },
    /// Hold the submitting *caller* until the backlog drops below
    /// `max_backlog` — backpressure instead of an error. The job itself
    /// still starts asynchronously once admitted.
    ///
    /// The wait ends only when a live job settles: if the backlog is held
    /// by jobs that cannot settle on their own — e.g. `start_paused`
    /// submissions whose only handle is owned by the blocked caller — the
    /// submit waits forever. Don't mix `Block` admission with paused
    /// submissions unless another thread resumes them; use `Reject` when
    /// the caller must stay responsive.
    Block {
        /// Live (non-terminal) jobs admitted at once.
        max_backlog: usize,
    },
}

/// Why [`Engine::submit`] refused a job at admission (as opposed to the
/// config/resume validation errors, which have their own types).
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum SubmitError {
    /// The engine's [`AdmissionPolicy`] bound is reached: `backlog` jobs
    /// are already queued or running. Wait for one to settle and retry,
    /// or raise the bound.
    #[error(
        "engine backlog full: {backlog} jobs already queued or running \
         (admission bound {max_backlog})"
    )]
    BacklogFull {
        /// Live jobs at the moment the submit was refused.
        backlog: usize,
        /// The policy's bound.
        max_backlog: usize,
    },
}

/// Lifecycle state of a submitted job, as seen through [`Session::status`]
/// and [`Engine::jobs`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    /// Submitted; no block task has been dispatched yet.
    Queued,
    /// Block tasks are being dispatched / executed.
    Running,
    /// Dispatch is gated by [`Session::pause`]; in-flight blocks drain.
    Paused,
    /// [`Session::cancel`] was requested; in-flight blocks are draining.
    Cancelling,
    /// The run trained to completion.
    Completed,
    /// The run ended cancelled (checkpoint written if requested and any
    /// block had completed).
    Cancelled,
    /// The run ended failed: a block task errored or panicked
    /// ([`TrainOutcome::Failed`]), or setup failed outright.
    Failed,
}

impl JobStatus {
    /// True once the job can no longer make progress (completed,
    /// cancelled, or failed).
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Completed | JobStatus::Cancelled | JobStatus::Failed)
    }
}

impl fmt::Display for JobStatus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Paused => "paused",
            JobStatus::Cancelling => "cancelling",
            JobStatus::Completed => "completed",
            JobStatus::Cancelled => "cancelled",
            JobStatus::Failed => "failed",
        })
    }
}

/// Live state shared between a running job's driver thread and its
/// [`Session`] handle (and, weakly, the engine's job registry).
struct SessionShared {
    job: JobId,
    priority: Priority,
    status: Mutex<JobStatus>,
    control: Arc<RunControl>,
}

impl SessionShared {
    fn snapshot(&self) -> JobSnapshot {
        let shards = self.control.shards.snapshot();
        JobSnapshot {
            id: self.job,
            priority: self.priority,
            status: *self.status.lock().unwrap(),
            blocks_done: self.control.blocks_done.load(Ordering::Relaxed),
            blocks_total: self.control.blocks_total.load(Ordering::Relaxed),
            queue_wait_secs: self.control.queue_wait(),
            shard_hits: shards.hits,
            shard_misses: shards.misses,
            shard_prefetch_hits: shards.prefetch_hits,
        }
    }
}

/// Point-in-time view of one submitted job, from [`Engine::jobs`].
#[derive(Debug, Clone, Copy)]
pub struct JobSnapshot {
    /// The job's stable id.
    pub id: JobId,
    /// The job's dispatch priority.
    pub priority: Priority,
    /// Lifecycle state at snapshot time.
    pub status: JobStatus,
    /// Blocks completed so far (sampled + restored).
    pub blocks_done: usize,
    /// Total blocks in the job's grid (0 until the run thread starts).
    pub blocks_total: usize,
    /// The run's measured dispatch delay (`RunStats::queue_wait_secs`):
    /// how long its first block sat in the ready queue behind
    /// higher-priority work. `None` until the schedule has measured it
    /// (the value is produced when the block DAG completes).
    pub queue_wait_secs: Option<f64>,
    /// Live shard-cache hits so far (0 for resident runs).
    pub shard_hits: u64,
    /// Live shard-cache misses so far (0 for resident runs).
    pub shard_misses: u64,
    /// Live prefetch hits so far (0 for resident runs).
    pub shard_prefetch_hits: u64,
}

/// The engine's session registry: weak handles to every submitted job,
/// plus the condvar admission waits on. Shared (via `Arc`) with each
/// job's driver thread, which signals `settled` when its run reaches a
/// terminal status so a [`AdmissionPolicy::Block`]ed submitter can
/// re-check the backlog.
struct JobsRegistry {
    entries: Mutex<Vec<Weak<SessionShared>>>,
    settled: Condvar,
}

impl JobsRegistry {
    /// Count the live (non-terminal) jobs, pruning dead entries.
    fn live_backlog(entries: &mut Vec<Weak<SessionShared>>) -> usize {
        entries.retain(|e| e.strong_count() > 0);
        entries
            .iter()
            .filter_map(Weak::upgrade)
            .filter(|s| !s.status.lock().unwrap().is_terminal())
            .count()
    }

    /// Wake admission waiters after a job reached a terminal status. The
    /// registry mutex is taken (and released) first so a waiter between
    /// its backlog check and its `wait` cannot miss the notification.
    fn notify_settled(&self) {
        drop(self.entries.lock().unwrap());
        self.settled.notify_all();
    }
}

/// A persistent training engine: owns the worker pool, accepts many
/// concurrent jobs.
///
/// Dropping the engine drains and joins the pool threads.
pub struct Engine {
    pool: Arc<WorkerPool>,
    spec: BackendSpec,
    registry: Arc<JobsRegistry>,
    admission: Mutex<AdmissionPolicy>,
}

impl Engine {
    /// Spawn an engine with `threads` pool workers, each constructing its
    /// own backend from `spec` (backend errors surface on the first job).
    pub fn new(spec: &BackendSpec, threads: usize) -> Engine {
        Engine {
            pool: Arc::new(WorkerPool::new(spec, threads)),
            spec: spec.clone(),
            registry: Arc::new(JobsRegistry {
                entries: Mutex::new(Vec::new()),
                settled: Condvar::new(),
            }),
            admission: Mutex::new(AdmissionPolicy::Unbounded),
        }
    }

    /// Builder: this engine with the given [`AdmissionPolicy`].
    pub fn with_admission(self, policy: AdmissionPolicy) -> Engine {
        *self.admission.lock().unwrap() = policy;
        self
    }

    /// Change the admission policy at runtime (applies to future submits;
    /// already-admitted jobs are unaffected).
    pub fn set_admission(&self, policy: AdmissionPolicy) {
        *self.admission.lock().unwrap() = policy;
        // a loosened bound may unblock held submitters; take (and release)
        // the registry mutex first so a waiter between its backlog check
        // and its wait cannot miss this notification
        self.registry.notify_settled();
    }

    /// The engine's current admission policy.
    pub fn admission(&self) -> AdmissionPolicy {
        *self.admission.lock().unwrap()
    }

    /// Live (queued or running) jobs right now — what admission compares
    /// against the policy's bound.
    pub fn backlog(&self) -> usize {
        JobsRegistry::live_backlog(&mut self.registry.entries.lock().unwrap())
    }

    /// Enforce the admission policy; returns holding the registry guard
    /// so the subsequent registration is atomic with the check (two
    /// concurrent submits cannot both squeeze past the bound).
    fn admit(&self) -> Result<std::sync::MutexGuard<'_, Vec<Weak<SessionShared>>>, SubmitError> {
        let mut entries = self.registry.entries.lock().unwrap();
        loop {
            // re-read each iteration: set_admission may change it mid-wait
            let policy = *self.admission.lock().unwrap();
            let bound = match policy {
                AdmissionPolicy::Unbounded => return Ok(entries),
                AdmissionPolicy::Reject { max_backlog } | AdmissionPolicy::Block { max_backlog } => {
                    max_backlog
                }
            };
            let backlog = JobsRegistry::live_backlog(&mut entries);
            if backlog < bound {
                return Ok(entries);
            }
            match policy {
                AdmissionPolicy::Reject { max_backlog } => {
                    return Err(SubmitError::BacklogFull { backlog, max_backlog })
                }
                _ => entries = self.registry.settled.wait(entries).unwrap(),
            }
        }
    }

    /// Engine over the default auto-resolved backend with the default
    /// block parallelism (same heuristics as [`TrainConfig::new`]).
    pub fn auto() -> Engine {
        let cfg = TrainConfig::new(1);
        Engine::new(&cfg.backend, cfg.block_parallelism)
    }

    /// The backend spec the pool workers were constructed from.
    pub fn backend(&self) -> &BackendSpec {
        &self.spec
    }

    /// Number of worker threads (parallel block slots).
    pub fn threads(&self) -> usize {
        self.pool.threads
    }

    /// The underlying pool, for callers that schedule raw phases/DAGs.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Run one training job to completion on the warm pool (no events).
    /// Blocking convenience over [`Engine::submit`]; a cancelled run (not
    /// possible from this call's own handle) surfaces as an error.
    pub fn train(&self, cfg: &TrainConfig, train: &Coo) -> anyhow::Result<TrainResult> {
        run_pp(cfg, &self.pool, train, None)
    }

    /// Run one training job to completion, delivering every [`TrainEvent`]
    /// to `on_event` as it happens (called from worker threads).
    pub fn train_observed(
        &self,
        cfg: &TrainConfig,
        train: &Coo,
        on_event: impl Fn(TrainEvent) + Send + Sync + 'static,
    ) -> anyhow::Result<TrainResult> {
        run_pp(cfg, &self.pool, train, Some(Arc::new(on_event)))
    }

    /// Run one store-backed training job to completion (no events):
    /// blocks stream from `store` through a byte-budgeted shard cache
    /// (`TrainConfig::cache_bytes`) instead of living in memory. The
    /// posterior is bitwise-identical to [`Engine::train`] on the data
    /// the store was ingested from. The config's grid must equal the
    /// store's ingest grid; a mismatch is a typed
    /// [`StoreError::GridMismatch`].
    pub fn train_store(
        &self,
        cfg: &TrainConfig,
        store: Arc<ShardStore>,
    ) -> anyhow::Result<TrainResult> {
        Self::check_store_grid(cfg, &store)?;
        run_pp_store(cfg, &self.pool, store, None)
    }

    /// [`Engine::train_store`] with a live [`TrainEvent`] callback —
    /// store-backed runs additionally stream
    /// [`TrainEvent::ShardLoaded`] as shards enter the cache.
    pub fn train_store_observed(
        &self,
        cfg: &TrainConfig,
        store: Arc<ShardStore>,
        on_event: impl Fn(TrainEvent) + Send + Sync + 'static,
    ) -> anyhow::Result<TrainResult> {
        Self::check_store_grid(cfg, &store)?;
        run_pp_store(cfg, &self.pool, store, Some(Arc::new(on_event)))
    }

    /// The training grid must equal the ingest grid: shards were cut on
    /// the latter, and block membership depends on it.
    fn check_store_grid(cfg: &TrainConfig, store: &ShardStore) -> Result<(), StoreError> {
        let store_grid = store.grid_dims();
        if store_grid != cfg.grid {
            return Err(StoreError::GridMismatch { cfg: cfg.grid, store: store_grid });
        }
        Ok(())
    }

    /// Validate `cfg` against `train` (and load + validate any
    /// `resume_from` checkpoint), enforce the engine's
    /// [`AdmissionPolicy`] (a full backlog yields a typed
    /// [`SubmitError::BacklogFull`] under `Reject`, or holds the caller
    /// under `Block`), then start the run on a background thread against
    /// this engine's warm pool. Returns immediately with a [`Session`];
    /// any number of admitted sessions run concurrently, interleaved by
    /// the pool's shared priority queue.
    pub fn submit(&self, cfg: TrainConfig, train: &Coo) -> anyhow::Result<Session> {
        cfg.validate(train.rows, train.cols)?;
        // resume problems surface here, not on the background thread
        let resume = load_resume(&cfg)?;
        // the session's single private copy of the data, centred during
        // the one unavoidable clone
        let (centered, global_mean) = center(train);
        self.submit_source(cfg, DataSource::Resident(centered), global_mean, resume, false)
    }

    /// [`Engine::submit`] against an opened shard store: same session
    /// lifecycle (events, pause/cancel, checkpoints, admission), but
    /// blocks stream from disk through a `TrainConfig::cache_bytes`-
    /// budgeted cache and the session holds no copy of the ratings at
    /// all. Grid mismatches against the ingest grid are a typed
    /// [`StoreError::GridMismatch`] here, at submit time.
    pub fn submit_store(&self, cfg: TrainConfig, store: Arc<ShardStore>) -> anyhow::Result<Session> {
        cfg.validate(store.rows(), store.cols())?;
        Self::check_store_grid(&cfg, &store)?;
        let resume = load_resume(&cfg)?;
        // the centring mean was computed once at ingest and persisted in
        // the manifest — bitwise the same f64 a resident run derives
        let global_mean = store.global_mean();
        self.submit_source(cfg, DataSource::Store(store), global_mean, resume, false)
    }

    /// Incremental posterior update: re-sample **only** the blocks a
    /// [`RatingDelta`] touches, passing every clean block's posterior from
    /// `prior` through unchanged.
    ///
    /// The mechanism is a *pruned resume*: the delta is projected through
    /// the block grid onto its dirty blocks
    /// ([`RatingDelta::dirty_blocks`]), those blocks are dropped from the
    /// prior checkpoint ([`prune_prior`](crate::online::update)), and the
    /// remainder seeds the run exactly like `resume_from` would. Clean
    /// blocks early-return their checkpointed posterior (emitting
    /// [`TrainEvent::BlockSkippedClean`]); dirty blocks re-sample with
    /// their original per-block seeds over the updated data; the
    /// aggregation replays in canonical order. Because `aggregate_part`
    /// divides each posterior by the prior it consumed, a clean posterior
    /// fed back as a prior is never counted twice — so an **empty delta
    /// reproduces the prior model bit for bit**, and a delta reaching new
    /// row/column ids simply dirties every block (a full retrain inside
    /// the same API).
    ///
    /// `base` must be the *raw* (uncentred) matrix the prior trained on —
    /// dimensions are checked against the checkpoint's per-block shapes
    /// and its mean against `prior.global_mean` (the same data
    /// fingerprint a resume enforces); the delta is upserted on top.
    /// Centring uses the **prior's** mean, pinned, so clean blocks see
    /// bitwise-identical data. `cfg` must carry the prior's `k`, `grid`,
    /// and `seed` (typed [`UpdateError`] otherwise); `cfg.resume_from` is
    /// ignored — the pruned prior *is* the resume state.
    pub fn update(
        &self,
        cfg: TrainConfig,
        prior: &PartialCheckpoint,
        delta: &RatingDelta,
        base: &Coo,
    ) -> anyhow::Result<Session> {
        check_prior(&cfg, prior)?;
        let dims = prior_dims(prior);
        if (base.rows, base.cols) != dims {
            return Err(
                UpdateError::DataMismatch { data: (base.rows, base.cols), prior: dims }.into()
            );
        }
        anyhow::ensure!(
            base.mean().to_bits() == prior.global_mean.to_bits(),
            "update base data does not fingerprint-match the checkpoint: \
             data mean {} vs checkpoint mean {} — pass the exact matrix the \
             prior trained on (the delta carries the changes)",
            base.mean(),
            prior.global_mean,
        );
        let updated = delta.apply_to(base);
        let mut cfg = cfg;
        cfg.resume_from = None;
        cfg.validate(updated.rows, updated.cols)?;
        let (gi, gj) = cfg.grid;
        // project against the BASE grid: growth past it dirties everything
        let dirty = delta.dirty_blocks(&Grid::new(base.rows, base.cols, gi, gj));
        let pruned = prune_prior(prior, &dirty);
        // centre with the pinned prior mean — NOT the updated data's own
        // mean — so every clean block's entries stay bitwise-identical
        let mean = prior.global_mean;
        let mut centered = updated;
        for e in &mut centered.entries {
            e.val -= mean as f32;
        }
        self.submit_source(cfg, DataSource::Resident(centered), mean, Some(pruned), true)
    }

    /// [`Engine::update`] against a shard store the delta has already
    /// been folded into (`bmf-pp ingest --append` /
    /// [`append_delta`](crate::online::append_delta)).
    ///
    /// The store carries the post-append data and the pinned centring
    /// mean, so only the dirty-set projection needs the delta here. Two
    /// extra checks against the store: its centring mean must equal the
    /// prior's bitwise (a re-ingested store re-derives the mean — that
    /// needs a full retrain, and fails typed here), and if its append
    /// `revision` is more than one step past
    /// `prior.store_revision` a non-fatal
    /// [`UpdateWarning`](crate::online::UpdateWarning) is logged — the
    /// delta likely does not cover the intermediate appends. An append
    /// that *grew* the matrix dirties every block, degrading to a full
    /// retrain within the same call.
    pub fn update_store(
        &self,
        cfg: TrainConfig,
        prior: &PartialCheckpoint,
        delta: &RatingDelta,
        store: Arc<ShardStore>,
    ) -> anyhow::Result<Session> {
        check_prior(&cfg, prior)?;
        let mut cfg = cfg;
        cfg.resume_from = None;
        cfg.validate(store.rows(), store.cols())?;
        Self::check_store_grid(&cfg, &store)?;
        anyhow::ensure!(
            store.global_mean().to_bits() == prior.global_mean.to_bits(),
            "store centring mean {} does not match the checkpoint's {} — \
             the store was re-ingested rather than appended to; run a full \
             retrain instead of an update",
            store.global_mean(),
            prior.global_mean,
        );
        if let Some(warning) = revision_skew(prior, store.revision()) {
            log::warn!("{warning}");
        }
        let dims = prior_dims(prior);
        let dirty = if (store.rows(), store.cols()) != dims {
            // the append grew the matrix: every block boundary moved
            store.partition_grid().blocks().map(|b| (b.i, b.j)).collect()
        } else {
            delta.dirty_blocks(store.partition_grid())
        };
        let pruned = prune_prior(prior, &dirty);
        let global_mean = store.global_mean();
        self.submit_source(cfg, DataSource::Store(store), global_mean, Some(pruned), true)
    }

    /// Shared back half of [`Engine::submit`] / [`Engine::submit_store`]:
    /// admission, registration, and the driver thread.
    fn submit_source(
        &self,
        cfg: TrainConfig,
        data: DataSource,
        global_mean: f64,
        resume: Option<PartialCheckpoint>,
        clean_skip: bool,
    ) -> anyhow::Result<Session> {
        // admission: the returned guard keeps check + registration atomic
        let mut reg = self.admit()?;
        let job = self.pool.register_job(cfg.priority, cfg.max_in_flight);
        if cfg.start_paused {
            self.pool.set_job_paused(job, true);
        }
        let shared = Arc::new(SessionShared {
            job,
            priority: cfg.priority,
            status: Mutex::new(if cfg.start_paused {
                JobStatus::Paused
            } else {
                JobStatus::Queued
            }),
            control: Arc::new(RunControl::new()),
        });
        reg.push(Arc::downgrade(&shared));
        drop(reg);
        let (tx, rx) = channel::<TrainEvent>();
        let pool = self.pool.clone();
        let registry = self.registry.clone();
        let shared_bg = shared.clone();
        let handle = std::thread::spawn(move || {
            {
                let mut st = shared_bg.status.lock().unwrap();
                if *st == JobStatus::Queued {
                    *st = JobStatus::Running;
                }
            }
            let sink: EventSink = Arc::new({
                let tx = tx.clone();
                move |e| {
                    // a dropped receiver just means nobody is watching
                    let _ = tx.send(e);
                }
            });
            let ctx = JobCtx { job, control: shared_bg.control.clone(), resume, clean_skip };
            let res = run_pp_centered(&cfg, &pool, data, global_mean, Some(sink), ctx);
            pool.finish_job(job);
            *shared_bg.status.lock().unwrap() = match &res {
                Ok(TrainOutcome::Completed(_)) => JobStatus::Completed,
                Ok(TrainOutcome::Cancelled(_)) => JobStatus::Cancelled,
                Ok(TrainOutcome::Failed(_)) | Err(_) => JobStatus::Failed,
            };
            // the job settled: admission waiters can re-check the backlog
            registry.notify_settled();
            // `tx` (kept alive until here) closes the event stream only
            // now, so a consumer that drains events always observes a
            // terminal status afterwards
            drop(tx);
            res
        });
        Ok(Session { rx, handle: Some(handle), shared, pool: self.pool.clone() })
    }

    /// Snapshot every submitted job whose [`Session`] handle (or driver
    /// thread) is still alive: id, priority, status, block progress.
    pub fn jobs(&self) -> Vec<JobSnapshot> {
        let mut reg = self.registry.entries.lock().unwrap();
        reg.retain(|e| e.strong_count() > 0);
        reg.iter().filter_map(Weak::upgrade).map(|s| s.snapshot()).collect()
    }
}

/// Handle to one in-flight training run submitted to an [`Engine`].
///
/// Events arrive on an unbounded channel, so a slow (or absent) consumer
/// never stalls training. The channel closes when the run finishes; after
/// that [`Session::wait`] returns the [`TrainOutcome`].
///
/// Dropping a session without waiting is safe: the run keeps executing
/// (and releases its pool bookkeeping when done) — a paused job is
/// resumed on drop so it cannot sit parked forever with no handle left
/// to resume it.
pub struct Session {
    rx: Receiver<TrainEvent>,
    handle: Option<std::thread::JoinHandle<anyhow::Result<TrainOutcome>>>,
    shared: Arc<SessionShared>,
    pool: Arc<WorkerPool>,
}

impl Session {
    /// The job's stable id in the engine's shared queue.
    pub fn id(&self) -> JobId {
        self.shared.job
    }

    /// The job's dispatch priority.
    pub fn priority(&self) -> Priority {
        self.shared.priority
    }

    /// The job's lifecycle state right now.
    pub fn status(&self) -> JobStatus {
        *self.shared.status.lock().unwrap()
    }

    /// Blocks completed vs total in the job's grid. The total is 0 until
    /// the run thread has started.
    pub fn progress(&self) -> (usize, usize) {
        (
            self.shared.control.blocks_done.load(Ordering::Relaxed),
            self.shared.control.blocks_total.load(Ordering::Relaxed),
        )
    }

    /// Request cancellation: no further block tasks are dispatched, queued
    /// ones fast-skip, in-flight ones drain. If
    /// `TrainConfig::checkpoint_on_cancel` was set and at least one block
    /// completed, the run writes a partial (v3) checkpoint
    /// ([`TrainEvent::CheckpointSaved`]) before yielding
    /// [`TrainOutcome::Cancelled`]. Idempotent; a no-op once terminal.
    pub fn cancel(&self) {
        {
            let mut st = self.shared.status.lock().unwrap();
            if st.is_terminal() {
                return;
            }
            *st = JobStatus::Cancelling;
        }
        self.shared.control.cancel.store(true, Ordering::Relaxed);
        // a paused job must still drain (its queued tasks fast-skip)
        self.pool.set_job_paused(self.shared.job, false);
    }

    /// Gate dispatch of this job's remaining block tasks; they keep their
    /// queue positions and in-flight ones drain. No-op unless the job is
    /// queued or running.
    pub fn pause(&self) {
        let mut st = self.shared.status.lock().unwrap();
        if matches!(*st, JobStatus::Queued | JobStatus::Running) {
            *st = JobStatus::Paused;
            self.pool.set_job_paused(self.shared.job, true);
        }
    }

    /// Lift a [`Session::pause`] (or a `start_paused` submission); the
    /// job's tasks become dispatchable again at their queue positions.
    pub fn resume(&self) {
        let mut st = self.shared.status.lock().unwrap();
        if *st == JobStatus::Paused {
            *st = JobStatus::Running;
            self.pool.set_job_paused(self.shared.job, false);
        }
    }

    /// Block for the next event; `None` once the run is over and the
    /// stream is drained.
    pub fn next_event(&self) -> Option<TrainEvent> {
        self.rx.recv().ok()
    }

    /// Non-blocking poll for an event.
    pub fn try_event(&self) -> Option<TrainEvent> {
        self.rx.try_recv().ok()
    }

    /// Iterate events until the run completes (the iterator is the live
    /// progress stream; it ends when training stops emitting).
    pub fn events(&self) -> impl Iterator<Item = TrainEvent> + '_ {
        std::iter::from_fn(move || self.rx.recv().ok())
    }

    /// Join the run and return how it ended (undelivered events are
    /// dropped): [`TrainOutcome::Completed`] with the result,
    /// [`TrainOutcome::Cancelled`] with the abort record, or
    /// [`TrainOutcome::Failed`] when a block task errored or panicked.
    /// Callers that treat anything short of completion as failure can
    /// chain [`TrainOutcome::into_result`]. Waiting is an explicit
    /// request for the run to finish, so a paused session is resumed
    /// first — joining the only handle that could ever resume it must not
    /// deadlock.
    pub fn wait(mut self) -> anyhow::Result<TrainOutcome> {
        self.resume();
        let handle = self.handle.take().expect("session joined exactly once");
        match handle.join() {
            Ok(res) => res,
            Err(_) => Err(anyhow::anyhow!("training thread panicked")),
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // no-op unless the job is still alive and paused: the run (or its
        // cancel drain) must be able to proceed without a handle — and
        // Engine::jobs must see it as running again, not parked
        {
            let mut st = self.shared.status.lock().unwrap();
            if *st == JobStatus::Paused {
                *st = JobStatus::Running;
            }
        }
        self.pool.set_job_paused(self.shared.job, false);
    }
}

/// A matrix-factorization method that can be fitted on an [`Engine`].
///
/// PP trains on the engine's pool; the SGD/ALS/CGD/SGLD baselines manage
/// their own intra-method threading and take the engine for interface
/// uniformity — either way, `fit` returns one servable [`PosteriorModel`]
/// so downstream evaluation code is method-agnostic.
pub trait Factorizer {
    /// Short method name ("pp", "nomad", …) for tables and logs.
    fn name(&self) -> &str;

    /// Train on `data`, returning the fitted model plus diagnostics.
    fn fit(&self, engine: &Engine, data: &Coo) -> anyhow::Result<FitOutcome>;
}

/// What a [`Factorizer`] fit produces: the servable model plus run
/// diagnostics (PP-specific scheduling stats when available).
pub struct FitOutcome {
    /// Short method name ("pp", "nomad", …).
    pub method: String,
    /// The servable model the fit produced.
    pub model: PosteriorModel,
    /// Wall-clock seconds of the fit.
    pub secs: f64,
    /// Phase timings + scheduling stats — `Some` only for PP runs.
    pub pp_stats: Option<(PhaseTimings, RunStats)>,
}

/// Posterior Propagation as a [`Factorizer`].
pub struct PpFactorizer(
    /// The PP training configuration each fit runs with.
    pub TrainConfig,
);

impl Factorizer for PpFactorizer {
    fn name(&self) -> &str {
        "pp"
    }

    fn fit(&self, engine: &Engine, data: &Coo) -> anyhow::Result<FitOutcome> {
        let t0 = std::time::Instant::now();
        let res = engine.train(&self.0, data)?;
        Ok(FitOutcome {
            method: "pp".to_string(),
            secs: t0.elapsed().as_secs_f64(),
            pp_stats: Some((res.timings, res.stats)),
            model: res.model,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::BlockBackend;
    use crate::coordinator::config::ConfigError;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use std::collections::HashSet;
    use std::thread::ThreadId;

    fn dataset() -> (Coo, Coo, usize) {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 31).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 32);
        (train, test, d.k)
    }

    fn quick_cfg(k: usize) -> TrainConfig {
        TrainConfig::new(k)
            .with_backend(BackendSpec::Native)
            .with_grid(2, 2)
            .with_sweeps(4, 8)
            .with_seed(33)
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("bmfpp_engine_{tag}_{}.json", std::process::id()))
    }

    /// Thread ids of pool workers observed while running a saturating batch.
    fn worker_ids(pool: &WorkerPool) -> HashSet<ThreadId> {
        let tasks: Vec<_> = (0..pool.threads * 4)
            .map(|_| {
                move |_b: &BlockBackend| -> anyhow::Result<ThreadId> {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    Ok(std::thread::current().id())
                }
            })
            .collect();
        pool.run_phase(tasks).unwrap().into_iter().collect()
    }

    #[test]
    fn sequential_sessions_match_fresh_engines_on_one_warm_pool() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 3);
        let ids_before = worker_ids(engine.pool());

        let r1 = engine
            .submit(quick_cfg(k), &train)
            .unwrap()
            .wait()
            .unwrap()
            .into_result()
            .unwrap();
        let r2 = engine
            .submit(quick_cfg(k), &train)
            .unwrap()
            .wait()
            .unwrap()
            .into_result()
            .unwrap();
        // the warm pool must not change the math: both sessions equal a
        // fresh single-run engine bit for bit
        let fresh = Engine::new(&BackendSpec::Native, 3).train(&quick_cfg(k), &train).unwrap();
        assert_eq!(r1.u_post.mean, fresh.u_post.mean);
        assert_eq!(r1.v_post.prec, fresh.v_post.prec);
        assert_eq!(r1.u_mean, r2.u_mean);
        assert_eq!(r1.v_mean, r2.v_mean);

        // and it must actually be the same pool: no threads re-spawned
        let ids_after = worker_ids(engine.pool());
        assert!(
            ids_after.is_subset(&ids_before),
            "pool threads changed: {ids_before:?} -> {ids_after:?}"
        );
    }

    #[test]
    fn concurrent_sessions_bitwise_match_solo_runs() {
        // two jobs interleaving on one pool must each produce the exact
        // posterior they produce alone on a fresh engine
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 3);
        let cfg_a = quick_cfg(k).with_seed(41);
        let cfg_b = quick_cfg(k).with_grid(3, 2).with_seed(42);
        let s_a = engine.submit(cfg_a.clone(), &train).unwrap();
        let s_b = engine.submit(cfg_b.clone(), &train).unwrap();
        assert_ne!(s_a.id(), s_b.id(), "job ids are distinct");
        let r_a = s_a.wait().unwrap().into_result().unwrap();
        let r_b = s_b.wait().unwrap().into_result().unwrap();

        let solo_a = Engine::new(&BackendSpec::Native, 3).train(&cfg_a, &train).unwrap();
        let solo_b = Engine::new(&BackendSpec::Native, 3).train(&cfg_b, &train).unwrap();
        assert_eq!(r_a.u_post.mean, solo_a.u_post.mean);
        assert_eq!(r_a.v_post.prec, solo_a.v_post.prec);
        assert_eq!(r_b.u_post.mean, solo_b.u_post.mean);
        assert_eq!(r_b.v_post.prec, solo_b.v_post.prec);
    }

    #[test]
    fn high_priority_job_finishes_before_wide_low_job() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let low = engine
            .submit(
                quick_cfg(k)
                    .with_grid(4, 4)
                    .with_sweeps(6, 12)
                    .with_priority(Priority::Low)
                    .with_seed(51),
                &train,
            )
            .unwrap();
        let high = engine
            .submit(
                quick_cfg(k)
                    .with_sweeps(2, 4)
                    .with_priority(Priority::High)
                    .with_seed(52),
                &train,
            )
            .unwrap();
        let r = high.wait().unwrap().into_result().unwrap();
        assert_eq!(r.stats.blocks, 4);
        // the wide low-priority job (16 blocks, ~12x the sweeps) must
        // still be going when the high one lands
        assert!(
            !low.status().is_terminal(),
            "low-priority job finished before the high-priority one"
        );
        let r_low = low.wait().unwrap().into_result().unwrap();
        assert_eq!(r_low.stats.blocks, 16);
    }

    #[test]
    fn same_priority_jobs_interleave_fairly() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let s1 = engine.submit(quick_cfg(k).with_seed(61), &train).unwrap();
        let s2 = engine.submit(quick_cfg(k).with_seed(62), &train).unwrap();
        let order: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        let consume = |session: Session, tag: u8, order: Arc<Mutex<Vec<u8>>>| {
            std::thread::spawn(move || {
                for event in session.events() {
                    if matches!(event, TrainEvent::BlockCompleted { .. }) {
                        order.lock().unwrap().push(tag);
                    }
                }
                session.wait().unwrap().into_result().unwrap()
            })
        };
        let h1 = consume(s1, 1, order.clone());
        let h2 = consume(s2, 2, order.clone());
        h1.join().unwrap();
        h2.join().unwrap();
        let order = order.lock().unwrap();
        let first = |tag| order.iter().position(|&t| t == tag).unwrap();
        let last = |tag| order.iter().rposition(|&t| t == tag).unwrap();
        // both jobs completed blocks before either finished all of its own
        assert!(
            first(1) < last(2) && first(2) < last(1),
            "no interleaving in completion order {order:?}"
        );
    }

    #[test]
    fn session_streams_typed_events() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let session = engine.submit(quick_cfg(k), &train).unwrap();
        let events: Vec<TrainEvent> = session.events().collect();
        assert!(session.status().is_terminal());
        let result = session.wait().unwrap().into_result().unwrap();

        // phase (a) starts before anything else
        assert!(matches!(events[0], TrainEvent::PhaseStarted { phase: PpPhase::A }));
        let blocks = events
            .iter()
            .filter(|e| matches!(e, TrainEvent::BlockCompleted { .. }))
            .count();
        assert_eq!(blocks, result.stats.blocks);
        assert_eq!(blocks, 4, "2x2 grid");
        // per-sweep samples stream from inside the blocks
        assert!(events.iter().any(|e| matches!(
            e,
            TrainEvent::SweepSample { rmse, .. } if rmse.is_finite()
        )));
        // aggregation is part of the stream, and the run closes with Finished
        assert!(events
            .iter()
            .any(|e| matches!(e, TrainEvent::PhaseStarted { phase: PpPhase::Aggregate })));
        assert!(matches!(events.last(), Some(TrainEvent::Finished { .. })));
    }

    #[test]
    fn submit_validates_config_before_spawning() {
        let (train, _, _) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let err = engine.submit(quick_cfg(0), &train).unwrap_err();
        assert_eq!(err.downcast_ref::<ConfigError>(), Some(&ConfigError::ZeroK));
        let err = engine.submit(quick_cfg(8).with_grid(train.rows + 1, 1), &train).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ConfigError>(),
            Some(ConfigError::GridExceedsMatrix { .. })
        ));
        // a missing resume checkpoint fails at submit, not in the thread
        let err = engine
            .submit(quick_cfg(8).with_resume_from("/definitely/missing.json"), &train)
            .unwrap_err();
        assert!(format!("{err:#}").contains("cannot resume"), "{err:#}");
    }

    #[test]
    fn cancel_before_start_yields_cancelled_without_checkpoint() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let ckpt = tmp("cancel_before_start");
        std::fs::remove_file(&ckpt).ok();
        let session = engine
            .submit(
                quick_cfg(k)
                    .with_start_paused(true)
                    .with_checkpoint_on_cancel(ckpt.clone()),
                &train,
            )
            .unwrap();
        assert_eq!(session.status(), JobStatus::Paused);
        session.cancel();
        let outcome = session.wait().unwrap();
        let info = outcome.cancelled().expect("cancel-before-start must cancel");
        assert_eq!(info.blocks_completed, 0);
        assert!(info.checkpoint.is_none(), "no blocks done → no checkpoint");
        assert!(!ckpt.exists(), "no checkpoint file may be written");
    }

    #[test]
    fn cancelled_job_checkpoints_and_resume_is_bitwise_identical() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let ckpt = tmp("cancel_resume");
        std::fs::remove_file(&ckpt).ok();
        let cfg = quick_cfg(k).with_grid(3, 3).with_sweeps(6, 12).with_seed(71);
        let session = engine
            .submit(cfg.clone().with_checkpoint_on_cancel(ckpt.clone()), &train)
            .unwrap();
        // let a couple of blocks land, then abort
        while session.progress().0 < 2 && !session.status().is_terminal() {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        session.cancel();
        let outcome = session.wait().unwrap();
        let Some(info) = outcome.cancelled() else {
            // the run beat the cancel; nothing to resume — rerun would
            // only repeat the completed-run tests
            eprintln!("run completed before cancel landed; skipping resume check");
            return;
        };
        assert!(info.blocks_completed >= 2);
        let saved = info.checkpoint.clone().expect("blocks completed → checkpoint written");
        assert_eq!(saved, ckpt);

        // resume must reproduce the uninterrupted run bit for bit
        let resumed = engine.train(&cfg.clone().with_resume_from(ckpt.clone()), &train).unwrap();
        let full = engine.train(&cfg, &train).unwrap();
        assert_eq!(resumed.u_post.mean, full.u_post.mean);
        assert_eq!(resumed.u_post.prec, full.u_post.prec);
        assert_eq!(resumed.v_post.mean, full.v_post.mean);
        assert_eq!(resumed.v_post.prec, full.v_post.prec);
        assert_eq!(resumed.stats.blocks_restored, info.blocks_completed);
        assert_eq!(resumed.stats.blocks + resumed.stats.blocks_restored, 9);
        std::fs::remove_file(ckpt).ok();
    }

    #[test]
    fn cancelling_a_resumed_run_never_shrinks_checkpointed_progress() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let ckpt1 = tmp("progress_1");
        let ckpt2 = tmp("progress_2");
        std::fs::remove_file(&ckpt1).ok();
        std::fs::remove_file(&ckpt2).ok();
        let cfg = quick_cfg(k).with_grid(3, 3).with_sweeps(6, 12).with_seed(81);

        let s1 = engine
            .submit(cfg.clone().with_checkpoint_on_cancel(ckpt1.clone()), &train)
            .unwrap();
        while s1.progress().0 < 2 && !s1.status().is_terminal() {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        s1.cancel();
        let Some(info1) = s1.wait().unwrap().cancelled().cloned() else {
            eprintln!("first run beat the cancel; skipping");
            return;
        };
        assert!(info1.blocks_completed >= 2);

        // resume and cancel again almost immediately: even if the restore
        // nodes never dispatched, the new checkpoint must carry at least
        // everything the old one knew
        let s2 = engine
            .submit(
                cfg.with_resume_from(ckpt1.clone()).with_checkpoint_on_cancel(ckpt2.clone()),
                &train,
            )
            .unwrap();
        while s2.progress().0 < 1 && !s2.status().is_terminal() {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        s2.cancel();
        match s2.wait().unwrap() {
            TrainOutcome::Cancelled(info2) => {
                if let Some(p) = &info2.checkpoint {
                    let loaded = crate::coordinator::checkpoint::load_partial(p).unwrap();
                    assert!(
                        loaded.blocks.len() >= info1.blocks_completed,
                        "checkpointed progress shrank: {} -> {}",
                        info1.blocks_completed,
                        loaded.blocks.len()
                    );
                } else {
                    assert_eq!(info2.blocks_completed, 0);
                }
            }
            TrainOutcome::Completed(_) => {} // cancel lost the race; fine
            TrainOutcome::Failed(info) => panic!("unexpected failure: {}", info.error),
        }
        std::fs::remove_file(ckpt1).ok();
        std::fs::remove_file(ckpt2).ok();
    }

    #[test]
    fn paused_session_makes_no_progress_until_resumed() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let session =
            engine.submit(quick_cfg(k).with_start_paused(true), &train).unwrap();
        assert_eq!(session.status(), JobStatus::Paused);
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(session.progress().0, 0, "paused job completed a block");
        session.resume();
        let result = session.wait().unwrap().into_result().unwrap();
        assert_eq!(result.stats.blocks, 4);
    }

    #[test]
    fn waiting_on_a_paused_session_resumes_it_instead_of_deadlocking() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let session =
            engine.submit(quick_cfg(k).with_start_paused(true), &train).unwrap();
        // wait() consumes the only handle that could ever resume the job,
        // so it must un-gate dispatch itself rather than join forever
        let result = session.wait().unwrap().into_result().unwrap();
        assert_eq!(result.stats.blocks, 4);
    }

    #[test]
    fn dropping_sessions_without_wait_leaves_pool_serving() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        {
            // running session dropped mid-flight: the run detaches and
            // finishes on its own
            let s = engine.submit(quick_cfg(k), &train).unwrap();
            let _ = s.try_event();
            drop(s);
        }
        {
            // paused session dropped: drop resumes it so it cannot park
            // its queued tasks forever
            let s = engine.submit(quick_cfg(k).with_start_paused(true), &train).unwrap();
            drop(s);
        }
        // the pool still serves fresh work promptly
        let r = engine.train(&quick_cfg(k), &train).unwrap();
        assert_eq!(r.stats.blocks, 4);
        // engine drop below joins the pool — a wedged queue would hang here
    }

    #[test]
    fn jobs_snapshot_reports_live_sessions() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        assert!(engine.jobs().is_empty());
        let s1 = engine
            .submit(quick_cfg(k).with_start_paused(true).with_priority(Priority::Low), &train)
            .unwrap();
        let s2 = engine.submit(quick_cfg(k).with_priority(Priority::High), &train).unwrap();
        let snap = engine.jobs();
        assert_eq!(snap.len(), 2);
        let of = |id| snap.iter().find(|j| j.id == id).copied().unwrap();
        assert_eq!(of(s1.id()).priority, Priority::Low);
        assert_eq!(of(s1.id()).status, JobStatus::Paused);
        assert_eq!(of(s2.id()).priority, Priority::High);
        s1.resume();
        s1.wait().unwrap().into_result().unwrap();
        s2.wait().unwrap().into_result().unwrap();
        // waited-out sessions drop out of the registry
        assert!(engine.jobs().is_empty());
    }

    #[test]
    fn jobs_snapshot_surfaces_queue_wait_once_measured() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let session = engine.submit(quick_cfg(k).with_start_paused(true), &train).unwrap();
        let before = engine.jobs();
        assert_eq!(before.len(), 1);
        assert_eq!(
            before[0].queue_wait_secs, None,
            "queue wait is unmeasured until the schedule completes"
        );
        session.resume();
        // the Finished event is emitted after the stats (and the shared
        // queue-wait cell) are final, so observing it orders the check
        for event in session.events() {
            if matches!(event, TrainEvent::Finished { .. }) {
                break;
            }
        }
        let after = engine.jobs();
        assert_eq!(after.len(), 1);
        let wait = after[0].queue_wait_secs.expect("measured after completion");
        assert!(wait.is_finite() && wait >= 0.0, "queue_wait_secs={wait}");
        session.wait().unwrap().into_result().unwrap();
    }

    #[test]
    fn reject_admission_bounds_the_backlog_with_typed_error() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2)
            .with_admission(AdmissionPolicy::Reject { max_backlog: 2 });
        assert_eq!(engine.backlog(), 0);
        // paused jobs stay live forever, making the test deterministic
        let s1 = engine.submit(quick_cfg(k).with_start_paused(true), &train).unwrap();
        let s2 = engine.submit(quick_cfg(k).with_start_paused(true), &train).unwrap();
        assert_eq!(engine.backlog(), 2);
        let err = engine.submit(quick_cfg(k), &train).unwrap_err();
        match err.downcast_ref::<SubmitError>() {
            Some(SubmitError::BacklogFull { backlog, max_backlog }) => {
                assert_eq!((*backlog, *max_backlog), (2, 2));
            }
            other => panic!("expected BacklogFull, got {other:?} ({err:#})"),
        }
        // a rejected submit must leave no pool/registry residue behind
        assert_eq!(engine.jobs().len(), 2);

        // once a job settles, the next submit is admitted again
        s1.resume();
        s1.wait().unwrap().into_result().unwrap();
        let s3 = engine.submit(quick_cfg(k), &train).unwrap();
        s3.wait().unwrap().into_result().unwrap();
        s2.resume();
        s2.wait().unwrap().into_result().unwrap();
    }

    #[test]
    fn block_admission_applies_backpressure_until_a_job_settles() {
        let (train, _, k) = dataset();
        let engine = Arc::new(
            Engine::new(&BackendSpec::Native, 2)
                .with_admission(AdmissionPolicy::Block { max_backlog: 1 }),
        );
        let first = engine.submit(quick_cfg(k), &train).unwrap();
        // the second submit must block until the first run settles — run
        // it on a helper thread and watch the ordering
        let submitted = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let (engine2, train2, flag) = (engine.clone(), train.clone(), submitted.clone());
        let helper = std::thread::spawn(move || {
            let s = engine2.submit(quick_cfg(k).with_seed(91), &train2).unwrap();
            flag.store(true, std::sync::atomic::Ordering::SeqCst);
            s.wait().unwrap().into_result().unwrap()
        });
        // while the first job is live the helper stays held
        std::thread::sleep(std::time::Duration::from_millis(30));
        if !first.status().is_terminal() {
            assert!(
                !submitted.load(std::sync::atomic::Ordering::SeqCst),
                "Block admission let a second job in past the bound"
            );
        }
        first.wait().unwrap().into_result().unwrap();
        let r = helper.join().unwrap();
        assert_eq!(r.stats.blocks, 4);
        assert!(submitted.load(std::sync::atomic::Ordering::SeqCst));
    }

    #[test]
    fn queue_wait_measures_real_dispatch_delay() {
        // deterministic probe of the fairness metric: a paused submission
        // cannot dispatch its first task until resumed, so its recorded
        // queue wait must cover the pause — and an uncontended run on the
        // same engine must wait strictly less. A stamping regression
        // (wait always 0) or a gate that stops holding paused jobs both
        // fail this.
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let held = engine
            .submit(quick_cfg(k).with_start_paused(true).with_seed(93), &train)
            .unwrap();
        std::thread::sleep(std::time::Duration::from_millis(200));
        held.resume();
        let r_held = held.wait().unwrap().into_result().unwrap();
        // generous slack below the 200ms pause for slow thread spawn
        assert!(
            r_held.stats.queue_wait_secs >= 0.05,
            "paused job reported queue wait {}s",
            r_held.stats.queue_wait_secs
        );
        assert!(r_held.stats.queue_wait_secs < 60.0);

        let r_free = engine.train(&quick_cfg(k).with_seed(94), &train).unwrap();
        assert!(
            r_free.stats.queue_wait_secs < r_held.stats.queue_wait_secs,
            "uncontended wait {}s not below held wait {}s",
            r_free.stats.queue_wait_secs,
            r_held.stats.queue_wait_secs
        );
    }

    #[test]
    fn train_observed_delivers_callback_events() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let count = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let c = count.clone();
        let res = engine
            .train_observed(&quick_cfg(k), &train, move |_e| {
                c.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            })
            .unwrap();
        assert!(res.rmse(&train).is_finite());
        assert!(count.load(std::sync::atomic::Ordering::Relaxed) > 4);
    }

    #[test]
    fn factorizer_runs_pp_on_engine() {
        let (train, test, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let out = PpFactorizer(quick_cfg(k)).fit(&engine, &train).unwrap();
        assert_eq!(out.method, "pp");
        assert!(out.model.rmse(&test).is_finite());
        assert!(out.pp_stats.is_some());
    }
}
