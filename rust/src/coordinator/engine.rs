//! The training engine: a warm, shareable compute context for many runs.
//!
//! [`Engine`] owns the persistent [`WorkerPool`] (and with it, under the
//! `pjrt` feature, each worker thread's PJRT client and compiled-artifact
//! cache) so that many training jobs — repeated benches, learning curves,
//! cross-validation folds, back-to-back CLI runs — execute on the same hot
//! threads instead of re-spawning and re-compiling per call.
//!
//! Three ways to run a job:
//!
//! - [`Engine::train`] — blocking, no events: the plain replacement for the
//!   old `PpTrainer::train`.
//! - [`Engine::train_observed`] — blocking, with a callback receiving
//!   typed [`TrainEvent`]s as the schedule executes.
//! - [`Engine::submit`] — returns a [`Session`] handle immediately; the run
//!   proceeds on a background thread and streams [`TrainEvent`]s through a
//!   channel ([`Session::events`]), with [`Session::wait`] yielding the
//!   final [`TrainResult`].
//!
//! The [`Factorizer`] trait unifies PP and the baseline comparators behind
//! `fit(&Engine, &Coo)`, so sweeping methods (or cross-validating one) is a
//! loop over fits on one warm engine.

use super::config::{BackendSpec, TrainConfig};
use super::scheduler::WorkerPool;
use super::trainer::{center, run_pp, run_pp_centered, PhaseTimings, RunStats, TrainResult};
use crate::data::sparse::Coo;
use crate::posterior::PosteriorModel;
use std::fmt;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;

/// One of the four stages of the PP pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PpPhase {
    /// Block (0,0), fresh priors both sides.
    A,
    /// First-row / first-column blocks consuming the phase-(a) posterior.
    B,
    /// Interior blocks consuming two phase-(b) posteriors.
    C,
    /// Posterior aggregation parts.
    Aggregate,
}

impl fmt::Display for PpPhase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PpPhase::A => "a",
            PpPhase::B => "b",
            PpPhase::C => "c",
            PpPhase::Aggregate => "aggregate",
        })
    }
}

/// Which factor side of a block a pipelined chunk belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FactorSide {
    /// The row side (users / compounds / …).
    U,
    /// The column side (items / targets / …).
    V,
}

impl fmt::Display for FactorSide {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            FactorSide::U => "U",
            FactorSide::V => "V",
        })
    }
}

/// Typed progress events streamed while a training run executes. Emitted
/// from worker threads the moment the underlying work happens, so a
/// consumer (CLI, recorder, bench) observes the run live, not post-hoc.
#[derive(Debug, Clone)]
pub enum TrainEvent {
    /// First task of `phase` started executing.
    PhaseStarted {
        /// The PP phase that just started.
        phase: PpPhase,
    },
    /// Block `node` = (i, j) of the grid finished its MCMC.
    BlockCompleted {
        /// Grid coordinates of the block.
        node: (usize, usize),
        /// The PP phase the block belongs to.
        phase: PpPhase,
        /// Wall-clock seconds the block's MCMC took.
        secs: f64,
        /// Total Gibbs sweeps the block ran (burn-in + retained).
        sweeps: usize,
    },
    /// One retained Gibbs sweep on block `node`: training-data RMSE of the
    /// current factor sample (mean-centred scale) — the live mixing signal.
    SweepSample {
        /// Grid coordinates of the block.
        node: (usize, usize),
        /// Sweep index within the block (burn-in sweeps included).
        sweep: usize,
        /// Block training RMSE of the current factor sample.
        rmse: f64,
    },
    /// One chunk of a pipelined half-sweep was published to the block's
    /// [`FactorMailbox`](super::mailbox::FactorMailbox) — the within-block
    /// exchange overlapping computation. Emitted only under
    /// [`SweepMode::Pipelined`](super::config::SweepMode::Pipelined).
    ChunkExchanged {
        /// Grid coordinates of the block.
        node: (usize, usize),
        /// Factor side the chunk belongs to.
        side: FactorSide,
        /// Sweep index within the block.
        sweep: usize,
        /// Chunk index within the side.
        chunk: usize,
        /// Writer sequence number: publications of this side's half-sweep
        /// so far, this one included (1-based).
        seq: u64,
    },
    /// The whole schedule (all blocks + aggregation) completed.
    Finished {
        /// Wall-clock seconds of the full run.
        secs: f64,
        /// Number of blocks sampled.
        blocks: usize,
    },
}

/// Where events go: any thread-safe callback. `Engine::submit` wires this
/// to a channel; `Engine::train_observed` passes the caller's closure.
pub type EventSink = Arc<dyn Fn(TrainEvent) + Send + Sync>;

/// A persistent training engine: owns the worker pool, accepts many jobs.
///
/// Dropping the engine drains and joins the pool threads.
pub struct Engine {
    pool: Arc<WorkerPool>,
    spec: BackendSpec,
}

impl Engine {
    /// Spawn an engine with `threads` pool workers, each constructing its
    /// own backend from `spec` (backend errors surface on the first job).
    pub fn new(spec: &BackendSpec, threads: usize) -> Engine {
        Engine { pool: Arc::new(WorkerPool::new(spec, threads)), spec: spec.clone() }
    }

    /// Engine over the default auto-resolved backend with the default
    /// block parallelism (same heuristics as [`TrainConfig::new`]).
    pub fn auto() -> Engine {
        let cfg = TrainConfig::new(1);
        Engine::new(&cfg.backend, cfg.block_parallelism)
    }

    /// The backend spec the pool workers were constructed from.
    pub fn backend(&self) -> &BackendSpec {
        &self.spec
    }

    /// Number of worker threads (parallel block slots).
    pub fn threads(&self) -> usize {
        self.pool.threads
    }

    /// The underlying pool, for callers that schedule raw phases/DAGs.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Run one training job to completion on the warm pool (no events).
    pub fn train(&self, cfg: &TrainConfig, train: &Coo) -> anyhow::Result<TrainResult> {
        run_pp(cfg, &self.pool, train, None)
    }

    /// Run one training job to completion, delivering every [`TrainEvent`]
    /// to `on_event` as it happens (called from worker threads).
    pub fn train_observed(
        &self,
        cfg: &TrainConfig,
        train: &Coo,
        on_event: impl Fn(TrainEvent) + Send + Sync + 'static,
    ) -> anyhow::Result<TrainResult> {
        run_pp(cfg, &self.pool, train, Some(Arc::new(on_event)))
    }

    /// Validate `cfg` against `train`, then start the run on a background
    /// thread against this engine's warm pool. Returns immediately with a
    /// [`Session`] streaming the run's events.
    pub fn submit(&self, cfg: TrainConfig, train: &Coo) -> anyhow::Result<Session> {
        cfg.validate(train.rows, train.cols)?;
        let (tx, rx) = channel::<TrainEvent>();
        let pool = self.pool.clone();
        // the session's single private copy of the data, centred during
        // the one unavoidable clone
        let (centered, global_mean) = center(train);
        let handle = std::thread::spawn(move || {
            let sink: EventSink = Arc::new(move |e| {
                // a dropped receiver just means nobody is watching
                let _ = tx.send(e);
            });
            run_pp_centered(&cfg, &pool, centered, global_mean, Some(sink))
        });
        Ok(Session { rx, handle })
    }
}

/// Handle to one in-flight training run submitted to an [`Engine`].
///
/// Events arrive on an unbounded channel, so a slow (or absent) consumer
/// never stalls training. The channel closes when the run finishes; after
/// that [`Session::wait`] returns the result.
pub struct Session {
    rx: Receiver<TrainEvent>,
    handle: std::thread::JoinHandle<anyhow::Result<TrainResult>>,
}

impl Session {
    /// Block for the next event; `None` once the run is over and the
    /// stream is drained.
    pub fn next_event(&self) -> Option<TrainEvent> {
        self.rx.recv().ok()
    }

    /// Non-blocking poll for an event.
    pub fn try_event(&self) -> Option<TrainEvent> {
        self.rx.try_recv().ok()
    }

    /// Iterate events until the run completes (the iterator is the live
    /// progress stream; it ends when training stops emitting).
    pub fn events(&self) -> impl Iterator<Item = TrainEvent> + '_ {
        std::iter::from_fn(move || self.rx.recv().ok())
    }

    /// Join the run and return its result (undelivered events are dropped).
    pub fn wait(self) -> anyhow::Result<TrainResult> {
        drop(self.rx);
        match self.handle.join() {
            Ok(res) => res,
            Err(_) => Err(anyhow::anyhow!("training thread panicked")),
        }
    }
}

/// A matrix-factorization method that can be fitted on an [`Engine`].
///
/// PP trains on the engine's pool; the SGD/ALS/CGD/SGLD baselines manage
/// their own intra-method threading and take the engine for interface
/// uniformity — either way, `fit` returns one servable [`PosteriorModel`]
/// so downstream evaluation code is method-agnostic.
pub trait Factorizer {
    /// Short method name ("pp", "nomad", …) for tables and logs.
    fn name(&self) -> &str;

    /// Train on `data`, returning the fitted model plus diagnostics.
    fn fit(&self, engine: &Engine, data: &Coo) -> anyhow::Result<FitOutcome>;
}

/// What a [`Factorizer`] fit produces: the servable model plus run
/// diagnostics (PP-specific scheduling stats when available).
pub struct FitOutcome {
    /// Short method name ("pp", "nomad", …).
    pub method: String,
    /// The servable model the fit produced.
    pub model: PosteriorModel,
    /// Wall-clock seconds of the fit.
    pub secs: f64,
    /// Phase timings + scheduling stats — `Some` only for PP runs.
    pub pp_stats: Option<(PhaseTimings, RunStats)>,
}

/// Posterior Propagation as a [`Factorizer`].
pub struct PpFactorizer(
    /// The PP training configuration each fit runs with.
    pub TrainConfig,
);

impl Factorizer for PpFactorizer {
    fn name(&self) -> &str {
        "pp"
    }

    fn fit(&self, engine: &Engine, data: &Coo) -> anyhow::Result<FitOutcome> {
        let t0 = std::time::Instant::now();
        let res = engine.train(&self.0, data)?;
        Ok(FitOutcome {
            method: "pp".to_string(),
            secs: t0.elapsed().as_secs_f64(),
            pp_stats: Some((res.timings, res.stats)),
            model: res.model,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::BlockBackend;
    use crate::coordinator::config::ConfigError;
    use crate::coordinator::PpTrainer;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use std::collections::HashSet;
    use std::thread::ThreadId;

    fn dataset() -> (Coo, Coo, usize) {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 31).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 32);
        (train, test, d.k)
    }

    fn quick_cfg(k: usize) -> TrainConfig {
        TrainConfig::new(k)
            .with_backend(BackendSpec::Native)
            .with_grid(2, 2)
            .with_sweeps(4, 8)
            .with_seed(33)
    }

    /// Thread ids of pool workers observed while running a saturating batch.
    fn worker_ids(pool: &WorkerPool) -> HashSet<ThreadId> {
        let tasks: Vec<_> = (0..pool.threads * 4)
            .map(|_| {
                move |_b: &BlockBackend| -> anyhow::Result<ThreadId> {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    Ok(std::thread::current().id())
                }
            })
            .collect();
        pool.run_phase(tasks).unwrap().into_iter().collect()
    }

    #[test]
    fn sequential_sessions_match_fresh_trainers_on_one_warm_pool() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 3);
        let ids_before = worker_ids(engine.pool());

        let r1 = engine.submit(quick_cfg(k), &train).unwrap().wait().unwrap();
        let r2 = engine.submit(quick_cfg(k), &train).unwrap().wait().unwrap();
        // the warm pool must not change the math: both sessions equal a
        // fresh one-shot trainer bit for bit
        let fresh = PpTrainer::new(quick_cfg(k)).train(&train).unwrap();
        assert_eq!(r1.u_post.mean, fresh.u_post.mean);
        assert_eq!(r1.v_post.prec, fresh.v_post.prec);
        assert_eq!(r1.u_mean, r2.u_mean);
        assert_eq!(r1.v_mean, r2.v_mean);

        // and it must actually be the same pool: no threads re-spawned
        let ids_after = worker_ids(engine.pool());
        assert!(
            ids_after.is_subset(&ids_before),
            "pool threads changed: {ids_before:?} -> {ids_after:?}"
        );
    }

    #[test]
    fn session_streams_typed_events() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let session = engine.submit(quick_cfg(k), &train).unwrap();
        let events: Vec<TrainEvent> = session.events().collect();
        let result = session.wait().unwrap();

        // phase (a) starts before anything else
        assert!(matches!(events[0], TrainEvent::PhaseStarted { phase: PpPhase::A }));
        let blocks = events
            .iter()
            .filter(|e| matches!(e, TrainEvent::BlockCompleted { .. }))
            .count();
        assert_eq!(blocks, result.stats.blocks);
        assert_eq!(blocks, 4, "2x2 grid");
        // per-sweep samples stream from inside the blocks
        assert!(events.iter().any(|e| matches!(
            e,
            TrainEvent::SweepSample { rmse, .. } if rmse.is_finite()
        )));
        // aggregation is part of the stream, and the run closes with Finished
        assert!(events
            .iter()
            .any(|e| matches!(e, TrainEvent::PhaseStarted { phase: PpPhase::Aggregate })));
        assert!(matches!(events.last(), Some(TrainEvent::Finished { .. })));
    }

    #[test]
    fn submit_validates_config_before_spawning() {
        let (train, _, _) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let err = engine.submit(quick_cfg(0), &train).unwrap_err();
        assert_eq!(err.downcast_ref::<ConfigError>(), Some(&ConfigError::ZeroK));
        let err = engine.submit(quick_cfg(8).with_grid(train.rows + 1, 1), &train).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ConfigError>(),
            Some(ConfigError::GridExceedsMatrix { .. })
        ));
    }

    #[test]
    fn train_observed_delivers_callback_events() {
        let (train, _, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let count = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let c = count.clone();
        let res = engine
            .train_observed(&quick_cfg(k), &train, move |_e| {
                c.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            })
            .unwrap();
        assert!(res.rmse(&train).is_finite());
        assert!(count.load(std::sync::atomic::Ordering::Relaxed) > 4);
    }

    #[test]
    fn factorizer_runs_pp_on_engine() {
        let (train, test, k) = dataset();
        let engine = Engine::new(&BackendSpec::Native, 2);
        let out = PpFactorizer(quick_cfg(k)).fit(&engine, &train).unwrap();
        assert_eq!(out.method, "pp");
        assert!(out.model.rmse(&test).is_finite());
        assert!(out.pp_stats.is_some());
    }
}
