//! Double-buffered, chunk-granular factor exchange for pipelined sweeps.
//!
//! A [`FactorMailbox`] holds one side's factor matrix (n × k, f32) as the
//! shared medium of the GASPI-style pipelined half-sweep: writers publish
//! freshly sampled row *chunks* the moment they finish them, readers pull
//! the opposite side either as a clean previous-sweep snapshot or as the
//! freshest available state under a bounded staleness τ — the in-process
//! analogue of one-sided RDMA puts with per-chunk notifications.
//!
//! The buffer is doubled per epoch (one epoch = one half-sweep):
//!
//! - `prev` — the fully published values of the *previous* epoch. Immutable
//!   for the whole current epoch, so readers that need the classic Gibbs
//!   dependency (side A of sweep *s* conditions on side B of sweep *s−1*)
//!   read it lock-free via [`FactorMailbox::prev`].
//! - `cur` — per-chunk buffers the current epoch's writers fill. Each
//!   chunk carries a sequence number (the epoch that last published it),
//!   so a reader can tell fresh chunks from stale ones.
//!
//! [`FactorMailbox::assemble_latest`] is the stale-bounded read: it blocks
//! until at most τ chunks of the current epoch are unpublished, then
//! assembles fresh chunks from `cur` and substitutes `prev` for the (≤ τ)
//! rest. Every stale substitution is counted, and the observed maximum
//! staleness is recorded, so tests can audit that no read ever exceeded
//! the configured bound.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Audit counters a mailbox accumulates across all epochs, read after a
/// run to verify the staleness contract (every read within τ chunks of
/// the writers' sequence number) actually held.
#[derive(Debug, Clone, Copy, Default)]
pub struct MailboxCounters {
    /// Total chunk publications across all epochs.
    pub publishes: u64,
    /// Chunks served from the previous epoch during a stale-bounded read.
    pub stale_chunk_reads: u64,
    /// Largest number of unpublished chunks any single read proceeded
    /// with — by construction never above the configured staleness bound.
    pub max_staleness: u64,
}

/// Publication progress of the current epoch, guarded by one mutex so the
/// gate in [`FactorMailbox::assemble_latest`] can wait on it.
struct Progress {
    /// Chunks published in the current epoch.
    published: usize,
    /// When the last chunk of the current epoch was published.
    completed_at: Option<Instant>,
}

/// One factor side's double-buffered, chunked exchange medium.
pub struct FactorMailbox {
    n: usize,
    k: usize,
    chunk_rows: usize,
    chunks: usize,
    /// Previous epoch's fully published factors; immutable during an
    /// epoch (only [`FactorMailbox::begin_epoch`], which needs `&mut
    /// self`, replaces it).
    prev: Vec<f32>,
    /// Current epoch's factors, one lock per chunk so writers of disjoint
    /// chunks never contend.
    cur: Vec<Mutex<Vec<f32>>>,
    /// Per-chunk sequence number: the epoch that last published the chunk.
    chunk_seq: Vec<AtomicU64>,
    /// Current epoch (starts at 0; the first [`FactorMailbox::begin_epoch`]
    /// moves it to 1, so seeded chunks are "previous" from the start).
    epoch: AtomicU64,
    progress: Mutex<Progress>,
    advanced: Condvar,
    publishes: AtomicU64,
    stale_chunk_reads: AtomicU64,
    max_staleness: AtomicU64,
}

impl FactorMailbox {
    /// Mailbox for an `n` × `k` factor side cut into chunks of
    /// `chunk_rows` rows, seeded so that the first epoch's readers see
    /// `init` as the previous-sweep state.
    pub fn new(n: usize, k: usize, chunk_rows: usize, init: &[f32]) -> FactorMailbox {
        assert!(chunk_rows > 0, "chunk_rows must be > 0");
        assert_eq!(init.len(), n * k, "init factor length");
        let chunks = n.div_ceil(chunk_rows);
        let cur = (0..chunks)
            .map(|c| {
                let a = c * chunk_rows;
                let b = ((c + 1) * chunk_rows).min(n);
                Mutex::new(init[a * k..b * k].to_vec())
            })
            .collect();
        FactorMailbox {
            n,
            k,
            chunk_rows,
            chunks,
            prev: init.to_vec(),
            cur,
            chunk_seq: (0..chunks).map(|_| AtomicU64::new(0)).collect(),
            epoch: AtomicU64::new(0),
            progress: Mutex::new(Progress { published: chunks, completed_at: None }),
            advanced: Condvar::new(),
            publishes: AtomicU64::new(0),
            stale_chunk_reads: AtomicU64::new(0),
            max_staleness: AtomicU64::new(0),
        }
    }

    /// Number of chunks the side is cut into.
    pub fn chunks(&self) -> usize {
        self.chunks
    }

    /// Configured rows per chunk (the last chunk may be shorter).
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// Total `n * k` length of one factor buffer.
    pub fn len(&self) -> usize {
        self.n * self.k
    }

    /// True when the side holds no rows (a degenerate empty block).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Global row range `[start, end)` of chunk `c`.
    pub fn chunk_span(&self, c: usize) -> (usize, usize) {
        let a = c * self.chunk_rows;
        (a, ((c + 1) * self.chunk_rows).min(self.n))
    }

    /// Start the next epoch (half-sweep): the chunks published in the
    /// epoch that just ended become the new `prev` snapshot and the
    /// publication count resets. Takes `&mut self`, so an epoch can only
    /// roll over while no reader or writer holds the mailbox.
    pub fn begin_epoch(&mut self) {
        let k = self.k;
        for c in 0..self.chunks {
            let (a, b) = self.chunk_span(c);
            let buf = self.cur[c].get_mut().expect("mailbox chunk lock poisoned");
            self.prev[a * k..b * k].copy_from_slice(buf);
        }
        let progress = self.progress.get_mut().expect("mailbox progress lock poisoned");
        progress.published = 0;
        progress.completed_at = if self.chunks == 0 { Some(Instant::now()) } else { None };
        *self.epoch.get_mut() += 1;
    }

    /// The previous epoch's fully published factors — the classic Gibbs
    /// dependency (this half-sweep conditions on the opposite side's
    /// previous state). Lock-free: `prev` is immutable during an epoch.
    pub fn prev(&self) -> &[f32] {
        &self.prev
    }

    /// Publish chunk `c` of the current epoch and wake any reader waiting
    /// at the staleness gate. Returns the writer's sequence number: how
    /// many chunks of this epoch are published after this one (1-based).
    pub fn publish(&self, c: usize, data: &[f32]) -> u64 {
        let (a, b) = self.chunk_span(c);
        assert_eq!(data.len(), (b - a) * self.k, "chunk {c} data length");
        {
            let mut buf = self.cur[c].lock().expect("mailbox chunk lock poisoned");
            buf.copy_from_slice(data);
        }
        self.chunk_seq[c].store(self.epoch.load(Ordering::Relaxed), Ordering::Release);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        let mut progress = self.progress.lock().expect("mailbox progress lock poisoned");
        progress.published += 1;
        let seq = progress.published as u64;
        if progress.published == self.chunks {
            progress.completed_at = Some(Instant::now());
        }
        self.advanced.notify_all();
        seq
    }

    /// The staleness gate alone: block until at most `max_stale` chunks
    /// of the current epoch are unpublished. Publication only grows
    /// within an epoch, so a subsequent [`FactorMailbox::assemble_latest`]
    /// with the same bound returns without waiting.
    pub fn wait_within(&self, max_stale: usize) {
        let mut progress = self.progress.lock().expect("mailbox progress lock poisoned");
        while self.chunks - progress.published > max_stale {
            progress = self
                .advanced
                .wait(progress)
                .expect("mailbox progress lock poisoned");
        }
    }

    /// Stale-bounded read: block until at most `max_stale` chunks of the
    /// current epoch are unpublished, then copy the freshest state into
    /// `dst` — published chunks from the current epoch, the previous
    /// epoch's values for the rest. Returns the number of stale chunks
    /// substituted (≤ `max_stale`); audit totals land in
    /// [`FactorMailbox::counters`].
    pub fn assemble_latest(&self, dst: &mut [f32], max_stale: usize) -> usize {
        assert_eq!(dst.len(), self.n * self.k, "destination length");
        self.wait_within(max_stale);
        let epoch = self.epoch.load(Ordering::Relaxed);
        let k = self.k;
        let mut stale = 0usize;
        for c in 0..self.chunks {
            let (a, b) = self.chunk_span(c);
            if self.chunk_seq[c].load(Ordering::Acquire) == epoch {
                let buf = self.cur[c].lock().expect("mailbox chunk lock poisoned");
                dst[a * k..b * k].copy_from_slice(&buf);
            } else {
                dst[a * k..b * k].copy_from_slice(&self.prev[a * k..b * k]);
                stale += 1;
            }
        }
        if stale > 0 {
            self.stale_chunk_reads.fetch_add(stale as u64, Ordering::Relaxed);
            self.max_staleness.fetch_max(stale as u64, Ordering::Relaxed);
        }
        stale
    }

    /// When the current epoch's last chunk was published; `None` while
    /// the epoch is still incomplete.
    pub fn completed_at(&self) -> Option<Instant> {
        self.progress.lock().expect("mailbox progress lock poisoned").completed_at
    }

    /// Accumulated audit counters.
    pub fn counters(&self) -> MailboxCounters {
        MailboxCounters {
            publishes: self.publishes.load(Ordering::Relaxed),
            stale_chunk_reads: self.stale_chunk_reads.load(Ordering::Relaxed),
            max_staleness: self.max_staleness.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seeded(n: usize, k: usize, chunk_rows: usize, fill: f32) -> FactorMailbox {
        FactorMailbox::new(n, k, chunk_rows, &vec![fill; n * k])
    }

    #[test]
    fn chunk_spans_cover_rows() {
        let m = seeded(10, 2, 4, 0.0);
        assert_eq!(m.chunks(), 3);
        assert_eq!(m.chunk_span(0), (0, 4));
        assert_eq!(m.chunk_span(1), (4, 8));
        assert_eq!(m.chunk_span(2), (8, 10));
    }

    #[test]
    fn publish_then_assemble_is_fresh() {
        let mut m = seeded(4, 2, 2, 0.0);
        m.begin_epoch();
        assert_eq!(m.publish(0, &[1.0; 4]), 1);
        assert_eq!(m.publish(1, &[2.0; 4]), 2);
        let mut dst = vec![0.0f32; 8];
        let stale = m.assemble_latest(&mut dst, 0);
        assert_eq!(stale, 0);
        assert_eq!(&dst[..4], &[1.0; 4]);
        assert_eq!(&dst[4..], &[2.0; 4]);
        assert_eq!(m.counters().stale_chunk_reads, 0);
        assert_eq!(m.counters().publishes, 2);
    }

    #[test]
    fn stale_read_substitutes_previous_epoch_within_bound() {
        let mut m = seeded(4, 1, 2, 7.0);
        // epoch 1: fully published with distinct values
        m.begin_epoch();
        m.publish(0, &[1.0, 1.0]);
        m.publish(1, &[2.0, 2.0]);
        // epoch 2: only chunk 0 published
        m.begin_epoch();
        m.publish(0, &[10.0, 10.0]);
        let mut dst = vec![0.0f32; 4];
        let stale = m.assemble_latest(&mut dst, 1);
        assert_eq!(stale, 1);
        // fresh chunk 0, epoch-1 values for chunk 1 (never the seed 7.0)
        assert_eq!(dst, vec![10.0, 10.0, 2.0, 2.0]);
        let c = m.counters();
        assert_eq!(c.stale_chunk_reads, 1);
        assert_eq!(c.max_staleness, 1);
    }

    #[test]
    fn prev_holds_last_completed_epoch() {
        let mut m = seeded(2, 1, 1, 5.0);
        assert_eq!(m.prev(), &[5.0, 5.0]);
        m.begin_epoch();
        assert_eq!(m.prev(), &[5.0, 5.0], "seed survives the first rollover");
        m.publish(0, &[1.0]);
        m.publish(1, &[2.0]);
        m.begin_epoch();
        assert_eq!(m.prev(), &[1.0, 2.0]);
        assert!(m.completed_at().is_none(), "new epoch not complete yet");
    }

    #[test]
    fn completion_time_recorded_when_last_chunk_lands() {
        let mut m = seeded(2, 1, 1, 0.0);
        m.begin_epoch();
        assert!(m.completed_at().is_none());
        m.publish(0, &[1.0]);
        assert!(m.completed_at().is_none());
        m.publish(1, &[2.0]);
        assert!(m.completed_at().is_some());
    }

    #[test]
    fn gate_blocks_until_within_staleness_bound() {
        // a writer thread publishes with a delay; a tau=0 reader must
        // observe the complete epoch despite starting first
        let mut m = seeded(8, 1, 2, 0.0);
        m.begin_epoch();
        let m = std::sync::Arc::new(m);
        let writer = {
            let m = m.clone();
            std::thread::spawn(move || {
                for c in 0..m.chunks() {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    let (a, b) = m.chunk_span(c);
                    m.publish(c, &vec![c as f32 + 1.0; b - a]);
                }
            })
        };
        let mut dst = vec![0.0f32; 8];
        let stale = m.assemble_latest(&mut dst, 0);
        writer.join().unwrap();
        assert_eq!(stale, 0);
        assert_eq!(dst, vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 4.0, 4.0]);
    }

    #[test]
    fn empty_side_is_trivially_complete() {
        let mut m = FactorMailbox::new(0, 3, 4, &[]);
        assert!(m.is_empty());
        assert_eq!(m.chunks(), 0);
        m.begin_epoch();
        assert!(m.completed_at().is_some());
        let mut dst = Vec::new();
        assert_eq!(m.assemble_latest(&mut dst, 0), 0);
    }
}
