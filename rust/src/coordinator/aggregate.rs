//! Posterior aggregation (paper §2.2 final step; Qin et al. 2019 §3):
//! combine the subset posteriors from phases (a)-(c) and divide away the
//! multiply-counted propagated marginals.
//!
//! For a factor sub-matrix that was used as a prior by `m` downstream
//! blocks, the product of the m downstream posteriors counts that prior m
//! times while the true joint counts it once, so the aggregate is
//!
//!   q_agg = [ Π_{t=1..m} q_t ] / q_prior^{m-1}
//!
//! which in Gaussian natural parameters is
//!   prec_agg = Σ prec_t − (m−1)·prec_prior
//!   h_agg    = Σ prec_t μ_t − (m−1)·prec_prior μ_prior.

use crate::linalg::Cholesky;
use crate::posterior::RowGaussians;

/// One part (row-group or column-group) of the final posterior: `prior`
/// refined by the downstream `posts` that each consumed it once. With no
/// downstream posts the prior passes through unchanged (e.g. a 1-column
/// grid has no phase-(c) refinements of a row block).
///
/// This is the unit the DAG scheduler runs the moment a part's own inputs
/// complete — aggregation no longer waits for every block of the grid.
pub fn aggregate_part(
    prior: &RowGaussians,
    posts: &[&RowGaussians],
    ridge: f64,
) -> RowGaussians {
    if posts.is_empty() {
        prior.clone()
    } else {
        aggregate_rows(posts, Some(prior), ridge)
    }
}

/// Aggregate `posts` (≥1) that each consumed `prior` once.
/// `prior=None` is only valid for a single posterior (no division needed).
pub fn aggregate_rows(
    posts: &[&RowGaussians],
    prior: Option<&RowGaussians>,
    ridge: f64,
) -> RowGaussians {
    assert!(!posts.is_empty());
    let (n, k) = (posts[0].n, posts[0].k);
    for p in posts {
        assert_eq!((p.n, p.k), (n, k), "posterior shape mismatch");
    }
    if posts.len() == 1 && prior.is_none() {
        return posts[0].clone();
    }
    let m = posts.len() as f64;
    let prior = prior.expect("aggregating multiple posteriors requires the shared prior");
    assert_eq!((prior.n, prior.k), (n, k));

    let mut out = posts[0].clone();
    for i in 0..n {
        let mut sum_prec = posts[0].row_prec(i);
        let mut sum_h = posts[0].row_prec(i).matvec(posts[0].row_mean(i));
        for p in &posts[1..] {
            let pp = p.row_prec(i);
            sum_prec.add_scaled(&pp, 1.0);
            let hp = pp.matvec(p.row_mean(i));
            for (a, b) in sum_h.iter_mut().zip(hp) {
                *a += b;
            }
        }
        let prior_prec = prior.row_prec(i);
        let prior_h = prior_prec.matvec(prior.row_mean(i));

        // The exact correction subtracts (m-1)·prior. With finite-sample
        // posteriors the subtraction can lose positive-definiteness, and
        // forcing it SPD with a ridge yields wildly inconsistent means.
        // Instead scale the correction by the largest γ ∈ [0, 1] that
        // keeps the precision comfortably SPD — γ=1 is the exact PP
        // aggregate; γ→0 degrades smoothly to a product-of-experts.
        // SPD alone is not enough: a subtraction that leaves a near-zero
        // eigenvalue passes Cholesky but produces an exploding mean solve.
        // Require the smallest eigenvalue to clear a margin proportional
        // to the summed precision's scale.
        let margin = 0.02 * (0..k).map(|d| sum_prec[(d, d)]).sum::<f64>() / k as f64 + ridge;
        let attempt = |gamma: f64| -> Option<(crate::linalg::Mat, Cholesky)> {
            let mut prec = sum_prec.clone();
            prec.add_scaled(&prior_prec, -gamma * (m - 1.0));
            prec.symmetrize();
            // margin test: prec − margin·I must itself be SPD
            let mut test = prec.clone();
            for d in 0..k {
                test[(d, d)] -= margin;
            }
            Cholesky::new(&test).ok()?;
            for d in 0..k {
                prec[(d, d)] += ridge;
            }
            Cholesky::new(&prec).ok().map(|c| (prec, c))
        };
        let gamma = if attempt(1.0).is_some() {
            1.0
        } else {
            let (mut lo, mut hi) = (0.0f64, 1.0f64);
            for _ in 0..24 {
                let mid = 0.5 * (lo + hi);
                if attempt(mid).is_some() {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            0.9 * lo // safety margin inside the feasible region
        };
        // final fallback: even γ=0 can fail the *margin* test when the
        // summed posterior has a genuinely tiny eigenvalue — accept the
        // plain ridged sum there (no subtraction, no margin requirement)
        let (gamma, prec, chol) = match attempt(gamma) {
            Some((p, c)) => (gamma, p, c),
            None => {
                let mut p = sum_prec.clone();
                p.symmetrize();
                for d in 0..k {
                    p[(d, d)] += ridge + margin;
                }
                let c = Cholesky::new(&p).expect("ridged SPD sum");
                (0.0, p, c)
            }
        };
        // h uses the same γ so (prec, h) stay a consistent natural pair
        let mut h = sum_h.clone();
        for (a, b) in h.iter_mut().zip(&prior_h) {
            *a -= gamma * (m - 1.0) * b;
        }
        let mut mean = chol.solve(&h);
        // trust region: the aggregate mean cannot legitimately exceed the
        // largest input mean by much; if it does, the correction was still
        // ill-conditioned — fall back to the conservative γ=0 aggregate.
        let in_scale = posts
            .iter()
            .map(|p| p.row_mean(i).iter().fold(0.0f64, |a, &b| a.max(b.abs())))
            .fold(0.0f64, f64::max);
        let out_scale = mean.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        let (prec, mean) = if gamma > 0.0 && out_scale > 5.0 * in_scale + 1e-6 {
            let (prec0, chol0) = attempt(0.0).expect("sum of SPD posteriors is SPD");
            mean = chol0.solve(&sum_h);
            (prec0, mean)
        } else {
            (prec, mean)
        };
        out.mean[i * k..(i + 1) * k].copy_from_slice(&mean);
        out.prec[i * k * k..(i + 1) * k * k].copy_from_slice(&prec.data);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn gaussians(n: usize, k: usize, seed: u64) -> RowGaussians {
        let mut rng = Rng::seed_from_u64(seed);
        let mut g = RowGaussians::standard(n, k, 1.0);
        for i in 0..n {
            let mut a = Mat::zeros(k, k);
            for v in a.data.iter_mut() {
                *v = rng.uniform() - 0.5;
            }
            let mut spd = a.matmul(&a.transpose());
            for d in 0..k {
                spd[(d, d)] += 1.5;
            }
            let mean: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0 - 1.0).collect();
            g.mean[i * k..(i + 1) * k].copy_from_slice(&mean);
            g.prec[i * k * k..(i + 1) * k * k].copy_from_slice(&spd.data);
        }
        g
    }

    #[test]
    fn single_posterior_passthrough() {
        let p = gaussians(4, 3, 1);
        let agg = aggregate_rows(&[&p], None, 1e-6);
        assert_eq!(agg.mean, p.mean);
        assert_eq!(agg.prec, p.prec);
    }

    #[test]
    fn exact_gaussian_case_recovers_joint() {
        // Construct the exact conjugate situation: prior q0; two "data
        // likelihoods" L1, L2 as Gaussians. Posteriors q1 = q0·L1,
        // q2 = q0·L2 (computed by combine). True joint = q0·L1·L2.
        // aggregate([q1, q2], prior=q0) must equal the true joint.
        let q0 = gaussians(5, 3, 2);
        let l1 = gaussians(5, 3, 3);
        let l2 = gaussians(5, 3, 4);
        let q1 = q0.combine(&l1);
        let q2 = q0.combine(&l2);
        let truth = q0.combine(&l1).combine(&l2);
        let agg = aggregate_rows(&[&q1, &q2], Some(&q0), 1e-10);
        for i in 0..5 {
            assert!(
                agg.row_prec(i).max_abs_diff(&truth.row_prec(i)) < 1e-8,
                "prec row {i}"
            );
            for (a, b) in agg.row_mean(i).iter().zip(truth.row_mean(i)) {
                assert!((a - b).abs() < 1e-8, "mean row {i}");
            }
        }
    }

    #[test]
    fn three_way_aggregation() {
        let q0 = gaussians(3, 2, 5);
        let ls: Vec<RowGaussians> = (0..3).map(|t| gaussians(3, 2, 10 + t)).collect();
        let posts: Vec<RowGaussians> = ls.iter().map(|l| q0.combine(l)).collect();
        let mut truth = q0.clone();
        for l in &ls {
            truth = truth.combine(l);
        }
        let refs: Vec<&RowGaussians> = posts.iter().collect();
        let agg = aggregate_rows(&refs, Some(&q0), 1e-10);
        for i in 0..3 {
            assert!(agg.row_prec(i).max_abs_diff(&truth.row_prec(i)) < 1e-8);
        }
    }

    #[test]
    fn part_aggregation_matches_bulk() {
        let q0 = gaussians(4, 3, 8);
        let l1 = gaussians(4, 3, 9);
        let l2 = gaussians(4, 3, 10);
        let q1 = q0.combine(&l1);
        let q2 = q0.combine(&l2);
        let part = aggregate_part(&q0, &[&q1, &q2], 1e-10);
        let bulk = aggregate_rows(&[&q1, &q2], Some(&q0), 1e-10);
        assert_eq!(part.mean, bulk.mean);
        assert_eq!(part.prec, bulk.prec);
        // no downstream posts: the prior passes through untouched
        let passthrough = aggregate_part(&q0, &[], 1e-10);
        assert_eq!(passthrough.mean, q0.mean);
        assert_eq!(passthrough.prec, q0.prec);
    }

    #[test]
    #[should_panic]
    fn multiple_posts_without_prior_panics() {
        let a = gaussians(2, 2, 6);
        let b = gaussians(2, 2, 7);
        let _ = aggregate_rows(&[&a, &b], None, 1e-6);
    }
}
