//! Per-thread compute backend dispatch: AOT HLO runtime or native oracle.

use super::config::BackendSpec;
use crate::data::sparse::{Coo, Csr};
use crate::gibbs::native::sample_side_native;
use crate::posterior::RowGaussians;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;

/// A block's data in the layouts both backends want: COO (densify for HLO)
/// and CSR/CSC (native row iteration). Built once per block task.
///
/// `dense_cache` memoizes the densified+padded (ratings, mask) buffers per
/// (pad_n, pad_d, transpose) — they are constant across the block's Gibbs
/// sweeps, and re-scattering the COO every half-sweep showed up as a top-3
/// hot spot in the L3 profile (EXPERIMENTS.md §Perf).
pub struct BlockData {
    /// The block's ratings in coordinate form (the HLO densify source).
    pub coo: Coo,
    /// Row-major CSR for row-side half-sweeps.
    pub csr: Csr,
    /// Column-major (transposed CSR) for column-side half-sweeps.
    pub csr_t: Csr,
    dense_cache: std::sync::Mutex<
        std::collections::HashMap<(usize, usize, bool), std::sync::Arc<(Vec<f32>, Vec<f32>)>>,
    >,
}

impl BlockData {
    /// Build all layouts from the block's COO ratings.
    pub fn new(coo: Coo) -> BlockData {
        let csr = Csr::from_coo(&coo);
        let csr_t = csr.transpose();
        BlockData { coo, csr, csr_t, dense_cache: Default::default() }
    }

    /// Densified + padded (ratings, mask), memoized.
    pub fn dense_padded(
        &self,
        pad_n: usize,
        pad_d: usize,
        transpose: bool,
    ) -> std::sync::Arc<(Vec<f32>, Vec<f32>)> {
        self.dense_cache
            .lock()
            .unwrap()
            .entry((pad_n, pad_d, transpose))
            .or_insert_with(|| {
                std::sync::Arc::new(self.coo.to_dense_padded(pad_n, pad_d, transpose))
            })
            .clone()
    }

    /// Row count of the block.
    pub fn rows(&self) -> usize {
        self.coo.rows
    }

    /// Column count of the block.
    pub fn cols(&self) -> usize {
        self.coo.cols
    }
}

/// Thread-confined backend instance. The HLO/PJRT variant only exists in
/// builds with the `pjrt` feature (it needs the XLA system libraries).
pub enum BlockBackend {
    /// Pure-rust oracle sampler (also the plain-BMF baseline path).
    Native,
    /// AOT HLO artifacts through the thread-confined PJRT engine.
    #[cfg(feature = "pjrt")]
    Hlo(Engine),
}

impl BlockBackend {
    /// Instantiate from a spec — called once per worker thread.
    pub fn create(spec: &BackendSpec) -> anyhow::Result<BlockBackend> {
        match spec.resolve() {
            BackendSpec::Native => Ok(BlockBackend::Native),
            #[cfg(feature = "pjrt")]
            BackendSpec::Hlo { artifact_dir } => {
                Ok(BlockBackend::Hlo(Engine::new(&artifact_dir)?))
            }
            #[cfg(not(feature = "pjrt"))]
            BackendSpec::Hlo { .. } => anyhow::bail!(
                "HLO backend requested but this build has no PJRT support \
                 (rebuild with `--features pjrt`)"
            ),
            BackendSpec::Auto { .. } => unreachable!("resolve() removes Auto"),
        }
    }

    /// True when this backend executes through the PJRT/HLO runtime.
    pub fn is_hlo(&self) -> bool {
        #[cfg(feature = "pjrt")]
        if matches!(self, BlockBackend::Hlo(_)) {
            return true;
        }
        false
    }

    /// One conditional Gibbs half-sweep of a block side.
    /// `transpose=false` updates the row side, `true` the column side.
    pub fn sample_side(
        &self,
        data: &BlockData,
        transpose: bool,
        v: &[f32],
        prior: &RowGaussians,
        tau: f64,
        noise: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        match self {
            BlockBackend::Native => {
                let csr = if transpose { &data.csr_t } else { &data.csr };
                Ok(sample_side_native(csr, v, prior.k, prior, tau, noise)?)
            }
            #[cfg(feature = "pjrt")]
            BlockBackend::Hlo(engine) => {
                let (n_real, d_real) = if transpose {
                    (data.cols(), data.rows())
                } else {
                    (data.rows(), data.cols())
                };
                // graceful degradation: blocks no registered artifact shape
                // fits run through the native oracle (identical math) with
                // a warning, instead of failing the whole training run
                let (pn, pd) = match engine.fit_sample_shape(n_real, d_real, prior.k) {
                    Ok(shape) => shape,
                    Err(e) => {
                        log::warn!(
                            "no AOT artifact fits {n_real}x{d_real} k={}: {e}; \
                             using native sampler for this side",
                            prior.k
                        );
                        let csr = if transpose { &data.csr_t } else { &data.csr };
                        return Ok(sample_side_native(csr, v, prior.k, prior, tau, noise)?);
                    }
                };
                let dense = data.dense_padded(pn, pd, transpose);
                Ok(engine.sample_side_prepadded(
                    &dense.0,
                    &dense.1,
                    (pn, pd),
                    (n_real, d_real),
                    v,
                    prior,
                    tau as f32,
                    noise,
                )?)
            }
        }
    }

    /// SSE + count of factors against a test block.
    pub fn predict_sse(
        &self,
        u: &[f32],
        v: &[f32],
        k: usize,
        block: &Coo,
    ) -> anyhow::Result<(f64, f64)> {
        match self {
            BlockBackend::Native => {
                let mut sse = 0.0f64;
                for e in &block.entries {
                    let (r, c) = (e.row as usize, e.col as usize);
                    let pred: f32 = (0..k).map(|j| u[r * k + j] * v[c * k + j]).sum();
                    sse += ((pred - e.val) as f64).powi(2);
                }
                Ok((sse, block.nnz() as f64))
            }
            #[cfg(feature = "pjrt")]
            BlockBackend::Hlo(engine) => Ok(engine.predict_sse(u, v, k, block)?),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{normal::standard_normal_vec, Rng};

    fn tiny_block() -> BlockData {
        let mut coo = Coo::new(6, 5);
        coo.push(0, 0, 4.0);
        coo.push(1, 2, 3.0);
        coo.push(3, 4, 2.0);
        coo.push(5, 1, 5.0);
        BlockData::new(coo)
    }

    #[test]
    fn native_backend_works() {
        let data = tiny_block();
        let k = 4;
        let backend = BlockBackend::Native;
        let mut rng = Rng::seed_from_u64(1);
        let v = standard_normal_vec(&mut rng, data.cols() * k);
        let prior = RowGaussians::standard(data.rows(), k, 1.0);
        let noise = standard_normal_vec(&mut rng, data.rows() * k);
        let (s, m) = backend.sample_side(&data, false, &v, &prior, 1.0, &noise).unwrap();
        assert_eq!(s.len(), data.rows() * k);
        assert_eq!(m.len(), data.rows() * k);
        assert!(s.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn native_predict_counts_all_entries() {
        let data = tiny_block();
        let k = 2;
        let u = vec![0.1f32; data.rows() * k];
        let v = vec![0.1f32; data.cols() * k];
        let (_, cnt) = BlockBackend::Native.predict_sse(&u, &v, k, &data.coo).unwrap();
        assert_eq!(cnt as usize, data.coo.nnz());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn hlo_falls_back_to_native_when_no_artifact_fits() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        // 2000 columns exceeds every registered artifact's d
        let mut coo = Coo::new(8, 2000);
        coo.push(0, 0, 3.0);
        coo.push(7, 1999, 2.0);
        let data = BlockData::new(coo);
        let k = 8;
        let hlo = BlockBackend::create(&BackendSpec::Hlo { artifact_dir: dir }).unwrap();
        let mut rng = Rng::seed_from_u64(4);
        let v = standard_normal_vec(&mut rng, 2000 * k);
        let prior = RowGaussians::standard(8, k, 1.0);
        let noise = standard_normal_vec(&mut rng, 8 * k);
        let (s_h, _) = hlo.sample_side(&data, false, &v, &prior, 1.0, &noise).unwrap();
        let (s_n, _) =
            BlockBackend::Native.sample_side(&data, false, &v, &prior, 1.0, &noise).unwrap();
        assert_eq!(s_h, s_n, "fallback must be the native path exactly");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn backends_agree_when_artifacts_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let data = tiny_block();
        let k = 8;
        let hlo = BlockBackend::create(&BackendSpec::Hlo { artifact_dir: dir }).unwrap();
        let mut rng = Rng::seed_from_u64(3);
        let v = standard_normal_vec(&mut rng, data.cols() * k);
        let prior = RowGaussians::standard(data.rows(), k, 1.5);
        let noise = standard_normal_vec(&mut rng, data.rows() * k);
        let (s_h, _) = hlo.sample_side(&data, false, &v, &prior, 2.0, &noise).unwrap();
        let (s_n, _) =
            BlockBackend::Native.sample_side(&data, false, &v, &prior, 2.0, &noise).unwrap();
        for (a, b) in s_h.iter().zip(&s_n) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }
}
