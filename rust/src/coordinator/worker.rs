//! Within-block distributed Gibbs workers — the paper's inner parallelism
//! level (distributed BMF, Vander Aa et al. 2017).
//!
//! A block's factor rows are conditionally independent given the opposite
//! side, so a half-sweep shards rows across W workers. With the native
//! backend the shards run on real threads and their results are gathered
//! through channels (the in-process analogue of the paper's MPI allgather
//! exchange, Fig. 2). With the HLO backend shards execute through the
//! thread-confined PJRT engine sequentially — same semantics, and the
//! shard-shaped artifacts measure the padding/dispatch overhead that the
//! cluster simulator uses for multi-node projections.
//!
//! These shard threads are private to one block task and live only for
//! its half-sweeps; they are NOT the engine's pool workers. Under the
//! multi-tenant engine, block tasks from several concurrent sessions run
//! side by side on the pool, each spawning its own shard workers — total
//! thread pressure is `pool threads × TrainConfig::workers`, which is why
//! wide jobs are bounded with `TrainConfig::max_in_flight` rather than by
//! shrinking W.

use super::backend::{BlockBackend, BlockData};
use super::engine::FactorSide;
use super::mailbox::FactorMailbox;
use crate::data::sparse::Csr;
use crate::gibbs::native::{GibbsPrecision, RowSampler, SampleError};
use crate::posterior::RowGaussians;
use std::time::Instant;

/// Contiguous row-shard boundaries for `n` rows over `workers` shards.
pub fn shard_bounds(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let w = workers.clamp(1, n.max(1));
    let base = n / w;
    let extra = n % w;
    let mut out = Vec::with_capacity(w);
    let mut start = 0;
    for i in 0..w {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// One sharded conditional half-sweep of a block side.
///
/// Updates the `transpose`-selected side's factors given opposite-side
/// factors `v`, with per-row priors and injected noise; returns (samples,
/// conditional means) for the full side. `mode` selects the kernel's
/// floating-point regime on the native backend (the HLO backend has its
/// own fixed f32 arithmetic and ignores it). A non-SPD posterior
/// precision in any shard surfaces as a typed
/// [`SampleError`] (smallest failing
/// row wins, deterministically) instead of panicking the worker thread.
#[allow(clippy::too_many_arguments)]
pub fn sample_side_sharded(
    backend: &BlockBackend,
    data: &BlockData,
    transpose: bool,
    v: &[f32],
    prior: &RowGaussians,
    tau: f64,
    noise: &[f32],
    workers: usize,
    mode: GibbsPrecision,
) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
    let n = if transpose { data.cols() } else { data.rows() };
    let k = prior.k;
    if workers <= 1 || n < 2 * workers {
        if let BlockBackend::Native = backend {
            let csr: &Csr = if transpose { &data.csr_t } else { &data.csr };
            return Ok(RowSampler::new(k, mode).sample_side(csr, v, prior, tau, noise)?);
        }
        return backend.sample_side(data, transpose, v, prior, tau, noise);
    }
    let bounds = shard_bounds(n, workers);

    match backend {
        BlockBackend::Native => {
            let csr: &Csr = if transpose { &data.csr_t } else { &data.csr };
            let mut samples = vec![0.0f32; n * k];
            let mut means = vec![0.0f32; n * k];
            // scoped threads: each worker samples its shard through its
            // own arena, sends results over a channel; the leader gathers
            // (MPI-allgather analogue).
            let (tx, rx) = std::sync::mpsc::channel();
            let mut first_err: Option<SampleError> = None;
            crossbeam_utils::thread::scope(|scope| {
                for (widx, &(a, b)) in bounds.iter().enumerate() {
                    let tx = tx.clone();
                    let prior_shard = prior.slice(a, b);
                    let noise_shard = &noise[a * k..b * k];
                    let shard = csr.slice_rows(a, b);
                    scope.spawn(move |_| {
                        let res = RowSampler::new(k, mode)
                            .sample_side(&shard, v, &prior_shard, tau, noise_shard);
                        tx.send((widx, a, b, res)).expect("gather channel closed");
                    });
                }
                drop(tx);
                for (_widx, a, b, res) in rx.iter() {
                    match res {
                        Ok((s, m)) => {
                            samples[a * k..b * k].copy_from_slice(&s);
                            means[a * k..b * k].copy_from_slice(&m);
                        }
                        Err(e) => {
                            // remap the shard-local row to the side's
                            // global index; keep the smallest failing row
                            // so the reported error is schedule-invariant
                            let e = SampleError { row: e.row + a, source: e.source };
                            if first_err.as_ref().map_or(true, |f| e.row < f.row) {
                                first_err = Some(e);
                            }
                        }
                    }
                }
            })
            .expect("worker thread panicked");
            if let Some(e) = first_err {
                return Err(e.into());
            }
            Ok((samples, means))
        }
        #[cfg(feature = "pjrt")]
        BlockBackend::Hlo(engine) => {
            // sequential shard execution through the thread-confined engine
            let mut samples = vec![0.0f32; n * k];
            let mut means = vec![0.0f32; n * k];
            for &(a, b) in &bounds {
                let shard_coo = if transpose {
                    data.csr_t.slice_rows(a, b).to_coo()
                } else {
                    data.csr.slice_rows(a, b).to_coo()
                };
                let prior_shard = prior.slice(a, b);
                let (s, m) = engine.sample_side(
                    &shard_coo,
                    false,
                    v,
                    &prior_shard,
                    tau as f32,
                    &noise[a * k..b * k],
                )?;
                samples[a * k..b * k].copy_from_slice(&s);
                means[a * k..b * k].copy_from_slice(&m);
            }
            Ok((samples, means))
        }
    }
}

/// Observer of per-chunk publications inside a pipelined sweep, called
/// from worker threads: `(side, chunk index, writer sequence number)`.
pub type ChunkObs<'a> = Option<&'a (dyn Fn(FactorSide, usize, u64) + Sync)>;

/// One full pipelined Gibbs sweep (U half-sweep, then V half-sweep) over
/// a block, GASPI-style: the U side's rows are cut into mailbox chunks
/// and every finished chunk is published to the other shards immediately,
/// while the publishing worker keeps sampling its next chunk. Each
/// worker's V half-sweep starts as soon as at most `stale_bound` U chunks
/// are unpublished (reading the previous sweep's values for exactly those
/// chunks), so the factor exchange and the U-side tail overlap the V-side
/// compute instead of preceding it.
///
/// With `stale_bound == 0` every read waits for the complete U side, so
/// the sweep is bitwise identical to the lockstep schedule (rows only
/// ever see exactly the inputs lockstep gives them — same priors, same
/// injected noise, same opposite-side values).
///
/// Returns the seconds of V-side work (receiving the U snapshot +
/// sampling) that ran while the U side was still sampling/publishing —
/// the communication/computation overlap the lockstep schedule cannot
/// have.
///
/// A non-SPD posterior precision surfaces as a typed
/// [`SampleError`] instead of a panic. A
/// worker that fails mid-U-half-sweep first publishes zero-filled
/// buffers for its remaining U chunks — the peers' staleness gates and
/// the completion clock still resolve (no deadlock), their results are
/// discarded with the sweep, and the first failing worker's error (a
/// deterministic function of the data, priors, and worker assignment) is
/// returned.
#[allow(clippy::too_many_arguments)]
pub fn pipelined_sweep(
    data: &BlockData,
    k: usize,
    tau: f64,
    workers: usize,
    prior_u: &RowGaussians,
    prior_v: &RowGaussians,
    noise_u: &[f32],
    noise_v: &[f32],
    u_mail: &mut FactorMailbox,
    v_mail: &mut FactorMailbox,
    stale_bound: usize,
    chunk_obs: ChunkObs<'_>,
    mode: GibbsPrecision,
) -> Result<f64, SampleError> {
    u_mail.begin_epoch();
    v_mail.begin_epoch();
    let w = workers.max(1);
    // contiguous chunk ranges per worker (fewer entries than w when a
    // side has fewer chunks than workers; the extras idle on that side)
    let u_bounds = shard_bounds(u_mail.chunks(), w);
    let v_bounds = shard_bounds(v_mail.chunks(), w);
    let u_ref: &FactorMailbox = u_mail;
    let v_ref: &FactorMailbox = v_mail;
    let csr: &Csr = &data.csr;
    let csr_t: &Csr = &data.csr_t;

    let mut v_spans: Vec<Result<(Instant, Instant), SampleError>> = Vec::with_capacity(w);
    crossbeam_utils::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(w);
        for wi in 0..w {
            let ur = u_bounds.get(wi).copied().unwrap_or((0, 0));
            let vr = v_bounds.get(wi).copied().unwrap_or((0, 0));
            handles.push(scope.spawn(move |_| -> Result<(Instant, Instant), SampleError> {
                let chunk_cap = u_ref.chunk_rows().max(v_ref.chunk_rows()) * k;
                let mut samples = vec![0.0f32; chunk_cap];
                let mut means = vec![0.0f32; chunk_cap];
                // one arena per worker, reused across every chunk of both
                // half-sweeps — the per-row allocations the old kernel
                // paid are gone
                let mut sampler = RowSampler::new(k, mode);

                // ---- U half-sweep: publish every chunk as it finishes ----
                let v_prev = v_ref.prev();
                for c in ur.0..ur.1 {
                    let (a, b) = u_ref.chunk_span(c);
                    let len = (b - a) * k;
                    if let Err(e) = sampler.sample_rows_into(
                        csr,
                        a..b,
                        v_prev,
                        prior_u,
                        tau,
                        noise_u,
                        &mut samples[..len],
                        &mut means[..len],
                    ) {
                        // peers wait on U publication counts: publish
                        // zeros for this worker's remaining chunks so
                        // their gates open, then fail the sweep (all
                        // published values are discarded on error)
                        for cz in c..ur.1 {
                            let (az, bz) = u_ref.chunk_span(cz);
                            let lz = (bz - az) * k;
                            samples[..lz].fill(0.0);
                            u_ref.publish(cz, &samples[..lz]);
                        }
                        return Err(e);
                    }
                    let seq = u_ref.publish(c, &samples[..len]);
                    if let Some(f) = chunk_obs {
                        f(FactorSide::U, c, seq);
                    }
                }

                // ---- V half-sweep: stale-bounded read of the U side ----
                if vr.0 >= vr.1 {
                    let now = Instant::now();
                    return Ok((now, now));
                }
                // each worker assembles its own U snapshot — the
                // in-process stand-in for the per-node receive buffer a
                // real one-sided exchange fills (w copies of n·k f32 per
                // sweep; hoisting them across sweeps would need persistent
                // per-block workers). The overlap clock starts when the
                // staleness gate opens: receive/unpack + V sampling are
                // the work that runs while U publication completes.
                u_ref.wait_within(stale_bound);
                let started = Instant::now();
                let mut u_view = vec![0.0f32; u_ref.len()];
                u_ref.assemble_latest(&mut u_view, stale_bound);
                for c in vr.0..vr.1 {
                    let (a, b) = v_ref.chunk_span(c);
                    let len = (b - a) * k;
                    // a V-side failure needs no zero-fill: nothing waits
                    // on V publication within the failing sweep
                    sampler.sample_rows_into(
                        csr_t,
                        a..b,
                        &u_view,
                        prior_v,
                        tau,
                        noise_v,
                        &mut samples[..len],
                        &mut means[..len],
                    )?;
                    let seq = v_ref.publish(c, &samples[..len]);
                    if let Some(f) = chunk_obs {
                        f(FactorSide::V, c, seq);
                    }
                }
                Ok((started, Instant::now()))
            }));
        }
        for h in handles {
            v_spans.push(h.join().expect("pipelined worker panicked"));
        }
    })
    .expect("pipelined sweep scope");

    // first failing worker wins — worker assignment and the per-row math
    // are deterministic, so the surfaced error is too
    let mut spans = Vec::with_capacity(w);
    for r in v_spans {
        spans.push(r?);
    }

    // overlap: V-side compute that ran before the last U chunk landed
    let u_done = u_ref.completed_at().expect("U side fully published");
    Ok(spans
        .iter()
        .map(|&(start, end)| {
            let end = end.min(u_done);
            if end > start { end.duration_since(start).as_secs_f64() } else { 0.0 }
        })
        .sum())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Coo;
    use crate::gibbs::native::sample_side_native;
    use crate::rng::{normal::standard_normal_vec, Rng};

    #[test]
    fn shard_bounds_cover_and_balance() {
        for n in [1usize, 7, 16, 100] {
            for w in [1usize, 2, 3, 8] {
                let b = shard_bounds(n, w);
                assert_eq!(b[0].0, 0);
                assert_eq!(b.last().unwrap().1, n);
                for pair in b.windows(2) {
                    assert_eq!(pair[0].1, pair[1].0, "gap in shards");
                }
                let sizes: Vec<usize> = b.iter().map(|(a, c)| c - a).collect();
                let (mn, mx) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "unbalanced: {sizes:?}");
            }
        }
    }

    #[test]
    fn sharded_equals_unsharded_native() {
        let mut coo = Coo::new(40, 30);
        let mut rng = Rng::seed_from_u64(50);
        for _ in 0..300 {
            coo.push(rng.below(40), rng.below(30), (rng.uniform() * 4.0 + 1.0) as f32);
        }
        let data = BlockData::new(coo);
        let k = 4;
        let v = standard_normal_vec(&mut rng, 30 * k);
        let prior = RowGaussians::standard(40, k, 1.5);
        let noise = standard_normal_vec(&mut rng, 40 * k);
        let backend = BlockBackend::Native;
        let (s1, m1) = sample_side_sharded(
            &backend, &data, false, &v, &prior, 2.0, &noise, 1, GibbsPrecision::F64,
        )
        .unwrap();
        for w in [2usize, 3, 4] {
            let (s, m) = sample_side_sharded(
                &backend, &data, false, &v, &prior, 2.0, &noise, w, GibbsPrecision::F64,
            )
            .unwrap();
            // sharding must not change the math at all (same noise rows)
            for i in 0..s.len() {
                assert!((s[i] - s1[i]).abs() < 1e-5, "w={w} sample[{i}]");
                assert!((m[i] - m1[i]).abs() < 1e-5, "w={w} mean[{i}]");
            }
        }
    }

    #[test]
    fn pipelined_sweep_tau0_matches_lockstep_bitwise() {
        let mut coo = Coo::new(40, 30);
        let mut rng = Rng::seed_from_u64(52);
        for _ in 0..350 {
            coo.push(rng.below(40), rng.below(30), (rng.uniform() * 4.0 + 1.0) as f32);
        }
        let data = BlockData::new(coo);
        let k = 4;
        let u0 = standard_normal_vec(&mut rng, 40 * k);
        let v0 = standard_normal_vec(&mut rng, 30 * k);
        let prior_u = RowGaussians::standard(40, k, 1.5);
        let prior_v = RowGaussians::standard(30, k, 1.0);
        let noise_u = standard_normal_vec(&mut rng, 40 * k);
        let noise_v = standard_normal_vec(&mut rng, 30 * k);

        // lockstep reference: full U half-sweep, then full V half-sweep
        let (u1, _) =
            sample_side_native(&data.csr, &v0, k, &prior_u, 2.0, &noise_u).unwrap();
        let (v1, _) =
            sample_side_native(&data.csr_t, &u1, k, &prior_v, 2.0, &noise_v).unwrap();

        for workers in [1usize, 2, 3] {
            let mut u_mail = FactorMailbox::new(40, k, 7, &u0);
            let mut v_mail = FactorMailbox::new(30, k, 5, &v0);
            let overlap = pipelined_sweep(
                &data, k, 2.0, workers, &prior_u, &prior_v, &noise_u, &noise_v,
                &mut u_mail, &mut v_mail, 0, None, GibbsPrecision::F64,
            )
            .unwrap();
            assert!(overlap >= 0.0);
            let mut u = vec![0.0f32; 40 * k];
            let mut v = vec![0.0f32; 30 * k];
            u_mail.assemble_latest(&mut u, 0);
            v_mail.assemble_latest(&mut v, 0);
            assert_eq!(u, u1, "workers={workers}: U must equal lockstep bitwise");
            assert_eq!(v, v1, "workers={workers}: V must equal lockstep bitwise");
            // tau = 0 forbids stale reads entirely
            assert_eq!(u_mail.counters().stale_chunk_reads, 0);
            assert_eq!(u_mail.counters().max_staleness, 0);
        }
    }

    #[test]
    fn pipelined_sweep_publishes_every_chunk_once() {
        let mut coo = Coo::new(24, 18);
        let mut rng = Rng::seed_from_u64(53);
        for _ in 0..150 {
            coo.push(rng.below(24), rng.below(18), 3.0);
        }
        let data = BlockData::new(coo);
        let k = 3;
        let u0 = standard_normal_vec(&mut rng, 24 * k);
        let v0 = standard_normal_vec(&mut rng, 18 * k);
        let prior_u = RowGaussians::standard(24, k, 1.0);
        let prior_v = RowGaussians::standard(18, k, 1.0);
        let noise_u = standard_normal_vec(&mut rng, 24 * k);
        let noise_v = standard_normal_vec(&mut rng, 18 * k);
        let mut u_mail = FactorMailbox::new(24, k, 4, &u0);
        let mut v_mail = FactorMailbox::new(18, k, 4, &v0);
        let seen = std::sync::Mutex::new(Vec::<(FactorSide, usize, u64)>::new());
        let obs = |side: FactorSide, chunk: usize, seq: u64| {
            seen.lock().unwrap().push((side, chunk, seq));
        };
        pipelined_sweep(
            &data, k, 1.0, 2, &prior_u, &prior_v, &noise_u, &noise_v,
            &mut u_mail, &mut v_mail, 1, Some(&obs), GibbsPrecision::F64,
        )
        .unwrap();
        let seen = seen.into_inner().unwrap();
        let u_chunks: Vec<usize> =
            seen.iter().filter(|e| e.0 == FactorSide::U).map(|e| e.1).collect();
        let v_chunks: Vec<usize> =
            seen.iter().filter(|e| e.0 == FactorSide::V).map(|e| e.1).collect();
        assert_eq!(u_chunks.len(), u_mail.chunks(), "every U chunk published once");
        assert_eq!(v_chunks.len(), v_mail.chunks(), "every V chunk published once");
        // writer sequence numbers count publications 1..=chunks per side
        let mut u_seqs: Vec<u64> =
            seen.iter().filter(|e| e.0 == FactorSide::U).map(|e| e.2).collect();
        u_seqs.sort_unstable();
        assert_eq!(u_seqs, (1..=u_mail.chunks() as u64).collect::<Vec<_>>());
    }

    #[test]
    fn sharded_transposed_side() {
        let mut coo = Coo::new(20, 36);
        let mut rng = Rng::seed_from_u64(51);
        for _ in 0..200 {
            coo.push(rng.below(20), rng.below(36), 3.0);
        }
        let data = BlockData::new(coo);
        let k = 4;
        let u = standard_normal_vec(&mut rng, 20 * k);
        let prior = RowGaussians::standard(36, k, 1.0);
        let noise = standard_normal_vec(&mut rng, 36 * k);
        let backend = BlockBackend::Native;
        let (s1, _) = sample_side_sharded(
            &backend, &data, true, &u, &prior, 1.0, &noise, 1, GibbsPrecision::F64,
        )
        .unwrap();
        let (s3, _) = sample_side_sharded(
            &backend, &data, true, &u, &prior, 1.0, &noise, 3, GibbsPrecision::F64,
        )
        .unwrap();
        for i in 0..s1.len() {
            assert!((s1[i] - s3[i]).abs() < 1e-5);
        }
    }
}
