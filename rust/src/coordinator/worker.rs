//! Within-block distributed Gibbs workers — the paper's inner parallelism
//! level (distributed BMF, Vander Aa et al. 2017).
//!
//! A block's factor rows are conditionally independent given the opposite
//! side, so a half-sweep shards rows across W workers. With the native
//! backend the shards run on real threads and their results are gathered
//! through channels (the in-process analogue of the paper's MPI allgather
//! exchange, Fig. 2). With the HLO backend shards execute through the
//! thread-confined PJRT engine sequentially — same semantics, and the
//! shard-shaped artifacts measure the padding/dispatch overhead that the
//! cluster simulator uses for multi-node projections.

use super::backend::{BlockBackend, BlockData};
use crate::data::sparse::Csr;
use crate::gibbs::native::sample_side_native;
use crate::posterior::RowGaussians;

/// Contiguous row-shard boundaries for `n` rows over `workers` shards.
pub fn shard_bounds(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let w = workers.clamp(1, n.max(1));
    let base = n / w;
    let extra = n % w;
    let mut out = Vec::with_capacity(w);
    let mut start = 0;
    for i in 0..w {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// One sharded conditional half-sweep of a block side.
///
/// Updates the `transpose`-selected side's factors given opposite-side
/// factors `v`, with per-row priors and injected noise; returns (samples,
/// conditional means) for the full side.
pub fn sample_side_sharded(
    backend: &BlockBackend,
    data: &BlockData,
    transpose: bool,
    v: &[f32],
    prior: &RowGaussians,
    tau: f64,
    noise: &[f32],
    workers: usize,
) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
    let n = if transpose { data.cols() } else { data.rows() };
    let k = prior.k;
    if workers <= 1 || n < 2 * workers {
        return backend.sample_side(data, transpose, v, prior, tau, noise);
    }
    let bounds = shard_bounds(n, workers);

    match backend {
        BlockBackend::Native => {
            let csr: &Csr = if transpose { &data.csr_t } else { &data.csr };
            let mut samples = vec![0.0f32; n * k];
            let mut means = vec![0.0f32; n * k];
            // scoped threads: each worker samples its shard, sends results
            // over a channel; the leader gathers (MPI-allgather analogue).
            let (tx, rx) = std::sync::mpsc::channel();
            crossbeam_utils::thread::scope(|scope| {
                for (widx, &(a, b)) in bounds.iter().enumerate() {
                    let tx = tx.clone();
                    let prior_shard = prior.slice(a, b);
                    let noise_shard = &noise[a * k..b * k];
                    let shard = csr.slice_rows(a, b);
                    scope.spawn(move |_| {
                        let (s, m) =
                            sample_side_native(&shard, v, k, &prior_shard, tau, noise_shard);
                        tx.send((widx, a, b, s, m)).expect("gather channel closed");
                    });
                }
                drop(tx);
                for (_widx, a, b, s, m) in rx.iter() {
                    samples[a * k..b * k].copy_from_slice(&s);
                    means[a * k..b * k].copy_from_slice(&m);
                }
            })
            .expect("worker thread panicked");
            Ok((samples, means))
        }
        #[cfg(feature = "pjrt")]
        BlockBackend::Hlo(engine) => {
            // sequential shard execution through the thread-confined engine
            let mut samples = vec![0.0f32; n * k];
            let mut means = vec![0.0f32; n * k];
            for &(a, b) in &bounds {
                let shard_coo = if transpose {
                    data.csr_t.slice_rows(a, b).to_coo()
                } else {
                    data.csr.slice_rows(a, b).to_coo()
                };
                let prior_shard = prior.slice(a, b);
                let (s, m) = engine.sample_side(
                    &shard_coo,
                    false,
                    v,
                    &prior_shard,
                    tau as f32,
                    &noise[a * k..b * k],
                )?;
                samples[a * k..b * k].copy_from_slice(&s);
                means[a * k..b * k].copy_from_slice(&m);
            }
            Ok((samples, means))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Coo;
    use crate::rng::{normal::standard_normal_vec, Rng};

    #[test]
    fn shard_bounds_cover_and_balance() {
        for n in [1usize, 7, 16, 100] {
            for w in [1usize, 2, 3, 8] {
                let b = shard_bounds(n, w);
                assert_eq!(b[0].0, 0);
                assert_eq!(b.last().unwrap().1, n);
                for pair in b.windows(2) {
                    assert_eq!(pair[0].1, pair[1].0, "gap in shards");
                }
                let sizes: Vec<usize> = b.iter().map(|(a, c)| c - a).collect();
                let (mn, mx) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "unbalanced: {sizes:?}");
            }
        }
    }

    #[test]
    fn sharded_equals_unsharded_native() {
        let mut coo = Coo::new(40, 30);
        let mut rng = Rng::seed_from_u64(50);
        for _ in 0..300 {
            coo.push(rng.below(40), rng.below(30), (rng.uniform() * 4.0 + 1.0) as f32);
        }
        let data = BlockData::new(coo);
        let k = 4;
        let v = standard_normal_vec(&mut rng, 30 * k);
        let prior = RowGaussians::standard(40, k, 1.5);
        let noise = standard_normal_vec(&mut rng, 40 * k);
        let backend = BlockBackend::Native;
        let (s1, m1) =
            sample_side_sharded(&backend, &data, false, &v, &prior, 2.0, &noise, 1).unwrap();
        for w in [2usize, 3, 4] {
            let (s, m) =
                sample_side_sharded(&backend, &data, false, &v, &prior, 2.0, &noise, w)
                    .unwrap();
            // sharding must not change the math at all (same noise rows)
            for i in 0..s.len() {
                assert!((s[i] - s1[i]).abs() < 1e-5, "w={w} sample[{i}]");
                assert!((m[i] - m1[i]).abs() < 1e-5, "w={w} mean[{i}]");
            }
        }
    }

    #[test]
    fn sharded_transposed_side() {
        let mut coo = Coo::new(20, 36);
        let mut rng = Rng::seed_from_u64(51);
        for _ in 0..200 {
            coo.push(rng.below(20), rng.below(36), 3.0);
        }
        let data = BlockData::new(coo);
        let k = 4;
        let u = standard_normal_vec(&mut rng, 20 * k);
        let prior = RowGaussians::standard(36, k, 1.0);
        let noise = standard_normal_vec(&mut rng, 36 * k);
        let backend = BlockBackend::Native;
        let (s1, _) =
            sample_side_sharded(&backend, &data, true, &u, &prior, 1.0, &noise, 1).unwrap();
        let (s3, _) =
            sample_side_sharded(&backend, &data, true, &u, &prior, 1.0, &noise, 3).unwrap();
        for i in 0..s1.len() {
            assert!((s1[i] - s3[i]).abs() < 1e-5);
        }
    }
}
