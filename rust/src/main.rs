//! `bmf-pp` — the D-BMF+PP command-line launcher.
//!
//! Subcommands:
//!   train     run Posterior-Propagation BMF on a dataset (synthetic profile
//!             or CSV/MatrixMarket file), streaming progress events, then
//!             report RMSE + timings; optionally save the model (--save)
//!             and the holdout set (--save-test). Within-block sweeps run
//!             lockstep by default; --sweep pipelined overlaps the factor
//!             exchange with sampling (--chunk-rows, --staleness).
//!             --kernel-f32 runs the native Gibbs kernel with f32-stored
//!             precisions/solves (f64 accumulation): a smaller per-row
//!             working set at ~1e-3 relative deviation, excluded from
//!             the bitwise-equivalence contracts (see docs/PERFORMANCE.md).
//!             --store <dir> trains out-of-core from a shard store written
//!             by `ingest` instead of loading the matrix: blocks stream
//!             through an LRU cache bounded by --cache-bytes (0 =
//!             unbounded), warmed by a DAG-order prefetcher — the
//!             posterior is bitwise-identical to the resident run (pass
//!             the same --tau; store-backed runs default --tau to 1.0
//!             because the resident data that `auto_tau` derives it from
//!             is not loaded); --test-file <csv> scores the holdout that
//!             `ingest --save-test` wrote.
//!             --priority low|normal|high tags the job in the engine's
//!             shared queue; --resume <v3.json | checkpoint-dir> continues
//!             an interrupted run from its partial checkpoint — a
//!             directory restores the newest valid generation —
//!             (bitwise-identical over the restored blocks);
//!             --checkpoint-on-cancel <file> arms checkpoint-on-abort for
//!             cancels issued through the session API (train itself never
//!             cancels; see `jobs --cancel-demo`); --checkpoint-every N +
//!             --checkpoint-dir <dir> write a crash-tolerant v3 generation
//!             every N completed blocks (atomic rename, keep-last
//!             --checkpoint-keep, default 3) so even SIGKILL loses at most
//!             N blocks; --max-in-flight caps the job's concurrent block
//!             tasks
//!   ingest    one-pass conversion of a dataset into a per-block shard
//!             store (--out <dir>, --grid IxJ): binary shard files plus a
//!             versioned, checksummed manifest, all written atomically.
//!             Splits off the same holdout `train` would (--test-frac,
//!             seed-stable) so --save-test <csv> + `train --store --test-file`
//!             reproduce the resident run's RMSE exactly.
//!             --append --delta <csv> folds a ratings delta into an
//!             existing store instead: only the shards of blocks the
//!             delta touches are rewritten (atomic tmp+rename), the
//!             manifest revision is bumped, and the centring mean stays
//!             pinned — the input side of `update --store`
//!   update    incremental retrain: apply a ratings delta (--delta <csv>,
//!             empty = no-op) on top of a finished run's v3 checkpoint
//!             (--from <file|dir>), re-sampling ONLY the blocks the delta
//!             touches and passing every clean block's posterior through
//!             unchanged — an empty delta reproduces the prior model bit
//!             for bit, and a delta with new row/col ids degrades to a
//!             full retrain inside the same call. K, grid, and seed come
//!             from the checkpoint; pass the original run's --tau. The
//!             base data is --store <dir> (after `ingest --append` folded
//!             the same delta in; a manifest revision more than one
//!             append past the checkpoint's warns, non-fatally) or the
//!             resident dataset flags the original run used. Writes
//!             checkpoint generations to --checkpoint-dir (default:
//!             --from when it is a directory) that a running
//!             `serve --checkpoint-dir` hot-swaps without dropping a
//!             request
//!   jobs      multi-tenant demo: submit several concurrent training jobs
//!             at mixed priorities on ONE engine and stream their status
//!             (id / priority / state / block progress) until all finish;
//!             --cancel-demo cancels the first (low-priority) job after
//!             its first block and reports the abort checkpoint;
//!             --backlog N rejects submits past N live jobs (typed
//!             admission control, rejections printed and skipped)
//!   predict   load a saved model (--load) and score a ratings file or a
//!             dataset holdout; optionally rank the top columns for a row
//!             (--top-for N, --top-n count). Checkpoints are format v2
//!             (v1 still loads); v0 or newer-than-v2 files are rejected
//!             with an error naming the found and supported versions (a
//!             v3 partial training checkpoint is pointed at train --resume)
//!   serve     long-running HTTP recommendation server over a saved model
//!             (--load) or a v3 checkpoint directory (--checkpoint-dir):
//!             GET /predict, /top, /healthz, /stats; POST /shutdown.
//!             Concurrent requests coalesce into batched passes
//!             (--batch-max, --batch-wait-us); with --checkpoint-dir the
//!             server polls every --poll-ms and hot-swaps to the newest
//!             servable generation without dropping a request (--ridge
//!             must match the trainer's for a bitwise handoff)
//!   baseline  run comparators (bmf | nomad | fpsgd | sgld | als | cgd) on
//!             the same data; --method accepts a comma-separated list and
//!             all fits share one warm engine
//!   evaluate  calibration report (coverage of posterior intervals) for a
//!             saved model
//!   datasets  print Table-1 style statistics for the synthetic profiles
//!   partition analyse block grids for a dataset (Fig-3 style table)
//!   simulate  strong-scaling simulation on the calibrated cluster model
//!             (--sweep lockstep|pipelined picks the exchange regime,
//!             --schedule barrier|dag the block schedule, --widths
//!             static|dynamic the DAG node-group sizing)
//!   scenario  run declarative end-to-end specs: `bmf-pp scenario
//!             <file|dir>` parses JSON scenario files (dataset, grid,
//!             sweep/scheduler modes, store-backed legs, fault plans,
//!             multi-tenant mixes) and checks their declared invariants
//!             (rmse_max, bitwise_equal, max_queue_wait_secs,
//!             min_evictions, expect_outcome, resume_bitwise,
//!             finish_before, max_blocks_resampled) against real Engine
//!             runs; update legs (update_from + delta_frac) replay a
//!             finished leg through Engine::update. A directory is
//!             swept in filename order; any failed invariant makes the
//!             exit code non-zero and prints the exact re-run line.
//!             --list shows the specs without running them, --filter S
//!             keeps scenarios whose name contains S, --report <file>
//!             writes a machine JSON report
//!
//! Examples:
//!   bmf-pp train --dataset netflix --scale 0.002 --grid 4x2 --samples 20
//!   bmf-pp train --dataset movielens --save m.json --save-test holdout.csv
//!   bmf-pp train --dataset movielens --resume aborted_v3.json
//!   bmf-pp ingest --dataset movielens --grid 3x3 --out shards --save-test h.csv
//!   bmf-pp train --store shards --tau 1.5 --cache-bytes 65536 --test-file h.csv
//!   bmf-pp ingest --append --delta new_ratings.csv --out shards
//!   bmf-pp update --from ckpts --store shards --delta new_ratings.csv --tau 1.5
//!   bmf-pp jobs --jobs 3 --cancel-demo
//!   bmf-pp predict --load m.json --file holdout.csv
//!   bmf-pp serve --checkpoint-dir ckpts --addr 127.0.0.1:7878
//!   bmf-pp baseline --method nomad,fpsgd,als --dataset movielens
//!   bmf-pp simulate --dataset yahoo --grid 16x16 --max-nodes 16384
//!   bmf-pp scenario scenarios/ --report scenario_report.json
//!   bmf-pp scenario scenarios/crash_resume.json
//!
//! Every subcommand parses its flags up front; the dispatch path then runs
//! a single unknown-flag check (listing the known flags on error) before
//! any data is loaded or work starts.

use bmf_pp::baselines::{factorizer, BaselineOpts};
use bmf_pp::cluster::{calibrate, sim};
use bmf_pp::coordinator::backend::BlockBackend;
use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{
    checkpoint, AdmissionPolicy, BackendSpec, ConfigError, Engine, Priority, SchedulerMode,
    SubmitError, SweepMode, TrainConfig, TrainEvent, TrainOutcome,
};
use bmf_pp::data::generator::{DatasetProfile, SyntheticDataset};
use bmf_pp::data::loader;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::data::sparse::Coo;
use bmf_pp::data::stats::DatasetStats;
use bmf_pp::metrics::recorder::Recorder;
use bmf_pp::online::update::revision_skew;
use bmf_pp::online::{append_delta, RatingDelta};
use bmf_pp::metrics::throughput::Throughput;
use bmf_pp::partition::{balance, Grid};
use bmf_pp::serve::{ModelSource, ServeConfig, Server};
use bmf_pp::store::{ingest, ShardStore};
use bmf_pp::util::cli::Args;
use bmf_pp::util::timer::{fmt_duration, fmt_hhmm, Stopwatch};
use std::path::Path;
use std::sync::Arc;

/// A fully-parsed subcommand, ready to execute. Parsing consumes flags;
/// execution does the work — so the dispatch path can reject unknown
/// flags after parse, before anything expensive runs.
type Action = Box<dyn FnOnce() -> anyhow::Result<()>>;

/// Shared `--sweep lockstep|pipelined` parsing (train and simulate).
fn parse_sweep_mode(args: &Args) -> anyhow::Result<SweepMode> {
    match args.get_or("sweep", "lockstep") {
        "lockstep" => Ok(SweepMode::Lockstep),
        "pipelined" => Ok(SweepMode::Pipelined),
        other => anyhow::bail!("unknown sweep mode '{other}' (lockstep | pipelined)"),
    }
}

/// `--priority low|normal|high` parsing (train and jobs).
fn parse_priority(args: &Args) -> anyhow::Result<Priority> {
    args.get_or("priority", "normal")
        .parse::<Priority>()
        .map_err(|e| anyhow::anyhow!(e))
}

/// Where the training matrix comes from (parsed flags, loaded lazily).
enum DataSpec {
    File { path: String, one_based: bool, k: usize },
    Synthetic { name: String, scale: f64, seed: u64, k: Option<usize> },
}

impl DataSpec {
    fn from_args(args: &Args) -> DataSpec {
        if let Some(file) = args.get("file") {
            DataSpec::File {
                path: file.to_string(),
                one_based: args.bool_or("one-based", false),
                k: args.usize_or("k", 16),
            }
        } else {
            DataSpec::Synthetic {
                name: args.get_or("dataset", "movielens").to_string(),
                scale: args.f64_or("scale", 0.002),
                seed: args.u64_or("seed", 42),
                k: args.get("k").and_then(|v| v.parse().ok()),
            }
        }
    }

    fn load(&self) -> anyhow::Result<(Coo, usize)> {
        match self {
            DataSpec::File { path, one_based, k } => {
                let p = Path::new(path);
                let coo = if path.ends_with(".mtx") {
                    loader::load_matrix_market(p)?
                } else {
                    loader::load_csv(p, *one_based)?
                };
                Ok((coo, *k))
            }
            DataSpec::Synthetic { name, scale, seed, k } => {
                let ds = SyntheticDataset::by_name(name, *scale, *seed)
                    .ok_or_else(|| anyhow::anyhow!("unknown dataset profile '{name}'"))?;
                Ok((ds.ratings, k.unwrap_or(ds.k)))
            }
        }
    }
}

fn plan_train(args: &Args) -> anyhow::Result<Action> {
    let data = DataSpec::from_args(args);
    let test_frac = args.f64_or("test-frac", 0.2);
    let grid = args.grid_or("grid", (1, 1));
    // store-backed runs default the grid to the store's ingest grid
    let grid_set = args.get("grid").is_some();
    let store_dir = args.get("store").map(str::to_string);
    let cache_bytes = args.u64_or("cache-bytes", 0);
    let test_file = args.get("test-file").map(str::to_string);
    let k_flag = args.usize_or("k", 16);
    let burnin = args.usize_or("burnin", 8);
    let samples = args.usize_or("samples", 20);
    let workers = args.usize_or("workers", 1);
    let seed = args.u64_or("seed", 42);
    let tau = args.get("tau").and_then(|v| v.parse::<f64>().ok());
    let native = args.bool_or("native", false);
    let scheduler = match args.get_or("scheduler", "dag") {
        "barrier" => SchedulerMode::Barrier,
        "dag" => SchedulerMode::Dag,
        other => anyhow::bail!("unknown scheduler '{other}' (barrier | dag)"),
    };
    let sweep = parse_sweep_mode(args)?;
    let chunk_rows = args.usize_or("chunk-rows", 256);
    let staleness = args.usize_or("staleness", 0);
    let kernel_f32 = args.bool_or("kernel-f32", false);
    // --staleness bounds how far a pipelined chunk read may lag; under
    // lockstep sweeps (the default) it can never apply, so passing it is
    // a mistyped run — reject at parse time, before any data loads
    if staleness > 0 && matches!(sweep, SweepMode::Lockstep) {
        return Err(ConfigError::StalenessWithLockstep(staleness).into());
    }
    let block_parallelism = args.get("block-parallelism").and_then(|v| v.parse().ok());
    let phase_sample_frac = args.f64_or("phase-sample-frac", 1.0);
    let priority = parse_priority(args)?;
    let max_in_flight = args.usize_or("max-in-flight", 0);
    let resume_path = args.get("resume").map(str::to_string);
    let cancel_ckpt = args.get("checkpoint-on-cancel").map(str::to_string);
    let checkpoint_every = args.usize_or("checkpoint-every", 0);
    let checkpoint_dir = args.get("checkpoint-dir").map(str::to_string);
    let checkpoint_keep = args.usize_or("checkpoint-keep", 3);
    let save_path = args.get("save").map(str::to_string);
    let save_test = args.get("save-test").map(str::to_string);
    let metrics_path = args.get("metrics").map(str::to_string);
    let quiet = args.bool_or("quiet", false);

    Ok(Box::new(move || {
        // one config builder for both data sources; only K, tau, and the
        // grid differ between them
        let build_cfg = |k: usize, tau: f64, grid: (usize, usize)| {
            let mut cfg = TrainConfig::new(k)
                .with_grid(grid.0, grid.1)
                .with_sweeps(burnin, samples)
                .with_workers(workers)
                .with_seed(seed)
                .with_tau(tau)
                .with_scheduler(scheduler)
                .with_sweep_mode(sweep)
                .with_chunk_rows(chunk_rows)
                .with_staleness(staleness)
                .with_cache_bytes(cache_bytes);
            if kernel_f32 {
                cfg = cfg.with_kernel_precision(bmf_pp::gibbs::GibbsPrecision::F32);
            }
            if native {
                cfg = cfg.with_backend(BackendSpec::Native);
            }
            if let Some(bp) = block_parallelism {
                cfg.block_parallelism = bp;
            }
            cfg = cfg.with_priority(priority).with_max_in_flight(max_in_flight);
            if let Some(path) = &resume_path {
                cfg = cfg.with_resume_from(path.clone());
            }
            if let Some(path) = &cancel_ckpt {
                cfg = cfg.with_checkpoint_on_cancel(path.clone());
            }
            if checkpoint_every > 0 {
                cfg = cfg.with_checkpoint_every(checkpoint_every);
            }
            if let Some(dir) = &checkpoint_dir {
                cfg = cfg.with_checkpoint_dir(dir.clone());
            }
            cfg = cfg.with_checkpoint_keep(checkpoint_keep);
            cfg.phase_sample_frac = phase_sample_frac;
            // per-sweep RMSE costs an extra O(nnz·k) pass per retained sweep;
            // only pay for it when --metrics will actually record the series
            cfg.stream_sweep_rmse = metrics_path.is_some();
            cfg
        };

        // data source: an ingested shard store (out-of-core) or a resident
        // matrix loaded and split here
        let (_engine, session, rows, cols, nnz, test) = if let Some(dir) = &store_dir {
            let store = Arc::new(ShardStore::open(Path::new(dir))?);
            let test = match &test_file {
                Some(p) => Some(loader::load_csv(Path::new(p), false)?),
                None => None,
            };
            let (rows, cols, nnz) = (store.rows(), store.cols(), store.nnz());
            let grid = if grid_set { grid } else { store.grid_dims() };
            // auto_tau needs the resident ratings; a store-backed run must
            // be told the value the resident run derived
            let tau = match tau {
                Some(t) => t,
                None => {
                    println!(
                        "note: --tau not set; store-backed runs default to 1.0 \
                         (pass the resident run's --tau for identical posteriors)"
                    );
                    1.0
                }
            };
            let cfg = build_cfg(k_flag, tau, grid);
            println!(
                "training D-BMF+PP (store-backed): {rows}x{cols} matrix, {nnz} ratings, \
                 K={k_flag}, grid {}x{}, cache budget {}",
                grid.0,
                grid.1,
                if cache_bytes == 0 {
                    "unbounded".to_string()
                } else {
                    format!("{cache_bytes} bytes")
                }
            );
            let engine = Engine::new(&cfg.backend, cfg.block_parallelism);
            let session = engine.submit_store(cfg, store)?;
            (engine, session, rows, cols, nnz, test)
        } else {
            let (data, k) = data.load()?;
            let (train, test) = holdout_split_covered(&data, test_frac, 7);
            let tau = tau.unwrap_or_else(|| auto_tau(&train));
            let cfg = build_cfg(k, tau, grid);
            println!(
                "training D-BMF+PP: {}x{} matrix, {} ratings, K={k}, grid {}x{}",
                train.rows,
                train.cols,
                train.nnz(),
                grid.0,
                grid.1
            );
            let engine = Engine::new(&cfg.backend, cfg.block_parallelism);
            let session = engine.submit(cfg, &train)?;
            (engine, session, train.rows, train.cols, train.nnz(), Some(test))
        };

        // live progress: consume the session's typed event stream
        let mut recorder = Recorder::new();
        let clock = Stopwatch::start();
        for event in session.events() {
            recorder.observe(&event);
            if quiet {
                continue;
            }
            match &event {
                TrainEvent::PhaseStarted { phase } => {
                    println!("[{:>6.2}s] phase ({phase}) started", clock.secs());
                }
                TrainEvent::BlockCompleted { node, phase, secs, sweeps } => {
                    println!(
                        "[{:>6.2}s] block ({},{}) done: {sweeps} sweeps in {} [phase {phase}]",
                        clock.secs(),
                        node.0,
                        node.1,
                        fmt_duration(*secs)
                    );
                }
                TrainEvent::BlockRestored { node } => {
                    println!(
                        "[{:>6.2}s] block ({},{}) restored from resume checkpoint",
                        clock.secs(),
                        node.0,
                        node.1
                    );
                }
                TrainEvent::BlockSkippedClean { node } => {
                    println!(
                        "[{:>6.2}s] block ({},{}) clean — posterior passed through",
                        clock.secs(),
                        node.0,
                        node.1
                    );
                }
                TrainEvent::SweepSample { .. } => {} // recorded, not printed
                TrainEvent::ChunkExchanged { .. } => {} // counted, not printed
                TrainEvent::ShardLoaded { .. } => {} // summarized after the run
                TrainEvent::CheckpointSaved { path, blocks } => {
                    println!(
                        "[{:>6.2}s] partial checkpoint ({blocks} blocks) -> {}",
                        clock.secs(),
                        path.display()
                    );
                }
                TrainEvent::Cancelled { blocks_completed } => {
                    println!(
                        "[{:>6.2}s] cancelled after {blocks_completed} blocks",
                        clock.secs()
                    );
                }
                TrainEvent::Failed { error, blocks_completed } => {
                    println!(
                        "[{:>6.2}s] FAILED after {blocks_completed} blocks: {error}",
                        clock.secs()
                    );
                }
                TrainEvent::Finished { secs, blocks } => {
                    println!(
                        "[{:>6.2}s] finished: {blocks} blocks in {}",
                        clock.secs(),
                        fmt_duration(*secs)
                    );
                }
            }
        }
        let result = match session.wait()? {
            TrainOutcome::Completed(result) => *result,
            TrainOutcome::Cancelled(info) => {
                println!(
                    "training cancelled after {} completed blocks{}",
                    info.blocks_completed,
                    match &info.checkpoint {
                        Some(p) => format!("; resume with --resume {}", p.display()),
                        None => String::new(),
                    }
                );
                return Ok(());
            }
            // a failed run exits non-zero so scripts (and the CI recovery
            // drill) can tell a crash from a finished run
            TrainOutcome::Failed(info) => anyhow::bail!(
                "training failed after {} completed blocks: {}{}",
                info.blocks_completed,
                info.error,
                match &info.checkpoint {
                    Some(p) => format!("; resume with --resume {}", p.display()),
                    None => String::new(),
                }
            ),
        };

        println!(
            "phases: a={} b={} c={} aggregate={} total={}",
            fmt_duration(result.timings.a),
            fmt_duration(result.timings.b),
            fmt_duration(result.timings.c),
            fmt_duration(result.timings.aggregate),
            fmt_duration(result.timings.total)
        );
        println!(
            "scheduling: compute {} / idle {} / phase-overlap {} / sweep-overlap {} / queue-wait {}",
            fmt_duration(result.stats.compute_secs),
            fmt_duration(result.stats.idle_secs),
            fmt_duration(result.stats.overlap_secs),
            fmt_duration(result.stats.comm_overlap_secs),
            fmt_duration(result.stats.queue_wait_secs)
        );
        if result.stats.blocks_restored > 0 {
            println!(
                "resume: {} blocks restored from checkpoint, {} re-sampled",
                result.stats.blocks_restored, result.stats.blocks
            );
        }
        if store_dir.is_some() {
            println!(
                "shard cache: {} hits, {} misses, {} prefetch hits, {} evictions \
                 (peak {} bytes resident)",
                result.stats.shard_hits,
                result.stats.shard_misses,
                result.stats.shard_prefetch_hits,
                result.stats.shard_evictions,
                result.stats.shard_bytes_peak
            );
        }
        let tp = Throughput::measure(
            rows,
            cols,
            nnz,
            result.stats.sweeps / result.stats.blocks.max(1),
            result.timings.total,
        );
        println!("throughput: {}", tp.format_table1());
        match &test {
            Some(test) => println!(
                "test RMSE = {:.4}  (wall-clock {})",
                result.rmse(test),
                fmt_hhmm(result.timings.total)
            ),
            None => println!(
                "wall-clock {} (no holdout scored; pass --test-file <csv>)",
                fmt_hhmm(result.timings.total)
            ),
        }
        if let Some(path) = metrics_path {
            if let Some(test) = &test {
                recorder.scalar("test_rmse", result.rmse(test));
            }
            recorder.save(Path::new(&path))?;
            println!("metrics saved to {path}");
        }
        if let Some(path) = save_path {
            checkpoint::save(&result, Path::new(&path))?;
            println!("checkpoint saved to {path}");
        }
        if let Some(path) = save_test {
            match &test {
                Some(test) => {
                    loader::save_csv(test, Path::new(&path))?;
                    println!("holdout set saved to {path} ({} ratings)", test.nnz());
                }
                None => anyhow::bail!(
                    "--save-test needs a dataset split (use `ingest --save-test` \
                     for store-backed runs)"
                ),
            }
        }
        Ok(())
    }))
}

/// `ingest --append` — fold a ratings delta into an existing shard
/// store: only the shards of blocks the delta touches are rewritten
/// (atomic tmp+rename), the manifest revision is bumped, and the
/// centring mean stays pinned — the input side of `update --store`.
fn plan_ingest_append(args: &Args) -> anyhow::Result<Action> {
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out <existing store dir> required"))?
        .to_string();
    let delta_path = args
        .get("delta")
        .ok_or_else(|| anyhow::anyhow!("--append requires --delta <csv>"))?
        .to_string();
    let one_based = args.bool_or("one-based", false);

    Ok(Box::new(move || {
        let clock = Stopwatch::start();
        let coo = loader::load_csv(Path::new(&delta_path), one_based)?;
        let delta = RatingDelta::from_coo(&coo);
        let report = append_delta(&delta, Path::new(&out))?;
        println!(
            "appended {} ratings into {out}: {} shard(s) rewritten{} in {}",
            report.delta_nnz,
            report.rewritten,
            if report.grown {
                format!(
                    " (matrix grew to {}x{}; every shard re-split)",
                    report.shape.0, report.shape.1
                )
            } else {
                String::new()
            },
            fmt_duration(clock.secs())
        );
        println!(
            "store now {}x{}, {} ratings, manifest revision {}",
            report.shape.0, report.shape.1, report.nnz, report.revision
        );
        Ok(())
    }))
}

/// `ingest` — one-pass conversion of a dataset into a per-block shard
/// store on disk, the input side of out-of-core `train --store`.
fn plan_ingest(args: &Args) -> anyhow::Result<Action> {
    if args.bool_or("append", false) {
        return plan_ingest_append(args);
    }
    let data = DataSpec::from_args(args);
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out <dir> required"))?
        .to_string();
    let (gi, gj) = args.grid_or("grid", (2, 2));
    let test_frac = args.f64_or("test-frac", 0.2);
    let save_test = args.get("save-test").map(str::to_string);

    Ok(Box::new(move || {
        let clock = Stopwatch::start();
        let (full, _k) = data.load()?;
        // mirror `train`'s holdout split (same seed) so a store-backed run
        // scores the exact holdout a resident run of these flags would;
        // --save-test writes it out for `train --store --test-file`
        let (train, test) = holdout_split_covered(&full, test_frac, 7);
        let report = ingest(&train, gi, gj, Path::new(&out))?;
        let secs = clock.secs();
        println!(
            "ingested {}x{} ({} ratings) as {} shards ({gi}x{gj} grid, {} bytes) in {}",
            train.rows,
            train.cols,
            report.nnz,
            report.blocks,
            report.bytes,
            fmt_duration(secs)
        );
        println!(
            "global mean {:.6}; manifest -> {}",
            report.global_mean,
            report.manifest_path.display()
        );
        println!("throughput: {:.0} ratings/s", report.nnz as f64 / secs.max(1e-9));
        if let Some(path) = save_test {
            loader::save_csv(&test, Path::new(&path))?;
            println!("holdout set saved to {path} ({} ratings)", test.nnz());
        }
        Ok(())
    }))
}

/// `update` — incremental retrain from a finished run's checkpoint:
/// re-sample only the blocks a ratings delta touches, pass every clean
/// block's posterior through unchanged, and write the result as new
/// checkpoint generations a running `serve` hot-swaps. K, grid, and seed
/// come from the checkpoint itself; only the sampling knobs are flags.
fn plan_update(args: &Args) -> anyhow::Result<Action> {
    let from = args
        .get("from")
        .ok_or_else(|| anyhow::anyhow!("--from <v3.json | checkpoint-dir> required"))?
        .to_string();
    let delta_path = args
        .get("delta")
        .ok_or_else(|| {
            anyhow::anyhow!("--delta <csv> required (an empty file is a valid no-op delta)")
        })?
        .to_string();
    let one_based = args.bool_or("one-based", false);
    let store_dir = args.get("store").map(str::to_string);
    // the resident path re-derives the base matrix from the same dataset
    // flags + split seed the original `train` run used
    let data = DataSpec::from_args(args);
    let test_frac = args.f64_or("test-frac", 0.2);
    let burnin = args.usize_or("burnin", 8);
    let samples = args.usize_or("samples", 20);
    let workers = args.usize_or("workers", 1);
    let native = args.bool_or("native", false);
    let tau = args.get("tau").and_then(|v| v.parse::<f64>().ok());
    let checkpoint_dir = args.get("checkpoint-dir").map(str::to_string);
    let checkpoint_keep = args.usize_or("checkpoint-keep", 3);
    let quiet = args.bool_or("quiet", false);

    Ok(Box::new(move || {
        let prior = bmf_pp::online::load_prior(Path::new(&from))?;
        // generations default to landing where the prior lives, so a
        // serve watching that directory hot-swaps the result
        let ckpt_dir = match checkpoint_dir {
            Some(d) => d,
            None if Path::new(&from).is_dir() => from.clone(),
            None => anyhow::bail!(
                "--checkpoint-dir <dir> required when --from is a file \
                 (a directory --from doubles as the output directory)"
            ),
        };
        let delta_coo = loader::load_csv(Path::new(&delta_path), one_based)?;
        let delta = RatingDelta::from_coo(&delta_coo);
        let tau = match tau {
            Some(t) => t,
            None => {
                println!(
                    "note: --tau not set; update defaults to 1.0 (pass the \
                     original run's --tau — a mismatch changes the dirty \
                     blocks' math)"
                );
                1.0
            }
        };
        let mut cfg = TrainConfig::new(prior.k)
            .with_grid(prior.grid.0, prior.grid.1)
            .with_seed(prior.seed)
            .with_sweeps(burnin, samples)
            .with_workers(workers)
            .with_tau(tau)
            // checkpoint after every completed block: the run's final
            // generation is complete and servable the moment it lands
            .with_checkpoint_every(1)
            .with_checkpoint_dir(ckpt_dir.clone())
            .with_checkpoint_keep(checkpoint_keep);
        if native {
            cfg = cfg.with_backend(BackendSpec::Native);
        }

        println!(
            "incremental update: prior generation {} ({}x{} grid, K={}, seed {}), \
             delta of {} ratings",
            prior.generation,
            prior.grid.0,
            prior.grid.1,
            prior.k,
            prior.seed,
            delta.len()
        );
        let engine = Engine::new(&cfg.backend, cfg.block_parallelism);
        let session = if let Some(dir) = &store_dir {
            let store = Arc::new(ShardStore::open(Path::new(dir))?);
            // non-fatal: the store moved further than the one append this
            // delta accounts for — surface it, then proceed
            if let Some(warning) = revision_skew(&prior, store.revision()) {
                println!("warning: {warning}");
            }
            engine.update_store(cfg, &prior, &delta, store)?
        } else {
            let (full, _k) = data.load()?;
            let (train, _test) = holdout_split_covered(&full, test_frac, 7);
            engine.update(cfg, &prior, &delta, &train)?
        };

        let clock = Stopwatch::start();
        for event in session.events() {
            if quiet {
                continue;
            }
            match &event {
                TrainEvent::BlockSkippedClean { node } => println!(
                    "[{:>6.2}s] block ({},{}) clean — posterior passed through",
                    clock.secs(),
                    node.0,
                    node.1
                ),
                TrainEvent::BlockCompleted { node, secs, sweeps, .. } => println!(
                    "[{:>6.2}s] block ({},{}) re-sampled: {sweeps} sweeps in {}",
                    clock.secs(),
                    node.0,
                    node.1,
                    fmt_duration(*secs)
                ),
                TrainEvent::CheckpointSaved { path, blocks } => println!(
                    "[{:>6.2}s] generation ({blocks} blocks) -> {}",
                    clock.secs(),
                    path.display()
                ),
                TrainEvent::Failed { error, blocks_completed } => println!(
                    "[{:>6.2}s] FAILED after {blocks_completed} blocks: {error}",
                    clock.secs()
                ),
                _ => {}
            }
        }
        let result = match session.wait()? {
            TrainOutcome::Completed(r) => *r,
            TrainOutcome::Cancelled(info) => {
                anyhow::bail!("update cancelled after {} blocks", info.blocks_completed)
            }
            TrainOutcome::Failed(info) => anyhow::bail!(
                "update failed after {} completed blocks: {}",
                info.blocks_completed,
                info.error
            ),
        };
        println!(
            "update: {} block(s) re-sampled, {} passed through clean, in {}",
            result.stats.blocks,
            result.stats.blocks_skipped_clean,
            fmt_duration(result.timings.total)
        );
        if result.stats.blocks == 0 {
            println!(
                "empty delta: no block changed, so no new generation was \
                 written — the prior model already is the answer, bit for bit"
            );
        } else {
            println!(
                "new generation in {ckpt_dir} — a running `serve \
                 --checkpoint-dir {ckpt_dir}` hot-swaps it within its --poll-ms"
            );
        }
        Ok(())
    }))
}

/// `jobs` — the multi-tenant engine demo: several concurrent sessions at
/// mixed priorities on one warm pool, status streamed until all terminal.
fn plan_jobs(args: &Args) -> anyhow::Result<Action> {
    let data = DataSpec::from_args(args);
    let n_jobs = args.usize_or("jobs", 3).max(1);
    let threads = args.usize_or("threads", 4);
    let burnin = args.usize_or("burnin", 4);
    let samples = args.usize_or("samples", 8);
    let seed = args.u64_or("seed", 42);
    let cancel_demo = args.bool_or("cancel-demo", false);
    let backlog = args.usize_or("backlog", 0);

    Ok(Box::new(move || {
        let (data, k) = data.load()?;
        let (train, _) = holdout_split_covered(&data, 0.2, 7);
        let mut engine = Engine::new(&BackendSpec::Native, threads);
        if backlog > 0 {
            engine = engine
                .with_admission(AdmissionPolicy::Reject { max_backlog: backlog });
            println!("admission: rejecting submits past a backlog of {backlog} live jobs");
        }
        let abort_ckpt =
            std::env::temp_dir().join(format!("bmfpp_jobs_abort_{}.json", std::process::id()));

        // job 0 is wide and Low; priorities then cycle upward, so the
        // finish order itself demonstrates priority dispatch
        let mut sessions = Vec::new();
        for idx in 0..n_jobs {
            let priority = match idx % 3 {
                0 => Priority::Low,
                1 => Priority::Normal,
                _ => Priority::High,
            };
            let grid = if priority == Priority::Low { (3, 3) } else { (2, 2) };
            let mut cfg = TrainConfig::new(k)
                .with_grid(grid.0, grid.1)
                .with_sweeps(burnin, samples)
                .with_seed(seed.wrapping_add(idx as u64))
                .with_tau(auto_tau(&train))
                .with_backend(BackendSpec::Native)
                .with_priority(priority);
            if cancel_demo && idx == 0 {
                cfg = cfg.with_checkpoint_on_cancel(abort_ckpt.clone());
            }
            let session = match engine.submit(cfg, &train) {
                Ok(s) => s,
                // load shedding in action: a typed rejection, not a hang
                Err(e) if e.downcast_ref::<SubmitError>().is_some() => {
                    println!("job {idx} REJECTED: {e}");
                    continue;
                }
                Err(e) => return Err(e),
            };
            println!(
                "submitted job #{} [{priority}] grid {}x{}",
                session.id(),
                grid.0,
                grid.1
            );
            sessions.push(session);
        }
        if cancel_demo {
            // cancel the wide low-priority job once it has produced a
            // block — checkpoint-on-abort in action
            let first = &sessions[0];
            while first.progress().0 < 1 && !first.status().is_terminal() {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            first.cancel();
        }

        let clock = Stopwatch::start();
        let mut finish_order: Vec<u64> = Vec::new();
        loop {
            let snap = engine.jobs();
            let line = snap
                .iter()
                .map(|j| {
                    // queue wait appears once the schedule has measured it
                    let qw = match j.queue_wait_secs {
                        Some(s) => format!(" wait={s:.2}s"),
                        None => String::new(),
                    };
                    // shard-cache traffic only appears for store-backed jobs
                    let sh = if j.shard_hits + j.shard_misses > 0 {
                        format!(
                            " cache={}h/{}m/{}p",
                            j.shard_hits, j.shard_misses, j.shard_prefetch_hits
                        )
                    } else {
                        String::new()
                    };
                    format!(
                        "#{} {}:{} {}/{}{qw}{sh}",
                        j.id, j.priority, j.status, j.blocks_done, j.blocks_total
                    )
                })
                .collect::<Vec<_>>()
                .join("  ");
            println!("[{:>5.1}s] {line}", clock.secs());
            for j in &snap {
                if j.status.is_terminal() && !finish_order.contains(&j.id) {
                    finish_order.push(j.id);
                }
            }
            if snap.iter().all(|j| j.status.is_terminal()) {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(200));
        }

        for session in sessions {
            let id = session.id();
            match session.wait()? {
                TrainOutcome::Completed(res) => println!(
                    "job #{id}: completed {} blocks, train RMSE {:.4}",
                    res.stats.blocks,
                    res.rmse(&train)
                ),
                TrainOutcome::Cancelled(info) => println!(
                    "job #{id}: cancelled after {} blocks{}",
                    info.blocks_completed,
                    match &info.checkpoint {
                        Some(p) => format!("; resume with train --resume {}", p.display()),
                        None => String::new(),
                    }
                ),
                TrainOutcome::Failed(info) => println!(
                    "job #{id}: FAILED after {} blocks: {}",
                    info.blocks_completed, info.error
                ),
            }
        }
        println!(
            "finish order: {}",
            finish_order.iter().map(|i| format!("#{i}")).collect::<Vec<_>>().join(" -> ")
        );
        Ok(())
    }))
}

fn plan_predict(args: &Args) -> anyhow::Result<Action> {
    let load_path = args
        .get("load")
        .ok_or_else(|| anyhow::anyhow!("--load <model.json> required"))?
        .to_string();
    let data = DataSpec::from_args(args);
    let test_frac = args.f64_or("test-frac", 0.2);
    let top_for = args.get("top-for").and_then(|v| v.parse::<usize>().ok());
    let top_n = args.usize_or("top-n", 5);

    Ok(Box::new(move || {
        let model = checkpoint::load(Path::new(&load_path))?;
        println!(
            "model {load_path}: K={} over {} rows x {} cols",
            model.k,
            model.rows(),
            model.cols()
        );
        let test = match &data {
            // a ratings file (CSV or MatrixMarket) is scored as-is — e.g.
            // the holdout written by `train --save-test`
            DataSpec::File { .. } => data.load()?.0,
            // otherwise reproduce train's split and score its holdout
            DataSpec::Synthetic { .. } => holdout_split_covered(&data.load()?.0, test_frac, 7).1,
        };
        anyhow::ensure!(test.nnz() > 0, "no ratings to score");
        anyhow::ensure!(
            test.rows <= model.rows() && test.cols <= model.cols(),
            "ratings reference row/col ids outside the model ({}x{} vs {}x{})",
            test.rows,
            test.cols,
            model.rows(),
            model.cols()
        );
        println!("test RMSE = {:.4} over {} ratings", model.rmse(&test), test.nnz());
        if let Some(row) = top_for {
            anyhow::ensure!(row < model.rows(), "--top-for row {row} out of range");
            println!("top-{top_n} columns for row {row} (posterior-mean score):");
            for (col, score) in model.top_n(row, top_n) {
                println!("  col {col:<8} predicted {score:.3}");
            }
        }
        Ok(())
    }))
}

fn plan_evaluate(args: &Args) -> anyhow::Result<Action> {
    let ckpt = args
        .get("checkpoint")
        .ok_or_else(|| anyhow::anyhow!("--checkpoint <file> required"))?
        .to_string();
    let data = DataSpec::from_args(args);
    let test_frac = args.f64_or("test-frac", 0.2);

    Ok(Box::new(move || {
        let model = checkpoint::load(Path::new(&ckpt))?;
        let (full, _) = data.load()?;
        let (_, test) = holdout_split_covered(&full, test_frac, 7);
        println!("checkpoint {ckpt}: K={}", model.k);
        println!("test RMSE = {:.4} over {} held-out ratings", model.rmse(&test), test.nnz());
        // calibration report using factor-posterior + residual variance
        let resid_var = 1.0 / auto_tau(&full);
        let report = bmf_pp::metrics::calibration::coverage(&test, &[1.0, 2.0, 3.0], |r, c| {
            let mu = model.predict(r, c);
            let sigma = (model.predict_variance(r, c) + resid_var).sqrt();
            (mu, sigma)
        });
        for (z, nominal, empirical) in report.rows {
            println!(
                "  ±{z:.0}σ coverage: {:.1}% (nominal {:.1}%)",
                empirical * 100.0,
                nominal * 100.0
            );
        }
        Ok(())
    }))
}

fn plan_baseline(args: &Args) -> anyhow::Result<Action> {
    let data = DataSpec::from_args(args);
    let test_frac = args.f64_or("test-frac", 0.2);
    let methods: Vec<String> = args
        .get_or("method", "fpsgd")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    // reject typos at parse time, before any method gets to train
    for m in &methods {
        if !bmf_pp::baselines::METHODS.contains(&m.as_str()) {
            anyhow::bail!(
                "unknown method '{m}' (expected one of: {})",
                bmf_pp::baselines::METHODS.join(", ")
            );
        }
    }
    let epochs = args.usize_or("epochs", 20);
    let threads = args.usize_or("threads", 4);
    let sweeps = args.usize_or("sweeps", 30);
    let seed = args.u64_or("seed", 42);
    let tau = args.get("tau").and_then(|v| v.parse::<f64>().ok());

    Ok(Box::new(move || {
        let (data, k) = data.load()?;
        let (train, test) = holdout_split_covered(&data, test_frac, 7);
        let opts = BaselineOpts {
            k,
            epochs,
            threads,
            sweeps,
            seed,
            tau: tau.unwrap_or_else(|| auto_tau(&train)),
        };
        // every method fits through the same Factorizer path on one engine
        let engine = Engine::new(&BackendSpec::Native, threads);
        for method in &methods {
            let f = factorizer(method, &opts).expect("method names validated at parse time");
            let out = f.fit(&engine, &train)?;
            println!(
                "{method}: test RMSE = {:.4} in {}",
                out.model.rmse(&test),
                fmt_duration(out.secs)
            );
        }
        Ok(())
    }))
}

fn plan_recommend_grid(args: &Args) -> anyhow::Result<Action> {
    let name = args.get_or("dataset", "netflix").to_string();
    let nodes = args.usize_or("nodes", 1024);
    let k_flag = args.get("k").and_then(|v| v.parse::<usize>().ok());
    let max_aspect = args.f64_or("max-aspect", 8.0);

    Ok(Box::new(move || {
        let profile = DatasetProfile::by_name(&name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
        let k = k_flag.unwrap_or(profile.k);
        let backend = BlockBackend::Native;
        let model = calibrate::calibrate(&backend, k.min(32));
        let (i, j) = balance::recommend_grid(
            &model,
            profile.paper_rows,
            profile.paper_cols,
            profile.paper_ratings,
            k,
            28,
            nodes,
            max_aspect,
        );
        println!(
            "{name} at {nodes} nodes, K={k}: recommended grid {i}x{j} (block aspect {:.2})",
            balance::block_aspect(profile.paper_rows, profile.paper_cols, i, j)
        );
        Ok(())
    }))
}

fn plan_datasets(args: &Args) -> anyhow::Result<Action> {
    let scale = args.f64_or("scale", 0.002);
    Ok(Box::new(move || {
        println!("synthetic dataset profiles at scale {scale} (paper Table 1 shape stats):");
        for p in DatasetProfile::all() {
            let eff_scale = match p.name {
                "amazon" => scale * 0.015,
                "yahoo" => scale * 0.2,
                _ => scale,
            };
            let ds = SyntheticDataset::generate(p.clone(), eff_scale, 42);
            let st = DatasetStats::compute(&ds.ratings);
            println!("{}  K={} (paper K={})", st.format_row(p.name), p.k, p.paper_k);
        }
        Ok(())
    }))
}

fn plan_partition(args: &Args) -> anyhow::Result<Action> {
    let data = DataSpec::from_args(args);
    let max_side = args.usize_or("max-side", 32);
    Ok(Box::new(move || {
        let (data, _) = data.load()?;
        println!("grid analysis for {}x{} ({} ratings):", data.rows, data.cols, data.nnz());
        println!("{:<8} {:>10} {:>14} {:>12}", "grid", "aspect", "area/circum", "max-par");
        for (i, j) in balance::candidate_grids(max_side) {
            if i > data.rows || j > data.cols {
                continue;
            }
            let g = Grid::new(data.rows, data.cols, i, j);
            let (_, pb, pc) = g.phase_parallelism();
            println!(
                "{:<8} {:>10.2} {:>14.1} {:>12}",
                format!("{i}x{j}"),
                balance::block_aspect(data.rows, data.cols, i, j),
                balance::area_over_circumference(data.rows, data.cols, i, j),
                pb.max(pc)
            );
        }
        Ok(())
    }))
}

fn plan_simulate(args: &Args) -> anyhow::Result<Action> {
    let name = args.get_or("dataset", "netflix").to_string();
    let (gi, gj) = args.grid_or("grid", (4, 4));
    let max_nodes = args.usize_or("max-nodes", 16384);
    // strict parse: --sweeps (count) sits one letter from --sweep (mode),
    // so a non-numeric value is almost certainly the other flag mistyped
    let sweeps = match args.get("sweeps") {
        Some(v) => v.parse::<usize>().map_err(|_| {
            anyhow::anyhow!("--sweeps expects a sweep count (got '{v}'); --sweep picks the mode")
        })?,
        None => 28,
    };
    let k_flag = args.get("k").and_then(|v| v.parse::<usize>().ok());
    let sweep_mode = parse_sweep_mode(args)?;
    let chunks = args.usize_or("chunks", 16);
    let schedule = match args.get_or("schedule", "barrier") {
        "barrier" => sim::ScheduleMode::Barrier,
        "dag" => sim::ScheduleMode::Dag,
        other => anyhow::bail!("unknown schedule '{other}' (barrier | dag)"),
    };
    let widths = match args.get_or("widths", "static") {
        "static" => sim::WidthPolicy::Static,
        "dynamic" => sim::WidthPolicy::Dynamic,
        other => anyhow::bail!("unknown width policy '{other}' (static | dynamic)"),
    };

    Ok(Box::new(move || {
        let profile = DatasetProfile::by_name(&name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
        let k = k_flag.unwrap_or(profile.paper_k);
        let backend = BlockBackend::Native;
        let model = calibrate::calibrate(&backend, k.min(32));
        let grid = Grid::new(profile.paper_rows, profile.paper_cols, gi, gj);
        let nnz = sim::uniform_block_nnz(&grid, profile.paper_ratings);

        println!(
            "strong scaling, {name} ({}x{}, {} ratings), K={k}, grid {gi}x{gj}:",
            profile.paper_rows, profile.paper_cols, profile.paper_ratings
        );
        let mut pts = Vec::new();
        let comm_model = sim::model_for_sweep(&model, sweep_mode, chunks);
        for p in sim::node_sweep(&grid, max_nodes) {
            let r = sim::simulate_pp_mode_widths(
                &comm_model,
                &grid,
                &nnz,
                k,
                sweeps,
                sweeps,
                p,
                schedule,
                widths,
            );
            pts.push((p, r.total));
            println!(
                "  nodes={p:<7} wall={:<12} (a={} b={} c={})",
                fmt_hhmm(r.total),
                fmt_hhmm(r.phase_a),
                fmt_hhmm(r.phase_b),
                fmt_hhmm(r.phase_c)
            );
        }
        let front = sim::pareto_front(&pts);
        println!(
            "pareto: {}",
            front
                .iter()
                .map(|(p, t)| format!("{p}@{}", fmt_hhmm(*t)))
                .collect::<Vec<_>>()
                .join(" ")
        );
        Ok(())
    }))
}

/// `serve`: long-running HTTP recommendation server with request
/// batching and checkpoint hot-swap (see `bmf_pp::serve`).
fn plan_serve(args: &Args) -> anyhow::Result<Action> {
    let load = args.get("load").map(str::to_string);
    let ckpt_dir = args.get("checkpoint-dir").map(str::to_string);
    let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
    let threads = args.usize_or("threads", 4);
    let batch_max = args.usize_or("batch-max", 32);
    let batch_wait_us = args.u64_or("batch-wait-us", 500);
    let poll_ms = args.u64_or("poll-ms", 200);
    let ridge = args.f64_or("ridge", 1e-3);
    Ok(Box::new(move || {
        let source = match (load, ckpt_dir) {
            (Some(path), None) => ModelSource::File(path.into()),
            (None, Some(dir)) => ModelSource::CheckpointDir(dir.into()),
            _ => anyhow::bail!(
                "serve needs exactly one model source: --load <model.json> \
                 or --checkpoint-dir <dir>"
            ),
        };
        let cfg = ServeConfig::default()
            .with_addr(addr)
            .with_threads(threads)
            .with_batching(batch_max, std::time::Duration::from_micros(batch_wait_us))
            .with_poll(std::time::Duration::from_millis(poll_ms))
            .with_ridge(ridge);
        let server = Server::start(cfg, source)?;
        let s = server.stats();
        println!(
            "serving generation {} ({}x{} k={}) on http://{}",
            s.generation,
            s.model_rows,
            s.model_cols,
            s.model_k,
            server.addr()
        );
        println!(
            "endpoints: GET /healthz /predict?row=&col=[&variance] \
             /top?row=[&n=] /stats | POST /shutdown"
        );
        let fin = server.join();
        println!(
            "served {} requests ({} errors) in {} batches, {} swaps; \
             p50={:.3}ms p99={:.3}ms qps={:.1}",
            fin.http_requests,
            fin.http_errors,
            fin.batches,
            fin.swaps,
            fin.p50_ms,
            fin.p99_ms,
            fin.qps
        );
        Ok(())
    }))
}

fn plan_scenario(args: &Args) -> anyhow::Result<Action> {
    // `--list` is boolean, but `--list scenarios/` parses as a key-value
    // pair — accept the value as the sweep path so both orders work.
    let list_val = args.get("list").map(str::to_string);
    let list = list_val.is_some();
    let filter = args.get("filter").map(str::to_string);
    let report_path = args.get("report").map(str::to_string);
    let path = args
        .positional
        .first()
        .cloned()
        .or_else(|| list_val.filter(|v| v != "true" && v != "false"))
        .unwrap_or_else(|| "scenarios".to_string());
    Ok(Box::new(move || {
        let all = bmf_pp::harness::load_path(Path::new(&path))?;
        let selected: Vec<_> = all
            .into_iter()
            .filter(|s| filter.as_deref().map_or(true, |f| s.name.contains(f)))
            .collect();
        if selected.is_empty() {
            anyhow::bail!(
                "no scenarios under {path} match --filter {}",
                filter.as_deref().unwrap_or("")
            );
        }
        if list {
            for s in &selected {
                println!(
                    "{:<28} {:>2} legs {:>2} invariants  {}  [{}]",
                    s.name,
                    s.legs.len(),
                    s.invariants.len(),
                    s.description,
                    s.display_path()
                );
            }
            return Ok(());
        }
        let mut reports = Vec::with_capacity(selected.len());
        for scn in &selected {
            println!("running {} ({})", scn.name, scn.description);
            let report = bmf_pp::harness::run_and_check(scn)?;
            print!("{}", bmf_pp::harness::render_human(&report));
            reports.push(report);
        }
        println!("{}", bmf_pp::harness::render_summary(&reports));
        if let Some(out) = &report_path {
            let json = bmf_pp::util::json::to_string_pretty(&bmf_pp::harness::to_json(&reports));
            std::fs::write(out, json + "\n")
                .map_err(|e| anyhow::anyhow!("cannot write report {out}: {e}"))?;
            println!("report written to {out}");
        }
        let failed = reports.iter().filter(|r| !r.passed()).count();
        if failed > 0 {
            anyhow::bail!("{failed} of {} scenarios failed", reports.len());
        }
        Ok(())
    }))
}

fn main() {
    bmf_pp::util::logging::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    // stage 1: parse — each plan_* consumes exactly the flags it accepts
    let planned = match args.subcommand.as_deref() {
        Some("train") => plan_train(&args),
        Some("ingest") => plan_ingest(&args),
        Some("update") => plan_update(&args),
        Some("jobs") => plan_jobs(&args),
        Some("predict") => plan_predict(&args),
        Some("serve") => plan_serve(&args),
        Some("baseline") => plan_baseline(&args),
        Some("datasets") => plan_datasets(&args),
        Some("partition") => plan_partition(&args),
        Some("simulate") => plan_simulate(&args),
        Some("evaluate") => plan_evaluate(&args),
        Some("recommend-grid") => plan_recommend_grid(&args),
        Some("scenario") => plan_scenario(&args),
        other => {
            eprintln!(
                "usage: bmf-pp <train|ingest|update|jobs|predict|serve|baseline|datasets|partition|simulate|evaluate|recommend-grid|scenario> [--flags]\n\
                 (got: {other:?}) — see crate docs for flag reference"
            );
            std::process::exit(2);
        }
    };
    let action = match planned {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    // stage 2: one shared unknown-flag check, before any work runs
    if let Err(e) = args.check_unknown() {
        eprintln!("argument error: {e}");
        std::process::exit(2);
    }
    // stage 3: execute
    if let Err(e) = action() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
