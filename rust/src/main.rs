//! `bmf-pp` — the D-BMF+PP command-line launcher.
//!
//! Subcommands:
//!   train     run Posterior-Propagation BMF on a dataset (synthetic profile
//!             or CSV/MatrixMarket file), report RMSE + timings
//!   baseline  run a comparator (bmf | nomad | fpsgd) on the same data
//!   datasets  print Table-1 style statistics for the synthetic profiles
//!   partition analyse block grids for a dataset (Fig-3 style table)
//!   simulate  strong-scaling simulation on the calibrated cluster model
//!
//! Examples:
//!   bmf-pp train --dataset netflix --scale 0.002 --grid 4x2 --samples 20
//!   bmf-pp train --file ratings.csv --k 16 --grid 8x8
//!   bmf-pp baseline --method nomad --dataset movielens --scale 0.002
//!   bmf-pp simulate --dataset yahoo --grid 16x16 --max-nodes 16384

use bmf_pp::baselines::sgd_common::SgdConfig;
use bmf_pp::baselines::{fpsgd, nomad};
use bmf_pp::cluster::{calibrate, sim};
use bmf_pp::coordinator::backend::BlockBackend;
use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{BackendSpec, PpTrainer, SchedulerMode, TrainConfig};
use bmf_pp::data::generator::{DatasetProfile, SyntheticDataset};
use bmf_pp::data::loader;
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::data::sparse::Coo;
use bmf_pp::data::stats::DatasetStats;
use bmf_pp::gibbs::NativeGibbs;
use bmf_pp::metrics::throughput::Throughput;
use bmf_pp::partition::{balance, Grid};
use bmf_pp::util::cli::Args;
use bmf_pp::util::timer::{fmt_duration, fmt_hhmm, Stopwatch};

fn load_data(args: &Args) -> anyhow::Result<(Coo, usize)> {
    if let Some(file) = args.get("file") {
        let path = std::path::Path::new(file);
        let coo = if file.ends_with(".mtx") {
            loader::load_matrix_market(path)?
        } else {
            loader::load_csv(path, args.bool_or("one-based", false))?
        };
        let k = args.usize_or("k", 16);
        Ok((coo, k))
    } else {
        let name = args.get_or("dataset", "movielens").to_string();
        let scale = args.f64_or("scale", 0.002);
        let seed = args.u64_or("seed", 42);
        let ds = SyntheticDataset::by_name(&name, scale, seed)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset profile '{name}'"))?;
        let k = args.usize_or("k", ds.k);
        Ok((ds.ratings, k))
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let (data, k) = load_data(args)?;
    let (train, test) = holdout_split_covered(&data, args.f64_or("test-frac", 0.2), 7);
    let grid = args.grid_or("grid", (1, 1));
    let mut cfg = TrainConfig::new(k)
        .with_grid(grid.0, grid.1)
        .with_sweeps(args.usize_or("burnin", 8), args.usize_or("samples", 20))
        .with_workers(args.usize_or("workers", 1))
        .with_seed(args.u64_or("seed", 42))
        .with_tau(args.f64_or("tau", auto_tau(&train)));
    if args.bool_or("native", false) {
        cfg = cfg.with_backend(BackendSpec::Native);
    }
    cfg = cfg.with_scheduler(match args.get_or("scheduler", "dag") {
        "barrier" => SchedulerMode::Barrier,
        "dag" => SchedulerMode::Dag,
        other => anyhow::bail!("unknown scheduler '{other}' (barrier | dag)"),
    });
    cfg.block_parallelism = args.usize_or("block-parallelism", cfg.block_parallelism);
    cfg.phase_sample_frac = args.f64_or("phase-sample-frac", 1.0);
    let save_path = args.get("save").map(str::to_string);
    args.check_unknown().map_err(|e| anyhow::anyhow!(e))?;

    println!(
        "training D-BMF+PP: {}x{} matrix, {} ratings, K={k}, grid {}x{}",
        train.rows,
        train.cols,
        train.nnz(),
        grid.0,
        grid.1
    );
    let result = PpTrainer::new(cfg).train(&train)?;
    let rmse = result.rmse(&test);
    println!(
        "phases: a={} b={} c={} aggregate={} total={}",
        fmt_duration(result.timings.a),
        fmt_duration(result.timings.b),
        fmt_duration(result.timings.c),
        fmt_duration(result.timings.aggregate),
        fmt_duration(result.timings.total)
    );
    println!(
        "scheduling: compute {} / idle {} / phase-overlap {}",
        fmt_duration(result.stats.compute_secs),
        fmt_duration(result.stats.idle_secs),
        fmt_duration(result.stats.overlap_secs)
    );
    let tp = Throughput::measure(
        train.rows,
        train.cols,
        train.nnz(),
        result.stats.sweeps / result.stats.blocks.max(1),
        result.timings.total,
    );
    println!("throughput: {}", tp.format_table1());
    println!("test RMSE = {rmse:.4}  (wall-clock {})", fmt_hhmm(result.timings.total));
    if let Some(path) = save_path {
        bmf_pp::coordinator::checkpoint::save(&result, std::path::Path::new(&path))?;
        println!("checkpoint saved to {path}");
    }
    Ok(())
}

fn cmd_evaluate(args: &Args) -> anyhow::Result<()> {
    let ckpt = args
        .get("checkpoint")
        .ok_or_else(|| anyhow::anyhow!("--checkpoint <file> required"))?
        .to_string();
    let model = bmf_pp::coordinator::checkpoint::load(std::path::Path::new(&ckpt))?;
    let (data, _) = load_data(args)?;
    let (_, test) = holdout_split_covered(&data, args.f64_or("test-frac", 0.2), 7);
    args.check_unknown().map_err(|e| anyhow::anyhow!(e))?;
    println!("checkpoint {ckpt}: K={} grid {}x{}", model.k, model.grid.0, model.grid.1);
    println!("test RMSE = {:.4} over {} held-out ratings", model.rmse(&test), test.nnz());
    // calibration report using factor-posterior + residual variance
    let resid_var = 1.0 / auto_tau(&data);
    let report = bmf_pp::metrics::calibration::coverage(&test, &[1.0, 2.0, 3.0], |r, c| {
        let mu = model.predict(r, c);
        let sigma = (model.predict_variance(r, c) + resid_var).sqrt();
        (mu, sigma)
    });
    for (z, nominal, empirical) in report.rows {
        println!(
            "  ±{z:.0}σ coverage: {:.1}% (nominal {:.1}%)",
            empirical * 100.0,
            nominal * 100.0
        );
    }
    Ok(())
}

fn cmd_recommend_grid(args: &Args) -> anyhow::Result<()> {
    let name = args.get_or("dataset", "netflix").to_string();
    let profile = bmf_pp::data::generator::DatasetProfile::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
    let nodes = args.usize_or("nodes", 1024);
    let k = args.usize_or("k", profile.k);
    let max_aspect = args.f64_or("max-aspect", 8.0);
    args.check_unknown().map_err(|e| anyhow::anyhow!(e))?;
    let backend = BlockBackend::Native;
    let model = calibrate::calibrate(&backend, k.min(32));
    let (i, j) = bmf_pp::partition::balance::recommend_grid(
        &model,
        profile.paper_rows,
        profile.paper_cols,
        profile.paper_ratings,
        k,
        28,
        nodes,
        max_aspect,
    );
    println!(
        "{name} at {nodes} nodes, K={k}: recommended grid {i}x{j} (block aspect {:.2})",
        bmf_pp::partition::balance::block_aspect(profile.paper_rows, profile.paper_cols, i, j)
    );
    Ok(())
}

fn cmd_baseline(args: &Args) -> anyhow::Result<()> {
    let (data, k) = load_data(args)?;
    let (train, test) = holdout_split_covered(&data, args.f64_or("test-frac", 0.2), 7);
    let method = args.get_or("method", "fpsgd").to_string();
    let sw = Stopwatch::start();
    let rmse = match method.as_str() {
        "bmf" => {
            let sweeps = args.usize_or("sweeps", 30);
            let tau = args.f64_or("tau", auto_tau(&train));
            let mut g = NativeGibbs::new(&train, k, tau, args.u64_or("seed", 42));
            for _ in 0..sweeps {
                g.sweep();
            }
            g.rmse(&test)
        }
        "nomad" | "fpsgd" => {
            let cfg = SgdConfig::new(k)
                .with_epochs(args.usize_or("epochs", 20))
                .with_threads(args.usize_or("threads", 4))
                .with_seed(args.u64_or("seed", 42));
            let model = if method == "nomad" {
                nomad::train(&train, &cfg)
            } else {
                fpsgd::train(&train, &cfg)
            };
            model.rmse(&test)
        }
        other => anyhow::bail!("unknown method '{other}' (bmf | nomad | fpsgd)"),
    };
    args.check_unknown().map_err(|e| anyhow::anyhow!(e))?;
    println!("{method}: test RMSE = {rmse:.4} in {}", fmt_duration(sw.secs()));
    Ok(())
}

fn cmd_datasets(args: &Args) -> anyhow::Result<()> {
    let scale = args.f64_or("scale", 0.002);
    args.check_unknown().map_err(|e| anyhow::anyhow!(e))?;
    println!("synthetic dataset profiles at scale {scale} (paper Table 1 shape stats):");
    for p in DatasetProfile::all() {
        let eff_scale = match p.name {
            "amazon" => scale * 0.015,
            "yahoo" => scale * 0.2,
            _ => scale,
        };
        let ds = SyntheticDataset::generate(p.clone(), eff_scale, 42);
        let st = DatasetStats::compute(&ds.ratings);
        println!("{}  K={} (paper K={})", st.format_row(p.name), p.k, p.paper_k);
    }
    Ok(())
}

fn cmd_partition(args: &Args) -> anyhow::Result<()> {
    let (data, _) = load_data(args)?;
    let max_side = args.usize_or("max-side", 32);
    args.check_unknown().map_err(|e| anyhow::anyhow!(e))?;
    println!("grid analysis for {}x{} ({} ratings):", data.rows, data.cols, data.nnz());
    println!("{:<8} {:>10} {:>14} {:>12}", "grid", "aspect", "area/circum", "max-par");
    for (i, j) in balance::candidate_grids(max_side) {
        if i > data.rows || j > data.cols {
            continue;
        }
        let g = Grid::new(data.rows, data.cols, i, j);
        let (_, pb, pc) = g.phase_parallelism();
        println!(
            "{:<8} {:>10.2} {:>14.1} {:>12}",
            format!("{i}x{j}"),
            balance::block_aspect(data.rows, data.cols, i, j),
            balance::area_over_circumference(data.rows, data.cols, i, j),
            pb.max(pc)
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let name = args.get_or("dataset", "netflix").to_string();
    let profile = DatasetProfile::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
    let (gi, gj) = args.grid_or("grid", (4, 4));
    let max_nodes = args.usize_or("max-nodes", 16384);
    let sweeps = args.usize_or("sweeps", 28);
    let k = args.usize_or("k", profile.paper_k);
    args.check_unknown().map_err(|e| anyhow::anyhow!(e))?;

    let backend = BlockBackend::Native;
    let model = calibrate::calibrate(&backend, k.min(32));
    let grid = Grid::new(profile.paper_rows, profile.paper_cols, gi, gj);
    let nnz = sim::uniform_block_nnz(&grid, profile.paper_ratings);

    println!(
        "strong scaling, {name} ({}x{}, {} ratings), K={k}, grid {gi}x{gj}:",
        profile.paper_rows, profile.paper_cols, profile.paper_ratings
    );
    let mut pts = Vec::new();
    for p in sim::node_sweep(&grid, max_nodes) {
        let r = sim::simulate_pp(&model, &grid, &nnz, k, sweeps, sweeps, p);
        pts.push((p, r.total));
        println!(
            "  nodes={p:<7} wall={:<12} (a={} b={} c={})",
            fmt_hhmm(r.total),
            fmt_hhmm(r.phase_a),
            fmt_hhmm(r.phase_b),
            fmt_hhmm(r.phase_c)
        );
    }
    let front = sim::pareto_front(&pts);
    println!(
        "pareto: {}",
        front
            .iter()
            .map(|(p, t)| format!("{p}@{}", fmt_hhmm(*t)))
            .collect::<Vec<_>>()
            .join(" ")
    );
    Ok(())
}

fn main() {
    bmf_pp::util::logging::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("baseline") => cmd_baseline(&args),
        Some("datasets") => cmd_datasets(&args),
        Some("partition") => cmd_partition(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("evaluate") => cmd_evaluate(&args),
        Some("recommend-grid") => cmd_recommend_grid(&args),
        other => {
            eprintln!(
                "usage: bmf-pp <train|baseline|datasets|partition|simulate|evaluate|recommend-grid> [--flags]\n\
                 (got: {other:?}) — see crate docs for flag reference"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
