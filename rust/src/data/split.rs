//! Train/test splitting of rating matrices.

use super::sparse::Coo;
use crate::rng::Rng;

/// Split entries uniformly at random into (train, test) with `test_frac`
/// of observations held out. Both matrices keep the full dimensions.
pub fn holdout_split(coo: &Coo, test_frac: f64, seed: u64) -> (Coo, Coo) {
    assert!((0.0..1.0).contains(&test_frac));
    let mut rng = Rng::seed_from_u64(seed);
    let mut idx: Vec<usize> = (0..coo.nnz()).collect();
    rng.shuffle(&mut idx);
    let n_test = (coo.nnz() as f64 * test_frac) as usize;
    let mut train = Coo::new(coo.rows, coo.cols);
    let mut test = Coo::new(coo.rows, coo.cols);
    for (pos, &i) in idx.iter().enumerate() {
        let e = coo.entries[i];
        if pos < n_test {
            test.entries.push(e);
        } else {
            train.entries.push(e);
        }
    }
    (train, test)
}

/// Like `holdout_split` but guarantees every row and column with ≥2
/// observations keeps at least one training observation (avoids cold-start
/// rows distorting RMSE comparisons on small data).
pub fn holdout_split_covered(coo: &Coo, test_frac: f64, seed: u64) -> (Coo, Coo) {
    let (mut train, mut test) = holdout_split(coo, test_frac, seed);
    let mut row_cnt = vec![0usize; coo.rows];
    let mut col_cnt = vec![0usize; coo.cols];
    for e in &train.entries {
        row_cnt[e.row as usize] += 1;
        col_cnt[e.col as usize] += 1;
    }
    // move test entries back to train where they are a row/col's only hope
    let mut kept = Vec::with_capacity(test.entries.len());
    for e in test.entries.drain(..) {
        if row_cnt[e.row as usize] == 0 || col_cnt[e.col as usize] == 0 {
            row_cnt[e.row as usize] += 1;
            col_cnt[e.col as usize] += 1;
            train.entries.push(e);
        } else {
            kept.push(e);
        }
    }
    test.entries = kept;
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::SyntheticDataset;
    use crate::testing::prop;

    #[test]
    fn split_partitions_entries() {
        let d = SyntheticDataset::by_name("movielens", 0.002, 1).unwrap();
        let (tr, te) = holdout_split(&d.ratings, 0.2, 9);
        assert_eq!(tr.nnz() + te.nnz(), d.ratings.nnz());
        let want = (d.ratings.nnz() as f64 * 0.2) as usize;
        assert_eq!(te.nnz(), want);
    }

    #[test]
    fn covered_split_leaves_no_orphan_rows() {
        let d = SyntheticDataset::by_name("amazon", 0.00002, 2).unwrap();
        let (tr, te) = holdout_split_covered(&d.ratings, 0.25, 3);
        let mut row_cnt = vec![0usize; tr.rows];
        let mut col_cnt = vec![0usize; tr.cols];
        for e in &tr.entries {
            row_cnt[e.row as usize] += 1;
            col_cnt[e.col as usize] += 1;
        }
        for e in &te.entries {
            assert!(row_cnt[e.row as usize] > 0, "orphan row {}", e.row);
            assert!(col_cnt[e.col as usize] > 0, "orphan col {}", e.col);
        }
    }

    #[test]
    fn prop_split_is_a_partition() {
        prop::check(
            20,
            |g| {
                let rows = g.size(4, 60);
                let cols = g.size(4, 60);
                let mut coo = Coo::new(rows, cols);
                let n = g.size(1, rows * cols / 2);
                for _ in 0..n {
                    let r = g.usize_in(0, rows - 1);
                    let c = g.usize_in(0, cols - 1);
                    coo.push(r, c, g.f64_in(1.0, 5.0) as f32);
                }
                (coo, g.f64_in(0.0, 0.9))
            },
            |(coo, frac)| {
                let (tr, te) = holdout_split(coo, *frac, 5);
                if tr.nnz() + te.nnz() != coo.nnz() {
                    return Err("entry count not preserved".into());
                }
                // multiset equality via sorted triplets
                let mut a: Vec<_> =
                    coo.entries.iter().map(|e| (e.row, e.col, e.val.to_bits())).collect();
                let mut b: Vec<_> = tr
                    .entries
                    .iter()
                    .chain(&te.entries)
                    .map(|e| (e.row, e.col, e.val.to_bits()))
                    .collect();
                a.sort_unstable();
                b.sort_unstable();
                if a != b {
                    return Err("entries mutated by split".into());
                }
                Ok(())
            },
        );
    }
}
