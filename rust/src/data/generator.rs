//! Synthetic dataset generator with web-scale dataset profiles.
//!
//! The paper evaluates on Movielens-20M, Netflix, Yahoo-KDD11 and Amazon
//! (Table 1). Those corpora are not redistributable here, so we generate
//! latent-factor synthetic analogues matched on the statistics that drive
//! the paper's findings: #rows/#cols aspect ratio, ratings/row, rating
//! scale, and the per-dataset K. The generator plants ground-truth factors
//! U*, V* with Gaussian noise, so the Bayes-optimal RMSE is known and
//! method orderings are meaningful (DESIGN.md §Substitutions).
//!
//! A `scale` knob shrinks row/col counts while preserving ratings/row, so
//! the same profile runs laptop-size (benches) or larger (stress).

use super::sparse::Coo;
use crate::rng::{normal::StdNormal, Rng};

/// Statistical profile of a rating dataset (paper Table 1).
#[derive(Debug, Clone)]
pub struct DatasetProfile {
    /// Profile name ("movielens", "netflix", "yahoo", "amazon").
    pub name: &'static str,
    /// Full-size dimensions from the paper.
    pub paper_rows: usize,
    /// Full-size column count from the paper.
    pub paper_cols: usize,
    /// Full-size rating count from the paper.
    pub paper_ratings: usize,
    /// Rating scale (values are clamped into this range).
    pub min_rating: f32,
    /// Upper end of the rating scale.
    pub max_rating: f32,
    /// Latent dimension used in the paper for this dataset.
    pub paper_k: usize,
    /// Latent dimension this repo uses (paper K scaled for CPU budget).
    pub k: usize,
}

impl DatasetProfile {
    /// MovieLens-20M shape statistics.
    pub fn movielens() -> Self {
        DatasetProfile {
            name: "movielens",
            paper_rows: 138_500,
            paper_cols: 27_300,
            paper_ratings: 20_000_000,
            min_rating: 1.0,
            max_rating: 5.0,
            paper_k: 10,
            k: 8,
        }
    }

    /// Netflix-prize shape statistics.
    pub fn netflix() -> Self {
        DatasetProfile {
            name: "netflix",
            paper_rows: 480_200,
            paper_cols: 17_800,
            paper_ratings: 100_500_000,
            min_rating: 1.0,
            max_rating: 5.0,
            paper_k: 100,
            k: 16,
        }
    }

    /// Yahoo-Music R2 shape statistics.
    pub fn yahoo() -> Self {
        DatasetProfile {
            name: "yahoo",
            paper_rows: 1_000_000,
            paper_cols: 625_000,
            paper_ratings: 262_800_000,
            min_rating: 0.0,
            max_rating: 100.0,
            paper_k: 100,
            k: 16,
        }
    }

    /// Amazon-ratings shape statistics.
    pub fn amazon() -> Self {
        DatasetProfile {
            name: "amazon",
            paper_rows: 21_200_000,
            paper_cols: 9_700_000,
            paper_ratings: 82_500_000,
            min_rating: 1.0,
            max_rating: 5.0,
            paper_k: 10,
            k: 8,
        }
    }

    /// Profile by name, if known.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "movielens" => Some(Self::movielens()),
            "netflix" => Some(Self::netflix()),
            "yahoo" => Some(Self::yahoo()),
            "amazon" => Some(Self::amazon()),
            _ => None,
        }
    }

    /// All four paper profiles.
    pub fn all() -> Vec<Self> {
        vec![Self::movielens(), Self::netflix(), Self::yahoo(), Self::amazon()]
    }

    /// Paper's ratings/row statistic.
    pub fn ratings_per_row(&self) -> f64 {
        self.paper_ratings as f64 / self.paper_rows as f64
    }

    /// Paper's #rows/#cols statistic.
    pub fn aspect(&self) -> f64 {
        self.paper_rows as f64 / self.paper_cols as f64
    }

    /// Scaled dimensions: shrink rows/cols by `scale`, keep ratings/row.
    /// Column count is floored so blocks stay non-degenerate.
    pub fn scaled_dims(&self, scale: f64) -> (usize, usize, usize) {
        let rows = ((self.paper_rows as f64 * scale).round() as usize).max(64);
        let cols = ((self.paper_cols as f64 * scale).round() as usize).max(48);
        let ratings = (rows as f64 * self.ratings_per_row()) as usize;
        // cap density at 60% — web-scale data is sparse; tiny scales would
        // otherwise saturate the matrix and distort the workload
        let cap = (rows * cols) * 6 / 10;
        (rows, cols, ratings.min(cap))
    }
}

/// A generated dataset with known ground truth.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    /// The profile this instance was generated from.
    pub profile: DatasetProfile,
    /// The generated observations.
    pub ratings: Coo,
    /// Planted factors (row-major rows × k, cols × k).
    pub true_u: Vec<f32>,
    /// Planted column-side factors.
    pub true_v: Vec<f32>,
    /// Latent dimension of the planted factors.
    pub k: usize,
    /// Residual noise std used when generating.
    pub noise_std: f32,
}

impl SyntheticDataset {
    /// Generate a scaled instance of `profile`.
    ///
    /// Ratings are r = clamp(mid + span*(u·v)/k_norm + ε). Row/column
    /// popularity is skewed (Zipf-ish) to mimic real rating data: a few
    /// heavy users/items, a long tail.
    pub fn generate(profile: DatasetProfile, scale: f64, seed: u64) -> SyntheticDataset {
        let (rows, cols, target_nnz) = profile.scaled_dims(scale);
        let k = profile.k;
        let mut rng = Rng::seed_from_u64(seed);
        let mut norm = StdNormal::new();

        let sigma_factor = (1.0 / k as f64).sqrt();
        let true_u: Vec<f32> =
            (0..rows * k).map(|_| (norm.sample(&mut rng) * sigma_factor) as f32).collect();
        let true_v: Vec<f32> =
            (0..cols * k).map(|_| (norm.sample(&mut rng) * sigma_factor) as f32).collect();

        // popularity weights ~ 1/(rank)^0.7, sampled via inverse-CDF walk
        let row_w = zipf_weights(rows, 0.7);
        let col_w = zipf_weights(cols, 0.7);
        let row_cdf = cumsum(&row_w);
        let col_cdf = cumsum(&col_w);

        let mid = 0.5 * (profile.min_rating + profile.max_rating);
        let span = 0.5 * (profile.max_rating - profile.min_rating);
        // noise at 20% of span: strong signal but non-trivial Bayes error
        let noise_std = 0.2 * span;

        let mut coo = Coo::new(rows, cols);
        let mut seen = std::collections::HashSet::with_capacity(target_nnz * 2);
        let mut attempts = 0usize;
        while coo.nnz() < target_nnz && attempts < target_nnz * 20 {
            attempts += 1;
            let r = sample_cdf(&row_cdf, rng.uniform());
            let c = sample_cdf(&col_cdf, rng.uniform());
            let key = (r as u64) << 32 | c as u64;
            if !seen.insert(key) {
                continue;
            }
            let dot: f32 = (0..k).map(|j| true_u[r * k + j] * true_v[c * k + j]).sum();
            let raw = mid + span * dot + noise_std * norm.sample(&mut rng) as f32;
            let val = raw.clamp(profile.min_rating, profile.max_rating);
            coo.push(r, c, val);
        }

        SyntheticDataset { profile, ratings: coo, true_u, true_v, k, noise_std }
    }

    /// Convenience: named profile at scale.
    pub fn by_name(name: &str, scale: f64, seed: u64) -> Option<SyntheticDataset> {
        DatasetProfile::by_name(name).map(|p| Self::generate(p, scale, seed))
    }
}

fn zipf_weights(n: usize, alpha: f64) -> Vec<f64> {
    (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(alpha)).collect()
}

fn cumsum(w: &[f64]) -> Vec<f64> {
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    w.iter()
        .map(|x| {
            acc += x / total;
            acc
        })
        .collect()
}

fn sample_cdf(cdf: &[f64], u: f64) -> usize {
    match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
        Ok(i) => i,
        Err(i) => i.min(cdf.len() - 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_match_paper_table1() {
        let ml = DatasetProfile::movielens();
        assert!((ml.ratings_per_row() - 144.4).abs() < 1.0);
        assert!((ml.aspect() - 5.07).abs() < 0.1);
        let nf = DatasetProfile::netflix();
        assert!((nf.ratings_per_row() - 209.3).abs() < 1.0);
        assert!((nf.aspect() - 27.0).abs() < 0.3);
        let am = DatasetProfile::amazon();
        assert!((am.ratings_per_row() - 3.9).abs() < 0.2);
    }

    #[test]
    fn generation_is_deterministic() {
        let a = SyntheticDataset::by_name("movielens", 0.002, 7).unwrap();
        let b = SyntheticDataset::by_name("movielens", 0.002, 7).unwrap();
        assert_eq!(a.ratings.nnz(), b.ratings.nnz());
        assert_eq!(a.ratings.entries[0], b.ratings.entries[0]);
    }

    #[test]
    fn values_respect_scale() {
        let d = SyntheticDataset::by_name("yahoo", 0.0005, 3).unwrap();
        for e in &d.ratings.entries {
            assert!((0.0..=100.0).contains(&e.val));
        }
        assert!(d.ratings.nnz() > 1000);
    }

    #[test]
    fn no_duplicate_cells() {
        let d = SyntheticDataset::by_name("netflix", 0.001, 4).unwrap();
        let mut seen = std::collections::HashSet::new();
        for e in &d.ratings.entries {
            assert!(seen.insert((e.row, e.col)), "dup at {e:?}");
        }
    }

    #[test]
    fn signal_dominates_noise() {
        // planted factors should explain most of the variance: RMSE of the
        // ground-truth predictor ≈ noise_std, well under rating std
        let d = SyntheticDataset::by_name("movielens", 0.003, 5).unwrap();
        let k = d.k;
        let mut sse = 0.0f64;
        let mid = 3.0f32;
        let span = 2.0f32;
        for e in &d.ratings.entries {
            let (r, c) = (e.row as usize, e.col as usize);
            let dot: f32 = (0..k).map(|j| d.true_u[r * k + j] * d.true_v[c * k + j]).sum();
            let pred = (mid + span * dot).clamp(1.0, 5.0);
            sse += ((pred - e.val) as f64).powi(2);
        }
        let rmse = (sse / d.ratings.nnz() as f64).sqrt();
        assert!(rmse < 0.75, "ground-truth rmse {rmse} too high");
        // rating std for comparison
        let mean = d.ratings.mean();
        let var: f64 = d
            .ratings
            .entries
            .iter()
            .map(|e| (e.val as f64 - mean).powi(2))
            .sum::<f64>()
            / d.ratings.nnz() as f64;
        assert!(rmse < var.sqrt(), "planted signal should beat the mean predictor");
    }

    #[test]
    fn scaled_dims_preserve_ratings_per_row_until_cap() {
        let p = DatasetProfile::netflix();
        let (rows, cols, nnz) = p.scaled_dims(0.01);
        // expected = min(uncapped target, density cap)
        let uncapped = rows as f64 * p.ratings_per_row();
        let cap = (rows * cols) as f64 * 0.6;
        let want = uncapped.min(cap);
        assert!((nnz as f64 - want).abs() / want < 0.05, "nnz={nnz} want={want}");
        // and never exceeds the density ceiling
        assert!(nnz as f64 <= cap + 1.0);
    }
}
