//! Sparse matrix representations for rating data.
//!
//! `Coo` is the interchange/build format; `Csr` the compute format (row
//! iteration for the U-side; `Csr::transpose` yields the V-side). Block
//! extraction (`Coo::slice_block`) is what the Posterior-Propagation grid
//! partitioner uses.

/// One observed rating.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    /// Row index.
    pub row: u32,
    /// Column index.
    pub col: u32,
    /// Rating value.
    pub val: f32,
}

/// Coordinate-format sparse matrix.
#[derive(Debug, Clone, Default)]
pub struct Coo {
    /// Row count of the full matrix.
    pub rows: usize,
    /// Column count of the full matrix.
    pub cols: usize,
    /// Observed ratings, in insertion order.
    pub entries: Vec<Entry>,
}

impl Coo {
    /// An empty rows × cols matrix.
    pub fn new(rows: usize, cols: usize) -> Coo {
        Coo { rows, cols, entries: Vec::new() }
    }

    /// Append one observation.
    pub fn push(&mut self, row: usize, col: usize, val: f32) {
        debug_assert!(row < self.rows && col < self.cols);
        self.entries.push(Entry { row: row as u32, col: col as u32, val });
    }

    /// Number of observed entries.
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Density = nnz / (rows*cols); the paper's "sparsity" is 1/density.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Extract the sub-matrix [r0, r1) × [c0, c1) with re-based indices.
    pub fn slice_block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Coo {
        let mut out = Coo::new(r1 - r0, c1 - c0);
        for e in &self.entries {
            let (r, c) = (e.row as usize, e.col as usize);
            if r >= r0 && r < r1 && c >= c0 && c < c1 {
                out.push(r - r0, c - c0, e.val);
            }
        }
        out
    }

    /// Mean rating over observed entries.
    pub fn mean(&self) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        self.entries.iter().map(|e| e.val as f64).sum::<f64>() / self.nnz() as f64
    }

    /// Densify into row-major ratings + mask buffers of shape (pad_rows,
    /// pad_cols), zero-padded — the layout the AOT `sample_side` artifact
    /// consumes. `transpose=true` writes the transposed block (V-side).
    pub fn to_dense_padded(
        &self,
        pad_rows: usize,
        pad_cols: usize,
        transpose: bool,
    ) -> (Vec<f32>, Vec<f32>) {
        let (er, ec) = if transpose { (self.cols, self.rows) } else { (self.rows, self.cols) };
        assert!(er <= pad_rows && ec <= pad_cols, "block larger than pad target");
        let mut ratings = vec![0.0f32; pad_rows * pad_cols];
        let mut mask = vec![0.0f32; pad_rows * pad_cols];
        for e in &self.entries {
            let (mut r, mut c) = (e.row as usize, e.col as usize);
            if transpose {
                std::mem::swap(&mut r, &mut c);
            }
            ratings[r * pad_cols + c] = e.val;
            mask[r * pad_cols + c] = 1.0;
        }
        (ratings, mask)
    }
}

/// Compressed sparse row matrix.
#[derive(Debug, Clone)]
pub struct Csr {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row start offsets into `indices`/`values` (length rows + 1).
    pub indptr: Vec<usize>,
    /// Column index of each stored value.
    pub indices: Vec<u32>,
    /// Stored rating values.
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from COO (stable within-row order).
    pub fn from_coo(coo: &Coo) -> Csr {
        let mut counts = vec![0usize; coo.rows + 1];
        for e in &coo.entries {
            counts[e.row as usize + 1] += 1;
        }
        for i in 0..coo.rows {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut next = counts;
        let mut indices = vec![0u32; coo.nnz()];
        let mut values = vec![0.0f32; coo.nnz()];
        for e in &coo.entries {
            let slot = next[e.row as usize];
            indices[slot] = e.col;
            values[slot] = e.val;
            next[e.row as usize] += 1;
        }
        Csr { rows: coo.rows, cols: coo.cols, indptr, indices, values }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (column indices, values) of row i.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Number of stored entries in row `i`.
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// CSR of the transpose (i.e. CSC view of self) — the V-side access path.
    pub fn transpose(&self) -> Csr {
        let mut coo = Coo::new(self.cols, self.rows);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(*c as usize, r, *v);
            }
        }
        Csr::from_coo(&coo)
    }

    /// Copy rows [a, b) into a standalone CSR (column space unchanged) —
    /// the shard extraction used by within-block distributed workers.
    pub fn slice_rows(&self, a: usize, b: usize) -> Csr {
        assert!(a <= b && b <= self.rows);
        let (lo, hi) = (self.indptr[a], self.indptr[b]);
        Csr {
            rows: b - a,
            cols: self.cols,
            indptr: self.indptr[a..=b].iter().map(|p| p - lo).collect(),
            indices: self.indices[lo..hi].to_vec(),
            values: self.values[lo..hi].to_vec(),
        }
    }

    /// Convert back to COO (row-major entry order).
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.rows, self.cols);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(r, *c as usize, *v);
            }
        }
        coo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo {
        let mut c = Coo::new(3, 4);
        c.push(0, 1, 1.0);
        c.push(2, 3, 2.0);
        c.push(1, 0, 3.0);
        c.push(2, 0, 4.0);
        c
    }

    #[test]
    fn coo_basics() {
        let c = sample();
        assert_eq!(c.nnz(), 4);
        assert!((c.density() - 4.0 / 12.0).abs() < 1e-12);
        assert!((c.mean() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn csr_roundtrip() {
        let c = sample();
        let m = Csr::from_coo(&c);
        assert_eq!(m.nnz(), 4);
        let (cols, vals) = m.row(2);
        // within a row, order follows insertion order of COO entries
        let mut pairs: Vec<_> = cols.iter().zip(vals).collect();
        pairs.sort_by_key(|(c, _)| **c);
        assert_eq!(pairs.len(), 2);
        assert_eq!(*pairs[0].0, 0);
        assert_eq!(*pairs[0].1, 4.0);
        let back = m.to_coo();
        assert_eq!(back.nnz(), 4);
    }

    #[test]
    fn transpose_swaps() {
        let m = Csr::from_coo(&sample());
        let t = m.transpose();
        assert_eq!((t.rows, t.cols), (4, 3));
        assert_eq!(t.nnz(), 4);
        let (cols, vals) = t.row(0);
        let mut pairs: Vec<_> = cols.iter().zip(vals).collect();
        pairs.sort_by_key(|(c, _)| **c);
        assert_eq!(pairs, vec![(&1u32, &3.0f32), (&2u32, &4.0f32)]);
    }

    #[test]
    fn slice_block_rebases() {
        let c = sample();
        let b = c.slice_block(1, 3, 0, 2);
        assert_eq!((b.rows, b.cols), (2, 2));
        assert_eq!(b.nnz(), 2); // (1,0,3.0) -> (0,0), (2,0,4.0) -> (1,0)
        assert!(b.entries.iter().any(|e| e.row == 0 && e.col == 0 && e.val == 3.0));
        assert!(b.entries.iter().any(|e| e.row == 1 && e.col == 0 && e.val == 4.0));
    }

    #[test]
    fn dense_padded_layout_and_transpose() {
        let c = sample();
        let (r, m) = c.to_dense_padded(4, 5, false);
        assert_eq!(r.len(), 20);
        assert_eq!(r[0 * 5 + 1], 1.0);
        assert_eq!(m[2 * 5 + 3], 1.0);
        assert_eq!(m[3 * 5 + 4], 0.0); // padding
        let (rt, mt) = c.to_dense_padded(5, 4, true);
        assert_eq!(rt[1 * 4 + 0], 1.0); // (0,1) transposed to (1,0)
        assert_eq!(mt[3 * 4 + 2], 1.0); // (2,3) -> (3,2)
    }

    #[test]
    fn slice_rows_extracts_shard() {
        let m = Csr::from_coo(&sample());
        let shard = m.slice_rows(1, 3);
        assert_eq!((shard.rows, shard.cols), (2, 4));
        assert_eq!(shard.nnz(), 3);
        let (cols, vals) = shard.row(0); // original row 1
        assert_eq!((cols, vals), (&[0u32][..], &[3.0f32][..]));
        // shards concatenated cover the original
        let a = m.slice_rows(0, 1);
        let b = m.slice_rows(1, 3);
        assert_eq!(a.nnz() + b.nnz(), m.nnz());
    }

    #[test]
    fn mask_sum_equals_nnz() {
        let c = sample();
        let (_, m) = c.to_dense_padded(3, 4, false);
        assert_eq!(m.iter().sum::<f32>() as usize, c.nnz());
    }
}
