//! Dataset statistics — the rows of the paper's Table 1.

use super::sparse::Coo;

/// Table-1 style statistics for a rating matrix.
#[derive(Debug, Clone)]
pub struct DatasetStats {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Observed rating count.
    pub ratings: usize,
    /// Paper's "Sparsity": (#rows * #cols) / #ratings.
    pub sparsity: f64,
    /// Mean observations per row.
    pub ratings_per_row: f64,
    /// Aspect statistic #rows / #cols.
    pub rows_per_col: f64,
    /// Smallest observed value.
    pub min_val: f32,
    /// Largest observed value.
    pub max_val: f32,
    /// Mean observed value.
    pub mean_val: f64,
}

impl DatasetStats {
    /// Compute all statistics in one pass.
    pub fn compute(coo: &Coo) -> DatasetStats {
        let mut min_val = f32::INFINITY;
        let mut max_val = f32::NEG_INFINITY;
        for e in &coo.entries {
            min_val = min_val.min(e.val);
            max_val = max_val.max(e.val);
        }
        if coo.entries.is_empty() {
            min_val = 0.0;
            max_val = 0.0;
        }
        DatasetStats {
            rows: coo.rows,
            cols: coo.cols,
            ratings: coo.nnz(),
            sparsity: (coo.rows as f64 * coo.cols as f64) / coo.nnz().max(1) as f64,
            ratings_per_row: coo.nnz() as f64 / coo.rows.max(1) as f64,
            rows_per_col: coo.rows as f64 / coo.cols.max(1) as f64,
            min_val,
            max_val,
            mean_val: coo.mean(),
        }
    }

    /// One formatted row of a Table-1 style report.
    pub fn format_row(&self, name: &str) -> String {
        format!(
            "{name:<12} rows={:<9} cols={:<9} ratings={:<10} sparsity={:<10.1} r/row={:<8.1} rows/cols={:<6.2}",
            self.rows,
            self.cols,
            self.ratings,
            self.sparsity,
            self.ratings_per_row,
            self.rows_per_col
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{DatasetProfile, SyntheticDataset};

    #[test]
    fn stats_on_known_matrix() {
        let mut c = Coo::new(10, 5);
        c.push(0, 0, 1.0);
        c.push(1, 1, 5.0);
        let s = DatasetStats::compute(&c);
        assert_eq!(s.ratings, 2);
        assert_eq!(s.sparsity, 25.0);
        assert_eq!(s.ratings_per_row, 0.2);
        assert_eq!(s.rows_per_col, 2.0);
        assert_eq!(s.min_val, 1.0);
        assert_eq!(s.max_val, 5.0);
        assert_eq!(s.mean_val, 3.0);
    }

    #[test]
    fn synthetic_profiles_reproduce_table1_shape() {
        // scaled synthetics must preserve the two key Table-1 shape stats
        for p in DatasetProfile::all() {
            let scale = match p.name {
                "amazon" => 0.00003,
                "yahoo" => 0.0004,
                _ => 0.002,
            };
            let d = SyntheticDataset::generate(p.clone(), scale, 11);
            let s = DatasetStats::compute(&d.ratings);
            let aspect_err = (s.rows_per_col - p.aspect()).abs() / p.aspect();
            assert!(aspect_err < 0.35, "{}: aspect {} vs {}", p.name, s.rows_per_col, p.aspect());
            // ratings/row may be capped by density ceiling at tiny scales;
            // allow under- but not over-shoot
            assert!(
                s.ratings_per_row <= p.ratings_per_row() * 1.3,
                "{}: r/row {} vs {}",
                p.name,
                s.ratings_per_row,
                p.ratings_per_row()
            );
        }
    }

    #[test]
    fn empty_matrix_is_safe() {
        let s = DatasetStats::compute(&Coo::new(3, 3));
        assert_eq!(s.ratings, 0);
        assert_eq!(s.mean_val, 0.0);
    }
}
