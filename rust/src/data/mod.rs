//! Data substrate: sparse rating matrices, synthetic web-scale dataset
//! profiles (Movielens / Netflix / Yahoo / Amazon analogues), file loaders
//! and train/test splitting.

pub mod generator;
pub mod loader;
pub mod sparse;
pub mod split;
pub mod stats;

pub use generator::{DatasetProfile, SyntheticDataset};
pub use sparse::{Coo, Csr, Entry};
