//! File loaders for users with the real datasets: CSV triplets
//! (`row,col,value`, optional header) and MatrixMarket coordinate files.
//!
//! Every error names the offending file (and line, for parse errors), so
//! a failed multi-file pipeline run points straight at the bad input.

use super::sparse::Coo;
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Why a ratings file failed to load.
#[derive(Debug, thiserror::Error)]
pub enum LoadError {
    /// The file could not be read.
    #[error("{}: io error: {source}", path.display())]
    Io {
        /// The file that failed.
        path: PathBuf,
        /// The underlying I/O error.
        source: std::io::Error,
    },
    /// A line did not parse as a rating triplet.
    #[error("{}:{line}: {msg}", path.display())]
    Parse {
        /// The file that failed.
        path: PathBuf,
        /// 1-based line number.
        line: usize,
        /// What was wrong with the line.
        msg: String,
    },
}

fn io_err(path: &Path) -> impl Fn(std::io::Error) -> LoadError + '_ {
    move |source| LoadError::Io { path: path.to_path_buf(), source }
}

fn perr<T>(path: &Path, line: usize, msg: impl Into<String>) -> Result<T, LoadError> {
    Err(LoadError::Parse { path: path.to_path_buf(), line, msg: msg.into() })
}

/// Load `row,col,value` CSV (0- or 1-based ids auto-detected by `one_based`).
/// Dimensions are inferred as max index + 1.
pub fn load_csv(path: &Path, one_based: bool) -> Result<Coo, LoadError> {
    let f = std::fs::File::open(path).map_err(io_err(path))?;
    let reader = BufReader::new(f);
    let mut entries = Vec::new();
    let (mut max_r, mut max_c) = (0usize, 0usize);
    for (i, line) in reader.lines().enumerate() {
        let line = line.map_err(io_err(path))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = t.split([',', '\t', ' ']).filter(|s| !s.is_empty()).collect();
        if parts.len() < 3 {
            return perr(path, i + 1, format!("expected 3 fields, got {}", parts.len()));
        }
        // skip a header row
        if i == 0 && parts[0].parse::<usize>().is_err() {
            continue;
        }
        let r: usize = match parts[0].parse() {
            Ok(v) => v,
            Err(_) => return perr(path, i + 1, "bad row id"),
        };
        let c: usize = match parts[1].parse() {
            Ok(v) => v,
            Err(_) => return perr(path, i + 1, "bad col id"),
        };
        let v: f32 = match parts[2].parse() {
            Ok(v) => v,
            Err(_) => return perr(path, i + 1, "bad value"),
        };
        let off = usize::from(one_based);
        if one_based && (r == 0 || c == 0) {
            return perr(path, i + 1, "index 0 in one-based file");
        }
        let (r, c) = (r - off, c - off);
        max_r = max_r.max(r);
        max_c = max_c.max(c);
        entries.push((r, c, v));
    }
    let mut coo = Coo::new(max_r + 1, max_c + 1);
    for (r, c, v) in entries {
        coo.push(r, c, v);
    }
    Ok(coo)
}

/// Load a MatrixMarket coordinate file (`%%MatrixMarket matrix coordinate ...`).
pub fn load_matrix_market(path: &Path) -> Result<Coo, LoadError> {
    let f = std::fs::File::open(path).map_err(io_err(path))?;
    let reader = BufReader::new(f);
    let mut lines = reader.lines().enumerate();

    // header
    let (_, first) = match lines.next() {
        Some((i, l)) => (i, l.map_err(io_err(path))?),
        None => return perr(path, 0, "empty file"),
    };
    if !first.starts_with("%%MatrixMarket") {
        return perr(path, 1, "missing MatrixMarket banner");
    }
    if !first.contains("coordinate") {
        return perr(path, 1, "only coordinate format supported");
    }

    let mut dims: Option<(usize, usize, usize)> = None;
    let mut coo = Coo::new(0, 0);
    let mut count = 0usize;
    for (i, line) in lines {
        let line = line.map_err(io_err(path))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let parts: Vec<&str> = t.split_whitespace().collect();
        match dims {
            None => {
                if parts.len() != 3 {
                    return perr(path, i + 1, "bad size line");
                }
                let r = match parts[0].parse() {
                    Ok(v) => v,
                    Err(_) => return perr(path, i + 1, "bad rows"),
                };
                let c = match parts[1].parse() {
                    Ok(v) => v,
                    Err(_) => return perr(path, i + 1, "bad cols"),
                };
                let n = match parts[2].parse() {
                    Ok(v) => v,
                    Err(_) => return perr(path, i + 1, "bad nnz"),
                };
                dims = Some((r, c, n));
                coo = Coo::new(r, c);
            }
            Some((r, c, _)) => {
                if parts.len() < 2 {
                    return perr(path, i + 1, "bad entry");
                }
                let er: usize = match parts[0].parse() {
                    Ok(v) => v,
                    Err(_) => return perr(path, i + 1, "bad row"),
                };
                let ec: usize = match parts[1].parse() {
                    Ok(v) => v,
                    Err(_) => return perr(path, i + 1, "bad col"),
                };
                let v: f32 = if parts.len() >= 3 {
                    match parts[2].parse() {
                        Ok(v) => v,
                        Err(_) => return perr(path, i + 1, "bad val"),
                    }
                } else {
                    1.0 // pattern matrices
                };
                if er == 0 || ec == 0 || er > r || ec > c {
                    return perr(path, i + 1, "index out of bounds");
                }
                coo.push(er - 1, ec - 1, v);
                count += 1;
            }
        }
    }
    match dims {
        Some((_, _, n)) if n != count => {
            perr(path, 0, format!("nnz mismatch: header {n}, got {count}"))
        }
        Some(_) => Ok(coo),
        None => perr(path, 0, "missing size line"),
    }
}

/// Save as CSV triplets (for exporting synthetic data). Atomic: the rows
/// stream into a unique sibling temp file that is renamed over `path`
/// only after a successful flush, so a crash mid-write can never leave a
/// truncated CSV where a complete one was expected.
pub fn save_csv(coo: &Coo, path: &Path) -> std::io::Result<()> {
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        WRITE_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let tmp = PathBuf::from(tmp_name);
    let write = (|| {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        writeln!(f, "row,col,value")?;
        for e in &coo.entries {
            writeln!(f, "{},{},{}", e.row, e.col, e.val)?;
        }
        f.flush()
    })();
    let renamed = write.and_then(|()| std::fs::rename(&tmp, path));
    if renamed.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    renamed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, content: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("bmfpp_test_{name}_{}", std::process::id()));
        std::fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn csv_roundtrip() {
        let p = tmp("csv1", "row,col,value\n0,1,3.5\n2,0,1.0\n");
        let c = load_csv(&p, false).unwrap();
        assert_eq!((c.rows, c.cols, c.nnz()), (3, 2, 2));
        let out = std::env::temp_dir().join(format!("bmfpp_out_{}", std::process::id()));
        save_csv(&c, &out).unwrap();
        let c2 = load_csv(&out, false).unwrap();
        assert_eq!(c2.nnz(), 2);
        std::fs::remove_file(p).ok();
        std::fs::remove_file(out).ok();
    }

    #[test]
    fn csv_one_based_and_whitespace() {
        let p = tmp("csv2", "1 1 4.0\n2\t3\t5.0\n");
        let c = load_csv(&p, true).unwrap();
        assert_eq!((c.rows, c.cols), (2, 3));
        assert_eq!(c.entries[0].row, 0);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn csv_rejects_bad_lines() {
        let p = tmp("csv3", "0,1\n");
        assert!(load_csv(&p, false).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn errors_name_the_offending_file() {
        let missing = std::env::temp_dir().join("bmfpp_definitely_missing.csv");
        let err = load_csv(&missing, false).unwrap_err();
        assert!(
            err.to_string().contains("bmfpp_definitely_missing.csv"),
            "io error does not name the file: {err}"
        );
        let p = tmp("csv4", "0,notanumber,1.0\n");
        let err = load_csv(&p, false).unwrap_err();
        let rendered = err.to_string();
        assert!(rendered.contains("bmfpp_test_csv4"), "parse error lacks path: {rendered}");
        assert!(rendered.contains(":1:"), "parse error lacks line: {rendered}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn save_csv_is_atomic_and_leaves_no_temp() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.5);
        let dir = std::env::temp_dir()
            .join(format!("bmfpp_atomic_csv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("data.csv");
        save_csv(&coo, &out).unwrap();
        assert!(out.exists());
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        // writing into a missing directory errors without creating junk
        let bad = dir.join("no_such_subdir").join("x.csv");
        assert!(save_csv(&coo, &bad).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn matrix_market_ok() {
        let p = tmp(
            "mm1",
            "%%MatrixMarket matrix coordinate real general\n% comment\n3 4 2\n1 2 0.5\n3 4 -1\n",
        );
        let c = load_matrix_market(&p).unwrap();
        assert_eq!((c.rows, c.cols, c.nnz()), (3, 4, 2));
        assert_eq!(c.entries[1].val, -1.0);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn matrix_market_detects_nnz_mismatch() {
        let p = tmp("mm2", "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n");
        assert!(load_matrix_market(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn matrix_market_rejects_oob() {
        let p = tmp("mm3", "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n");
        assert!(load_matrix_market(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
