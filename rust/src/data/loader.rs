//! File loaders for users with the real datasets: CSV triplets
//! (`row,col,value`, optional header) and MatrixMarket coordinate files.

use super::sparse::Coo;
use std::io::{BufRead, BufReader};
use std::path::Path;

/// Why a ratings file failed to load.
#[derive(Debug, thiserror::Error)]
pub enum LoadError {
    /// The file could not be read.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// A line did not parse as a rating triplet.
    #[error("parse error at line {line}: {msg}")]
    Parse {
        /// 1-based line number.
        line: usize,
        /// What was wrong with the line.
        msg: String,
    },
}

fn perr<T>(line: usize, msg: impl Into<String>) -> Result<T, LoadError> {
    Err(LoadError::Parse { line, msg: msg.into() })
}

/// Load `row,col,value` CSV (0- or 1-based ids auto-detected by `one_based`).
/// Dimensions are inferred as max index + 1.
pub fn load_csv(path: &Path, one_based: bool) -> Result<Coo, LoadError> {
    let f = std::fs::File::open(path)?;
    let reader = BufReader::new(f);
    let mut entries = Vec::new();
    let (mut max_r, mut max_c) = (0usize, 0usize);
    for (i, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = t.split([',', '\t', ' ']).filter(|s| !s.is_empty()).collect();
        if parts.len() < 3 {
            return perr(i + 1, format!("expected 3 fields, got {}", parts.len()));
        }
        // skip a header row
        if i == 0 && parts[0].parse::<usize>().is_err() {
            continue;
        }
        let r: usize = match parts[0].parse() {
            Ok(v) => v,
            Err(_) => return perr(i + 1, "bad row id"),
        };
        let c: usize = match parts[1].parse() {
            Ok(v) => v,
            Err(_) => return perr(i + 1, "bad col id"),
        };
        let v: f32 = match parts[2].parse() {
            Ok(v) => v,
            Err(_) => return perr(i + 1, "bad value"),
        };
        let off = usize::from(one_based);
        if one_based && (r == 0 || c == 0) {
            return perr(i + 1, "index 0 in one-based file");
        }
        let (r, c) = (r - off, c - off);
        max_r = max_r.max(r);
        max_c = max_c.max(c);
        entries.push((r, c, v));
    }
    let mut coo = Coo::new(max_r + 1, max_c + 1);
    for (r, c, v) in entries {
        coo.push(r, c, v);
    }
    Ok(coo)
}

/// Load a MatrixMarket coordinate file (`%%MatrixMarket matrix coordinate ...`).
pub fn load_matrix_market(path: &Path) -> Result<Coo, LoadError> {
    let f = std::fs::File::open(path)?;
    let reader = BufReader::new(f);
    let mut lines = reader.lines().enumerate();

    // header
    let (_, first) = match lines.next() {
        Some((i, l)) => (i, l?),
        None => return perr(0, "empty file"),
    };
    if !first.starts_with("%%MatrixMarket") {
        return perr(1, "missing MatrixMarket banner");
    }
    if !first.contains("coordinate") {
        return perr(1, "only coordinate format supported");
    }

    let mut dims: Option<(usize, usize, usize)> = None;
    let mut coo = Coo::new(0, 0);
    let mut count = 0usize;
    for (i, line) in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let parts: Vec<&str> = t.split_whitespace().collect();
        match dims {
            None => {
                if parts.len() != 3 {
                    return perr(i + 1, "bad size line");
                }
                let r = parts[0].parse().map_err(|_| LoadError::Parse {
                    line: i + 1,
                    msg: "bad rows".into(),
                })?;
                let c = parts[1].parse().map_err(|_| LoadError::Parse {
                    line: i + 1,
                    msg: "bad cols".into(),
                })?;
                let n = parts[2].parse().map_err(|_| LoadError::Parse {
                    line: i + 1,
                    msg: "bad nnz".into(),
                })?;
                dims = Some((r, c, n));
                coo = Coo::new(r, c);
            }
            Some((r, c, _)) => {
                if parts.len() < 2 {
                    return perr(i + 1, "bad entry");
                }
                let er: usize = parts[0]
                    .parse()
                    .map_err(|_| LoadError::Parse { line: i + 1, msg: "bad row".into() })?;
                let ec: usize = parts[1]
                    .parse()
                    .map_err(|_| LoadError::Parse { line: i + 1, msg: "bad col".into() })?;
                let v: f32 = if parts.len() >= 3 {
                    parts[2]
                        .parse()
                        .map_err(|_| LoadError::Parse { line: i + 1, msg: "bad val".into() })?
                } else {
                    1.0 // pattern matrices
                };
                if er == 0 || ec == 0 || er > r || ec > c {
                    return perr(i + 1, "index out of bounds");
                }
                coo.push(er - 1, ec - 1, v);
                count += 1;
            }
        }
    }
    match dims {
        Some((_, _, n)) if n != count => perr(0, format!("nnz mismatch: header {n}, got {count}")),
        Some(_) => Ok(coo),
        None => perr(0, "missing size line"),
    }
}

/// Save as CSV triplets (for exporting synthetic data).
pub fn save_csv(coo: &Coo, path: &Path) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "row,col,value")?;
    for e in &coo.entries {
        writeln!(f, "{},{},{}", e.row, e.col, e.val)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, content: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("bmfpp_test_{name}_{}", std::process::id()));
        std::fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn csv_roundtrip() {
        let p = tmp("csv1", "row,col,value\n0,1,3.5\n2,0,1.0\n");
        let c = load_csv(&p, false).unwrap();
        assert_eq!((c.rows, c.cols, c.nnz()), (3, 2, 2));
        let out = std::env::temp_dir().join(format!("bmfpp_out_{}", std::process::id()));
        save_csv(&c, &out).unwrap();
        let c2 = load_csv(&out, false).unwrap();
        assert_eq!(c2.nnz(), 2);
        std::fs::remove_file(p).ok();
        std::fs::remove_file(out).ok();
    }

    #[test]
    fn csv_one_based_and_whitespace() {
        let p = tmp("csv2", "1 1 4.0\n2\t3\t5.0\n");
        let c = load_csv(&p, true).unwrap();
        assert_eq!((c.rows, c.cols), (2, 3));
        assert_eq!(c.entries[0].row, 0);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn csv_rejects_bad_lines() {
        let p = tmp("csv3", "0,1\n");
        assert!(load_csv(&p, false).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn matrix_market_ok() {
        let p = tmp(
            "mm1",
            "%%MatrixMarket matrix coordinate real general\n% comment\n3 4 2\n1 2 0.5\n3 4 -1\n",
        );
        let c = load_matrix_market(&p).unwrap();
        assert_eq!((c.rows, c.cols, c.nnz()), (3, 4, 2));
        assert_eq!(c.entries[1].val, -1.0);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn matrix_market_detects_nnz_mismatch() {
        let p = tmp("mm2", "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n");
        assert!(load_matrix_market(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn matrix_market_rejects_oob() {
        let p = tmp("mm3", "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n");
        assert!(load_matrix_market(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
