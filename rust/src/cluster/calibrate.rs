//! Calibration: fit the cluster model's compute coefficients from measured
//! block runs on the real backend, so the simulated scaling curves are
//! anchored to this machine's actual sampler throughput.
//!
//! The per-sweep cost model is  t = c_row·k³·(n+d) + c_rating·k²·2·nnz.
//! Two measurements with different (rows+cols) : nnz ratios give a 2×2
//! system for (c_row, c_rating); both are clamped positive.

use super::model::{BlockCost, ClusterModel};
use crate::coordinator::backend::{BlockBackend, BlockData};
use crate::coordinator::block_task::{run_block, BlockTaskCfg};
use crate::data::sparse::Coo;
use crate::rng::Rng;

/// Measure one synthetic block; returns seconds per sweep.
fn measure(backend: &BlockBackend, n: usize, d: usize, nnz: usize, k: usize, seed: u64) -> f64 {
    let mut rng = Rng::seed_from_u64(seed);
    let mut coo = Coo::new(n, d);
    let mut placed = 0usize;
    while placed < nnz {
        let r = rng.below(n);
        let c = rng.below(d);
        coo.push(r, c, (rng.uniform() * 4.0 + 1.0) as f32);
        placed += 1;
    }
    let data = BlockData::new(coo);
    let sweeps = 4usize;
    let cfg = BlockTaskCfg {
        k,
        tau: 2.0,
        burnin: sweeps - 2,
        samples: 2,
        workers: 1,
        ridge: 1e-2,
        seed,
        sweep: crate::coordinator::SweepMode::Lockstep,
        chunk_rows: 256,
        staleness: 0,
        precision: crate::gibbs::GibbsPrecision::F64,
    };
    let (_, stats) =
        run_block(backend, &data, &cfg, None, None, Default::default()).expect("calibration run");
    stats.secs / stats.sweeps as f64
}

/// Calibrate (c_row, c_rating) on the given backend; other model fields
/// keep their defaults.
pub fn calibrate(backend: &BlockBackend, k: usize) -> ClusterModel {
    let mut model = ClusterModel::default();
    // measurement A: row-heavy (few ratings), B: rating-heavy
    let (n, d) = (192, 192);
    let t_a = measure(backend, n, d, 400, k, 1001);
    let t_b = measure(backend, n, d, 8_000, k, 1002);

    let k3 = (k * k * k) as f64;
    let k2 = (k * k) as f64;
    let rows = (n + d) as f64;
    // t_a = c_row k3 rows + c_rating k2 2·400
    // t_b = c_row k3 rows + c_rating k2 2·8000
    let c_rating = ((t_b - t_a) / (k2 * 2.0 * (8_000.0 - 400.0))).max(1e-13);
    let c_row = ((t_a - c_rating * k2 * 2.0 * 400.0) / (k3 * rows)).max(1e-13);
    model.c_rating = c_rating;
    model.c_row = c_row;
    log::info!(
        "calibrated cluster model: c_row={:.3e} c_rating={:.3e} (t_a={t_a:.4}s t_b={t_b:.4}s)",
        c_row,
        c_rating
    );
    model
}

/// Predicted single-node seconds for a full dataset sweep set — a sanity
/// hook comparing model vs measurement.
pub fn predicted_secs(model: &ClusterModel, b: &BlockCost, k: usize, sweeps: usize) -> f64 {
    model.block_compute_secs(b, k, sweeps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_produces_positive_rates() {
        let backend = BlockBackend::Native;
        let m = calibrate(&backend, 8);
        assert!(m.c_row > 0.0 && m.c_rating > 0.0);
        assert!(m.c_row < 1e-3 && m.c_rating < 1e-3, "rates implausibly slow");
    }

    #[test]
    fn model_predicts_measurement_within_factor() {
        // calibrate, then check a third configuration is predicted within
        // a generous factor (cache effects etc.)
        let backend = BlockBackend::Native;
        let m = calibrate(&backend, 8);
        let t = measure(&backend, 256, 256, 4_000, 8, 7);
        let want = predicted_secs(
            &m,
            &BlockCost { rows: 256, cols: 256, nnz: 4_000 },
            8,
            1,
        );
        let ratio = t / want;
        assert!(
            (0.2..5.0).contains(&ratio),
            "model {want:.5}s vs measured {t:.5}s (ratio {ratio:.2})"
        );
    }
}
