//! Discrete-event simulation of the PP schedule on P nodes — regenerates
//! the paper's strong-scaling curves (Figs. 4-5).
//!
//! The schedule follows §3.4 of the paper: phase (a) is one block (all P
//! nodes, capped by within-block saturation); phase (b) runs its I+J-2
//! blocks in parallel waves; phase (c) its (I-1)(J-1) blocks. Node counts
//! that align with the phase parallelism (P = I+J-2, P = (I-1)(J-1))
//! avoid ragged waves — the run-time "drops" the paper observes.

use super::model::{BlockCost, ClusterModel, CommBackend};
use crate::coordinator::config::SweepMode;
use crate::partition::Grid;

/// Scheduling regime the simulator models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Full barrier between PP phases: phase (c) starts only when the
    /// slowest phase-(b) block has finished (the paper's Fig. 4/5 runs).
    Barrier,
    /// Dependency-driven dispatch: block (i,j) starts as soon as (i,0) and
    /// (0,j) are done and nodes are free — the barrier-free coordinator.
    Dag,
}

/// How the DAG schedule sizes a block's node group at dispatch time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WidthPolicy {
    /// Every block keeps the width the barrier schedule's LPT wave plan
    /// assigned it, whatever is free when it dispatches.
    #[default]
    Static,
    /// Node-group widths grow dynamically as blocks free nodes: a
    /// dispatching block takes `free / ready` nodes (at least its planned
    /// width, at most its saturation knee), so idle nodes left by a
    /// drained ready-queue — straggler tails, ragged last waves — are
    /// folded into the blocks that are actually runnable.
    Dynamic,
}

/// Simulated wall-clock of a full PP run.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    /// Wall-clock of phase (a).
    pub phase_a: f64,
    /// Wall-clock of phase (b) past phase (a).
    pub phase_b: f64,
    /// Wall-clock of phase (c) past phase (b).
    pub phase_c: f64,
    /// Total simulated wall-clock.
    pub total: f64,
    /// Aggregate node-seconds actually consumed (efficiency metric).
    pub node_secs: f64,
}

/// Wave partition of `n` LPT-sorted blocks over `p` nodes: a list of
/// (start index, group size, per-block width). Both schedule modes derive
/// their node-group widths from this single formula — group = min(p,
/// remaining), width = p / group — so they stay comparable by construction.
fn lpt_wave_widths(n: usize, p: usize) -> Vec<(usize, usize, usize)> {
    let p = p.max(1);
    let mut out = Vec::new();
    let mut idx = 0;
    while idx < n {
        let group = (n - idx).min(p);
        let w = (p / group).max(1);
        out.push((idx, group, w));
        idx += group;
    }
    out
}

/// One phase: distribute `blocks` over `p` nodes in waves.
///
/// Blocks are processed in parallel groups of g = min(p, #blocks); each
/// block in a group gets w = p / g nodes (the paper assigns node groups per
/// block). Returns (wall seconds, node-seconds).
fn simulate_phase(
    model: &ClusterModel,
    blocks: &[BlockCost],
    k: usize,
    sweeps: usize,
    p: usize,
) -> (f64, f64) {
    if blocks.is_empty() {
        return (0.0, 0.0);
    }
    let mut remaining: Vec<BlockCost> = blocks.to_vec();
    // longest blocks first: classic LPT wave packing
    remaining.sort_by(|a, b| {
        model
            .block_compute_secs(b, k, sweeps)
            .partial_cmp(&model.block_compute_secs(a, k, sweeps))
            .unwrap()
    });
    let mut wall = 0.0;
    let mut node_secs = 0.0;
    for (start, group, w) in lpt_wave_widths(remaining.len(), p) {
        let mut wave_time = 0.0f64;
        for b in &remaining[start..start + group] {
            let t = model.block_secs(b, k, sweeps, w);
            wave_time = wave_time.max(t);
            node_secs += t * w as f64;
        }
        wall += wave_time;
    }
    (wall, node_secs)
}

/// Derive the cluster model a within-block sweep regime implies:
/// lockstep half-sweeps pay the synchronizing MPI allgather after every
/// half-sweep; pipelined half-sweeps publish `chunks` chunks one-sidedly
/// (GASPI-style) while sampling continues, so all but the pipeline-drain
/// fraction (the last chunk, which has no compute left to hide behind)
/// of each exchange overlaps computation. Used so the Table-3 / Fig-4/5
/// projections reflect the coordinator's `SweepMode`.
pub fn model_for_sweep(base: &ClusterModel, sweep: SweepMode, chunks: usize) -> ClusterModel {
    let mut m = *base;
    match sweep {
        SweepMode::Lockstep => m.comm = CommBackend::Mpi,
        SweepMode::Pipelined => {
            m.comm = CommBackend::Gaspi;
            m.overlap = 1.0 - 1.0 / chunks.max(1) as f64;
        }
    }
    m
}

/// [`simulate_pp_mode`] with the exchange model of a sweep regime applied
/// (see [`model_for_sweep`]).
#[allow(clippy::too_many_arguments)]
pub fn simulate_pp_sweep(
    model: &ClusterModel,
    grid: &Grid,
    block_nnz: &[Vec<usize>],
    k: usize,
    sweeps_a: usize,
    sweeps_bc: usize,
    p: usize,
    mode: ScheduleMode,
    sweep: SweepMode,
    chunks: usize,
) -> SimResult {
    simulate_pp_mode(
        &model_for_sweep(model, sweep, chunks),
        grid,
        block_nnz,
        k,
        sweeps_a,
        sweeps_bc,
        p,
        mode,
    )
}

/// Simulate a full PP run over a partitioned workload under `mode`
/// (DAG widths stay static; see [`simulate_pp_mode_widths`]).
#[allow(clippy::too_many_arguments)]
pub fn simulate_pp_mode(
    model: &ClusterModel,
    grid: &Grid,
    block_nnz: &[Vec<usize>],
    k: usize,
    sweeps_a: usize,
    sweeps_bc: usize,
    p: usize,
    mode: ScheduleMode,
) -> SimResult {
    simulate_pp_mode_widths(
        model,
        grid,
        block_nnz,
        k,
        sweeps_a,
        sweeps_bc,
        p,
        mode,
        WidthPolicy::Static,
    )
}

/// [`simulate_pp_mode`] with an explicit DAG [`WidthPolicy`]. The barrier
/// schedule ignores the policy — its wave widths are fixed by
/// construction; under [`ScheduleMode::Dag`] with
/// [`WidthPolicy::Dynamic`], node-group widths grow as blocks free nodes.
#[allow(clippy::too_many_arguments)]
pub fn simulate_pp_mode_widths(
    model: &ClusterModel,
    grid: &Grid,
    block_nnz: &[Vec<usize>],
    k: usize,
    sweeps_a: usize,
    sweeps_bc: usize,
    p: usize,
    mode: ScheduleMode,
    policy: WidthPolicy,
) -> SimResult {
    match mode {
        ScheduleMode::Barrier => simulate_pp(model, grid, block_nnz, k, sweeps_a, sweeps_bc, p),
        ScheduleMode::Dag => {
            simulate_pp_dag(model, grid, block_nnz, k, sweeps_a, sweeps_bc, p, policy)
        }
    }
}

/// Simulate a full PP run over a partitioned workload.
///
/// `block_nnz[i][j]` gives each block's observation count (from a real
/// `Grid::split` or an estimate); `sweeps_a` applies to phase (a) and
/// `sweeps_bc` to phases (b)/(c) (sweep-reduction ablation).
pub fn simulate_pp(
    model: &ClusterModel,
    grid: &Grid,
    block_nnz: &[Vec<usize>],
    k: usize,
    sweeps_a: usize,
    sweeps_bc: usize,
    p: usize,
) -> SimResult {
    let cost = |i: usize, j: usize| {
        let (r, c) = grid.block_shape(crate::partition::BlockId { i, j });
        BlockCost { rows: r, cols: c, nnz: block_nnz[i][j] }
    };

    // phase (a)
    let (ta, na) = simulate_phase(model, &[cost(0, 0)], k, sweeps_a, p);

    // phase (b)
    let mut b_blocks = Vec::new();
    for i in 1..grid.i_blocks {
        b_blocks.push(cost(i, 0));
    }
    for j in 1..grid.j_blocks {
        b_blocks.push(cost(0, j));
    }
    let (tb, nb) = simulate_phase(model, &b_blocks, k, sweeps_bc, p);

    // phase (c)
    let mut c_blocks = Vec::new();
    for i in 1..grid.i_blocks {
        for j in 1..grid.j_blocks {
            c_blocks.push(cost(i, j));
        }
    }
    let (tc, nc) = simulate_phase(model, &c_blocks, k, sweeps_bc, p);

    SimResult {
        phase_a: ta,
        phase_b: tb,
        phase_c: tc,
        total: ta + tb + tc,
        node_secs: na + nb + nc,
    }
}

/// Event-driven simulation of the dependency-driven schedule: blocks are
/// DAG nodes ((i,0) and (0,j) depend on (0,0); (i,j) on those two) and a
/// ready block starts as soon as its node group fits in the free nodes —
/// phase-(c) blocks overlap phase-(b) stragglers exactly as the
/// coordinator's `DagScheduler` overlaps them.
///
/// Each block's *planned* width is the one the barrier schedule would
/// have assigned it (LPT waves, `w = p / group`), and dispatch follows
/// strict wave priority (a later-wave block never bypasses an earlier one
/// that is waiting for nodes). Under [`WidthPolicy::Static`] blocks keep
/// exactly those widths: removing the phase barriers can then only move
/// start times earlier, so the DAG schedule is never slower than the
/// barrier schedule, and strictly faster whenever a straggler block holds
/// a phase open. Under [`WidthPolicy::Dynamic`] a dispatching block may
/// additionally absorb nodes freed by finished blocks — its fair share of
/// the free pool (`free / ready`), capped at its saturation knee and only
/// taken when that strictly shrinks the block — which folds the idle
/// tails behind stragglers and ragged last waves back into useful width.
#[allow(clippy::too_many_arguments)]
fn simulate_pp_dag(
    model: &ClusterModel,
    grid: &Grid,
    block_nnz: &[Vec<usize>],
    k: usize,
    sweeps_a: usize,
    sweeps_bc: usize,
    p: usize,
    policy: WidthPolicy,
) -> SimResult {
    struct Node {
        deps: Vec<usize>,
        secs: f64,
        width: usize,
        phase: usize,
        cost: BlockCost,
        sweeps: usize,
    }
    let p = p.max(1);
    let cost = |i: usize, j: usize| {
        let (r, c) = grid.block_shape(crate::partition::BlockId { i, j });
        BlockCost { rows: r, cols: c, nnz: block_nnz[i][j] }
    };
    // per-block widths exactly as the barrier schedule would assign them
    // (LPT order, shared lpt_wave_widths formula)
    let wave_plan = |mut blocks: Vec<((usize, usize), BlockCost)>,
                     sweeps: usize|
     -> Vec<((usize, usize), usize, f64, BlockCost)> {
        blocks.sort_by(|a, b| {
            model
                .block_compute_secs(&b.1, k, sweeps)
                .partial_cmp(&model.block_compute_secs(&a.1, k, sweeps))
                .unwrap()
        });
        let mut out = Vec::with_capacity(blocks.len());
        for (start, group, w) in lpt_wave_widths(blocks.len(), p) {
            for (key, b) in &blocks[start..start + group] {
                out.push((*key, w, model.block_secs(b, k, sweeps, w), *b));
            }
        }
        out
    };

    // nodes in priority order: (a), then phase (b) in wave order, then (c)
    let mut nodes = vec![Node {
        deps: Vec::new(),
        secs: model.block_secs(&cost(0, 0), k, sweeps_a, p),
        width: p,
        phase: 0,
        cost: cost(0, 0),
        sweeps: sweeps_a,
    }];
    let mut b_blocks = Vec::new();
    for i in 1..grid.i_blocks {
        b_blocks.push(((i, 0), cost(i, 0)));
    }
    for j in 1..grid.j_blocks {
        b_blocks.push(((0, j), cost(0, j)));
    }
    let mut row_id = vec![0usize; grid.i_blocks];
    let mut col_id = vec![0usize; grid.j_blocks];
    for ((i, j), w, secs, bc) in wave_plan(b_blocks, sweeps_bc) {
        if j == 0 {
            row_id[i] = nodes.len();
        } else {
            col_id[j] = nodes.len();
        }
        nodes.push(Node { deps: vec![0], secs, width: w, phase: 1, cost: bc, sweeps: sweeps_bc });
    }
    let mut c_blocks = Vec::new();
    for i in 1..grid.i_blocks {
        for j in 1..grid.j_blocks {
            c_blocks.push(((i, j), cost(i, j)));
        }
    }
    for ((i, j), w, secs, bc) in wave_plan(c_blocks, sweeps_bc) {
        nodes.push(Node {
            deps: vec![row_id[i], col_id[j]],
            secs,
            width: w,
            phase: 2,
            cost: bc,
            sweeps: sweeps_bc,
        });
    }

    let n = nodes.len();
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut unmet: Vec<usize> = vec![0; n];
    for (id, nd) in nodes.iter().enumerate() {
        unmet[id] = nd.deps.len();
        for &d in &nd.deps {
            dependents[d].push(id);
        }
    }
    let mut ready: Vec<usize> = (0..n).filter(|&id| unmet[id] == 0).collect();
    let mut running: Vec<(f64, usize, usize)> = Vec::new(); // (finish, id, width)
    let mut free = p;
    let mut now = 0.0f64;
    let mut node_secs = 0.0f64;
    let mut phase_finish = [0.0f64; 3];
    let mut done = 0usize;
    while done < n {
        // dispatch strictly in priority (wave) order; stop at the first
        // ready block whose node group does not fit — no bypassing
        ready.sort_unstable();
        while let Some(&id) = ready.first() {
            let planned = nodes[id].width;
            if planned > free {
                break;
            }
            ready.remove(0);
            let (w, secs) = match policy {
                WidthPolicy::Static => (planned, nodes[id].secs),
                WidthPolicy::Dynamic => {
                    // fair share of the free pool among everything
                    // runnable right now, never below the planned width,
                    // never past the block's strong-scaling knee, and
                    // only taken when it strictly shrinks the block
                    let fair = free / (ready.len() + 1);
                    let sat = model.saturation_nodes(&nodes[id].cost, k, nodes[id].sweeps);
                    let w_dyn = planned.max(fair.min(sat));
                    let s_dyn =
                        model.block_secs(&nodes[id].cost, k, nodes[id].sweeps, w_dyn);
                    if w_dyn > planned && s_dyn < nodes[id].secs {
                        (w_dyn, s_dyn)
                    } else {
                        (planned, nodes[id].secs)
                    }
                }
            };
            free -= w;
            node_secs += secs * w as f64;
            running.push((now + secs, id, w));
        }
        // advance to the earliest completion
        let mut best = 0usize;
        for (i, r) in running.iter().enumerate() {
            if r.0 < running[best].0 {
                best = i;
            }
        }
        let (t, id, w) = running.swap_remove(best);
        now = t;
        free += w;
        done += 1;
        let ph = nodes[id].phase;
        phase_finish[ph] = phase_finish[ph].max(now);
        for &child in &dependents[id] {
            unmet[child] -= 1;
            if unmet[child] == 0 {
                ready.push(child);
            }
        }
    }
    let fa = phase_finish[0];
    let fb = phase_finish[1].max(fa);
    let fc = phase_finish[2].max(fb);
    SimResult { phase_a: fa, phase_b: fb - fa, phase_c: fc - fb, total: fc, node_secs }
}

/// Uniform block-nnz estimate when no real split is available: distributes
/// `total_nnz` proportionally to block area.
pub fn uniform_block_nnz(grid: &Grid, total_nnz: usize) -> Vec<Vec<usize>> {
    let total_area = (grid.rows * grid.cols) as f64;
    (0..grid.i_blocks)
        .map(|i| {
            (0..grid.j_blocks)
                .map(|j| {
                    let (r, c) = grid.block_shape(crate::partition::BlockId { i, j });
                    ((r * c) as f64 / total_area * total_nnz as f64) as usize
                })
                .collect()
        })
        .collect()
}

/// Sweep node counts (powers of two plus phase-aligned points) for one grid.
pub fn node_sweep(grid: &Grid, max_nodes: usize) -> Vec<usize> {
    let mut pts = Vec::new();
    let mut p = 1usize;
    while p <= max_nodes {
        pts.push(p);
        p *= 2;
    }
    let (_, pb, pc) = grid.phase_parallelism();
    for aligned in [pb, pc, pb * 2, pc * 2] {
        if aligned >= 1 && aligned <= max_nodes {
            pts.push(aligned);
        }
    }
    pts.sort_unstable();
    pts.dedup();
    pts
}

/// Pareto front of (nodes, time): points where no other point has both
/// fewer-or-equal nodes and strictly less time (the paper's blue dots).
pub fn pareto_front(points: &[(usize, f64)]) -> Vec<(usize, f64)> {
    let mut sorted = points.to_vec();
    sorted.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.partial_cmp(&b.1).unwrap()));
    let mut front = Vec::new();
    let mut best = f64::INFINITY;
    for (p, t) in sorted {
        if t < best {
            best = t;
            front.push((p, t));
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(i: usize, j: usize) -> (ClusterModel, Grid, Vec<Vec<usize>>) {
        let model = ClusterModel::default();
        let grid = Grid::new(480_000, 17_800, i, j);
        let nnz = uniform_block_nnz(&grid, 100_000_000);
        (model, grid, nnz)
    }

    #[test]
    fn more_nodes_never_slower() {
        let (m, g, nnz) = setup(4, 4);
        let mut last = f64::INFINITY;
        for p in [1usize, 2, 4, 8, 16, 64, 256] {
            let r = simulate_pp(&m, &g, &nnz, 16, 20, 20, p);
            assert!(r.total <= last * 1.0001, "p={p}: {} > {last}", r.total);
            last = r.total;
        }
    }

    #[test]
    fn more_blocks_cost_more_total_compute() {
        // paper §3.4: same node count + more blocks → more wall-clock,
        // because every factor row is re-sampled once per block that
        // touches it (the row/K³ term multiplies with the grid; the
        // per-rating term is grid-invariant).
        let (m, g1, n1) = setup(1, 1);
        let (_, g8, n8) = setup(8, 8);
        let r1 = simulate_pp(&m, &g1, &n1, 16, 20, 20, 1);
        let r8 = simulate_pp(&m, &g8, &n8, 16, 20, 20, 1);
        assert!(
            r8.node_secs > 1.2 * r1.node_secs,
            "8x8 node-secs {} vs 1x1 {}",
            r8.node_secs,
            r1.node_secs
        );
        // with a high-K workload the row term dominates and the gap widens
        let r1k = simulate_pp(&m, &g1, &n1, 64, 20, 20, 1);
        let r8k = simulate_pp(&m, &g8, &n8, 64, 20, 20, 1);
        assert!(r8k.node_secs / r1k.node_secs > r8.node_secs / r1.node_secs);
    }

    #[test]
    fn bigger_grids_scale_further() {
        // at high node counts, a larger grid should beat 1x1 (which
        // saturates at the within-block cap)
        let (m, g1, n1) = setup(1, 1);
        let (_, g16, n16) = setup(16, 16);
        let p = 4096;
        let r1 = simulate_pp(&m, &g1, &n1, 16, 20, 20, p);
        let r16 = simulate_pp(&m, &g16, &n16, 16, 20, 20, p);
        assert!(
            r16.total < r1.total,
            "16x16 at p={p}: {} should beat 1x1 {}",
            r16.total,
            r1.total
        );
    }

    #[test]
    fn phase_alignment_gives_drop() {
        // crossing P = (I-1)(J-1) removes the ragged last wave of phase c
        let (m, g, nnz) = setup(5, 5);
        let pc = 16; // (5-1)*(5-1)
        let before = simulate_pp(&m, &g, &nnz, 16, 20, 20, pc - 1);
        let at = simulate_pp(&m, &g, &nnz, 16, 20, 20, pc);
        assert!(at.phase_c < before.phase_c, "no drop at aligned node count");
    }

    #[test]
    fn dag_schedule_never_materially_slower_than_barrier() {
        let (m, g, nnz) = setup(4, 4);
        for p in [1usize, 2, 4, 6, 8, 16, 64, 256] {
            let bar = simulate_pp_mode(&m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Barrier);
            let dag = simulate_pp_mode(&m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Dag);
            assert!(
                dag.total <= bar.total * 1.05,
                "p={p}: dag {} vs barrier {}",
                dag.total,
                bar.total
            );
        }
    }

    #[test]
    fn dag_schedule_beats_barrier_on_straggler_blocks() {
        // one phase-(b) block carries 10x the observations: the barrier
        // schedule stalls phase (c) behind it, the DAG schedule overlaps
        let (m, g, mut nnz) = setup(4, 4);
        nnz[1][0] *= 10;
        let p = 6; // = I+J-2: every phase-(b) block in flight at once
        let bar = simulate_pp_mode(&m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Barrier);
        let dag = simulate_pp_mode(&m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Dag);
        assert!(dag.total < bar.total, "dag {} vs barrier {}", dag.total, bar.total);
    }

    #[test]
    fn dag_schedule_matches_barrier_at_one_node() {
        // sequential execution: both schedules run every block back to back
        let (m, g, nnz) = setup(3, 3);
        let bar = simulate_pp_mode(&m, &g, &nnz, 16, 20, 20, 1, ScheduleMode::Barrier);
        let dag = simulate_pp_mode(&m, &g, &nnz, 16, 20, 20, 1, ScheduleMode::Dag);
        assert!((dag.total - bar.total).abs() < 1e-9 * bar.total.max(1.0));
        assert!((dag.node_secs - bar.node_secs).abs() < 1e-9 * bar.node_secs.max(1.0));
    }

    #[test]
    fn dynamic_widths_never_slower_than_static() {
        // across grids, node counts, and a straggler, letting ready blocks
        // absorb freed nodes must never cost wall-clock (same tolerance as
        // the barrier-vs-dag assert)
        for (gi, gj) in [(3usize, 3usize), (4, 4), (5, 2)] {
            let (m, g, mut nnz) = setup(gi, gj);
            nnz[1][0] *= 6; // phase-(b) straggler leaves idle tails behind
            for p in [1usize, 2, 4, 8, 16, 64] {
                let stat = simulate_pp_mode_widths(
                    &m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Dag, WidthPolicy::Static,
                );
                let dynw = simulate_pp_mode_widths(
                    &m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Dag, WidthPolicy::Dynamic,
                );
                assert!(
                    dynw.total <= stat.total * 1.05,
                    "{gi}x{gj} p={p}: dynamic {} vs static {}",
                    dynw.total,
                    stat.total
                );
            }
        }
    }

    #[test]
    fn dynamic_widths_fold_idle_nodes_into_straggler_tails() {
        // 3x3 with a 10x phase-(b) straggler at p=4: statically, the c
        // blocks released by the straggler run at their planned width 1
        // while 2-3 nodes idle; dynamically they absorb the free nodes
        // and the tail shrinks strictly
        let (m, g, mut nnz) = setup(3, 3);
        nnz[1][0] *= 10;
        let p = 4;
        let stat = simulate_pp_mode_widths(
            &m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Dag, WidthPolicy::Static,
        );
        let dynw = simulate_pp_mode_widths(
            &m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Dag, WidthPolicy::Dynamic,
        );
        assert!(
            dynw.total < stat.total,
            "dynamic {} should beat static {}",
            dynw.total,
            stat.total
        );
        // widened groups consume more node-seconds, never fewer
        assert!(dynw.node_secs >= stat.node_secs * 0.999);
    }

    #[test]
    fn dynamic_widths_match_static_at_one_node() {
        // with a single node there is never anything free to absorb
        let (m, g, nnz) = setup(3, 3);
        let stat = simulate_pp_mode_widths(
            &m, &g, &nnz, 16, 20, 20, 1, ScheduleMode::Dag, WidthPolicy::Static,
        );
        let dynw = simulate_pp_mode_widths(
            &m, &g, &nnz, 16, 20, 20, 1, ScheduleMode::Dag, WidthPolicy::Dynamic,
        );
        assert!((dynw.total - stat.total).abs() < 1e-12 * stat.total.max(1.0));
    }

    #[test]
    fn pipelined_exchange_never_slower_and_wins_at_scale() {
        let (m, g, nnz) = setup(4, 4);
        for p in [1usize, 2, 8, 64, 256, 1024] {
            let lock = simulate_pp_sweep(
                &m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Barrier, SweepMode::Lockstep, 16,
            );
            let pipe = simulate_pp_sweep(
                &m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Barrier, SweepMode::Pipelined, 16,
            );
            assert!(
                pipe.total <= lock.total * (1.0 + 1e-9),
                "p={p}: pipelined {} vs lockstep {}",
                pipe.total,
                lock.total
            );
        }
        // single node: no within-block exchange at all, identical times
        let lock1 = simulate_pp_sweep(
            &m, &g, &nnz, 16, 20, 20, 1, ScheduleMode::Barrier, SweepMode::Lockstep, 16,
        );
        let pipe1 = simulate_pp_sweep(
            &m, &g, &nnz, 16, 20, 20, 1, ScheduleMode::Barrier, SweepMode::Pipelined, 16,
        );
        assert!((lock1.total - pipe1.total).abs() < 1e-9 * lock1.total.max(1.0));
        // at high node counts the exchange dominates, so hiding it must
        // show up as a strict win
        let lock_hi = simulate_pp_sweep(
            &m, &g, &nnz, 16, 20, 20, 1024, ScheduleMode::Barrier, SweepMode::Lockstep, 16,
        );
        let pipe_hi = simulate_pp_sweep(
            &m, &g, &nnz, 16, 20, 20, 1024, ScheduleMode::Barrier, SweepMode::Pipelined, 16,
        );
        assert!(pipe_hi.total < lock_hi.total, "{} vs {}", pipe_hi.total, lock_hi.total);
    }

    #[test]
    fn finer_chunks_hide_more_of_the_exchange() {
        let (m, g, nnz) = setup(4, 4);
        let p = 256;
        let coarse = simulate_pp_sweep(
            &m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Barrier, SweepMode::Pipelined, 2,
        );
        let fine = simulate_pp_sweep(
            &m, &g, &nnz, 16, 20, 20, p, ScheduleMode::Barrier, SweepMode::Pipelined, 64,
        );
        assert!(fine.total <= coarse.total * (1.0 + 1e-9), "{} vs {}", fine.total, coarse.total);
    }

    #[test]
    fn pareto_front_is_monotone() {
        let pts = vec![(1, 100.0), (2, 60.0), (4, 70.0), (8, 30.0), (16, 30.0)];
        let front = pareto_front(&pts);
        assert_eq!(front, vec![(1, 100.0), (2, 60.0), (8, 30.0)]);
    }

    #[test]
    fn node_sweep_contains_alignment_points() {
        let g = Grid::new(1000, 1000, 5, 5);
        let pts = node_sweep(&g, 1000);
        assert!(pts.contains(&8)); // I+J-2
        assert!(pts.contains(&16)); // (I-1)(J-1)
    }
}
