//! Discrete-event simulation of the PP schedule on P nodes — regenerates
//! the paper's strong-scaling curves (Figs. 4-5).
//!
//! The schedule follows §3.4 of the paper: phase (a) is one block (all P
//! nodes, capped by within-block saturation); phase (b) runs its I+J-2
//! blocks in parallel waves; phase (c) its (I-1)(J-1) blocks. Node counts
//! that align with the phase parallelism (P = I+J-2, P = (I-1)(J-1))
//! avoid ragged waves — the run-time "drops" the paper observes.

use super::model::{BlockCost, ClusterModel};
use crate::partition::Grid;

/// Simulated wall-clock of a full PP run.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    pub phase_a: f64,
    pub phase_b: f64,
    pub phase_c: f64,
    pub total: f64,
    /// Aggregate node-seconds actually consumed (efficiency metric).
    pub node_secs: f64,
}

/// One phase: distribute `blocks` over `p` nodes in waves.
///
/// Blocks are processed in parallel groups of g = min(p, #blocks); each
/// block in a group gets w = p / g nodes (the paper assigns node groups per
/// block). Returns (wall seconds, node-seconds).
fn simulate_phase(model: &ClusterModel, blocks: &[BlockCost], k: usize, sweeps: usize, p: usize) -> (f64, f64) {
    if blocks.is_empty() {
        return (0.0, 0.0);
    }
    let mut remaining: Vec<BlockCost> = blocks.to_vec();
    // longest blocks first: classic LPT wave packing
    remaining.sort_by(|a, b| {
        model
            .block_compute_secs(b, k, sweeps)
            .partial_cmp(&model.block_compute_secs(a, k, sweeps))
            .unwrap()
    });
    let mut wall = 0.0;
    let mut node_secs = 0.0;
    let mut idx = 0;
    while idx < remaining.len() {
        let group = (remaining.len() - idx).min(p.max(1));
        let w = (p / group).max(1);
        let mut wave_time = 0.0f64;
        for b in &remaining[idx..idx + group] {
            let t = model.block_secs(b, k, sweeps, w);
            wave_time = wave_time.max(t);
            node_secs += t * w as f64;
        }
        wall += wave_time;
        idx += group;
    }
    (wall, node_secs)
}

/// Simulate a full PP run over a partitioned workload.
///
/// `block_nnz[i][j]` gives each block's observation count (from a real
/// `Grid::split` or an estimate); `sweeps_a` applies to phase (a) and
/// `sweeps_bc` to phases (b)/(c) (sweep-reduction ablation).
pub fn simulate_pp(
    model: &ClusterModel,
    grid: &Grid,
    block_nnz: &[Vec<usize>],
    k: usize,
    sweeps_a: usize,
    sweeps_bc: usize,
    p: usize,
) -> SimResult {
    let cost = |i: usize, j: usize| {
        let (r, c) = grid.block_shape(crate::partition::BlockId { i, j });
        BlockCost { rows: r, cols: c, nnz: block_nnz[i][j] }
    };

    // phase (a)
    let (ta, na) = simulate_phase(model, &[cost(0, 0)], k, sweeps_a, p);

    // phase (b)
    let mut b_blocks = Vec::new();
    for i in 1..grid.i_blocks {
        b_blocks.push(cost(i, 0));
    }
    for j in 1..grid.j_blocks {
        b_blocks.push(cost(0, j));
    }
    let (tb, nb) = simulate_phase(model, &b_blocks, k, sweeps_bc, p);

    // phase (c)
    let mut c_blocks = Vec::new();
    for i in 1..grid.i_blocks {
        for j in 1..grid.j_blocks {
            c_blocks.push(cost(i, j));
        }
    }
    let (tc, nc) = simulate_phase(model, &c_blocks, k, sweeps_bc, p);

    SimResult {
        phase_a: ta,
        phase_b: tb,
        phase_c: tc,
        total: ta + tb + tc,
        node_secs: na + nb + nc,
    }
}

/// Uniform block-nnz estimate when no real split is available: distributes
/// `total_nnz` proportionally to block area.
pub fn uniform_block_nnz(grid: &Grid, total_nnz: usize) -> Vec<Vec<usize>> {
    let total_area = (grid.rows * grid.cols) as f64;
    (0..grid.i_blocks)
        .map(|i| {
            (0..grid.j_blocks)
                .map(|j| {
                    let (r, c) = grid.block_shape(crate::partition::BlockId { i, j });
                    ((r * c) as f64 / total_area * total_nnz as f64) as usize
                })
                .collect()
        })
        .collect()
}

/// Sweep node counts (powers of two plus phase-aligned points) for one grid.
pub fn node_sweep(grid: &Grid, max_nodes: usize) -> Vec<usize> {
    let mut pts = Vec::new();
    let mut p = 1usize;
    while p <= max_nodes {
        pts.push(p);
        p *= 2;
    }
    let (_, pb, pc) = grid.phase_parallelism();
    for aligned in [pb, pc, pb * 2, pc * 2] {
        if aligned >= 1 && aligned <= max_nodes {
            pts.push(aligned);
        }
    }
    pts.sort_unstable();
    pts.dedup();
    pts
}

/// Pareto front of (nodes, time): points where no other point has both
/// fewer-or-equal nodes and strictly less time (the paper's blue dots).
pub fn pareto_front(points: &[(usize, f64)]) -> Vec<(usize, f64)> {
    let mut sorted = points.to_vec();
    sorted.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.partial_cmp(&b.1).unwrap()));
    let mut front = Vec::new();
    let mut best = f64::INFINITY;
    for (p, t) in sorted {
        if t < best {
            best = t;
            front.push((p, t));
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(i: usize, j: usize) -> (ClusterModel, Grid, Vec<Vec<usize>>) {
        let model = ClusterModel::default();
        let grid = Grid::new(480_000, 17_800, i, j);
        let nnz = uniform_block_nnz(&grid, 100_000_000);
        (model, grid, nnz)
    }

    #[test]
    fn more_nodes_never_slower() {
        let (m, g, nnz) = setup(4, 4);
        let mut last = f64::INFINITY;
        for p in [1usize, 2, 4, 8, 16, 64, 256] {
            let r = simulate_pp(&m, &g, &nnz, 16, 20, 20, p);
            assert!(r.total <= last * 1.0001, "p={p}: {} > {last}", r.total);
            last = r.total;
        }
    }

    #[test]
    fn more_blocks_cost_more_total_compute() {
        // paper §3.4: same node count + more blocks → more wall-clock,
        // because every factor row is re-sampled once per block that
        // touches it (the row/K³ term multiplies with the grid; the
        // per-rating term is grid-invariant).
        let (m, g1, n1) = setup(1, 1);
        let (_, g8, n8) = setup(8, 8);
        let r1 = simulate_pp(&m, &g1, &n1, 16, 20, 20, 1);
        let r8 = simulate_pp(&m, &g8, &n8, 16, 20, 20, 1);
        assert!(
            r8.node_secs > 1.2 * r1.node_secs,
            "8x8 node-secs {} vs 1x1 {}",
            r8.node_secs,
            r1.node_secs
        );
        // with a high-K workload the row term dominates and the gap widens
        let r1k = simulate_pp(&m, &g1, &n1, 64, 20, 20, 1);
        let r8k = simulate_pp(&m, &g8, &n8, 64, 20, 20, 1);
        assert!(r8k.node_secs / r1k.node_secs > r8.node_secs / r1.node_secs);
    }

    #[test]
    fn bigger_grids_scale_further() {
        // at high node counts, a larger grid should beat 1x1 (which
        // saturates at the within-block cap)
        let (m, g1, n1) = setup(1, 1);
        let (_, g16, n16) = setup(16, 16);
        let p = 4096;
        let r1 = simulate_pp(&m, &g1, &n1, 16, 20, 20, p);
        let r16 = simulate_pp(&m, &g16, &n16, 16, 20, 20, p);
        assert!(
            r16.total < r1.total,
            "16x16 at p={p}: {} should beat 1x1 {}",
            r16.total,
            r1.total
        );
    }

    #[test]
    fn phase_alignment_gives_drop() {
        // crossing P = (I-1)(J-1) removes the ragged last wave of phase c
        let (m, g, nnz) = setup(5, 5);
        let pc = 16; // (5-1)*(5-1)
        let before = simulate_pp(&m, &g, &nnz, 16, 20, 20, pc - 1);
        let at = simulate_pp(&m, &g, &nnz, 16, 20, 20, pc);
        assert!(at.phase_c < before.phase_c, "no drop at aligned node count");
    }

    #[test]
    fn pareto_front_is_monotone() {
        let pts = vec![(1, 100.0), (2, 60.0), (4, 70.0), (8, 30.0), (16, 30.0)];
        let front = pareto_front(&pts);
        assert_eq!(front, vec![(1, 100.0), (2, 60.0), (8, 30.0)]);
    }

    #[test]
    fn node_sweep_contains_alignment_points() {
        let g = Grid::new(1000, 1000, 5, 5);
        let pts = node_sweep(&g, 1000);
        assert!(pts.contains(&8)); // I+J-2
        assert!(pts.contains(&16)); // (I-1)(J-1)
    }
}
