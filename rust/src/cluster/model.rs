//! Cost model of a distributed-BMF cluster (the Hazel-Hen substitute).
//!
//! Compute: a node samples factor rows at a rate governed by two
//! calibrated coefficients — per-row cost (the K³ Cholesky/solve work) and
//! per-rating cost (the K² precision accumulation). Communication: the
//! within-block factor exchange each half-sweep is an allgather, modeled
//! with the standard α-β (latency-bandwidth) form
//!
//!   t = α ⌈log2 w⌉ + β · bytes · (w-1)/w .
//!
//! Defaults for α/β follow a Cray-Aries-class interconnect (~1.5 µs
//! latency, ~10 GB/s effective per-node bandwidth); the compute
//! coefficients come from `calibrate::calibrate()` on the actual backend.

/// Communication backend of the within-block factor exchange — the paper's
/// future-work item #3 compares the MPI allgather implementation against
/// the GASPI one-sided implementation of Vander Aa et al. 2017.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommBackend {
    /// Two-sided collective: synchronizing allgather each half-sweep.
    Mpi,
    /// One-sided asynchronous puts: communication overlaps the next
    /// shard's compute; only the non-overlappable fraction is exposed.
    Gaspi,
}

/// Calibrated + configured cluster parameters.
#[derive(Debug, Clone, Copy)]
pub struct ClusterModel {
    /// Seconds per factor row per sweep, divided by k³ (Cholesky term).
    pub c_row: f64,
    /// Seconds per rating per sweep, divided by k² (accumulation term).
    pub c_rating: f64,
    /// Allgather latency per hop (seconds).
    pub alpha: f64,
    /// Inverse bandwidth (seconds per byte).
    pub beta: f64,
    /// Max useful nodes inside one block (paper: scaling saturates ~128).
    pub within_block_cap: usize,
    /// Which exchange implementation the within-block comm term models.
    pub comm: CommBackend,
    /// GASPI: fraction of communication hidden behind compute (0..1).
    pub overlap: f64,
}

impl Default for ClusterModel {
    fn default() -> Self {
        ClusterModel {
            // ballpark CPU rates; calibrate() overwrites the first two
            c_row: 2.0e-9,
            c_rating: 1.2e-9,
            alpha: 1.5e-6,
            beta: 1.0 / 10.0e9,
            within_block_cap: 128,
            comm: CommBackend::Mpi,
            overlap: 0.7,
        }
    }
}

/// One block's workload for the simulator.
#[derive(Debug, Clone, Copy)]
pub struct BlockCost {
    /// Block rows.
    pub rows: usize,
    /// Block columns.
    pub cols: usize,
    /// Observations in the block.
    pub nnz: usize,
}

impl ClusterModel {
    /// Single-node compute seconds for `sweeps` full Gibbs sweeps on a block.
    pub fn block_compute_secs(&self, b: &BlockCost, k: usize, sweeps: usize) -> f64 {
        let k3 = (k * k * k) as f64;
        let k2 = (k * k) as f64;
        let per_sweep = self.c_row * k3 * (b.rows + b.cols) as f64
            + self.c_rating * k2 * 2.0 * b.nnz as f64;
        per_sweep * sweeps as f64
    }

    /// Allgather time of `bytes` over `w` nodes.
    pub fn allgather_secs(&self, bytes: f64, w: usize) -> f64 {
        if w <= 1 {
            return 0.0;
        }
        let hops = (w as f64).log2().ceil();
        self.alpha * hops + self.beta * bytes * (w as f64 - 1.0) / w as f64
    }

    /// Wall-clock of one block processed by `w` nodes (distributed BMF):
    /// compute divides across nodes; each sweep pays two factor-side
    /// exchanges (U then V, paper Fig. 2). With the GASPI backend the
    /// overlappable fraction of each exchange hides behind compute, but
    /// never more communication than there is compute to hide it behind.
    pub fn block_secs(&self, b: &BlockCost, k: usize, sweeps: usize, w: usize) -> f64 {
        let w = w.clamp(1, self.within_block_cap);
        let compute = self.block_compute_secs(b, k, sweeps) / w as f64;
        let bytes_u = (b.rows * k * 4) as f64;
        let bytes_v = (b.cols * k * 4) as f64;
        let comm = sweeps as f64
            * (self.allgather_secs(bytes_u, w) + self.allgather_secs(bytes_v, w));
        match self.comm {
            CommBackend::Mpi => compute + comm,
            CommBackend::Gaspi => {
                let hidden = (comm * self.overlap).min(compute);
                compute + comm - hidden
            }
        }
    }

    /// Nodes beyond which adding more stops helping for this block
    /// (d block_secs / d w ≥ 0): the strong-scaling knee.
    pub fn saturation_nodes(&self, b: &BlockCost, k: usize, sweeps: usize) -> usize {
        let mut best = (f64::INFINITY, 1usize);
        let mut w = 1usize;
        while w <= self.within_block_cap {
            let t = self.block_secs(b, k, sweeps, w);
            if t < best.0 {
                best = (t, w);
            }
            w *= 2;
        }
        best.1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block() -> BlockCost {
        BlockCost { rows: 10_000, cols: 5_000, nnz: 2_000_000 }
    }

    #[test]
    fn compute_scales_with_k_and_sweeps() {
        let m = ClusterModel::default();
        let b = block();
        let t1 = m.block_compute_secs(&b, 16, 10);
        assert!(m.block_compute_secs(&b, 32, 10) > 3.0 * t1, "K³ scaling");
        assert!((m.block_compute_secs(&b, 16, 20) / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn more_nodes_help_until_saturation() {
        let m = ClusterModel::default();
        let b = block();
        let t1 = m.block_secs(&b, 16, 10, 1);
        let t8 = m.block_secs(&b, 16, 10, 8);
        assert!(t8 < t1 / 4.0, "8 nodes should be ≥4x faster: {t1} vs {t8}");
        // a tiny block saturates strictly before the cap; a huge block
        // saturates later than the tiny one
        let tiny = BlockCost { rows: 100, cols: 100, nnz: 500 };
        let sat_tiny = m.saturation_nodes(&tiny, 8, 10);
        assert!(sat_tiny < m.within_block_cap, "tiny block saturated at {sat_tiny}");
        let sat_big = m.saturation_nodes(&block(), 32, 10);
        assert!(sat_big >= sat_tiny, "big {sat_big} vs tiny {sat_tiny}");
    }

    #[test]
    fn allgather_grows_with_nodes_and_bytes() {
        let m = ClusterModel::default();
        assert_eq!(m.allgather_secs(1e6, 1), 0.0);
        assert!(m.allgather_secs(1e6, 4) > m.allgather_secs(1e6, 2));
        assert!(m.allgather_secs(2e6, 4) > m.allgather_secs(1e6, 4));
    }

    #[test]
    fn gaspi_overlap_beats_mpi_when_comm_bound() {
        let mut mpi = ClusterModel::default();
        mpi.comm = CommBackend::Mpi;
        let mut gaspi = mpi;
        gaspi.comm = CommBackend::Gaspi;
        let b = block();
        for w in [2usize, 8, 32, 128] {
            let t_mpi = mpi.block_secs(&b, 16, 10, w);
            let t_gaspi = gaspi.block_secs(&b, 16, 10, w);
            assert!(t_gaspi <= t_mpi, "w={w}: gaspi {t_gaspi} > mpi {t_mpi}");
        }
        // single node: no communication, identical
        assert_eq!(mpi.block_secs(&b, 16, 10, 1), gaspi.block_secs(&b, 16, 10, 1));
    }

    #[test]
    fn gaspi_cannot_hide_more_than_compute() {
        let mut gaspi = ClusterModel::default();
        gaspi.comm = CommBackend::Gaspi;
        gaspi.overlap = 1.0;
        // a tiny block at many nodes is pure communication; hidden part is
        // bounded by the (tiny) compute share
        let tiny = BlockCost { rows: 64, cols: 64, nnz: 100 };
        let t = gaspi.block_secs(&tiny, 8, 10, 64);
        let compute = gaspi.block_compute_secs(&tiny, 8, 10) / 64.0;
        assert!(t >= compute, "time below compute floor");
        let bytes = (64 * 8 * 4) as f64;
        let comm = 10.0 * 2.0 * gaspi.allgather_secs(bytes, 64);
        assert!(t >= comm - compute, "hid more than compute");
    }

    #[test]
    fn cap_limits_within_block_nodes() {
        let m = ClusterModel::default();
        let b = block();
        let t_cap = m.block_secs(&b, 16, 10, m.within_block_cap);
        let t_over = m.block_secs(&b, 16, 10, m.within_block_cap * 8);
        assert_eq!(t_cap, t_over);
    }
}
