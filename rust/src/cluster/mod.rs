//! Discrete-event cluster simulator for the strong-scaling studies
//! (paper Figs. 4-5): executes the PP schedule on a modeled cluster of P
//! nodes with a calibrated compute rate and an MPI-like communication model.

pub mod calibrate;
pub mod model;
pub mod sim;
