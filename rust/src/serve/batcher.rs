//! Request coalescing: many concurrent HTTP requests, one pass over the
//! factor matrices.
//!
//! The paper's central trade is amortization — per-block communication
//! cost spread over many Gibbs sweeps. Serving makes the same trade at
//! request granularity: instead of every HTTP worker resolving its own
//! snapshot and walking the factors alone, requests queue into a
//! [`RequestBatcher`] and a single batch thread drains up to
//! `max_batch` of them at a time (waiting at most `max_wait` for
//! stragglers to coalesce), resolves the model snapshot *once*, and
//! answers the whole batch against it. Besides amortizing the snapshot
//! resolution, this gives a hard atomicity guarantee for free: all
//! requests in one batch are answered by one model — a checkpoint
//! hot-swap lands between batches, never inside one.

use super::snapshot::SnapshotReader;
use crate::posterior::{PosteriorModel, PredictError};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One prediction-side request, as parsed off the HTTP surface.
#[derive(Debug, Clone)]
pub enum Request {
    /// Posterior-mean prediction for one cell, optionally with the
    /// delta-method predictive variance.
    Predict {
        /// Row entity id.
        row: usize,
        /// Column entity id.
        col: usize,
        /// Also compute the predictive variance.
        variance: bool,
    },
    /// The `n` best columns for a row, best first.
    TopN {
        /// Row entity id.
        row: usize,
        /// How many columns to return.
        n: usize,
    },
}

/// The answer to one [`Request`], produced against a single snapshot.
#[derive(Debug, Clone)]
pub enum Response {
    /// Answer to [`Request::Predict`].
    Predict {
        /// Posterior-mean prediction.
        value: f64,
        /// Predictive variance, when requested.
        variance: Option<f64>,
    },
    /// Answer to [`Request::TopN`].
    TopN {
        /// `(column, score)` pairs, best first.
        items: Vec<(usize, f64)>,
    },
}

/// What a submitter gets back: the response plus the generation of the
/// snapshot that served it, or the typed out-of-range error.
pub type Reply = Result<(Response, u64), PredictError>;

/// Counters describing how well coalescing is working.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatcherStats {
    /// Batches executed.
    pub batches: u64,
    /// Requests answered across all batches.
    pub requests: u64,
    /// Largest batch observed.
    pub max_batch: u64,
}

struct Queue {
    items: VecDeque<(Request, mpsc::Sender<Reply>)>,
    closed: bool,
}

/// The coalescing queue between HTTP workers and the batch thread.
///
/// Workers call [`RequestBatcher::submit`] (blocking until their reply
/// arrives); the batch thread loops in [`RequestBatcher::run`]. Batch
/// boundaries are controlled by `max_batch` (drain at most this many per
/// pass) and `max_wait` (how long the first request in a batch waits for
/// company before the batch goes out regardless).
pub struct RequestBatcher {
    q: Mutex<Queue>,
    cv: Condvar,
    max_batch: usize,
    max_wait: Duration,
    batches: AtomicU64,
    requests: AtomicU64,
    max_batch_seen: AtomicU64,
}

impl RequestBatcher {
    /// Build a batcher; `max_batch` is clamped to at least 1.
    pub fn new(max_batch: usize, max_wait: Duration) -> RequestBatcher {
        RequestBatcher {
            q: Mutex::new(Queue { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            max_batch: max_batch.max(1),
            max_wait,
            batches: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            max_batch_seen: AtomicU64::new(0),
        }
    }

    /// Enqueue a request and block until its reply arrives. `None` when
    /// the batcher has shut down (submitted too late, or the batch
    /// thread is gone) — the server maps that to a 503.
    pub fn submit(&self, req: Request) -> Option<Reply> {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.q.lock().unwrap();
            if q.closed {
                return None;
            }
            q.items.push_back((req, tx));
        }
        self.cv.notify_all();
        rx.recv().ok()
    }

    /// Stop accepting new requests and wake the batch thread; requests
    /// already queued are still answered before [`RequestBatcher::run`]
    /// returns.
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Snapshot the coalescing counters.
    pub fn stats(&self) -> BatcherStats {
        BatcherStats {
            batches: self.batches.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            max_batch: self.max_batch_seen.load(Ordering::Relaxed),
        }
    }

    /// Block until at least one request is queued (or the batcher is
    /// closed and drained), linger up to `max_wait` for the batch to
    /// fill, then drain at most `max_batch` requests.
    fn next_batch(&self) -> Option<Vec<(Request, mpsc::Sender<Reply>)>> {
        let mut q = self.q.lock().unwrap();
        loop {
            if !q.items.is_empty() {
                break;
            }
            if q.closed {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
        let deadline = Instant::now() + self.max_wait;
        while q.items.len() < self.max_batch && !q.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) = self.cv.wait_timeout(q, deadline - now).unwrap();
            q = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let take = q.items.len().min(self.max_batch);
        Some(q.items.drain(..take).collect())
    }

    /// The batch thread's main loop: drain batches and answer each
    /// against one snapshot until closed and drained. `reader` is this
    /// thread's cached view of the snapshot cell, so a hot-swap is picked
    /// up at the next batch boundary.
    pub fn run(&self, mut reader: SnapshotReader) {
        while let Some(batch) = self.next_batch() {
            let snap = reader.current().clone();
            self.batches.fetch_add(1, Ordering::Relaxed);
            self.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
            self.max_batch_seen.fetch_max(batch.len() as u64, Ordering::Relaxed);
            for (req, tx) in batch {
                let reply = answer(&snap.model, &req).map(|r| (r, snap.generation));
                // a submitter that gave up (disconnected) is not an error
                let _ = tx.send(reply);
            }
        }
    }
}

/// Answer one request against one model.
fn answer(model: &PosteriorModel, req: &Request) -> Result<Response, PredictError> {
    match *req {
        Request::Predict { row, col, variance } => {
            let value = model.try_predict(row, col)?;
            let variance =
                if variance { Some(model.try_predict_variance(row, col)?) } else { None };
            Ok(Response::Predict { value, variance })
        }
        Request::TopN { row, n } => {
            Ok(Response::TopN { items: model.try_top_n(row, n)? })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::snapshot::{ModelSnapshot, SnapshotCell};
    use std::sync::Arc;

    fn cell() -> Arc<SnapshotCell> {
        let u = vec![1.0f32, 0.0, 0.0, 1.0];
        let v = vec![1.0f32, 2.0, 3.0, -1.0, 0.5, 0.5];
        Arc::new(SnapshotCell::new(ModelSnapshot {
            model: PosteriorModel::from_factors(2, &u, &v, 1.5, 1e6),
            generation: 7,
            source: None,
        }))
    }

    #[test]
    fn coalesces_concurrent_requests_into_few_batches() {
        let cell = cell();
        let batcher = Arc::new(RequestBatcher::new(64, Duration::from_millis(20)));
        let runner = {
            let b = batcher.clone();
            let reader = cell.reader();
            std::thread::spawn(move || b.run(reader))
        };
        let mut handles = Vec::new();
        for i in 0..16 {
            let b = batcher.clone();
            handles.push(std::thread::spawn(move || {
                b.submit(Request::Predict { row: i % 2, col: i % 3, variance: false })
                    .expect("batcher alive")
            }));
        }
        for h in handles {
            let (resp, generation) = h.join().unwrap().expect("in-range ids");
            assert_eq!(generation, 7);
            match resp {
                Response::Predict { value, variance } => {
                    assert!(value.is_finite());
                    assert!(variance.is_none());
                }
                other => panic!("unexpected response {other:?}"),
            }
        }
        batcher.close();
        runner.join().unwrap();
        let stats = batcher.stats();
        assert_eq!(stats.requests, 16);
        assert!(stats.batches <= 16, "batches={}", stats.batches);
        assert!(stats.max_batch >= 1);
    }

    #[test]
    fn out_of_range_ids_return_typed_errors_not_panics() {
        let cell = cell();
        let batcher = Arc::new(RequestBatcher::new(4, Duration::from_millis(1)));
        let runner = {
            let b = batcher.clone();
            let reader = cell.reader();
            std::thread::spawn(move || b.run(reader))
        };
        let err = batcher
            .submit(Request::Predict { row: 99, col: 0, variance: false })
            .expect("batcher alive")
            .unwrap_err();
        assert_eq!(err, PredictError::RowOutOfRange { row: 99, rows: 2 });
        let err = batcher
            .submit(Request::TopN { row: 5, n: 3 })
            .expect("batcher alive")
            .unwrap_err();
        assert_eq!(err, PredictError::RowOutOfRange { row: 5, rows: 2 });
        batcher.close();
        runner.join().unwrap();
    }

    #[test]
    fn close_rejects_new_but_answers_queued() {
        let batcher = Arc::new(RequestBatcher::new(8, Duration::from_millis(1)));
        batcher.close();
        assert!(batcher
            .submit(Request::Predict { row: 0, col: 0, variance: false })
            .is_none());
        // run() on a closed, empty batcher returns immediately
        batcher.run(cell().reader());
    }

    #[test]
    fn top_n_flows_through_the_batch_path() {
        let cell = cell();
        let batcher = Arc::new(RequestBatcher::new(8, Duration::from_millis(1)));
        let runner = {
            let b = batcher.clone();
            let reader = cell.reader();
            std::thread::spawn(move || b.run(reader))
        };
        let (resp, _) =
            batcher.submit(Request::TopN { row: 0, n: 2 }).expect("alive").expect("in range");
        match resp {
            Response::TopN { items } => {
                assert_eq!(items.len(), 2);
                assert_eq!(items[0].0, 1); // col 1 scores highest for row 0
            }
            other => panic!("unexpected response {other:?}"),
        }
        batcher.close();
        runner.join().unwrap();
    }
}
