//! The long-running HTTP server: lifecycle, worker pool, and the
//! checkpoint hot-swap watcher.
//!
//! Wiring (all std, no async runtime):
//!
//! ```text
//!   accept thread ──streams──▶ worker threads ──requests──▶ batcher
//!        │                          │                          │
//!        │                     handlers.rs                batch thread
//!        │                          │                          │
//!        ▼                          ▼                          ▼
//!   TcpListener              SnapshotReader ◀──── flip ──  SnapshotCell
//!                                                              ▲
//!   watcher thread ── poll checkpoint dir ── scan_servable ────┘
//! ```
//!
//! The accept thread hands connections to a fixed pool of HTTP workers
//! over a channel; workers parse and route (see
//! [`handlers`](super::handlers)), prediction traffic funnels through the
//! [`RequestBatcher`], and the watcher thread polls the checkpoint
//! directory, flipping the [`SnapshotCell`] whenever a newer *servable*
//! generation appears. Everything shuts down cleanly on `POST /shutdown`
//! or [`Server::shutdown`]: the listener stops accepting, queued
//! requests drain, threads join.

use super::batcher::RequestBatcher;
use super::snapshot::{scan_servable, ModelSnapshot, SnapshotCell};
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Where the served model comes from.
#[derive(Debug, Clone)]
pub enum ModelSource {
    /// A v1/v2 model checkpoint file (`bmf-pp train --save`). No
    /// hot-swap: the file is loaded once.
    File(PathBuf),
    /// A directory of v3 generation files (`train --checkpoint-dir`).
    /// The newest servable generation is loaded at startup and the
    /// watcher thread hot-swaps to newer ones as training writes them.
    CheckpointDir(PathBuf),
}

/// Serving knobs, builder-style like `TrainConfig`.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address; port 0 asks the OS for a free port (tests).
    pub addr: String,
    /// HTTP worker threads.
    pub threads: usize,
    /// Most requests coalesced into one batch.
    pub batch_max: usize,
    /// Longest a batch's first request waits for company.
    pub batch_wait: Duration,
    /// Checkpoint-directory poll interval for hot-swap.
    pub poll: Duration,
    /// Ridge used when rebuilding a model from a v3 generation; must
    /// match the trainer's `TrainConfig::ridge` for bitwise handoff.
    pub ridge: f64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            threads: 4,
            batch_max: 32,
            batch_wait: Duration::from_micros(500),
            poll: Duration::from_millis(200),
            ridge: 1e-3,
        }
    }
}

impl ServeConfig {
    /// Set the listen address.
    pub fn with_addr(mut self, addr: impl Into<String>) -> Self {
        self.addr = addr.into();
        self
    }

    /// Set the HTTP worker thread count (clamped to ≥ 1).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Set the batcher's size and linger bounds.
    pub fn with_batching(mut self, batch_max: usize, batch_wait: Duration) -> Self {
        self.batch_max = batch_max.max(1);
        self.batch_wait = batch_wait;
        self
    }

    /// Set the hot-swap poll interval.
    pub fn with_poll(mut self, poll: Duration) -> Self {
        self.poll = poll;
        self
    }

    /// Set the aggregation ridge used to rebuild models from generations.
    pub fn with_ridge(mut self, ridge: f64) -> Self {
        self.ridge = ridge;
        self
    }
}

/// Fixed-capacity reservoir of recent request latencies (milliseconds).
pub(crate) struct LatencyRecorder {
    ring: Mutex<LatencyRing>,
    count: AtomicU64,
}

struct LatencyRing {
    buf: Vec<f64>,
    next: usize,
}

impl LatencyRecorder {
    const CAP: usize = 4096;

    fn new() -> LatencyRecorder {
        LatencyRecorder {
            ring: Mutex::new(LatencyRing { buf: Vec::with_capacity(Self::CAP), next: 0 }),
            count: AtomicU64::new(0),
        }
    }

    pub(crate) fn record(&self, ms: f64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.ring.lock().unwrap();
        if ring.buf.len() < Self::CAP {
            ring.buf.push(ms);
        } else {
            let i = ring.next;
            ring.buf[i] = ms;
            ring.next = (i + 1) % Self::CAP;
        }
    }

    /// Total recorded count and the (p50, p99) of the retained window.
    pub(crate) fn summary(&self) -> (u64, f64, f64) {
        let count = self.count.load(Ordering::Relaxed);
        let mut sorted = self.ring.lock().unwrap().buf.clone();
        if sorted.is_empty() {
            return (count, 0.0, 0.0);
        }
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let pick = |q: f64| {
            let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
            sorted[idx.min(sorted.len() - 1)]
        };
        (count, pick(0.50), pick(0.99))
    }
}

/// Everything the request path touches, shared across all threads.
pub(crate) struct ServerShared {
    pub(crate) cell: Arc<SnapshotCell>,
    pub(crate) batcher: Arc<RequestBatcher>,
    pub(crate) shutdown: AtomicBool,
    pub(crate) started: Instant,
    pub(crate) swaps: AtomicU64,
    pub(crate) swaps_skipped: AtomicU64,
    pub(crate) http_requests: AtomicU64,
    pub(crate) http_errors: AtomicU64,
    pub(crate) latency: LatencyRecorder,
}

impl ServerShared {
    /// Snapshot every observable counter (also rendered by `/stats`).
    pub(crate) fn stats(&self) -> ServerStats {
        let snap = self.cell.load();
        let b = self.batcher.stats();
        let (latency_count, p50_ms, p99_ms) = self.latency.summary();
        let uptime_secs = self.started.elapsed().as_secs_f64();
        ServerStats {
            generation: snap.generation,
            model_rows: snap.model.rows(),
            model_cols: snap.model.cols(),
            model_k: snap.model.k,
            swaps: self.swaps.load(Ordering::Relaxed),
            swaps_skipped: self.swaps_skipped.load(Ordering::Relaxed),
            http_requests: self.http_requests.load(Ordering::Relaxed),
            http_errors: self.http_errors.load(Ordering::Relaxed),
            batches: b.batches,
            batched_requests: b.requests,
            max_batch: b.max_batch,
            p50_ms,
            p99_ms,
            qps: if uptime_secs > 0.0 { latency_count as f64 / uptime_secs } else { 0.0 },
            uptime_secs,
        }
    }
}

/// Point-in-time observability snapshot; `/stats` is this struct as JSON.
#[derive(Debug, Clone, Copy)]
pub struct ServerStats {
    /// Checkpoint generation of the snapshot currently serving.
    pub generation: u64,
    /// Row entities in the serving model.
    pub model_rows: usize,
    /// Column entities in the serving model.
    pub model_cols: usize,
    /// Latent dimension of the serving model.
    pub model_k: usize,
    /// Successful hot-swaps since startup.
    pub swaps: u64,
    /// Candidate generations skipped as unservable (corrupt/incomplete).
    pub swaps_skipped: u64,
    /// HTTP requests handled (all endpoints).
    pub http_requests: u64,
    /// HTTP requests answered with a 4xx/5xx status.
    pub http_errors: u64,
    /// Batches the coalescer executed.
    pub batches: u64,
    /// Requests answered through the batcher.
    pub batched_requests: u64,
    /// Largest coalesced batch observed.
    pub max_batch: u64,
    /// Median prediction latency over the retained window, milliseconds.
    pub p50_ms: f64,
    /// 99th-percentile prediction latency, milliseconds.
    pub p99_ms: f64,
    /// Prediction requests per second since startup.
    pub qps: f64,
    /// Seconds since the server started.
    pub uptime_secs: f64,
}

/// A running `bmf-pp serve` instance. Dropping the handle does *not*
/// stop the server; call [`Server::shutdown`] (or `POST /shutdown`) and
/// then [`Server::join`].
pub struct Server {
    addr: SocketAddr,
    shared: Arc<ServerShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Load the initial snapshot from `source`, bind `cfg.addr`, and
    /// spawn the accept loop, HTTP workers, batch thread, and (for
    /// checkpoint-directory sources) the hot-swap watcher.
    pub fn start(cfg: ServeConfig, source: ModelSource) -> anyhow::Result<Server> {
        let (initial, watch_dir) = match &source {
            ModelSource::File(path) => (
                ModelSnapshot::from_model_file(path)
                    .map_err(|e| anyhow::anyhow!("loading model {}: {e}", path.display()))?,
                None,
            ),
            ModelSource::CheckpointDir(dir) => {
                let scan = scan_servable(dir, None, cfg.ridge)
                    .map_err(|e| anyhow::anyhow!("scanning {}: {e}", dir.display()))?;
                let snap = scan.snapshot.ok_or_else(|| {
                    anyhow::anyhow!(
                        "no servable checkpoint generation in {} (need a complete \
                         v3 generation — run train with --checkpoint-dir first)",
                        dir.display()
                    )
                })?;
                (snap, Some(dir.clone()))
            }
        };

        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let cell = Arc::new(SnapshotCell::new(initial));
        let batcher = Arc::new(RequestBatcher::new(cfg.batch_max, cfg.batch_wait));
        let shared = Arc::new(ServerShared {
            cell: cell.clone(),
            batcher: batcher.clone(),
            shutdown: AtomicBool::new(false),
            started: Instant::now(),
            swaps: AtomicU64::new(0),
            swaps_skipped: AtomicU64::new(0),
            http_requests: AtomicU64::new(0),
            http_errors: AtomicU64::new(0),
            latency: LatencyRecorder::new(),
        });
        let mut handles = Vec::new();

        // batch thread: the only place model math runs
        {
            let batcher = batcher.clone();
            let reader = cell.reader();
            handles.push(std::thread::spawn(move || batcher.run(reader)));
        }

        // HTTP workers: parse/route connections off a shared channel
        let (conn_tx, conn_rx) = mpsc::channel::<std::net::TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        for _ in 0..cfg.threads.max(1) {
            let rx = conn_rx.clone();
            let shared = shared.clone();
            handles.push(std::thread::spawn(move || loop {
                // hold the lock only for the recv, not while handling
                let stream = rx.lock().unwrap().recv();
                match stream {
                    Ok(stream) => super::handlers::handle_connection(stream, &shared),
                    Err(_) => break, // accept loop gone: drain done
                }
            }));
        }

        // accept loop: non-blocking so shutdown is observed within ~1ms
        {
            let shared = shared.clone();
            handles.push(std::thread::spawn(move || {
                loop {
                    if shared.shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // handlers do one read + one write per
                            // connection; blocking mode with a timeout
                            stream.set_nonblocking(false).ok();
                            if conn_tx.send(stream).is_err() {
                                break;
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(e) => {
                            log::warn!("serve: accept failed: {e}");
                            std::thread::sleep(Duration::from_millis(1));
                        }
                    }
                }
                // dropping conn_tx closes the channel and releases workers
            }));
        }

        // watcher: poll the checkpoint directory, flip on newer servable
        if let Some(dir) = watch_dir {
            let shared = shared.clone();
            let cell = cell.clone();
            let (poll, ridge) = (cfg.poll, cfg.ridge);
            handles.push(std::thread::spawn(move || {
                while !shared.shutdown.load(Ordering::Relaxed) {
                    // sleep in small slices so shutdown isn't held up by
                    // a long poll interval
                    let wake = Instant::now() + poll;
                    while Instant::now() < wake {
                        if shared.shutdown.load(Ordering::Relaxed) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(5).min(poll));
                    }
                    let serving = cell.load().generation;
                    match scan_servable(&dir, Some(serving), ridge) {
                        Ok(scan) => {
                            shared
                                .swaps_skipped
                                .fetch_add(scan.skipped as u64, Ordering::Relaxed);
                            if let Some(snap) = scan.snapshot {
                                let generation = snap.generation;
                                cell.store(snap);
                                shared.swaps.fetch_add(1, Ordering::Relaxed);
                                log::info!(
                                    "serve: hot-swapped to generation {generation} \
                                     (was {serving})"
                                );
                            }
                        }
                        Err(e) => log::warn!("serve: watcher scan failed: {e}"),
                    }
                }
            }));
        }

        Ok(Server { addr, shared, handles })
    }

    /// The address actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current observability counters (what `/stats` serves).
    pub fn stats(&self) -> ServerStats {
        self.shared.stats()
    }

    /// Signal every thread to stop: the listener stops accepting, queued
    /// requests drain, the watcher exits at its next slice.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.batcher.close();
    }

    /// True once shutdown has been requested (by [`Server::shutdown`] or
    /// `POST /shutdown`).
    pub fn is_shutting_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::Relaxed)
    }

    /// Wait for every server thread to exit. Returns the final stats so
    /// callers can log a parting summary.
    pub fn join(self) -> ServerStats {
        for h in self.handles {
            let _ = h.join();
        }
        self.shared.stats()
    }

    /// Convenience for tests and one-shot probes: shutdown, then join.
    pub fn stop(self) -> ServerStats {
        self.shutdown();
        self.join()
    }
}
