//! Serving: a long-running HTTP/JSON recommendation server over a
//! trained [`PosteriorModel`] — the `bmf_pp::serve` facade.
//!
//! Training produces a model; this module keeps it answering traffic.
//! Three mechanisms, one per submodule:
//!
//! - [`batcher`] — concurrent predict/top-n requests coalesce into one
//!   batched pass over the factor matrices (configurable max batch size
//!   and max wait), amortizing per-request overhead the way the trainer
//!   amortizes per-block communication.
//! - [`snapshot`] — requests read an immutable [`ModelSnapshot`] through
//!   an atomic pointer flip ([`SnapshotCell`]); the read path takes no
//!   lock at steady state and a swap can never tear a model.
//! - [`server`] — lifecycle: the TCP accept loop, HTTP workers
//!   ([`handlers`]), and the hot-swap watcher that polls a checkpoint
//!   directory and flips to the newest *servable* generation the moment
//!   training writes one, with swap counters and the serving generation
//!   exposed on `/stats`.
//!
//! ## Quickstart
//!
//! ```
//! use bmf_pp::prelude::*;
//!
//! // any trained model serves; a tiny point model keeps the test fast
//! let model = PosteriorModel::from_factors(2, &[1.0, 0.0], &[0.5, 0.5], 3.0, 1e6);
//! let path = std::env::temp_dir()
//!     .join(format!("bmfpp_serve_doc_{}.json", std::process::id()));
//! bmf_pp::train::checkpoint::save(&model, &path).unwrap();
//!
//! let server = Server::start(
//!     ServeConfig::default().with_addr("127.0.0.1:0").with_threads(2),
//!     ModelSource::File(path.clone()),
//! )
//! .unwrap();
//! assert_eq!(server.stats().generation, 0); // model files carry no generation
//! server.stop();
//! std::fs::remove_file(path).ok();
//! ```
//!
//! To serve a *training pipeline* rather than a frozen file, point
//! [`ModelSource::CheckpointDir`] at the directory a run writes with
//! `TrainConfig::with_checkpoint_dir` — the server starts on the newest
//! complete generation and hot-swaps as retraining publishes new ones.

pub mod batcher;
pub mod handlers;
pub mod server;
pub mod snapshot;

pub use batcher::{BatcherStats, Request, Response};
pub use server::{ModelSource, ServeConfig, Server, ServerStats};
pub use snapshot::{scan_servable, ModelSnapshot, ServableScan, SnapshotCell, SnapshotReader};

pub use crate::posterior::{PosteriorModel, PredictError};
