//! HTTP/1.1 parsing, routing, and JSON rendering for the serve
//! endpoints (hand-rolled on `std::net` — the crate set is frozen, so no
//! hyper/axum).
//!
//! One connection carries one request: the handler reads the request
//! head, routes it, writes a `Connection: close` response, and hangs up.
//! That keeps the worker pool trivially fair and is plenty for the
//! batcher to do its coalescing — concurrency comes from many
//! connections, not pipelining.
//!
//! | Endpoint          | Query                          | Answer |
//! |-------------------|--------------------------------|--------|
//! | `GET /predict`    | `row`, `col`, [`variance`]     | posterior-mean prediction (+ variance) |
//! | `GET /top`        | `row`, [`n`]                   | best-first `(col, score)` ranking |
//! | `GET /stats`      | —                              | generation, swap counters, latency, QPS |
//! | `GET /healthz`    | —                              | liveness |
//! | `POST /shutdown`  | —                              | clean stop |
//!
//! Malformed queries are 400s; in-range parse but out-of-range ids are
//! 404s carrying the typed [`PredictError`](crate::posterior::PredictError)
//! message; a request arriving during shutdown is a 503.

use super::batcher::{Request, Response};
use super::server::ServerShared;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

const HEAD_CAP: usize = 8 * 1024;

/// Read and answer one request on `stream`, then close it.
pub(crate) fn handle_connection(mut stream: TcpStream, shared: &ServerShared) {
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok();
    stream.set_write_timeout(Some(Duration::from_secs(5))).ok();
    let Some((method, path, query)) = read_request_head(&mut stream) else {
        write_response(&mut stream, 400, &err_json("malformed HTTP request"));
        return;
    };
    shared.http_requests.fetch_add(1, Ordering::Relaxed);
    let timed = matches!(path.as_str(), "/predict" | "/top");
    let started = Instant::now();
    let (status, body) = route(&method, &path, &query, shared);
    if timed {
        shared.latency.record(started.elapsed().as_secs_f64() * 1e3);
    }
    if status >= 400 {
        shared.http_errors.fetch_add(1, Ordering::Relaxed);
    }
    write_response(&mut stream, status, &body);
}

/// Read the request head and split the request line into
/// `(method, path, query)`. `None` on anything that isn't HTTP.
fn read_request_head(stream: &mut TcpStream) -> Option<(String, String, BTreeMap<String, String>)> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    while !contains_head_end(&buf) && buf.len() < HEAD_CAP {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => return None,
        }
    }
    let head = std::str::from_utf8(&buf).ok()?;
    let line = head.lines().next()?;
    let mut parts = line.split_whitespace();
    let method = parts.next()?.to_string();
    let target = parts.next()?;
    let version = parts.next()?;
    if !version.starts_with("HTTP/") {
        return None;
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), parse_query(q)),
        None => (target.to_string(), BTreeMap::new()),
    };
    Some((method, path, query))
}

fn contains_head_end(buf: &[u8]) -> bool {
    buf.windows(4).any(|w| w == b"\r\n\r\n")
}

fn parse_query(q: &str) -> BTreeMap<String, String> {
    q.split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (kv.to_string(), "true".to_string()),
        })
        .collect()
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("error", msg.into())])
}

/// A required numeric query parameter, with a 400-worthy message.
fn q_usize(query: &BTreeMap<String, String>, key: &str) -> Result<usize, String> {
    let raw = query.get(key).ok_or_else(|| format!("missing query parameter '{key}'"))?;
    raw.parse().map_err(|_| format!("query parameter '{key}' is not a non-negative integer"))
}

fn route(
    method: &str,
    path: &str,
    query: &BTreeMap<String, String>,
    shared: &ServerShared,
) -> (u16, Json) {
    match (method, path) {
        ("GET", "/healthz") => (200, Json::obj(vec![("ok", true.into())])),
        ("GET", "/predict") => predict(query, shared),
        ("GET", "/top") => top(query, shared),
        ("GET", "/stats") => (200, stats_json(shared)),
        ("POST", "/shutdown") => {
            shared.shutdown.store(true, Ordering::Relaxed);
            shared.batcher.close();
            (200, Json::obj(vec![("ok", true.into()), ("stopping", true.into())]))
        }
        ("GET", _) | ("POST", _) => (404, err_json("no such endpoint")),
        _ => (405, err_json("method not allowed")),
    }
}

fn predict(query: &BTreeMap<String, String>, shared: &ServerShared) -> (u16, Json) {
    let (row, col) = match (q_usize(query, "row"), q_usize(query, "col")) {
        (Ok(r), Ok(c)) => (r, c),
        (Err(e), _) | (_, Err(e)) => return (400, err_json(&e)),
    };
    let variance = query.get("variance").map(|v| v != "false").unwrap_or(false);
    match shared.batcher.submit(Request::Predict { row, col, variance }) {
        None => (503, err_json("server is shutting down")),
        Some(Err(e)) => (404, err_json(&e.to_string())),
        Some(Ok((Response::Predict { value, variance }, generation))) => {
            let mut fields = vec![
                ("row", row.into()),
                ("col", col.into()),
                ("value", value.into()),
                ("generation", Json::Str(generation.to_string())),
            ];
            if let Some(var) = variance {
                fields.push(("variance", var.into()));
            }
            (200, Json::obj(fields))
        }
        Some(Ok(_)) => (500, err_json("batcher returned a mismatched response")),
    }
}

fn top(query: &BTreeMap<String, String>, shared: &ServerShared) -> (u16, Json) {
    let row = match q_usize(query, "row") {
        Ok(r) => r,
        Err(e) => return (400, err_json(&e)),
    };
    let n = match query.get("n") {
        None => 10,
        Some(raw) => match raw.parse() {
            Ok(n) => n,
            Err(_) => return (400, err_json("query parameter 'n' is not a non-negative integer")),
        },
    };
    match shared.batcher.submit(Request::TopN { row, n }) {
        None => (503, err_json("server is shutting down")),
        Some(Err(e)) => (404, err_json(&e.to_string())),
        Some(Ok((Response::TopN { items }, generation))) => {
            let items = Json::Arr(
                items
                    .into_iter()
                    .map(|(col, score)| {
                        Json::obj(vec![("col", col.into()), ("score", score.into())])
                    })
                    .collect(),
            );
            (
                200,
                Json::obj(vec![
                    ("row", row.into()),
                    ("items", items),
                    ("generation", Json::Str(generation.to_string())),
                ]),
            )
        }
        Some(Ok(_)) => (500, err_json("batcher returned a mismatched response")),
    }
}

fn stats_json(shared: &ServerShared) -> Json {
    let s = shared.stats();
    Json::obj(vec![
        ("generation", Json::Str(s.generation.to_string())),
        (
            "model",
            Json::obj(vec![
                ("rows", s.model_rows.into()),
                ("cols", s.model_cols.into()),
                ("k", s.model_k.into()),
            ]),
        ),
        ("swaps", Json::Str(s.swaps.to_string())),
        ("swaps_skipped", Json::Str(s.swaps_skipped.to_string())),
        ("http_requests", Json::Str(s.http_requests.to_string())),
        ("http_errors", Json::Str(s.http_errors.to_string())),
        (
            "batcher",
            Json::obj(vec![
                ("batches", Json::Str(s.batches.to_string())),
                ("requests", Json::Str(s.batched_requests.to_string())),
                ("max_batch", Json::Str(s.max_batch.to_string())),
            ]),
        ),
        (
            "latency",
            Json::obj(vec![
                ("p50_ms", s.p50_ms.into()),
                ("p99_ms", s.p99_ms.into()),
                ("qps", s.qps.into()),
            ]),
        ),
        ("uptime_secs", s.uptime_secs.into()),
    ])
}

fn write_response(stream: &mut TcpStream, status: u16, body: &Json) {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    };
    let body = json::to_string(body);
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\ncontent-type: application/json\r\n\
         content-length: {}\r\nconnection: close\r\n\r\n",
        body.len()
    );
    // a client that hung up mid-write is its problem, not the server's
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_parsing_handles_flags_and_pairs() {
        let q = parse_query("row=3&col=7&variance");
        assert_eq!(q.get("row").map(String::as_str), Some("3"));
        assert_eq!(q.get("col").map(String::as_str), Some("7"));
        assert_eq!(q.get("variance").map(String::as_str), Some("true"));
        assert!(parse_query("").is_empty());
    }

    #[test]
    fn q_usize_reports_missing_and_malformed() {
        let q = parse_query("row=3&col=x");
        assert_eq!(q_usize(&q, "row"), Ok(3));
        assert!(q_usize(&q, "col").unwrap_err().contains("col"));
        assert!(q_usize(&q, "n").unwrap_err().contains("missing"));
    }

    #[test]
    fn head_end_detection() {
        assert!(contains_head_end(b"GET / HTTP/1.1\r\n\r\n"));
        assert!(!contains_head_end(b"GET / HTTP/1.1\r\n"));
    }
}
