//! Immutable model snapshots and the lock-free reader cell they flip
//! through.
//!
//! Serving never mutates a model: a [`ModelSnapshot`] is frozen at load
//! time and shared behind an `Arc`. Swapping in a retrained model is a
//! single atomic pointer flip inside [`SnapshotCell`] (the `arc-swap`
//! idiom, hand-rolled because the crate set is frozen): a version counter
//! published with `Release` ordering plus a mutex-guarded writer slot.
//! Readers hold a [`SnapshotReader`] that caches the current `Arc` and
//! re-reads the slot only when the version counter moves, so the steady-
//! state read path is one atomic load — no reader-side lock, no
//! allocation, and a swap can never tear a model in half (requests see
//! the old model or the new one, bitwise, never a mix).
//!
//! Snapshots come from two sources: a v1/v2 model checkpoint file
//! (`bmf-pp train --save`) or a directory of v3 generation files written
//! by periodic checkpointing (`train --checkpoint-dir`). The directory
//! path is what enables hot-swap: [`scan_servable`] walks the
//! generations newest-first, skipping files that are corrupt *or
//! incomplete* (a mid-retrain generation does not hold every grid block),
//! and rebuilds a full model from the newest servable one via
//! [`crate::coordinator::checkpoint::model_from_partial`].

use crate::coordinator::checkpoint::{
    self, list_generations, model_from_partial, CheckpointError,
};
use crate::posterior::PosteriorModel;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// An immutable, servable model plus its provenance: which checkpoint
/// generation it came from (0 for plain model files) and the file it was
/// loaded from.
#[derive(Debug)]
pub struct ModelSnapshot {
    /// The frozen model all predictions in this snapshot's lifetime use.
    pub model: PosteriorModel,
    /// Checkpoint generation the model was rebuilt from (0 when loaded
    /// from a v1/v2 model file, which carries no generation counter).
    pub generation: u64,
    /// File the snapshot was loaded from, when known.
    pub source: Option<PathBuf>,
}

impl ModelSnapshot {
    /// Load a snapshot from a v1/v2 model checkpoint file.
    pub fn from_model_file(path: &Path) -> Result<ModelSnapshot, CheckpointError> {
        let model = checkpoint::load(path)?;
        Ok(ModelSnapshot { model, generation: 0, source: Some(path.to_path_buf()) })
    }
}

/// Result of scanning a checkpoint directory for a servable generation.
#[derive(Debug)]
pub struct ServableScan {
    /// The newest servable snapshot found, if any.
    pub snapshot: Option<ModelSnapshot>,
    /// Candidate generations newer than the floor that were skipped as
    /// unservable (corrupt, truncated, or incomplete).
    pub skipped: usize,
}

/// Walk the generation files in `dir` newest-first and load the newest
/// *servable* one strictly newer than `newer_than` (pass `None` for no
/// floor): a generation is servable when it parses as a v3 partial
/// checkpoint *and* holds every block of its grid, so a model can be
/// rebuilt from it. Corrupt, truncated, or incomplete candidates are
/// counted in [`ServableScan::skipped`] and the walk continues — exactly
/// the degradation contract of
/// [`crate::coordinator::checkpoint::latest_valid_partial`], tightened by
/// the completeness requirement serving adds.
///
/// `ridge` must match the `TrainConfig::ridge` the writer used (default
/// `1e-3`) for the rebuilt model to be bitwise-identical to the one the
/// training run returned.
pub fn scan_servable(
    dir: &Path,
    newer_than: Option<u64>,
    ridge: f64,
) -> std::io::Result<ServableScan> {
    let generations = list_generations(dir)?;
    let mut skipped = 0;
    for (gen_no, path) in generations.iter().rev() {
        if let Some(floor) = newer_than {
            if *gen_no <= floor {
                break; // sorted: everything further back is older still
            }
        }
        let ckpt = match checkpoint::load_partial(path) {
            Ok(c) => c,
            Err(e) => {
                log::warn!("serve: skipping unreadable generation {}: {e}", path.display());
                skipped += 1;
                continue;
            }
        };
        if !ckpt.is_complete() {
            log::debug!(
                "serve: skipping incomplete generation {} ({} blocks)",
                path.display(),
                ckpt.blocks.len()
            );
            skipped += 1;
            continue;
        }
        match model_from_partial(&ckpt, ridge) {
            Ok(model) => {
                return Ok(ServableScan {
                    snapshot: Some(ModelSnapshot {
                        model,
                        generation: ckpt.generation,
                        source: Some(path.clone()),
                    }),
                    skipped,
                })
            }
            Err(e) => {
                log::warn!("serve: cannot rebuild model from {}: {e}", path.display());
                skipped += 1;
            }
        }
    }
    Ok(ServableScan { snapshot: None, skipped })
}

/// The swap point between the checkpoint watcher (one writer) and the
/// request path (many readers).
///
/// A store replaces the slot and then bumps the version with `Release`
/// ordering; a reader's hot path is a single `Acquire` load of the
/// version, touching the slot mutex only when the version moved since its
/// cached `Arc` was taken. The mutex is therefore contended only in the
/// instants around a swap — reads are lock-free at steady state, and old
/// snapshots are reclaimed as soon as the last cached `Arc` drops.
#[derive(Debug)]
pub struct SnapshotCell {
    version: AtomicU64,
    slot: Mutex<Arc<ModelSnapshot>>,
}

impl SnapshotCell {
    /// Wrap the initial snapshot.
    pub fn new(initial: ModelSnapshot) -> SnapshotCell {
        SnapshotCell { version: AtomicU64::new(0), slot: Mutex::new(Arc::new(initial)) }
    }

    /// Atomically flip every future read to `snap` (current readers keep
    /// their `Arc` until their next version check).
    pub fn store(&self, snap: ModelSnapshot) {
        let mut slot = self.slot.lock().unwrap();
        *slot = Arc::new(snap);
        self.version.fetch_add(1, Ordering::Release);
    }

    /// The swap counter: bumped once per [`SnapshotCell::store`].
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// One-off read of the current snapshot (locks the slot; request
    /// paths should hold a [`SnapshotReader`] instead).
    pub fn load(&self) -> Arc<ModelSnapshot> {
        self.slot.lock().unwrap().clone()
    }

    /// A cached reader for a thread that resolves snapshots repeatedly.
    pub fn reader(self: &Arc<Self>) -> SnapshotReader {
        // version first, slot second: a store racing in between leaves
        // the cache *newer* than `seen` (refreshed on the next check),
        // never staler than the version we claim to have observed
        let seen = self.version();
        let cached = self.load();
        SnapshotReader { cell: self.clone(), cached, seen }
    }
}

/// A per-thread view of a [`SnapshotCell`]: one atomic load per
/// resolution at steady state.
#[derive(Debug)]
pub struct SnapshotReader {
    cell: Arc<SnapshotCell>,
    cached: Arc<ModelSnapshot>,
    seen: u64,
}

impl SnapshotReader {
    /// The current snapshot, refreshing the cache only when the cell's
    /// version moved.
    pub fn current(&mut self) -> &Arc<ModelSnapshot> {
        let v = self.cell.version.load(Ordering::Acquire);
        if v != self.seen {
            self.cached = self.cell.load();
            self.seen = v;
        }
        &self.cached
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(mean: f64, generation: u64) -> ModelSnapshot {
        let u = vec![mean as f32; 2];
        let v = vec![1.0f32, 0.5];
        ModelSnapshot {
            model: PosteriorModel::from_factors(1, &u, &v, 0.0, 1e6),
            generation,
            source: None,
        }
    }

    #[test]
    fn reader_sees_flips_and_never_tears() {
        let cell = Arc::new(SnapshotCell::new(snap(1.0, 1)));
        let mut reader = cell.reader();
        assert_eq!(reader.current().generation, 1);
        cell.store(snap(2.0, 2));
        assert_eq!(reader.current().generation, 2);
        assert_eq!(cell.version(), 1);
        // a reader created after the swap starts on the new snapshot
        assert_eq!(cell.reader().current().generation, 2);
    }

    #[test]
    fn concurrent_readers_observe_only_whole_snapshots() {
        // hammer the cell from reader threads while the writer flips
        // between two models whose predictions differ; every observed
        // prediction must bitwise-match one of the two models
        let a = snap(1.0, 1);
        let b = snap(2.0, 2);
        let pa = a.model.predict(0, 0).to_bits();
        let pb = b.model.predict(0, 0).to_bits();
        let cell = Arc::new(SnapshotCell::new(a));
        let stop = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let cell = cell.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                let mut reader = cell.reader();
                let mut seen_new = false;
                while stop.load(Ordering::Relaxed) == 0 {
                    let s = reader.current();
                    let bits = s.model.predict(0, 0).to_bits();
                    let generation = s.generation;
                    assert!(
                        (bits == pa && generation == 1) || (bits == pb && generation == 2),
                        "torn snapshot: bits={bits} generation={generation}"
                    );
                    seen_new |= generation == 2;
                }
                seen_new
            }));
        }
        for flip in 0..200 {
            cell.store(if flip % 2 == 0 { snap(2.0, 2) } else { snap(1.0, 1) });
            std::thread::yield_now();
        }
        cell.store(snap(2.0, 2));
        std::thread::sleep(std::time::Duration::from_millis(10));
        stop.store(1, Ordering::Relaxed);
        let mut any_new = false;
        for h in handles {
            any_new |= h.join().unwrap();
        }
        assert!(any_new, "readers never observed the swapped-in snapshot");
        assert_eq!(cell.version(), 201);
    }

    #[test]
    fn scan_prefers_newest_complete_generation() {
        use crate::coordinator::checkpoint::{
            generation_path, save_partial, PartialBlock, PartialCheckpoint,
        };
        use crate::posterior::RowGaussians;

        let dir = std::env::temp_dir()
            .join(format!("bmfpp_serve_scan_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();

        let g = |vals: &[f64]| RowGaussians {
            n: vals.len(),
            k: 1,
            mean: vals.to_vec(),
            prec: vals.iter().map(|_| 4.0).collect(),
        };
        let block = |i: usize, j: usize| PartialBlock {
            i,
            j,
            post: crate::coordinator::block_task::BlockPosteriors {
                u: g(&[0.5]),
                v: g(&[2.0]),
            },
        };
        let complete = PartialCheckpoint {
            k: 1,
            seed: 7,
            grid: (1, 1),
            global_mean: 0.25,
            generation: 1,
            store_revision: 0,
            blocks: vec![block(0, 0)],
        };
        save_partial(&complete, &generation_path(&dir, 1)).unwrap();
        // newer but incomplete (mid-retrain): must be skipped
        let incomplete = PartialCheckpoint {
            grid: (2, 1),
            generation: 2,
            blocks: vec![block(0, 0)],
            ..complete.clone()
        };
        save_partial(&incomplete, &generation_path(&dir, 2)).unwrap();
        // newest of all is garbage: must also be skipped
        std::fs::write(generation_path(&dir, 3), "not json").unwrap();

        let scan = scan_servable(&dir, None, 1e-3).unwrap();
        let found = scan.snapshot.expect("generation 1 is servable");
        assert_eq!(found.generation, 1);
        assert_eq!(scan.skipped, 2);
        // with generation 1 already serving, nothing newer is servable
        let scan = scan_servable(&dir, Some(1), 1e-3).unwrap();
        assert!(scan.snapshot.is_none());
        assert_eq!(scan.skipped, 2);
        std::fs::remove_dir_all(dir).ok();
    }
}
