//! Minimal property-testing harness.
//!
//! `check(cases, gen, prop)` runs `prop` on `cases` generated inputs; on
//! failure it retries with progressively "smaller" regenerated inputs
//! (generator receives a shrink level) and reports the seed so the case is
//! reproducible. A deliberate substitute for proptest (offline environment),
//! covering what the coordinator invariants need: randomized inputs,
//! reproducible failures, basic shrinking.

use crate::rng::Rng;

/// Context handed to generators: RNG + shrink level (0 = full size).
pub struct Gen<'a> {
    /// The case's reproducible RNG.
    pub rng: &'a mut Rng,
    /// 0 = full-size inputs; higher values should produce smaller inputs.
    pub shrink: u32,
}

impl<'a> Gen<'a> {
    /// A size in [lo, hi] scaled down by the shrink level.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        let hi_eff = (hi >> self.shrink).max(lo);
        lo + self.rng.below(hi_eff - lo + 1)
    }

    /// A uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    /// A uniform usize in [lo, hi].
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// A uniformly chosen element of `xs`.
    pub fn pick<'t, T>(&mut self, xs: &'t [T]) -> &'t T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run a property over `cases` random inputs. Panics with the failing seed
/// and (if found) a shrunk failing input description.
pub fn check<T: std::fmt::Debug>(
    cases: u32,
    mut generate: impl FnMut(&mut Gen) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    let base_seed = match std::env::var("PROP_SEED") {
        Ok(s) => s.parse().unwrap_or(0xBADC0FFE),
        Err(_) => 0xBADC0FFE,
    };
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from_u64(seed);
        let input = generate(&mut Gen { rng: &mut rng, shrink: 0 });
        if let Err(msg) = property(&input) {
            // try to find a smaller failure with the same seed family
            for shrink in 1..=4u32 {
                let mut srng = Rng::seed_from_u64(seed);
                let small = generate(&mut Gen { rng: &mut srng, shrink });
                if let Err(smsg) = property(&small) {
                    panic!(
                        "property failed (case {case}, seed {seed:#x}, shrink {shrink}): {smsg}\ninput: {small:?}"
                    );
                }
            }
            panic!("property failed (case {case}, seed {seed:#x}): {msg}\ninput: {input:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            50,
            |g| (g.size(1, 100), g.f64_in(-1.0, 1.0)),
            |(n, x)| {
                if *n >= 1 && x.abs() <= 1.0 {
                    Ok(())
                } else {
                    Err("bounds".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        check(
            20,
            |g| g.size(0, 1000),
            |n| if *n < 900 { Ok(()) } else { Err(format!("{n} too big")) },
        );
    }

    #[test]
    fn shrink_reduces_sizes() {
        let mut rng = Rng::seed_from_u64(1);
        let mut g0 = Gen { rng: &mut rng, shrink: 0 };
        let full: Vec<usize> = (0..100).map(|_| g0.size(1, 1024)).collect();
        let mut rng2 = Rng::seed_from_u64(1);
        let mut g3 = Gen { rng: &mut rng2, shrink: 3 };
        let small: Vec<usize> = (0..100).map(|_| g3.size(1, 1024)).collect();
        assert!(small.iter().max() <= full.iter().max());
        assert!(*small.iter().max().unwrap() <= 1024 >> 3);
    }
}
