//! Deterministic fault injection for crash-tolerance tests.
//!
//! A [`FaultPlan`] attached to a `TrainConfig`
//! (`TrainConfig::with_fault_plan`) is consulted by the trainer right
//! before each block task starts sampling, on the worker thread that will
//! run it. Blocks are addressed by their **canonical index** — the order
//! the trainer inserts block nodes into the DAG: phase (a) is 0, then the
//! phase-(b) row blocks (1,0)…(I-1,0), the phase-(b) column blocks
//! (0,1)…(0,J-1), then the phase-(c) interior blocks in row-major order.
//! That numbering is a pure function of the grid, so a plan fires at the
//! same block whatever the schedule, worker count, or tenant mix —
//! deterministic by construction, no shared counters.
//!
//! Blocks restored from a resume checkpoint never sample, so they never
//! consult the plan: a resumed run that restores past the fault point
//! sails through. A run resumed with the *same* plan and the faulted
//! block still unsampled will fault again — clear the plan on the resume
//! config (`cfg.fault = None`) to model "the crash does not recur".
//!
//! Panics raised here are caught at the worker-pool task boundary and
//! surface as `TrainOutcome::Failed` for *that job only*; sibling jobs on
//! the same pool are untouched (asserted in `tests/fault.rs`).

use std::time::Duration;

/// What the plan does when its trigger fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Panic when the block with this canonical index starts sampling —
    /// the deterministic stand-in for a worker crash mid-run.
    PanicAtBlock(usize),
    /// Sleep before sampling the block with canonical index `block` — a
    /// straggler injection that must change timings, never the math.
    DelayBlock {
        /// Canonical index of the delayed block.
        block: usize,
        /// How long the block is held before sampling, in milliseconds.
        millis: u64,
    },
    /// Panic at each block independently with probability `p`, decided by
    /// a hash of `(seed, canonical index)` — a seeded random kill that is
    /// reproducible run-to-run and schedule-independent.
    RandomPanic {
        /// Seed of the per-block kill decision.
        seed: u64,
        /// Kill probability per block, in `[0, 1]`.
        p: f64,
    },
}

/// A deterministic fault schedule, consulted before every sampled block.
///
/// Testing hook: production configs leave `TrainConfig::fault` as `None`
/// and never pay anything for this. The plan is a stateless `Copy` value
/// — cloning a config copies it — and must stay that way: the trigger is
/// a pure function of the block's canonical index, so every copy behaves
/// identically. Fire-once or otherwise stateful plans would break under
/// config cloning; model "the crash does not recur" by clearing
/// `cfg.fault` on the retry instead.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    kind: FaultKind,
}

impl FaultPlan {
    /// A plan executing `kind`.
    pub fn new(kind: FaultKind) -> FaultPlan {
        FaultPlan { kind }
    }

    /// Shorthand: panic when canonical block `block` starts sampling.
    pub fn panic_at_block(block: usize) -> FaultPlan {
        FaultPlan::new(FaultKind::PanicAtBlock(block))
    }

    /// Shorthand: delay canonical block `block` by `millis` milliseconds.
    pub fn delay_block(block: usize, millis: u64) -> FaultPlan {
        FaultPlan::new(FaultKind::DelayBlock { block, millis })
    }

    /// Shorthand: seeded random kill with per-block probability `p`.
    pub fn random_panic(seed: u64, p: f64) -> FaultPlan {
        FaultPlan::new(FaultKind::RandomPanic { seed, p })
    }

    /// The plan's trigger/action.
    pub fn kind(&self) -> FaultKind {
        self.kind
    }

    /// Would [`FaultPlan::before_block`] panic for this canonical index?
    /// Lets tests predict the fault point without tripping it.
    pub fn kills_block(&self, index: usize) -> bool {
        match self.kind {
            FaultKind::PanicAtBlock(n) => index == n,
            FaultKind::DelayBlock { .. } => false,
            FaultKind::RandomPanic { seed, p } => kill_draw(seed, index) < p,
        }
    }

    /// The trainer's hook: called on the worker thread right before block
    /// `index` (at grid coordinate `node`) starts sampling. Panics or
    /// sleeps according to the plan; a no-op for every other block.
    pub fn before_block(&self, index: usize, node: (usize, usize)) {
        match self.kind {
            FaultKind::DelayBlock { block, millis } if block == index => {
                std::thread::sleep(Duration::from_millis(millis));
            }
            _ if self.kills_block(index) => {
                panic!(
                    "fault injection: killed block {index} at grid ({}, {})",
                    node.0, node.1
                );
            }
            _ => {}
        }
    }
}

/// Deterministic uniform draw in `[0, 1)` from `(seed, index)` — the same
/// splitmix-style mix the trainer uses for per-block seeds, so the kill
/// pattern is stable across platforms.
fn kill_draw(seed: u64, index: usize) -> f64 {
    let mut z = seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(index as u64)
        .wrapping_add(0x243F6A8885A308D3);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panic_plan_fires_only_at_its_block() {
        let plan = FaultPlan::panic_at_block(3);
        assert!(!plan.kills_block(2) && plan.kills_block(3) && !plan.kills_block(4));
        // a non-matching index is a no-op, not a panic
        plan.before_block(2, (0, 0));
    }

    #[test]
    #[should_panic(expected = "fault injection")]
    fn panic_plan_panics_at_its_block() {
        FaultPlan::panic_at_block(1).before_block(1, (1, 0));
    }

    #[test]
    fn delay_plan_sleeps_instead_of_panicking() {
        let plan = FaultPlan::delay_block(0, 15);
        assert!(!plan.kills_block(0));
        let t0 = std::time::Instant::now();
        plan.before_block(0, (0, 0));
        assert!(t0.elapsed() >= Duration::from_millis(15));
        let t0 = std::time::Instant::now();
        plan.before_block(1, (1, 0));
        assert!(t0.elapsed() < Duration::from_millis(15), "wrong block delayed");
    }

    #[test]
    fn random_kill_is_deterministic_and_roughly_calibrated() {
        let plan = FaultPlan::random_panic(7, 0.3);
        let a: Vec<bool> = (0..64).map(|i| plan.kills_block(i)).collect();
        let b: Vec<bool> = (0..64).map(|i| plan.kills_block(i)).collect();
        assert_eq!(a, b, "same seed, same kill pattern");
        let kills = a.iter().filter(|&&k| k).count();
        assert!((5..=35).contains(&kills), "p=0.3 over 64 blocks killed {kills}");
        // edge probabilities behave
        assert!(!FaultPlan::random_panic(7, 0.0).kills_block(0));
        assert!(FaultPlan::random_panic(7, 1.1).kills_block(0));
    }
}
