//! Property-testing substrate (proptest is unavailable offline) and
//! deterministic fault injection for crash-tolerance tests.

pub mod fault;
pub mod prop;
