//! Property-testing substrate (proptest is unavailable offline).

pub mod prop;
