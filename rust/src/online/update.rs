//! Incremental-update validation: prior checks, dirty-set pruning, and
//! the store-revision skew warning.
//!
//! `Engine::update` (`crate::train::Engine`) is a *pruned resume*: the
//! prior checkpoint minus the dirty blocks becomes the resume state, so
//! the trainer re-samples exactly the dirty blocks (with their original
//! per-block seeds, over the updated data) and restores every clean
//! block's posterior unchanged. This module holds the pieces that are
//! pure data-plumbing — everything that does not need the engine:
//!
//! - [`check_prior`]: the prior must be *complete* (every grid block
//!   present — a mid-run generation cannot seed an update) and must
//!   match the config's `k` / `grid` / `seed`, the same identity triple
//!   a plain resume enforces. Violations are typed [`UpdateError`]s.
//! - [`prune_prior`]: drop the dirty blocks from the checkpoint. What
//!   remains seeds the run; `aggregate_part`'s prior-division contract
//!   guarantees a clean posterior fed back as a prior is not counted
//!   twice (see `docs/ARCHITECTURE.md`, "Online updates").
//! - [`revision_skew`]: a non-fatal, typed [`UpdateWarning`] when the
//!   store's append revision has moved more than one step past the
//!   revision the checkpoint trained against — the delta being applied
//!   probably does not cover everything that changed.
//! - [`load_prior`]: fetch the prior from a v3 file or, for a
//!   checkpoint *directory*, its newest valid generation.

use crate::coordinator::checkpoint::{
    latest_valid_partial, load_partial, PartialCheckpoint,
};
use crate::coordinator::config::TrainConfig;
use std::collections::BTreeSet;
use std::path::Path;

/// Why a prior checkpoint cannot seed an incremental update. Every
/// variant names the prior's value and the conflicting one, mirroring
/// the resume-path validation messages.
#[derive(Debug, thiserror::Error)]
pub enum UpdateError {
    /// The prior is a mid-run generation: some grid blocks never
    /// completed, so there is no posterior to pass through for them.
    /// Resume the interrupted run to completion first.
    #[error(
        "prior checkpoint is incomplete ({have} of {need} blocks) — an \
         incremental update needs a finished run; resume it to completion first"
    )]
    IncompletePrior {
        /// Blocks present in the prior.
        have: usize,
        /// Blocks the grid requires.
        need: usize,
    },
    /// The config's latent dimension differs from the prior's.
    #[error("checkpoint has k={prior}, config wants k={cfg}")]
    KMismatch {
        /// Latent dimension recorded in the prior.
        prior: usize,
        /// Latent dimension the config requests.
        cfg: usize,
    },
    /// The config's block grid differs from the prior's — blocks would
    /// not line up, so no posterior could be passed through.
    #[error(
        "checkpoint grid {}x{} does not match config grid {}x{}",
        prior.0, prior.1, cfg.0, cfg.1
    )]
    GridMismatch {
        /// Grid recorded in the prior.
        prior: (usize, usize),
        /// Grid the config requests.
        cfg: (usize, usize),
    },
    /// The config's base seed differs from the prior's: dirty blocks
    /// would re-sample with different per-block seeds, silently changing
    /// the math of the clean/dirty split.
    #[error("checkpoint seed {prior} does not match config seed {cfg}")]
    SeedMismatch {
        /// Seed recorded in the prior.
        prior: u64,
        /// Seed the config requests.
        cfg: u64,
    },
    /// The base data's dimensions differ from what the prior trained on
    /// (derived from its per-block posterior row counts). A *delta* may
    /// grow the matrix; the *base* must be the one the prior saw.
    #[error(
        "base data is {}x{}, the checkpoint trained on {}x{}",
        data.0, data.1, prior.0, prior.1
    )]
    DataMismatch {
        /// Dimensions of the base data handed to the update.
        data: (usize, usize),
        /// Dimensions reconstructed from the prior checkpoint.
        prior: (usize, usize),
    },
}

/// Non-fatal conditions an update surfaces before running. Typed so CLI
/// and tests can match on them; the update itself proceeds.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum UpdateWarning {
    /// The store has been appended to more than once since the prior
    /// checkpoint was written: the delta being applied now likely does
    /// not cover the earlier appends, so blocks they touched will be
    /// treated as clean even though their data changed.
    #[error(
        "store is at revision {store} but the checkpoint trained against \
         revision {checkpoint} — appends between the two are not covered \
         by this delta; consider a full retrain"
    )]
    StoreRevisionAhead {
        /// The store's current append revision.
        store: u64,
        /// Revision recorded in the prior checkpoint.
        checkpoint: u64,
    },
}

/// Detect store/checkpoint revision skew: `Some(warning)` when
/// `store_revision` is more than one append ahead of the revision the
/// prior trained against. Exactly one append ahead is the expected state
/// — the append this very update accounts for — and warns nothing.
pub fn revision_skew(prior: &PartialCheckpoint, store_revision: u64) -> Option<UpdateWarning> {
    if store_revision > prior.store_revision.saturating_add(1) {
        Some(UpdateWarning::StoreRevisionAhead {
            store: store_revision,
            checkpoint: prior.store_revision,
        })
    } else {
        None
    }
}

/// Validate that `prior` can seed an incremental update under `cfg`:
/// complete, and matching the config's `k`, `grid`, and `seed` (the
/// resume identity triple).
pub fn check_prior(cfg: &TrainConfig, prior: &PartialCheckpoint) -> Result<(), UpdateError> {
    if prior.k != cfg.k {
        return Err(UpdateError::KMismatch { prior: prior.k, cfg: cfg.k });
    }
    if prior.grid != cfg.grid {
        return Err(UpdateError::GridMismatch { prior: prior.grid, cfg: cfg.grid });
    }
    if prior.seed != cfg.seed {
        return Err(UpdateError::SeedMismatch { prior: prior.seed, cfg: cfg.seed });
    }
    if !prior.is_complete() {
        // distinct coordinates only — duplicates must not inflate `have`
        let (gi, gj) = prior.grid;
        let have = prior
            .blocks
            .iter()
            .map(|b| (b.i, b.j))
            .collect::<BTreeSet<_>>()
            .len();
        return Err(UpdateError::IncompletePrior { have, need: gi * gj });
    }
    Ok(())
}

/// Matrix dimensions the prior trained on, reconstructed from its block
/// posteriors: rows = Σᵢ rows of block (i,0)'s U posterior, cols = Σⱼ
/// columns of block (0,j)'s V posterior. Requires a *complete* prior
/// (run [`check_prior`] first); missing first-row/column blocks make
/// the reconstruction undercount, which [`UpdateError::DataMismatch`]
/// then reports against the caller's data.
pub fn prior_dims(prior: &PartialCheckpoint) -> (usize, usize) {
    let (gi, gj) = prior.grid;
    let mut rows = vec![0usize; gi];
    let mut cols = vec![0usize; gj];
    for b in &prior.blocks {
        if b.j == 0 && b.i < gi {
            rows[b.i] = b.post.u.n;
        }
        if b.i == 0 && b.j < gj {
            cols[b.j] = b.post.v.n;
        }
    }
    (rows.iter().sum(), cols.iter().sum())
}

/// The pruned resume state: `prior` minus the dirty blocks. The trainer
/// restores every surviving block's posterior unchanged (emitting
/// `BlockSkippedClean`) and re-samples exactly the dropped ones.
/// Generation and store-revision counters carry over, so the update's
/// checkpoint generations continue the prior's sequence.
pub fn prune_prior(
    prior: &PartialCheckpoint,
    dirty: &BTreeSet<(usize, usize)>,
) -> PartialCheckpoint {
    let mut pruned = prior.clone();
    pruned.blocks.retain(|b| !dirty.contains(&(b.i, b.j)));
    pruned
}

/// Load the prior checkpoint for an update: a v3 partial-checkpoint
/// *file* loads directly; a checkpoint *directory* loads its newest
/// valid generation (the same discovery `serve` and `--resume` use).
pub fn load_prior(path: &Path) -> anyhow::Result<PartialCheckpoint> {
    if path.is_dir() {
        match latest_valid_partial(path)? {
            Some((ckpt, from)) => {
                log::info!("update prior: {}", from.display());
                Ok(ckpt)
            }
            None => anyhow::bail!(
                "no checkpoint generation found in {} — train with \
                 --checkpoint-every/--checkpoint-dir first",
                path.display()
            ),
        }
    } else {
        Ok(load_partial(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::block_task::BlockPosteriors;
    use crate::coordinator::checkpoint::PartialBlock;
    use crate::posterior::RowGaussians;

    /// A complete 2x2 prior over a 5x4 matrix (rows 3+2, cols 2+2), k=1.
    fn complete_prior() -> PartialCheckpoint {
        let g = |n: usize| RowGaussians {
            n,
            k: 1,
            mean: vec![0.5; n],
            prec: vec![4.0; n],
        };
        let block = |i: usize, j: usize, rows: usize, cols: usize| PartialBlock {
            i,
            j,
            post: BlockPosteriors { u: g(rows), v: g(cols) },
        };
        PartialCheckpoint {
            k: 1,
            seed: 7,
            grid: (2, 2),
            global_mean: 1.5,
            generation: 4,
            store_revision: 2,
            blocks: vec![
                block(0, 0, 3, 2),
                block(0, 1, 3, 2),
                block(1, 0, 2, 2),
                block(1, 1, 2, 2),
            ],
        }
    }

    fn cfg() -> TrainConfig {
        TrainConfig::new(1).with_grid(2, 2).with_seed(7)
    }

    #[test]
    fn check_prior_accepts_matching_complete_checkpoint() {
        assert!(check_prior(&cfg(), &complete_prior()).is_ok());
    }

    #[test]
    fn check_prior_names_each_mismatch() {
        let prior = complete_prior();
        let err = check_prior(&cfg().with_seed(8), &prior).unwrap_err();
        assert!(matches!(err, UpdateError::SeedMismatch { prior: 7, cfg: 8 }), "{err}");
        let err = check_prior(&TrainConfig::new(2).with_grid(2, 2).with_seed(7), &prior)
            .unwrap_err();
        assert!(matches!(err, UpdateError::KMismatch { prior: 1, cfg: 2 }), "{err}");
        let err = check_prior(&TrainConfig::new(1).with_grid(2, 1).with_seed(7), &prior)
            .unwrap_err();
        assert!(matches!(err, UpdateError::GridMismatch { .. }), "{err}");
        assert!(err.to_string().contains("2x2"), "{err}");
    }

    #[test]
    fn check_prior_rejects_incomplete_with_counts() {
        let mut prior = complete_prior();
        prior.blocks.pop();
        let err = check_prior(&cfg(), &prior).unwrap_err();
        assert!(
            matches!(err, UpdateError::IncompletePrior { have: 3, need: 4 }),
            "{err}"
        );
        assert!(err.to_string().contains("resume it to completion"), "{err}");
    }

    #[test]
    fn prior_dims_reconstructs_the_training_shape() {
        assert_eq!(prior_dims(&complete_prior()), (5, 4));
    }

    #[test]
    fn prune_drops_exactly_the_dirty_blocks() {
        let prior = complete_prior();
        let dirty: BTreeSet<_> = [(0usize, 1usize), (1, 1)].into_iter().collect();
        let pruned = prune_prior(&prior, &dirty);
        let left: Vec<_> = pruned.blocks.iter().map(|b| (b.i, b.j)).collect();
        assert_eq!(left, vec![(0, 0), (1, 0)]);
        // run identity and counters carry over untouched
        assert_eq!(pruned.generation, prior.generation);
        assert_eq!(pruned.store_revision, prior.store_revision);
        assert_eq!(pruned.global_mean.to_bits(), prior.global_mean.to_bits());
    }

    #[test]
    fn prune_with_empty_dirty_set_is_identity_sized() {
        let prior = complete_prior();
        assert_eq!(prune_prior(&prior, &BTreeSet::new()).blocks.len(), prior.blocks.len());
    }

    #[test]
    fn revision_skew_warns_only_past_one_append() {
        let prior = complete_prior(); // store_revision: 2
        assert_eq!(revision_skew(&prior, 2), None, "no append since: fine");
        assert_eq!(revision_skew(&prior, 3), None, "the expected single append: fine");
        let warn = revision_skew(&prior, 5).expect("two extra appends must warn");
        assert_eq!(warn, UpdateWarning::StoreRevisionAhead { store: 5, checkpoint: 2 });
        assert!(warn.to_string().contains("revision 5"), "{warn}");
        assert!(warn.to_string().contains("revision 2"), "{warn}");
    }

    #[test]
    fn load_prior_reads_files_and_directories() {
        use crate::coordinator::checkpoint::{generation_path, save_partial};
        let dir = std::env::temp_dir()
            .join(format!("bmfpp_load_prior_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        // empty directory: actionable error
        let err = load_prior(&dir).unwrap_err();
        assert!(err.to_string().contains("--checkpoint-every"), "{err}");
        // directory with generations: newest wins
        let mut ckpt = complete_prior();
        for generation in [1u64, 2] {
            ckpt.generation = generation;
            save_partial(&ckpt, &generation_path(&dir, generation)).unwrap();
        }
        assert_eq!(load_prior(&dir).unwrap().generation, 2);
        // a direct file path loads that exact generation
        assert_eq!(load_prior(&generation_path(&dir, 1)).unwrap().generation, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
