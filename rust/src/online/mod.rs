//! Incremental posterior updates — the serve → collect → retrain →
//! hot-swap loop.
//!
//! Posterior Propagation's defining property is that a block's posterior
//! becomes the prior for its dependents (Qin et al., arXiv:1703.00734).
//! This module exploits exactly that for online learning: when a batch of
//! new or corrected ratings arrives ([`RatingDelta`]), only the blocks
//! the batch touches need re-sampling — every clean block's saved
//! posterior passes through unchanged, still serving as the prior for the
//! dirty blocks downstream of it.
//!
//! The loop, end to end:
//!
//! 1. **Collect** a [`RatingDelta`] (new cells, corrected cells,
//!    optionally new row/column ids).
//! 2. **Project** it onto the block grid: [`RatingDelta::dirty_blocks`]
//!    maps each delta cell to its canonical block index with the exact
//!    routing arithmetic of [`Grid::split`](crate::partition::Grid).
//! 3. **Fold** it into the on-disk shard store ([`append_delta`], the
//!    `bmf-pp ingest --append` path): only dirty shards are rewritten
//!    (atomic temp + rename), and the manifest's monotonic `revision` is
//!    bumped.
//! 4. **Update**: `Engine::update` / `Engine::update_store`
//!    (`crate::train::Engine`) build a *pruned* resume — the prior
//!    checkpoint minus the dirty blocks — so the training DAG re-samples
//!    exactly the dirty blocks (with their original per-block seeds, on
//!    the updated data) while every clean block early-returns its
//!    checkpointed posterior, emitting
//!    [`TrainEvent::BlockSkippedClean`](crate::train::TrainEvent). The
//!    aggregation replays in canonical order, so an *empty* delta
//!    reproduces the prior model bit for bit.
//! 5. **Hot-swap**: the `bmf-pp update` CLI writes the result as a new
//!    checkpoint generation; a running `bmf-pp serve` watcher picks it up
//!    automatically.
//!
//! The prior-seeding contract and the double-counting argument (why
//! clean posteriors can feed `aggregate_part` unchanged) are documented
//! on [`update`] and in `docs/ARCHITECTURE.md` ("Online updates").

pub mod delta;
pub mod update;

pub use delta::{append_delta, AppendReport, RatingDelta};
pub use update::{load_prior, UpdateError, UpdateWarning};
